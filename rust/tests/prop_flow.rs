//! Property-based integration tests over the analysis stack: random
//! models through rate propagation, planning and the complexity model,
//! checking the paper's structural invariants.

use cnn_flow::complexity::{layer_cost, model_cost, parallel::fully_parallel_cost, CostOpts};
use cnn_flow::flow::{analyze, analyze_dag, plan_all, schedule::LAT_MERGE, Ratio, UnitPlan};
use cnn_flow::model::{config, zoo, Block, Layer, Model};
use cnn_flow::quant::QModel;
use cnn_flow::sim::pipeline::PipelineSim;
use cnn_flow::util::prop::prop_check;
use cnn_flow::util::Rng;
use cnn_flow::{prop_assert, prop_assert_eq};

/// Generate a random valid chain CNN: a few conv/pool blocks + dense head.
fn random_model(rng: &mut Rng) -> Model {
    let f0 = [12usize, 16, 24, 28][rng.range(0, 3)];
    let d0 = [1usize, 2, 3][rng.range(0, 2)];
    let mut m = Model::new("rand", f0, d0);
    let mut f = f0;
    let blocks = rng.range(1, 3);
    for b in 0..blocks {
        let k = [3usize, 5][rng.range(0, 1)];
        let p = (k - 1) / 2;
        let filters = [4usize, 8, 16][rng.range(0, 2)];
        m.push(Layer::conv(&format!("C{b}"), k, 1, p, filters));
        if f >= 4 && f % 2 == 0 {
            m.push(Layer::maxpool(&format!("P{b}"), 2, 2));
            f /= 2;
        }
    }
    m.push(Layer::dense("F", rng.range(2, 12)));
    m
}

/// Random residual model: stem conv, one or two shortcut blocks drawn
/// from {identity, strided projection, nested identity-in-identity},
/// then a dense head. Shapes valid by construction; merges never land
/// on the final layer.
fn random_residual_model(rng: &mut Rng) -> Model {
    let f0 = [8usize, 9, 12][rng.range(0, 2)];
    let mut m = Model::new("rand-res-flow", f0, 1);
    let mut f = f0;
    let mut c = [4usize, 8][rng.range(0, 1)];
    m.push(Layer::conv("c1", 3, 1, 1, c));
    let n_blocks = 1 + rng.range(0, 1);
    for bi in 0..n_blocks {
        let choice = rng.range(0, 2);
        if choice == 1 && f >= 6 {
            let cout = c * 2;
            m.blocks.push(Block::Residual {
                name: format!("r{bi}"),
                body: vec![
                    Block::Layer(Layer::conv(&format!("r{bi}a"), 3, 2, 1, cout)),
                    Block::Layer(Layer::conv(&format!("r{bi}b"), 3, 1, 1, cout).no_relu()),
                ],
                projection: Some(Layer::conv(&format!("r{bi}p"), 1, 2, 0, cout).no_relu()),
                post_relu: true,
            });
            f = (f - 1) / 2 + 1;
            c = cout;
        } else if choice == 2 {
            let inner = Block::Residual {
                name: format!("r{bi}i"),
                body: vec![
                    Block::Layer(Layer::conv(&format!("r{bi}ia"), 3, 1, 1, c)),
                    Block::Layer(Layer::conv(&format!("r{bi}ib"), 3, 1, 1, c).no_relu()),
                ],
                projection: None,
                post_relu: true,
            };
            m.blocks.push(Block::Residual {
                name: format!("r{bi}"),
                body: vec![
                    Block::Layer(Layer::conv(&format!("r{bi}a"), 3, 1, 1, c)),
                    inner,
                    Block::Layer(Layer::conv(&format!("r{bi}b"), 3, 1, 1, c).no_relu()),
                ],
                projection: None,
                post_relu: rng.range(0, 1) == 1,
            });
        } else {
            m.blocks.push(Block::Residual {
                name: format!("r{bi}"),
                body: vec![
                    Block::Layer(Layer::conv(&format!("r{bi}a"), 3, 1, 1, c)),
                    Block::Layer(Layer::conv(&format!("r{bi}b"), 3, 1, 1, c).no_relu()),
                ],
                projection: None,
                post_relu: rng.range(0, 1) == 1,
            });
        }
    }
    m.push(Layer::dense("fc", 2 + rng.range(0, 4)));
    m
}

#[test]
fn rate_conservation_invariant() {
    // f^2 * d / r (cycles per frame) is constant along a stall-free chain:
    // each layer's output stream carries exactly one frame per input-frame
    // period. This is the paper's continuous-flow condition in one number.
    prop_check(200, 0xF10, |rng| {
        let m = random_model(rng);
        let a = analyze(&m, None).map_err(|e| e.to_string())?;
        let period0 = {
            let l = &a.layers[0];
            Ratio::int((l.shaped.input.f * l.shaped.input.f * l.d_in()) as u64)
                .div(l.r_in)
        };
        for l in &a.layers {
            let f_out = l.shaped.output.f.max(1);
            let period = Ratio::int((f_out * f_out * l.d_out()) as u64).div(l.r_out);
            prop_assert_eq!(
                period,
                period0,
                "layer {} breaks frame-period conservation",
                l.shaped.layer.name
            );
        }
        Ok(())
    });
}

#[test]
fn planner_capacity_covers_work() {
    // A non-stalled conv plan must provide exactly enough kernel-dot slots:
    // #KPUs * C >= d_in * d_out / ceil stuff; and never more than one
    // interleave period of slack.
    prop_check(300, 0xF11, |rng| {
        let d_in = rng.range(1, 32);
        let d_out = rng.range(1, 32);
        let r = Ratio::new(rng.range(1, 64) as u64, rng.range(1, 8) as u64);
        let pl = cnn_flow::report::synthetic_conv_layer(28, 3, 1, d_in, d_out, r);
        if let UnitPlan::Kpu {
            kpus,
            configs,
            interleave,
            stalled,
            ..
        } = pl.plan
        {
            if !stalled {
                let capacity = kpus as u64 * configs as u64;
                let work = (d_in * d_out) as u64;
                prop_assert!(
                    capacity * (interleave as u64) >= work,
                    "capacity {capacity}*I{interleave} < work {work} (d_in={d_in} d_out={d_out} r={r})"
                );
            }
            Ok(())
        } else {
            Err("expected KPU plan".into())
        }
    });
}

#[test]
fn registers_invariant_under_rate() {
    // Table VI's observation: register count is invariant across input
    // data rates for a conv layer (only their organisation changes).
    // The invariant requires the rate to divide the channel count evenly —
    // the paper itself notes the exception ("MobileNet alpha=0.75 ...
    // rounding up ... adds register costs"), so channel counts here are
    // powers of two as in Table VI.
    prop_check(150, 0xF12, |rng| {
        let d_in = 1usize << rng.range(0, 4);
        let d_out = 1usize << rng.range(0, 4);
        let k = [3usize, 5, 7][rng.range(0, 2)];
        let f = k + rng.range(0, 20);
        let base = layer_cost(
            &cnn_flow::report::synthetic_conv_layer(f, k, (k - 1) / 2, d_in, d_out, Ratio::int(d_in as u64)),
            CostOpts::LAYER_ONLY,
        );
        for shift in 1..5u64 {
            let r = Ratio::new(d_in as u64, 1 << shift);
            let pl = cnn_flow::report::synthetic_conv_layer(f, k, (k - 1) / 2, d_in, d_out, r);
            if pl.plan.stalled() {
                continue;
            }
            let cost = layer_cost(&pl, CostOpts::LAYER_ONLY);
            prop_assert_eq!(
                cost.registers,
                base.registers,
                "registers changed at r={r} (f={f},k={k},{d_in}->{d_out})"
            );
        }
        Ok(())
    });
}

#[test]
fn arithmetic_halves_as_rate_halves() {
    // Multipliers scale with ceil(r): halving the rate (above 1 KPU) never
    // increases arithmetic and usually halves it (Table VI shape).
    prop_check(100, 0xF13, |rng| {
        let d_in = 1 << rng.range(1, 4); // 2..16, powers of two
        let d_out = 1 << rng.range(1, 4);
        let mut prev_mults = u64::MAX;
        for shift in 0..4u64 {
            let r = Ratio::new(d_in as u64, 1 << shift);
            let pl = cnn_flow::report::synthetic_conv_layer(20, 3, 1, d_in, d_out, r);
            if pl.plan.stalled() {
                break;
            }
            let cost = layer_cost(&pl, CostOpts::LAYER_ONLY);
            prop_assert!(
                cost.multipliers <= prev_mults,
                "multipliers grew at r={r}"
            );
            prev_mults = cost.multipliers;
        }
        Ok(())
    });
}

#[test]
fn ours_never_beats_reference_on_nothing() {
    // For random models: continuous flow uses <= arithmetic and >= muxes
    // vs the fully-parallel reference, with identical register totals
    // modulo interleaving FIFOs.
    prop_check(100, 0xF14, |rng| {
        let m = random_model(rng);
        let a = analyze(&m, None).map_err(|e| e.to_string())?;
        let ours = model_cost(&plan_all(&a), CostOpts::FULL).total;
        let r = fully_parallel_cost(&a, CostOpts::FULL).total;
        prop_assert!(ours.multipliers <= r.multipliers, "mults");
        prop_assert!(ours.adders <= r.adders, "adders");
        prop_assert!(ours.mux2 >= r.mux2, "muxes");
        Ok(())
    });
}

#[test]
fn json_roundtrip_random_models() {
    prop_check(100, 0xF15, |rng| {
        let m = random_model(rng);
        let text = config::model_to_json(&m);
        let back = config::model_from_json(&text).map_err(|e| e.to_string())?;
        prop_assert_eq!(
            m.param_count().unwrap(),
            back.param_count().unwrap(),
            "params changed in roundtrip"
        );
        let a1 = analyze(&m, None).map_err(|e| e.to_string())?;
        let a2 = analyze(&back, None).map_err(|e| e.to_string())?;
        for (l1, l2) in a1.layers.iter().zip(a2.layers.iter()) {
            prop_assert_eq!(l1.r_out, l2.r_out, "rates changed in roundtrip");
        }
        Ok(())
    });
}

fn gcd(mut a: u64, mut b: u64) -> u64 {
    while b != 0 {
        (a, b) = (b, a % b);
    }
    a.max(1)
}

#[test]
fn eq8_propagation_exact_and_in_lowest_terms() {
    // Random layer stacks: every step must satisfy Eq. 8 exactly —
    // r_out * d_in * s^2 == r_in * d_out under u128 cross-multiplication
    // (independent of Ratio's own mul/reduce code) — and every stored
    // ratio must be in lowest terms. The whole chain must equal the
    // independently-computed big fraction r0 * prod(d_out / (d_in * s^2)).
    prop_check(300, 0xF17, |rng| {
        let r0 = Ratio::new(rng.range(1, 32) as u64, rng.range(1, 32) as u64);
        let mut r = r0;
        let mut d_in = rng.range(1, 32);
        let (mut big_num, mut big_den) = (r0.num() as u128, r0.den() as u128);
        for step in 0..rng.range(1, 8) {
            let d_out = rng.range(1, 32);
            let s = [1usize, 1, 2, 3][rng.range(0, 3)];
            let out = cnn_flow::flow::layer_rate(d_in, d_out, s, r);
            // Eq. 8, cross-multiplied exactly.
            let lhs = out.num() as u128 * (r.den() as u128 * (d_in * s * s) as u128);
            let rhs = out.den() as u128 * (r.num() as u128 * d_out as u128);
            prop_assert_eq!(lhs, rhs, "step {step} violates Eq. 8");
            prop_assert_eq!(
                gcd(out.num(), out.den()),
                1,
                "ratio {out} not in lowest terms"
            );
            prop_assert!(!out.is_zero(), "rate collapsed to zero at step {step}");
            big_num *= d_out as u128;
            big_den *= (d_in * s * s) as u128;
            r = out;
            d_in = d_out;
        }
        // Reduce the big fraction and compare with the chained result.
        let (mut a, mut b) = (big_num, big_den);
        while b != 0 {
            (a, b) = (b, a % b);
        }
        let g = a.max(1);
        prop_assert_eq!(
            (r.num() as u128, r.den() as u128),
            (big_num / g, big_den / g),
            "chained rate != independent product"
        );
        Ok(())
    });
}

#[test]
fn analyze_applies_eq8_to_every_layer() {
    // The model-level walk must agree with the single-layer formula on
    // random chain CNNs, layer by layer, with nonzero lowest-term rates.
    prop_check(150, 0xF18, |rng| {
        let m = random_model(rng);
        let a = analyze(&m, None).map_err(|e| e.to_string())?;
        for l in &a.layers {
            let expect = cnn_flow::flow::layer_rate(
                l.d_in(),
                l.d_out(),
                l.shaped.layer.s,
                l.r_in,
            );
            prop_assert_eq!(
                l.r_out,
                expect,
                "layer {} breaks Eq. 8",
                l.shaped.layer.name
            );
            prop_assert!(!l.r_out.is_zero(), "{} rate is zero", l.shaped.layer.name);
            prop_assert_eq!(
                gcd(l.r_out.num(), l.r_out.den()),
                1,
                "{} rate not reduced",
                l.shaped.layer.name
            );
        }
        Ok(())
    });
}

#[test]
fn planning_never_yields_zero_units_or_configs() {
    // Any rated layer — conv, depthwise, pool, dense — at any positive
    // rate must plan at least one physical unit and one configuration.
    prop_check(400, 0xF19, |rng| {
        let d_in = rng.range(1, 24);
        let d_out = rng.range(1, 24);
        let r = Ratio::new(rng.range(1, 48) as u64, rng.range(1, 48) as u64);
        let k = [2usize, 3, 5][rng.range(0, 2)];
        let f = k + 1 + rng.range(0, 12);
        let layer = match rng.range(0, 3) {
            0 => Layer::conv("c", k, 1, (k - 1) / 2, d_out),
            1 => Layer::dwconv("dw", k, 1, (k - 1) / 2),
            2 => Layer::maxpool("p", k, k),
            _ => Layer::dense("d", d_out),
        };
        let pl = cnn_flow::report::synthetic_layer(layer, f, d_in, r);
        prop_assert!(
            pl.plan.unit_count() >= 1,
            "zero units (d_in={d_in}, d_out={d_out}, r={r}, f={f}, k={k})"
        );
        prop_assert!(
            pl.plan.configs() >= 1,
            "zero configs (d_in={d_in}, d_out={d_out}, r={r}, f={f}, k={k})"
        );
        Ok(())
    });
}

#[test]
fn eq8_residual_merge_rate_is_min_of_branches() {
    // The DAG extension of Eq. 8 (DESIGN.md §11): along every edge the
    // plain Eq. 8 still holds against the layer's incoming rate, a merge
    // clamps its node's outgoing stream to the slower branch (min of the
    // two branch rates), and every reader of a merge node sees the
    // clamped rate — re-derived here independently, edge by edge.
    prop_check(150, 0xF1A, |rng| {
        let m = random_residual_model(rng);
        let shaped = m.shapes().map_err(|e| e.to_string())?;
        let links = m.links().map_err(|e| e.to_string())?;
        let r0 = Ratio::int(m.input.d as u64);
        let d = analyze_dag(&m.name, shaped, &links, r0);
        prop_assert!(
            links.iter().any(|l| l.merge.is_some()),
            "generator must emit merges"
        );
        // Effective (post-clamp) stream rate of every node, re-derived:
        // a merge node's stream runs at min(its own Eq.-8 rate, the
        // shortcut branch's effective rate).
        let mut eff: Vec<Ratio> = d.layers.iter().map(|l| l.r_out).collect();
        for (j, lk) in links.iter().enumerate() {
            if let Some(mg) = &lk.merge {
                let other = match mg.with {
                    Some(w) => eff[w],
                    None => r0,
                };
                eff[j] = eff[j].min(other);
            }
        }
        for (i, lk) in links.iter().enumerate() {
            let l = &d.layers[i];
            let want_in = match lk.src {
                Some(j) => eff[j],
                None => r0,
            };
            prop_assert_eq!(
                l.r_in,
                want_in,
                "{} r_in != merged source rate",
                l.shaped.layer.name
            );
            prop_assert_eq!(
                l.r_out,
                cnn_flow::flow::layer_rate(l.d_in(), l.d_out(), l.shaped.layer.s, l.r_in),
                "{} raw r_out breaks Eq. 8",
                l.shaped.layer.name
            );
        }
        Ok(())
    });
}

#[test]
fn merge_replay_never_reads_empty_fifo() {
    // Schedule-replay contract for residual merges (DESIGN.md §11): the
    // merge node consumes each shortcut pixel at max(branch arrivals) +
    // LAT_MERGE, so every merged output strictly postdates its shortcut
    // arrival — the skip FIFO is never read empty — and the occupancy at
    // every event stays within the `max_occupancy` depth that
    // `PipelineSim::skip_fifo_depths` provisions.
    prop_check(40, 0xF1B, |rng| {
        let m = random_residual_model(rng);
        let seed = 0xB00 + rng.range(0, 400) as u64;
        let qm = QModel::synthesize(&m, seed).map_err(|e| e.to_string())?;
        let sim = PipelineSim::new(qm, None)?;
        let res = sim.schedule.run(8);
        prop_assert!(
            !res.merge_fifo.is_empty(),
            "residual replay must trace its merges"
        );
        for f in &res.merge_fifo {
            prop_assert_eq!(
                f.shortcut_arrivals.len(),
                f.merge_consumes.len(),
                "layer {}: push/pop streams out of sync",
                f.layer
            );
            prop_assert!(f.max_occupancy >= 1, "layer {}: zero FIFO depth", f.layer);
            let mut consumed = 0usize;
            for (p, &a) in f.shortcut_arrivals.iter().enumerate() {
                prop_assert!(
                    f.merge_consumes[p] >= a + LAT_MERGE,
                    "layer {} pixel {p}: merged output at {} does not postdate \
                     shortcut arrival {a} (empty FIFO read)",
                    f.layer,
                    f.merge_consumes[p]
                );
                while consumed < f.merge_consumes.len() && f.merge_consumes[consumed] <= a {
                    consumed += 1;
                }
                prop_assert!(
                    p + 1 - consumed <= f.max_occupancy,
                    "layer {} pixel {p}: occupancy {} overflows depth {}",
                    f.layer,
                    p + 1 - consumed,
                    f.max_occupancy
                );
            }
            prop_assert!(
                sim.skip_fifo_depths
                    .iter()
                    .any(|&(li, depth)| li == f.layer && depth == f.max_occupancy),
                "skip_fifo_depths does not provision layer {} at depth {}",
                f.layer,
                f.max_occupancy
            );
        }
        Ok(())
    });
}

#[test]
fn zoo_residual_fifo_depth_is_frame_count_invariant() {
    // Frame-period conservation holds on both branches of a shortcut, so
    // the skew the skip FIFO absorbs is a warm-up transient: the peak
    // occupancy measured over 8 frames must not grow at 16, and it is
    // exactly what assemble time provisioned.
    for m in [zoo::resnet_micro(), zoo::mobilenet_v2_micro()] {
        let qm = QModel::synthesize(&m, 0x123).unwrap();
        let sim = PipelineSim::new(qm, None).unwrap();
        let depths = |n: usize| -> Vec<(usize, usize)> {
            sim.schedule
                .run(n)
                .merge_fifo
                .iter()
                .map(|f| (f.layer, f.max_occupancy))
                .collect()
        };
        let d8 = depths(8);
        assert_eq!(d8, depths(16), "{}: FIFO depth grew with frame count", m.name);
        assert_eq!(sim.skip_fifo_depths, d8, "{}: assemble-time depths stale", m.name);
    }
}

#[test]
fn stall_detection_matches_cap() {
    // A conv stalls iff ceil(d_in / r) exceeds d_in * d_out (Eq. 17 cap).
    prop_check(300, 0xF16, |rng| {
        let d_in = rng.range(1, 12);
        let d_out = rng.range(1, 12);
        let r = Ratio::new(1, 1 << rng.range(0, 9));
        let pl = cnn_flow::report::synthetic_conv_layer(16, 3, 1, d_in, d_out, r);
        let needs = r.ceil_div_into(d_in as u64);
        let cap = (d_in * d_out) as u64;
        prop_assert_eq!(
            pl.plan.stalled(),
            needs > cap,
            "stall flag wrong (d_in={d_in}, d_out={d_out}, r={r})"
        );
        Ok(())
    });
}
