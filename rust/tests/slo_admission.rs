//! The §12 overload gate (DESIGN.md): under a seeded bursty multi-tenant
//! trace that overwhelms a fixed shard count, model-predictive admission
//! control plus least-predicted-load dispatch must strictly improve the
//! SLO-met fraction over blind round-robin — at equal bit-exactness
//! (both runs reproduce the same interpreter goldens) and with every
//! counter reconciling exactly across the load report, the coordinator
//! intake, and the network layer. Pinned on both net cores.
//!
//! Determinism of the comparison rests on three harness choices:
//!
//! * `clock_hz = 1e6` makes one modelled cycle equal one microsecond, so
//!   a request's `deadline_us` IS its cycle budget with no rounding;
//! * `max_batch` (64) exceeds any per-shard per-tick accumulation and
//!   `batch_deadline` (100 ms) dwarfs a tick's submit burst, so no batch
//!   flushes while a tick is still being submitted — queue depths (the
//!   §12 predictor's denominator) grow deterministically within a tick;
//! * the replay's tick barriers settle everything in flight before the
//!   clock advances, so every tick starts from empty queues.
//!
//! Each model serves a "tight" tenant whose 1 µs deadline no schedule
//! can meet (budget 1 cycle < first-frame latency) and a "loose" tenant
//! whose budget is `first_latency + 8.5 × steady_cycles_per_frame` —
//! met exactly when at most 7 requests sit ahead on the chosen shard.
//! Blind round-robin enqueues the doomed tight requests, letting them
//! occupy the loose class's meetable queue positions; admission sheds
//! them at the door, which is where the strict improvement comes from.

use std::sync::Arc;
use std::time::Duration;

use cnn_flow::coordinator::{
    loadgen, AutoscaleConfig, DispatchKind, MetricsSnapshot, NetMetricsSnapshot, Server,
    ServerConfig,
};
use cnn_flow::net::client::Client;
use cnn_flow::net::{FrontEnd, NetCore};
use cnn_flow::quant::QModel;
use cnn_flow::sim::pipeline::PipelineSim;

/// Two distinct synthetic models (8×8 input, 64 frame elements) so the
/// gate also exercises per-model routing and per-model report sums.
fn two_model_fleet() -> Vec<(String, PipelineSim)> {
    [0xA1u64, 0xB2]
        .iter()
        .enumerate()
        .map(|(i, &seed)| {
            let qm = QModel::synthetic(8, 4, 6, seed);
            (format!("slo_model_{i}"), PipelineSim::new(qm, None).unwrap())
        })
        .collect()
}

fn overload_config(
    dispatch: DispatchKind,
    admission: bool,
    autoscale: Option<AutoscaleConfig>,
) -> ServerConfig {
    ServerConfig {
        workers: 2,
        max_batch: 64,
        queue_depth: 64,
        verify_every: 0,
        clock_hz: 1.0e6,
        batch_deadline: Duration::from_millis(100),
        dispatch,
        admission,
        autoscale,
        ..Default::default()
    }
}

/// Tight (class 1) + loose (class 2) tenant pair per model, bursty
/// calm/burst phases: ticks 0‑2 at weight ×1, ticks 3‑5 at ×3 — the
/// burst is what makes queue positions 8+ (and hence SLO misses)
/// unavoidable for part of the loose class.
fn overload_trace(fleet: &[(String, PipelineSim)]) -> loadgen::MultiTrace {
    let specs: Vec<(String, usize)> = fleet
        .iter()
        .map(|(id, sim)| (id.clone(), sim.input_len()))
        .collect();
    let mut tenants = Vec::new();
    for (m, (_, sim)) in fleet.iter().enumerate() {
        let cpf = sim.predicted.steady_cycles_per_frame.max(1);
        let fl = sim.predicted.first_frame_latency;
        tenants.push(loadgen::Tenant {
            model: m,
            class: 1,
            deadline_us: 1,
            weight: 6,
        });
        tenants.push(loadgen::Tenant {
            model: m,
            class: 2,
            deadline_us: fl + 8 * cpf + cpf / 2,
            weight: 6,
        });
    }
    loadgen::MultiTrace::bursty(0x510A, &specs, &tenants, 6, 3, 1, 3)
}

struct RunOutcome {
    report: loadgen::MultiLoadReport,
    coord: MetricsSnapshot,
    net: NetMetricsSnapshot,
}

/// One full overload replay over TCP: fresh fleet, chosen net core,
/// window ≥ the largest per-tick burst (72) so tick barriers are the
/// only settle points.
fn run(
    core: NetCore,
    cfg: ServerConfig,
    trace: &loadgen::MultiTrace,
    expected: &[Vec<i64>],
) -> RunOutcome {
    let coord = Arc::new(Server::start_multi(two_model_fleet(), cfg, None).unwrap());
    let mut net = FrontEnd::bind(core, "127.0.0.1:0", Arc::clone(&coord)).unwrap();
    let client = Client::connect(&net.local_addr().to_string(), 128).unwrap();
    let report = loadgen::replay_net(&client, trace, 128, Some(expected));
    drop(client);
    let net_snap = net.shutdown();
    let coord_snap = coord.metrics();
    RunOutcome {
        report,
        coord: coord_snap,
        net: net_snap,
    }
}

fn class(report: &loadgen::MultiLoadReport, class: u8) -> loadgen::ClassReport {
    *report
        .classes
        .iter()
        .find(|c| c.class == class)
        .expect("class missing from report")
}

/// Exact three-way reconciliation: load report ↔ coordinator intake ↔
/// net counters, plus per-model and per-class partitions summing to the
/// aggregate. Holds identically for blind and predictive runs.
fn check_reconciliation(o: &RunOutcome, trace: &loadgen::MultiTrace) {
    let total = trace.requests.len() as u64;
    let r = &o.report;
    assert_eq!(r.aggregate.mismatched, 0, "diverged from interpreter goldens");
    assert_eq!(r.aggregate.rejected, 0, "queues must never fill in this harness");
    assert_eq!(r.aggregate.dropped, 0);
    assert_eq!(r.aggregate.submitted, total);
    assert_eq!(r.aggregate.ok + r.aggregate.shed, total);

    assert_eq!(r.per_model.iter().map(|p| p.ok).sum::<u64>(), r.aggregate.ok);
    assert_eq!(r.per_model.iter().map(|p| p.shed).sum::<u64>(), r.aggregate.shed);
    assert_eq!(
        r.per_model.iter().map(|p| p.submitted).sum::<u64>(),
        r.aggregate.submitted
    );
    assert_eq!(r.classes.iter().map(|c| c.submitted).sum::<u64>(), total);
    assert_eq!(r.classes.iter().map(|c| c.met).sum::<u64>(), r.aggregate.slo_met);
    assert_eq!(r.classes.iter().map(|c| c.shed).sum::<u64>(), r.aggregate.shed);

    // Coordinator intake partitions exactly (§12 contract):
    // submitted == accepted + rejected + shed, accepted == completed +
    // errored — every drained request is accounted once.
    let m = &o.coord;
    assert_eq!(m.completed, r.aggregate.ok);
    assert_eq!(m.shed, r.aggregate.shed);
    assert_eq!(m.rejected, 0);
    assert_eq!(m.errored, 0);
    assert_eq!(m.accepted, m.completed + m.errored);
    assert_eq!(m.accepted + m.rejected + m.shed, total);

    // The net layer saw every request and mapped shed 1:1 to SloMiss.
    assert_eq!(o.net.requests, total);
    assert_eq!(o.net.responses_ok, m.completed);
    assert_eq!(o.net.err_slo_miss, m.shed);
    assert_eq!(o.net.errors_total(), o.net.err_slo_miss);
    assert_eq!(o.net.err_malformed, 0);
}

fn overload_gate(core: NetCore) {
    let fleet = two_model_fleet();
    let trace = overload_trace(&fleet);
    let total = trace.requests.len() as u64;
    // 4 tenants × weight 6 × (3 calm + 3×3 burst tick-weights) = 288.
    assert_eq!(total, 288, "trace shape drifted; the margin math assumes this");
    let golden_refs: Vec<&PipelineSim> = fleet.iter().map(|(_, s)| s).collect();
    let expected = loadgen::golden_outputs_multi(&golden_refs, &trace);

    let blind = run(
        core,
        overload_config(DispatchKind::RoundRobin, false, None),
        &trace,
        &expected,
    );
    // 2:2 autoscale bounds: the controller runs on every submit but has
    // no headroom, so the comparison stays a pure dispatch/admission
    // experiment while still exercising the autoscale tick path.
    let predictive = run(
        core,
        overload_config(
            DispatchKind::Predictive,
            true,
            Some(AutoscaleConfig {
                min_workers: 2,
                max_workers: 2,
            }),
        ),
        &trace,
        &expected,
    );

    check_reconciliation(&blind, &trace);
    check_reconciliation(&predictive, &trace);

    // Blind mode admits everything and still reports misses honestly.
    assert_eq!(blind.report.aggregate.shed, 0);
    assert_eq!(blind.report.aggregate.ok, total);
    let b_tight = class(&blind.report, 1);
    assert_eq!(b_tight.met, 0, "a 1 µs budget is below first-frame latency");
    assert_eq!(b_tight.ok, b_tight.submitted);

    // Admission sheds every unmeetable request at the door.
    let p_tight = class(&predictive.report, 1);
    assert_eq!(p_tight.met, 0);
    assert_eq!(p_tight.ok, 0);
    assert_eq!(p_tight.shed, p_tight.submitted);

    // The overload is real: blind dispatch misses part of the loose
    // class during bursts (doomed tight requests hold its queue slots).
    let b_loose = class(&blind.report, 2);
    let p_loose = class(&predictive.report, 2);
    assert_eq!(b_loose.with_deadline, p_loose.with_deadline);
    assert!(
        b_loose.met < b_loose.with_deadline,
        "blind run met every loose deadline ({}/{}) — no overload, gate is vacuous",
        b_loose.met,
        b_loose.with_deadline
    );

    // THE gate: predictive admission + dispatch strictly improves the
    // SLO-met fraction at equal bit-exactness.
    assert!(
        p_loose.met > b_loose.met,
        "loose class: predictive met {} vs blind met {} of {}",
        p_loose.met,
        b_loose.met,
        b_loose.with_deadline
    );
    assert!(p_loose.slo_met_fraction() > b_loose.slo_met_fraction());
    assert!(
        predictive.report.aggregate.slo_met > blind.report.aggregate.slo_met,
        "aggregate: predictive {} vs blind {}",
        predictive.report.aggregate.slo_met,
        blind.report.aggregate.slo_met
    );
    assert!(predictive.report.slo_met_fraction() > blind.report.slo_met_fraction());

    // min == max bounds: the tick evaluated but never moved.
    assert_eq!(predictive.coord.scale_up_events, 0);
    assert_eq!(predictive.coord.scale_down_events, 0);
    assert_eq!(predictive.coord.active_workers, 4, "2 shards × 2 models");
    assert_eq!(blind.coord.active_workers, 4);
}

#[test]
fn predictive_admission_beats_blind_dispatch_threaded() {
    overload_gate(NetCore::Threaded);
}

#[cfg(unix)]
#[test]
fn predictive_admission_beats_blind_dispatch_evented() {
    overload_gate(NetCore::Evented);
}
