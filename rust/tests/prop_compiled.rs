//! Property tests for the compile-once execution engine (DESIGN.md §4):
//! across seeded random zoo-shaped models (varying kernel/stride/padding
//! and conv/dwconv/pool/dense mixes) and random int8 frames,
//!
//! * `CompiledPipeline::execute` must be **bit-identical** to the fused
//!   interpreter (`PipelineSim::run_interpreted`), and
//! * the analytic schedule (`ScheduleModel` replay and the closed-form
//!   `SchedulePrediction`) must reproduce the interpreter's
//!   `total_cycles` / `first_frame_latency` / `cycles_per_frame` and
//!   per-layer statistics **exactly**.

use cnn_flow::flow::Ratio;
use cnn_flow::model::{zoo, Block, Layer, Model};
use cnn_flow::quant::{QKind, QLayer, QModel};
use cnn_flow::sim::compiled::CompiledPipeline;
use cnn_flow::sim::pipeline::PipelineSim;
use cnn_flow::util::prop::prop_check;
use cnn_flow::util::Rng;
use cnn_flow::{prop_assert, prop_assert_eq};

/// Build a random small quantized model mixing every simulated layer
/// kind, with valid shape/rate chains by construction.
fn random_qmodel(rng: &mut Rng) -> QModel {
    let f0 = [6usize, 8, 9][rng.range(0, 2)];
    let c0 = rng.range(1, 2);
    let (mut f, mut c) = (f0, c0);
    let mut layers: Vec<QLayer> = Vec::new();
    let n_window = rng.range(1, 3);
    for i in 0..n_window {
        if f < 4 {
            break;
        }
        match rng.range(0, 3) {
            0 => {
                // Standard conv with varying k/s/p.
                let k = [1usize, 3][rng.range(0, 1)];
                let s = if f >= 6 { rng.range(1, 2) } else { 1 };
                let p = if k == 3 && rng.range(0, 1) == 1 { 1 } else { 0 };
                let cout = rng.range(1, 4);
                let f_out = (f + 2 * p - k) / s + 1;
                layers.push(QLayer {
                    name: format!("C{i}"),
                    kind: QKind::Conv,
                    k,
                    s,
                    p,
                    relu: rng.range(0, 1) == 1,
                    w_q: (0..k * k * c * cout)
                        .map(|_| rng.range(0, 16) as i64 - 8)
                        .collect(),
                    w_shape: vec![k, k, c, cout],
                    b_q: (0..cout).map(|_| rng.range(0, 40) as i64 - 20).collect(),
                    m: 0.002 + rng.f64() as f32 * 0.01,
                    in_shape: [f, f, c],
                    out_shape: [f_out, f_out, cout],
                });
                f = f_out;
                c = cout;
            }
            1 => {
                // Depthwise conv.
                let k = 3;
                let s = if f >= 6 { rng.range(1, 2) } else { 1 };
                let p = rng.range(0, 1);
                let f_out = (f + 2 * p - k) / s + 1;
                layers.push(QLayer {
                    name: format!("D{i}"),
                    kind: QKind::DwConv,
                    k,
                    s,
                    p,
                    relu: rng.range(0, 1) == 1,
                    w_q: (0..k * k * c).map(|_| rng.range(0, 16) as i64 - 8).collect(),
                    w_shape: vec![k, k, c],
                    b_q: (0..c).map(|_| rng.range(0, 20) as i64 - 10).collect(),
                    m: 0.01 + rng.f64() as f32 * 0.02,
                    in_shape: [f, f, c],
                    out_shape: [f_out, f_out, c],
                });
                f = f_out;
            }
            2 => {
                // Max pooling.
                let f_out = (f - 2) / 2 + 1;
                layers.push(QLayer {
                    name: format!("P{i}"),
                    kind: QKind::MaxPool,
                    k: 2,
                    s: 2,
                    p: 0,
                    relu: false,
                    w_q: vec![],
                    w_shape: vec![],
                    b_q: vec![],
                    m: 0.0,
                    in_shape: [f, f, c],
                    out_shape: [f_out, f_out, c],
                });
                f = f_out;
            }
            _ => {
                // Average pooling (depthwise conv with constant weights).
                let f_out = (f - 2) / 2 + 1;
                layers.push(QLayer {
                    name: format!("A{i}"),
                    kind: QKind::AvgPool,
                    k: 2,
                    s: 2,
                    p: 0,
                    relu: false,
                    w_q: vec![1; 2 * 2 * c],
                    w_shape: vec![2, 2, c],
                    b_q: vec![0; c],
                    m: 0.05 + rng.f64() as f32 * 0.1,
                    in_shape: [f, f, c],
                    out_shape: [f_out, f_out, c],
                });
                f = f_out;
            }
        }
    }
    let feats = f * f * c;
    let units = rng.range(2, 6);
    layers.push(QLayer {
        name: "F".into(),
        kind: QKind::Dense,
        k: 0,
        s: 1,
        p: 0,
        relu: false,
        w_q: (0..units * feats)
            .map(|_| rng.range(0, 10) as i64 - 5)
            .collect(),
        w_shape: vec![units, feats],
        b_q: (0..units).map(|_| rng.range(0, 20) as i64 - 10).collect(),
        m: 0.0,
        in_shape: [1, 1, feats],
        out_shape: [1, 1, units],
    });
    QModel {
        name: "rand-compiled".into(),
        input_shape: [f0, f0, c0],
        input_scale: 1.0,
        layers,
        topology: vec![],
        test_vectors: vec![],
        qat_accuracy: 0.0,
    }
}

/// Random residual-graph model: a stem conv, then one or two residual
/// blocks drawn from {identity shortcut, strided projection shortcut,
/// nested identity-in-identity}, then a dense head. Shapes are valid by
/// construction; a merge never lands on the final layer (the quantized
/// IR keeps the head at accumulator scale).
fn random_residual_model(rng: &mut Rng) -> Model {
    let f0 = [8usize, 9, 12][rng.range(0, 2)];
    let mut m = Model::new("rand-residual", f0, 1);
    let mut f = f0;
    let mut c = [4usize, 8][rng.range(0, 1)];
    m.push(Layer::conv("c1", 3, 1, 1, c));
    let n_blocks = 1 + rng.range(0, 1);
    for bi in 0..n_blocks {
        let choice = rng.range(0, 2);
        if choice == 1 && f >= 6 {
            // Strided projection shortcut: both branches downsample to
            // the same (f - 1) / 2 + 1 map, channels double.
            let cout = c * 2;
            m.blocks.push(Block::Residual {
                name: format!("r{bi}"),
                body: vec![
                    Block::Layer(Layer::conv(&format!("r{bi}a"), 3, 2, 1, cout)),
                    Block::Layer(Layer::conv(&format!("r{bi}b"), 3, 1, 1, cout).no_relu()),
                ],
                projection: Some(Layer::conv(&format!("r{bi}p"), 1, 2, 0, cout).no_relu()),
                post_relu: true,
            });
            f = (f - 1) / 2 + 1;
            c = cout;
        } else if choice == 2 {
            // Nested: an identity residual inside the body of another.
            let inner = Block::Residual {
                name: format!("r{bi}i"),
                body: vec![
                    Block::Layer(Layer::conv(&format!("r{bi}ia"), 3, 1, 1, c)),
                    Block::Layer(Layer::conv(&format!("r{bi}ib"), 3, 1, 1, c).no_relu()),
                ],
                projection: None,
                post_relu: true,
            };
            m.blocks.push(Block::Residual {
                name: format!("r{bi}"),
                body: vec![
                    Block::Layer(Layer::conv(&format!("r{bi}a"), 3, 1, 1, c)),
                    inner,
                    Block::Layer(Layer::conv(&format!("r{bi}b"), 3, 1, 1, c).no_relu()),
                ],
                projection: None,
                post_relu: rng.range(0, 1) == 1,
            });
        } else {
            // Identity shortcut: body keeps the shape; ReLU (ResNet) or
            // linear (MobileNetV2) merge.
            m.blocks.push(Block::Residual {
                name: format!("r{bi}"),
                body: vec![
                    Block::Layer(Layer::conv(&format!("r{bi}a"), 3, 1, 1, c)),
                    Block::Layer(Layer::conv(&format!("r{bi}b"), 3, 1, 1, c).no_relu()),
                ],
                projection: None,
                post_relu: rng.range(0, 1) == 1,
            });
        }
    }
    m.push(Layer::dense("fc", 2 + rng.range(0, 4)));
    m
}

fn rand_frames(rng: &mut Rng, n: usize, len: usize) -> Vec<Vec<i64>> {
    (0..n)
        .map(|_| (0..len).map(|_| rng.int8() as i64).collect())
        .collect()
}

#[test]
fn compiled_values_match_interpreter() {
    prop_check(50, 0xC0F1, |rng| {
        let qm = random_qmodel(rng);
        let len: usize = qm.input_shape.iter().product();
        let sim = PipelineSim::new(qm.clone(), None)?;
        let mut engine = CompiledPipeline::lower(&qm)?;
        for _ in 0..3 {
            let x: Vec<i64> = (0..len).map(|_| rng.int8() as i64).collect();
            let want = sim.run_interpreted(std::slice::from_ref(&x))?.outputs[0].clone();
            let got = engine.execute(&x)?.to_vec();
            prop_assert_eq!(got, want, "standalone engine diverged");
            let fast = sim.run(std::slice::from_ref(&x))?;
            prop_assert_eq!(
                fast.outputs[0].clone(),
                want,
                "PipelineSim::run diverged"
            );
        }
        Ok(())
    });
}

#[test]
fn execute_batch_bit_identical_to_execute() {
    // The batched tier's contract (DESIGN.md §6): for random models
    // mixing conv/dwconv/pool/dense and any batch size — full lane
    // tiles, ragged tails, the B = 1 dispatch — `execute_batch` is
    // bit-identical per frame to `execute` (and so to the interpreter).
    prop_check(30, 0xBA7C, |rng| {
        let qm = random_qmodel(rng);
        let len: usize = qm.input_shape.iter().product();
        let sim = PipelineSim::new(qm.clone(), None)?;
        let mut engine = CompiledPipeline::lower(&qm)?;
        for b in [1usize, 3, 8, 13] {
            let frames = rand_frames(rng, b, len);
            let mut want = Vec::with_capacity(b);
            for f in &frames {
                want.push(engine.execute(f)?.to_vec());
            }
            let refs: Vec<&[i64]> = frames.iter().map(|f| f.as_slice()).collect();
            let got = engine.execute_batch(&refs)?;
            prop_assert_eq!(&got, &want, "batch B={b} diverged from execute");
            let oracle = sim.run_interpreted(&frames)?;
            prop_assert_eq!(got, oracle.outputs, "batch B={b} diverged from the interpreter");
        }
        Ok(())
    });
}

#[test]
fn batch_prediction_divergence_is_zero_at_any_size() {
    // Closed-form batched cycle figures must equal the exact schedule
    // replay at every batch size (the serving tier's cycle contract).
    prop_check(20, 0xBA7D, |rng| {
        let qm = random_qmodel(rng);
        let sim = PipelineSim::new(qm, None)?;
        for b in [1usize, 2, 5, 9, 33] {
            let bp = sim.predicted.batched(b);
            let replay = sim.schedule.run(b);
            prop_assert!(bp.exact, "full-rate model must certify exact batch figures (B={b})");
            prop_assert_eq!(
                bp.total_cycles,
                replay.total_cycles,
                "batched total_cycles diverged (B={b})"
            );
            prop_assert_eq!(
                bp.steady_cycles_per_frame,
                replay.cycles_per_frame,
                "batched cycles/frame diverged (B={b})"
            );
            for (u, s) in bp.utilization.iter().zip(&replay.stats) {
                prop_assert!(
                    (u - s.utilization).abs() < 1e-12,
                    "batched utilisation diverged (B={b})"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn folded_engine_bit_identical_across_every_tier_on_random_chains() {
    // The folded tier's contract (DESIGN.md §9): for random models mixing
    // conv/dwconv/pool/dense, the rate-aware folded engine — fused
    // low-rate pairs, register-blocked kernels — is bit-identical to the
    // unfolded compiled engine, the batched tier, and the interpreter,
    // frame for frame, at every batch size.
    prop_check(30, 0xF01D, |rng| {
        let qm = random_qmodel(rng);
        let len: usize = qm.input_shape.iter().product();
        let sim = PipelineSim::new(qm.clone(), None)?;
        let mut engine = CompiledPipeline::lower(&qm)?;
        let mut folded = sim.folded.clone();
        for b in [1usize, 3, 8, 13] {
            let frames = rand_frames(rng, b, len);
            let oracle = sim.run_interpreted(&frames)?;
            for (f, want) in frames.iter().zip(&oracle.outputs) {
                let got = folded.execute(f)?.to_vec();
                prop_assert_eq!(&got, want, "folded execute diverged (B={b})");
            }
            let refs: Vec<&[i64]> = frames.iter().map(|f| f.as_slice()).collect();
            let got = folded.execute_batch(&refs)?;
            prop_assert_eq!(
                &got,
                &oracle.outputs,
                "folded batch B={b} diverged from the interpreter"
            );
            prop_assert_eq!(
                got,
                engine.execute_batch(&refs)?,
                "folded batch B={b} diverged from the unfolded batched tier"
            );
        }
        Ok(())
    });
}

#[test]
fn folded_prediction_divergence_is_zero_at_any_size() {
    // The FoldedPrediction certificate: the closed-form folded cycle
    // figures must equal the exact schedule replay accounted against the
    // same folded unit counts, at every batch size — folding
    // time-multiplexes units, it never moves a completion cycle.
    prop_check(20, 0xF01E, |rng| {
        let qm = random_qmodel(rng);
        let sim = PipelineSim::new(qm, None)?;
        let folds = &sim.fold_factors;
        prop_assert_eq!(
            folds.len(),
            sim.qmodel.layers.len(),
            "one fold factor per layer"
        );
        prop_assert!(
            folds.iter().all(|&f| f >= 1),
            "fold factors are at least 1"
        );
        for b in [1usize, 2, 5, 9, 33] {
            let fp = sim.predicted.folded(b, folds);
            let replay = sim.schedule.run_folded(b, folds);
            prop_assert!(fp.exact, "full-rate model must certify folded figures (B={b})");
            prop_assert_eq!(
                fp.total_cycles,
                replay.total_cycles,
                "folded total_cycles diverged (B={b})"
            );
            prop_assert_eq!(
                fp.steady_cycles_per_frame,
                replay.steady_cycles_per_frame,
                "folded cycles/frame diverged (B={b})"
            );
            prop_assert_eq!(
                fp.first_frame_latency,
                replay.first_frame_latency,
                "folded frame-0 latency diverged (B={b})"
            );
            prop_assert_eq!(
                &fp.folded_units,
                &replay.folded_units,
                "folded unit counts diverged (B={b})"
            );
            for (u, r) in fp.utilization.iter().zip(&replay.utilization) {
                prop_assert!(
                    (u - r).abs() < 1e-12,
                    "folded utilisation diverged (B={b})"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn serving_zoo_configs_bit_identical_across_every_tier() {
    // The multi-model serving contract (DESIGN.md §7): every serving-zoo
    // config — MobileNet-like depthwise stack, VGG-style net, digits CNN,
    // JSC MLP — lowers and runs **bit-identical** across the fused
    // interpreter, single-frame `execute`, and the batched
    // `execute_batch`, and the closed-form `SchedulePrediction` matches
    // the exact `ScheduleModel` replay cycle-for-cycle.
    let mut rng = Rng::new(0x5E2F);
    for (i, model) in zoo::serving_zoo().iter().enumerate() {
        let qm = QModel::synthesize(model, 0x600 + i as u64)
            .unwrap_or_else(|e| panic!("{}: synthesize failed: {e}", model.name));
        let sim = PipelineSim::new(qm.clone(), None)
            .unwrap_or_else(|e| panic!("{}: lowering failed: {e}", model.name));
        let mut engine = CompiledPipeline::lower(&qm).unwrap();
        let len = sim.input_len();
        let frames = rand_frames(&mut rng, 5, len);
        let oracle = sim.run_interpreted(&frames).unwrap();
        // Tier 1: single-frame compiled execution.
        for (f, want) in frames.iter().zip(&oracle.outputs) {
            assert_eq!(
                engine.execute(f).unwrap(),
                want.as_slice(),
                "{}: execute diverged from the interpreter",
                model.name
            );
        }
        // Tier 2: one batched traversal over the whole stream.
        let refs: Vec<&[i64]> = frames.iter().map(|f| f.as_slice()).collect();
        assert_eq!(
            engine.execute_batch(&refs).unwrap(),
            oracle.outputs,
            "{}: execute_batch diverged from the interpreter",
            model.name
        );
        // Tier 2b: the rate-aware folded engine, and its certificate —
        // the closed-form folded figures must equal the exact replay.
        let mut folded = sim.folded.clone();
        assert_eq!(
            folded.execute_batch(&refs).unwrap(),
            oracle.outputs,
            "{}: folded execute_batch diverged from the interpreter",
            model.name
        );
        for n in [1usize, frames.len(), 40] {
            let fp = sim.predicted.folded(n, &sim.fold_factors);
            let replay = sim.schedule.run_folded(n, &sim.fold_factors);
            assert!(fp.exact, "{}: folded figures not certified", model.name);
            assert_eq!(
                fp.total_cycles, replay.total_cycles,
                "{}: folded total_cycles diverged at n={n}",
                model.name
            );
            assert_eq!(
                fp.first_frame_latency, replay.first_frame_latency,
                "{}: folded frame-0 latency diverged at n={n}",
                model.name
            );
        }
        // Tier 3: the analytic schedule. The exact replay must reproduce
        // the interpreter's cycles, and the closed-form prediction must
        // reproduce the replay at every count (these full-rate plans
        // certify their steady state).
        assert!(
            sim.predicted.exact,
            "{}: full-rate serving config failed to certify steady state",
            model.name
        );
        for n in [1usize, 2, frames.len(), 40] {
            let replay = sim.schedule.run(n);
            assert_eq!(
                sim.predicted.total_cycles(n),
                replay.total_cycles,
                "{}: prediction total_cycles diverged at n={n}",
                model.name
            );
            assert_eq!(
                sim.predicted.cycles_per_frame(n),
                replay.cycles_per_frame,
                "{}: prediction cycles/frame diverged at n={n}",
                model.name
            );
        }
        let replay = sim.schedule.run(frames.len());
        assert_eq!(
            replay.total_cycles, oracle.total_cycles,
            "{}: schedule replay diverged from the interpreter",
            model.name
        );
        assert_eq!(
            replay.first_frame_latency, oracle.first_frame_latency,
            "{}: frame-0 latency diverged",
            model.name
        );
    }
}

#[test]
fn schedule_matches_interpreter_exactly() {
    prop_check(40, 0xC0F2, |rng| {
        let qm = random_qmodel(rng);
        let len: usize = qm.input_shape.iter().product();
        let sim = PipelineSim::new(qm.clone(), None)?;
        for n in [1usize, 2, 3, 6] {
            let frames = rand_frames(rng, n, len);
            let fast = sim.run(&frames)?;
            let oracle = sim.run_interpreted(&frames)?;
            prop_assert_eq!(fast.total_cycles, oracle.total_cycles, "total n={n}");
            prop_assert_eq!(
                fast.first_frame_latency,
                oracle.first_frame_latency,
                "latency n={n}"
            );
            prop_assert_eq!(
                fast.cycles_per_frame,
                oracle.cycles_per_frame,
                "cycles/frame n={n}"
            );
            for (a, b) in fast.stats.iter().zip(oracle.stats.iter()) {
                prop_assert_eq!(a.useful_ops, b.useful_ops, "{} ops n={n}", a.name);
                prop_assert_eq!(a.first_cycle, b.first_cycle, "{} first n={n}", a.name);
                prop_assert_eq!(a.last_cycle, b.last_cycle, "{} last n={n}", a.name);
                prop_assert!(
                    (a.utilization - b.utilization).abs() < 1e-12,
                    "{} utilization n={n}",
                    a.name
                );
            }
            // The closed form answers the same questions without replay.
            if sim.predicted.exact || n <= sim.predicted.frames_observed() {
                prop_assert_eq!(
                    sim.predicted.total_cycles(n),
                    oracle.total_cycles,
                    "prediction total n={n}"
                );
                prop_assert_eq!(
                    sim.predicted.cycles_per_frame(n),
                    oracle.cycles_per_frame,
                    "prediction cycles/frame n={n}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prediction_extrapolates_beyond_observation() {
    // For full-rate models the steady state certifies quickly, and the
    // closed form must stay exact far past its observed prefix.
    prop_check(15, 0xC0F3, |rng| {
        let qm = random_qmodel(rng);
        let len: usize = qm.input_shape.iter().product();
        let sim = PipelineSim::new(qm.clone(), None)?;
        prop_assert!(
            sim.predicted.exact,
            "full-rate model failed to certify steady state"
        );
        let n = sim.predicted.frames_observed() + 8;
        let frames = rand_frames(rng, n, len);
        let oracle = sim.run_interpreted(&frames)?;
        prop_assert_eq!(
            sim.predicted.total_cycles(n),
            oracle.total_cycles,
            "extrapolated total"
        );
        prop_assert_eq!(
            sim.predicted.cycles_per_frame(n),
            oracle.cycles_per_frame,
            "extrapolated cycles/frame"
        );
        Ok(())
    });
}

#[test]
fn schedule_replay_exact_at_scaled_rates() {
    // Rational r0 sweeps (Table X territory): the value-free replay must
    // still track the interpreter cycle-for-cycle.
    prop_check(15, 0xC0F4, |rng| {
        let qm = random_qmodel(rng);
        let len: usize = qm.input_shape.iter().product();
        let d0 = qm.input_shape[2] as u64;
        for r0 in [Ratio::new(d0, 2), Ratio::new(d0, 3)] {
            let sim = PipelineSim::new(qm.clone(), Some(r0))?;
            let frames = rand_frames(rng, 4, len);
            let fast = sim.run(&frames)?;
            let oracle = sim.run_interpreted(&frames)?;
            prop_assert_eq!(fast.outputs, oracle.outputs, "values r0={r0}");
            prop_assert_eq!(fast.total_cycles, oracle.total_cycles, "total r0={r0}");
            prop_assert_eq!(
                fast.cycles_per_frame,
                oracle.cycles_per_frame,
                "cycles/frame r0={r0}"
            );
        }
        Ok(())
    });
}

#[test]
fn residual_graphs_bit_identical_across_every_tier() {
    // The residual certification fleet (DESIGN.md §11): seeded random
    // residual DAGs — identity and projection shortcuts, nested bodies,
    // mixed strides, ReLU and linear merges — must lower through
    // `QModel::synthesize` and run bit-identical across the fused
    // interpreter, the compiled engine, the batched tier, and the
    // folded engine, while the DAG-aware schedule replay and the
    // closed-form prediction reproduce the interpreter's cycles exactly.
    prop_check(25, 0xD0D6, |rng| {
        let model = random_residual_model(rng);
        let seed = 0x900 + rng.range(0, 500) as u64;
        let qm = QModel::synthesize(&model, seed).map_err(|e| e.to_string())?;
        prop_assert!(!qm.is_chain(), "generator must emit a residual topology");
        let len: usize = qm.input_shape.iter().product();
        let sim = PipelineSim::new(qm.clone(), None)?;
        let mut engine = CompiledPipeline::lower(&qm)?;
        let mut folded = sim.folded.clone();
        for b in [1usize, 3, 8] {
            let frames = rand_frames(rng, b, len);
            let oracle = sim.run_interpreted(&frames)?;
            for (x, want) in frames.iter().zip(&oracle.outputs) {
                prop_assert_eq!(
                    engine.execute(x)?.to_vec(),
                    want.clone(),
                    "execute diverged (B={b})"
                );
                prop_assert_eq!(
                    folded.execute(x)?.to_vec(),
                    want.clone(),
                    "folded execute diverged (B={b})"
                );
            }
            let refs: Vec<&[i64]> = frames.iter().map(|f| f.as_slice()).collect();
            prop_assert_eq!(
                engine.execute_batch(&refs)?,
                oracle.outputs.clone(),
                "execute_batch diverged (B={b})"
            );
            prop_assert_eq!(
                folded.execute_batch(&refs)?,
                oracle.outputs.clone(),
                "folded execute_batch diverged (B={b})"
            );
            // Cycle certification: the DAG-aware schedule replay is the
            // interpreter's cycle model, merge epilogue included.
            let fast = sim.run(&frames)?;
            prop_assert_eq!(fast.outputs, oracle.outputs.clone(), "run diverged (B={b})");
            prop_assert_eq!(fast.total_cycles, oracle.total_cycles, "total_cycles (B={b})");
            prop_assert_eq!(
                fast.first_frame_latency,
                oracle.first_frame_latency,
                "frame-0 latency (B={b})"
            );
            prop_assert_eq!(
                fast.cycles_per_frame,
                oracle.cycles_per_frame,
                "cycles/frame (B={b})"
            );
            for (a, o) in fast.stats.iter().zip(oracle.stats.iter()) {
                prop_assert_eq!(a.useful_ops, o.useful_ops, "{} ops (B={b})", a.name);
                prop_assert_eq!(a.first_cycle, o.first_cycle, "{} first (B={b})", a.name);
                prop_assert_eq!(a.last_cycle, o.last_cycle, "{} last (B={b})", a.name);
            }
            if sim.predicted.exact || b <= sim.predicted.frames_observed() {
                prop_assert_eq!(
                    sim.predicted.total_cycles(b),
                    oracle.total_cycles,
                    "prediction total (B={b})"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn reference_plan_compiled_equivalence() {
    prop_check(20, 0xC0F5, |rng| {
        let qm = random_qmodel(rng);
        let len: usize = qm.input_shape.iter().product();
        let ours = PipelineSim::new(qm.clone(), None)?;
        let reference = PipelineSim::new_reference(qm)?;
        let frames = rand_frames(rng, 2, len);
        prop_assert_eq!(
            ours.run(&frames)?.outputs,
            reference.run(&frames)?.outputs,
            "reference plan values diverged"
        );
        Ok(())
    });
}
