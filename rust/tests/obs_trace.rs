//! Integration tests for the observability tier (DESIGN.md §13):
//! flight-recorder span accounting under overload (in-process and over
//! both network cores), byte-deterministic virtual-clock traces across
//! seeded replays, profiler-on ≡ profiler-off output bit-exactness on
//! the serving zoo, and Prometheus exposition format linting through
//! the wire protocol's `MetricsText` request.

use std::sync::atomic::AtomicU64;
use std::sync::Arc;
use std::time::Duration;

use cnn_flow::coordinator::{loadgen, EngineKind, Server, ServerConfig};
use cnn_flow::model::zoo;
use cnn_flow::net::{Client, FrontEnd, NetCore};
use cnn_flow::obs::{lint, stage_summary, Clock, SpanOutcome, SpanRecord};
use cnn_flow::quant::QModel;
use cnn_flow::sim::pipeline::PipelineSim;

/// Two heterogeneous serving-zoo models, synthesized with fixed seeds —
/// small enough for the determinism/overload loops, heterogeneous
/// enough to exercise per-group recorders and profilers.
fn two_model_fleet() -> Vec<(String, PipelineSim)> {
    [zoo::digits_cnn(), zoo::mobilenet_micro()]
        .iter()
        .enumerate()
        .map(|(i, m)| {
            let qm = QModel::synthesize(m, 0x0B50 + i as u64).unwrap();
            (m.name.clone(), PipelineSim::new(qm, None).unwrap())
        })
        .collect()
}

/// The full serving zoo (chains plus the residual DAGs) — the fleet the
/// profiler exactness test replays.
fn full_zoo_fleet() -> Vec<(String, PipelineSim)> {
    zoo::serving_zoo()
        .iter()
        .enumerate()
        .map(|(i, m)| {
            let qm = QModel::synthesize(m, 0x7CB0 + i as u64).unwrap();
            (m.name.clone(), PipelineSim::new(qm, None).unwrap())
        })
        .collect()
}

fn fleet_specs(fleet: &[(String, PipelineSim)]) -> Vec<(String, usize)> {
    fleet
        .iter()
        .map(|(id, sim)| (id.clone(), sim.input_len()))
        .collect()
}

/// Tight-queue config that forces intake rejections under a wide replay
/// window, with a deliberately small span ring so overflow accounting
/// is exercised too.
fn overload_config() -> ServerConfig {
    ServerConfig {
        workers: 1,
        max_batch: 2,
        queue_depth: 2,
        verify_every: 0,
        batch_deadline: Duration::ZERO,
        trace: true,
        trace_capacity: 16,
        ..Default::default()
    }
}

// --------------------------------------------------------------------
// Span accounting: the reconciliation identity under seeded overload.
// --------------------------------------------------------------------

#[test]
fn overload_replay_reconciles_spans_and_wraps_ring() {
    let fleet = two_model_fleet();
    let specs = fleet_specs(&fleet);
    // 300 requests all at tick 0 against queue_depth 2: heavy rejection.
    let trace = loadgen::MultiTrace::seeded(0x0B51, 300, &specs, 0);
    let mut server = Server::start_multi(fleet, overload_config(), None).unwrap();
    let report = loadgen::replay_multi(&server, &trace, 64, None);
    server.drain();

    let m = server.metrics();
    let stats = server.trace_stats().expect("tracing is on");
    // Every routed submission ends in exactly one terminal outcome and
    // exactly one recorded-or-dropped span.
    assert_eq!(report.aggregate.submitted, 300);
    assert_eq!(
        stats.spans_recorded + stats.spans_dropped,
        m.completed + m.errored + m.rejected + m.shed,
        "span ledger diverged from the intake ledger: {stats:?} vs {m:?}"
    );
    assert_eq!(stats.spans_recorded + stats.spans_dropped, 300);
    assert!(
        stats.spans_dropped > 0,
        "300 spans into a 16-slot ring must overflow"
    );
    assert_eq!(stats.retained, 16, "ring keeps exactly its capacity");
    assert!(report.aggregate.rejected > 0, "overload never materialized");

    // The retained spans are the first 16 to finish (drop-new
    // semantics) and each rejected span carries no execute stamps.
    let spans = server.flight_recorder().unwrap().spans();
    assert_eq!(spans.len(), 16);
    for s in &spans {
        if s.outcome == SpanOutcome::Rejected {
            assert_eq!(s.exec_start_ns, 0);
            assert_eq!(s.batch_size, 0);
        } else {
            assert!(s.batch_size >= 1);
            assert!(s.exec_end_ns >= s.exec_start_ns);
        }
    }
    // The stage summary is well-formed over a mixed dump: every span
    // contributes to `total`, only executed ones to `execute`.
    let summary = stage_summary(&spans);
    let by = |n: &str| summary.iter().find(|s| s.stage == n).unwrap().clone();
    assert_eq!(by("total").count, 16);
    let executed = spans
        .iter()
        .filter(|s| s.outcome == SpanOutcome::Completed)
        .count() as u64;
    assert_eq!(by("execute").count, executed);
}

fn net_overload_reconciles(core: NetCore) {
    let fleet = two_model_fleet();
    let specs = fleet_specs(&fleet);
    let trace = loadgen::MultiTrace::seeded(0x0B52, 200, &specs, 0);
    let coord = Arc::new(Server::start_multi(fleet, overload_config(), None).unwrap());
    let mut net = FrontEnd::bind(core, "127.0.0.1:0", Arc::clone(&coord)).unwrap();
    let client = Client::connect(&net.local_addr().to_string(), 8).unwrap();
    let report = loadgen::replay_net(&client, &trace, 32, None);
    net.shutdown(); // drains the coordinator too

    let m = coord.metrics();
    let stats = coord.trace_stats().expect("tracing is on");
    assert_eq!(report.aggregate.submitted, 200);
    assert_eq!(
        stats.spans_recorded + stats.spans_dropped,
        m.completed + m.errored + m.rejected + m.shed,
        "{core} core: span ledger diverged: {stats:?} vs {m:?}"
    );
    assert_eq!(stats.spans_recorded + stats.spans_dropped, 200);
    assert!(
        report.aggregate.rejected > 0,
        "{core} core: overload never materialized"
    );
}

#[test]
fn tcp_threaded_overload_reconciles_spans() {
    net_overload_reconciles(NetCore::Threaded);
}

#[cfg(unix)]
#[test]
fn tcp_evented_overload_reconciles_spans() {
    net_overload_reconciles(NetCore::Evented);
}

// --------------------------------------------------------------------
// Virtual-clock determinism: two seeded replays, byte-equal span dumps.
// --------------------------------------------------------------------

#[test]
fn virtual_clock_traces_are_byte_deterministic() {
    let fleet = two_model_fleet();
    let specs = fleet_specs(&fleet);
    let trace = loadgen::MultiTrace::seeded(0xDE7, 64, &specs, 1);
    let max_tick = trace.requests.iter().map(|r| r.at_tick).max().unwrap();

    let run = |fleet: Vec<(String, PipelineSim)>| -> Vec<SpanRecord> {
        let ticks = Arc::new(AtomicU64::new(0));
        let config = ServerConfig {
            workers: 1,
            max_batch: 1,
            queue_depth: 64,
            verify_every: 0,
            batch_deadline: Duration::ZERO,
            trace: true,
            trace_capacity: 256,
            clock: Clock::virtual_from(Arc::clone(&ticks)),
            ..Default::default()
        };
        let mut server = Server::start_multi(fleet, config, None).unwrap();
        // window 1: each request settles before the next submission, so
        // no span's lifetime straddles a tick-sink store.
        let report = loadgen::replay_multi_clocked(&server, &trace, 1, None, &ticks);
        assert_eq!(report.aggregate.ok, 64);
        server.drain();
        server.flight_recorder().unwrap().spans()
    };

    let a = run(fleet.clone());
    let b = run(fleet);
    assert_eq!(a.len(), 64);
    assert_eq!(
        a, b,
        "virtual-clock replays of the same seed must dump identical spans"
    );
    for s in &a {
        assert_eq!(s.outcome, SpanOutcome::Completed);
        // Stamps are virtual ticks, not wall nanoseconds: bounded by the
        // trace's tick range and monotone through the stages.
        assert!(s.replied_ns <= max_tick, "stamp {} is not a tick", s.replied_ns);
        assert!(s.submitted_ns <= s.admitted_ns);
        assert!(s.admitted_ns <= s.dequeued_ns);
        assert!(s.exec_start_ns <= s.exec_end_ns);
        assert!(s.exec_end_ns <= s.replied_ns);
        assert_eq!(s.batch_size, 1);
    }
}

// --------------------------------------------------------------------
// Profiler exactness: timing-only instrumentation changes no output.
// --------------------------------------------------------------------

#[test]
fn profiler_on_output_is_bit_exact_with_profiler_off() {
    let fleet = full_zoo_fleet();
    let specs = fleet_specs(&fleet);
    let golden_refs: Vec<&PipelineSim> = fleet.iter().map(|(_, s)| s).collect();
    let trace = loadgen::MultiTrace::seeded(0x0F17, 64, &specs, 1);
    let expected = loadgen::golden_outputs_multi(&golden_refs, &trace);

    for profile in [false, true] {
        let config = ServerConfig {
            workers: 2,
            max_batch: 4,
            queue_depth: 64,
            verify_every: 0,
            batch_deadline: Duration::from_micros(300),
            profile,
            ..Default::default()
        };
        let mut server = Server::start_multi(fleet.clone(), config, None).unwrap();
        let report = loadgen::replay_multi(&server, &trace, 8, Some(&expected));
        server.drain();
        assert_eq!(report.aggregate.ok, 64, "profile={profile}");
        assert_eq!(
            report.aggregate.mismatched, 0,
            "profile={profile}: outputs diverged from the interpreter goldens"
        );

        let profiles = server.layer_profiles();
        if profile {
            assert!(!profiles.is_empty(), "profiler on must expose rows");
            // The interpreter engine's per-unit cycle model doesn't feed
            // the wall-time profiler; the value engines do.
            if EngineKind::default_from_env() != EngineKind::Interpreter {
                let sampled: u64 = profiles
                    .iter()
                    .flat_map(|(_, rows)| rows.iter().map(|r| r.samples))
                    .sum();
                assert!(sampled > 0, "profiler on but nothing sampled");
            }
            for (model, rows) in &profiles {
                let total: f64 = rows.iter().map(|r| r.measured_share).sum();
                assert!(
                    total == 0.0 || (total - 1.0).abs() < 1e-9,
                    "{model}: measured shares sum to {total}"
                );
            }
        } else {
            assert!(profiles.is_empty(), "profiler off must expose no rows");
        }
    }
}

// --------------------------------------------------------------------
// Exposition: the wire MetricsText page lints on both cores.
// --------------------------------------------------------------------

fn metrics_text_lints(core: NetCore) {
    let fleet = two_model_fleet();
    let specs = fleet_specs(&fleet);
    let trace = loadgen::MultiTrace::seeded(0x3C4A, 48, &specs, 1);
    let config = ServerConfig {
        workers: 1,
        max_batch: 4,
        queue_depth: 64,
        verify_every: 0,
        batch_deadline: Duration::from_micros(300),
        trace: true,
        profile: true,
        ..Default::default()
    };
    let coord = Arc::new(Server::start_multi(fleet, config, None).unwrap());
    let mut net = FrontEnd::bind(core, "127.0.0.1:0", Arc::clone(&coord)).unwrap();
    let client = Client::connect(&net.local_addr().to_string(), 4).unwrap();
    let report = loadgen::replay_net(&client, &trace, 8, None);
    assert_eq!(report.aggregate.ok, 48, "{core} core");

    let page = client.metrics_text().expect("metrics-text round trip");
    lint(&page).unwrap_or_else(|e| panic!("{core} core: exposition lint failed: {e}\n{page}"));
    assert!(
        page.contains("cnn_flow_completed_total"),
        "{core} core: page misses the intake counters:\n{page}"
    );
    assert!(
        page.contains("cnn_flow_net_requests_total") || page.contains("cnn_flow_net_"),
        "{core} core: page misses the net counters:\n{page}"
    );
    net.shutdown();
}

#[test]
fn metrics_text_page_lints_on_threaded_core() {
    metrics_text_lints(NetCore::Threaded);
}

#[cfg(unix)]
#[test]
fn metrics_text_page_lints_on_evented_core() {
    metrics_text_lints(NetCore::Evented);
}
