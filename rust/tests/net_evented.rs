//! Differential tests for the evented network core (DESIGN.md §10):
//! the thread-per-connection `NetServer` is the oracle, the reactor-
//! based `EventedServer` must be observationally identical — same
//! response bytes on the same seeded replay, same `NetMetrics`
//! accounting, same drain / malformed / pipelining semantics — while
//! serving every connection off one thread. Fan-in scale (1k and 10k
//! connections) is covered by `#[ignore]`d smokes driven through the
//! poller-multiplexed `net::fanin` loadgen; CI runs the 1k smoke on a
//! raised-ulimit leg (each fan-in connection costs two fds in-process:
//! the client end plus the server's accepted end).

#![cfg(unix)]

use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use cnn_flow::coordinator::{loadgen, NetMetricsSnapshot, Server, ServerConfig};
use cnn_flow::model::zoo;
use cnn_flow::net::client::Client;
use cnn_flow::net::evented::EventedServer;
use cnn_flow::net::proto::{self, ErrorCode, Msg};
use cnn_flow::net::server::{NetServer, NetServerConfig};
use cnn_flow::net::{fanin, FrontEnd, NetCore};
use cnn_flow::quant::QModel;
use cnn_flow::sim::pipeline::PipelineSim;
use cnn_flow::util::Rng;

/// Three heterogeneous serving-zoo models, synthesized with fixed seeds —
/// the same fleet shape `tests/net_serving.rs` replays.
fn three_model_fleet() -> Vec<(String, PipelineSim)> {
    [zoo::digits_cnn(), zoo::mobilenet_micro(), zoo::vgg_micro()]
        .iter()
        .enumerate()
        .map(|(i, m)| {
            let qm = QModel::synthesize(m, 0x7CB0 + i as u64).unwrap();
            (m.name.clone(), PipelineSim::new(qm, None).unwrap())
        })
        .collect()
}

/// The full serving zoo — chains plus the residual `resnet_micro` /
/// `mobilenet_v2_micro` DAGs — synthesized with the same fixed seeds
/// `tests/net_serving.rs` uses.
fn full_zoo_fleet() -> Vec<(String, PipelineSim)> {
    zoo::serving_zoo()
        .iter()
        .enumerate()
        .map(|(i, m)| {
            let qm = QModel::synthesize(m, 0x7CB0 + i as u64).unwrap();
            (m.name.clone(), PipelineSim::new(qm, None).unwrap())
        })
        .collect()
}

fn fleet_specs(fleet: &[(String, PipelineSim)]) -> Vec<(String, usize)> {
    fleet
        .iter()
        .map(|(id, sim)| (id.clone(), sim.input_len()))
        .collect()
}

fn fleet_config() -> ServerConfig {
    ServerConfig {
        workers: 2,
        max_batch: 4,
        queue_depth: 64,
        verify_every: 0,
        batch_deadline: Duration::from_micros(300),
        ..Default::default()
    }
}

/// Bounded spin until the coordinator's intake has accepted `n`
/// requests (socket-carried submissions are asynchronous).
fn await_accepted(server: &Server, n: u64) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while server.metrics().accepted < n {
        assert!(
            Instant::now() < deadline,
            "coordinator never accepted {n} requests: {:?}",
            server.metrics()
        );
        std::thread::yield_now();
    }
}

/// Connection churn is load- not protocol-determined (the pooled client
/// dials lazily, so peak-concurrency jitter can open one fewer socket on
/// a fast run); zero those two fields when comparing cores and assert
/// `connections == disconnects` per core instead.
fn sans_churn(s: NetMetricsSnapshot) -> NetMetricsSnapshot {
    NetMetricsSnapshot {
        connections: 0,
        disconnects: 0,
        ..s
    }
}

// --------------------------------------------------------------------
// THE acceptance case: the evented core vs the threaded oracle.
// --------------------------------------------------------------------

#[test]
fn evented_replay_is_byte_identical_to_threaded_oracle() {
    // One seeded heterogeneous trace, one set of interpreter-backed
    // golden outputs, the SAME transport-generic `replay_net` — the only
    // variable is the network core. Reports must be equal (both
    // reproduce the goldens bit-for-bit) and the net + coordinator
    // counters must reconcile exactly across cores.
    let fleet = three_model_fleet();
    let specs = fleet_specs(&fleet);
    let golden_refs: Vec<&PipelineSim> = fleet.iter().map(|(_, s)| s).collect();
    let trace = loadgen::MultiTrace::seeded(0x9E7D, 96, &specs, 1);
    let expected = loadgen::golden_outputs_multi(&golden_refs, &trace);

    // Threaded oracle run.
    let coord_thr = Arc::new(Server::start_multi(fleet.clone(), fleet_config(), None).unwrap());
    let mut thr = NetServer::bind("127.0.0.1:0", Arc::clone(&coord_thr)).unwrap();
    let client = Client::connect(&thr.local_addr().to_string(), 8).unwrap();
    let report_thr = loadgen::replay_net(&client, &trace, 8, Some(&expected));
    let snap_thr = thr.shutdown();
    let m_thr = coord_thr.metrics();

    // Evented run of the SAME trace against an identical fresh fleet.
    let coord_evt = Arc::new(Server::start_multi(fleet, fleet_config(), None).unwrap());
    let mut evt = EventedServer::bind("127.0.0.1:0", Arc::clone(&coord_evt)).unwrap();
    let client = Client::connect(&evt.local_addr().to_string(), 8).unwrap();
    let report_evt = loadgen::replay_net(&client, &trace, 8, Some(&expected));
    let snap_evt = evt.shutdown();
    let m_evt = coord_evt.metrics();

    assert_eq!(report_evt.aggregate.ok, 96);
    assert_eq!(report_evt.aggregate.mismatched, 0, "evented path diverged from golden");
    assert_eq!(report_evt.aggregate.rejected, 0);
    assert_eq!(report_evt.aggregate.dropped, 0);
    assert_eq!(
        report_evt, report_thr,
        "evented and threaded replays must produce identical reports"
    );
    // Exact net-layer reconciliation across cores...
    assert_eq!(sans_churn(snap_evt), sans_churn(snap_thr));
    assert_eq!(snap_evt.requests, 96);
    assert_eq!(snap_evt.responses_ok, 96);
    assert_eq!(snap_evt.errors_total(), 0);
    assert_eq!(snap_evt.err_malformed, 0);
    assert_eq!(snap_evt.connections, snap_evt.disconnects);
    assert_eq!(snap_thr.connections, snap_thr.disconnects);
    // ...and coordinator intake is core-independent.
    assert_eq!(m_evt.completed, m_thr.completed);
    assert_eq!(m_evt.accepted, m_thr.accepted);
    assert_eq!(m_evt.errored, 0);
    assert_eq!(snap_evt.responses_ok, m_evt.completed);
}

#[test]
fn evented_replay_full_zoo_with_residual_models_matches_threaded_oracle() {
    // The extended-zoo differential: one seeded trace over all six
    // serving-zoo models — the residual resnet_micro / mobilenet_v2_micro
    // DAGs included — replayed through both network cores. Reports must
    // be equal per model and both must reproduce the interpreter goldens
    // bit-for-bit: a residual model is just another route to either core.
    let fleet = full_zoo_fleet();
    let specs = fleet_specs(&fleet);
    assert!(specs.iter().any(|(id, _)| id == "resnet_micro"));
    assert!(specs.iter().any(|(id, _)| id == "mobilenet_v2_micro"));
    let golden_refs: Vec<&PipelineSim> = fleet.iter().map(|(_, s)| s).collect();
    let trace = loadgen::MultiTrace::seeded(0x8E51D, 120, &specs, 1);
    let counts = trace.per_model_counts();
    assert!(
        counts.iter().all(|&c| c > 0),
        "every model, residual ones included, must take traffic: {counts:?}"
    );
    let expected = loadgen::golden_outputs_multi(&golden_refs, &trace);

    // Threaded oracle run.
    let coord_thr = Arc::new(Server::start_multi(fleet.clone(), fleet_config(), None).unwrap());
    let mut thr = NetServer::bind("127.0.0.1:0", Arc::clone(&coord_thr)).unwrap();
    let client = Client::connect(&thr.local_addr().to_string(), 8).unwrap();
    let report_thr = loadgen::replay_net(&client, &trace, 8, Some(&expected));
    let snap_thr = thr.shutdown();
    let m_thr = coord_thr.metrics();

    // Evented run of the SAME trace against an identical fresh fleet.
    let coord_evt = Arc::new(Server::start_multi(fleet, fleet_config(), None).unwrap());
    let mut evt = EventedServer::bind("127.0.0.1:0", Arc::clone(&coord_evt)).unwrap();
    let client = Client::connect(&evt.local_addr().to_string(), 8).unwrap();
    let report_evt = loadgen::replay_net(&client, &trace, 8, Some(&expected));
    let snap_evt = evt.shutdown();
    let m_evt = coord_evt.metrics();

    assert_eq!(report_evt.aggregate.ok, 120);
    assert_eq!(report_evt.aggregate.mismatched, 0, "evented path diverged from golden");
    assert_eq!(
        report_evt, report_thr,
        "evented and threaded replays must produce identical reports"
    );
    for (i, (id, _)) in specs.iter().enumerate() {
        let r = &report_evt.per_model[i];
        assert_eq!(r.submitted, counts[i], "{id}: trace share");
        assert_eq!(r.ok, counts[i], "{id}: all answered");
        assert_eq!(r.mismatched, 0, "{id}: diverged from golden");
    }
    assert_eq!(sans_churn(snap_evt), sans_churn(snap_thr));
    assert_eq!(snap_evt.requests, 120);
    assert_eq!(snap_evt.errors_total(), 0);
    assert_eq!(m_evt.completed, m_thr.completed);
    assert_eq!(m_evt.errored, 0);
    assert_eq!(snap_evt.responses_ok, m_evt.completed);
}

// --------------------------------------------------------------------
// Reactor semantics: pipelining order, malformed input, drain.
// --------------------------------------------------------------------

#[test]
fn evented_pipelined_burst_answers_in_order_and_matches_golden() {
    let qm = QModel::synthetic(8, 4, 6, 0x41FE);
    let golden = PipelineSim::new(qm.clone(), None).unwrap();
    let coord = Arc::new(
        Server::start(
            qm,
            ServerConfig {
                workers: 2,
                max_batch: 4,
                queue_depth: 64,
                verify_every: 0,
                batch_deadline: Duration::from_micros(200),
                ..Default::default()
            },
            None,
        )
        .unwrap(),
    );
    let mut net = EventedServer::bind("127.0.0.1:0", Arc::clone(&coord)).unwrap();
    let model = coord.models()[0].clone();

    // A pipelined burst written back-to-back before reading anything —
    // the whole burst lands in the reactor's per-connection scratch
    // buffer and must come back in request order, bit-identical.
    let mut rng = Rng::new(0x60D);
    let frames: Vec<Vec<i64>> = (0..24)
        .map(|_| (0..64).map(|_| rng.int8() as i64).collect())
        .collect();
    let mut stream = TcpStream::connect(net.local_addr()).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut wire = Vec::new();
    for (i, frame) in frames.iter().enumerate() {
        Msg::InferRequest {
            id: 100 + i as u64,
            model: model.clone(),
            frame: frame.clone(),
            deadline_us: 0,
            class: 0,
        }
        .encode_into(&mut wire)
        .unwrap();
    }
    stream.write_all(&wire).unwrap();

    for (i, frame) in frames.iter().enumerate() {
        let expect = golden.run_interpreted(&[frame.clone()]).unwrap().outputs[0].clone();
        match proto::read_frame(&mut stream).unwrap().unwrap() {
            Msg::InferOk { id, logits, .. } => {
                assert_eq!(id, 100 + i as u64, "pipelined responses out of order");
                assert_eq!(logits, expect, "frame {i} diverged");
            }
            other => panic!("unexpected reply: {other:?}"),
        }
    }
    drop(stream);
    let stats = net.reactor_stats();
    let snap = net.shutdown();
    assert_eq!(snap.requests, 24);
    assert_eq!(snap.responses_ok, 24);
    assert_eq!(snap.connections, 1, "pipelining happened on one socket");
    assert!(stats.polls > 0, "the readiness loop must have run: {stats:?}");
    assert!(
        stats.completions > 0,
        "pipelined settles must flow through the completion queue: {stats:?}"
    );
}

#[test]
fn evented_answers_malformed_bytes_and_keeps_serving() {
    let qm = QModel::synthetic(8, 4, 6, 0xBAD0);
    let coord = Arc::new(
        Server::start(
            qm,
            ServerConfig {
                workers: 1,
                verify_every: 0,
                batch_deadline: Duration::from_millis(0),
                ..Default::default()
            },
            None,
        )
        .unwrap(),
    );
    let mut net = EventedServer::bind("127.0.0.1:0", Arc::clone(&coord)).unwrap();

    // Oversized length prefix: typed Malformed answer (id 0), then close.
    let mut bad = TcpStream::connect(net.local_addr()).unwrap();
    bad.write_all(&[0xFF, 0xFF, 0xFF, 0xFF]).unwrap();
    match proto::read_frame(&mut bad).unwrap() {
        Some(Msg::InferErr { id, code, .. }) => {
            assert_eq!(id, 0);
            assert_eq!(code, ErrorCode::Malformed);
        }
        other => panic!("expected a Malformed error, got {other:?}"),
    }
    assert_eq!(proto::read_frame(&mut bad).unwrap(), None);

    // A server→client kind arriving at the server: same contract.
    let mut liar = TcpStream::connect(net.local_addr()).unwrap();
    liar.write_all(&Msg::ListModels.encode().unwrap()).unwrap();
    let mut upside_down = Vec::new();
    Msg::InferOk {
        id: 9,
        argmax: 0,
        sim_latency_cycles: 1,
        logits: vec![1],
    }
    .encode_into(&mut upside_down)
    .unwrap();
    liar.write_all(&upside_down).unwrap();
    match proto::read_frame(&mut liar).unwrap() {
        Some(Msg::ModelList { models }) => assert!(!models.is_empty()),
        other => panic!("expected the model list, got {other:?}"),
    }
    match proto::read_frame(&mut liar).unwrap() {
        Some(Msg::InferErr { id, code, .. }) => {
            assert_eq!(id, 0);
            assert_eq!(code, ErrorCode::Malformed);
        }
        other => panic!("expected a Malformed error, got {other:?}"),
    }
    assert_eq!(proto::read_frame(&mut liar).unwrap(), None);

    // The reactor is still alive: a well-formed client is served.
    let client = Client::connect(&net.local_addr().to_string(), 1).unwrap();
    let (model, len) = client.models().unwrap()[0].clone();
    assert!(client.infer(&model, &vec![1i64; len]).is_ok());

    let snap = net.shutdown();
    assert_eq!(snap.err_malformed, 2);
    assert_eq!(snap.responses_ok, 1);
    assert_eq!(snap.connections, snap.disconnects);
    assert_eq!(coord.metrics().completed, 1, "malformed bytes never reach a shard");
}

#[test]
fn evented_drain_completes_in_flight_partial_batches_per_model() {
    // The evented image of the threaded drain test: far deadline + big
    // max_batch, so nothing flushes until `shutdown` drains — every
    // in-flight request must be answered through the reactor's final
    // settle-and-flush sweep before its socket closes.
    let fleet = three_model_fleet();
    let specs = fleet_specs(&fleet);
    let golden_refs: Vec<PipelineSim> = fleet.iter().map(|(_, s)| s.clone()).collect();
    let coord = Arc::new(
        Server::start_multi(
            fleet,
            ServerConfig {
                workers: 1,
                max_batch: 16,
                queue_depth: 64,
                verify_every: 0,
                batch_deadline: Duration::from_secs(30),
                ..Default::default()
            },
            None,
        )
        .unwrap(),
    );
    let mut net = EventedServer::bind("127.0.0.1:0", Arc::clone(&coord)).unwrap();
    let client = Client::connect(&net.local_addr().to_string(), 6).unwrap();

    let mut pendings = Vec::new();
    let mut expects = Vec::new();
    for (i, (id, len)) in specs.iter().enumerate() {
        for _ in 0..=i {
            let frame = vec![1i64; *len];
            expects.push(
                golden_refs[i]
                    .run_interpreted(&[frame.clone()])
                    .unwrap()
                    .outputs[0]
                    .clone(),
            );
            pendings.push(client.submit(id, &frame).unwrap());
        }
    }
    await_accepted(&coord, 6);

    let snap = net.shutdown();
    for (pending, expect) in pendings.into_iter().zip(expects) {
        let resp = pending.wait().expect("in-flight request dropped by drain");
        assert_eq!(resp.logits, expect, "drained response diverged from golden");
    }
    let m = coord.metrics();
    assert_eq!(m.completed, 6, "1 + 2 + 3 drained requests");
    assert_eq!(m.flush_drain, 3, "one partial drain batch per model");
    assert_eq!(snap.requests, 6);
    assert_eq!(snap.responses_ok, 6, "drain must not drop in-flight replies");
    assert_eq!(snap.errors_total(), 0);
    assert_eq!(snap.connections, snap.disconnects);

    // After the drain the front-end refuses new work entirely.
    if let Ok(c) = Client::connect(&net.local_addr().to_string(), 1) {
        assert!(c.models().is_err(), "listener must be gone after drain");
    }
}

#[test]
fn evented_write_stall_tears_down_and_counters_balance() {
    // A client that pipelines a burst of large-response requests and
    // never reads: the reactor's write buffers stop draining, the
    // configured stall timeout expires, and the connection is torn down
    // — with every decoded request still landing in exactly one counter
    // (the threaded core pins the same invariant in net_serving.rs).
    let qm = QModel::synthetic(8, 4, 384, 0x57A1);
    let coord = Arc::new(
        Server::start(
            qm,
            ServerConfig {
                workers: 2,
                max_batch: 16,
                queue_depth: 1024,
                verify_every: 0,
                batch_deadline: Duration::from_micros(200),
                ..Default::default()
            },
            None,
        )
        .unwrap(),
    );
    let config = NetServerConfig {
        writer_queue_depth: 1024,
        write_stall_timeout: Duration::from_millis(100),
    };
    let mut net = EventedServer::bind_with("127.0.0.1:0", Arc::clone(&coord), config).unwrap();
    let model = coord.models()[0].clone();

    let burst = 400u64;
    let mut stream = TcpStream::connect(net.local_addr()).unwrap();
    stream.set_nodelay(true).unwrap();
    let frame = vec![1i64; 8 * 8];
    let mut wire = Vec::new();
    for id in 0..burst {
        Msg::InferRequest {
            id,
            model: model.clone(),
            frame: frame.clone(),
            deadline_us: 0,
            class: 0,
        }
        .encode_into(&mut wire)
        .unwrap();
    }
    stream.write_all(&wire).unwrap();
    // Do NOT read. ~384 i64 logits per response (~3KB) x 400 responses
    // far exceeds the loopback socket buffers, so the reactor must hit
    // the write stall and give up on this peer.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let snap = net.metrics();
        if snap.responses_ok + snap.errors_total() == burst {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "reactor never settled the burst: {snap:?} / {:?}",
            net.reactor_stats()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    let stats = net.reactor_stats();
    assert!(
        stats.stall_teardowns >= 1,
        "a non-reading peer must trip the stall teardown: {stats:?}"
    );
    drop(stream);
    let snap = net.shutdown();
    assert_eq!(snap.requests, burst);
    assert_eq!(
        snap.requests,
        snap.responses_ok + snap.errors_total(),
        "every decoded request gets exactly one counter: {snap:?}"
    );
    assert_eq!(snap.connections, snap.disconnects);
}

// --------------------------------------------------------------------
// Fan-in: default-size reconciliation + ignored 1k/10k smokes.
// --------------------------------------------------------------------

/// Drive `connections` pipelined fan-in connections at a fresh
/// synthetic-model coordinator behind `core`; assert exact intake
/// reconciliation and return (report, final net snapshot).
fn fanin_roundtrip(core: NetCore, connections: usize, requests_per_conn: usize) {
    let coord = Arc::new(
        Server::start(
            QModel::synthetic(8, 4, 6, 0x7CF),
            ServerConfig {
                workers: 2,
                max_batch: 16,
                queue_depth: 4096,
                verify_every: 0,
                batch_deadline: Duration::from_micros(200),
                ..Default::default()
            },
            None,
        )
        .unwrap(),
    );
    let (model, frame_len) = coord.model_specs().first().cloned().unwrap();
    let mut net = FrontEnd::bind(core, "127.0.0.1:0", Arc::clone(&coord)).unwrap();
    let report = fanin::run(
        net.local_addr(),
        &model,
        frame_len,
        &fanin::FanInConfig {
            connections,
            requests_per_conn,
            window: 4.min(requests_per_conn),
            seed: 0xFA51,
            deadline: Some(Duration::from_secs(120)),
        },
    )
    .unwrap();
    let total = (connections * requests_per_conn) as u64;
    assert_eq!(report.sent, total);
    assert_eq!(report.ok + report.errors, total, "every request answered");
    let snap = net.shutdown();
    assert_eq!(snap.requests, total, "{core}: intake reconciliation");
    assert_eq!(snap.responses_ok, report.ok);
    assert_eq!(snap.errors_total(), report.errors);
    assert_eq!(snap.connections, connections as u64);
    assert_eq!(snap.disconnects, connections as u64);
    assert_eq!(coord.metrics().completed, report.ok);
}

#[test]
fn fanin_reconciles_exactly_on_both_cores() {
    // Modest size so the default test run stays fast and under any fd
    // limit; the same path scales to the ignored 1k/10k smokes below.
    fanin_roundtrip(NetCore::Evented, 128, 8);
    fanin_roundtrip(NetCore::Threaded, 128, 8);
}

/// 1k-connection smoke. `#[ignore]` by default: ~2k fds in-process plus
/// (on the threaded core leg) ~2k OS threads. CI runs it on a leg with
/// `ulimit -n 8192`; locally: `cargo test --release --test net_evented
/// -- --ignored fanin_1k`.
#[test]
#[ignore = "1k fds; run explicitly with a raised ulimit (see .github/workflows/ci.yml)"]
fn fanin_1k_connections_evented() {
    fanin_roundtrip(NetCore::Evented, 1000, 4);
}

/// The 10k+ headline: one reactor thread serving 10,000 concurrent
/// pipelined connections. `#[ignore]` by default — the client and
/// server ends live in one process, so this needs `ulimit -n` >= ~21k.
#[test]
#[ignore = "20k+ fds; needs ulimit -n >= 24576: cargo test --release --test net_evented -- --ignored fanin_10k"]
fn fanin_10k_connections_evented() {
    fanin_roundtrip(NetCore::Evented, 10_000, 2);
}
