//! Property-based integration tests for the pipeline simulator: random
//! quantized models checked against an *independent* naive evaluator
//! (written here, separate from the simulator's in-module oracle), plus
//! schedule invariants.

use cnn_flow::flow::Ratio;
use cnn_flow::quant::{requant, QKind, QLayer, QModel, QMAX};
use cnn_flow::sim::pipeline::PipelineSim;
use cnn_flow::util::prop::prop_check;
use cnn_flow::util::Rng;
use cnn_flow::{prop_assert, prop_assert_eq};

/// Build a random small quantized CNN (conv[+pool]..., dense head).
fn random_qmodel(rng: &mut Rng) -> QModel {
    let f0 = [4usize, 6, 8][rng.range(0, 2)];
    let c0 = rng.range(1, 3);
    let mut layers: Vec<QLayer> = Vec::new();
    let (mut f, mut c) = (f0, c0);
    let n_conv = rng.range(1, 2);
    for i in 0..n_conv {
        let k = 3;
        let p = 1;
        let cout = rng.range(1, 4);
        let w_q: Vec<i64> = (0..k * k * c * cout)
            .map(|_| rng.range(0, 16) as i64 - 8)
            .collect();
        let b_q: Vec<i64> = (0..cout).map(|_| rng.range(0, 40) as i64 - 20).collect();
        layers.push(QLayer {
            name: format!("C{i}"),
            kind: QKind::Conv,
            k,
            s: 1,
            p,
            relu: rng.range(0, 1) == 1,
            w_q,
            w_shape: vec![k, k, c, cout],
            b_q,
            m: 0.002 + rng.f64() as f32 * 0.01,
            in_shape: [f, f, c],
            out_shape: [f, f, cout],
        });
        c = cout;
        if f % 2 == 0 && rng.range(0, 1) == 1 {
            layers.push(QLayer {
                name: format!("P{i}"),
                kind: QKind::MaxPool,
                k: 2,
                s: 2,
                p: 0,
                relu: false,
                w_q: vec![],
                w_shape: vec![],
                b_q: vec![],
                m: 0.0,
                in_shape: [f, f, c],
                out_shape: [f / 2, f / 2, c],
            });
            f /= 2;
        }
    }
    let feats = f * f * c;
    let units = rng.range(2, 6);
    layers.push(QLayer {
        name: "F".into(),
        kind: QKind::Dense,
        k: 0,
        s: 1,
        p: 0,
        relu: false,
        w_q: (0..units * feats).map(|_| rng.range(0, 10) as i64 - 5).collect(),
        w_shape: vec![units, feats],
        b_q: (0..units).map(|_| rng.range(0, 20) as i64 - 10).collect(),
        m: 0.0,
        in_shape: [1, 1, feats],
        out_shape: [1, 1, units],
    });
    QModel {
        name: "rand".into(),
        input_shape: [f0, f0, c0],
        input_scale: 1.0,
        layers,
        topology: vec![],
        test_vectors: vec![],
        qat_accuracy: 0.0,
    }
}

/// Independent naive evaluator of the int8 pipeline semantics.
fn naive_eval(qm: &QModel, x: &[i64]) -> Vec<i64> {
    let mut cur = x.to_vec();
    let n = qm.layers.len();
    for (idx, l) in qm.layers.iter().enumerate() {
        let last = idx + 1 == n;
        let [h, w, cin] = l.in_shape;
        let [ho, wo, cout] = l.out_shape;
        let mut next = vec![0i64; ho * wo * cout];
        match l.kind {
            QKind::Conv => {
                for or in 0..ho {
                    for oc in 0..wo {
                        for co in 0..cout {
                            let mut acc = l.b_q[co];
                            for u in 0..l.k {
                                for v in 0..l.k {
                                    let ir = or as isize + u as isize - l.p as isize;
                                    let ic = oc as isize + v as isize - l.p as isize;
                                    if ir < 0 || ic < 0 || ir >= h as isize || ic >= w as isize {
                                        continue;
                                    }
                                    for ci in 0..cin {
                                        let xval =
                                            cur[(ir as usize * w + ic as usize) * cin + ci];
                                        let wval = l.w_q
                                            [((u * l.k + v) * cin + ci) * cout + co];
                                        acc += wval * xval;
                                    }
                                }
                            }
                            if l.relu {
                                acc = acc.max(0);
                            }
                            next[(or * wo + oc) * cout + co] =
                                if last { acc } else { requant(acc, l.m) };
                        }
                    }
                }
            }
            QKind::MaxPool => {
                for or in 0..ho {
                    for oc in 0..wo {
                        for ch in 0..cout {
                            let mut m = i64::MIN;
                            for u in 0..l.k {
                                for v in 0..l.k {
                                    m = m.max(
                                        cur[((or * l.s + u) * w + oc * l.s + v) * cin + ch],
                                    );
                                }
                            }
                            next[(or * wo + oc) * cout + ch] = m;
                        }
                    }
                }
            }
            QKind::Dense => {
                for unit in 0..cout {
                    let mut acc = l.b_q[unit];
                    for (fi, &v) in cur.iter().enumerate() {
                        acc += l.w_q[unit * (h * w * cin) + fi] * v;
                    }
                    if l.relu {
                        acc = acc.max(0);
                    }
                    next[unit] = if last { acc } else { requant(acc, l.m) };
                }
            }
            _ => unreachable!("generator emits conv/pool/dense only"),
        }
        cur = next;
    }
    cur
}

#[test]
fn pipeline_matches_independent_evaluator() {
    prop_check(60, 0xA1, |rng| {
        let qm = random_qmodel(rng);
        let n: usize = qm.input_shape.iter().product();
        let sim = PipelineSim::new(qm.clone(), None)?;
        for _ in 0..3 {
            let x: Vec<i64> = (0..n).map(|_| rng.int8() as i64).collect();
            let got = sim.run(&[x.clone()])?.outputs[0].clone();
            let want = naive_eval(&qm, &x);
            prop_assert_eq!(got, want, "model {:?}", qm.input_shape);
        }
        Ok(())
    });
}

#[test]
fn reference_plan_value_equivalence() {
    // The fully-parallel reference must compute identical values.
    prop_check(40, 0xA2, |rng| {
        let qm = random_qmodel(rng);
        let n: usize = qm.input_shape.iter().product();
        let ours = PipelineSim::new(qm.clone(), None)?;
        let reference = PipelineSim::new_reference(qm)?;
        let x: Vec<i64> = (0..n).map(|_| rng.int8() as i64).collect();
        prop_assert_eq!(
            ours.run(&[x.clone()]).unwrap().outputs,
            reference.run(&[x]).unwrap().outputs,
            "plans disagree on values"
        );
        Ok(())
    });
}

#[test]
fn intermediate_activations_fit_int8() {
    prop_check(40, 0xA3, |rng| {
        let qm = random_qmodel(rng);
        // Evaluate all but the final layer and check int8 bounds.
        let n: usize = qm.input_shape.iter().product();
        let x: Vec<i64> = (0..n).map(|_| rng.int8() as i64).collect();
        let mut partial = qm.clone();
        let full_len = partial.layers.len();
        if full_len < 2 {
            return Ok(());
        }
        partial.layers.truncate(full_len - 1);
        // Evaluating a truncated model: its new "last" layer skips requant,
        // so instead evaluate the full naive path layer by layer.
        let vals = naive_eval(&qm, &x);
        let _ = vals; // final layer may exceed int8 by design
        let mut cur = x;
        for (idx, l) in qm.layers.iter().enumerate() {
            if idx + 1 == qm.layers.len() {
                break;
            }
            let one = QModel {
                layers: vec![QLayer { m: l.m, ..l.clone() }],
                input_shape: l.in_shape,
                ..qm.clone()
            };
            // A single-layer model treats its layer as last (no requant):
            // apply requant manually for non-pool layers.
            cur = naive_eval(&one, &cur)
                .into_iter()
                .map(|v| {
                    if l.kind == QKind::MaxPool {
                        v
                    } else {
                        requant(if l.relu { v.max(0) } else { v }, l.m)
                    }
                })
                .collect();
            for &v in &cur {
                prop_assert!(v.abs() <= QMAX, "layer {idx} value {v} exceeds int8");
            }
        }
        Ok(())
    });
}

#[test]
fn throughput_scales_inversely_with_rate() {
    // Halving r0 must roughly double cycles/frame for the same model.
    prop_check(20, 0xA4, |rng| {
        let qm = random_qmodel(rng);
        let n: usize = qm.input_shape.iter().product();
        let frames: Vec<Vec<i64>> = (0..8)
            .map(|_| (0..n).map(|_| rng.int8() as i64).collect())
            .collect();
        let d0 = qm.input_shape[2] as u64;
        let full = PipelineSim::new(qm.clone(), Some(Ratio::int(d0)))?.run(&frames)?;
        let half = PipelineSim::new(qm, Some(Ratio::new(d0, 2)))?.run(&frames)?;
        let ratio = half.cycles_per_frame / full.cycles_per_frame;
        prop_assert!(
            (1.7..2.3).contains(&ratio),
            "cycles/frame ratio {ratio} not ~2 (full {}, half {})",
            full.cycles_per_frame,
            half.cycles_per_frame
        );
        Ok(())
    });
}
