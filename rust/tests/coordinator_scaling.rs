//! Integration tests for the sharded coordinator: bit-exactness against
//! the single-`PipelineSim` golden path under concurrent load, rejection
//! under queue overflow, metric reconciliation, and deterministic
//! simulated-throughput scaling with the worker count — plus the
//! multi-model tier: registry caching (hit/miss/eviction, single-flight,
//! cold-vs-warm lowering), seeded heterogeneous traces, and per-model +
//! aggregate reconciliation including drain partial batches.
//!
//! Everything runs on synthetic or synthesized fixtures — no artifacts,
//! no skips, no wall-clock sleeps: determinism comes from seeded traces,
//! the FIFO drain-on-shutdown, and simulated (not wall) time.

use std::sync::Arc;
use std::time::{Duration, Instant};

use cnn_flow::coordinator::{loadgen, ModelRoute, Pending, Server, ServerConfig};
use cnn_flow::model::zoo;
use cnn_flow::quant::QModel;
use cnn_flow::runtime::ModelRegistry;
use cnn_flow::sim::pipeline::PipelineSim;
use cnn_flow::util::Rng;

fn fixture() -> QModel {
    QModel::synthetic(8, 4, 6, 0x5CA1E)
}

/// Three heterogeneous serving-zoo models, synthesized with fixed seeds:
/// the mixed-traffic fleet every multi-model test replays against.
fn three_model_fleet() -> Vec<(String, PipelineSim)> {
    [zoo::digits_cnn(), zoo::mobilenet_micro(), zoo::vgg_micro()]
        .iter()
        .enumerate()
        .map(|(i, m)| {
            let qm = QModel::synthesize(m, 0xF1EE7 + i as u64).unwrap();
            (m.name.clone(), PipelineSim::new(qm, None).unwrap())
        })
        .collect()
}

fn fleet_specs(fleet: &[(String, PipelineSim)]) -> Vec<(String, usize)> {
    fleet
        .iter()
        .map(|(id, sim)| (id.clone(), sim.input_len()))
        .collect()
}

#[test]
fn concurrent_load_is_bit_identical_to_single_sim() {
    let qm = fixture();
    let golden = Arc::new(PipelineSim::new(qm.clone(), None).unwrap());
    let server = Arc::new(
        Server::start(
            qm,
            ServerConfig {
                workers: 4,
                max_batch: 4,
                queue_depth: 128,
                verify_every: 0,
                batch_deadline: Duration::from_millis(1),
                ..Default::default()
            },
            None,
        )
        .unwrap(),
    );
    let mut handles = Vec::new();
    for c in 0..8u64 {
        let s = Arc::clone(&server);
        let g = Arc::clone(&golden);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0x1D + c);
            for _ in 0..12 {
                let x: Vec<i64> = (0..64).map(|_| rng.int8() as i64).collect();
                let expect = g.run(&[x.clone()]).unwrap().outputs[0].clone();
                let resp = s.infer(x).unwrap();
                assert_eq!(resp.logits, expect, "client {c} diverged");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = Arc::try_unwrap(server).ok().unwrap().shutdown();
    assert_eq!(m.completed, 96);
    assert_eq!(m.accepted, 96);
    assert_eq!(m.rejected, 0);
}

#[test]
fn queue_overflow_rejects_and_counters_reconcile() {
    // A heavy fixture (24x24 input) with total queue capacity 2: a
    // non-blocking submit burst must outpace the drain, so rejections are
    // observed, and afterwards accepted = completed with
    // accepted + rejected = submitted.
    let qm = QModel::synthetic(24, 8, 10, 0xBEEF);
    let server = Server::start(
        qm,
        ServerConfig {
            workers: 2,
            max_batch: 1,
            queue_depth: 1,
            verify_every: 0,
            batch_deadline: Duration::from_millis(0),
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let burst = 300usize;
    let frame = vec![1i64; 576];
    let mut pendings: Vec<Pending> = Vec::new();
    let mut errs = 0u64;
    for _ in 0..burst {
        match server.submit(frame.clone()) {
            Ok(p) => pendings.push(p),
            Err(e) => {
                assert!(e.contains("backpressure"), "{e}");
                errs += 1;
            }
        }
    }
    assert!(errs > 0, "burst of {burst} never overflowed capacity-2 queues");
    let accepted = pendings.len() as u64;
    for p in pendings {
        p.wait().unwrap();
    }
    let m = server.shutdown();
    assert_eq!(m.rejected, errs);
    assert_eq!(m.accepted, accepted);
    assert_eq!(m.completed, m.accepted, "accepted requests must all complete");
    assert_eq!(m.accepted + m.rejected, burst as u64);
}

#[test]
fn simulated_throughput_scales_with_workers() {
    // Deterministic scaling proof in simulated time: with batch = 1 and a
    // window-1 replay the per-shard frame assignment is exact round-robin,
    // so each shard's busy cycles — and the aggregate throughput — are
    // reproducible. 4 shards must run >= 2x one shard.
    let qm = fixture();
    let trace = loadgen::Trace::seeded(0x7E, 64, 64, 0);
    let mut agg_fps = Vec::new();
    let mut busy_max = Vec::new();
    for workers in [1usize, 4] {
        let mut server = Server::start(
            qm.clone(),
            ServerConfig {
                workers,
                max_batch: 1,
                queue_depth: 16,
                verify_every: 0,
                batch_deadline: Duration::from_millis(0),
                ..Default::default()
            },
            None,
        )
        .unwrap();
        let report = loadgen::replay(&server, &trace, 1, None);
        assert_eq!(report.ok, 64);
        assert_eq!(report.rejected, 0);
        server.drain();
        let shards = server.shard_metrics();
        busy_max.push(shards.iter().map(|s| s.busy_cycles).max().unwrap());
        let m = server.metrics();
        assert_eq!(m.completed, 64);
        agg_fps.push(m.aggregate_fps);
    }
    // Work splits evenly, so the simulated makespan shrinks ~4x.
    assert!(
        busy_max[1] * 2 < busy_max[0],
        "4-shard makespan {} !<< 1-shard {}",
        busy_max[1],
        busy_max[0]
    );
    assert!(
        agg_fps[1] >= 2.0 * agg_fps[0],
        "aggregate fps {:.0} !>= 2x {:.0}",
        agg_fps[1],
        agg_fps[0]
    );
}

#[test]
fn scaling_preserves_bit_exactness_via_loadgen() {
    // The same seeded trace through every worker count yields the same
    // golden-checked responses and fully reconciled counters.
    let qm = fixture();
    let sim = PipelineSim::new(qm.clone(), None).unwrap();
    let trace = loadgen::Trace::seeded(0x99, 60, 64, 2);
    let expected = loadgen::golden_outputs(&sim, &trace);
    for workers in [1usize, 2, 3, 4] {
        let mut server = Server::start(
            qm.clone(),
            ServerConfig {
                workers,
                max_batch: 6,
                queue_depth: 32,
                verify_every: 0,
                batch_deadline: Duration::from_micros(500),
                ..Default::default()
            },
            None,
        )
        .unwrap();
        let report = loadgen::replay(&server, &trace, 8, Some(&expected));
        server.drain();
        let shards = server.shard_metrics();
        let m = server.metrics();
        assert_eq!(report.ok, 60, "workers={workers}");
        assert_eq!(report.mismatched, 0, "workers={workers}");
        assert_eq!(report.rejected, 0, "workers={workers}");
        assert_eq!(m.completed, 60, "workers={workers}");
        assert_eq!(m.accepted, 60, "workers={workers}");
        // Shard counters must reconcile with the aggregate exactly.
        let shard_sum: u64 = shards.iter().map(|s| s.completed).sum();
        assert_eq!(shard_sum, m.completed, "workers={workers}");
        assert!(m.p50 <= m.p99, "workers={workers}");
    }
}

#[test]
fn batch_metrics_reconcile_under_seeded_trace() {
    // Micro-batch accounting must reconcile exactly for every worker
    // count: the summed batch occupancies equal the completed requests
    // (no frame counted twice, none dropped), the flush-reason counters
    // and the occupancy histogram both sum to the batch count, and the
    // same invariants hold per shard.
    let qm = fixture();
    let trace = loadgen::Trace::seeded(0xBA7C, 72, 64, 1);
    for workers in [1usize, 3] {
        let mut server = Server::start(
            qm.clone(),
            ServerConfig {
                workers,
                max_batch: 5,
                queue_depth: 64,
                verify_every: 0,
                batch_deadline: Duration::from_micros(200),
                ..Default::default()
            },
            None,
        )
        .unwrap();
        let report = loadgen::replay(&server, &trace, 12, None);
        assert_eq!(report.ok, 72, "workers={workers}");
        server.drain();
        let m = server.metrics();
        assert_eq!(m.completed, 72, "workers={workers}");
        assert_eq!(m.errored, 0, "workers={workers}");
        assert_eq!(
            m.occupancy_frames,
            m.completed,
            "workers={workers}: sum(batch occupancies) != completed"
        );
        assert_eq!(
            m.flush_full + m.flush_deadline + m.flush_drain,
            m.batches,
            "workers={workers}: flush reasons must partition the batches"
        );
        let hist_batches: u64 = m.batch_occupancy.iter().sum();
        assert_eq!(hist_batches, m.batches, "workers={workers}");
        // Sizes tracked exactly below the overflow bucket reconstruct the
        // frame total (max_batch = 5 stays far below OCC_BUCKETS).
        let hist_frames: u64 = m
            .batch_occupancy
            .iter()
            .enumerate()
            .map(|(i, &c)| (i as u64 + 1) * c)
            .sum();
        assert_eq!(hist_frames, m.occupancy_frames, "workers={workers}");
        for s in server.shard_metrics() {
            assert_eq!(s.occupancy_frames, s.completed, "shard {}", s.shard);
            assert_eq!(
                s.flush_full + s.flush_deadline + s.flush_drain,
                s.batches,
                "shard {}",
                s.shard
            );
        }
    }
}

// --------------------------------------------------------------------
// Registry: lowered-pipeline cache behaviour.
// --------------------------------------------------------------------

#[test]
fn registry_counts_hits_misses_and_evictions() {
    let reg = ModelRegistry::new(2);
    let a1 = reg
        .get_or_lower("a", || Ok(QModel::synthetic(8, 4, 6, 1)))
        .unwrap();
    let a2 = reg
        .get_or_lower("a", || Err("cached entries must not re-lower".to_string()))
        .unwrap();
    assert!(Arc::ptr_eq(&a1, &a2), "hit must return the cached artifact");
    reg.get_or_lower("b", || Ok(QModel::synthetic(8, 4, 6, 2)))
        .unwrap();
    // Capacity 2: inserting c evicts the LRU entry (a, last used before b).
    reg.get_or_lower("c", || Ok(QModel::synthetic(8, 4, 6, 3)))
        .unwrap();
    assert!(!reg.contains("a"));
    assert!(reg.contains("b") && reg.contains("c"));
    // Re-requesting the evicted model is a fresh miss (and evicts b).
    reg.get_or_lower("a", || Ok(QModel::synthetic(8, 4, 6, 1)))
        .unwrap();
    let s = reg.stats();
    assert_eq!(s.hits, 1, "{s:?}");
    assert_eq!(s.misses, 4, "{s:?}");
    assert_eq!(s.evictions, 2, "{s:?}");
    assert_eq!(s.cached, 2, "{s:?}");
}

#[test]
fn registry_concurrent_get_or_lower_shares_one_artifact() {
    // Single-flight: N threads racing on a cold key observe exactly one
    // lowering and end up holding the same Arc.
    let reg = Arc::new(ModelRegistry::new(4));
    let mut handles = Vec::new();
    for _ in 0..8 {
        let r = Arc::clone(&reg);
        handles.push(std::thread::spawn(move || {
            r.get_or_lower("shared", || Ok(QModel::synthetic(12, 8, 10, 0xCC)))
                .unwrap()
        }));
    }
    let artifacts: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for a in &artifacts[1..] {
        assert!(Arc::ptr_eq(&artifacts[0], a), "racers must share one bundle");
    }
    let s = reg.stats();
    assert_eq!(s.misses, 1, "exactly one lowering: {s:?}");
    assert_eq!(s.hits, 7, "{s:?}");
}

#[test]
fn registry_warm_lookup_beats_cold_lowering() {
    // Cold = synthesize + plan + lower a heavyweight fixture; warm = a
    // lock + hash lookup. The gap is orders of magnitude, so asserting
    // warm <= cold is robust.
    let reg = ModelRegistry::new(2);
    let t0 = Instant::now();
    reg.get_or_lower("heavy", || Ok(QModel::synthetic(24, 8, 10, 0xC01D)))
        .unwrap();
    let cold = t0.elapsed();
    let t1 = Instant::now();
    reg.get_or_lower("heavy", || Err("warm lookups must not re-lower".to_string()))
        .unwrap();
    let warm = t1.elapsed();
    // Generous escape hatch against scheduler noise: a warm hit is a lock
    // + hash lookup, so it either beats the cold path outright or stays
    // far below any plausible lowering time.
    assert!(
        warm <= cold || warm < Duration::from_micros(50),
        "warm lookup {warm:?} slower than cold lowering {cold:?}"
    );
}

// --------------------------------------------------------------------
// Multi-model serving: heterogeneous traces, routing, reconciliation.
// --------------------------------------------------------------------

#[test]
fn heterogeneous_trace_same_seed_is_deterministic() {
    // Same seed => identical per-model completion counts and identical
    // per-model metrics reconciliation across independent replays.
    let mut per_run_completed: Vec<Vec<u64>> = Vec::new();
    for _run in 0..2 {
        let fleet = three_model_fleet();
        let specs = fleet_specs(&fleet);
        let trace = loadgen::MultiTrace::seeded(0xDE7E, 75, &specs, 2);
        let mut server = Server::start_multi(
            fleet,
            ServerConfig {
                workers: 2,
                max_batch: 4,
                queue_depth: 64,
                verify_every: 0,
                batch_deadline: Duration::from_micros(300),
                ..Default::default()
            },
            None,
        )
        .unwrap();
        let report = loadgen::replay_multi(&server, &trace, 8, None);
        assert_eq!(report.aggregate.ok, 75);
        assert_eq!(report.aggregate.rejected, 0);
        server.drain();
        let m = server.metrics();
        assert_eq!(m.completed, 75);
        assert_eq!(m.errored, 0);
        assert_eq!(
            m.occupancy_frames,
            m.completed + m.errored,
            "aggregate occupancy must reconcile"
        );
        assert_eq!(m.flush_full + m.flush_deadline + m.flush_drain, m.batches);
        let per = server.model_metrics();
        let counts: Vec<u64> = per.iter().map(|p| p.metrics.completed).collect();
        // Replay-side per-model ok counts agree with the server's view,
        // and both match the seeded trace's model assignment.
        for ((p, rep), trace_count) in per
            .iter()
            .zip(&report.per_model)
            .zip(trace.per_model_counts())
        {
            assert_eq!(p.metrics.completed, rep.ok, "{}", p.model);
            assert_eq!(p.metrics.completed, trace_count, "{}", p.model);
            assert_eq!(
                p.metrics.occupancy_frames,
                p.metrics.completed + p.metrics.errored,
                "{}: per-model occupancy must reconcile",
                p.model
            );
            assert_eq!(
                p.metrics.flush_full + p.metrics.flush_deadline + p.metrics.flush_drain,
                p.metrics.batches,
                "{}: flush reasons must partition the batches",
                p.model
            );
        }
        per_run_completed.push(counts);
    }
    assert_eq!(
        per_run_completed[0], per_run_completed[1],
        "same seed must give identical per-model completion counts"
    );
}

#[test]
fn mixed_three_model_trace_bit_exact_and_fully_reconciled() {
    // THE acceptance case: a seeded 3-model trace through per-model shard
    // groups (sized by the route table) is bit-exact against each model's
    // own single-`PipelineSim` interpreter-backed golden path, and every
    // per-model + aggregate counter reconciles exactly.
    let fleet = three_model_fleet();
    let specs = fleet_specs(&fleet);
    let golden_sims: Vec<PipelineSim> = fleet.iter().map(|(_, s)| s.clone()).collect();
    let golden_refs: Vec<&PipelineSim> = golden_sims.iter().collect();
    let trace = loadgen::MultiTrace::seeded(0x3A0D, 90, &specs, 1);
    let expected = loadgen::golden_outputs_multi(&golden_refs, &trace);
    let routes: Vec<ModelRoute> = specs
        .iter()
        .enumerate()
        .map(|(i, (id, _))| ModelRoute {
            model: id.clone(),
            workers: 1 + i % 2, // mixed group sizes: 1, 2, 1
        })
        .collect();
    let mut server = Server::start_multi(
        fleet,
        ServerConfig {
            workers: 4, // overridden per model by the route table
            max_batch: 5,
            queue_depth: 64,
            verify_every: 0,
            batch_deadline: Duration::from_micros(400),
            routes,
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let report = loadgen::replay_multi(&server, &trace, 10, Some(&expected));
    assert_eq!(report.aggregate.ok, 90);
    assert_eq!(report.aggregate.mismatched, 0, "multi-model serving diverged");
    assert_eq!(report.aggregate.rejected, 0);
    server.drain();
    let m = server.metrics();
    assert_eq!(m.models, 3);
    assert_eq!(m.workers, 4, "route table: 1 + 2 + 1 shards");
    assert_eq!(m.completed, 90);
    assert_eq!(m.accepted, 90);
    assert_eq!(m.occupancy_frames, m.completed + m.errored);
    assert_eq!(m.flush_full + m.flush_deadline + m.flush_drain, m.batches);
    let hist_batches: u64 = m.batch_occupancy.iter().sum();
    assert_eq!(hist_batches, m.batches);
    let per = server.model_metrics();
    assert_eq!(per.iter().map(|p| p.metrics.completed).sum::<u64>(), 90);
    assert_eq!(
        per.iter().map(|p| p.metrics.batches).sum::<u64>(),
        m.batches,
        "per-model batches must sum to the aggregate"
    );
    for (p, rep) in per.iter().zip(&report.per_model) {
        assert_eq!(p.metrics.completed, rep.ok, "{}", p.model);
        assert_eq!(rep.mismatched, 0, "{}", p.model);
    }
}

#[test]
fn multi_model_drain_partial_batches_reconcile_per_model() {
    // Queue a different sub-max_batch request count per model with a far
    // deadline, then shut down: each group flushes exactly one drain
    // batch, and per-model + aggregate occupancy accounting includes
    // these partial batches.
    let fleet = three_model_fleet();
    let specs = fleet_specs(&fleet);
    let models: Vec<String> = specs.iter().map(|(id, _)| id.clone()).collect();
    let server = Server::start_multi(
        fleet,
        ServerConfig {
            workers: 1,
            max_batch: 16,
            queue_depth: 64,
            verify_every: 0,
            batch_deadline: Duration::from_secs(30),
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let mut pendings: Vec<Pending> = Vec::new();
    for (i, (id, len)) in specs.iter().enumerate() {
        for _ in 0..=i {
            pendings.push(server.submit_to(id, vec![1i64; *len]).unwrap());
        }
    }
    // Inspect per-model views before consuming the server.
    let per_before = server.models();
    assert_eq!(per_before, models);
    let m = server.shutdown();
    for p in pendings {
        p.wait().unwrap();
    }
    assert_eq!(m.completed, 6, "1 + 2 + 3 drained requests");
    assert_eq!(m.batches, 3, "one partial drain batch per model");
    assert_eq!(m.flush_drain, 3);
    assert_eq!(m.flush_full + m.flush_deadline, 0);
    assert_eq!(m.occupancy_frames, 6, "drain partial batches accounted");
    // Occupancy histogram: one batch each of sizes 1, 2 and 3.
    assert_eq!(m.batch_occupancy[0], 1);
    assert_eq!(m.batch_occupancy[1], 1);
    assert_eq!(m.batch_occupancy[2], 1);
}

#[test]
fn drain_on_shutdown_partial_batch_is_accounted() {
    // Queue K < max_batch requests with a deadline far in the future,
    // then shut down: the worker is still accumulating when the shutdown
    // marker arrives, so the whole group flushes as ONE drain batch of
    // exactly K frames — and the occupancy metrics must include it.
    let qm = fixture();
    let server = Server::start(
        qm,
        ServerConfig {
            workers: 1,
            max_batch: 16,
            queue_depth: 64,
            verify_every: 0,
            batch_deadline: Duration::from_secs(30),
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let frame = vec![1i64; 64];
    let pendings: Vec<Pending> = (0..5)
        .map(|_| server.submit(frame.clone()).unwrap())
        .collect();
    let m = server.shutdown();
    for p in pendings {
        p.wait().unwrap();
    }
    assert_eq!(m.completed, 5);
    assert_eq!(m.batches, 1, "one partial drain batch expected");
    assert_eq!(m.occupancy_frames, 5, "partial batch must be accounted");
    assert_eq!(m.flush_drain, 1);
    assert_eq!(m.flush_full + m.flush_deadline, 0);
    assert_eq!(m.batch_occupancy[4], 1, "occupancy bucket for size 5");
}
