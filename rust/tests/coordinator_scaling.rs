//! Integration tests for the sharded coordinator: bit-exactness against
//! the single-`PipelineSim` golden path under concurrent load, rejection
//! under queue overflow, metric reconciliation, and deterministic
//! simulated-throughput scaling with the worker count.
//!
//! Everything runs on the synthetic fixture — no artifacts, no skips, no
//! wall-clock sleeps: determinism comes from seeded traces, the FIFO
//! drain-on-shutdown, and simulated (not wall) time.

use std::sync::Arc;
use std::time::Duration;

use cnn_flow::coordinator::{loadgen, Pending, Server, ServerConfig};
use cnn_flow::quant::QModel;
use cnn_flow::sim::pipeline::PipelineSim;
use cnn_flow::util::Rng;

fn fixture() -> QModel {
    QModel::synthetic(8, 4, 6, 0x5CA1E)
}

#[test]
fn concurrent_load_is_bit_identical_to_single_sim() {
    let qm = fixture();
    let golden = Arc::new(PipelineSim::new(qm.clone(), None).unwrap());
    let server = Arc::new(
        Server::start(
            qm,
            ServerConfig {
                workers: 4,
                max_batch: 4,
                queue_depth: 128,
                verify_every: 0,
                batch_deadline: Duration::from_millis(1),
                ..Default::default()
            },
            None,
        )
        .unwrap(),
    );
    let mut handles = Vec::new();
    for c in 0..8u64 {
        let s = Arc::clone(&server);
        let g = Arc::clone(&golden);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0x1D + c);
            for _ in 0..12 {
                let x: Vec<i64> = (0..64).map(|_| rng.int8() as i64).collect();
                let expect = g.run(&[x.clone()]).unwrap().outputs[0].clone();
                let resp = s.infer(x).unwrap();
                assert_eq!(resp.logits, expect, "client {c} diverged");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = Arc::try_unwrap(server).ok().unwrap().shutdown();
    assert_eq!(m.completed, 96);
    assert_eq!(m.accepted, 96);
    assert_eq!(m.rejected, 0);
}

#[test]
fn queue_overflow_rejects_and_counters_reconcile() {
    // A heavy fixture (24x24 input) with total queue capacity 2: a
    // non-blocking submit burst must outpace the drain, so rejections are
    // observed, and afterwards accepted = completed with
    // accepted + rejected = submitted.
    let qm = QModel::synthetic(24, 8, 10, 0xBEEF);
    let server = Server::start(
        qm,
        ServerConfig {
            workers: 2,
            max_batch: 1,
            queue_depth: 1,
            verify_every: 0,
            batch_deadline: Duration::from_millis(0),
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let burst = 300usize;
    let frame = vec![1i64; 576];
    let mut pendings: Vec<Pending> = Vec::new();
    let mut errs = 0u64;
    for _ in 0..burst {
        match server.submit(frame.clone()) {
            Ok(p) => pendings.push(p),
            Err(e) => {
                assert!(e.contains("backpressure"), "{e}");
                errs += 1;
            }
        }
    }
    assert!(errs > 0, "burst of {burst} never overflowed capacity-2 queues");
    let accepted = pendings.len() as u64;
    for p in pendings {
        p.wait().unwrap();
    }
    let m = server.shutdown();
    assert_eq!(m.rejected, errs);
    assert_eq!(m.accepted, accepted);
    assert_eq!(m.completed, m.accepted, "accepted requests must all complete");
    assert_eq!(m.accepted + m.rejected, burst as u64);
}

#[test]
fn simulated_throughput_scales_with_workers() {
    // Deterministic scaling proof in simulated time: with batch = 1 and a
    // window-1 replay the per-shard frame assignment is exact round-robin,
    // so each shard's busy cycles — and the aggregate throughput — are
    // reproducible. 4 shards must run >= 2x one shard.
    let qm = fixture();
    let trace = loadgen::Trace::seeded(0x7E, 64, 64, 0);
    let mut agg_fps = Vec::new();
    let mut busy_max = Vec::new();
    for workers in [1usize, 4] {
        let mut server = Server::start(
            qm.clone(),
            ServerConfig {
                workers,
                max_batch: 1,
                queue_depth: 16,
                verify_every: 0,
                batch_deadline: Duration::from_millis(0),
                ..Default::default()
            },
            None,
        )
        .unwrap();
        let report = loadgen::replay(&server, &trace, 1, None);
        assert_eq!(report.ok, 64);
        assert_eq!(report.rejected, 0);
        server.drain();
        let shards = server.shard_metrics();
        busy_max.push(shards.iter().map(|s| s.busy_cycles).max().unwrap());
        let m = server.metrics();
        assert_eq!(m.completed, 64);
        agg_fps.push(m.aggregate_fps);
    }
    // Work splits evenly, so the simulated makespan shrinks ~4x.
    assert!(
        busy_max[1] * 2 < busy_max[0],
        "4-shard makespan {} !<< 1-shard {}",
        busy_max[1],
        busy_max[0]
    );
    assert!(
        agg_fps[1] >= 2.0 * agg_fps[0],
        "aggregate fps {:.0} !>= 2x {:.0}",
        agg_fps[1],
        agg_fps[0]
    );
}

#[test]
fn scaling_preserves_bit_exactness_via_loadgen() {
    // The same seeded trace through every worker count yields the same
    // golden-checked responses and fully reconciled counters.
    let qm = fixture();
    let sim = PipelineSim::new(qm.clone(), None).unwrap();
    let trace = loadgen::Trace::seeded(0x99, 60, 64, 2);
    let expected = loadgen::golden_outputs(&sim, &trace);
    for workers in [1usize, 2, 3, 4] {
        let mut server = Server::start(
            qm.clone(),
            ServerConfig {
                workers,
                max_batch: 6,
                queue_depth: 32,
                verify_every: 0,
                batch_deadline: Duration::from_micros(500),
                ..Default::default()
            },
            None,
        )
        .unwrap();
        let report = loadgen::replay(&server, &trace, 8, Some(&expected));
        server.drain();
        let shards = server.shard_metrics();
        let m = server.metrics();
        assert_eq!(report.ok, 60, "workers={workers}");
        assert_eq!(report.mismatched, 0, "workers={workers}");
        assert_eq!(report.rejected, 0, "workers={workers}");
        assert_eq!(m.completed, 60, "workers={workers}");
        assert_eq!(m.accepted, 60, "workers={workers}");
        // Shard counters must reconcile with the aggregate exactly.
        let shard_sum: u64 = shards.iter().map(|s| s.completed).sum();
        assert_eq!(shard_sum, m.completed, "workers={workers}");
        assert!(m.p50 <= m.p99, "workers={workers}");
    }
}

#[test]
fn batch_metrics_reconcile_under_seeded_trace() {
    // Micro-batch accounting must reconcile exactly for every worker
    // count: the summed batch occupancies equal the completed requests
    // (no frame counted twice, none dropped), the flush-reason counters
    // and the occupancy histogram both sum to the batch count, and the
    // same invariants hold per shard.
    let qm = fixture();
    let trace = loadgen::Trace::seeded(0xBA7C, 72, 64, 1);
    for workers in [1usize, 3] {
        let mut server = Server::start(
            qm.clone(),
            ServerConfig {
                workers,
                max_batch: 5,
                queue_depth: 64,
                verify_every: 0,
                batch_deadline: Duration::from_micros(200),
                ..Default::default()
            },
            None,
        )
        .unwrap();
        let report = loadgen::replay(&server, &trace, 12, None);
        assert_eq!(report.ok, 72, "workers={workers}");
        server.drain();
        let m = server.metrics();
        assert_eq!(m.completed, 72, "workers={workers}");
        assert_eq!(m.errored, 0, "workers={workers}");
        assert_eq!(
            m.occupancy_frames,
            m.completed,
            "workers={workers}: sum(batch occupancies) != completed"
        );
        assert_eq!(
            m.flush_full + m.flush_deadline + m.flush_drain,
            m.batches,
            "workers={workers}: flush reasons must partition the batches"
        );
        let hist_batches: u64 = m.batch_occupancy.iter().sum();
        assert_eq!(hist_batches, m.batches, "workers={workers}");
        // Sizes tracked exactly below the overflow bucket reconstruct the
        // frame total (max_batch = 5 stays far below OCC_BUCKETS).
        let hist_frames: u64 = m
            .batch_occupancy
            .iter()
            .enumerate()
            .map(|(i, &c)| (i as u64 + 1) * c)
            .sum();
        assert_eq!(hist_frames, m.occupancy_frames, "workers={workers}");
        for s in server.shard_metrics() {
            assert_eq!(s.occupancy_frames, s.completed, "shard {}", s.shard);
            assert_eq!(
                s.flush_full + s.flush_deadline + s.flush_drain,
                s.batches,
                "shard {}",
                s.shard
            );
        }
    }
}

#[test]
fn drain_on_shutdown_partial_batch_is_accounted() {
    // Queue K < max_batch requests with a deadline far in the future,
    // then shut down: the worker is still accumulating when the shutdown
    // marker arrives, so the whole group flushes as ONE drain batch of
    // exactly K frames — and the occupancy metrics must include it.
    let qm = fixture();
    let server = Server::start(
        qm,
        ServerConfig {
            workers: 1,
            max_batch: 16,
            queue_depth: 64,
            verify_every: 0,
            batch_deadline: Duration::from_secs(30),
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let frame = vec![1i64; 64];
    let pendings: Vec<Pending> = (0..5)
        .map(|_| server.submit(frame.clone()).unwrap())
        .collect();
    let m = server.shutdown();
    for p in pendings {
        p.wait().unwrap();
    }
    assert_eq!(m.completed, 5);
    assert_eq!(m.batches, 1, "one partial drain batch expected");
    assert_eq!(m.occupancy_frames, 5, "partial batch must be accounted");
    assert_eq!(m.flush_drain, 1);
    assert_eq!(m.flush_full + m.flush_deadline, 0);
    assert_eq!(m.batch_occupancy[4], 1, "occupancy bucket for size 5");
}
