//! Integration tests for the TCP serving front-end (DESIGN.md §8):
//! network golden-output equality (the TCP path must be byte-identical
//! to in-process `replay_multi`), protocol error-code ↔ coordinator
//! counter reconciliation (including a drain-partial case), pipelining
//! order, and malformed-input robustness — all over localhost sockets
//! with ephemeral ports, no external services, deterministic via seeded
//! traces and the FIFO drain (the only waiting is a bounded spin for
//! socket-carried requests to reach the coordinator's intake counters).

use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use cnn_flow::coordinator::{loadgen, EngineKind, Server, ServerConfig};
use cnn_flow::model::zoo;
use cnn_flow::net::client::Client;
use cnn_flow::net::proto::{self, ErrorCode, FrameDecoder, Msg, ProtoError, PROTO_VERSION};
use cnn_flow::net::server::{NetServer, NetServerConfig};
use cnn_flow::quant::QModel;
use cnn_flow::sim::pipeline::PipelineSim;
use cnn_flow::util::prop::prop_check;
use cnn_flow::util::Rng;

/// Three heterogeneous serving-zoo models, synthesized with fixed seeds —
/// the same fleet shape `tests/coordinator_scaling.rs` replays.
fn three_model_fleet() -> Vec<(String, PipelineSim)> {
    [zoo::digits_cnn(), zoo::mobilenet_micro(), zoo::vgg_micro()]
        .iter()
        .enumerate()
        .map(|(i, m)| {
            let qm = QModel::synthesize(m, 0x7CB0 + i as u64).unwrap();
            (m.name.clone(), PipelineSim::new(qm, None).unwrap())
        })
        .collect()
}

/// The full serving zoo — the chain configs plus the residual
/// `resnet_micro` / `mobilenet_v2_micro` DAGs — synthesized with fixed
/// seeds. Serving a residual model must need no serving-layer changes.
fn full_zoo_fleet() -> Vec<(String, PipelineSim)> {
    zoo::serving_zoo()
        .iter()
        .enumerate()
        .map(|(i, m)| {
            let qm = QModel::synthesize(m, 0x7CB0 + i as u64).unwrap();
            (m.name.clone(), PipelineSim::new(qm, None).unwrap())
        })
        .collect()
}

fn fleet_specs(fleet: &[(String, PipelineSim)]) -> Vec<(String, usize)> {
    fleet
        .iter()
        .map(|(id, sim)| (id.clone(), sim.input_len()))
        .collect()
}

fn fleet_config() -> ServerConfig {
    ServerConfig {
        workers: 2,
        max_batch: 4,
        queue_depth: 64,
        verify_every: 0,
        batch_deadline: Duration::from_micros(300),
        ..Default::default()
    }
}

/// Bounded spin until the coordinator's intake has accepted `n`
/// requests: socket-carried submissions are asynchronous (client write →
/// server reader → `submit_to`), so tests that reason about intake state
/// after a `submit` must wait for the counter, not for the write.
fn await_accepted(server: &Server, n: u64) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while server.metrics().accepted < n {
        assert!(
            Instant::now() < deadline,
            "coordinator never accepted {n} requests: {:?}",
            server.metrics()
        );
        std::thread::yield_now();
    }
}

// --------------------------------------------------------------------
// THE acceptance case: network golden-output equality.
// --------------------------------------------------------------------

#[test]
fn tcp_replay_is_byte_identical_to_in_process_replay() {
    // One seeded heterogeneous trace, one set of interpreter-backed
    // golden outputs, two transports: the in-process `replay_multi` and
    // the TCP `replay_net` must both reproduce the goldens bit-for-bit,
    // and their reports must be EQUAL — same ok/rejected/dropped/
    // mismatched per model — which is what "the socket boundary adds no
    // semantics" means.
    let fleet = three_model_fleet();
    let specs = fleet_specs(&fleet);
    let golden_refs: Vec<&PipelineSim> = fleet.iter().map(|(_, s)| s).collect();
    let trace = loadgen::MultiTrace::seeded(0x9E7D, 96, &specs, 1);
    let expected = loadgen::golden_outputs_multi(&golden_refs, &trace);

    // In-process replay.
    let mut inproc = Server::start_multi(fleet.clone(), fleet_config(), None).unwrap();
    let report_inproc = loadgen::replay_multi(&inproc, &trace, 8, Some(&expected));
    inproc.drain();
    let m_inproc = inproc.metrics();

    // TCP replay of the SAME trace against an identical fresh fleet.
    let coord = Arc::new(Server::start_multi(fleet, fleet_config(), None).unwrap());
    let mut net = NetServer::bind("127.0.0.1:0", Arc::clone(&coord)).unwrap();
    let client = Client::connect(&net.local_addr().to_string(), 8).unwrap();
    let report_tcp = loadgen::replay_net(&client, &trace, 8, Some(&expected));
    let net_snap = net.shutdown();
    let m_tcp = coord.metrics();

    assert_eq!(report_tcp.aggregate.ok, 96);
    assert_eq!(report_tcp.aggregate.mismatched, 0, "TCP path diverged from golden");
    assert_eq!(report_tcp.aggregate.rejected, 0);
    assert_eq!(report_tcp.aggregate.dropped, 0);
    assert_eq!(
        report_tcp, report_inproc,
        "TCP and in-process replays must produce identical reports"
    );
    // Coordinator-side accounting is transport-independent...
    assert_eq!(m_tcp.completed, m_inproc.completed);
    assert_eq!(m_tcp.accepted, m_inproc.accepted);
    assert_eq!(m_tcp.errored, 0);
    // ...and the net layer reconciles exactly with it.
    assert_eq!(net_snap.requests, 96);
    assert_eq!(net_snap.responses_ok, m_tcp.completed);
    assert_eq!(net_snap.errors_total(), 0);
    assert_eq!(net_snap.err_malformed, 0);
    assert_eq!(net_snap.connections, net_snap.disconnects);
}

#[test]
fn tcp_replay_full_zoo_with_residual_models_is_byte_identical() {
    // The extended-zoo acceptance case: one seeded trace over ALL six
    // serving-zoo models — including the residual resnet_micro and
    // mobilenet_v2_micro DAGs — replayed in-process and over TCP. Both
    // reports must reproduce the interpreter goldens bit-for-bit and be
    // EQUAL, per model: the socket boundary and the residual merge
    // epilogue both add no semantics.
    let fleet = full_zoo_fleet();
    let specs = fleet_specs(&fleet);
    assert!(specs.iter().any(|(id, _)| id == "resnet_micro"));
    assert!(specs.iter().any(|(id, _)| id == "mobilenet_v2_micro"));
    let golden_refs: Vec<&PipelineSim> = fleet.iter().map(|(_, s)| s).collect();
    let trace = loadgen::MultiTrace::seeded(0x8E51D, 120, &specs, 1);
    let counts = trace.per_model_counts();
    assert!(
        counts.iter().all(|&c| c > 0),
        "every model, residual ones included, must take traffic: {counts:?}"
    );
    let expected = loadgen::golden_outputs_multi(&golden_refs, &trace);

    // In-process replay.
    let mut inproc = Server::start_multi(fleet.clone(), fleet_config(), None).unwrap();
    let report_inproc = loadgen::replay_multi(&inproc, &trace, 8, Some(&expected));
    inproc.drain();
    let m_inproc = inproc.metrics();

    // TCP replay of the SAME trace against an identical fresh fleet.
    let coord = Arc::new(Server::start_multi(fleet, fleet_config(), None).unwrap());
    let mut net = NetServer::bind("127.0.0.1:0", Arc::clone(&coord)).unwrap();
    let client = Client::connect(&net.local_addr().to_string(), 8).unwrap();
    let report_tcp = loadgen::replay_net(&client, &trace, 8, Some(&expected));
    let net_snap = net.shutdown();
    let m_tcp = coord.metrics();

    assert_eq!(report_tcp.aggregate.ok, 120);
    assert_eq!(report_tcp.aggregate.mismatched, 0, "TCP path diverged from golden");
    assert_eq!(report_tcp.aggregate.rejected, 0);
    assert_eq!(report_tcp.aggregate.dropped, 0);
    assert_eq!(
        report_tcp, report_inproc,
        "TCP and in-process replays must produce identical reports"
    );
    // Exact per-model reconciliation on both transports: every model got
    // its trace share, answered it all, and matched its goldens.
    for (i, (id, _)) in specs.iter().enumerate() {
        let r = &report_tcp.per_model[i];
        assert_eq!(r.submitted, counts[i], "{id}: trace share");
        assert_eq!(r.ok, counts[i], "{id}: all answered");
        assert_eq!(r.mismatched, 0, "{id}: diverged from golden");
        assert_eq!(r.rejected + r.dropped, 0, "{id}: lost requests");
    }
    assert_eq!(m_tcp.completed, m_inproc.completed);
    assert_eq!(m_tcp.accepted, m_inproc.accepted);
    assert_eq!(m_tcp.errored, 0);
    assert_eq!(net_snap.requests, 120);
    assert_eq!(net_snap.responses_ok, m_tcp.completed);
    assert_eq!(net_snap.errors_total(), 0);
}

#[test]
fn tcp_drain_completes_partial_batches_for_residual_models() {
    // Drain-partial over the residual pair alone: 1 + 2 requests with a
    // far deadline and a big max_batch, so nothing flushes until the
    // front-end drains — one partial batch per residual model, every
    // reply bit-identical to the interpreter golden.
    let fleet: Vec<(String, PipelineSim)> = full_zoo_fleet()
        .into_iter()
        .filter(|(id, _)| id == "resnet_micro" || id == "mobilenet_v2_micro")
        .collect();
    assert_eq!(fleet.len(), 2);
    let specs = fleet_specs(&fleet);
    let golden_refs: Vec<PipelineSim> = fleet.iter().map(|(_, s)| s.clone()).collect();
    let coord = Arc::new(
        Server::start_multi(
            fleet,
            ServerConfig {
                workers: 1,
                max_batch: 16,
                queue_depth: 64,
                verify_every: 0,
                batch_deadline: Duration::from_secs(30),
                ..Default::default()
            },
            None,
        )
        .unwrap(),
    );
    let mut net = NetServer::bind("127.0.0.1:0", Arc::clone(&coord)).unwrap();
    let client = Client::connect(&net.local_addr().to_string(), 3).unwrap();

    let mut pendings = Vec::new();
    let mut expects = Vec::new();
    for (i, (id, len)) in specs.iter().enumerate() {
        for _ in 0..=i {
            let frame = vec![1i64; *len];
            expects.push(
                golden_refs[i]
                    .run_interpreted(&[frame.clone()])
                    .unwrap()
                    .outputs[0]
                    .clone(),
            );
            pendings.push(client.submit(id, &frame).unwrap());
        }
    }
    await_accepted(&coord, 3);

    let net_snap = net.shutdown();
    for (pending, expect) in pendings.into_iter().zip(expects) {
        let resp = pending.wait().expect("in-flight request dropped by drain");
        assert_eq!(resp.logits, expect, "drained residual response diverged");
    }
    let m = coord.metrics();
    assert_eq!(m.completed, 3, "1 + 2 drained requests");
    assert_eq!(m.batches, 2, "one partial drain batch per residual model");
    assert_eq!(m.flush_drain, 2);
    assert_eq!(m.flush_full + m.flush_deadline, 0);
    assert_eq!(net_snap.requests, 3);
    assert_eq!(net_snap.responses_ok, 3);
    assert_eq!(net_snap.errors_total(), 0);
}

// --------------------------------------------------------------------
// Error-code ↔ coordinator-counter reconciliation.
// --------------------------------------------------------------------

#[test]
fn unknown_model_and_invalid_frame_codes_reconcile() {
    let fleet = three_model_fleet();
    let specs = fleet_specs(&fleet);
    let coord = Arc::new(Server::start_multi(fleet, fleet_config(), None).unwrap());
    let mut net = NetServer::bind("127.0.0.1:0", Arc::clone(&coord)).unwrap();
    let client = Client::connect(&net.local_addr().to_string(), 2).unwrap();

    // The advertised model list matches the coordinator's routes.
    assert_eq!(client.models().unwrap(), specs);

    // Unknown route: typed refusal, coordinator counts it unrouted.
    let err = client.infer("no-such-model", &[0i64; 4]).unwrap_err();
    assert_eq!(err.code, Some(ErrorCode::UnknownModel));

    // Wrong frame length: accepted, then refused by validation.
    let (model, input_len) = specs[0].clone();
    let err = client.infer(&model, &vec![1i64; input_len + 3]).unwrap_err();
    assert_eq!(err.code, Some(ErrorCode::InvalidFrame));

    // A good request still works on the same pooled connection.
    assert!(client.infer(&model, &vec![1i64; input_len]).is_ok());

    let net_snap = net.shutdown();
    let m = coord.metrics();
    assert_eq!(net_snap.requests, 3);
    assert_eq!(net_snap.responses_ok, 1);
    assert_eq!(net_snap.err_unknown_model, 1);
    assert_eq!(net_snap.err_unknown_model, m.unrouted);
    assert_eq!(net_snap.err_invalid_frame, 1);
    assert_eq!(net_snap.err_invalid_frame, m.errored);
    assert_eq!(net_snap.responses_ok, m.completed);
    assert_eq!(
        net_snap.requests,
        net_snap.responses_ok + net_snap.errors_total(),
        "every decoded request gets exactly one answer"
    );
}

#[test]
fn backpressure_surfaces_as_queue_full_and_reconciles() {
    // Heavy fixture (24x24 input), total queue capacity ~2, batch 1: a
    // pipelined burst on ONE socket outruns the drain by construction —
    // the reader submits back-to-back while each frame takes real
    // simulation time — so rejections are observed as typed QueueFull
    // errors, and the net tally equals the coordinator's intake counter.
    let qm = QModel::synthetic(24, 8, 10, 0x8EEF);
    let golden = PipelineSim::new(qm.clone(), None).unwrap();
    let coord = Arc::new(
        Server::start(
            qm,
            ServerConfig {
                workers: 1,
                max_batch: 1,
                queue_depth: 1,
                verify_every: 0,
                batch_deadline: Duration::from_millis(0),
                // Pin the slow oracle engine so per-frame execution is
                // orders of magnitude slower than decode+submit — the
                // reader outruns the drain regardless of CI leg.
                engine: EngineKind::Interpreter,
                ..Default::default()
            },
            None,
        )
        .unwrap(),
    );
    let mut net = NetServer::bind("127.0.0.1:0", Arc::clone(&coord)).unwrap();
    let mut stream = TcpStream::connect(net.local_addr()).unwrap();
    stream.set_nodelay(true).unwrap();

    let burst = 200u64;
    let frame = vec![1i64; golden.input_len()];
    let expect = golden.run_interpreted(&[frame.clone()]).unwrap().outputs[0].clone();
    let mut wire = Vec::new();
    for id in 0..burst {
        wire.extend_from_slice(
            &Msg::InferRequest {
                id,
                model: coord.models()[0].clone(),
                frame: frame.clone(),
                deadline_us: 0,
                class: 0,
            }
            .encode()
            .unwrap(),
        );
    }
    stream.write_all(&wire).unwrap();

    // Responses come back in request order: ids 0..burst, each either ok
    // (bit-identical to the golden sim) or a typed QueueFull refusal.
    let (mut ok, mut full) = (0u64, 0u64);
    for id in 0..burst {
        match proto::read_frame(&mut stream).unwrap().unwrap() {
            Msg::InferOk { id: got, logits, .. } => {
                assert_eq!(got, id, "responses must preserve request order");
                assert_eq!(logits, expect);
                ok += 1;
            }
            Msg::InferErr { id: got, code, .. } => {
                assert_eq!(got, id, "responses must preserve request order");
                assert_eq!(code, ErrorCode::QueueFull);
                full += 1;
            }
            other => panic!("unexpected reply: {other:?}"),
        }
    }
    assert_eq!(ok + full, burst);
    assert!(full > 0, "burst of {burst} never overflowed capacity-2 queues");
    drop(stream);

    let net_snap = net.shutdown();
    let m = coord.metrics();
    assert_eq!(net_snap.requests, burst);
    assert_eq!(net_snap.responses_ok, ok);
    assert_eq!(net_snap.err_queue_full, full);
    assert_eq!(m.rejected, full, "QueueFull must reconcile with intake rejected");
    assert_eq!(m.completed, ok);
}

// --------------------------------------------------------------------
// Graceful drain over TCP, incl. the drain-partial batch case.
// --------------------------------------------------------------------

#[test]
fn tcp_drain_completes_in_flight_partial_batches_per_model() {
    // 1 + 2 + 3 requests across three models with a far deadline and a
    // big max_batch: nothing flushes until the front-end drains. The
    // shutdown must answer every in-flight request (one partial drain
    // batch per model), close the sockets cleanly, and leave net +
    // coordinator counters reconciled — the TCP image of
    // `multi_model_drain_partial_batches_reconcile_per_model`.
    let fleet = three_model_fleet();
    let specs = fleet_specs(&fleet);
    let golden_refs: Vec<PipelineSim> = fleet.iter().map(|(_, s)| s.clone()).collect();
    let coord = Arc::new(
        Server::start_multi(
            fleet,
            ServerConfig {
                workers: 1,
                max_batch: 16,
                queue_depth: 64,
                verify_every: 0,
                batch_deadline: Duration::from_secs(30),
                ..Default::default()
            },
            None,
        )
        .unwrap(),
    );
    let mut net = NetServer::bind("127.0.0.1:0", Arc::clone(&coord)).unwrap();
    let client = Client::connect(&net.local_addr().to_string(), 6).unwrap();

    let mut pendings = Vec::new();
    let mut expects = Vec::new();
    for (i, (id, len)) in specs.iter().enumerate() {
        for _ in 0..=i {
            let frame = vec![1i64; *len];
            expects.push(
                golden_refs[i]
                    .run_interpreted(&[frame.clone()])
                    .unwrap()
                    .outputs[0]
                    .clone(),
            );
            pendings.push(client.submit(id, &frame).unwrap());
        }
    }
    // The submissions are socket-borne: wait until the coordinator has
    // accepted all six before initiating the drain.
    await_accepted(&coord, 6);

    let net_snap = net.shutdown();
    // Every in-flight request was answered before its socket closed.
    for (pending, expect) in pendings.into_iter().zip(expects) {
        let resp = pending.wait().expect("in-flight request dropped by drain");
        assert_eq!(resp.logits, expect, "drained response diverged from golden");
    }
    let m = coord.metrics();
    assert_eq!(m.completed, 6, "1 + 2 + 3 drained requests");
    assert_eq!(m.batches, 3, "one partial drain batch per model");
    assert_eq!(m.flush_drain, 3);
    assert_eq!(m.flush_full + m.flush_deadline, 0);
    assert_eq!(m.occupancy_frames, 6, "drain partial batches accounted");
    assert_eq!(net_snap.requests, 6);
    assert_eq!(net_snap.responses_ok, 6, "drain must not drop in-flight replies");
    assert_eq!(net_snap.errors_total(), 0);
    assert_eq!(net_snap.connections, net_snap.disconnects);

    // After the drain the front-end refuses new work entirely.
    match Client::connect(&net.local_addr().to_string(), 1) {
        Err(_) => {}
        Ok(c) => assert!(c.models().is_err(), "listener must be gone after drain"),
    }
}

// --------------------------------------------------------------------
// Wire protocol: seeded round-trip property + malformed-frame handling.
// --------------------------------------------------------------------

#[test]
fn wire_protocol_roundtrips_for_random_valid_frames() {
    prop_check(192, 0x9120E, |rng| {
        let msg = random_msg(rng);
        let bytes = msg
            .encode()
            .map_err(|e| format!("encode of valid {msg:?} refused: {e}"))?;
        let mut cursor = &bytes[..];
        let decoded = proto::read_frame(&mut cursor)
            .map_err(|e| format!("decode of encoded {msg:?} failed: {e}"))?
            .ok_or_else(|| "unexpected EOF".to_string())?;
        if decoded != msg {
            return Err(format!("roundtrip changed the message: {msg:?} -> {decoded:?}"));
        }
        if !cursor.is_empty() {
            return Err(format!("{} undecoded bytes left", cursor.len()));
        }
        Ok(())
    });
}

fn random_msg(rng: &mut Rng) -> Msg {
    fn random_string(rng: &mut Rng) -> String {
        let n = rng.below(24) as usize;
        (0..n)
            .map(|_| char::from(b'a' + rng.below(26) as u8))
            .collect()
    }
    fn random_vec(rng: &mut Rng) -> Vec<i64> {
        let n = rng.below(96) as usize;
        (0..n)
            .map(|_| match rng.below(8) {
                0 => i64::MIN,
                1 => i64::MAX,
                _ => rng.int8() as i64,
            })
            .collect()
    }
    match rng.below(5) {
        0 => Msg::InferRequest {
            id: rng.next_u64(),
            model: random_string(rng),
            frame: random_vec(rng),
            deadline_us: rng.next_u64() >> (rng.below(64) as u32),
            class: rng.below(256) as u8,
        },
        1 => Msg::InferOk {
            id: rng.next_u64(),
            argmax: rng.below(1 << 16) as u32,
            sim_latency_cycles: rng.next_u64(),
            logits: random_vec(rng),
            predicted_cycles: rng.next_u64() >> (rng.below(64) as u32),
            slo_met: rng.below(2) == 1,
        },
        2 => Msg::InferErr {
            id: rng.next_u64(),
            code: ErrorCode::from_u8(1 + rng.below(6) as u8).unwrap(),
            message: random_string(rng),
        },
        3 => Msg::ListModels,
        _ => Msg::ModelList {
            models: (0..rng.below(6))
                .map(|_| (random_string(rng), rng.below(1 << 20) as u32))
                .collect(),
        },
    }
}

#[test]
fn malformed_wire_bytes_never_panic_the_decoder() {
    // Targeted malformations get their typed errors...
    let mut two: &[u8] = &[0, 1];
    assert_eq!(proto::read_frame(&mut two), Err(ProtoError::Truncated));
    let mut oversized: &[u8] = &[0xFF, 0xFF, 0xFF, 0xFF, 0, 0];
    assert!(matches!(
        proto::read_frame(&mut oversized),
        Err(ProtoError::Oversized(_))
    ));
    let bad_version = [0, 0, 0, 2, PROTO_VERSION + 7, 0x04];
    let mut cursor = &bad_version[..];
    assert_eq!(
        proto::read_frame(&mut cursor),
        Err(ProtoError::BadVersion(PROTO_VERSION + 7))
    );
    // ...and arbitrary fuzzed bodies decode to *some* Result, never a
    // panic (the server's no-panic guarantee rests on this).
    prop_check(256, 0xF022, |rng| {
        let n = rng.below(64) as usize + 2;
        let mut body: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let _ = Msg::decode(&body);
        // Also with a plausible header, fuzzing only the payload.
        body[0] = PROTO_VERSION;
        body[1] = 1 + rng.below(5) as u8;
        let _ = Msg::decode(&body);
        Ok(())
    });
}

#[test]
fn server_answers_malformed_bytes_and_keeps_serving() {
    let qm = QModel::synthetic(8, 4, 6, 0xBAD0);
    let coord = Arc::new(
        Server::start(
            qm,
            ServerConfig {
                workers: 1,
                verify_every: 0,
                batch_deadline: Duration::from_millis(0),
                ..Default::default()
            },
            None,
        )
        .unwrap(),
    );
    let mut net = NetServer::bind("127.0.0.1:0", Arc::clone(&coord)).unwrap();

    // Connection 1: an oversized length prefix. The server must answer
    // with a typed Malformed error (request id 0) and close — and MUST
    // NOT crash. (Nothing is written beyond the prefix, so the close is
    // a clean FIN rather than an RST that could race the error frame.)
    let mut bad = TcpStream::connect(net.local_addr()).unwrap();
    bad.write_all(&[0xFF, 0xFF, 0xFF, 0xFF]).unwrap();
    match proto::read_frame(&mut bad).unwrap() {
        Some(Msg::InferErr { id, code, .. }) => {
            assert_eq!(id, 0);
            assert_eq!(code, ErrorCode::Malformed);
        }
        other => panic!("expected a Malformed error, got {other:?}"),
    }
    // The connection is closed after a framing violation.
    assert_eq!(proto::read_frame(&mut bad).unwrap(), None);

    // Connection 2: a body that lies about its vector count.
    let mut liar = TcpStream::connect(net.local_addr()).unwrap();
    let mut body = vec![PROTO_VERSION, 0x01]; // InferRequest
    body.extend_from_slice(&7u64.to_be_bytes());
    body.extend_from_slice(&1u16.to_be_bytes());
    body.push(b'm');
    body.extend_from_slice(&u32::MAX.to_be_bytes()); // "4 billion values"
    let mut framed = (body.len() as u32).to_be_bytes().to_vec();
    framed.extend_from_slice(&body);
    liar.write_all(&framed).unwrap();
    match proto::read_frame(&mut liar).unwrap() {
        Some(Msg::InferErr { code, .. }) => assert_eq!(code, ErrorCode::Malformed),
        other => panic!("expected a Malformed error, got {other:?}"),
    }

    // The server is still alive: a well-formed client is served.
    let client = Client::connect(&net.local_addr().to_string(), 1).unwrap();
    let (model, len) = client.models().unwrap()[0].clone();
    assert!(client.infer(&model, &vec![1i64; len]).is_ok());

    let snap = net.shutdown();
    assert_eq!(snap.err_malformed, 2);
    assert_eq!(snap.responses_ok, 1);
    assert_eq!(coord.metrics().completed, 1, "malformed bytes never reach a shard");
}

#[test]
fn pipelined_requests_on_one_socket_answer_in_order() {
    let qm = QModel::synthetic(8, 4, 6, 0x41FE);
    let golden = PipelineSim::new(qm.clone(), None).unwrap();
    let coord = Arc::new(
        Server::start(
            qm,
            ServerConfig {
                workers: 2,
                max_batch: 4,
                queue_depth: 64,
                verify_every: 0,
                batch_deadline: Duration::from_micros(200),
                ..Default::default()
            },
            None,
        )
        .unwrap(),
    );
    let mut net = NetServer::bind("127.0.0.1:0", Arc::clone(&coord)).unwrap();
    let model = coord.models()[0].clone();

    // Six distinct frames, written back-to-back before reading anything.
    let mut rng = Rng::new(0x60D);
    let frames: Vec<Vec<i64>> = (0..6)
        .map(|_| (0..64).map(|_| rng.int8() as i64).collect())
        .collect();
    let mut stream = TcpStream::connect(net.local_addr()).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut wire = Vec::new();
    for (i, frame) in frames.iter().enumerate() {
        wire.extend_from_slice(
            &Msg::InferRequest {
                id: 100 + i as u64,
                model: model.clone(),
                frame: frame.clone(),
                deadline_us: 0,
                class: 0,
            }
            .encode()
            .unwrap(),
        );
    }
    stream.write_all(&wire).unwrap();

    for (i, frame) in frames.iter().enumerate() {
        let expect = golden.run_interpreted(&[frame.clone()]).unwrap().outputs[0].clone();
        match proto::read_frame(&mut stream).unwrap().unwrap() {
            Msg::InferOk { id, logits, .. } => {
                assert_eq!(id, 100 + i as u64, "pipelined responses out of order");
                assert_eq!(logits, expect, "frame {i} diverged");
            }
            other => panic!("unexpected reply: {other:?}"),
        }
    }
    drop(stream);
    let snap = net.shutdown();
    assert_eq!(snap.requests, 6);
    assert_eq!(snap.responses_ok, 6);
    assert_eq!(snap.connections, 1, "pipelining happened on one socket");
}

// --------------------------------------------------------------------
// Incremental decoder: split-point properties vs the blocking reader.
// --------------------------------------------------------------------

#[test]
fn incremental_decoder_matches_blocking_reader_at_every_split() {
    // One seeded multi-message stream, re-decoded once per chunk size
    // from 1 byte (every read lands mid-prefix or mid-body somewhere)
    // up to the whole wire image in a single push. Every split schedule
    // must yield the identical message sequence the blocking
    // `read_frame` oracle produces, with no residue.
    let mut rng = Rng::new(0xDEC0);
    let msgs: Vec<Msg> = (0..8).map(|_| random_msg(&mut rng)).collect();
    let mut wire = Vec::new();
    for m in &msgs {
        m.encode_into(&mut wire).unwrap();
    }
    let mut cursor = &wire[..];
    let mut oracle = Vec::new();
    while let Some(m) = proto::read_frame(&mut cursor).unwrap() {
        oracle.push(m);
    }
    assert_eq!(oracle, msgs, "the blocking reader is the ground truth");

    for chunk in 1..=wire.len() {
        let mut dec = FrameDecoder::new();
        let mut got = Vec::new();
        for piece in wire.chunks(chunk) {
            dec.push_bytes(piece);
            while let Some(m) = dec.next().unwrap() {
                got.push(m);
            }
        }
        assert_eq!(got, msgs, "chunk size {chunk} diverged from the oracle");
        assert!(!dec.has_partial(), "chunk size {chunk} left residue");
    }
}

#[test]
fn incremental_decoder_matches_blocking_reader_at_random_splits() {
    prop_check(64, 0x5EED5, |rng| {
        let n = 1 + rng.below(6) as usize;
        let msgs: Vec<Msg> = (0..n).map(|_| random_msg(rng)).collect();
        let mut wire = Vec::new();
        for m in &msgs {
            m.encode_into(&mut wire)
                .map_err(|e| format!("encode of valid {m:?} refused: {e}"))?;
        }
        let mut dec = FrameDecoder::new();
        let mut got = Vec::new();
        let mut off = 0;
        while off < wire.len() {
            let end = (off + 1 + rng.below(257) as usize).min(wire.len());
            dec.push_bytes(&wire[off..end]);
            off = end;
            loop {
                match dec.next() {
                    Ok(Some(m)) => got.push(m),
                    Ok(None) => break,
                    Err(e) => return Err(format!("decoder refused valid bytes: {e}")),
                }
            }
        }
        if got != msgs {
            return Err(format!("decoded {} of {} messages", got.len(), msgs.len()));
        }
        if dec.has_partial() {
            return Err("residue left after a fully-consumed stream".into());
        }
        Ok(())
    });
}

#[test]
fn incremental_decoder_never_panics_and_matches_blocking_verdict() {
    // Adversarial streams: random bytes, half the time prefixed with one
    // valid frame so the corruption lands *after* a successful decode.
    // The decoder must never panic, must reproduce the oracle's decoded
    // prefix, and must reach the oracle's verdict — with EOF-mid-frame
    // (`Truncated`) showing up as buffered residue on the incremental
    // side, since only the push-side caller can observe EOF.
    prop_check(128, 0xADB17E5, |rng| {
        let mut bytes: Vec<u8> = (0..1 + rng.below(2048) as usize)
            .map(|_| rng.below(256) as u8)
            .collect();
        if rng.below(2) == 0 {
            let msg = random_msg(rng);
            let mut framed = msg
                .encode()
                .map_err(|e| format!("encode of valid {msg:?} refused: {e}"))?;
            framed.extend_from_slice(&bytes);
            bytes = framed;
        }
        let mut cursor = &bytes[..];
        let mut oracle = Vec::new();
        let oracle_err = loop {
            match proto::read_frame(&mut cursor) {
                Ok(Some(m)) => oracle.push(m),
                Ok(None) => break None,
                Err(e) => break Some(e),
            }
        };

        let mut dec = FrameDecoder::new();
        let mut got = Vec::new();
        let mut dec_err = None;
        let mut off = 0;
        'feed: while off < bytes.len() {
            let end = (off + 1 + rng.below(64) as usize).min(bytes.len());
            dec.push_bytes(&bytes[off..end]);
            off = end;
            loop {
                match dec.next() {
                    Ok(Some(m)) => got.push(m),
                    Ok(None) => break,
                    Err(e) => {
                        dec_err = Some(e);
                        break 'feed;
                    }
                }
            }
        }
        if got != oracle {
            return Err(format!(
                "decoded prefixes differ: {} vs oracle {}",
                got.len(),
                oracle.len()
            ));
        }
        match (oracle_err, dec_err) {
            (None, None) if dec.has_partial() => Err("residue without truncation".into()),
            (None, None) => Ok(()),
            (Some(ProtoError::Truncated), None) if dec.has_partial() => Ok(()),
            (Some(o), Some(d)) if o == d => Ok(()),
            (o, d) => Err(format!("verdicts differ: oracle {o:?} vs decoder {d:?}")),
        }
    });
}

// --------------------------------------------------------------------
// Write-stall teardown on the threaded core.
// --------------------------------------------------------------------

#[test]
fn threaded_write_stall_tears_down_and_counters_balance() {
    // A client that pipelines a burst of large-response requests and
    // never reads: once the kernel socket buffers fill, the writer
    // thread blocks, the bounded reply queue fills, and the configured
    // `write_stall_timeout` must tear the connection down instead of
    // wedging a handler thread forever — with every decoded request
    // still landing in exactly one counter (`net_evented.rs` pins the
    // identical invariant on the reactor core).
    let qm = QModel::synthetic(8, 4, 384, 0x57A1);
    let coord = Arc::new(
        Server::start(
            qm,
            ServerConfig {
                workers: 2,
                max_batch: 16,
                queue_depth: 1024,
                verify_every: 0,
                batch_deadline: Duration::from_micros(200),
                ..Default::default()
            },
            None,
        )
        .unwrap(),
    );
    let config = NetServerConfig {
        writer_queue_depth: 16,
        write_stall_timeout: Duration::from_millis(100),
    };
    let mut net = NetServer::bind_with("127.0.0.1:0", Arc::clone(&coord), config).unwrap();
    let model = coord.models()[0].clone();

    let burst = 400u64;
    let stream = TcpStream::connect(net.local_addr()).unwrap();
    stream.set_nodelay(true).unwrap();
    {
        let mut tx = stream.try_clone().unwrap();
        let mut wire = Vec::new();
        let frame = vec![1i64; 8 * 8];
        for id in 0..burst {
            Msg::InferRequest {
                id,
                model: model.clone(),
                frame: frame.clone(),
                deadline_us: 0,
                class: 0,
            }
            .encode_into(&mut wire)
            .unwrap();
        }
        tx.write_all(&wire).unwrap();
    }
    // Do NOT read. ~384 i64 logits per response (~3KB on the wire) x 400
    // responses far exceeds the loopback socket buffers, so the stalled
    // writer must trip the timeout and the server must give up on this
    // peer without losing any counter.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let snap = net.metrics();
        if snap.responses_ok + snap.errors_total() == burst {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "stalled connection never settled the burst: {snap:?}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    drop(stream);
    let snap = net.shutdown();
    assert_eq!(snap.requests, burst);
    assert_eq!(
        snap.requests,
        snap.responses_ok + snap.errors_total(),
        "every decoded request gets exactly one counter: {snap:?}"
    );
    assert_eq!(snap.connections, 1);
    assert_eq!(snap.disconnects, 1, "the stalled connection was torn down");
}
