//! End-to-end integration over the real artifacts (E12 in test form):
//! artifact manifest sanity, three-way value agreement (rust cycle sim ==
//! PJRT-executed JAX golden == exporter vectors), and a full serve loop
//! with golden verification enabled.
//!
//! All tests skip (with a note) when `make artifacts` hasn't run.

use std::sync::Arc;
use std::time::Duration;

use cnn_flow::coordinator::{Server, ServerConfig};
use cnn_flow::quant::QModel;
use cnn_flow::runtime::{artifacts_dir, ModelBundle, Runtime};
use cnn_flow::sim::pipeline::PipelineSim;
use cnn_flow::util::json::Json;
use cnn_flow::util::Rng;

fn ready() -> bool {
    let ok = artifacts_dir().join("meta.json").exists();
    if !ok {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
    }
    ok
}

#[test]
fn meta_manifest_lists_both_models() {
    if !ready() {
        return;
    }
    let text = std::fs::read_to_string(artifacts_dir().join("meta.json")).unwrap();
    let j = Json::parse(&text).unwrap();
    for name in ["digits", "jsc"] {
        let entry = j.get("models").get(name);
        assert!(entry.get("qat_accuracy").as_f64().unwrap() > 0.9, "{name}");
        let hlo = entry.get("int8_hlo").as_str().unwrap();
        assert!(artifacts_dir().join(hlo).exists(), "{hlo} missing");
    }
}

#[test]
fn hlo_artifacts_have_full_constants() {
    if !ready() {
        return;
    }
    for name in ["digits_int8", "jsc_int8", "digits_float", "model"] {
        let path = artifacts_dir().join(format!("{name}.hlo.txt"));
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(
            !text.contains("{...}"),
            "{name}: HLO printer elided constants"
        );
        assert!(text.contains("ENTRY"), "{name}: not an HLO module");
    }
}

#[test]
fn three_way_agreement_on_random_inputs() {
    if !ready() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    for name in ["digits", "jsc"] {
        let bundle = ModelBundle::load(&rt, name).unwrap();
        let sim = PipelineSim::new(bundle.qmodel.clone(), None).unwrap();
        let n: usize = bundle.qmodel.input_shape.iter().product();
        let mut rng = Rng::new(0x3A3);
        for case in 0..6 {
            let x_q: Vec<i64> = (0..n).map(|_| rng.int8() as i64).collect();
            let xf: Vec<f32> = x_q.iter().map(|&v| v as f32).collect();
            let golden: Vec<i64> = bundle
                .golden
                .run_f32(&xf)
                .unwrap()
                .iter()
                .map(|&v| v as i64)
                .collect();
            let simulated = sim.run(&[x_q]).unwrap().outputs[0].clone();
            assert_eq!(simulated, golden, "{name} case {case}");
        }
        // And the exporter's stored vectors agree too.
        for (i, tv) in bundle.qmodel.test_vectors.iter().enumerate() {
            let simulated = sim.run(&[tv.x_q.clone()]).unwrap().outputs[0].clone();
            assert_eq!(simulated, tv.y, "{name} stored vector {i}");
        }
    }
}

#[test]
fn serve_with_live_golden_verification() {
    if !ready() {
        return;
    }
    let qm = QModel::load(&artifacts_dir().join("weights/digits.json")).unwrap();
    let server = Arc::new(
        Server::start(
            qm.clone(),
            ServerConfig {
                batch: 8,
                verify_every: 2, // verify half of all requests
                ..Default::default()
            },
            Some("digits".into()),
        )
        .unwrap(),
    );
    let mut handles = Vec::new();
    for c in 0..4 {
        let s = Arc::clone(&server);
        let vectors: Vec<Vec<i64>> = qm.test_vectors.iter().map(|t| t.x_q.clone()).collect();
        handles.push(std::thread::spawn(move || {
            for i in 0..24 {
                s.infer(vectors[(c + i) % vectors.len()].clone()).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    // Let the async verifier drain.
    std::thread::sleep(Duration::from_millis(800));
    let m = Arc::try_unwrap(server).ok().unwrap().shutdown();
    assert_eq!(m.completed, 96);
    assert!(m.verified > 0, "verifier never ran");
    assert_eq!(m.mismatches, 0, "golden mismatches detected");
}

#[test]
fn utilization_advantage_over_reference_on_digits() {
    if !ready() {
        return;
    }
    let qm = QModel::load(&artifacts_dir().join("weights/digits.json")).unwrap();
    let frames: Vec<Vec<i64>> = qm
        .test_vectors
        .iter()
        .cycle()
        .take(24)
        .map(|t| t.x_q.clone())
        .collect();
    let ours = PipelineSim::new(qm.clone(), None).unwrap().run(&frames).unwrap();
    let reference = PipelineSim::new_reference(qm).unwrap().run(&frames).unwrap();
    // Weighted mean utilisation (by unit count) must favour ours; the
    // fully-parallel reference leaves interleavable units idle.
    let mean = |stats: &[cnn_flow::sim::pipeline::LayerStats]| {
        let units: f64 = stats.iter().map(|s| s.units as f64).sum();
        stats
            .iter()
            .map(|s| s.utilization * s.units as f64)
            .sum::<f64>()
            / units
    };
    let u_ours = mean(&ours.stats);
    let u_ref = mean(&reference.stats);
    assert!(
        u_ours > u_ref * 1.5,
        "expected a clear utilisation win: ours {u_ours:.3} vs ref {u_ref:.3}"
    );
    // And the paper's headline: continuous-flow utilisation close to full.
    assert!(u_ours > 0.7, "mean utilisation {u_ours:.3}");
}
