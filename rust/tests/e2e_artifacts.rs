//! End-to-end integration (E12 in test form), in two tiers:
//!
//! * **fixture tier** — the serve and utilisation scenarios run on the
//!   deterministic synthetic fixture ([`QModel::synthetic`]), so they
//!   always execute (no artifacts, no skips, no wall-clock sleeps);
//! * **artifact tier** — manifest sanity, three-way value agreement (rust
//!   cycle sim == PJRT-executed JAX golden == exporter vectors), and a
//!   full serve loop with live golden verification. These skip with a
//!   note when `make artifacts` hasn't run, and the PJRT-backed ones only
//!   build with `--features pjrt`.
//!
//! Shutdown is a deterministic drain (queue FIFO + thread joins), so none
//! of these tests sleep.

use std::sync::Arc;

use cnn_flow::coordinator::{loadgen, Server, ServerConfig};
use cnn_flow::quant::QModel;
use cnn_flow::runtime::artifacts_dir;
use cnn_flow::sim::pipeline::PipelineSim;
use cnn_flow::util::json::Json;

#[cfg(feature = "pjrt-xla")]
use cnn_flow::runtime::{ModelBundle, Runtime};
#[cfg(feature = "pjrt-xla")]
use cnn_flow::util::Rng;

fn ready() -> bool {
    let ok = artifacts_dir().join("meta.json").exists();
    if !ok {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
    }
    ok
}

// --------------------------------------------------------------------
// Fixture tier: always runs.
// --------------------------------------------------------------------

#[test]
fn serve_fixture_stream_bit_identical() {
    // The full serve loop on the synthetic fixture: a seeded trace through
    // a 3-shard server, every response checked against the single-sim
    // golden path, final snapshot from the deterministic drain.
    let qm = QModel::synthetic(12, 8, 10, 0xE2E);
    let golden = PipelineSim::new(qm.clone(), None).unwrap();
    let trace = loadgen::Trace::seeded(0x51, 96, 144, 1);
    let expected = loadgen::golden_outputs(&golden, &trace);
    let server = Server::start(
        qm,
        ServerConfig {
            workers: 3,
            max_batch: 8,
            queue_depth: 64,
            verify_every: 0,
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let report = loadgen::replay(&server, &trace, 12, Some(&expected));
    let m = server.shutdown();
    assert_eq!(report.ok, 96);
    assert_eq!(report.mismatched, 0, "sharded serving diverged from golden");
    assert_eq!(report.rejected, 0);
    assert_eq!(m.completed, 96);
    assert_eq!(m.accepted, 96);
    assert_eq!(m.mismatches, 0);
}

#[test]
fn serve_fixture_concurrent_clients() {
    // Concurrent client threads (not the loadgen harness): every answer
    // must still be bit-identical to the golden sim, and the metrics must
    // reconcile after the drain.
    let qm = QModel::synthetic(8, 4, 6, 0xC0C);
    let golden = Arc::new(PipelineSim::new(qm.clone(), None).unwrap());
    let server = Arc::new(
        Server::start(
            qm,
            ServerConfig {
                workers: 4,
                max_batch: 4,
                queue_depth: 256,
                verify_every: 0,
                ..Default::default()
            },
            None,
        )
        .unwrap(),
    );
    let mut handles = Vec::new();
    for c in 0..6u64 {
        let s = Arc::clone(&server);
        let g = Arc::clone(&golden);
        handles.push(std::thread::spawn(move || {
            let mut rng = cnn_flow::util::Rng::new(0xC11E27 + c);
            for _ in 0..16 {
                let x: Vec<i64> = (0..64).map(|_| rng.int8() as i64).collect();
                let expect = g.run(&[x.clone()]).unwrap().outputs[0].clone();
                let resp = s.infer(x).unwrap();
                assert_eq!(resp.logits, expect);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = Arc::try_unwrap(server).ok().unwrap().shutdown();
    assert_eq!(m.completed, 96);
    assert_eq!(m.completed, m.accepted);
    assert_eq!(m.rejected, 0);
}

#[test]
fn utilization_advantage_over_reference_on_fixture() {
    // The continuous-flow plan must beat the fully-parallel reference on
    // weighted mean utilisation for a back-to-back frame stream — the
    // Table VIII claim, demonstrable without artifacts.
    let qm = QModel::synthetic(12, 8, 10, 0x0717);
    let trace = loadgen::Trace::seeded(0x11, 24, 144, 0);
    let frames = trace.frames();
    let ours = PipelineSim::new(qm.clone(), None).unwrap().run(&frames).unwrap();
    let reference = PipelineSim::new_reference(qm).unwrap().run(&frames).unwrap();
    assert_eq!(ours.outputs, reference.outputs, "plans must agree on values");
    let mean = |stats: &[cnn_flow::sim::pipeline::LayerStats]| {
        let units: f64 = stats.iter().map(|s| s.units as f64).sum();
        stats
            .iter()
            .map(|s| s.utilization * s.units as f64)
            .sum::<f64>()
            / units
    };
    let u_ours = mean(&ours.stats);
    let u_ref = mean(&reference.stats);
    assert!(
        u_ours > u_ref * 1.3,
        "expected a clear utilisation win: ours {u_ours:.3} vs ref {u_ref:.3}"
    );
    assert!(u_ours > 0.6, "mean utilisation {u_ours:.3}");
    // The stride-1 conv keeps streaming back-to-back: near-full busy.
    let conv = ours.stats.iter().find(|s| s.name == "C1").unwrap();
    assert!(conv.utilization > 0.8, "C1 utilization {:.3}", conv.utilization);
}

// --------------------------------------------------------------------
// Artifact tier: skips without `make artifacts`.
// --------------------------------------------------------------------

#[test]
fn meta_manifest_lists_both_models() {
    if !ready() {
        return;
    }
    let text = std::fs::read_to_string(artifacts_dir().join("meta.json")).unwrap();
    let j = Json::parse(&text).unwrap();
    for name in ["digits", "jsc"] {
        let entry = j.get("models").get(name);
        assert!(entry.get("qat_accuracy").as_f64().unwrap() > 0.9, "{name}");
        let hlo = entry.get("int8_hlo").as_str().unwrap();
        assert!(artifacts_dir().join(hlo).exists(), "{hlo} missing");
    }
}

#[test]
fn hlo_artifacts_have_full_constants() {
    if !ready() {
        return;
    }
    for name in ["digits_int8", "jsc_int8", "digits_float", "model"] {
        let path = artifacts_dir().join(format!("{name}.hlo.txt"));
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(
            !text.contains("{...}"),
            "{name}: HLO printer elided constants"
        );
        assert!(text.contains("ENTRY"), "{name}: not an HLO module");
    }
}

#[test]
fn serve_digits_artifact_bit_identical_no_pjrt_needed() {
    // The artifact serve path minus the PJRT verifier: exporter vectors
    // through a sharded server must match their recorded outputs.
    if !ready() {
        return;
    }
    let qm = QModel::load(&artifacts_dir().join("weights/digits.json")).unwrap();
    let server = Server::start(
        qm.clone(),
        ServerConfig {
            workers: 2,
            max_batch: 8,
            verify_every: 0,
            ..Default::default()
        },
        None,
    )
    .unwrap();
    for (i, tv) in qm.test_vectors.iter().enumerate() {
        let resp = server.infer(tv.x_q.clone()).unwrap();
        assert_eq!(resp.logits, tv.y, "vector {i}");
    }
    let m = server.shutdown();
    assert_eq!(m.completed, qm.test_vectors.len() as u64);
}

#[cfg(feature = "pjrt-xla")]
#[test]
fn three_way_agreement_on_random_inputs() {
    if !ready() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    for name in ["digits", "jsc"] {
        let bundle = ModelBundle::load(&rt, name).unwrap();
        let sim = PipelineSim::new(bundle.qmodel.clone(), None).unwrap();
        let n: usize = bundle.qmodel.input_shape.iter().product();
        let mut rng = Rng::new(0x3A3);
        for case in 0..6 {
            let x_q: Vec<i64> = (0..n).map(|_| rng.int8() as i64).collect();
            let xf: Vec<f32> = x_q.iter().map(|&v| v as f32).collect();
            let golden: Vec<i64> = bundle
                .golden
                .run_f32(&xf)
                .unwrap()
                .iter()
                .map(|&v| v as i64)
                .collect();
            let simulated = sim.run(&[x_q]).unwrap().outputs[0].clone();
            assert_eq!(simulated, golden, "{name} case {case}");
        }
        // And the exporter's stored vectors agree too.
        for (i, tv) in bundle.qmodel.test_vectors.iter().enumerate() {
            let simulated = sim.run(&[tv.x_q.clone()]).unwrap().outputs[0].clone();
            assert_eq!(simulated, tv.y, "{name} stored vector {i}");
        }
    }
}

#[cfg(feature = "pjrt-xla")]
#[test]
fn serve_with_live_golden_verification() {
    if !ready() {
        return;
    }
    let qm = QModel::load(&artifacts_dir().join("weights/digits.json")).unwrap();
    let server = Arc::new(
        Server::start(
            qm.clone(),
            ServerConfig {
                workers: 2,
                max_batch: 8,
                verify_every: 2, // verify half of all requests
                ..Default::default()
            },
            Some("digits".into()),
        )
        .unwrap(),
    );
    let mut handles = Vec::new();
    for c in 0..4 {
        let s = Arc::clone(&server);
        let vectors: Vec<Vec<i64>> = qm.test_vectors.iter().map(|t| t.x_q.clone()).collect();
        handles.push(std::thread::spawn(move || {
            for i in 0..24 {
                s.infer(vectors[(c + i) % vectors.len()].clone()).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    // Deterministic drain (no sleep): shutdown joins the shard workers,
    // which closes the sampling channel; the verifier then empties its
    // queue and exits before the final snapshot is taken.
    let m = Arc::try_unwrap(server).ok().unwrap().shutdown();
    assert_eq!(m.completed, 96);
    assert!(m.verified > 0, "verifier never ran");
    assert_eq!(m.mismatches, 0, "golden mismatches detected");
}

#[test]
fn utilization_advantage_over_reference_on_digits() {
    if !ready() {
        return;
    }
    let qm = QModel::load(&artifacts_dir().join("weights/digits.json")).unwrap();
    let frames: Vec<Vec<i64>> = qm
        .test_vectors
        .iter()
        .cycle()
        .take(24)
        .map(|t| t.x_q.clone())
        .collect();
    let ours = PipelineSim::new(qm.clone(), None).unwrap().run(&frames).unwrap();
    let reference = PipelineSim::new_reference(qm).unwrap().run(&frames).unwrap();
    // Weighted mean utilisation (by unit count) must favour ours; the
    // fully-parallel reference leaves interleavable units idle.
    let mean = |stats: &[cnn_flow::sim::pipeline::LayerStats]| {
        let units: f64 = stats.iter().map(|s| s.units as f64).sum();
        stats
            .iter()
            .map(|s| s.utilization * s.units as f64)
            .sum::<f64>()
            / units
    };
    let u_ours = mean(&ours.stats);
    let u_ref = mean(&reference.stats);
    assert!(
        u_ours > u_ref * 1.5,
        "expected a clear utilisation win: ours {u_ours:.3} vs ref {u_ref:.3}"
    );
    // And the paper's headline: continuous-flow utilisation close to full.
    assert!(u_ours > 0.7, "mean utilisation {u_ours:.3}");
}
