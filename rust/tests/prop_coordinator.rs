//! Property-based integration tests on coordinator invariants: routing
//! (every accepted request answered exactly once, with its own answer),
//! batching (never exceeds the configured group size), and state/metrics
//! consistency under concurrency and backpressure.

use std::sync::Arc;
use std::time::Duration;

use cnn_flow::coordinator::{Server, ServerConfig, SubmitOpts};
use cnn_flow::quant::{QKind, QLayer, QModel};
use cnn_flow::util::prop::prop_check;
use cnn_flow::util::Rng;
use cnn_flow::{prop_assert, prop_assert_eq};

/// Identity-plus-bias dense model: logits = x + 7, so every response is
/// attributable to its request (routing check).
fn probe_model(n: usize) -> QModel {
    let mut w_q = vec![0i64; n * n];
    for i in 0..n {
        w_q[i * n + i] = 1;
    }
    QModel {
        name: "probe".into(),
        input_shape: [1, 1, n],
        input_scale: 1.0,
        layers: vec![QLayer {
            name: "id".into(),
            kind: QKind::Dense,
            k: 0,
            s: 1,
            p: 0,
            relu: false,
            w_q,
            w_shape: vec![n, n],
            b_q: vec![7; n],
            m: 0.0,
            in_shape: [1, 1, n],
            out_shape: [1, 1, n],
        }],
        topology: vec![],
        test_vectors: vec![],
        qat_accuracy: 1.0,
    }
}

#[test]
fn routing_every_request_gets_its_own_answer() {
    prop_check(10, 0xC0, |rng| {
        let n = 4;
        let batch = rng.range(1, 16);
        let clients = rng.range(1, 6);
        let per_client = rng.range(3, 12);
        let server = Arc::new(
            Server::start(
                probe_model(n),
                ServerConfig {
                    max_batch: batch,
                    queue_depth: 1024,
                    verify_every: 0,
                    batch_deadline: Duration::from_millis(2),
                    ..Default::default()
                },
                None,
            )?,
        );
        let mut handles = Vec::new();
        for c in 0..clients {
            let s = Arc::clone(&server);
            handles.push(std::thread::spawn(move || -> Result<(), String> {
                let mut rng = Rng::new(c as u64 * 7919);
                for _ in 0..per_client {
                    let x: Vec<i64> = (0..4).map(|_| rng.int8() as i64).collect();
                    let expect: Vec<i64> = x.iter().map(|v| v + 7).collect();
                    let resp = s.infer(x)?;
                    if resp.logits != expect {
                        return Err(format!("mis-routed: {:?} != {expect:?}", resp.logits));
                    }
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().unwrap()?;
        }
        let m = server.metrics();
        prop_assert_eq!(
            m.completed,
            (clients * per_client) as u64,
            "completed count"
        );
        prop_assert_eq!(m.accepted, m.completed, "accepted != completed");
        prop_assert_eq!(m.rejected, 0u64, "unexpected rejections");
        Ok(())
    });
}

#[test]
fn batching_respects_group_bound() {
    prop_check(8, 0xC1, |rng| {
        let batch = rng.range(2, 8);
        let server = Arc::new(
            Server::start(
                probe_model(4),
                ServerConfig {
                    max_batch: batch,
                    queue_depth: 512,
                    verify_every: 0,
                    batch_deadline: Duration::from_millis(10),
                    ..Default::default()
                },
                None,
            )?,
        );
        let total = batch * 6;
        let mut handles = Vec::new();
        for _ in 0..total {
            let s = Arc::clone(&server);
            handles.push(std::thread::spawn(move || s.infer(vec![1, 2, 3, 4]).is_ok()));
        }
        let ok = handles
            .into_iter()
            .map(|h| h.join().unwrap())
            .filter(|&b| b)
            .count();
        let m = server.metrics();
        prop_assert_eq!(m.completed as usize, ok, "ok count mismatch");
        // Mean batch size can never exceed the configured bound.
        prop_assert!(
            m.mean_batch <= batch as f64 + 1e-9,
            "mean batch {} > bound {batch}",
            m.mean_batch
        );
        Ok(())
    });
}

#[test]
fn metrics_account_for_backpressure() {
    prop_check(6, 0xC2, |rng| {
        let server = Arc::new(
            Server::start(
                probe_model(4),
                ServerConfig {
                    max_batch: 1,
                    queue_depth: 1,
                    verify_every: 0,
                    batch_deadline: Duration::from_millis(0),
                    ..Default::default()
                },
                None,
            )?,
        );
        let burst = rng.range(8, 40);
        let mut handles = Vec::new();
        for _ in 0..burst {
            let s = Arc::clone(&server);
            handles.push(std::thread::spawn(move || s.infer(vec![0, 0, 0, 0]).is_ok()));
        }
        let ok = handles
            .into_iter()
            .map(|h| h.join().unwrap())
            .filter(|&b| b)
            .count();
        let m = server.metrics();
        prop_assert_eq!(
            (m.accepted + m.rejected) as usize,
            burst,
            "accepted + rejected != submitted"
        );
        prop_assert_eq!(m.completed as usize, ok, "completed != successful calls");
        Ok(())
    });
}

#[test]
fn zero_batch_deadline_flushes_immediately_and_reconciles() {
    // `batch_deadline = Duration::ZERO` is the immediate-flush path:
    // every batch flushes as soon as its first request is seen (full
    // batches excepted), so after a full drain the flush-reason split
    // and the occupancy histogram must reconcile exactly with the
    // completed count — no request may hide in an unflushed batch.
    prop_check(8, 0xC3, |rng| {
        let batch = rng.range(1, 8);
        let workers = rng.range(1, 4);
        let total = rng.range(4, 40);
        let server = Arc::new(
            Server::start(
                probe_model(4),
                ServerConfig {
                    workers,
                    max_batch: batch,
                    queue_depth: 1024,
                    verify_every: 0,
                    batch_deadline: Duration::ZERO,
                    ..Default::default()
                },
                None,
            )?,
        );
        let pendings: Vec<_> = (0..total)
            .map(|i| server.submit(vec![i as i64 % 100, 0, 0, 0]))
            .collect::<Result<_, _>>()?;
        for p in pendings {
            p.wait()?;
        }
        let server = Arc::into_inner(server).expect("sole owner after joins");
        let m = server.shutdown();
        prop_assert_eq!(m.completed as usize, total, "all answered");
        prop_assert_eq!(
            m.batches,
            m.flush_full + m.flush_deadline + m.flush_drain,
            "every batch has exactly one flush reason"
        );
        // The occupancy histogram is per-flush; weighted by batch size it
        // must account for every completed frame.
        prop_assert_eq!(m.occupancy_frames, m.completed, "occupancy ledger");
        prop_assert!(
            m.mean_batch <= batch as f64 + 1e-9,
            "immediate flush cannot exceed the bound, mean {}",
            m.mean_batch
        );
        Ok(())
    });
}

#[test]
fn slo_counters_reconcile_under_drain() {
    // Mixed deadline-free / unmeetable-deadline traffic against a
    // clock_hz-1.0 server: after a drain, every submission is accounted
    // for in exactly one intake bucket
    // (`submitted == completed + errored + rejected + shed`) and shed
    // never leaks into rejected.
    prop_check(8, 0xC4, |rng| {
        let total = rng.range(8, 48);
        let server = Arc::new(
            Server::start(
                probe_model(4),
                ServerConfig {
                    workers: 2,
                    max_batch: 4,
                    queue_depth: 1024,
                    verify_every: 0,
                    clock_hz: 1.0,
                    batch_deadline: Duration::from_millis(1),
                    ..Default::default()
                },
                None,
            )?,
        );
        let model = server.models()[0].clone();
        let mut submitted = 0u64;
        let mut shed_seen = 0u64;
        let mut pendings = Vec::new();
        for i in 0..total {
            // Every third request carries a 1 us deadline — a zero-cycle
            // budget at 1 Hz, so admission must shed it.
            let opts = if i % 3 == 0 {
                SubmitOpts {
                    deadline_us: 1,
                    class: 1,
                }
            } else {
                SubmitOpts::default()
            };
            submitted += 1;
            match server.submit_to_opts(&model, vec![1, 2, 3, 4], opts, None) {
                Ok(p) => pendings.push(p),
                Err(e) if e.starts_with("slo miss") => shed_seen += 1,
                Err(e) => return Err(format!("unexpected refusal: {e}")),
            }
        }
        for p in pendings {
            p.wait()?;
        }
        let server = Arc::into_inner(server).expect("sole owner after joins");
        let m = server.shutdown();
        prop_assert_eq!(m.shed, shed_seen, "every slo-miss error counted once");
        prop_assert_eq!(m.rejected, 0u64, "shed must not leak into rejected");
        prop_assert_eq!(
            m.completed + m.errored + m.rejected + m.shed,
            submitted,
            "intake partition"
        );
        prop_assert_eq!(m.accepted, m.completed + m.errored, "accepted split");
        Ok(())
    });
}

#[test]
fn shutdown_is_clean_after_load() {
    let server = Server::start(probe_model(4), ServerConfig::default(), None).unwrap();
    for _ in 0..32 {
        server.infer(vec![1, 1, 1, 1]).unwrap();
    }
    let m = server.shutdown();
    assert_eq!(m.completed, 32);
    assert_eq!(m.mismatches, 0);
}
