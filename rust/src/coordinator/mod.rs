//! Sharded streaming inference coordinator (system S10) — the L3 serving
//! layer.
//!
//! The paper's architecture is a continuous-flow pipeline: throughput is
//! maximised when frames stream back-to-back so no unit ever starves. Its
//! companion work (*Data-Rate-Aware High-Speed CNN Inference on FPGAs*)
//! scales past one stream by **replicating pipelines**; this coordinator
//! mirrors that at the serving layer:
//!
//! * **N worker shards** — each worker thread owns its own [`PipelineSim`]
//!   clone (one modelled pipeline replica) and a private bounded queue.
//!   By default a shard executes frames on the lowered
//!   [`CompiledPipeline`] value engine and takes its cycle figures from
//!   the closed-form `SchedulePrediction` — no per-frame cycle
//!   simulation at all ([`EngineKind::Compiled`]);
//!   [`EngineKind::Interpreter`] keeps the fused cycle-exact loop as a
//!   serving-time oracle and cross-checks the prediction on every group;
//! * **model-predictive dispatch** (DESIGN.md §12) — [`Server::submit`]
//!   tries shards in ascending predicted completion (`first_frame_latency
//!   + (queued+1) × steady_cycles_per_frame`, from the same analytic
//!   schedule model that certifies folding), spilling on saturation and
//!   rejecting only when *every* candidate queue is full; blind
//!   round-robin stays config-selectable ([`DispatchKind::RoundRobin`])
//!   as the differential oracle. Deadline-bearing requests pass the same
//!   prediction through **admission control** (shed early as
//!   `ErrorCode::SloMiss` when no shard can meet the budget), and the
//!   same backlog figure drives optional per-group **shard autoscaling**
//!   ([`AutoscaleConfig`]);
//! * **deadline-aware micro-batching** — each shard accumulates requests
//!   into a batch of up to `max_batch` frames, flushing early when the
//!   *oldest* queued request's age reaches `batch_deadline` (whichever
//!   comes first; shutdown drains flush whatever has accumulated). A
//!   compiled shard runs the whole batch through
//!   [`CompiledPipeline::execute_batch`] — one program traversal per
//!   batch — and each flush records its occupancy and reason
//!   ([`metrics::OccupancyHistogram`], flush-full/-deadline/-drain
//!   counters) next to the existing p50/p95/p99 aggregation. Contiguous
//!   frames are also the condition under which the modelled hardware
//!   reaches ~100% utilisation;
//! * **per-shard metrics** — every shard keeps its own counters and log2
//!   latency histogram ([`metrics::ShardMetrics`]); snapshots merge them
//!   into aggregate p50/p95/p99 and a sharded throughput projection
//!   (`aggregate_fps` = frames over the max per-shard busy cycles);
//! * **graceful drain** — [`Server::shutdown`] closes intake, enqueues a
//!   shutdown marker *behind* every already-accepted request (FIFO), joins
//!   the workers once they have answered everything, then joins the
//!   verifier after its queue disconnects and drains. No sleeps, no
//!   dropped accepted requests — the final snapshot is deterministic;
//! * **multi-model routing** — [`Server::start_multi`] hosts several
//!   heterogeneous models behind one intake: each model id owns a *shard
//!   group* (its shards clone that model's pre-lowered pipeline, with the
//!   per-group worker count taken from the [`ServerConfig::routes`]
//!   table), tagged requests ([`Server::submit_to`] /
//!   [`Server::infer_to`]) are dispatched round-robin *within* their
//!   model's group (spill never crosses models — the pipelines differ),
//!   and metrics split into per-model views ([`Server::model_metrics`])
//!   next to the aggregate snapshot. Lowering is amortized across servers
//!   by [`crate::runtime::ModelRegistry`] (DESIGN.md §7).
//!
//! Threads (std::thread — tokio is not vendored in this offline image):
//! callers block on [`Server::infer`] (or hold a [`Pending`] from
//! [`Server::submit`]); one worker thread per shard runs the pipeline
//! simulator; an optional verifier thread owns the PJRT runtime and
//! cross-checks a sample of responses against the AOT-compiled JAX int8
//! golden model (never on the request path — samples are dropped, not
//! queued, when it falls behind).
//!
//! [`loadgen`] provides the deterministic seeded-trace replay harness used
//! by the integration tests and `benches/bench_coordinator.rs`.

pub mod loadgen;
pub mod metrics;

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::obs::profile::LayerProfileRow;
use crate::obs::{ActiveSpan, Clock, FlightRecorder, LayerProfiler, SpanOutcome, TraceStatsSnapshot};
use crate::quant::QModel;
use crate::sim::compiled::{CompiledPipeline, FoldedPipeline};
use crate::sim::pipeline::PipelineSim;

pub use metrics::{
    metrics_report_json, Metrics, MetricsSnapshot, ModelMetricsSnapshot, NetMetrics,
    NetMetricsSnapshot, ReactorStats, ReactorStatsSnapshot, ShardSnapshot,
};
use metrics::{IntakeMetrics, ShardMetrics};

/// Which execution engine the worker shards run (DESIGN.md §4/§5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    /// The lowered [`CompiledPipeline`] value engine plus the closed-form
    /// `SchedulePrediction` — no per-frame cycle simulation at all. The
    /// serving default.
    #[default]
    Compiled,
    /// The original fused pixel-by-pixel interpreter
    /// ([`PipelineSim::run_interpreted`]) — the validation oracle. Also
    /// cross-checks the closed-form cycle prediction live
    /// (`MetricsSnapshot::cycle_divergence`).
    Interpreter,
    /// The rate-aware folded value engine ([`FoldedPipeline`], DESIGN.md
    /// §9): bit-identical to the compiled engine, with consecutive
    /// low-rate layers fused into single traversals. Cycle figures come
    /// from the certified `FoldedPrediction`.
    Folded,
}

impl EngineKind {
    /// The engine named by `$CNN_FLOW_ENGINE` (`compiled`, `folded`, or
    /// `interp` / `interpreter`). CI's engine matrix legs force the
    /// oracle and folded engines through every default-configured test
    /// this way, so all engines stay green. Unset or empty means "no
    /// override"; an unrecognized non-empty value **panics** — silently
    /// falling back to the compiled default would turn a typo in the CI
    /// matrix into a leg that tests the wrong engine while staying green.
    pub fn from_env() -> Option<EngineKind> {
        let raw = std::env::var("CNN_FLOW_ENGINE").ok()?;
        if raw.is_empty() {
            return None;
        }
        match Self::parse(&raw) {
            Some(engine) => Some(engine),
            None => panic!(
                "CNN_FLOW_ENGINE='{raw}' is not a recognized engine \
                 (expected compiled | folded | interp | interpreter)"
            ),
        }
    }

    /// Parse an engine name (`compiled`, `folded`, `interp`,
    /// `interpreter`; case-insensitive) — shared by the env override and
    /// the CLI's `--engine` flag.
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s.to_ascii_lowercase().as_str() {
            "interp" | "interpreter" => Some(EngineKind::Interpreter),
            "compiled" => Some(EngineKind::Compiled),
            "folded" => Some(EngineKind::Folded),
            _ => None,
        }
    }

    /// [`EngineKind::from_env`], falling back to the compiled default.
    /// This is what `ServerConfig::default()` uses — which means every
    /// config built with `..Default::default()` reads the env var (and
    /// panics on an unrecognized value) even when it then overrides
    /// `engine` explicitly: the override wins for execution, but a
    /// malformed `$CNN_FLOW_ENGINE` is a config error everywhere.
    pub fn default_from_env() -> EngineKind {
        Self::from_env().unwrap_or_default()
    }
}

/// How requests pick a shard within their model's group (DESIGN.md §12).
///
/// Mirrors [`EngineKind`]'s selection pattern: the analytic default plus
/// a config-selectable blind oracle, the way `run_interpreted` anchors
/// the compiled engine — the SLO gate test replays one trace under both
/// and pins that prediction-aware dispatch strictly improves SLO
/// attainment.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DispatchKind {
    /// Least-predicted-load: shards are tried in ascending order of
    /// `queued × steady_cycles_per_frame` (the analytic backlog, the
    /// same denominator admission control uses), with round-robin
    /// rotation breaking ties so idle groups still spread evenly. The
    /// serving default.
    #[default]
    Predictive,
    /// Blind round-robin with backpressure spill — the pre-§12 dispatch,
    /// kept as the differential oracle.
    RoundRobin,
}

impl DispatchKind {
    /// Parse a dispatch policy name (`predictive` | `roundrobin`;
    /// case-insensitive) — shared by the env override and the CLI's
    /// `--dispatch` flag.
    pub fn parse(s: &str) -> Option<DispatchKind> {
        match s.to_ascii_lowercase().as_str() {
            "predictive" | "least-loaded" | "least_loaded" => Some(DispatchKind::Predictive),
            "roundrobin" | "round-robin" | "rr" => Some(DispatchKind::RoundRobin),
            _ => None,
        }
    }

    /// The policy named by `$CNN_FLOW_DISPATCH`. Unset or empty means
    /// "no override"; an unrecognized non-empty value **panics**, same
    /// rationale as [`EngineKind::from_env`].
    pub fn from_env() -> Option<DispatchKind> {
        let raw = std::env::var("CNN_FLOW_DISPATCH").ok()?;
        if raw.is_empty() {
            return None;
        }
        match Self::parse(&raw) {
            Some(d) => Some(d),
            None => panic!(
                "CNN_FLOW_DISPATCH='{raw}' is not a recognized dispatch policy \
                 (expected predictive | roundrobin)"
            ),
        }
    }

    /// [`DispatchKind::from_env`], falling back to the predictive default.
    pub fn default_from_env() -> DispatchKind {
        Self::from_env().unwrap_or_default()
    }
}

impl std::fmt::Display for DispatchKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            DispatchKind::Predictive => "predictive",
            DispatchKind::RoundRobin => "roundrobin",
        })
    }
}

/// Per-model shard-group autoscaling bounds (DESIGN.md §12). Every
/// route's shards are still spawned up front (threads parked on an empty
/// queue are nearly free and the registry has already amortized
/// lowering); autoscaling gates how many of them dispatch admits, so the
/// `workers` gauge stays the spawned count and `active_workers` tracks
/// the controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AutoscaleConfig {
    /// Floor on active shards per group (clamped to at least 1).
    pub min_workers: usize,
    /// Ceiling on active shards per group (clamped to the spawned count).
    pub max_workers: usize,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            min_workers: 1,
            max_workers: usize::MAX,
        }
    }
}

impl AutoscaleConfig {
    /// Parse an autoscale spec: `off` → disabled, `on` → full range
    /// (1..=spawned), `MIN:MAX` → explicit bounds. `None` = unrecognized.
    pub fn parse(s: &str) -> Option<Option<AutoscaleConfig>> {
        match s.to_ascii_lowercase().as_str() {
            "off" | "0" | "false" => return Some(None),
            "on" | "1" | "true" => return Some(Some(AutoscaleConfig::default())),
            _ => {}
        }
        let (lo, hi) = s.split_once(':')?;
        let min_workers = lo.trim().parse::<usize>().ok()?;
        let max_workers = hi.trim().parse::<usize>().ok()?;
        if min_workers == 0 || max_workers < min_workers {
            return None;
        }
        Some(Some(AutoscaleConfig {
            min_workers,
            max_workers,
        }))
    }

    /// The autoscale setting named by `$CNN_FLOW_AUTOSCALE` (`on`, `off`,
    /// or `MIN:MAX`). Unset or empty means "no override"; an
    /// unrecognized non-empty value **panics**, same rationale as
    /// [`EngineKind::from_env`].
    pub fn from_env() -> Option<Option<AutoscaleConfig>> {
        let raw = std::env::var("CNN_FLOW_AUTOSCALE").ok()?;
        if raw.is_empty() {
            return None;
        }
        match Self::parse(&raw) {
            Some(v) => Some(v),
            None => panic!(
                "CNN_FLOW_AUTOSCALE='{raw}' is not a recognized autoscale spec \
                 (expected on | off | MIN:MAX)"
            ),
        }
    }

    /// [`AutoscaleConfig::from_env`], falling back to disabled.
    pub fn default_from_env() -> Option<AutoscaleConfig> {
        Self::from_env().unwrap_or(None)
    }
}

/// The admission-control setting named by `$CNN_FLOW_ADMISSION` (`on` |
/// `off`). Unset or empty means "no override" (admission defaults on —
/// it only affects deadline-bearing requests, so deadline-free traffic
/// is untouched either way); typos panic, same rationale as
/// [`EngineKind::from_env`].
pub fn admission_from_env() -> Option<bool> {
    let raw = std::env::var("CNN_FLOW_ADMISSION").ok()?;
    if raw.is_empty() {
        return None;
    }
    match raw.to_ascii_lowercase().as_str() {
        "on" | "1" | "true" => Some(true),
        "off" | "0" | "false" => Some(false),
        _ => panic!(
            "CNN_FLOW_ADMISSION='{raw}' is not a recognized admission setting \
             (expected on | off)"
        ),
    }
}

/// The flight-recorder setting named by `$CNN_FLOW_TRACE` (`on` |
/// `off`). Unset or empty means "no override" (tracing defaults off —
/// the recorder costs one ring-lock acquisition per finished request);
/// typos panic, same rationale as [`EngineKind::from_env`]. CI's tracing
/// matrix legs force the recorder through both net cores this way.
pub fn trace_from_env() -> Option<bool> {
    let raw = std::env::var("CNN_FLOW_TRACE").ok()?;
    if raw.is_empty() {
        return None;
    }
    match raw.to_ascii_lowercase().as_str() {
        "on" | "1" | "true" => Some(true),
        "off" | "0" | "false" => Some(false),
        _ => panic!(
            "CNN_FLOW_TRACE='{raw}' is not a recognized tracing setting \
             (expected on | off)"
        ),
    }
}

/// One row of the multi-model route table: how many worker shards the
/// named model's group gets in [`Server::start_multi`]. Models without a
/// route fall back to [`ServerConfig::workers`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelRoute {
    pub model: String,
    pub workers: usize,
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Number of worker shards (modelled pipeline replicas). Aggregate
    /// simulated throughput scales with this count; 1 reproduces the
    /// original single-pipeline server.
    pub workers: usize,
    /// Max frames per micro-batch (one continuous-flow group).
    pub max_batch: usize,
    /// Bounded request queue depth *per shard* (backpressure threshold).
    pub queue_depth: usize,
    /// Cross-check every n-th request (per shard) against the PJRT golden
    /// model (0 = never).
    pub verify_every: usize,
    /// Modelled hardware clock, used to convert simulated cycles into
    /// projected hardware latency/throughput figures.
    pub clock_hz: f64,
    /// Deadline-aware flush bound: a batch flushes as soon as its
    /// *oldest* request has been waiting this long since enqueue (so the
    /// added batching latency is capped per request, not per group).
    pub batch_deadline: Duration,
    /// Value/cycle engine the shards execute (compiled by default; the
    /// default honours `$CNN_FLOW_ENGINE`, see [`EngineKind::from_env`]).
    pub engine: EngineKind,
    /// Multi-model route table: per-model worker counts consulted by
    /// [`Server::start_multi`]. Models not listed here get
    /// [`ServerConfig::workers`] shards. Ignored by the single-model
    /// constructors beyond their own model's entry.
    pub routes: Vec<ModelRoute>,
    /// Shard-selection policy within a group (predictive by default; the
    /// default honours `$CNN_FLOW_DISPATCH`, see
    /// [`DispatchKind::from_env`]).
    pub dispatch: DispatchKind,
    /// Deadline admission control: when on, a deadline-bearing request
    /// whose predicted completion exceeds its budget on *every* candidate
    /// shard is shed at submit time (`"slo miss: …"`, wire
    /// `ErrorCode::SloMiss`) instead of enqueued to fail late. Default on
    /// (deadline-free requests are never shed); the default honours
    /// `$CNN_FLOW_ADMISSION`, see [`admission_from_env`].
    pub admission: bool,
    /// Per-group shard autoscaling bounds (None = all spawned shards stay
    /// active). The default honours `$CNN_FLOW_AUTOSCALE`, see
    /// [`AutoscaleConfig::from_env`].
    pub autoscale: Option<AutoscaleConfig>,
    /// Flight-recorder tracing (DESIGN.md §13): when on, every routed
    /// request carries a span from intake to its terminal outcome and
    /// `spans_recorded + spans_dropped` reconciles exactly with
    /// `completed + errored + rejected + shed`. Default off; the default
    /// honours `$CNN_FLOW_TRACE`, see [`trace_from_env`].
    pub trace: bool,
    /// Flight-recorder ring capacity in spans; overflow is counted,
    /// never blocking.
    pub trace_capacity: usize,
    /// Per-layer execute-path profiling ([`LayerProfiler`]): timing-only
    /// atomic accumulators shared across a group's shards, so profiled
    /// runs stay bit-identical to unprofiled ones. The interpreter
    /// engine ignores it (its per-unit cycle model already attributes
    /// work per layer).
    pub profile: bool,
    /// The clock every span stamp reads ([`Clock`], DESIGN.md §13): wall
    /// in production, the loadgen virtual clock under seeded replay so
    /// traces are byte-deterministic.
    pub clock: Clock,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            workers: 1,
            max_batch: 16,
            queue_depth: 256,
            verify_every: 8,
            clock_hz: 600.0e6, // the paper's JSC designs close ~600 MHz
            batch_deadline: Duration::from_millis(1),
            engine: EngineKind::default_from_env(),
            routes: Vec::new(),
            dispatch: DispatchKind::default_from_env(),
            admission: admission_from_env().unwrap_or(true),
            autoscale: AutoscaleConfig::default_from_env(),
            trace: trace_from_env().unwrap_or(false),
            trace_capacity: 4096,
            profile: false,
            clock: Clock::wall(),
        }
    }
}

impl ServerConfig {
    /// Worker-shard count for `model`: its route-table entry, or the
    /// global `workers` default (always at least 1).
    pub fn route_workers(&self, model: &str) -> usize {
        self.routes
            .iter()
            .find(|r| r.model == model)
            .map(|r| r.workers)
            .unwrap_or(self.workers)
            .max(1)
    }
}

/// Why a shard flushed an accumulating micro-batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FlushReason {
    /// The batch reached `max_batch` frames.
    Full,
    /// The oldest request's `batch_deadline` expired.
    Deadline,
    /// Shutdown/disconnect drain (incl. the final partial batch).
    Drain,
}

/// One inference answer.
#[derive(Debug, Clone)]
pub struct InferResponse {
    /// Final-layer accumulator-scale outputs.
    pub logits: Vec<i64>,
    pub argmax: usize,
    /// Simulated hardware cycles from frame entry to last output.
    pub sim_latency_cycles: u64,
    /// Wall-clock time from enqueue to answer.
    pub service_time: Duration,
    /// Admission-time predicted completion in modelled cycles
    /// (`first_frame_latency + (queued+1) × steady_cycles_per_frame` on
    /// the shard that accepted the request). 0 for deadline-free
    /// requests — the wire reply then stays on the v1 encoding.
    pub predicted_cycles: u64,
    /// Whether `predicted_cycles` fit the request's deadline budget at
    /// admission. Decided from modelled time, not wall clock, so it is
    /// deterministic for a given queue state and identical across
    /// engines/net cores; always false for deadline-free requests.
    pub slo_met: bool,
}

/// Per-request submit-time options: the SLO extension carried by the v2
/// wire protocol. `Default` (no deadline, class 0) reproduces the
/// pre-§12 behaviour bit-for-bit.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SubmitOpts {
    /// Completion deadline in microseconds of *modelled* hardware time
    /// (0 = none). Admission converts it to a cycle budget via
    /// [`ServerConfig::clock_hz`].
    pub deadline_us: u64,
    /// Priority class, an opaque tenant label. The coordinator carries it
    /// for per-class SLO reporting (loadgen buckets its reports by
    /// class); it does not affect scheduling.
    pub class: u8,
}

/// Completion hook for nonblocking front-ends: invoked by the worker
/// after a request's reply has been sent (success or per-request error),
/// so an event loop can [`Pending::try_wait`] exactly when an answer is
/// ready instead of polling. Implementations must be cheap and
/// non-blocking — they run on the shard worker's hot path.
pub trait CompletionNotify: Send + Sync {
    fn notify(&self);
}

struct Request {
    x_q: Vec<i64>,
    enqueued: Instant,
    reply: SyncSender<Result<InferResponse, String>>,
    /// See [`CompletionNotify`]; `None` for blocking callers.
    notify: Option<Arc<dyn CompletionNotify>>,
    /// Stamped at admission for deadline-bearing requests (else 0/false);
    /// echoed verbatim into [`InferResponse`] by the worker.
    predicted_cycles: u64,
    slo_met: bool,
    /// Flight-recorder span riding the request (None when tracing is
    /// off). Boxed: the hot path without tracing pays one null-pointer
    /// word, not the whole span.
    trace: Option<Box<ActiveSpan>>,
}

impl Request {
    /// Finalize the span (if any), send the reply, then fire the
    /// completion hook. The order matters twice over: the span must be
    /// in the recorder before the reply is observable (so a settled
    /// replay sees every span), and the notify must observe a
    /// `try_wait`-able channel.
    fn answer(mut self, result: Result<InferResponse, String>) {
        if let Some(t) = self.trace.take() {
            t.finish(match &result {
                Ok(_) => SpanOutcome::Completed,
                Err(_) => SpanOutcome::Errored,
            });
        }
        let _ = self.reply.send(result);
        if let Some(n) = &self.notify {
            n.notify();
        }
    }
}

enum Job {
    Infer(Request),
    Shutdown,
}

/// Consecutive zero-backlog autoscale evaluations before the controller
/// shrinks a group by one shard (hysteresis against calm gaps inside a
/// bursty trace).
const SHRINK_IDLE_TICKS: usize = 64;

/// Advance a dispatch cursor over `n` slots and return the slot to try
/// first. The stored value is kept reduced (`< n`) via `fetch_update`
/// rather than `fetch_add(1) % n`: a free-running counter skews one step
/// at `usize` wraparound whenever `n` is not a power of two (e.g. n=3:
/// `usize::MAX % 3 == 0` is followed by `0 % 3 == 0` — shard 0 twice).
/// Reducing both the stored and the returned value makes the cycle exact
/// for every `n` and also tolerates `n` shrinking between calls
/// (autoscale), since any stale out-of-range value reduces mod the new
/// `n`.
fn rr_next(rr: &AtomicUsize, n: usize) -> usize {
    let n = n.max(1);
    let prev = rr
        .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            Some((v % n + 1) % n)
        })
        .expect("rr_next update is infallible");
    prev % n
}

/// A submitted-but-unanswered request (from [`Server::submit`]).
pub struct Pending {
    rx: Receiver<Result<InferResponse, String>>,
}

impl Pending {
    /// Block until the answer arrives.
    pub fn wait(self) -> Result<InferResponse, String> {
        self.rx
            .recv()
            .map_err(|_| "server dropped request".to_string())?
    }

    /// Nonblocking probe: `Some` once the answer has arrived (after
    /// which the `Pending` is spent and must be discarded), `None` while
    /// it is still in flight. A worker that died without answering
    /// yields the same "server dropped request" error as [`wait`]
    /// (Pending::wait). This is the evented core's settle primitive,
    /// paired with [`CompletionNotify`].
    pub fn try_wait(&mut self) -> Option<Result<InferResponse, String>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(std::sync::mpsc::TryRecvError::Empty) => None,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                Some(Err("server dropped request".to_string()))
            }
        }
    }
}

struct Shard {
    tx: SyncSender<Job>,
    metrics: Arc<ShardMetrics>,
    /// Worker join handle. Behind a mutex so [`Server::close`] can run
    /// through a shared reference — the TCP front-end holds the server in
    /// an `Arc` and must be able to drain it ([`Server::drain_shared`]).
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

/// One model's shard group: the shards serving its pre-lowered pipeline,
/// that model's dispatch cursor, its analytic capacity constants, and its
/// intake counters.
struct Group {
    model: String,
    /// Flattened input frame length the group's pipeline expects —
    /// advertised to TCP clients via [`Server::model_specs`].
    input_len: usize,
    shards: Vec<Shard>,
    /// Dispatch cursor. Stored value is always kept `< shards.len()` (see
    /// [`rr_next`]) so the old `fetch_add % n` wraparound skew cannot
    /// occur.
    rr: AtomicUsize,
    intake: IntakeMetrics,
    /// Analytic steady-state cycles per frame from the group's
    /// `SchedulePrediction` (engine-independent: folded execution
    /// re-accounts unit work, never completion cycles — DESIGN.md §10).
    /// Floor 1 so backlog products are never zero.
    steady_cpf: u64,
    /// Analytic first-frame fill latency (pipeline depth cost paid once
    /// per batch group), the constant term of the admission predictor.
    first_latency: u64,
    /// Per-shard backlog allowance in cycles before autoscale grows the
    /// group: `max(batch_deadline in cycles, max_batch × steady_cpf)`.
    allowance_cycles: u64,
    /// Number of leading shards dispatch may select
    /// (`shards[..active]`). Autoscale moves it within its configured
    /// bounds; without autoscale it stays `shards.len()`. Deactivated
    /// shards keep draining whatever they already queued.
    active: AtomicUsize,
    /// Consecutive zero-backlog autoscale evaluations (shrink hysteresis).
    idle: AtomicUsize,
    /// The model id as a shared str so every span clones a pointer, not
    /// a String.
    tag: Arc<str>,
    /// Per-layer measured-time accumulators shared by every shard's
    /// engine clone (None when profiling is off).
    profiler: Option<Arc<LayerProfiler>>,
}

/// The running sharded server (one or many models).
pub struct Server {
    groups: Vec<Group>,
    metrics: Arc<Metrics>,
    verifier: Mutex<Option<std::thread::JoinHandle<()>>>,
    config: ServerConfig,
    open: AtomicBool,
    /// Flight recorder shared by every routed request's span (None when
    /// tracing is off). Server-wide, not per-group: the reconciliation
    /// identity sums intake counters over all groups.
    recorder: Option<Arc<FlightRecorder>>,
}

impl Server {
    /// Start a server over a quantized model: the layer plan is computed
    /// and lowered once, then each worker shard receives its own clone of
    /// the compiled state. `verify_model` names an artifact bundle to load
    /// in the verifier thread (None = no verification, e.g. when artifacts
    /// are absent).
    pub fn start(
        qmodel: QModel,
        config: ServerConfig,
        verify_model: Option<String>,
    ) -> Result<Server, String> {
        let base_sim = PipelineSim::new(qmodel, None)?;
        Self::start_prelowered(base_sim, config, verify_model)
    }

    /// Like [`Server::start`] but over an already planned-and-lowered
    /// pipeline (e.g. from `runtime::ModelBundle` or a
    /// [`crate::runtime::ModelRegistry`] entry), so shards clone compiled
    /// state instead of re-planning.
    pub fn start_prelowered(
        base_sim: PipelineSim,
        config: ServerConfig,
        verify_model: Option<String>,
    ) -> Result<Server, String> {
        let id = base_sim.qmodel.name.clone();
        Self::start_multi(vec![(id, base_sim)], config, verify_model)
    }

    /// Start a multi-model server: one shard group per `(model id,
    /// pre-lowered pipeline)` entry, with per-group worker counts from
    /// [`ServerConfig::routes`] (fallback [`ServerConfig::workers`]).
    /// Tagged requests route via [`Server::submit_to`]; the untagged
    /// [`Server::submit`] serves the first group. When `verify_model` is
    /// given with several groups, only the matching group's shards sample
    /// into the golden verifier (a single-model server always samples).
    pub fn start_multi(
        models: Vec<(String, PipelineSim)>,
        config: ServerConfig,
        verify_model: Option<String>,
    ) -> Result<Server, String> {
        if models.is_empty() {
            return Err("start_multi requires at least one model".into());
        }
        for (i, (id, _)) in models.iter().enumerate() {
            if models[..i].iter().any(|(other, _)| other == id) {
                return Err(format!("duplicate model id '{id}'"));
            }
        }
        let single = models.len() == 1;
        let metrics = Arc::new(Metrics::default());
        let recorder = config
            .trace
            .then(|| Arc::new(FlightRecorder::new(config.trace_capacity)));

        // Verifier thread (owns the PJRT runtime end-to-end). All sampling
        // shards share one channel — the verifier handle is the channel,
        // cloned per worker.
        let (vtx, vrx) = sync_channel::<(Vec<i64>, Vec<i64>)>(64);
        let verifier = verify_model.clone().map(|name| {
            let vmetrics = Arc::clone(&metrics);
            std::thread::spawn(move || verifier_loop(&name, vrx, &vmetrics))
        });

        let mut groups = Vec::with_capacity(models.len());
        let mut shard_id = 0usize;
        for (model_id, base_sim) in models {
            let workers = config.route_workers(&model_id);
            let input_len = base_sim.input_len();
            // Analytic capacity constants for admission/dispatch/autoscale
            // (DESIGN.md §12). Engine-independent: the folded engine's
            // prediction shares completion cycles with the compiled one.
            let steady_cpf = base_sim.predicted.steady_cycles_per_frame.max(1);
            let first_latency = base_sim.predicted.first_frame_latency;
            let deadline_cycles =
                (config.batch_deadline.as_secs_f64() * config.clock_hz) as u64;
            let allowance_cycles = deadline_cycles
                .max(steady_cpf.saturating_mul(config.max_batch.max(1) as u64))
                .max(1);
            let active = match config.autoscale {
                Some(a) => a.min_workers.clamp(1, workers.min(a.max_workers.max(1))),
                None => workers,
            };
            // Only the verified model's shards sample responses — the
            // golden executable belongs to exactly one model.
            let samples = verify_model.is_some()
                && (single || verify_model.as_deref() == Some(model_id.as_str()));
            let tag: Arc<str> = Arc::from(model_id.as_str());
            // One profiler per group, shared by every shard's engine
            // clone, with rows named after the analytic prediction's
            // layers — so the measured and analytic sides of the
            // divergence table index identically.
            let profiler = config.profile.then(|| {
                Arc::new(LayerProfiler::new(
                    base_sim.predicted.layers.iter().map(|l| l.name.clone()).collect(),
                ))
            });
            let mut shards = Vec::with_capacity(workers);
            for _ in 0..workers {
                let (tx, rx) = sync_channel::<Job>(config.queue_depth.max(1));
                let shard_metrics = Arc::new(ShardMetrics::default());
                let sim = base_sim.clone();
                let mut wconfig = config.clone();
                if !samples {
                    wconfig.verify_every = 0;
                }
                let wmetrics = Arc::clone(&shard_metrics);
                let wvtx = vtx.clone();
                let wprof = profiler.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("cnn-flow-shard-{shard_id}"))
                    .spawn(move || worker_loop(sim, wconfig, rx, wvtx, &wmetrics, wprof))
                    .map_err(|e| format!("spawn shard {shard_id}: {e}"))?;
                shards.push(Shard {
                    tx,
                    metrics: shard_metrics,
                    handle: Mutex::new(Some(handle)),
                });
                shard_id += 1;
            }
            groups.push(Group {
                model: model_id,
                input_len,
                shards,
                rr: AtomicUsize::new(0),
                intake: IntakeMetrics::default(),
                steady_cpf,
                first_latency,
                allowance_cycles,
                active: AtomicUsize::new(active),
                idle: AtomicUsize::new(0),
                tag,
                profiler,
            });
        }
        // Workers hold the only remaining sampling senders: the verifier's
        // channel disconnects — and it drains, then exits — exactly when
        // the last worker does.
        drop(vtx);

        Ok(Server {
            groups,
            metrics,
            verifier: Mutex::new(verifier),
            config,
            open: AtomicBool::new(true),
            recorder,
        })
    }

    /// The model ids this server routes, in group order.
    pub fn models(&self) -> Vec<String> {
        self.groups.iter().map(|g| g.model.clone()).collect()
    }

    /// `(model id, flattened input frame length)` per group, in group
    /// order — what the TCP front-end ([`crate::net::server::NetServer`])
    /// advertises so clients can synthesize valid traffic without
    /// out-of-band knowledge of the hosted models.
    pub fn model_specs(&self) -> Vec<(String, usize)> {
        self.groups
            .iter()
            .map(|g| (g.model.clone(), g.input_len))
            .collect()
    }

    /// Predicted completion of a request admitted to `shard` right now,
    /// in modelled cycles: the pipeline fill cost plus one steady-state
    /// interval per request already queued (or in flight) ahead of it,
    /// plus its own. Queue depth × predicted cycles-per-frame is the
    /// denominator everywhere in §12 — admission, dispatch order, and
    /// autoscale all read this one formula.
    fn predict_on(group: &Group, shard: &Shard) -> u64 {
        let queued = shard.metrics.queued.load(Ordering::Relaxed);
        group
            .first_latency
            .saturating_add(queued.saturating_add(1).saturating_mul(group.steady_cpf))
    }

    /// Convert a microsecond deadline into a budget of modelled cycles.
    fn budget_cycles(&self, deadline_us: u64) -> u64 {
        (deadline_us as f64 * self.config.clock_hz / 1.0e6) as u64
    }

    /// One autoscale evaluation on the submit path ("between batches" —
    /// submission is the only clocked edge the coordinator owns). Grows
    /// the active-shard count when the analytic backlog exceeds one
    /// allowance per active shard; shrinks one step toward the floor
    /// after a run of zero-backlog evaluations (hysteresis against
    /// flapping).
    fn autoscale_tick(&self, group: &Group, bounds: AutoscaleConfig) {
        let spawned = group.shards.len();
        let max = bounds.max_workers.clamp(1, spawned);
        let min = bounds.min_workers.clamp(1, max);
        let active = group.active.load(Ordering::Relaxed).clamp(min, max);
        let backlog: u64 = group.shards[..active]
            .iter()
            .map(|s| s.metrics.queued.load(Ordering::Relaxed))
            .sum();
        let backlog_cycles = backlog.saturating_mul(group.steady_cpf);
        if backlog_cycles > group.allowance_cycles.saturating_mul(active as u64) && active < max
        {
            if group
                .active
                .compare_exchange(active, active + 1, Ordering::AcqRel, Ordering::Relaxed)
                .is_ok()
            {
                group.intake.scale_up.fetch_add(1, Ordering::Relaxed);
            }
            group.idle.store(0, Ordering::Relaxed);
        } else if backlog == 0 && active > min {
            let idle = group.idle.fetch_add(1, Ordering::Relaxed) + 1;
            if idle >= SHRINK_IDLE_TICKS {
                if group
                    .active
                    .compare_exchange(active, active - 1, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
                {
                    group.intake.scale_down.fetch_add(1, Ordering::Relaxed);
                }
                group.idle.store(0, Ordering::Relaxed);
            }
        } else {
            group.idle.store(0, Ordering::Relaxed);
        }
    }

    /// Dispatch within one model's shard group (DESIGN.md §12): shards
    /// are tried in policy order — ascending predicted load
    /// ([`DispatchKind::Predictive`], rotation breaking ties) or plain
    /// rotation ([`DispatchKind::RoundRobin`]) — with backpressure-aware
    /// spill. Deadline-bearing requests are screened by admission
    /// control first: shards that cannot meet the budget are skipped,
    /// and when *no* shard can, the request is shed (`"slo miss: …"`)
    /// instead of enqueued to fail late. `Err` otherwise only when every
    /// candidate queue is full (counted as rejected) or the server has
    /// stopped.
    fn submit_group(
        &self,
        group: &Group,
        x_q: Vec<i64>,
        opts: SubmitOpts,
        notify: Option<Arc<dyn CompletionNotify>>,
    ) -> Result<Pending, String> {
        if let Some(bounds) = self.config.autoscale {
            self.autoscale_tick(group, bounds);
        }
        let n = group.shards.len();
        let active = group.active.load(Ordering::Acquire).clamp(1, n);
        let budget = if opts.deadline_us == 0 {
            None
        } else {
            Some(self.budget_cycles(opts.deadline_us))
        };

        // Attempt order: rotation offset first (also the predictive
        // tie-break, so an idle group still wears evenly), then a stable
        // sort by predicted load when the policy is predictive.
        let preferred = rr_next(&group.rr, active);
        let mut order: Vec<usize> = (0..active).map(|i| (preferred + i) % active).collect();
        if self.config.dispatch == DispatchKind::Predictive {
            order.sort_by_key(|&i| Self::predict_on(group, &group.shards[i]));
        }

        // Span opens at intake, before admission screening, so shed and
        // rejected requests are traced too — the reconciliation identity
        // covers every intake outcome.
        let trace = self
            .recorder
            .as_ref()
            .map(|r| Box::new(ActiveSpan::begin(r, &self.config.clock, &group.tag)));
        let (rtx, rrx) = sync_channel(1);
        let mut job = Some(Job::Infer(Request {
            x_q,
            enqueued: Instant::now(),
            reply: rtx,
            notify,
            predicted_cycles: 0,
            slo_met: false,
            trace,
        }));
        let mut disconnected = 0usize;
        let mut screened = 0usize;
        let mut min_predicted = u64::MAX;
        for (attempt, &idx) in order.iter().enumerate() {
            let shard = &group.shards[idx];
            let predicted = Self::predict_on(group, shard);
            min_predicted = min_predicted.min(predicted);
            if let Some(b) = budget {
                if self.config.admission && predicted > b {
                    screened += 1;
                    continue;
                }
            }
            let mut j = job.take().expect("job consumed before send");
            if let Job::Infer(req) = &mut j {
                // Stamp the prediction for the shard actually tried; with
                // admission off this is how blind dispatch still reports
                // misses honestly (`slo_met` is decided here either way).
                req.predicted_cycles = if budget.is_some() { predicted } else { 0 };
                req.slo_met = budget.is_some_and(|b| predicted <= b);
                // Tentative admission stamp for the shard about to be
                // tried; cleared again on the rejection tail below if no
                // try_send ever succeeds.
                if let Some(t) = req.trace.as_deref_mut() {
                    t.span.shard = idx as u32;
                    t.span.admitted_ns = t.clock.now_nanos();
                }
            }
            match shard.tx.try_send(j) {
                Ok(()) => {
                    shard.metrics.queued.fetch_add(1, Ordering::Relaxed);
                    group.intake.accepted.fetch_add(1, Ordering::Relaxed);
                    if attempt > 0 {
                        group.intake.spilled.fetch_add(1, Ordering::Relaxed);
                    }
                    return Ok(Pending { rx: rrx });
                }
                Err(TrySendError::Full(j)) => job = Some(j),
                Err(TrySendError::Disconnected(j)) => {
                    job = Some(j);
                    disconnected += 1;
                }
            }
        }
        if disconnected == active {
            // Not an intake outcome (no counter moves), so the span is
            // dropped unrecorded — the reconciliation identity only
            // covers completed/errored/rejected/shed.
            return Err("server stopped".into());
        }
        if screened == active - disconnected {
            // Every live candidate failed the admission screen: cheap
            // shed beats late work. The "slo miss" prefix is the wire
            // contract for `ErrorCode::SloMiss` (net/proto.rs).
            group.intake.shed.fetch_add(1, Ordering::Relaxed);
            finish_turned_away(&mut job, SpanOutcome::Shed);
            return Err(format!(
                "slo miss: predicted {min_predicted} cycles exceeds deadline budget {} cycles",
                budget.unwrap_or(0)
            ));
        }
        group.intake.rejected.fetch_add(1, Ordering::Relaxed);
        finish_turned_away(&mut job, SpanOutcome::Rejected);
        Err("backpressure: all shard queues full".into())
    }

    /// Enqueue a request without blocking for its answer, dispatched to
    /// the first (default) model group — the single-model API.
    pub fn submit(&self, x_q: Vec<i64>) -> Result<Pending, String> {
        if !self.open.load(Ordering::Acquire) {
            return Err("server stopped".into());
        }
        self.submit_group(&self.groups[0], x_q, SubmitOpts::default(), None)
    }

    /// Enqueue a tagged request for `model`'s shard group. Unknown model
    /// ids are refused (and counted as `unrouted` in the snapshot);
    /// requests never spill across models.
    pub fn submit_to(&self, model: &str, x_q: Vec<i64>) -> Result<Pending, String> {
        self.submit_to_opts(model, x_q, SubmitOpts::default(), None)
    }

    /// [`submit_to`](Server::submit_to) with a completion hook: `notify`
    /// fires on the worker after the answer becomes
    /// [`Pending::try_wait`]-able. This is how the evented TCP core
    /// learns a reply is ready without parking a thread per request —
    /// rejections at submit time return `Err` synchronously and never
    /// fire the hook.
    pub fn submit_to_notified(
        &self,
        model: &str,
        x_q: Vec<i64>,
        notify: Option<Arc<dyn CompletionNotify>>,
    ) -> Result<Pending, String> {
        self.submit_to_opts(model, x_q, SubmitOpts::default(), notify)
    }

    /// [`submit_to_notified`](Server::submit_to_notified) with per-request
    /// SLO options ([`SubmitOpts`]) — the full submit surface both TCP
    /// cores use. Deadline-bearing requests go through admission control
    /// when [`ServerConfig::admission`] is on; a shed request returns
    /// `Err("slo miss: …")` synchronously (counted in the `shed`
    /// snapshot gauge, wire `ErrorCode::SloMiss`).
    pub fn submit_to_opts(
        &self,
        model: &str,
        x_q: Vec<i64>,
        opts: SubmitOpts,
        notify: Option<Arc<dyn CompletionNotify>>,
    ) -> Result<Pending, String> {
        if !self.open.load(Ordering::Acquire) {
            return Err("server stopped".into());
        }
        match self.groups.iter().find(|g| g.model == model) {
            Some(group) => self.submit_group(group, x_q, opts, notify),
            None => {
                self.metrics.unrouted.fetch_add(1, Ordering::Relaxed);
                Err(format!("no route for model '{model}'"))
            }
        }
    }

    /// Blocking inference on the default (first) model group. Returns Err
    /// when every shard queue is saturated (backpressure) or the server
    /// is shutting down.
    pub fn infer(&self, x_q: Vec<i64>) -> Result<InferResponse, String> {
        self.submit(x_q)?.wait()
    }

    /// Blocking tagged inference on `model`'s shard group.
    pub fn infer_to(&self, model: &str, x_q: Vec<i64>) -> Result<InferResponse, String> {
        self.submit_to(model, x_q)?.wait()
    }

    /// Merge intake + shard counters over a set of groups into one
    /// snapshot. Verifier counters and `unrouted` are server-global, so
    /// they stay zero here and are filled in by [`Server::metrics`] —
    /// per-model views report them as 0 by contract (DESIGN.md §7).
    fn snapshot_of(&self, groups: &[&Group]) -> MetricsSnapshot {
        let mut workers = 0usize;
        let mut active_workers = 0usize;
        let mut accepted = 0u64;
        let mut rejected = 0u64;
        let mut shed = 0u64;
        let mut scale_up_events = 0u64;
        let mut scale_down_events = 0u64;
        let mut spilled = 0u64;
        let mut completed = 0u64;
        let mut batches = 0u64;
        let mut cycles = 0u64;
        let mut service_ns = 0u64;
        let mut busy_max = 0u64;
        let mut predicted_cycles = 0u64;
        let mut simulated_cycles = 0u64;
        let mut cycle_divergence = 0u64;
        let mut errored = 0u64;
        let mut occupancy_frames = 0u64;
        let mut flush_full = 0u64;
        let mut flush_deadline = 0u64;
        let mut flush_drain = 0u64;
        let mut batch_occupancy = [0u64; metrics::OCC_SLOTS];
        let mut buckets = [0u64; metrics::BUCKETS];
        for g in groups {
            workers += g.shards.len();
            active_workers += g.active.load(Ordering::Relaxed).clamp(1, g.shards.len());
            accepted += g.intake.accepted.load(Ordering::Relaxed);
            rejected += g.intake.rejected.load(Ordering::Relaxed);
            shed += g.intake.shed.load(Ordering::Relaxed);
            scale_up_events += g.intake.scale_up.load(Ordering::Relaxed);
            scale_down_events += g.intake.scale_down.load(Ordering::Relaxed);
            spilled += g.intake.spilled.load(Ordering::Relaxed);
            for s in &g.shards {
                completed += s.metrics.completed.load(Ordering::Relaxed);
                batches += s.metrics.batches.load(Ordering::Relaxed);
                cycles += s.metrics.sim_cycles_total.load(Ordering::Relaxed);
                service_ns += s.metrics.service_ns_total.load(Ordering::Relaxed);
                busy_max = busy_max.max(s.metrics.busy_cycles.load(Ordering::Relaxed));
                predicted_cycles += s.metrics.predicted_cycles.load(Ordering::Relaxed);
                simulated_cycles += s.metrics.simulated_cycles.load(Ordering::Relaxed);
                cycle_divergence += s.metrics.cycle_divergence.load(Ordering::Relaxed);
                errored += s.metrics.errored.load(Ordering::Relaxed);
                occupancy_frames += s.metrics.occupancy_frames.load(Ordering::Relaxed);
                flush_full += s.metrics.flush_full.load(Ordering::Relaxed);
                flush_deadline += s.metrics.flush_deadline.load(Ordering::Relaxed);
                flush_drain += s.metrics.flush_drain.load(Ordering::Relaxed);
                for (b, v) in batch_occupancy
                    .iter_mut()
                    .zip(s.metrics.occupancy.counts().iter())
                {
                    *b += v;
                }
                for (b, v) in buckets.iter_mut().zip(s.metrics.latency.counts().iter()) {
                    *b += v;
                }
            }
        }
        MetricsSnapshot {
            workers,
            active_workers,
            models: groups.len(),
            accepted,
            rejected,
            shed,
            scale_up_events,
            scale_down_events,
            spilled,
            unrouted: 0,
            completed,
            batches,
            verified: 0,
            mismatches: 0,
            predicted_cycles,
            simulated_cycles,
            cycle_divergence,
            errored,
            occupancy_frames,
            flush_full,
            flush_deadline,
            flush_drain,
            batch_occupancy,
            mean_batch: completed as f64 / batches.max(1) as f64,
            mean_service: Duration::from_nanos(if completed == 0 {
                0
            } else {
                service_ns / completed
            }),
            p50: metrics::quantile(&buckets, 0.50),
            p95: metrics::quantile(&buckets, 0.95),
            p99: metrics::quantile(&buckets, 0.99),
            projected_fps: if cycles == 0 {
                0.0
            } else {
                completed as f64 / (cycles as f64 / self.config.clock_hz)
            },
            aggregate_fps: if busy_max == 0 {
                0.0
            } else {
                completed as f64 / (busy_max as f64 / self.config.clock_hz)
            },
        }
    }

    /// Aggregate snapshot across all models and shards.
    pub fn metrics(&self) -> MetricsSnapshot {
        let groups: Vec<&Group> = self.groups.iter().collect();
        let mut snap = self.snapshot_of(&groups);
        snap.verified = self.metrics.verified.load(Ordering::Relaxed);
        snap.mismatches = self.metrics.mismatches.load(Ordering::Relaxed);
        snap.unrouted = self.metrics.unrouted.load(Ordering::Relaxed);
        snap
    }

    /// Per-model snapshots (one per shard group), in group order. Each is
    /// the same shape as the aggregate view, restricted to that model's
    /// intake and shards; verifier counters and `unrouted` are
    /// server-global and report 0 here.
    pub fn model_metrics(&self) -> Vec<ModelMetricsSnapshot> {
        self.groups
            .iter()
            .map(|g| ModelMetricsSnapshot {
                model: g.model.clone(),
                metrics: self.snapshot_of(&[g]),
            })
            .collect()
    }

    /// Per-shard snapshots (completed counts, busy cycles, latency
    /// quantiles) for load-balance inspection, tagged with the model the
    /// shard serves; shard indices are global across groups.
    pub fn shard_metrics(&self) -> Vec<ShardSnapshot> {
        let mut out = Vec::new();
        for g in &self.groups {
            for s in &g.shards {
                let mut snap = s.metrics.snapshot(out.len());
                snap.model = g.model.clone();
                out.push(snap);
            }
        }
        out
    }

    /// The flight recorder, when tracing is enabled
    /// ([`ServerConfig::trace`]). Spans land here as requests reach
    /// their terminal outcome; after a drain the recorder is frozen and
    /// `spans_recorded + spans_dropped` equals
    /// `completed + errored + rejected + shed`.
    pub fn flight_recorder(&self) -> Option<Arc<FlightRecorder>> {
        self.recorder.as_ref().map(Arc::clone)
    }

    /// Recorder accounting snapshot (None when tracing is off).
    pub fn trace_stats(&self) -> Option<TraceStatsSnapshot> {
        self.recorder.as_ref().map(|r| r.stats())
    }

    /// Per-model measured layer profiles, in group order (empty when
    /// profiling is off). Rows are named and ordered identically to the
    /// group's `SchedulePrediction::layers`, so callers can zip them
    /// against the analytic cycle shares directly.
    pub fn layer_profiles(&self) -> Vec<(String, Vec<LayerProfileRow>)> {
        self.groups
            .iter()
            .filter_map(|g| {
                g.profiler
                    .as_ref()
                    .map(|p| (g.model.clone(), p.snapshot()))
            })
            .collect()
    }

    /// Render the live Prometheus text-format exposition page for this
    /// server: aggregate + per-model snapshots, the trace accounting
    /// when tracing is on, plus whatever front-end snapshots the caller
    /// has (`net` for either TCP core, `reactor` for the evented one).
    pub fn metrics_text(
        &self,
        net: Option<&NetMetricsSnapshot>,
        reactor: Option<&ReactorStatsSnapshot>,
    ) -> String {
        let aggregate = self.metrics();
        let per_model = self.model_metrics();
        let trace = self.trace_stats();
        crate::obs::prom::render_exposition(&aggregate, &per_model, net, reactor, trace.as_ref())
    }

    /// Graceful shutdown: close intake, drain every shard queue, join all
    /// threads, return the final (deterministic) snapshot.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.close();
        self.metrics()
    }

    /// Like [`Server::shutdown`] but without consuming the server, so the
    /// final per-shard metrics stay inspectable. Idempotent; after
    /// draining, every snapshot is frozen.
    pub fn drain(&mut self) {
        self.close();
    }

    /// [`Server::drain`] through a shared reference — for callers that
    /// hold the server in an `Arc`, like the TCP front-end, which must
    /// flush in-flight coordinator work *between* EOF-ing its connection
    /// readers and joining its connection writers
    /// (`net::server::NetServer::shutdown`). Same semantics, same
    /// idempotence: concurrent drains race benignly on the taken handles.
    pub fn drain_shared(&self) {
        self.close();
    }

    fn close(&self) {
        self.open.store(false, Ordering::Release);
        // The shutdown marker queues FIFO behind every accepted request,
        // so workers answer everything before exiting.
        for g in &self.groups {
            for s in &g.shards {
                let _ = s.tx.send(Job::Shutdown);
            }
        }
        for g in &self.groups {
            for s in &g.shards {
                let handle = s
                    .handle
                    .lock()
                    .unwrap_or_else(|p| p.into_inner())
                    .take();
                if let Some(h) = handle {
                    let _ = h.join();
                }
            }
        }
        // All worker-held sampling senders are gone now: the verifier
        // drains its queue and exits.
        let verifier = self
            .verifier
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .take();
        if let Some(v) = verifier {
            let _ = v.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.close();
    }
}

/// Finalize the span of a request turned away at intake (rejected or
/// shed). The tentative admission stamps from failed `try_send` attempts
/// are cleared first: the request was never admitted anywhere.
fn finish_turned_away(job: &mut Option<Job>, outcome: SpanOutcome) {
    if let Some(Job::Infer(req)) = job.as_mut() {
        if let Some(mut t) = req.trace.take() {
            t.span.shard = 0;
            t.span.admitted_ns = 0;
            t.finish(outcome);
        }
    }
}

/// Stamp the queue-exit time on a freshly dequeued request's span.
fn stamp_dequeued(req: &mut Request) {
    if let Some(t) = req.trace.as_deref_mut() {
        t.span.dequeued_ns = t.clock.now_nanos();
    }
}

/// One shard: accumulate queued requests into deadline-bounded
/// micro-batches and stream each batch through this shard's own pipeline
/// replica.
fn worker_loop(
    sim: PipelineSim,
    config: ServerConfig,
    rx: Receiver<Job>,
    vtx: SyncSender<(Vec<i64>, Vec<i64>)>,
    shard: &ShardMetrics,
    profiler: Option<Arc<LayerProfiler>>,
) {
    // The value engine is cloned once per shard and reused across all
    // groups — scratch buffers included, so the hot path never allocates
    // activation storage.
    let mut engine: WorkerEngine = match config.engine {
        EngineKind::Compiled => WorkerEngine::Compiled(sim.compiled.clone()),
        EngineKind::Folded => WorkerEngine::Folded(sim.folded.clone()),
        EngineKind::Interpreter => WorkerEngine::Interp,
    };
    // The profiler rides the shard's private engine clone; the
    // interpreter oracle ignores it (its cycle model already attributes
    // work per layer analytically).
    match &mut engine {
        WorkerEngine::Compiled(cp) => cp.set_profiler(profiler),
        WorkerEngine::Folded(fp) => fp.set_profiler(profiler),
        WorkerEngine::Interp => {}
    }
    let max_batch = config.max_batch.max(1);
    let mut serial: u64 = 0;
    let mut open = true;
    while open {
        // Block for the first request, then accumulate until the batch is
        // full or the first request's deadline expires — contiguous
        // frames = continuous flow, the deadline caps the added latency.
        let mut first = match rx.recv() {
            Ok(Job::Infer(r)) => r,
            Ok(Job::Shutdown) | Err(_) => break,
        };
        stamp_dequeued(&mut first);
        // checked_add: an absurd --batch-deadline must degrade to "wait
        // a day" rather than panic on Instant overflow.
        let deadline = first
            .enqueued
            .checked_add(config.batch_deadline)
            .unwrap_or_else(|| Instant::now() + Duration::from_secs(86_400));
        let mut group = vec![first];
        let mut reason = FlushReason::Full;
        while group.len() < max_batch {
            let left = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(left) {
                Ok(Job::Infer(mut r)) => {
                    stamp_dequeued(&mut r);
                    group.push(r);
                }
                Ok(Job::Shutdown) => {
                    open = false;
                    reason = FlushReason::Drain;
                    break;
                }
                Err(RecvTimeoutError::Timeout) => {
                    reason = FlushReason::Deadline;
                    break;
                }
                Err(RecvTimeoutError::Disconnected) => {
                    open = false;
                    reason = FlushReason::Drain;
                    break;
                }
            }
        }
        run_group(&sim, &mut engine, &config, group, &vtx, shard, &mut serial, reason);
    }
    // Drain: answer anything still queued (e.g. requests that raced the
    // shutdown marker) so no accepted request is dropped unanswered. The
    // final partial batches record like any other flush.
    loop {
        let mut group = Vec::new();
        while group.len() < max_batch {
            match rx.try_recv() {
                Ok(Job::Infer(mut r)) => {
                    stamp_dequeued(&mut r);
                    group.push(r);
                }
                Ok(Job::Shutdown) => continue,
                Err(_) => break,
            }
        }
        if group.is_empty() {
            break;
        }
        run_group(
            &sim,
            &mut engine,
            &config,
            group,
            &vtx,
            shard,
            &mut serial,
            FlushReason::Drain,
        );
    }
}

/// Per-shard clone of the configured value engine (the interpreter runs
/// straight off the shared [`PipelineSim`], so it carries no state here).
enum WorkerEngine {
    Compiled(CompiledPipeline),
    Folded(FoldedPipeline),
    Interp,
}

/// Outcome of one frame group, engine-independent. Per-frame results so
/// one malformed request (wrong length, out-of-grid values) errors only
/// its own reply, never its co-batched neighbours.
struct GroupResult {
    outputs: Vec<Result<Vec<i64>, String>>,
    /// Frame-0 latency (cycles) reported per response.
    latency_cycles: u64,
    /// Steady-state cycles attributed to each frame of the group.
    per_frame_cycles: u64,
    /// Total modelled cycles the group occupied the pipeline for.
    group_cycles: u64,
}

/// Compiled hot path: the whole micro-batch runs through
/// [`CompiledPipeline::execute_batch`] (one program traversal, batch
/// innermost), with O(1) closed-form cycle figures from the
/// [`crate::flow::BatchPrediction`] — no cycle simulation. Requests are
/// screened individually first, so one malformed frame errors only its
/// own reply, never its co-batched neighbours.
fn run_group_compiled(
    sim: &PipelineSim,
    engine: &mut CompiledPipeline,
    group: &[Request],
    shard: &ShardMetrics,
) -> GroupResult {
    let mut outputs: Vec<Result<Vec<i64>, String>> = Vec::with_capacity(group.len());
    let mut frames: Vec<&[i64]> = Vec::with_capacity(group.len());
    let mut slots: Vec<usize> = Vec::with_capacity(group.len());
    for (i, r) in group.iter().enumerate() {
        match engine.validate_frame(&r.x_q) {
            Ok(()) => {
                slots.push(i);
                frames.push(&r.x_q);
                outputs.push(Ok(Vec::new()));
            }
            Err(e) => outputs.push(Err(e)),
        }
    }
    // Every frame in `frames` passed validate_frame above, so the
    // prevalidated entry point skips the redundant second scan.
    match engine.execute_batch_prevalidated(&frames) {
        Ok(batch_out) => {
            for (&slot, o) in slots.iter().zip(batch_out) {
                outputs[slot] = Ok(o);
            }
        }
        Err(e) => {
            for &slot in &slots {
                outputs[slot] = Err(e.clone());
            }
        }
    }
    let bp = sim.predicted.batched(frames.len());
    shard
        .predicted_cycles
        .fetch_add(bp.total_cycles, Ordering::Relaxed);
    GroupResult {
        outputs,
        latency_cycles: bp.first_frame_latency,
        per_frame_cycles: bp.steady_cycles_per_frame.max(1.0) as u64,
        group_cycles: bp.total_cycles,
    }
}

/// Folded hot path: same screening and batched traversal structure as
/// [`run_group_compiled`], but on the rate-aware [`FoldedPipeline`]
/// (fused low-rate layers, register-blocked kernels) with cycle figures
/// from the certified `FoldedPrediction` — which shares every cycle
/// field with the unfolded prediction, because folding re-accounts unit
/// *work*, never completion times (DESIGN.md §9).
fn run_group_folded(
    sim: &PipelineSim,
    engine: &mut FoldedPipeline,
    group: &[Request],
    shard: &ShardMetrics,
) -> GroupResult {
    let mut outputs: Vec<Result<Vec<i64>, String>> = Vec::with_capacity(group.len());
    let mut frames: Vec<&[i64]> = Vec::with_capacity(group.len());
    let mut slots: Vec<usize> = Vec::with_capacity(group.len());
    for (i, r) in group.iter().enumerate() {
        match engine.validate_frame(&r.x_q) {
            Ok(()) => {
                slots.push(i);
                frames.push(&r.x_q);
                outputs.push(Ok(Vec::new()));
            }
            Err(e) => outputs.push(Err(e)),
        }
    }
    match engine.execute_batch_prevalidated(&frames) {
        Ok(batch_out) => {
            for (&slot, o) in slots.iter().zip(batch_out) {
                outputs[slot] = Ok(o);
            }
        }
        Err(e) => {
            for &slot in &slots {
                outputs[slot] = Err(e.clone());
            }
        }
    }
    let fp = sim.predicted.folded(frames.len(), &sim.fold_factors);
    shard
        .predicted_cycles
        .fetch_add(fp.total_cycles, Ordering::Relaxed);
    GroupResult {
        outputs,
        latency_cycles: fp.first_frame_latency,
        per_frame_cycles: fp.steady_cycles_per_frame.max(1.0) as u64,
        group_cycles: fp.total_cycles,
    }
}

/// Oracle path: the fused interpreter, cross-checking the closed-form
/// cycle prediction on every group.
fn run_group_interpreted(
    sim: &PipelineSim,
    group: &[Request],
    shard: &ShardMetrics,
) -> GroupResult {
    let frames: Vec<Vec<i64>> = group.iter().map(|r| r.x_q.clone()).collect();
    let result = match sim.run_interpreted(&frames) {
        Ok(r) => r,
        Err(e) => {
            // The fused loop answers all-or-nothing: surface the error on
            // every reply (frame-length errors are per-request anyway).
            return GroupResult {
                outputs: group.iter().map(|_| Err(e.clone())).collect(),
                latency_cycles: 0,
                per_frame_cycles: 0,
                group_cycles: 0,
            };
        }
    };
    let predicted = sim.predicted.total_cycles(group.len());
    shard
        .predicted_cycles
        .fetch_add(predicted, Ordering::Relaxed);
    shard
        .simulated_cycles
        .fetch_add(result.total_cycles, Ordering::Relaxed);
    if predicted != result.total_cycles {
        shard.cycle_divergence.fetch_add(1, Ordering::Relaxed);
    }
    GroupResult {
        latency_cycles: result.first_frame_latency,
        per_frame_cycles: result.cycles_per_frame.max(1.0) as u64,
        group_cycles: result.total_cycles,
        outputs: result.outputs.into_iter().map(Ok).collect(),
    }
}

#[allow(clippy::too_many_arguments)]
fn run_group(
    sim: &PipelineSim,
    engine: &mut WorkerEngine,
    config: &ServerConfig,
    mut group: Vec<Request>,
    vtx: &SyncSender<(Vec<i64>, Vec<i64>)>,
    shard: &ShardMetrics,
    serial: &mut u64,
    reason: FlushReason,
) {
    // One clock reading closes batch assembly AND opens execution for
    // the whole group (batch_assembly = dequeue → flush, execute =
    // engine time); a second closes execution after the engine returns.
    let exec_start = if config.trace { config.clock.now_nanos() } else { 0 };
    let result = match engine {
        WorkerEngine::Compiled(cp) => run_group_compiled(sim, cp, &group, shard),
        WorkerEngine::Folded(fp) => run_group_folded(sim, fp, &group, shard),
        WorkerEngine::Interp => run_group_interpreted(sim, &group, shard),
    };
    if config.trace {
        let exec_end = config.clock.now_nanos();
        let bsz = group.len() as u32;
        for req in &mut group {
            if let Some(t) = req.trace.as_deref_mut() {
                t.span.batch_size = bsz;
                t.span.batched_ns = exec_start;
                t.span.exec_start_ns = exec_start;
                t.span.exec_end_ns = exec_end;
            }
        }
    }
    shard.batches.fetch_add(1, Ordering::Relaxed);
    match reason {
        FlushReason::Full => shard.flush_full.fetch_add(1, Ordering::Relaxed),
        FlushReason::Deadline => shard.flush_deadline.fetch_add(1, Ordering::Relaxed),
        FlushReason::Drain => shard.flush_drain.fetch_add(1, Ordering::Relaxed),
    };
    shard
        .occupancy_frames
        .fetch_add(group.len() as u64, Ordering::Relaxed);
    shard.occupancy.record(group.len());
    shard
        .busy_cycles
        .fetch_add(result.group_cycles, Ordering::Relaxed);
    for (req, outcome) in group.into_iter().zip(result.outputs.into_iter()) {
        // The request leaves this shard's analytic backlog when answered,
        // on every path — the `queued` gauge feeds admission predictions.
        shard.queued.fetch_sub(1, Ordering::Relaxed);
        let logits = match outcome {
            Ok(logits) => logits,
            Err(e) => {
                shard.errored.fetch_add(1, Ordering::Relaxed);
                req.answer(Err(e));
                continue;
            }
        };
        *serial += 1;
        let argmax = logits
            .iter()
            .enumerate()
            .max_by_key(|(_, v)| **v)
            .map(|(i, _)| i)
            .unwrap_or(0);
        let service = req.enqueued.elapsed();
        let resp = InferResponse {
            logits: logits.clone(),
            argmax,
            sim_latency_cycles: result.latency_cycles,
            service_time: service,
            predicted_cycles: req.predicted_cycles,
            slo_met: req.slo_met,
        };
        shard.completed.fetch_add(1, Ordering::Relaxed);
        shard
            .sim_cycles_total
            .fetch_add(result.per_frame_cycles, Ordering::Relaxed);
        // Saturate the u128→u64 narrowing: a clock anomaly (or a request
        // parked for centuries) must clamp, not alias small.
        shard
            .service_ns_total
            .fetch_add(metrics::saturating_nanos(service), Ordering::Relaxed);
        shard.latency.record(service);
        if config.verify_every > 0 && *serial % config.verify_every as u64 == 0 {
            // Sampled golden check; drop silently if the verifier
            // is busy (never blocks serving).
            let _ = vtx.try_send((req.x_q.clone(), logits));
        }
        req.answer(Ok(resp));
    }
}

fn verifier_loop(
    model_name: &str,
    vrx: Receiver<(Vec<i64>, Vec<i64>)>,
    metrics: &Metrics,
) {
    let rt = match crate::runtime::Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("verifier disabled: {e}");
            return;
        }
    };
    let bundle = match crate::runtime::ModelBundle::load(&rt, model_name) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("verifier disabled: {e}");
            return;
        }
    };
    // Drains everything still queued after the workers disconnect, so a
    // post-shutdown snapshot reflects every sampled request.
    while let Ok((x_q, logits)) = vrx.recv() {
        let xf: Vec<f32> = x_q.iter().map(|&v| v as f32).collect();
        match bundle.golden.run_f32(&xf) {
            Ok(y) => {
                let y_i: Vec<i64> = y.iter().map(|&v| v as i64).collect();
                metrics.verified.fetch_add(1, Ordering::Relaxed);
                if y_i != logits {
                    metrics.mismatches.fetch_add(1, Ordering::Relaxed);
                    eprintln!("GOLDEN MISMATCH: sim {logits:?} != pjrt {y_i:?}");
                }
            }
            Err(e) => eprintln!("verifier execute error: {e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{QKind, QLayer};
    use crate::util::Rng;

    fn tiny_qmodel() -> QModel {
        // Single dense layer 4 -> 3, accumulator out.
        QModel {
            name: "t".into(),
            input_shape: [1, 1, 4],
            input_scale: 1.0,
            layers: vec![QLayer {
                name: "d".into(),
                kind: QKind::Dense,
                k: 0,
                s: 1,
                p: 0,
                relu: false,
                w_q: vec![1, 0, 0, 0, 0, 1, 0, 0, 0, 0, 1, 1],
                w_shape: vec![3, 4],
                b_q: vec![0, 0, 0],
                m: 0.0,
                in_shape: [1, 1, 4],
                out_shape: [1, 1, 3],
            }],
            topology: vec![],
            test_vectors: vec![],
            qat_accuracy: 1.0,
        }
    }

    #[test]
    fn serve_and_answer() {
        let server = Server::start(tiny_qmodel(), ServerConfig::default(), None).unwrap();
        let resp = server.infer(vec![5, -3, 7, 2]).unwrap();
        assert_eq!(resp.logits, vec![5, -3, 9]);
        assert_eq!(resp.argmax, 2);
        let m = server.shutdown();
        assert_eq!(m.completed, 1);
        assert_eq!(m.mismatches, 0);
    }

    #[test]
    fn concurrent_clients_all_served() {
        let server = Arc::new(
            Server::start(tiny_qmodel(), ServerConfig::default(), None).unwrap(),
        );
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let s = Arc::clone(&server);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(t);
                for _ in 0..20 {
                    let x: Vec<i64> = (0..4).map(|_| rng.int8() as i64).collect();
                    let expect = vec![x[0], x[1], x[2] + x[3]];
                    match s.infer(x) {
                        Ok(r) => assert_eq!(r.logits, expect),
                        Err(e) => assert!(e.contains("backpressure"), "{e}"),
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = server.metrics();
        assert!(m.completed + m.rejected >= 160);
        assert_eq!(m.completed, m.accepted);
    }

    #[test]
    fn batching_groups_requests() {
        let config = ServerConfig {
            max_batch: 8,
            batch_deadline: Duration::from_millis(20),
            ..Default::default()
        };
        let server = Arc::new(Server::start(tiny_qmodel(), config, None).unwrap());
        let mut handles = Vec::new();
        for _ in 0..16 {
            let s = Arc::clone(&server);
            handles.push(std::thread::spawn(move || s.infer(vec![1, 2, 3, 4]).unwrap()));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = server.metrics();
        assert_eq!(m.completed, 16);
        assert!(
            m.mean_batch > 1.0,
            "expected batching, mean batch {}",
            m.mean_batch
        );
    }

    #[test]
    fn backpressure_rejects_when_saturated() {
        // Queue depth 1 and a slow drain: the burst must see rejections
        // rather than unbounded queueing.
        let config = ServerConfig {
            max_batch: 1,
            queue_depth: 1,
            batch_deadline: Duration::from_millis(0),
            ..Default::default()
        };
        let server = Arc::new(Server::start(tiny_qmodel(), config, None).unwrap());
        let mut rejected = 0;
        let mut handles = Vec::new();
        for _ in 0..32 {
            let s = Arc::clone(&server);
            handles.push(std::thread::spawn(move || s.infer(vec![0, 0, 0, 0]).is_err()));
        }
        for h in handles {
            if h.join().unwrap() {
                rejected += 1;
            }
        }
        let m = server.metrics();
        assert_eq!(m.rejected as usize, rejected);
        assert_eq!(m.accepted + m.rejected, 32);
    }

    #[test]
    fn projected_fps_positive() {
        let server = Server::start(tiny_qmodel(), ServerConfig::default(), None).unwrap();
        for _ in 0..4 {
            server.infer(vec![1, 1, 1, 1]).unwrap();
        }
        let m = server.shutdown();
        assert!(m.projected_fps > 0.0);
        assert!(m.aggregate_fps > 0.0);
    }

    #[test]
    fn sharded_server_matches_single_shard_golden() {
        // The same seeded trace through 1 and 4 shards must produce
        // bit-identical logits (checked against the single-sim oracle).
        let qm = QModel::synthetic(8, 4, 6, 0x5EED);
        let sim = PipelineSim::new(qm.clone(), None).unwrap();
        let trace = loadgen::Trace::seeded(11, 48, 64, 2);
        let expected = loadgen::golden_outputs(&sim, &trace);
        for workers in [1usize, 4] {
            let server = Server::start(
                qm.clone(),
                ServerConfig {
                    workers,
                    max_batch: 4,
                    queue_depth: 64,
                    verify_every: 0,
                    batch_deadline: Duration::from_millis(1),
                    ..Default::default()
                },
                None,
            )
            .unwrap();
            let report = loadgen::replay(&server, &trace, 8, Some(&expected));
            let m = server.shutdown();
            assert_eq!(report.ok, 48, "workers={workers}");
            assert_eq!(report.mismatched, 0, "workers={workers}");
            assert_eq!(report.rejected, 0, "workers={workers}");
            assert_eq!(m.completed, 48, "workers={workers}");
            assert_eq!(m.workers, workers);
        }
    }

    #[test]
    fn shutdown_drains_queued_requests() {
        // Requests accepted before shutdown must all be answered: the
        // shutdown marker queues behind them (deterministic, no sleeps).
        let server = Server::start(
            tiny_qmodel(),
            ServerConfig {
                workers: 1,
                max_batch: 4,
                queue_depth: 64,
                verify_every: 0,
                batch_deadline: Duration::from_millis(0),
                ..Default::default()
            },
            None,
        )
        .unwrap();
        let pendings: Vec<Pending> = (0..8)
            .map(|i| server.submit(vec![i, 0, 0, 0]).unwrap())
            .collect();
        let m = server.shutdown();
        assert_eq!(m.completed, 8);
        assert_eq!(m.accepted, 8);
        for (i, p) in pendings.into_iter().enumerate() {
            let r = p.wait().unwrap();
            assert_eq!(r.logits, vec![i as i64, 0, 0]);
        }
    }

    #[test]
    fn round_robin_distributes_evenly_with_serial_load() {
        // With one request in flight at a time every queue is empty at
        // dispatch, so the round-robin preference is always honoured and
        // the shards split the trace exactly evenly.
        let qm = QModel::synthetic(8, 4, 6, 0xD15);
        let server = Server::start(
            qm,
            ServerConfig {
                workers: 4,
                max_batch: 1,
                queue_depth: 8,
                verify_every: 0,
                batch_deadline: Duration::from_millis(0),
                dispatch: DispatchKind::RoundRobin,
                autoscale: None,
                ..Default::default()
            },
            None,
        )
        .unwrap();
        let trace = loadgen::Trace::seeded(3, 32, 64, 0);
        let report = loadgen::replay(&server, &trace, 1, None);
        assert_eq!(report.ok, 32);
        assert_eq!(report.rejected, 0);
        let shards = server.shard_metrics();
        assert_eq!(shards.len(), 4);
        for s in &shards {
            assert_eq!(s.completed, 8, "shard {} unbalanced", s.shard);
        }
        let m = server.shutdown();
        assert_eq!(m.spilled, 0);
        assert_eq!(m.completed, 32);
    }

    #[test]
    fn interpreter_engine_matches_compiled_bit_for_bit() {
        // The same seeded trace through both engines must produce
        // identical logits, and the interpreter engine must confirm the
        // closed-form cycle prediction on every group.
        let qm = QModel::synthetic(8, 4, 6, 0xE6);
        let sim = PipelineSim::new(qm.clone(), None).unwrap();
        let trace = loadgen::Trace::seeded(17, 40, 64, 1);
        let expected = loadgen::golden_outputs(&sim, &trace);
        let mut snapshots = Vec::new();
        for engine in [
            EngineKind::Compiled,
            EngineKind::Folded,
            EngineKind::Interpreter,
        ] {
            let server = Server::start(
                qm.clone(),
                ServerConfig {
                    workers: 2,
                    max_batch: 4,
                    queue_depth: 64,
                    verify_every: 0,
                    engine,
                    batch_deadline: Duration::from_millis(1),
                    ..Default::default()
                },
                None,
            )
            .unwrap();
            let report = loadgen::replay(&server, &trace, 8, Some(&expected));
            let m = server.shutdown();
            assert_eq!(report.ok, 40, "{engine:?}");
            assert_eq!(report.mismatched, 0, "{engine:?}");
            assert_eq!(m.cycle_divergence, 0, "{engine:?}");
            snapshots.push(m);
        }
        // Interpreter mode measured cycles; they must equal its own
        // predictions exactly (the live predicted-vs-simulated check).
        let interp = &snapshots[2];
        assert!(interp.simulated_cycles > 0);
        assert_eq!(interp.simulated_cycles, interp.predicted_cycles);
        // Compiled and folded modes never simulate cycles but predict
        // totals for the same group shapes; the folded certificate's
        // totals must match the unfolded prediction (same groups, same
        // closed form — folding changes unit accounting, not completion).
        assert_eq!(snapshots[0].simulated_cycles, 0);
        assert!(snapshots[0].predicted_cycles > 0);
        assert_eq!(snapshots[1].simulated_cycles, 0);
        assert_eq!(snapshots[1].predicted_cycles, snapshots[0].predicted_cycles);
    }

    #[test]
    fn prelowered_start_serves_identically() {
        let qm = QModel::synthetic(8, 4, 6, 0xE7);
        let sim = PipelineSim::new(qm.clone(), None).unwrap();
        let expect = sim.run(&[vec![1; 64]]).unwrap().outputs[0].clone();
        let server =
            Server::start_prelowered(sim, ServerConfig::default(), None).unwrap();
        let resp = server.infer(vec![1; 64]).unwrap();
        assert_eq!(resp.logits, expect);
        let m = server.shutdown();
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn engine_names_parse_case_insensitively() {
        assert_eq!(EngineKind::parse("interp"), Some(EngineKind::Interpreter));
        assert_eq!(
            EngineKind::parse("Interpreter"),
            Some(EngineKind::Interpreter)
        );
        assert_eq!(EngineKind::parse("COMPILED"), Some(EngineKind::Compiled));
        assert_eq!(EngineKind::parse("folded"), Some(EngineKind::Folded));
        assert_eq!(EngineKind::parse("Folded"), Some(EngineKind::Folded));
        assert_eq!(EngineKind::parse("gpu"), None);
    }

    #[test]
    fn multi_model_routes_requests_and_splits_metrics() {
        let qa = QModel::synthetic(8, 4, 6, 0xA);
        let qb = QModel::synthetic(12, 4, 5, 0xB);
        let sa = PipelineSim::new(qa, None).unwrap();
        let sb = PipelineSim::new(qb, None).unwrap();
        let ea = sa.run(&[vec![1; 64]]).unwrap().outputs[0].clone();
        let eb = sb.run(&[vec![2; 144]]).unwrap().outputs[0].clone();
        let config = ServerConfig {
            workers: 1,
            verify_every: 0,
            batch_deadline: Duration::from_millis(0),
            routes: vec![ModelRoute {
                model: "b".into(),
                workers: 2,
            }],
            ..Default::default()
        };
        let mut server = Server::start_multi(
            vec![("a".to_string(), sa), ("b".to_string(), sb)],
            config,
            None,
        )
        .unwrap();
        assert_eq!(server.models(), vec!["a".to_string(), "b".to_string()]);
        // Tagged requests reach their own model's pipeline, bit-exactly.
        assert_eq!(server.infer_to("a", vec![1; 64]).unwrap().logits, ea);
        assert_eq!(server.infer_to("b", vec![2; 144]).unwrap().logits, eb);
        // Untagged submits serve the first (default) group.
        assert_eq!(server.infer(vec![1; 64]).unwrap().logits, ea);
        // Unknown tags are refused and counted, never silently served.
        assert!(server.submit_to("nope", vec![0; 64]).is_err());
        server.drain();
        let m = server.metrics();
        assert_eq!(m.models, 2);
        assert_eq!(m.workers, 3, "route table: 1 shard for a + 2 for b");
        assert_eq!(m.completed, 3);
        assert_eq!(m.unrouted, 1);
        let per = server.model_metrics();
        assert_eq!(per.len(), 2);
        assert_eq!(per[0].model, "a");
        assert_eq!(per[0].metrics.completed, 2);
        assert_eq!(per[0].metrics.workers, 1);
        assert_eq!(per[1].model, "b");
        assert_eq!(per[1].metrics.completed, 1);
        assert_eq!(per[1].metrics.workers, 2);
        // Per-model counters reconcile with the aggregate exactly.
        assert_eq!(
            per.iter().map(|p| p.metrics.completed).sum::<u64>(),
            m.completed
        );
        assert_eq!(
            per.iter().map(|p| p.metrics.accepted).sum::<u64>(),
            m.accepted
        );
        // Per-model views report the server-global counters as 0.
        assert_eq!(per[0].metrics.unrouted, 0);
        let shards = server.shard_metrics();
        assert_eq!(shards.len(), 3);
        assert_eq!(shards[0].model, "a");
        assert!(shards[1..].iter().all(|s| s.model == "b"));
        assert_eq!(shards[1].shard, 1, "shard indices stay global");
    }

    #[test]
    fn multi_model_rejects_duplicates_and_empty() {
        let qm = QModel::synthetic(8, 4, 6, 0xD0);
        let s1 = PipelineSim::new(qm.clone(), None).unwrap();
        let s2 = PipelineSim::new(qm, None).unwrap();
        assert!(Server::start_multi(
            vec![("m".to_string(), s1), ("m".to_string(), s2)],
            ServerConfig::default(),
            None,
        )
        .is_err());
        assert!(Server::start_multi(Vec::new(), ServerConfig::default(), None).is_err());
    }

    #[test]
    fn dispatch_and_autoscale_specs_parse() {
        assert_eq!(DispatchKind::parse("Predictive"), Some(DispatchKind::Predictive));
        assert_eq!(DispatchKind::parse("least-loaded"), Some(DispatchKind::Predictive));
        assert_eq!(DispatchKind::parse("rr"), Some(DispatchKind::RoundRobin));
        assert_eq!(DispatchKind::parse("Round-Robin"), Some(DispatchKind::RoundRobin));
        assert_eq!(DispatchKind::parse("random"), None);

        assert_eq!(AutoscaleConfig::parse("off"), Some(None));
        assert_eq!(
            AutoscaleConfig::parse("on"),
            Some(Some(AutoscaleConfig::default()))
        );
        assert_eq!(
            AutoscaleConfig::parse("2:6"),
            Some(Some(AutoscaleConfig {
                min_workers: 2,
                max_workers: 6,
            }))
        );
        assert_eq!(AutoscaleConfig::parse("0:4"), None, "floor must be positive");
        assert_eq!(AutoscaleConfig::parse("4:2"), None, "inverted bounds");
        assert_eq!(AutoscaleConfig::parse("many"), None);
    }

    #[test]
    fn rr_cursor_cycles_exactly_at_wraparound() {
        // The old free-running `fetch_add % n` cursor visits shard 0
        // twice in a row at usize wraparound for any n that doesn't
        // divide 2^64 (usize::MAX % 3 == 0, then 0 % 3 == 0). The
        // reduced cursor keeps the cycle exact from any starting value.
        let rr = AtomicUsize::new(usize::MAX);
        let seq: Vec<usize> = (0..6).map(|_| rr_next(&rr, 3)).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2]);
        // A stale out-of-range cursor (autoscale shrank the group)
        // reduces mod the new n instead of indexing out of bounds.
        let rr = AtomicUsize::new(5);
        assert_eq!(rr_next(&rr, 2), 1);
        assert_eq!(rr_next(&rr, 2), 0);
        // n == 0 is clamped, never a divide-by-zero.
        let rr = AtomicUsize::new(0);
        assert_eq!(rr_next(&rr, 0), 0);
    }

    #[test]
    fn admission_sheds_unmeetable_deadlines_and_reports_met() {
        // clock_hz 1.0 makes a 1 us deadline a zero-cycle budget: no
        // shard can meet it, so admission must shed at submit time
        // (counted apart from backpressure) while a generous deadline is
        // admitted and echoed back with its prediction and verdict.
        let server = Server::start(
            tiny_qmodel(),
            ServerConfig {
                workers: 2,
                clock_hz: 1.0,
                verify_every: 0,
                batch_deadline: Duration::from_millis(0),
                dispatch: DispatchKind::Predictive,
                admission: true,
                autoscale: None,
                ..Default::default()
            },
            None,
        )
        .unwrap();
        let model = server.models()[0].clone();
        let err = server
            .submit_to_opts(
                &model,
                vec![1, 2, 3, 4],
                SubmitOpts {
                    deadline_us: 1,
                    class: 2,
                },
                None,
            )
            .unwrap_err();
        assert!(err.starts_with("slo miss"), "{err}");

        // 10^12 us at 1 Hz = 10^6 cycles of budget — comfortably met.
        let resp = server
            .submit_to_opts(
                &model,
                vec![1, 2, 3, 4],
                SubmitOpts {
                    deadline_us: 1_000_000_000_000,
                    class: 2,
                },
                None,
            )
            .unwrap()
            .wait()
            .unwrap();
        assert!(resp.slo_met, "generous deadline must be met");
        assert!(resp.predicted_cycles > 0, "prediction echoed to the client");

        // Deadline-free traffic bypasses the screen entirely.
        server.infer(vec![1, 2, 3, 4]).unwrap();

        let m = server.shutdown();
        assert_eq!(m.shed, 1);
        assert_eq!(m.rejected, 0, "shed is not backpressure");
        assert_eq!(m.accepted, 2);
        assert_eq!(m.completed, 2);
    }

    #[test]
    fn autoscale_starts_at_the_floor() {
        let server = Server::start(
            tiny_qmodel(),
            ServerConfig {
                workers: 4,
                verify_every: 0,
                autoscale: Some(AutoscaleConfig {
                    min_workers: 2,
                    max_workers: 4,
                }),
                ..Default::default()
            },
            None,
        )
        .unwrap();
        let m = server.metrics();
        assert_eq!(m.workers, 4, "every shard is spawned up front");
        assert_eq!(m.active_workers, 2, "dispatch starts at the floor");
        server.infer(vec![1, 2, 3, 4]).unwrap();
        let m = server.shutdown();
        assert_eq!(m.completed, 1);
        assert_eq!(m.scale_up_events, 0, "one idle request never grows");
    }

    #[test]
    fn autoscale_grows_under_backlog_and_shrinks_when_idle() {
        // Backlog-driven growth: a conv model is ~100x slower per frame
        // than a submit, so a 256-deep async burst onto the floor shard
        // must push the analytic backlog past one allowance
        // (max_batch × steady_cpf, since batch_deadline is ZERO) and
        // grow the active set. Shrink is then deterministic: serial
        // request-reply traffic evaluates the controller with zero
        // backlog on every submit, and SHRINK_IDLE_TICKS consecutive
        // such evaluations step the active set back down.
        let qm = QModel::synthetic(8, 4, 6, 0xE5);
        let server = Server::start(
            qm,
            ServerConfig {
                workers: 4,
                max_batch: 8,
                queue_depth: 512,
                verify_every: 0,
                batch_deadline: Duration::from_millis(0),
                dispatch: DispatchKind::Predictive,
                admission: false,
                autoscale: Some(AutoscaleConfig {
                    min_workers: 1,
                    max_workers: 4,
                }),
                ..Default::default()
            },
            None,
        )
        .unwrap();

        let pendings: Vec<Pending> = (0..256)
            .map(|_| server.submit(vec![1; 64]).unwrap())
            .collect();
        for p in pendings {
            p.wait().unwrap();
        }
        let m = server.metrics();
        let grown = m.active_workers;
        assert!(grown > 1, "256-deep backlog never grew the active set: {m:?}");
        assert_eq!(m.scale_up_events, grown as u64 - 1, "started at the floor of 1");
        assert_eq!(m.scale_down_events, 0, "burst evaluations are never idle");

        // > 2 × SHRINK_IDLE_TICKS zero-backlog evaluations: at least one
        // shrink step even straight from the ceiling.
        for _ in 0..(2 * SHRINK_IDLE_TICKS + 8) {
            server.infer(vec![1; 64]).unwrap();
        }
        let m = server.shutdown();
        assert!(m.scale_down_events >= 1, "idle run never shrank: {m:?}");
        assert!(m.active_workers < grown);
        assert!(m.active_workers >= 1, "shrink respects the floor");
        assert_eq!(m.completed, 256 + 2 * SHRINK_IDLE_TICKS as u64 + 8);
        assert_eq!(m.rejected, 0);
    }

    #[test]
    fn latency_quantiles_populated_and_ordered() {
        let server = Server::start(tiny_qmodel(), ServerConfig::default(), None).unwrap();
        for _ in 0..16 {
            server.infer(vec![1, 2, 3, 4]).unwrap();
        }
        let m = server.shutdown();
        assert!(m.p50 > Duration::ZERO);
        assert!(m.p50 <= m.p95 && m.p95 <= m.p99, "{m:?}");
    }
}
