//! Streaming inference coordinator (system S10) — the L3 serving layer.
//!
//! The paper's architecture is a continuous-flow pipeline: throughput is
//! maximised when frames stream back-to-back so no unit ever starves.
//! The coordinator therefore implements *data-rate-aware batching*: it
//! drains the request queue into contiguous frame groups and feeds each
//! group through the cycle-accurate pipeline as one uninterrupted stream,
//! which is exactly the condition under which the hardware would hit its
//! ~100% utilisation.
//!
//! Threads (std::thread — tokio is not vendored in this offline image):
//!
//! * callers block on [`Server::infer`] (bounded queue = backpressure);
//! * a batcher/worker thread drains the queue, runs the pipeline
//!   simulator, and answers;
//! * an optional verifier thread owns the PJRT runtime and cross-checks a
//!   sample of responses against the AOT-compiled JAX int8 golden model
//!   (never on the request path).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::quant::QModel;
use crate::sim::pipeline::PipelineSim;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Max frames per continuous-flow group.
    pub batch: usize,
    /// Bounded request queue depth (backpressure threshold).
    pub queue_depth: usize,
    /// Cross-check every n-th request against the PJRT golden model
    /// (0 = never).
    pub verify_every: usize,
    /// Modelled hardware clock, used to convert simulated cycles into
    /// projected hardware latency/throughput figures.
    pub clock_hz: f64,
    /// How long the batcher waits to fill a group before flushing.
    pub batch_window: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            batch: 16,
            queue_depth: 256,
            verify_every: 8,
            clock_hz: 600.0e6, // the paper's JSC designs close ~600 MHz
            batch_window: Duration::from_millis(1),
        }
    }
}

/// One inference answer.
#[derive(Debug, Clone)]
pub struct InferResponse {
    /// Final-layer accumulator-scale outputs.
    pub logits: Vec<i64>,
    pub argmax: usize,
    /// Simulated hardware cycles from frame entry to last output.
    pub sim_latency_cycles: u64,
    /// Wall-clock service time in the coordinator.
    pub service_time: Duration,
}

#[derive(Debug, Default)]
pub struct Metrics {
    pub accepted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub batches: AtomicU64,
    pub verified: AtomicU64,
    pub mismatches: AtomicU64,
    pub sim_cycles_total: AtomicU64,
    pub service_ns_total: AtomicU64,
}

/// A point-in-time view of the metrics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricsSnapshot {
    pub accepted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub batches: u64,
    pub verified: u64,
    pub mismatches: u64,
    pub mean_batch: f64,
    pub mean_service: Duration,
    /// Projected hardware throughput (frames/s at the configured clock).
    pub projected_fps: f64,
}

struct Request {
    x_q: Vec<i64>,
    enqueued: Instant,
    reply: SyncSender<Result<InferResponse, String>>,
}

enum Job {
    Infer(Request),
    Shutdown,
}

/// The running server.
pub struct Server {
    tx: SyncSender<Job>,
    metrics: Arc<Metrics>,
    worker: Option<std::thread::JoinHandle<()>>,
    verifier: Option<std::thread::JoinHandle<()>>,
    config: ServerConfig,
}

impl Server {
    /// Start a server over a quantized model. `verify_model` names an
    /// artifact bundle to load in the verifier thread (None = no
    /// verification, e.g. when artifacts are absent).
    pub fn start(
        qmodel: QModel,
        config: ServerConfig,
        verify_model: Option<String>,
    ) -> Result<Server, String> {
        let sim = PipelineSim::new(qmodel.clone(), None)?;
        let metrics = Arc::new(Metrics::default());
        let (tx, rx) = sync_channel::<Job>(config.queue_depth);

        // Verifier thread (owns the PJRT runtime end-to-end).
        let (vtx, vrx) = sync_channel::<(Vec<i64>, Vec<i64>)>(64);
        let verifier = verify_model.map(|name| {
            let vmetrics = Arc::clone(&metrics);
            std::thread::spawn(move || verifier_loop(&name, vrx, &vmetrics))
        });

        let wmetrics = Arc::clone(&metrics);
        let wconfig = config.clone();
        let worker = std::thread::spawn(move || {
            worker_loop(sim, wconfig, rx, vtx, &wmetrics);
        });
        Ok(Server {
            tx,
            metrics,
            worker: Some(worker),
            verifier,
            config,
        })
    }

    /// Blocking inference. Returns Err when the queue is saturated
    /// (backpressure) or the server is shutting down.
    pub fn infer(&self, x_q: Vec<i64>) -> Result<InferResponse, String> {
        let (rtx, rrx) = sync_channel(1);
        let req = Request {
            x_q,
            enqueued: Instant::now(),
            reply: rtx,
        };
        match self.tx.try_send(Job::Infer(req)) {
            Ok(()) => {
                self.metrics.accepted.fetch_add(1, Ordering::Relaxed);
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return Err("backpressure: request queue full".into());
            }
            Err(TrySendError::Disconnected(_)) => return Err("server stopped".into()),
        }
        rrx.recv().map_err(|_| "server dropped request".to_string())?
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        let m = &self.metrics;
        let completed = m.completed.load(Ordering::Relaxed);
        let batches = m.batches.load(Ordering::Relaxed).max(1);
        let service_ns = m.service_ns_total.load(Ordering::Relaxed);
        let cycles = m.sim_cycles_total.load(Ordering::Relaxed);
        MetricsSnapshot {
            accepted: m.accepted.load(Ordering::Relaxed),
            rejected: m.rejected.load(Ordering::Relaxed),
            completed,
            batches,
            verified: m.verified.load(Ordering::Relaxed),
            mismatches: m.mismatches.load(Ordering::Relaxed),
            mean_batch: completed as f64 / batches as f64,
            mean_service: Duration::from_nanos(if completed == 0 {
                0
            } else {
                service_ns / completed
            }),
            projected_fps: if cycles == 0 {
                0.0
            } else {
                completed as f64 / (cycles as f64 / self.config.clock_hz)
            },
        }
    }

    /// Graceful shutdown: drain, stop threads.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        if let Some(v) = self.verifier.take() {
            let _ = v.join();
        }
        self.metrics()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        // Verifier exits when its channel disconnects (worker dropped vtx).
        if let Some(v) = self.verifier.take() {
            let _ = v.join();
        }
    }
}

fn worker_loop(
    sim: PipelineSim,
    config: ServerConfig,
    rx: Receiver<Job>,
    vtx: SyncSender<(Vec<i64>, Vec<i64>)>,
    metrics: &Metrics,
) {
    let mut serial: u64 = 0;
    loop {
        // Block for the first request, then drain up to `batch` within the
        // batching window — contiguous frames = continuous flow.
        let first = match rx.recv() {
            Ok(Job::Infer(r)) => r,
            Ok(Job::Shutdown) | Err(_) => return,
        };
        let mut group = vec![first];
        let deadline = Instant::now() + config.batch_window;
        while group.len() < config.batch {
            let left = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(left) {
                Ok(Job::Infer(r)) => group.push(r),
                Ok(Job::Shutdown) => break,
                Err(_) => break,
            }
        }
        let frames: Vec<Vec<i64>> = group.iter().map(|r| r.x_q.clone()).collect();
        let started = Instant::now();
        match sim.run(&frames) {
            Ok(result) => {
                metrics.batches.fetch_add(1, Ordering::Relaxed);
                let per_frame_cycles = result.cycles_per_frame.max(1.0) as u64;
                for (req, logits) in group.into_iter().zip(result.outputs.into_iter()) {
                    serial += 1;
                    let argmax = logits
                        .iter()
                        .enumerate()
                        .max_by_key(|(_, v)| **v)
                        .map(|(i, _)| i)
                        .unwrap_or(0);
                    let resp = InferResponse {
                        logits: logits.clone(),
                        argmax,
                        sim_latency_cycles: result.first_frame_latency,
                        service_time: req.enqueued.elapsed(),
                    };
                    metrics.completed.fetch_add(1, Ordering::Relaxed);
                    metrics
                        .sim_cycles_total
                        .fetch_add(per_frame_cycles, Ordering::Relaxed);
                    metrics.service_ns_total.fetch_add(
                        started.elapsed().as_nanos() as u64,
                        Ordering::Relaxed,
                    );
                    if config.verify_every > 0 && serial % config.verify_every as u64 == 0 {
                        // Sampled golden check; drop silently if the
                        // verifier is busy (never blocks serving).
                        let _ = vtx.try_send((req.x_q.clone(), logits.clone()));
                    }
                    let _ = req.reply.send(Ok(resp));
                }
            }
            Err(e) => {
                for req in group {
                    let _ = req.reply.send(Err(e.clone()));
                }
            }
        }
    }
}

fn verifier_loop(
    model_name: &str,
    vrx: Receiver<(Vec<i64>, Vec<i64>)>,
    metrics: &Metrics,
) {
    let rt = match crate::runtime::Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("verifier disabled: {e}");
            return;
        }
    };
    let bundle = match crate::runtime::ModelBundle::load(&rt, model_name) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("verifier disabled: {e}");
            return;
        }
    };
    while let Ok((x_q, logits)) = vrx.recv() {
        let xf: Vec<f32> = x_q.iter().map(|&v| v as f32).collect();
        match bundle.golden.run_f32(&xf) {
            Ok(y) => {
                let y_i: Vec<i64> = y.iter().map(|&v| v as i64).collect();
                metrics.verified.fetch_add(1, Ordering::Relaxed);
                if y_i != logits {
                    metrics.mismatches.fetch_add(1, Ordering::Relaxed);
                    eprintln!("GOLDEN MISMATCH: sim {logits:?} != pjrt {y_i:?}");
                }
            }
            Err(e) => eprintln!("verifier execute error: {e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{QKind, QLayer};
    use crate::util::Rng;

    fn tiny_qmodel() -> QModel {
        // Single dense layer 4 -> 3, accumulator out.
        QModel {
            name: "t".into(),
            input_shape: [1, 1, 4],
            input_scale: 1.0,
            layers: vec![QLayer {
                name: "d".into(),
                kind: QKind::Dense,
                k: 0,
                s: 1,
                p: 0,
                relu: false,
                w_q: vec![1, 0, 0, 0, 0, 1, 0, 0, 0, 0, 1, 1],
                w_shape: vec![3, 4],
                b_q: vec![0, 0, 0],
                m: 0.0,
                in_shape: [1, 1, 4],
                out_shape: [1, 1, 3],
            }],
            test_vectors: vec![],
            qat_accuracy: 1.0,
        }
    }

    #[test]
    fn serve_and_answer() {
        let server = Server::start(tiny_qmodel(), ServerConfig::default(), None).unwrap();
        let resp = server.infer(vec![5, -3, 7, 2]).unwrap();
        assert_eq!(resp.logits, vec![5, -3, 9]);
        assert_eq!(resp.argmax, 2);
        let m = server.shutdown();
        assert_eq!(m.completed, 1);
        assert_eq!(m.mismatches, 0);
    }

    #[test]
    fn concurrent_clients_all_served() {
        let server = Arc::new(
            Server::start(tiny_qmodel(), ServerConfig::default(), None).unwrap(),
        );
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let s = Arc::clone(&server);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(t);
                for _ in 0..20 {
                    let x: Vec<i64> = (0..4).map(|_| rng.int8() as i64).collect();
                    let expect = vec![x[0], x[1], x[2] + x[3]];
                    match s.infer(x) {
                        Ok(r) => assert_eq!(r.logits, expect),
                        Err(e) => assert!(e.contains("backpressure"), "{e}"),
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = server.metrics();
        assert!(m.completed + m.rejected >= 160);
        assert_eq!(m.completed, m.accepted);
    }

    #[test]
    fn batching_groups_requests() {
        let config = ServerConfig {
            batch: 8,
            batch_window: Duration::from_millis(20),
            ..Default::default()
        };
        let server = Arc::new(Server::start(tiny_qmodel(), config, None).unwrap());
        let mut handles = Vec::new();
        for _ in 0..16 {
            let s = Arc::clone(&server);
            handles.push(std::thread::spawn(move || s.infer(vec![1, 2, 3, 4]).unwrap()));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = server.metrics();
        assert_eq!(m.completed, 16);
        assert!(
            m.mean_batch > 1.0,
            "expected batching, mean batch {}",
            m.mean_batch
        );
    }

    #[test]
    fn backpressure_rejects_when_saturated() {
        // Queue depth 1 and a slow drain: the burst must see rejections
        // rather than unbounded queueing.
        let config = ServerConfig {
            batch: 1,
            queue_depth: 1,
            batch_window: Duration::from_millis(0),
            ..Default::default()
        };
        let server = Arc::new(Server::start(tiny_qmodel(), config, None).unwrap());
        let mut rejected = 0;
        let mut handles = Vec::new();
        for _ in 0..32 {
            let s = Arc::clone(&server);
            handles.push(std::thread::spawn(move || s.infer(vec![0, 0, 0, 0]).is_err()));
        }
        for h in handles {
            if h.join().unwrap() {
                rejected += 1;
            }
        }
        let m = server.metrics();
        assert_eq!(m.rejected as usize, rejected);
        assert_eq!(m.accepted + m.rejected, 32);
    }

    #[test]
    fn projected_fps_positive() {
        let server = Server::start(tiny_qmodel(), ServerConfig::default(), None).unwrap();
        for _ in 0..4 {
            server.infer(vec![1, 1, 1, 1]).unwrap();
        }
        let m = server.shutdown();
        assert!(m.projected_fps > 0.0);
    }
}
