//! Serving metrics: lock-free counters plus log2-bucketed latency
//! histograms and per-batch occupancy/flush accounting, kept per shard
//! and merged into one aggregate snapshot.
//!
//! Shards never share cache lines for their hot counters (each shard owns
//! its own `ShardMetrics` allocation), and the request path only ever does
//! relaxed `fetch_add`s — snapshotting pays the merge cost instead.
//!
//! The batch accounting reconciles exactly (DESIGN.md §6, pinned by
//! `tests/coordinator_scaling.rs`): `occupancy_frames` equals
//! `completed + errored`, the flush-reason counters sum to `batches`, and
//! so do the occupancy histogram's buckets — including the partial batch
//! a drain-on-shutdown flushes.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::json::Json;

/// Number of log2 latency buckets: bucket `i` covers `[2^i, 2^(i+1))`
/// nanoseconds, so 40 buckets span 1 ns .. ~18 minutes.
pub const BUCKETS: usize = 40;

/// Exact batch-occupancy buckets: bucket `i` counts batches of exactly
/// `i + 1` frames, for batch sizes 1 ..= [`OCC_BUCKETS`].
pub const OCC_BUCKETS: usize = 32;

/// Histogram slots: the exact buckets plus one explicit overflow bucket
/// (index [`OCC_BUCKETS`]) for batches larger than [`OCC_BUCKETS`]
/// frames. Larger `--max-batch` configurations used to fold oversized
/// batches into the last *exact* bucket, silently mislabelling them as
/// size-32 batches; the dedicated slot keeps every exact bucket honest
/// while preserving `sum(buckets) == batches`.
pub const OCC_SLOTS: usize = OCC_BUCKETS + 1;

/// A lock-free batch-size histogram.
pub struct OccupancyHistogram {
    buckets: [AtomicU64; OCC_SLOTS],
}

impl OccupancyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Record one batch of `frames` frames (empty batches never flush).
    /// Sizes above [`OCC_BUCKETS`] land in the overflow slot, so every
    /// batch lands in exactly one bucket.
    pub fn record(&self, frames: usize) {
        let idx = if frames > OCC_BUCKETS {
            OCC_BUCKETS
        } else {
            frames.max(1) - 1
        };
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Point-in-time bucket counts (for merging across shards); the last
    /// entry is the overflow slot.
    pub fn counts(&self) -> [u64; OCC_SLOTS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }
}

impl Default for OccupancyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Saturate a [`Duration`]'s nanosecond count into `u64`. `as_nanos()`
/// is `u128`, and the old `as u64` narrowing aliased durations beyond
/// ~584 years (clock anomalies, requests parked across a suspend) onto
/// small values — every counter and JSON field now clamps to
/// `u64::MAX` instead.
pub fn saturating_nanos(d: Duration) -> u64 {
    u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
}

/// A lock-free log2 latency histogram.
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
}

fn bucket_index(ns: u64) -> usize {
    (63 - ns.max(1).leading_zeros() as usize).min(BUCKETS - 1)
}

/// Upper edge (ns) of bucket `i`; quantiles report this bound, so they are
/// conservative within a factor of two — adequate for p50/p95/p99 triage.
fn bucket_upper_ns(i: usize) -> u64 {
    if i + 1 >= 64 {
        u64::MAX
    } else {
        (1u64 << (i + 1)) - 1
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    pub fn record(&self, d: Duration) {
        self.buckets[bucket_index(saturating_nanos(d))].fetch_add(1, Ordering::Relaxed);
    }

    /// Point-in-time bucket counts (for merging across shards).
    pub fn counts(&self) -> [u64; BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    pub fn quantile(&self, q: f64) -> Duration {
        quantile(&self.counts(), q)
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Quantile over (possibly merged) bucket counts.
pub fn quantile(counts: &[u64; BUCKETS], q: f64) -> Duration {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return Duration::ZERO;
    }
    let target = ((total as f64 * q).ceil() as u64).clamp(1, total);
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        cum += c;
        if cum >= target {
            return Duration::from_nanos(bucket_upper_ns(i));
        }
    }
    Duration::from_nanos(bucket_upper_ns(BUCKETS - 1))
}

/// Server-global counters: the golden verifier's tallies plus requests
/// refused because no route matched their model tag. Intake counters
/// (accepted/rejected/spilled) live per model group in
/// [`IntakeMetrics`] since the multi-model split.
#[derive(Debug, Default)]
pub struct Metrics {
    pub verified: AtomicU64,
    pub mismatches: AtomicU64,
    /// Tagged submissions naming a model the server has no route for.
    pub unrouted: AtomicU64,
}

/// Per-model-group intake counters (one instance per shard group).
#[derive(Debug, Default)]
pub struct IntakeMetrics {
    pub accepted: AtomicU64,
    pub rejected: AtomicU64,
    /// Requests placed on a shard other than their dispatch-order
    /// preference (backpressure-aware spill, always within the model's
    /// own group).
    pub spilled: AtomicU64,
    /// Deadline-bearing requests shed by admission control: every live
    /// candidate shard's predicted completion exceeded the deadline
    /// budget (DESIGN.md §12). Reconciles with the net layer's
    /// `err_slo_miss`.
    pub shed: AtomicU64,
    /// Autoscale grow events (active shard count incremented).
    pub scale_up: AtomicU64,
    /// Autoscale shrink events (active shard count decremented).
    pub scale_down: AtomicU64,
}

/// Per-shard serving counters, owned by exactly one worker thread.
#[derive(Default)]
pub struct ShardMetrics {
    /// Requests accepted onto this shard's queue and not yet answered
    /// (a gauge, not a counter: the submit path increments, the worker
    /// decrements per answer). `queued × steady_cycles_per_frame` is the
    /// shard's analytic backlog — the admission/dispatch/autoscale
    /// denominator of DESIGN.md §12.
    pub queued: AtomicU64,
    pub completed: AtomicU64,
    pub batches: AtomicU64,
    /// Steady-state modelled cycles attributed per frame (throughput) by
    /// whichever engine the shard runs.
    pub sim_cycles_total: AtomicU64,
    /// Modelled cycles this shard's pipeline spent occupied by frame
    /// groups; the max across shards is the simulated makespan, from which
    /// the aggregate projected throughput follows.
    pub busy_cycles: AtomicU64,
    /// Closed-form `SchedulePrediction` cycles for the served groups
    /// (always recorded, whichever engine runs).
    pub predicted_cycles: AtomicU64,
    /// Cycle-exact interpreter cycles for the served groups (recorded
    /// only on the `Interpreter` engine).
    pub simulated_cycles: AtomicU64,
    /// Groups where the closed-form prediction disagreed with the
    /// interpreter's cycle count (must stay 0; interpreter engine only).
    pub cycle_divergence: AtomicU64,
    pub service_ns_total: AtomicU64,
    pub latency: Histogram,
    /// Requests answered with an error (malformed frames); grouped frames
    /// reconcile as `occupancy_frames == completed + errored`.
    pub errored: AtomicU64,
    /// Total frames over all recorded batch occupancies.
    pub occupancy_frames: AtomicU64,
    /// Batches flushed because they reached `max_batch`.
    pub flush_full: AtomicU64,
    /// Batches flushed by the `batch_deadline` expiring.
    pub flush_deadline: AtomicU64,
    /// Batches flushed by shutdown/disconnect drains (incl. the final
    /// partial batch).
    pub flush_drain: AtomicU64,
    /// Batch-size distribution.
    pub occupancy: OccupancyHistogram,
}

/// A point-in-time view of one shard.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardSnapshot {
    pub shard: usize,
    /// Model id this shard serves (its group's route key; filled in by
    /// `Server::shard_metrics`).
    pub model: String,
    /// In-flight requests on this shard's queue at snapshot time.
    pub queued: u64,
    pub completed: u64,
    pub batches: u64,
    pub busy_cycles: u64,
    pub mean_batch: f64,
    /// Frames summed over this shard's batch occupancies
    /// (= completed + errored).
    pub occupancy_frames: u64,
    pub flush_full: u64,
    pub flush_deadline: u64,
    pub flush_drain: u64,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
}

impl ShardMetrics {
    pub fn snapshot(&self, shard: usize) -> ShardSnapshot {
        let completed = self.completed.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        ShardSnapshot {
            shard,
            model: String::new(),
            queued: self.queued.load(Ordering::Relaxed),
            completed,
            batches,
            busy_cycles: self.busy_cycles.load(Ordering::Relaxed),
            mean_batch: completed as f64 / batches.max(1) as f64,
            occupancy_frames: self.occupancy_frames.load(Ordering::Relaxed),
            flush_full: self.flush_full.load(Ordering::Relaxed),
            flush_deadline: self.flush_deadline.load(Ordering::Relaxed),
            flush_drain: self.flush_drain.load(Ordering::Relaxed),
            p50: self.latency.quantile(0.50),
            p95: self.latency.quantile(0.95),
            p99: self.latency.quantile(0.99),
        }
    }
}

/// A point-in-time view of the whole server (all shards merged), or —
/// via `Server::model_metrics` — of one model's shard group.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricsSnapshot {
    pub workers: usize,
    /// Shards currently admitted by dispatch, summed over groups. Equals
    /// `workers` without autoscaling; with it, the controller's current
    /// position within its bounds.
    pub active_workers: usize,
    /// Model groups covered by this snapshot (1 for a per-model view).
    pub models: usize,
    pub accepted: u64,
    pub rejected: u64,
    /// Requests shed by deadline admission control (see
    /// [`IntakeMetrics::shed`]). Intake partitions exactly:
    /// `submitted == accepted + rejected + shed` (+ `unrouted`
    /// server-globally).
    pub shed: u64,
    /// Autoscale grow/shrink events summed over groups.
    pub scale_up_events: u64,
    pub scale_down_events: u64,
    pub spilled: u64,
    /// Tagged submissions naming an unknown model (server-global; 0 in
    /// per-model views).
    pub unrouted: u64,
    pub completed: u64,
    pub batches: u64,
    pub verified: u64,
    pub mismatches: u64,
    /// Closed-form predicted cycles across all served groups.
    pub predicted_cycles: u64,
    /// Interpreter-measured cycles (0 unless the engine is `Interpreter`;
    /// when populated, equal to `predicted_cycles` unless the analytic
    /// schedule diverged).
    pub simulated_cycles: u64,
    /// Groups where prediction != interpreter cycles (must stay 0).
    pub cycle_divergence: u64,
    /// Requests answered with an error (malformed frames).
    pub errored: u64,
    /// Frames summed over all batch occupancies (= completed + errored).
    pub occupancy_frames: u64,
    /// Batches flushed full / by deadline / by drain; the three sum to
    /// `batches`.
    pub flush_full: u64,
    pub flush_deadline: u64,
    pub flush_drain: u64,
    /// Merged batch-occupancy histogram: bucket `i` counts batches of
    /// exactly `i + 1` frames; the final slot (index [`OCC_BUCKETS`]) is
    /// the overflow bucket for batches larger than [`OCC_BUCKETS`]
    /// frames. The slots always sum to `batches`.
    pub batch_occupancy: [u64; OCC_SLOTS],
    pub mean_batch: f64,
    /// Mean wall-clock time from enqueue to answer.
    pub mean_service: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    /// Projected hardware throughput of ONE pipeline at the configured
    /// clock (frames/s from mean steady-state cycles/frame).
    pub projected_fps: f64,
    /// Projected throughput of the sharded deployment: completed frames
    /// over the simulated makespan (max busy cycles across shards) — this
    /// is the number that scales with the worker count.
    pub aggregate_fps: f64,
}

impl MetricsSnapshot {
    /// Machine-readable export via `util::json`: counters as **exact**
    /// integers ([`Json::UInt`] — the cycle accumulators overflow f64's
    /// 2^53 integer range on long sessions, so counters never pass
    /// through a float), durations in nanoseconds, the occupancy
    /// histogram as an array.
    pub fn to_json(&self) -> Json {
        let ns = |d: Duration| Json::from(saturating_nanos(d));
        Json::obj(vec![
            ("workers", Json::from(self.workers)),
            ("active_workers", Json::from(self.active_workers)),
            ("models", Json::from(self.models)),
            ("accepted", Json::from(self.accepted)),
            ("rejected", Json::from(self.rejected)),
            ("shed", Json::from(self.shed)),
            ("scale_up_events", Json::from(self.scale_up_events)),
            ("scale_down_events", Json::from(self.scale_down_events)),
            ("spilled", Json::from(self.spilled)),
            ("unrouted", Json::from(self.unrouted)),
            ("completed", Json::from(self.completed)),
            ("batches", Json::from(self.batches)),
            ("verified", Json::from(self.verified)),
            ("mismatches", Json::from(self.mismatches)),
            ("predicted_cycles", Json::from(self.predicted_cycles)),
            ("simulated_cycles", Json::from(self.simulated_cycles)),
            ("cycle_divergence", Json::from(self.cycle_divergence)),
            ("errored", Json::from(self.errored)),
            ("occupancy_frames", Json::from(self.occupancy_frames)),
            ("flush_full", Json::from(self.flush_full)),
            ("flush_deadline", Json::from(self.flush_deadline)),
            ("flush_drain", Json::from(self.flush_drain)),
            (
                "batch_occupancy",
                Json::arr_u64(&self.batch_occupancy),
            ),
            ("mean_batch", Json::from(self.mean_batch)),
            ("mean_service_ns", ns(self.mean_service)),
            ("p50_ns", ns(self.p50)),
            ("p95_ns", ns(self.p95)),
            ("p99_ns", ns(self.p99)),
            ("projected_fps", Json::from(self.projected_fps)),
            ("aggregate_fps", Json::from(self.aggregate_fps)),
        ])
    }
}

/// One model's metrics view: the group's route key plus a
/// [`MetricsSnapshot`] restricted to that group's intake and shards
/// (DESIGN.md §7 — per-model and aggregate views reconcile exactly:
/// summing per-model counters over all models reproduces the aggregate,
/// except the server-global verifier/unrouted counters, which per-model
/// views report as 0).
#[derive(Debug, Clone)]
pub struct ModelMetricsSnapshot {
    pub model: String,
    pub metrics: MetricsSnapshot,
}

impl ModelMetricsSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::from(self.model.as_str())),
            ("metrics", self.metrics.to_json()),
        ])
    }
}

/// TCP front-end counters (`net::server::NetServer`): per-connection
/// bookkeeping plus one protocol-error tally per [`ErrorCode`] — each
/// code reconciling with exactly one coordinator counter (DESIGN.md §8,
/// pinned by `tests/net_serving.rs`):
///
/// * `responses_ok` ↔ shard `completed` (when the front-end is the only
///   intake);
/// * `err_queue_full` ↔ intake `rejected`;
/// * `err_slo_miss` ↔ intake `shed` (deadline admission control,
///   DESIGN.md §12);
/// * `err_unknown_model` ↔ [`Metrics::unrouted`];
/// * `err_invalid_frame` ↔ shard `errored`;
/// * `err_draining` — refused at the net layer or by a closed intake
///   (no coordinator counter moves), plus the rare accepted request
///   whose reply was lost to a drain race (`server dropped request`);
/// * `err_malformed` — wire-level violations that never became decoded
///   requests, excluded from the `requests` balance below.
///
/// Once drained, `requests == responses_ok + err_queue_full +
/// err_slo_miss + err_invalid_frame + err_unknown_model + err_draining`.
///
/// [`ErrorCode`]: crate::net::proto::ErrorCode
#[derive(Debug, Default)]
pub struct NetMetrics {
    /// Connections accepted.
    pub connections: AtomicU64,
    /// Connections fully torn down (reader EOF + writer drained).
    pub disconnects: AtomicU64,
    /// Decoded `InferRequest` messages.
    pub requests: AtomicU64,
    pub responses_ok: AtomicU64,
    pub err_queue_full: AtomicU64,
    /// Requests shed by deadline admission control
    /// ([`crate::net::proto::ErrorCode::SloMiss`]).
    pub err_slo_miss: AtomicU64,
    pub err_invalid_frame: AtomicU64,
    pub err_unknown_model: AtomicU64,
    pub err_draining: AtomicU64,
    pub err_malformed: AtomicU64,
}

impl NetMetrics {
    pub fn snapshot(&self) -> NetMetricsSnapshot {
        NetMetricsSnapshot {
            connections: self.connections.load(Ordering::Relaxed),
            disconnects: self.disconnects.load(Ordering::Relaxed),
            requests: self.requests.load(Ordering::Relaxed),
            responses_ok: self.responses_ok.load(Ordering::Relaxed),
            err_queue_full: self.err_queue_full.load(Ordering::Relaxed),
            err_slo_miss: self.err_slo_miss.load(Ordering::Relaxed),
            err_invalid_frame: self.err_invalid_frame.load(Ordering::Relaxed),
            err_unknown_model: self.err_unknown_model.load(Ordering::Relaxed),
            err_draining: self.err_draining.load(Ordering::Relaxed),
            err_malformed: self.err_malformed.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time view of [`NetMetrics`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NetMetricsSnapshot {
    pub connections: u64,
    pub disconnects: u64,
    pub requests: u64,
    pub responses_ok: u64,
    pub err_queue_full: u64,
    pub err_slo_miss: u64,
    pub err_invalid_frame: u64,
    pub err_unknown_model: u64,
    pub err_draining: u64,
    pub err_malformed: u64,
}

impl NetMetricsSnapshot {
    /// Protocol errors answered to decoded requests (everything except
    /// `err_malformed`, which never became a request).
    pub fn errors_total(&self) -> u64 {
        self.err_queue_full
            + self.err_slo_miss
            + self.err_invalid_frame
            + self.err_unknown_model
            + self.err_draining
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("connections", Json::from(self.connections)),
            ("disconnects", Json::from(self.disconnects)),
            ("requests", Json::from(self.requests)),
            ("responses_ok", Json::from(self.responses_ok)),
            ("err_queue_full", Json::from(self.err_queue_full)),
            ("err_slo_miss", Json::from(self.err_slo_miss)),
            ("err_invalid_frame", Json::from(self.err_invalid_frame)),
            ("err_unknown_model", Json::from(self.err_unknown_model)),
            ("err_draining", Json::from(self.err_draining)),
            ("err_malformed", Json::from(self.err_malformed)),
        ])
    }
}

/// Readiness-loop counters for the evented network core (DESIGN.md
/// §10). Kept **separate** from [`NetMetrics`] on purpose: every core
/// (threaded or evented) owns its own `NetMetrics`, and the
/// cross-core differential tests compare those snapshots for exact
/// equality — reactor-only counters would never reconcile against a
/// thread-per-connection oracle, so they live here instead.
#[derive(Debug, Default)]
pub struct ReactorStats {
    /// Times the poller returned with at least one event.
    pub polls: AtomicU64,
    /// Readiness events dispatched (listener + waker + connections).
    pub events: AtomicU64,
    /// Waker fires observed (shutdown signals + completion batches).
    pub wakeups: AtomicU64,
    /// Replies settled through the completion queue (worker notify →
    /// waker → `Pending::try_wait`), as opposed to settled inline.
    pub completions: AtomicU64,
    /// Times a connection's read interest was paused because its reply
    /// queue hit the configured depth (per-connection backpressure).
    pub read_pauses: AtomicU64,
    /// Connections torn down by the write-stall timeout (non-reading
    /// clients with a full write buffer).
    pub stall_teardowns: AtomicU64,
}

impl ReactorStats {
    pub fn snapshot(&self) -> ReactorStatsSnapshot {
        ReactorStatsSnapshot {
            polls: self.polls.load(Ordering::Relaxed),
            events: self.events.load(Ordering::Relaxed),
            wakeups: self.wakeups.load(Ordering::Relaxed),
            completions: self.completions.load(Ordering::Relaxed),
            read_pauses: self.read_pauses.load(Ordering::Relaxed),
            stall_teardowns: self.stall_teardowns.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time view of [`ReactorStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReactorStatsSnapshot {
    pub polls: u64,
    pub events: u64,
    pub wakeups: u64,
    pub completions: u64,
    pub read_pauses: u64,
    pub stall_teardowns: u64,
}

impl ReactorStatsSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("polls", Json::from(self.polls)),
            ("events", Json::from(self.events)),
            ("wakeups", Json::from(self.wakeups)),
            ("completions", Json::from(self.completions)),
            ("read_pauses", Json::from(self.read_pauses)),
            ("stall_teardowns", Json::from(self.stall_teardowns)),
        ])
    }
}

/// Schema version stamped on every [`metrics_report_json`] report.
/// Bump whenever a field is renamed, removed, or changes meaning —
/// additive fields don't require a bump. Consumers (dashboards, the
/// periodic `--metrics-interval` flush readers) key on this instead of
/// sniffing field shapes.
pub const METRICS_SCHEMA_VERSION: u64 = 1;

/// The full machine-readable metrics report `serve --metrics-json`
/// writes (at shutdown, and periodically under `--metrics-interval`):
/// the schema version, the aggregate snapshot, the per-model views, and
/// (when the TCP front-end ran) the net-layer counters.
pub fn metrics_report_json(
    aggregate: &MetricsSnapshot,
    per_model: &[ModelMetricsSnapshot],
    net: Option<&NetMetricsSnapshot>,
) -> Json {
    let mut pairs = vec![
        ("schema_version", Json::from(METRICS_SCHEMA_VERSION)),
        ("aggregate", aggregate.to_json()),
        (
            "models",
            Json::Arr(per_model.iter().map(|m| m.to_json()).collect()),
        ),
    ];
    if let Some(n) = net {
        pairs.push(("net", n.to_json()));
    }
    Json::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_quantiles_are_zero() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), Duration::ZERO);
        assert_eq!(h.quantile(0.99), Duration::ZERO);
    }

    #[test]
    fn quantiles_are_ordered_and_bracket_samples() {
        let h = Histogram::new();
        for us in [1u64, 2, 4, 100, 100, 100, 100, 5000] {
            h.record(Duration::from_micros(us));
        }
        let (p50, p95, p99) = (h.quantile(0.5), h.quantile(0.95), h.quantile(0.99));
        assert!(p50 <= p95 && p95 <= p99, "{p50:?} {p95:?} {p99:?}");
        // p50 lands in the 100us bucket (upper edge < 2x the sample).
        assert!(p50 >= Duration::from_micros(100));
        assert!(p50 < Duration::from_micros(200));
        // p99 lands in the 5ms bucket.
        assert!(p99 >= Duration::from_micros(5000));
        assert!(p99 < Duration::from_micros(10000));
    }

    #[test]
    fn merged_quantile_matches_union() {
        let a = Histogram::new();
        let b = Histogram::new();
        for _ in 0..9 {
            a.record(Duration::from_nanos(100));
        }
        b.record(Duration::from_millis(1));
        let mut merged = a.counts();
        for (m, v) in merged.iter_mut().zip(b.counts().iter()) {
            *m += v;
        }
        // 9 fast + 1 slow: p50 fast, p99 slow.
        assert!(quantile(&merged, 0.5) < Duration::from_micros(1));
        assert!(quantile(&merged, 0.99) >= Duration::from_millis(1));
    }

    #[test]
    fn occupancy_histogram_buckets_exact_sizes() {
        let h = OccupancyHistogram::new();
        h.record(1);
        h.record(1);
        h.record(4);
        h.record(OCC_BUCKETS); // last exact bucket
        h.record(OCC_BUCKETS + 9); // overflow gets its own slot
        let c = h.counts();
        assert_eq!(c[0], 2);
        assert_eq!(c[3], 1);
        assert_eq!(c[OCC_BUCKETS - 1], 1, "exact bucket holds only size-32");
        assert_eq!(c[OCC_BUCKETS], 1, "oversized batch lands in overflow");
        assert_eq!(c.iter().sum::<u64>(), 5, "every batch lands in a bucket");
    }

    #[test]
    fn occupancy_histogram_overflow_preserves_sum_at_max_batch_64() {
        // A --max-batch 64 deployment flushes batches of every size up to
        // 64: each exact size keeps its own bucket, everything above
        // OCC_BUCKETS shares the overflow slot, and the bucket sum still
        // equals the number of recorded batches.
        let h = OccupancyHistogram::new();
        let max_batch = 64usize;
        for frames in 1..=max_batch {
            h.record(frames);
        }
        let c = h.counts();
        for (i, &n) in c[..OCC_BUCKETS].iter().enumerate() {
            assert_eq!(n, 1, "exact bucket {i} counts its own size only");
        }
        assert_eq!(c[OCC_BUCKETS], (max_batch - OCC_BUCKETS) as u64);
        assert_eq!(c.iter().sum::<u64>(), max_batch as u64);
    }

    fn sample_snapshot() -> MetricsSnapshot {
        MetricsSnapshot {
            workers: 2,
            active_workers: 2,
            models: 1,
            accepted: 10,
            rejected: 1,
            shed: 0,
            scale_up_events: 0,
            scale_down_events: 0,
            spilled: 0,
            unrouted: 2,
            completed: 9,
            batches: 3,
            verified: 0,
            mismatches: 0,
            predicted_cycles: 1234,
            simulated_cycles: 0,
            cycle_divergence: 0,
            errored: 1,
            occupancy_frames: 10,
            flush_full: 1,
            flush_deadline: 1,
            flush_drain: 1,
            batch_occupancy: [0; OCC_SLOTS],
            mean_batch: 3.3,
            mean_service: Duration::from_micros(5),
            p50: Duration::from_micros(4),
            p95: Duration::from_micros(8),
            p99: Duration::from_micros(9),
            projected_fps: 1.0e6,
            aggregate_fps: 2.0e6,
        }
    }

    #[test]
    fn snapshot_json_roundtrips_through_parser() {
        let snap = sample_snapshot();
        let text = snap.to_json().render_pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("completed").as_usize(), Some(9));
        assert_eq!(parsed.get("rejected").as_usize(), Some(1));
        assert_eq!(parsed.get("p99_ns").as_usize(), Some(9000));
        assert_eq!(
            parsed.get("batch_occupancy").as_arr().unwrap().len(),
            OCC_SLOTS
        );
    }

    #[test]
    fn counters_above_2_pow_53_survive_json_exactly() {
        // The old serialization went through `as f64`, which aliases
        // integers above 2^53: (2^53 + 1) as f64 == 2^53. Cycle
        // accumulators reach that range on long sessions, so the report
        // must round-trip them exactly.
        let big = (1u64 << 53) + 1;
        assert_ne!((big as f64) as u64, big, "f64 would alias this value");
        let mut snap = sample_snapshot();
        snap.predicted_cycles = big;
        snap.simulated_cycles = u64::MAX;
        snap.accepted = u64::MAX - 1;
        let parsed = Json::parse(&snap.to_json().render_pretty()).unwrap();
        assert_eq!(parsed.get("predicted_cycles").as_u64(), Some(big));
        assert_eq!(parsed.get("simulated_cycles").as_u64(), Some(u64::MAX));
        assert_eq!(parsed.get("accepted").as_u64(), Some(u64::MAX - 1));

        let net = NetMetrics::default();
        net.requests.fetch_add(big, Ordering::Relaxed);
        let nparsed = Json::parse(&net.snapshot().to_json().render()).unwrap();
        assert_eq!(nparsed.get("requests").as_u64(), Some(big));
    }

    #[test]
    fn nanos_narrowing_saturates_at_the_u64_boundary() {
        // Everything up to u64::MAX nanoseconds converts exactly...
        assert_eq!(saturating_nanos(Duration::ZERO), 0);
        assert_eq!(saturating_nanos(Duration::from_nanos(u64::MAX)), u64::MAX);
        // ...and one nanosecond past the boundary clamps instead of
        // aliasing small the way the old `as u64` narrowing did.
        let over = Duration::from_nanos(u64::MAX) + Duration::from_nanos(1);
        assert!(over.as_nanos() > u64::MAX as u128);
        assert_eq!(saturating_nanos(over), u64::MAX);
        assert_eq!(over.as_nanos() as u64, 0, "the bug this replaces");
        assert_eq!(saturating_nanos(Duration::MAX), u64::MAX);
    }

    #[test]
    fn metrics_report_includes_models_and_net() {
        let snap = sample_snapshot();
        let per = vec![ModelMetricsSnapshot {
            model: "digits_cnn".into(),
            metrics: snap,
        }];
        let net = NetMetrics::default();
        net.requests.fetch_add(12, Ordering::Relaxed);
        net.responses_ok.fetch_add(9, Ordering::Relaxed);
        net.err_queue_full.fetch_add(1, Ordering::Relaxed);
        net.err_unknown_model.fetch_add(2, Ordering::Relaxed);
        let ns = net.snapshot();
        assert_eq!(ns.errors_total(), 3);
        let doc = metrics_report_json(&snap, &per, Some(&ns));
        let parsed = Json::parse(&doc.render()).unwrap();
        assert_eq!(
            parsed.get("models").as_arr().unwrap()[0]
                .get("model")
                .as_str(),
            Some("digits_cnn")
        );
        assert_eq!(parsed.get("net").get("requests").as_usize(), Some(12));
        let without_net = metrics_report_json(&snap, &per, None);
        assert_eq!(*without_net.get("net"), Json::Null);
    }

    #[test]
    fn extreme_durations_clamp_into_range() {
        let h = Histogram::new();
        h.record(Duration::ZERO);
        h.record(Duration::from_secs(60 * 60));
        assert!(h.quantile(0.25) > Duration::ZERO);
        assert!(h.quantile(1.0) > Duration::from_secs(1));
    }
}
