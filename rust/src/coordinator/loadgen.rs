//! Deterministic load generation for the sharded coordinator.
//!
//! Integration tests and benches need repeatable traffic, but the old
//! approach (client threads + wall-clock sleeps) made request streams —
//! and therefore metrics assertions — racy. This harness replays a
//! *seeded trace* under a *virtual clock*:
//!
//! * [`Trace::seeded`] derives every frame and arrival tick from one seed,
//!   so two runs (or two servers) see byte-identical request streams;
//! * [`replay`] submits in virtual-arrival order with a bounded in-flight
//!   window (closed loop), and the arrival ticks are **barriers**:
//!   requests sharing a tick form one burst, and every in-flight request
//!   is settled before the clock advances to the next tick. Time is the
//!   trace's tick counter, not the wall clock: the replay never sleeps,
//!   burstiness is shaped entirely by `mean_gap_ticks` (0 = one
//!   back-to-back burst), and with `window <= workers * queue_depth` a
//!   request can never be rejected by backpressure, so acceptance counts
//!   are exactly reproducible.
//!
//! Responses are optionally checked against caller-provided expected
//! outputs (the single-`PipelineSim` golden path), which is how the
//! sharded server's bit-exactness is asserted.
//!
//! The replay loop is generic over a [`ReplayTransport`], so the same
//! harness drives the server in-process ([`replay`], [`replay_multi`])
//! and over localhost sockets through the TCP front-end ([`replay_net`])
//! — the network path must reproduce the in-process golden outputs
//! byte-for-byte (DESIGN.md §8, pinned by `tests/net_serving.rs`).

use std::collections::VecDeque;

use super::{Pending, Server};
use crate::net::client::{Client, ClientPending};
use crate::net::proto::ErrorCode;
use crate::sim::pipeline::PipelineSim;
use crate::util::Rng;

/// One request of a trace: a virtual arrival tick plus the input frame.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    pub at_tick: u64,
    pub frame: Vec<i64>,
}

/// A deterministic request trace.
#[derive(Debug, Clone)]
pub struct Trace {
    pub requests: Vec<TraceRequest>,
}

impl Trace {
    /// Generate `n` requests of `input_len` int8 features each. Arrival
    /// gaps are uniform in `[0, 2 * mean_gap_ticks]` virtual ticks
    /// (`mean_gap_ticks = 0` models a back-to-back burst).
    pub fn seeded(seed: u64, n: usize, input_len: usize, mean_gap_ticks: u64) -> Trace {
        let mut rng = Rng::new(seed);
        let mut tick = 0u64;
        let mut requests = Vec::with_capacity(n);
        for _ in 0..n {
            tick += rng.below(2 * mean_gap_ticks + 1);
            let frame: Vec<i64> = (0..input_len).map(|_| rng.int8() as i64).collect();
            requests.push(TraceRequest {
                at_tick: tick,
                frame,
            });
        }
        Trace { requests }
    }

    /// The trace's frames in arrival order (for computing golden outputs).
    pub fn frames(&self) -> Vec<Vec<i64>> {
        self.requests.iter().map(|r| r.frame.clone()).collect()
    }
}

/// Golden outputs for a trace: every frame through one `PipelineSim`'s
/// **fused interpreter** individually (`run_interpreted`) — the
/// single-pipeline golden path that sharded serving must reproduce
/// bit-for-bit (pass the result to [`replay`]). Like
/// [`golden_outputs_multi`], the oracle is deliberately NOT the compiled
/// tier the server executes by default, so a value bug in the
/// compiled/batched path cannot corrupt responses and expectations
/// identically.
pub fn golden_outputs(sim: &PipelineSim, trace: &Trace) -> Vec<Vec<i64>> {
    trace
        .requests
        .iter()
        .map(|r| {
            let mut res = sim
                .run_interpreted(std::slice::from_ref(&r.frame))
                .expect("golden interpreter run failed");
            res.outputs.swap_remove(0)
        })
        .collect()
}

/// Outcome counts of one replay.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct LoadReport {
    pub submitted: u64,
    pub ok: u64,
    /// Submissions refused by the server — backpressure, unknown route,
    /// or shutdown/drain (including the drain race that loses an
    /// accepted request's reply channel) — whether the refusal surfaced
    /// at submit time (in-process) or as a typed protocol error at
    /// settle time (TCP); both transports share one `classify` split.
    pub rejected: u64,
    /// Requests whose answer failed for per-request reasons: frame
    /// validation errors or transport losses.
    pub dropped: u64,
    /// Responses that differed from the expected golden outputs.
    pub mismatched: u64,
}

/// How a failed replay request is counted: `Rejected` maps to
/// [`LoadReport::rejected`], `Dropped` to [`LoadReport::dropped`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplayError {
    Rejected,
    Dropped,
}

/// A transport the virtual-clock replay loop can drive. Two
/// implementations: in-process ([`Server`] — `submit_to` + `Pending`)
/// and over TCP ([`Client`] — one pooled socket per in-flight request).
/// `submit` must never block on the answer; `wait` settles one request.
/// Keeping both behind one trait is what guarantees [`replay_multi`] and
/// [`replay_net`] can never drift apart semantically — the golden
/// network-equality tests compare their reports directly.
pub trait ReplayTransport {
    type Pending;
    /// Borrowed frame: each transport copies exactly once (the in-process
    /// path into its `Vec`, the TCP path into the wire frame).
    fn submit(&self, model: &str, frame: &[i64]) -> Result<Self::Pending, ReplayError>;
    fn wait(pending: Self::Pending) -> Result<Vec<i64>, ReplayError>;
}

/// The single rejected/dropped split both transports share, keyed on the
/// wire-level [`ErrorCode`] classification (in-process errors are run
/// through [`ErrorCode::from_reject`] first): server *refusals* —
/// backpressure, unknown route, drain — count as rejected; per-request
/// validation failures and transport losses count as dropped. One
/// classifier for both paths is what makes the report-equality contract
/// (`tests/net_serving.rs`) hold even on error-bearing traces.
fn classify(code: ErrorCode) -> ReplayError {
    match code {
        ErrorCode::QueueFull | ErrorCode::UnknownModel | ErrorCode::Draining => {
            ReplayError::Rejected
        }
        ErrorCode::InvalidFrame | ErrorCode::Malformed => ReplayError::Dropped,
    }
}

impl ReplayTransport for Server {
    type Pending = Pending;

    fn submit(&self, model: &str, frame: &[i64]) -> Result<Pending, ReplayError> {
        // Every in-process submit refusal (backpressure, unknown route,
        // stopped server) classifies as a rejection.
        self.submit_to(model, frame.to_vec())
            .map_err(|e| classify(ErrorCode::from_reject(&e)))
    }

    fn wait(pending: Pending) -> Result<Vec<i64>, ReplayError> {
        pending
            .wait()
            .map(|resp| resp.logits)
            .map_err(|e| classify(ErrorCode::from_reject(&e)))
    }
}

impl ReplayTransport for Client {
    type Pending = ClientPending;

    fn submit(&self, model: &str, frame: &[i64]) -> Result<ClientPending, ReplayError> {
        // A submit failure here is a transport problem (dial/send), not a
        // server refusal — refusals come back as typed protocol errors.
        Client::submit(self, model, frame).map_err(|_| ReplayError::Dropped)
    }

    fn wait(pending: ClientPending) -> Result<Vec<i64>, ReplayError> {
        match pending.wait() {
            Ok(resp) => Ok(resp.logits),
            Err(e) => Err(e.code.map_or(ReplayError::Dropped, classify)),
        }
    }
}

/// Replay `trace` against `server` with at most `window` requests in
/// flight within one virtual tick; advancing to the next arrival tick
/// settles everything outstanding first (tick barrier). When `expected`
/// is given, response `i` must equal `expected[i]` bit-for-bit or it is
/// counted as mismatched.
///
/// This is the single-model view of the shared `replay_core` loop — the
/// trace is viewed as a one-model request stream targeting the server's
/// first (default) group, so this and [`replay_multi`] can never drift
/// apart semantically. Only borrows are collected here; frames are
/// cloned once, at submission, like every other path.
pub fn replay(
    server: &Server,
    trace: &Trace,
    window: usize,
    expected: Option<&[Vec<i64>]>,
) -> LoadReport {
    let model = server
        .models()
        .into_iter()
        .next()
        .expect("server has at least one model group");
    let requests: Vec<(u64, usize, &[i64])> = trace
        .requests
        .iter()
        .map(|r| (r.at_tick, 0, r.frame.as_slice()))
        .collect();
    replay_core(server, &[model], &requests, window, expected).aggregate
}

// ---------------------------------------------------------------------
// Heterogeneous (multi-model) traces.
// ---------------------------------------------------------------------

/// One request of a heterogeneous trace: a virtual arrival tick, the
/// index of its model in [`MultiTrace::models`], and the input frame
/// (already sized for that model).
#[derive(Debug, Clone)]
pub struct MultiTraceRequest {
    pub at_tick: u64,
    pub model: usize,
    pub frame: Vec<i64>,
}

/// A deterministic mixed-traffic trace over several models: every frame,
/// arrival tick **and model assignment** derives from one seed, so two
/// replays see byte-identical request streams — including identical
/// per-model request counts.
#[derive(Debug, Clone)]
pub struct MultiTrace {
    /// Model ids, in the order [`MultiTraceRequest::model`] indexes.
    pub models: Vec<String>,
    pub requests: Vec<MultiTraceRequest>,
}

impl MultiTrace {
    /// Generate `n` requests over `models` (`(model id, input frame
    /// length)` pairs). Each request picks its model uniformly from the
    /// same seeded stream that shapes arrivals and frames; gaps are
    /// uniform in `[0, 2 * mean_gap_ticks]` virtual ticks, as in
    /// [`Trace::seeded`].
    pub fn seeded(
        seed: u64,
        n: usize,
        models: &[(String, usize)],
        mean_gap_ticks: u64,
    ) -> MultiTrace {
        assert!(!models.is_empty(), "MultiTrace needs at least one model");
        let mut rng = Rng::new(seed);
        let mut tick = 0u64;
        let mut requests = Vec::with_capacity(n);
        for _ in 0..n {
            tick += rng.below(2 * mean_gap_ticks + 1);
            let model = rng.below(models.len() as u64) as usize;
            let frame: Vec<i64> = (0..models[model].1).map(|_| rng.int8() as i64).collect();
            requests.push(MultiTraceRequest {
                at_tick: tick,
                model,
                frame,
            });
        }
        MultiTrace {
            models: models.iter().map(|(id, _)| id.clone()).collect(),
            requests,
        }
    }

    /// Requests per model, indexed like [`MultiTrace::models`].
    pub fn per_model_counts(&self) -> Vec<u64> {
        let mut counts = vec![0u64; self.models.len()];
        for r in &self.requests {
            counts[r.model] += 1;
        }
        counts
    }
}

/// Golden outputs for a heterogeneous trace: every frame through its own
/// model's **fused interpreter** individually
/// (`PipelineSim::run_interpreted`; `sims` indexed like
/// [`MultiTrace::models`]). The oracle engine is deliberately NOT the
/// compiled tier the server executes by default, so a value bug in the
/// compiled/batched path cannot corrupt the expected outputs the same
/// way it corrupts the responses — multi-model serving must reproduce
/// the per-model interpreter replay bit-for-bit.
pub fn golden_outputs_multi(sims: &[&PipelineSim], trace: &MultiTrace) -> Vec<Vec<i64>> {
    assert_eq!(sims.len(), trace.models.len(), "one sim per trace model");
    trace
        .requests
        .iter()
        .map(|r| {
            let mut res = sims[r.model]
                .run_interpreted(std::slice::from_ref(&r.frame))
                .expect("golden interpreter run failed");
            res.outputs.swap_remove(0)
        })
        .collect()
}

/// Outcome counts of one heterogeneous replay: the aggregate plus one
/// [`LoadReport`] per model (indexed like [`MultiTrace::models`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MultiLoadReport {
    pub aggregate: LoadReport,
    pub per_model: Vec<LoadReport>,
}

/// Replay a heterogeneous `trace` against a multi-model `server` with the
/// same virtual-clock semantics as [`replay`] (tick barriers, bounded
/// in-flight window), dispatching every request to its model's shard
/// group via `Server::submit_to`. When `expected` is given (indexed like
/// `trace.requests`), response `i` must equal `expected[i]` bit-for-bit
/// or it counts as mismatched — both in the aggregate and in its model's
/// report.
pub fn replay_multi(
    server: &Server,
    trace: &MultiTrace,
    window: usize,
    expected: Option<&[Vec<i64>]>,
) -> MultiLoadReport {
    let requests: Vec<(u64, usize, &[i64])> = trace
        .requests
        .iter()
        .map(|r| (r.at_tick, r.model, r.frame.as_slice()))
        .collect();
    replay_core(server, &trace.models, &requests, window, expected)
}

/// Replay a heterogeneous `trace` **over localhost sockets** through a
/// pooled [`Client`], with the same virtual-clock semantics as
/// [`replay_multi`] (tick barriers, bounded in-flight window — each
/// in-flight request holds one pooled connection, so size the client's
/// pool to `window` to avoid re-dialing). The TCP path must be
/// **byte-identical** to the in-process replay: the same `expected`
/// golden outputs apply unchanged, and `tests/net_serving.rs` pins that
/// both transports produce equal reports for the same seeded trace.
pub fn replay_net(
    client: &Client,
    trace: &MultiTrace,
    window: usize,
    expected: Option<&[Vec<i64>]>,
) -> MultiLoadReport {
    let requests: Vec<(u64, usize, &[i64])> = trace
        .requests
        .iter()
        .map(|r| (r.at_tick, r.model, r.frame.as_slice()))
        .collect();
    replay_core(client, &trace.models, &requests, window, expected)
}

/// The shared virtual-clock replay loop behind [`replay`],
/// [`replay_multi`] and [`replay_net`]: requests are `(arrival tick,
/// model index, frame)` borrows, submitted to `models[model index]`'s
/// shard group in arrival order with a bounded in-flight window; arrival
/// ticks are barriers (everything outstanding settles before the clock
/// advances). Generic over the [`ReplayTransport`], so the in-process
/// and TCP paths share every semantic.
fn replay_core<T: ReplayTransport>(
    target: &T,
    models: &[String],
    requests: &[(u64, usize, &[i64])],
    window: usize,
    expected: Option<&[Vec<i64>]>,
) -> MultiLoadReport {
    fn settle<T: ReplayTransport>(
        idx: usize,
        model: usize,
        pending: T::Pending,
        expected: Option<&[Vec<i64>]>,
        report: &mut MultiLoadReport,
    ) {
        match T::wait(pending) {
            Ok(logits) => {
                report.aggregate.ok += 1;
                report.per_model[model].ok += 1;
                if let Some(exp) = expected {
                    if logits != exp[idx] {
                        report.aggregate.mismatched += 1;
                        report.per_model[model].mismatched += 1;
                    }
                }
            }
            Err(ReplayError::Rejected) => {
                report.aggregate.rejected += 1;
                report.per_model[model].rejected += 1;
            }
            Err(ReplayError::Dropped) => {
                report.aggregate.dropped += 1;
                report.per_model[model].dropped += 1;
            }
        }
    }

    let window = window.max(1);
    let mut report = MultiLoadReport {
        aggregate: LoadReport::default(),
        per_model: vec![LoadReport::default(); models.len()],
    };
    let mut inflight: VecDeque<(usize, usize, T::Pending)> = VecDeque::new();
    let mut clock = requests.first().map(|&(tick, _, _)| tick).unwrap_or(0);
    for (i, &(at_tick, model, frame)) in requests.iter().enumerate() {
        // Tick barrier: the virtual clock only advances once every
        // request from earlier ticks has been answered.
        if at_tick != clock {
            clock = at_tick;
            while let Some((idx, m, p)) = inflight.pop_front() {
                settle::<T>(idx, m, p, expected, &mut report);
            }
        }
        while inflight.len() >= window {
            let (idx, m, p) = inflight.pop_front().unwrap();
            settle::<T>(idx, m, p, expected, &mut report);
        }
        report.aggregate.submitted += 1;
        report.per_model[model].submitted += 1;
        match target.submit(&models[model], frame) {
            Ok(p) => inflight.push_back((i, model, p)),
            Err(ReplayError::Rejected) => {
                report.aggregate.rejected += 1;
                report.per_model[model].rejected += 1;
            }
            Err(ReplayError::Dropped) => {
                report.aggregate.dropped += 1;
                report.per_model[model].dropped += 1;
            }
        }
    }
    while let Some((idx, m, p)) = inflight.pop_front() {
        settle::<T>(idx, m, p, expected, &mut report);
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_are_deterministic_per_seed() {
        let a = Trace::seeded(9, 32, 16, 3);
        let b = Trace::seeded(9, 32, 16, 3);
        assert_eq!(a.frames(), b.frames());
        assert_eq!(
            a.requests.iter().map(|r| r.at_tick).collect::<Vec<_>>(),
            b.requests.iter().map(|r| r.at_tick).collect::<Vec<_>>()
        );
        let c = Trace::seeded(10, 32, 16, 3);
        assert_ne!(a.frames(), c.frames());
    }

    #[test]
    fn ticks_are_monotone_and_frames_int8() {
        let t = Trace::seeded(4, 64, 9, 5);
        let mut prev = 0;
        for r in &t.requests {
            assert!(r.at_tick >= prev);
            prev = r.at_tick;
            assert_eq!(r.frame.len(), 9);
            assert!(r.frame.iter().all(|v| v.abs() <= 127));
        }
    }

    #[test]
    fn zero_gap_trace_is_a_burst() {
        let t = Trace::seeded(1, 16, 4, 0);
        assert!(t.requests.iter().all(|r| r.at_tick == 0));
    }

    #[test]
    fn multi_traces_are_deterministic_per_seed() {
        let specs = [("a".to_string(), 4usize), ("b".to_string(), 9)];
        let x = MultiTrace::seeded(7, 48, &specs, 2);
        let y = MultiTrace::seeded(7, 48, &specs, 2);
        assert_eq!(x.models, y.models);
        assert_eq!(x.per_model_counts(), y.per_model_counts());
        for (rx, ry) in x.requests.iter().zip(&y.requests) {
            assert_eq!(rx.at_tick, ry.at_tick);
            assert_eq!(rx.model, ry.model);
            assert_eq!(rx.frame, ry.frame);
        }
        let z = MultiTrace::seeded(8, 48, &specs, 2);
        assert_ne!(
            x.requests.iter().map(|r| r.model).collect::<Vec<_>>(),
            z.requests.iter().map(|r| r.model).collect::<Vec<_>>()
        );
    }

    #[test]
    fn multi_trace_frames_sized_per_model_and_counts_reconcile() {
        let specs = [("small".to_string(), 3usize), ("big".to_string(), 12)];
        let t = MultiTrace::seeded(11, 64, &specs, 1);
        for r in &t.requests {
            assert_eq!(r.frame.len(), specs[r.model].1);
        }
        let counts = t.per_model_counts();
        assert_eq!(counts.iter().sum::<u64>(), 64);
        assert!(counts.iter().all(|&c| c > 0), "both models drawn: {counts:?}");
    }
}
