//! Deterministic load generation for the sharded coordinator.
//!
//! Integration tests and benches need repeatable traffic, but the old
//! approach (client threads + wall-clock sleeps) made request streams —
//! and therefore metrics assertions — racy. This harness replays a
//! *seeded trace* under a *virtual clock*:
//!
//! * [`Trace::seeded`] derives every frame and arrival tick from one seed,
//!   so two runs (or two servers) see byte-identical request streams;
//! * [`replay`] submits in virtual-arrival order with a bounded in-flight
//!   window (closed loop), and the arrival ticks are **barriers**:
//!   requests sharing a tick form one burst, and every in-flight request
//!   is settled before the clock advances to the next tick. Time is the
//!   trace's tick counter, not the wall clock: the replay never sleeps,
//!   burstiness is shaped entirely by `mean_gap_ticks` (0 = one
//!   back-to-back burst), and with `window <= workers * queue_depth` a
//!   request can never be rejected by backpressure, so acceptance counts
//!   are exactly reproducible.
//!
//! Responses are optionally checked against caller-provided expected
//! outputs (the single-`PipelineSim` golden path), which is how the
//! sharded server's bit-exactness is asserted.
//!
//! The replay loop is generic over a [`ReplayTransport`], so the same
//! harness drives the server in-process ([`replay`], [`replay_multi`])
//! and over localhost sockets through the TCP front-end ([`replay_net`])
//! — the network path must reproduce the in-process golden outputs
//! byte-for-byte (DESIGN.md §8, pinned by `tests/net_serving.rs`).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};

use super::{Pending, Server, SubmitOpts};
use crate::net::client::{Client, ClientPending};
use crate::net::proto::ErrorCode;
use crate::sim::pipeline::PipelineSim;
use crate::util::Rng;

/// One request of a trace: a virtual arrival tick plus the input frame.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    pub at_tick: u64,
    pub frame: Vec<i64>,
}

/// A deterministic request trace.
#[derive(Debug, Clone)]
pub struct Trace {
    pub requests: Vec<TraceRequest>,
}

impl Trace {
    /// Generate `n` requests of `input_len` int8 features each. Arrival
    /// gaps are uniform in `[0, 2 * mean_gap_ticks]` virtual ticks
    /// (`mean_gap_ticks = 0` models a back-to-back burst).
    pub fn seeded(seed: u64, n: usize, input_len: usize, mean_gap_ticks: u64) -> Trace {
        let mut rng = Rng::new(seed);
        let mut tick = 0u64;
        let mut requests = Vec::with_capacity(n);
        for _ in 0..n {
            tick += rng.below(2 * mean_gap_ticks + 1);
            let frame: Vec<i64> = (0..input_len).map(|_| rng.int8() as i64).collect();
            requests.push(TraceRequest {
                at_tick: tick,
                frame,
            });
        }
        Trace { requests }
    }

    /// The trace's frames in arrival order (for computing golden outputs).
    pub fn frames(&self) -> Vec<Vec<i64>> {
        self.requests.iter().map(|r| r.frame.clone()).collect()
    }
}

/// Golden outputs for a trace: every frame through one `PipelineSim`'s
/// **fused interpreter** individually (`run_interpreted`) — the
/// single-pipeline golden path that sharded serving must reproduce
/// bit-for-bit (pass the result to [`replay`]). Like
/// [`golden_outputs_multi`], the oracle is deliberately NOT the compiled
/// tier the server executes by default, so a value bug in the
/// compiled/batched path cannot corrupt responses and expectations
/// identically.
pub fn golden_outputs(sim: &PipelineSim, trace: &Trace) -> Vec<Vec<i64>> {
    trace
        .requests
        .iter()
        .map(|r| {
            let mut res = sim
                .run_interpreted(std::slice::from_ref(&r.frame))
                .expect("golden interpreter run failed");
            res.outputs.swap_remove(0)
        })
        .collect()
}

/// Outcome counts of one replay.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct LoadReport {
    pub submitted: u64,
    pub ok: u64,
    /// Submissions refused by the server — backpressure, unknown route,
    /// or shutdown/drain (including the drain race that loses an
    /// accepted request's reply channel) — whether the refusal surfaced
    /// at submit time (in-process) or as a typed protocol error at
    /// settle time (TCP); both transports share one `classify` split.
    pub rejected: u64,
    /// Deadline-bearing requests shed by admission control
    /// (`ErrorCode::SloMiss` / in-process `"slo miss: …"`) — kept apart
    /// from `rejected` because shedding is the predictive tier working
    /// as designed, not a capacity refusal.
    pub shed: u64,
    /// Requests whose answer failed for per-request reasons: frame
    /// validation errors or transport losses.
    pub dropped: u64,
    /// Responses that differed from the expected golden outputs.
    pub mismatched: u64,
    /// Completed deadline-bearing requests whose server-side SLO verdict
    /// was "met" (admission-time prediction fit the deadline budget).
    pub slo_met: u64,
}

/// How a failed replay request is counted: `Rejected` maps to
/// [`LoadReport::rejected`], `Shed` to [`LoadReport::shed`], `Dropped`
/// to [`LoadReport::dropped`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplayError {
    Rejected,
    Shed,
    Dropped,
}

/// A transport the virtual-clock replay loop can drive. Two
/// implementations: in-process ([`Server`] — `submit_to` + `Pending`)
/// and over TCP ([`Client`] — one pooled socket per in-flight request).
/// `submit` must never block on the answer; `wait` settles one request.
/// Keeping both behind one trait is what guarantees [`replay_multi`] and
/// [`replay_net`] can never drift apart semantically — the golden
/// network-equality tests compare their reports directly.
pub trait ReplayTransport {
    type Pending;
    /// Borrowed frame: each transport copies exactly once (the in-process
    /// path into its `Vec`, the TCP path into the wire frame). A
    /// `deadline_us` of 0 means deadline-free — both transports then
    /// reproduce the pre-SLO submit byte-for-byte.
    fn submit(
        &self,
        model: &str,
        frame: &[i64],
        deadline_us: u64,
        class: u8,
    ) -> Result<Self::Pending, ReplayError>;
    /// Settle one request: the logits plus the server-side SLO verdict
    /// (always false for deadline-free requests on both transports).
    fn wait(pending: Self::Pending) -> Result<(Vec<i64>, bool), ReplayError>;
}

/// The single rejected/dropped split both transports share, keyed on the
/// wire-level [`ErrorCode`] classification (in-process errors are run
/// through [`ErrorCode::from_reject`] first): server *refusals* —
/// backpressure, unknown route, drain — count as rejected; per-request
/// validation failures and transport losses count as dropped. One
/// classifier for both paths is what makes the report-equality contract
/// (`tests/net_serving.rs`) hold even on error-bearing traces.
fn classify(code: ErrorCode) -> ReplayError {
    match code {
        ErrorCode::QueueFull | ErrorCode::UnknownModel | ErrorCode::Draining => {
            ReplayError::Rejected
        }
        ErrorCode::SloMiss => ReplayError::Shed,
        ErrorCode::InvalidFrame | ErrorCode::Malformed => ReplayError::Dropped,
    }
}

impl ReplayTransport for Server {
    type Pending = Pending;

    fn submit(
        &self,
        model: &str,
        frame: &[i64],
        deadline_us: u64,
        class: u8,
    ) -> Result<Pending, ReplayError> {
        // Every in-process submit refusal (backpressure, unknown route,
        // admission shed, stopped server) classifies through the same
        // wire split the TCP path uses.
        self.submit_to_opts(
            model,
            frame.to_vec(),
            SubmitOpts { deadline_us, class },
            None,
        )
        .map_err(|e| classify(ErrorCode::from_reject(&e)))
    }

    fn wait(pending: Pending) -> Result<(Vec<i64>, bool), ReplayError> {
        pending
            .wait()
            .map(|resp| (resp.logits, resp.slo_met))
            .map_err(|e| classify(ErrorCode::from_reject(&e)))
    }
}

impl ReplayTransport for Client {
    type Pending = ClientPending;

    fn submit(
        &self,
        model: &str,
        frame: &[i64],
        deadline_us: u64,
        class: u8,
    ) -> Result<ClientPending, ReplayError> {
        // A submit failure here is a transport problem (dial/send), not a
        // server refusal — refusals come back as typed protocol errors.
        Client::submit_slo(self, model, frame, deadline_us, class)
            .map_err(|_| ReplayError::Dropped)
    }

    fn wait(pending: ClientPending) -> Result<(Vec<i64>, bool), ReplayError> {
        match pending.wait() {
            Ok(resp) => Ok((resp.logits, resp.slo_met)),
            Err(e) => Err(e.code.map_or(ReplayError::Dropped, classify)),
        }
    }
}

/// Replay `trace` against `server` with at most `window` requests in
/// flight within one virtual tick; advancing to the next arrival tick
/// settles everything outstanding first (tick barrier). When `expected`
/// is given, response `i` must equal `expected[i]` bit-for-bit or it is
/// counted as mismatched.
///
/// This is the single-model view of the shared `replay_core` loop — the
/// trace is viewed as a one-model request stream targeting the server's
/// first (default) group, so this and [`replay_multi`] can never drift
/// apart semantically. Only borrows are collected here; frames are
/// cloned once, at submission, like every other path.
pub fn replay(
    server: &Server,
    trace: &Trace,
    window: usize,
    expected: Option<&[Vec<i64>]>,
) -> LoadReport {
    let model = server
        .models()
        .into_iter()
        .next()
        .expect("server has at least one model group");
    let requests: Vec<(u64, usize, &[i64], u64, u8)> = trace
        .requests
        .iter()
        .map(|r| (r.at_tick, 0, r.frame.as_slice(), 0, 0))
        .collect();
    replay_core(server, &[model], &requests, window, expected, None).aggregate
}

// ---------------------------------------------------------------------
// Heterogeneous (multi-model) traces.
// ---------------------------------------------------------------------

/// One request of a heterogeneous trace: a virtual arrival tick, the
/// index of its model in [`MultiTrace::models`], the input frame
/// (already sized for that model), and the request's SLO envelope — a
/// `deadline_us` of 0 means deadline-free (exempt from admission
/// control), and `class` is an opaque priority label used only for
/// per-class reporting.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MultiTraceRequest {
    pub at_tick: u64,
    pub model: usize,
    pub frame: Vec<i64>,
    pub deadline_us: u64,
    pub class: u8,
}

/// One tenant of a multi-tenant trace: which model it targets, the SLO
/// envelope stamped on its requests, and its steady request rate
/// (`weight` requests per virtual tick, before the per-constructor load
/// shape scales it).
#[derive(Debug, Clone)]
pub struct Tenant {
    pub model: usize,
    pub class: u8,
    pub deadline_us: u64,
    pub weight: usize,
}

/// A deterministic mixed-traffic trace over several models: every frame,
/// arrival tick **and model assignment** derives from one seed, so two
/// replays see byte-identical request streams — including identical
/// per-model request counts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MultiTrace {
    /// Model ids, in the order [`MultiTraceRequest::model`] indexes.
    pub models: Vec<String>,
    pub requests: Vec<MultiTraceRequest>,
}

impl MultiTrace {
    /// Generate `n` requests over `models` (`(model id, input frame
    /// length)` pairs). Each request picks its model uniformly from the
    /// same seeded stream that shapes arrivals and frames; gaps are
    /// uniform in `[0, 2 * mean_gap_ticks]` virtual ticks, as in
    /// [`Trace::seeded`].
    pub fn seeded(
        seed: u64,
        n: usize,
        models: &[(String, usize)],
        mean_gap_ticks: u64,
    ) -> MultiTrace {
        assert!(!models.is_empty(), "MultiTrace needs at least one model");
        let mut rng = Rng::new(seed);
        let mut tick = 0u64;
        let mut requests = Vec::with_capacity(n);
        for _ in 0..n {
            tick += rng.below(2 * mean_gap_ticks + 1);
            let model = rng.below(models.len() as u64) as usize;
            let frame: Vec<i64> = (0..models[model].1).map(|_| rng.int8() as i64).collect();
            requests.push(MultiTraceRequest {
                at_tick: tick,
                model,
                frame,
                deadline_us: 0,
                class: 0,
            });
        }
        MultiTrace {
            models: models.iter().map(|(id, _)| id.clone()).collect(),
            requests,
        }
    }

    /// Multi-tenant trace with alternating calm/burst phases: every
    /// `period` ticks the whole tenant mix switches between `calm_x`
    /// and `burst_x` copies of each tenant's per-tick `weight`. The
    /// bursts are what overwhelm a fixed shard count and make the
    /// predictive tier (shed + autoscale) observable.
    pub fn bursty(
        seed: u64,
        models: &[(String, usize)],
        tenants: &[Tenant],
        ticks: u64,
        period: u64,
        calm_x: usize,
        burst_x: usize,
    ) -> MultiTrace {
        let period = period.max(1);
        Self::from_tenant_rates(seed, models, tenants, ticks, |t, _, w| {
            if (t / period) % 2 == 1 {
                w * burst_x
            } else {
                w * calm_x
            }
        })
    }

    /// Multi-tenant trace with a diurnal (triangle-wave) load profile:
    /// each tenant emits `weight` requests per tick at the trough and
    /// ramps linearly to `weight * peak_x` at mid-trace, then back down
    /// — one full "day" across the whole trace.
    pub fn diurnal(
        seed: u64,
        models: &[(String, usize)],
        tenants: &[Tenant],
        ticks: u64,
        peak_x: usize,
    ) -> MultiTrace {
        let half = (ticks / 2).max(1);
        Self::from_tenant_rates(seed, models, tenants, ticks, move |t, _, w| {
            let pos = t.min(ticks.saturating_sub(1).saturating_sub(t));
            let extra = (w as u64 * peak_x.saturating_sub(1) as u64 * pos) / half;
            w + extra as usize
        })
    }

    /// Multi-tenant trace where tenant `flood` misbehaves: during every
    /// other `period`-tick window it emits `flood_x` times its weight,
    /// and is silent otherwise; all other tenants send their steady
    /// `weight` per tick throughout. The victims' per-class SLO-met
    /// fraction under this trace is the adversarial-isolation signal.
    pub fn adversarial(
        seed: u64,
        models: &[(String, usize)],
        tenants: &[Tenant],
        flood: usize,
        ticks: u64,
        period: u64,
        flood_x: usize,
    ) -> MultiTrace {
        assert!(flood < tenants.len(), "flood tenant index out of range");
        let period = period.max(1);
        Self::from_tenant_rates(seed, models, tenants, ticks, move |t, i, w| {
            if i == flood {
                if (t / period) % 2 == 1 {
                    w * flood_x
                } else {
                    0
                }
            } else {
                w
            }
        })
    }

    /// The shared per-tick synthesis loop behind the tenant-based
    /// constructors: for each virtual tick, `rate(tick, tenant index,
    /// weight)` gives every tenant's request count, and the tick's
    /// requests are interleaved by a seeded shuffle so no tenant
    /// systematically front-runs the others within a burst.
    fn from_tenant_rates(
        seed: u64,
        models: &[(String, usize)],
        tenants: &[Tenant],
        ticks: u64,
        rate: impl Fn(u64, usize, usize) -> usize,
    ) -> MultiTrace {
        assert!(!models.is_empty(), "MultiTrace needs at least one model");
        assert!(!tenants.is_empty(), "MultiTrace needs at least one tenant");
        for t in tenants {
            assert!(t.model < models.len(), "tenant model index out of range");
        }
        let mut rng = Rng::new(seed);
        let mut requests = Vec::new();
        for tick in 0..ticks {
            // Emission order within a tick: list each tenant's slots,
            // then Fisher-Yates shuffle from the same seeded stream
            // that shapes the frames.
            let mut slots: Vec<usize> = Vec::new();
            for (i, t) in tenants.iter().enumerate() {
                slots.extend(std::iter::repeat(i).take(rate(tick, i, t.weight)));
            }
            for i in (1..slots.len()).rev() {
                let j = rng.below(i as u64 + 1) as usize;
                slots.swap(i, j);
            }
            for tenant in slots {
                let t = &tenants[tenant];
                let frame: Vec<i64> =
                    (0..models[t.model].1).map(|_| rng.int8() as i64).collect();
                requests.push(MultiTraceRequest {
                    at_tick: tick,
                    model: t.model,
                    frame,
                    deadline_us: t.deadline_us,
                    class: t.class,
                });
            }
        }
        MultiTrace {
            models: models.iter().map(|(id, _)| id.clone()).collect(),
            requests,
        }
    }

    /// Requests per class label, as `(class, count)` sorted by class.
    pub fn per_class_counts(&self) -> Vec<(u8, u64)> {
        let mut counts: Vec<(u8, u64)> = Vec::new();
        for r in &self.requests {
            match counts.binary_search_by_key(&r.class, |&(c, _)| c) {
                Ok(i) => counts[i].1 += 1,
                Err(i) => counts.insert(i, (r.class, 1)),
            }
        }
        counts
    }

    /// Requests per model, indexed like [`MultiTrace::models`].
    pub fn per_model_counts(&self) -> Vec<u64> {
        let mut counts = vec![0u64; self.models.len()];
        for r in &self.requests {
            counts[r.model] += 1;
        }
        counts
    }
}

/// Golden outputs for a heterogeneous trace: every frame through its own
/// model's **fused interpreter** individually
/// (`PipelineSim::run_interpreted`; `sims` indexed like
/// [`MultiTrace::models`]). The oracle engine is deliberately NOT the
/// compiled tier the server executes by default, so a value bug in the
/// compiled/batched path cannot corrupt the expected outputs the same
/// way it corrupts the responses — multi-model serving must reproduce
/// the per-model interpreter replay bit-for-bit.
pub fn golden_outputs_multi(sims: &[&PipelineSim], trace: &MultiTrace) -> Vec<Vec<i64>> {
    assert_eq!(sims.len(), trace.models.len(), "one sim per trace model");
    trace
        .requests
        .iter()
        .map(|r| {
            let mut res = sims[r.model]
                .run_interpreted(std::slice::from_ref(&r.frame))
                .expect("golden interpreter run failed");
            res.outputs.swap_remove(0)
        })
        .collect()
}

/// Per-priority-class outcome counts of one heterogeneous replay — the
/// SLO ledger the overload gate reads. `met / with_deadline` is the
/// class's SLO-met fraction; shed and completed-but-missed requests both
/// count against it, so admission control cannot inflate the fraction by
/// shedding (a shed request is a miss, just a cheap one).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ClassReport {
    pub class: u8,
    pub submitted: u64,
    pub ok: u64,
    pub shed: u64,
    /// Submitted requests carrying a non-zero deadline.
    pub with_deadline: u64,
    /// Completed deadline-bearing requests whose server-side verdict
    /// was "met".
    pub met: u64,
}

impl ClassReport {
    /// Fraction of this class's deadline-bearing requests that completed
    /// with their modelled budget met (1.0 when none carried deadlines).
    pub fn slo_met_fraction(&self) -> f64 {
        if self.with_deadline == 0 {
            1.0
        } else {
            self.met as f64 / self.with_deadline as f64
        }
    }
}

/// Outcome counts of one heterogeneous replay: the aggregate plus one
/// [`LoadReport`] per model (indexed like [`MultiTrace::models`]) and one
/// [`ClassReport`] per priority class present in the trace (sorted by
/// class label).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MultiLoadReport {
    pub aggregate: LoadReport,
    pub per_model: Vec<LoadReport>,
    pub classes: Vec<ClassReport>,
}

impl MultiLoadReport {
    /// Overall SLO-met fraction across every deadline-bearing request
    /// (1.0 when none carried deadlines).
    pub fn slo_met_fraction(&self) -> f64 {
        let with_deadline: u64 = self.classes.iter().map(|c| c.with_deadline).sum();
        if with_deadline == 0 {
            1.0
        } else {
            self.classes.iter().map(|c| c.met).sum::<u64>() as f64 / with_deadline as f64
        }
    }
}

/// Replay a heterogeneous `trace` against a multi-model `server` with the
/// same virtual-clock semantics as [`replay`] (tick barriers, bounded
/// in-flight window), dispatching every request to its model's shard
/// group via `Server::submit_to`. When `expected` is given (indexed like
/// `trace.requests`), response `i` must equal `expected[i]` bit-for-bit
/// or it counts as mismatched — both in the aggregate and in its model's
/// report.
pub fn replay_multi(
    server: &Server,
    trace: &MultiTrace,
    window: usize,
    expected: Option<&[Vec<i64>]>,
) -> MultiLoadReport {
    let requests: Vec<(u64, usize, &[i64], u64, u8)> = trace
        .requests
        .iter()
        .map(|r| (r.at_tick, r.model, r.frame.as_slice(), r.deadline_us, r.class))
        .collect();
    replay_core(server, &trace.models, &requests, window, expected, None)
}

/// [`replay_multi`] with the trace's virtual clock published into
/// `ticks` — the `Arc` a [`crate::obs::Clock::virtual_from`] server
/// clock reads, which is what makes flight-recorder span stamps
/// byte-deterministic across replays (DESIGN.md §13). The tick store
/// happens only while **nothing is in flight** (tick barriers settle
/// every outstanding request first), so no span can straddle a clock
/// edge.
pub fn replay_multi_clocked(
    server: &Server,
    trace: &MultiTrace,
    window: usize,
    expected: Option<&[Vec<i64>]>,
    ticks: &AtomicU64,
) -> MultiLoadReport {
    let requests: Vec<(u64, usize, &[i64], u64, u8)> = trace
        .requests
        .iter()
        .map(|r| (r.at_tick, r.model, r.frame.as_slice(), r.deadline_us, r.class))
        .collect();
    replay_core(server, &trace.models, &requests, window, expected, Some(ticks))
}

/// Replay a heterogeneous `trace` **over localhost sockets** through a
/// pooled [`Client`], with the same virtual-clock semantics as
/// [`replay_multi`] (tick barriers, bounded in-flight window — each
/// in-flight request holds one pooled connection, so size the client's
/// pool to `window` to avoid re-dialing). The TCP path must be
/// **byte-identical** to the in-process replay: the same `expected`
/// golden outputs apply unchanged, and `tests/net_serving.rs` pins that
/// both transports produce equal reports for the same seeded trace.
pub fn replay_net(
    client: &Client,
    trace: &MultiTrace,
    window: usize,
    expected: Option<&[Vec<i64>]>,
) -> MultiLoadReport {
    let requests: Vec<(u64, usize, &[i64], u64, u8)> = trace
        .requests
        .iter()
        .map(|r| (r.at_tick, r.model, r.frame.as_slice(), r.deadline_us, r.class))
        .collect();
    replay_core(client, &trace.models, &requests, window, expected, None)
}

/// [`replay_net`] with the trace's virtual clock published into `ticks`
/// — see [`replay_multi_clocked`]. The store still happens with nothing
/// in flight; submissions within a tick reach the server only after the
/// store (the TCP write happens-after it on the replay thread), so the
/// networked spans are as deterministic as the in-process ones.
pub fn replay_net_clocked(
    client: &Client,
    trace: &MultiTrace,
    window: usize,
    expected: Option<&[Vec<i64>]>,
    ticks: &AtomicU64,
) -> MultiLoadReport {
    let requests: Vec<(u64, usize, &[i64], u64, u8)> = trace
        .requests
        .iter()
        .map(|r| (r.at_tick, r.model, r.frame.as_slice(), r.deadline_us, r.class))
        .collect();
    replay_core(client, &trace.models, &requests, window, expected, Some(ticks))
}

/// The shared virtual-clock replay loop behind [`replay`],
/// [`replay_multi`] and [`replay_net`]: requests are `(arrival tick,
/// model index, frame, deadline_us, class)` borrows, submitted to
/// `models[model index]`'s shard group in arrival order with a bounded
/// in-flight window; arrival ticks are barriers (everything outstanding
/// settles before the clock advances). Generic over the
/// [`ReplayTransport`], so the in-process and TCP paths share every
/// semantic — including the per-class SLO ledger.
fn replay_core<T: ReplayTransport>(
    target: &T,
    models: &[String],
    requests: &[(u64, usize, &[i64], u64, u8)],
    window: usize,
    expected: Option<&[Vec<i64>]>,
    tick_sink: Option<&AtomicU64>,
) -> MultiLoadReport {
    /// One in-flight request: trace index, model index, class slot in
    /// `report.classes`, whether it carried a deadline, and the pending
    /// handle.
    struct InFlight<P> {
        idx: usize,
        model: usize,
        slot: usize,
        with_deadline: bool,
        pending: P,
    }

    fn settle<T: ReplayTransport>(
        f: InFlight<T::Pending>,
        expected: Option<&[Vec<i64>]>,
        report: &mut MultiLoadReport,
    ) {
        match T::wait(f.pending) {
            Ok((logits, slo_met)) => {
                report.aggregate.ok += 1;
                report.per_model[f.model].ok += 1;
                report.classes[f.slot].ok += 1;
                if f.with_deadline && slo_met {
                    report.aggregate.slo_met += 1;
                    report.per_model[f.model].slo_met += 1;
                    report.classes[f.slot].met += 1;
                }
                if let Some(exp) = expected {
                    if logits != exp[f.idx] {
                        report.aggregate.mismatched += 1;
                        report.per_model[f.model].mismatched += 1;
                    }
                }
            }
            Err(e) => count_error(e, f.model, f.slot, report),
        }
    }

    fn count_error(e: ReplayError, model: usize, slot: usize, report: &mut MultiLoadReport) {
        match e {
            ReplayError::Rejected => {
                report.aggregate.rejected += 1;
                report.per_model[model].rejected += 1;
            }
            ReplayError::Shed => {
                report.aggregate.shed += 1;
                report.per_model[model].shed += 1;
                report.classes[slot].shed += 1;
            }
            ReplayError::Dropped => {
                report.aggregate.dropped += 1;
                report.per_model[model].dropped += 1;
            }
        }
    }

    // One ClassReport slot per class label present in the trace, sorted;
    // the empty-trace case keeps a single slot for class 0 so lookups
    // below can never fail.
    let mut class_ids: Vec<u8> = requests.iter().map(|&(_, _, _, _, c)| c).collect();
    class_ids.sort_unstable();
    class_ids.dedup();
    if class_ids.is_empty() {
        class_ids.push(0);
    }

    let window = window.max(1);
    let mut report = MultiLoadReport {
        aggregate: LoadReport::default(),
        per_model: vec![LoadReport::default(); models.len()],
        classes: class_ids
            .iter()
            .map(|&class| ClassReport {
                class,
                ..ClassReport::default()
            })
            .collect(),
    };
    let mut inflight: VecDeque<InFlight<T::Pending>> = VecDeque::new();
    let mut clock = requests.first().map(|&(tick, ..)| tick).unwrap_or(0);
    if let Some(sink) = tick_sink {
        sink.store(clock, Ordering::Release);
    }
    for (i, &(at_tick, model, frame, deadline_us, class)) in requests.iter().enumerate() {
        // Tick barrier: the virtual clock only advances once every
        // request from earlier ticks has been answered. Settling happens
        // *before* the tick store so no span straddles a clock edge —
        // every stamp a request takes comes from exactly one tick value,
        // which is what makes virtual-clock traces deterministic.
        if at_tick != clock {
            while let Some(f) = inflight.pop_front() {
                settle::<T>(f, expected, &mut report);
            }
            clock = at_tick;
            if let Some(sink) = tick_sink {
                sink.store(clock, Ordering::Release);
            }
        }
        while inflight.len() >= window {
            let f = inflight.pop_front().unwrap();
            settle::<T>(f, expected, &mut report);
        }
        let slot = class_ids
            .binary_search(&class)
            .expect("class slot prebuilt from the same requests");
        let with_deadline = deadline_us != 0;
        report.aggregate.submitted += 1;
        report.per_model[model].submitted += 1;
        report.classes[slot].submitted += 1;
        if with_deadline {
            report.classes[slot].with_deadline += 1;
        }
        match target.submit(&models[model], frame, deadline_us, class) {
            Ok(pending) => inflight.push_back(InFlight {
                idx: i,
                model,
                slot,
                with_deadline,
                pending,
            }),
            Err(e) => count_error(e, model, slot, &mut report),
        }
    }
    while let Some(f) = inflight.pop_front() {
        settle::<T>(f, expected, &mut report);
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_are_deterministic_per_seed() {
        let a = Trace::seeded(9, 32, 16, 3);
        let b = Trace::seeded(9, 32, 16, 3);
        assert_eq!(a.frames(), b.frames());
        assert_eq!(
            a.requests.iter().map(|r| r.at_tick).collect::<Vec<_>>(),
            b.requests.iter().map(|r| r.at_tick).collect::<Vec<_>>()
        );
        let c = Trace::seeded(10, 32, 16, 3);
        assert_ne!(a.frames(), c.frames());
    }

    #[test]
    fn ticks_are_monotone_and_frames_int8() {
        let t = Trace::seeded(4, 64, 9, 5);
        let mut prev = 0;
        for r in &t.requests {
            assert!(r.at_tick >= prev);
            prev = r.at_tick;
            assert_eq!(r.frame.len(), 9);
            assert!(r.frame.iter().all(|v| v.abs() <= 127));
        }
    }

    #[test]
    fn zero_gap_trace_is_a_burst() {
        let t = Trace::seeded(1, 16, 4, 0);
        assert!(t.requests.iter().all(|r| r.at_tick == 0));
    }

    #[test]
    fn multi_traces_are_deterministic_per_seed() {
        let specs = [("a".to_string(), 4usize), ("b".to_string(), 9)];
        let x = MultiTrace::seeded(7, 48, &specs, 2);
        let y = MultiTrace::seeded(7, 48, &specs, 2);
        assert_eq!(x.models, y.models);
        assert_eq!(x.per_model_counts(), y.per_model_counts());
        for (rx, ry) in x.requests.iter().zip(&y.requests) {
            assert_eq!(rx.at_tick, ry.at_tick);
            assert_eq!(rx.model, ry.model);
            assert_eq!(rx.frame, ry.frame);
        }
        let z = MultiTrace::seeded(8, 48, &specs, 2);
        assert_ne!(
            x.requests.iter().map(|r| r.model).collect::<Vec<_>>(),
            z.requests.iter().map(|r| r.model).collect::<Vec<_>>()
        );
    }

    #[test]
    fn multi_trace_frames_sized_per_model_and_counts_reconcile() {
        let specs = [("small".to_string(), 3usize), ("big".to_string(), 12)];
        let t = MultiTrace::seeded(11, 64, &specs, 1);
        for r in &t.requests {
            assert_eq!(r.frame.len(), specs[r.model].1);
            assert_eq!((r.deadline_us, r.class), (0, 0), "seeded traces are SLO-free");
        }
        let counts = t.per_model_counts();
        assert_eq!(counts.iter().sum::<u64>(), 64);
        assert!(counts.iter().all(|&c| c > 0), "both models drawn: {counts:?}");
    }

    fn tick_counts(t: &MultiTrace, ticks: u64) -> Vec<usize> {
        let mut counts = vec![0usize; ticks as usize];
        for r in &t.requests {
            counts[r.at_tick as usize] += 1;
        }
        counts
    }

    fn two_tenants() -> Vec<Tenant> {
        vec![
            Tenant {
                model: 0,
                class: 1,
                deadline_us: 500,
                weight: 1,
            },
            Tenant {
                model: 1,
                class: 2,
                deadline_us: 0,
                weight: 2,
            },
        ]
    }

    #[test]
    fn bursty_trace_alternates_phases_and_stamps_tenant_slo() {
        let specs = [("a".to_string(), 4usize), ("b".to_string(), 6)];
        let tenants = two_tenants();
        let t = MultiTrace::bursty(21, &specs, &tenants, 8, 2, 1, 5);
        let again = MultiTrace::bursty(21, &specs, &tenants, 8, 2, 1, 5);
        assert_eq!(t, again, "tenant traces are deterministic per seed");
        // weight sum 3 per tick: calm ticks {0,1,4,5} carry 3, burst
        // ticks {2,3,6,7} carry 15.
        let counts = tick_counts(&t, 8);
        assert_eq!(counts, vec![3, 3, 15, 15, 3, 3, 15, 15]);
        for r in &t.requests {
            let tenant = tenants.iter().find(|x| x.class == r.class).unwrap();
            assert_eq!(r.model, tenant.model);
            assert_eq!(r.deadline_us, tenant.deadline_us);
            assert_eq!(r.frame.len(), specs[r.model].1);
        }
        let classes = t.per_class_counts();
        assert_eq!(classes.iter().map(|&(_, n)| n).sum::<u64>(), t.requests.len() as u64);
    }

    #[test]
    fn diurnal_trace_peaks_mid_trace() {
        let specs = [("a".to_string(), 4usize), ("b".to_string(), 6)];
        let t = MultiTrace::diurnal(5, &specs, &two_tenants(), 16, 6);
        let counts = tick_counts(&t, 16);
        assert_eq!(counts[0], 3, "trough starts at the base weights");
        assert_eq!(*counts.last().unwrap(), 3, "and returns to them");
        let peak = *counts.iter().max().unwrap();
        assert!(peak > 3 * 3, "mid-trace ramps well above trough: {counts:?}");
        assert!(counts[8] >= counts[2], "ramp is monotone toward the middle");
    }

    #[test]
    fn adversarial_trace_floods_in_windows_only() {
        let specs = [("a".to_string(), 4usize), ("b".to_string(), 6)];
        let tenants = two_tenants();
        // Tenant 1 (class 2) misbehaves: silent in even windows, 8x its
        // weight in odd ones; tenant 0 (class 1) is steady throughout.
        let t = MultiTrace::adversarial(13, &specs, &tenants, 1, 8, 2, 8);
        let mut victim = vec![0usize; 8];
        let mut flood = vec![0usize; 8];
        for r in &t.requests {
            match r.class {
                1 => victim[r.at_tick as usize] += 1,
                2 => flood[r.at_tick as usize] += 1,
                c => panic!("unexpected class {c}"),
            }
        }
        assert_eq!(victim, vec![1; 8], "victim rate is steady");
        assert_eq!(flood, vec![0, 0, 16, 16, 0, 0, 16, 16]);
    }
}
