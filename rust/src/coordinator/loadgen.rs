//! Deterministic load generation for the sharded coordinator.
//!
//! Integration tests and benches need repeatable traffic, but the old
//! approach (client threads + wall-clock sleeps) made request streams —
//! and therefore metrics assertions — racy. This harness replays a
//! *seeded trace* under a *virtual clock*:
//!
//! * [`Trace::seeded`] derives every frame and arrival tick from one seed,
//!   so two runs (or two servers) see byte-identical request streams;
//! * [`replay`] submits in virtual-arrival order with a bounded in-flight
//!   window (closed loop), and the arrival ticks are **barriers**:
//!   requests sharing a tick form one burst, and every in-flight request
//!   is settled before the clock advances to the next tick. Time is the
//!   trace's tick counter, not the wall clock: the replay never sleeps,
//!   burstiness is shaped entirely by `mean_gap_ticks` (0 = one
//!   back-to-back burst), and with `window <= workers * queue_depth` a
//!   request can never be rejected by backpressure, so acceptance counts
//!   are exactly reproducible.
//!
//! Responses are optionally checked against caller-provided expected
//! outputs (the single-`PipelineSim` golden path), which is how the
//! sharded server's bit-exactness is asserted.

use std::collections::VecDeque;

use super::{Pending, Server};
use crate::sim::pipeline::PipelineSim;
use crate::util::Rng;

/// One request of a trace: a virtual arrival tick plus the input frame.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    pub at_tick: u64,
    pub frame: Vec<i64>,
}

/// A deterministic request trace.
#[derive(Debug, Clone)]
pub struct Trace {
    pub requests: Vec<TraceRequest>,
}

impl Trace {
    /// Generate `n` requests of `input_len` int8 features each. Arrival
    /// gaps are uniform in `[0, 2 * mean_gap_ticks]` virtual ticks
    /// (`mean_gap_ticks = 0` models a back-to-back burst).
    pub fn seeded(seed: u64, n: usize, input_len: usize, mean_gap_ticks: u64) -> Trace {
        let mut rng = Rng::new(seed);
        let mut tick = 0u64;
        let mut requests = Vec::with_capacity(n);
        for _ in 0..n {
            tick += rng.below(2 * mean_gap_ticks + 1);
            let frame: Vec<i64> = (0..input_len).map(|_| rng.int8() as i64).collect();
            requests.push(TraceRequest {
                at_tick: tick,
                frame,
            });
        }
        Trace { requests }
    }

    /// The trace's frames in arrival order (for computing golden outputs).
    pub fn frames(&self) -> Vec<Vec<i64>> {
        self.requests.iter().map(|r| r.frame.clone()).collect()
    }
}

/// Golden outputs for a trace: every frame through one `PipelineSim`
/// individually — the single-pipeline golden path that sharded serving
/// must reproduce bit-for-bit (pass the result to [`replay`]).
pub fn golden_outputs(sim: &PipelineSim, trace: &Trace) -> Vec<Vec<i64>> {
    trace
        .requests
        .iter()
        .map(|r| {
            let mut res = sim
                .run(std::slice::from_ref(&r.frame))
                .expect("golden sim run failed");
            res.outputs.swap_remove(0)
        })
        .collect()
}

/// Outcome counts of one replay.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct LoadReport {
    pub submitted: u64,
    pub ok: u64,
    /// Submissions refused by the server (backpressure or shutdown).
    pub rejected: u64,
    /// Accepted requests whose reply channel was dropped.
    pub dropped: u64,
    /// Responses that differed from the expected golden outputs.
    pub mismatched: u64,
}

/// Replay `trace` against `server` with at most `window` requests in
/// flight within one virtual tick; advancing to the next arrival tick
/// settles everything outstanding first (tick barrier). When `expected`
/// is given, response `i` must equal `expected[i]` bit-for-bit or it is
/// counted as mismatched.
pub fn replay(
    server: &Server,
    trace: &Trace,
    window: usize,
    expected: Option<&[Vec<i64>]>,
) -> LoadReport {
    fn settle(
        idx: usize,
        pending: Pending,
        expected: Option<&[Vec<i64>]>,
        report: &mut LoadReport,
    ) {
        match pending.wait() {
            Ok(resp) => {
                report.ok += 1;
                if let Some(exp) = expected {
                    if resp.logits != exp[idx] {
                        report.mismatched += 1;
                    }
                }
            }
            Err(_) => report.dropped += 1,
        }
    }

    let window = window.max(1);
    let mut report = LoadReport::default();
    let mut inflight: VecDeque<(usize, Pending)> = VecDeque::new();
    let mut clock = trace.requests.first().map(|r| r.at_tick).unwrap_or(0);
    for (i, req) in trace.requests.iter().enumerate() {
        // Tick barrier: the virtual clock only advances once every
        // request from earlier ticks has been answered.
        if req.at_tick != clock {
            clock = req.at_tick;
            while let Some((idx, p)) = inflight.pop_front() {
                settle(idx, p, expected, &mut report);
            }
        }
        while inflight.len() >= window {
            let (idx, p) = inflight.pop_front().unwrap();
            settle(idx, p, expected, &mut report);
        }
        report.submitted += 1;
        match server.submit(req.frame.clone()) {
            Ok(p) => inflight.push_back((i, p)),
            Err(_) => report.rejected += 1,
        }
    }
    while let Some((idx, p)) = inflight.pop_front() {
        settle(idx, p, expected, &mut report);
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_are_deterministic_per_seed() {
        let a = Trace::seeded(9, 32, 16, 3);
        let b = Trace::seeded(9, 32, 16, 3);
        assert_eq!(a.frames(), b.frames());
        assert_eq!(
            a.requests.iter().map(|r| r.at_tick).collect::<Vec<_>>(),
            b.requests.iter().map(|r| r.at_tick).collect::<Vec<_>>()
        );
        let c = Trace::seeded(10, 32, 16, 3);
        assert_ne!(a.frames(), c.frames());
    }

    #[test]
    fn ticks_are_monotone_and_frames_int8() {
        let t = Trace::seeded(4, 64, 9, 5);
        let mut prev = 0;
        for r in &t.requests {
            assert!(r.at_tick >= prev);
            prev = r.at_tick;
            assert_eq!(r.frame.len(), 9);
            assert!(r.frame.iter().all(|v| v.abs() <= 127));
        }
    }

    #[test]
    fn zero_gap_trace_is_a_burst() {
        let t = Trace::seeded(1, 16, 4, 0);
        assert!(t.requests.iter().all(|r| r.at_tick == 0));
    }
}
