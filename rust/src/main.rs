//! `cnn-flow` CLI — the L3 entrypoint.
//!
//! ```text
//! cnn-flow table <1..10>          reproduce a paper table
//! cnn-flow fig 13                 reproduce the Fig. 13 Pareto data
//! cnn-flow all-tables             every table + figure (report input)
//! cnn-flow analyze --model M      rates, unit plan, resources per layer
//! cnn-flow simulate --model M     cycle-accurate pipeline run + utilisation
//! cnn-flow serve --model M        sharded streaming coordinator demo (E12)
//! cnn-flow serve --models A,B,C   multi-model serving: registry-lowered zoo
//!                                 configs behind per-model shard groups
//! cnn-flow serve --listen H:P     expose the coordinator over TCP (net
//!                                 front-end; EOF on stdin drains + exits)
//! cnn-flow client --connect H:P   blocking TCP client: list models, send
//!                                 seeded traffic, report latency
//! cnn-flow trace                  flight-recorder dump: per-stage latency
//!                                 quantiles over a traced serving run
//! cnn-flow profile <model>        measured per-layer time share vs the
//!                                 analytic cycle share (DESIGN.md §13)
//! cnn-flow list                   zoo models
//! ```
//!
//! Argument parsing is hand-rolled (clap is not vendored offline).

use std::collections::HashMap;

use cnn_flow::complexity::{layer_cost, model_cost, CostOpts};
use cnn_flow::coordinator::{
    metrics_report_json, AutoscaleConfig, DispatchKind, EngineKind, MetricsSnapshot,
    ModelMetricsSnapshot, NetMetricsSnapshot, Server, ServerConfig,
};
use cnn_flow::flow::{analyze, plan_all, Ratio};
use cnn_flow::model::{config::model_from_json, zoo, Model};
use cnn_flow::net::{Client, FrontEnd, NetCore};
use cnn_flow::quant::QModel;
use cnn_flow::report;
use cnn_flow::sim::pipeline::PipelineSim;
use cnn_flow::util::bench;
use cnn_flow::util::{paper_count, Rng, Table};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = run(&args);
    std::process::exit(code);
}

fn run(args: &[String]) -> i32 {
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r),
        None => {
            usage();
            return 2;
        }
    };
    let opts = parse_flags(rest);
    match cmd {
        "table" => cmd_table(rest.first().map(String::as_str)),
        "fig" => cmd_fig(rest.first().map(String::as_str)),
        "all-tables" => {
            for n in 1..=10 {
                if cmd_table(Some(&n.to_string())) != 0 {
                    return 1;
                }
                println!();
            }
            cmd_fig(Some("13"))
        }
        "ablation" => {
            for t in cnn_flow::report::ablation::all_ablations() {
                println!("{t}");
            }
            0
        }
        "analyze" => cmd_analyze(&opts),
        "simulate" => cmd_simulate(&opts),
        "serve" => cmd_serve(&opts),
        "client" => cmd_client(&opts),
        "trace" => cmd_trace(&opts),
        "profile" => cmd_profile(rest, &opts),
        "bench" => cmd_bench(&opts),
        "list" => {
            for m in zoo::all_models() {
                let shape = m.output_shape().unwrap();
                println!(
                    "{:<18} input {}x{}x{} -> {} classes, {} params",
                    m.name,
                    m.input.f,
                    m.input.f,
                    m.input.d,
                    shape.d,
                    paper_count(m.param_count().unwrap())
                );
            }
            0
        }
        "help" | "--help" | "-h" => {
            usage();
            0
        }
        other => {
            eprintln!("unknown command '{other}'");
            usage();
            2
        }
    }
}

fn usage() {
    eprintln!(
        "cnn-flow — continuous-flow data-rate-aware CNN inference\n\
         usage:\n  cnn-flow table <1..10>\n  cnn-flow fig 13\n  cnn-flow all-tables\n  \
         cnn-flow ablation\n  cnn-flow analyze  --model <zoo-name|model.json> [--r0 n[/d]]\n  \
         cnn-flow simulate --model <digits|jsc> [--frames N] [--r0 n[/d]] [--reference]\n  \
         cnn-flow serve    --model <digits|jsc> [--synthetic] [--workers N] [--requests N]\n  \
                    [--max-batch N] [--batch-deadline USEC] [--queue-depth N]\n  \
                    [--verify-every N] [--engine compiled|folded|interp]\n  \
                    [--dispatch predictive|roundrobin] [--admission on|off]\n  \
                    [--autoscale on|off|MIN:MAX] [--metrics-json PATH]\n  \
                    [--trace on|off] [--profile on|off] (all serve modes)\n  \
         cnn-flow serve    --models <zoo,names,...> (multi-model shard groups; same flags\n  \
                    except --verify-every; --workers = shards per model)\n  \
         cnn-flow serve    --listen <host:port> [--model M|--models A,B|--synthetic]\n  \
                    [--net-core threaded|evented] [--metrics-listen <host:port>]\n  \
                    [--metrics-interval SECS] (TCP front-end; EOF on stdin\n  \
                    drains and exits)\n  \
         cnn-flow client   --connect <host:port> [--model M] [--requests N] [--pool N]\n  \
                    [--seed S] [--deadline-us N] [--class N]\n  \
         cnn-flow trace    [--model M|--synthetic] [--requests N] [--workers N]\n  \
                    (flight-recorder per-stage p50/p95/p99)\n  \
         cnn-flow profile  <model> [--requests N] [--engine compiled|folded]\n  \
                    (measured vs analytic per-layer shares)\n  \
         cnn-flow bench    [--synthetic] [--frames N] [--out BENCH_pipeline.json]\n  \
                    [--fanin MAXCONNS] (0 skips the network fan-in ladder)\n  \
         cnn-flow list"
    );
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut map = HashMap::new();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(key) = a.strip_prefix("--") {
            let val = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                _ => "true".to_string(),
            };
            map.insert(key.to_string(), val);
        }
    }
    map
}

fn parse_ratio(s: &str) -> Option<Ratio> {
    if let Some((n, d)) = s.split_once('/') {
        Some(Ratio::new(n.parse().ok()?, d.parse().ok()?))
    } else {
        Some(Ratio::int(s.parse().ok()?))
    }
}

fn load_model(spec: &str) -> Result<Model, String> {
    if let Some(m) = zoo::by_name(spec) {
        return Ok(m);
    }
    if spec.ends_with(".json") {
        let text = std::fs::read_to_string(spec).map_err(|e| e.to_string())?;
        return model_from_json(&text).map_err(|e| e.to_string());
    }
    Err(format!("unknown model '{spec}' (see `cnn-flow list`)"))
}

fn load_qmodel(name: &str) -> Result<QModel, String> {
    let path = cnn_flow::runtime::artifacts_dir()
        .join("weights")
        .join(format!("{name}.json"));
    QModel::load(&path).map_err(|e| format!("{e}\n(hint: run `make artifacts` first)"))
}

fn cmd_table(n: Option<&str>) -> i32 {
    let jsc = report::synthesis::load_jsc_artifact();
    let t: Table = match n {
        Some("1") => report::timing::table1(),
        Some("2") => report::timing::table2(),
        Some("3") => report::timing::table3(),
        Some("4") => report::timing::table4(),
        Some("5") => report::tables::table5(),
        Some("6") => report::tables::table6(),
        Some("7") => report::tables::table7(),
        Some("8") => report::tables::table8(),
        Some("9") => report::synthesis::table9(),
        Some("10") => report::synthesis::table10(jsc.as_ref()),
        other => {
            eprintln!("usage: cnn-flow table <1..10> (got {other:?})");
            return 2;
        }
    };
    println!("{t}");
    0
}

fn cmd_fig(n: Option<&str>) -> i32 {
    match n {
        Some("13") => {
            let jsc = report::synthesis::load_jsc_artifact();
            println!("{}", report::synthesis::fig13(jsc.as_ref()));
            0
        }
        other => {
            eprintln!("usage: cnn-flow fig 13 (got {other:?})");
            2
        }
    }
}

fn cmd_analyze(opts: &HashMap<String, String>) -> i32 {
    let spec = match opts.get("model") {
        Some(s) => s,
        None => {
            eprintln!("analyze requires --model");
            return 2;
        }
    };
    let model = match load_model(spec) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let r0 = opts.get("r0").and_then(|s| parse_ratio(s));
    let analysis = match analyze(&model, r0) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("shape error: {e}");
            return 1;
        }
    };
    let plans = plan_all(&analysis);
    let mut t = Table::new(
        format!("{} @ r0={}", model.name, analysis.r0),
        &[
            "Layer", "kind", "f", "d_in", "d_out", "r_in", "r_out", "units", "C", "stall",
            "Add.", "Mul.", "Reg.", "MUX",
        ],
    );
    for pl in &plans {
        let cost = layer_cost(pl, CostOpts::FULL);
        let l = &pl.rated.shaped.layer;
        t.row(&[
            l.name.clone(),
            l.kind.short().to_string(),
            pl.rated.shaped.input.f.to_string(),
            pl.rated.d_in().to_string(),
            pl.rated.d_out().to_string(),
            pl.rated.r_in.paper(),
            pl.rated.r_out.paper(),
            pl.plan.unit_count().to_string(),
            pl.plan.configs().to_string(),
            if pl.plan.stalled() { "*".into() } else { String::new() },
            paper_count(cost.adders),
            paper_count(cost.multipliers),
            paper_count(cost.registers),
            paper_count(cost.mux2),
        ]);
    }
    let total = model_cost(&plans, CostOpts::FULL).total;
    t.row(&[
        "TOTAL".to_string(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        format!("{}", total.kpus + total.fcus + total.ppus),
        String::new(),
        String::new(),
        paper_count(total.adders),
        paper_count(total.multipliers),
        paper_count(total.registers),
        paper_count(total.mux2),
    ]);
    println!("{t}");
    let est = cnn_flow::fpga::estimate_model(&plans, Default::default(), None);
    println!(
        "FPGA estimate: {} LUT, {} FF, {} DSP, {:.1} BRAM36, Fmax {:.0} MHz, {:.1} W",
        est.lut, est.ff, est.dsp, est.bram36, est.fmax_mhz, est.power_w
    );
    0
}

fn cmd_simulate(opts: &HashMap<String, String>) -> i32 {
    let name = opts.get("model").map(String::as_str).unwrap_or("digits");
    let frames: usize = opts
        .get("frames")
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    let qm = match load_qmodel(name) {
        Ok(q) => q,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let r0 = opts.get("r0").and_then(|s| parse_ratio(s));
    let sim = if opts.contains_key("reference") {
        PipelineSim::new_reference(qm.clone())
    } else {
        PipelineSim::new(qm.clone(), r0)
    };
    let sim = match sim {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let inputs: Vec<Vec<i64>> = qm
        .test_vectors
        .iter()
        .cycle()
        .take(frames.max(1))
        .map(|tv| tv.x_q.clone())
        .collect();
    if inputs.is_empty() {
        eprintln!("model has no test vectors");
        return 1;
    }
    let res = match sim.run(&inputs) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let mut t = Table::new(
        format!(
            "{} pipeline, {} frames ({})",
            qm.name,
            inputs.len(),
            if sim.fully_parallel {
                "fully-parallel reference"
            } else {
                "continuous flow"
            }
        ),
        &["Layer", "unit", "count", "useful ops", "utilization"],
    );
    for s in &res.stats {
        t.row(&[
            s.name.clone(),
            s.unit_kind.to_string(),
            s.units.to_string(),
            s.useful_ops.to_string(),
            format!("{:.1}%", s.utilization * 100.0),
        ]);
    }
    println!("{t}");
    println!(
        "latency (frame 0): {} cycles; steady state: {:.1} cycles/frame; total {} cycles",
        res.first_frame_latency, res.cycles_per_frame, res.total_cycles
    );
    0
}

/// Resolve `--engine`, failing loudly on a typo — silently falling back
/// to the compiled default would run the wrong engine while looking
/// green (mirrors `EngineKind::from_env`, which panics on bad values).
fn engine_flag(opts: &HashMap<String, String>) -> Result<EngineKind, String> {
    match opts.get("engine") {
        None => Ok(EngineKind::default_from_env()),
        Some(s) => EngineKind::parse(s).ok_or_else(|| {
            format!("unknown engine '{s}' (expected compiled | folded | interp | interpreter)")
        }),
    }
}

/// Resolve `--net-core` (threaded | evented) with the same fail-loudly
/// contract as [`engine_flag`]; the default honours `$CNN_FLOW_NET`
/// (see [`NetCore::from_env`]) so CI matrix legs can force the evented
/// core through every serve invocation.
fn net_core_flag(opts: &HashMap<String, String>) -> Result<NetCore, String> {
    match opts.get("net-core") {
        None => Ok(NetCore::default_from_env()),
        Some(s) => NetCore::parse(s)
            .ok_or_else(|| format!("unknown net core '{s}' (expected threaded | evented)")),
    }
}

/// Parse an on/off switch value (`--admission`, `--trace`,
/// `--profile`); a bare flag comes through `parse_flags` as `"true"`.
fn on_off(s: &str) -> Option<bool> {
    match s.to_ascii_lowercase().as_str() {
        "on" | "true" | "1" => Some(true),
        "off" | "false" | "0" => Some(false),
        _ => None,
    }
}

/// Resolve a model name to a `QModel`: zoo names synthesize weights
/// with the stable per-name seed; anything else goes through the
/// artifact loader.
fn resolve_qmodel(name: &str) -> Result<QModel, String> {
    if let Some(model) = zoo::by_name(name) {
        return QModel::synthesize(&model, model_seed(&model.name))
            .map_err(|e| format!("{name}: {e}"));
    }
    load_qmodel(name)
}

/// Stable per-model weight seed for the synthesized serving zoo, derived
/// from the model name so repeated runs (and tests) agree.
fn model_seed(name: &str) -> u64 {
    name.bytes()
        .fold(0xCBF29CE484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001B3))
}

/// Canonicalize `--models` aliases through the zoo, dedupe, and lower
/// each config exactly once through the `ModelRegistry` (`digits` and
/// `digits_cnn` name the same config, which is lowered and seeded once
/// under its canonical name and hosted by exactly one group). Prints the
/// registry stats and per-model predictions; returns `(model id,
/// pre-lowered pipeline)` pairs ready for `Server::start_multi`.
fn lower_zoo_models(list: &str) -> Result<Vec<(String, PipelineSim)>, String> {
    use cnn_flow::runtime::ModelRegistry;

    let mut names: Vec<String> = Vec::new();
    for raw in list.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let Some(model) = zoo::by_name(raw) else {
            return Err(format!("unknown zoo model '{raw}' (see `cnn-flow list`)"));
        };
        if !names.contains(&model.name) {
            names.push(model.name.clone());
        }
    }
    if names.is_empty() {
        return Err("--models needs at least one zoo model name".into());
    }
    let registry = ModelRegistry::new(names.len());
    let mut lowered = Vec::new();
    for name in &names {
        // `names` only holds canonical zoo names resolved above, so the
        // lookup cannot miss; synthesis errors keep their typed rendering
        // (model, block index, reason) through the registry.
        let model = zoo::by_name(name).expect("canonical zoo name");
        let bundle =
            registry.get_or_lower(name, || QModel::synthesize(&model, model_seed(name)));
        match bundle {
            Ok(b) => lowered.push(b),
            Err(e) => return Err(format!("{name}: {e}")),
        }
    }
    let rs = registry.stats();
    println!(
        "registry: {}/{} models cached ({} hits, {} misses, {} evictions)",
        rs.cached,
        registry.capacity(),
        rs.hits,
        rs.misses,
        rs.evictions
    );
    for (name, b) in names.iter().zip(&lowered) {
        println!(
            "  {name}: {} inputs, predicted {} cycles/frame steady ({:.2} MInf/s at 600 MHz)",
            b.input_len(),
            b.pipeline.predicted.steady_cycles_per_frame,
            b.pipeline.predicted.throughput_fps(600.0e6) / 1e6,
        );
    }
    Ok(names
        .into_iter()
        .zip(lowered.iter().map(|b| b.pipeline.clone()))
        .collect())
}

/// Shared `serve` flag parsing — one place wires a `ServerConfig` flag
/// for every serve mode (`--model`, `--models`, `--listen`), so a new
/// flag cannot be silently honored by one mode and ignored by another.
/// Per-mode defaults come in as arguments; `verify_every` starts at 0
/// (only the single-artifact-model paths opt into the PJRT verifier).
fn serve_config(
    opts: &HashMap<String, String>,
    workers_default: usize,
    max_batch_default: usize,
    deadline_default_us: u64,
) -> Result<ServerConfig, String> {
    let workers = opts
        .get("workers")
        .and_then(|s| s.parse().ok())
        .unwrap_or(workers_default);
    // --max-batch is the micro-batch bound; --batch stays as an alias.
    let max_batch = opts
        .get("max-batch")
        .or_else(|| opts.get("batch"))
        .and_then(|s| s.parse().ok())
        .unwrap_or(max_batch_default);
    let batch_deadline_us = opts
        .get("batch-deadline")
        .and_then(|s| s.parse().ok())
        .unwrap_or(deadline_default_us);
    let queue_depth = opts
        .get("queue-depth")
        .and_then(|s| s.parse().ok())
        .unwrap_or(256);
    let mut config = ServerConfig {
        workers,
        max_batch,
        queue_depth,
        verify_every: 0,
        engine: engine_flag(opts)?,
        batch_deadline: std::time::Duration::from_micros(batch_deadline_us),
        // dispatch/admission/autoscale default from their env overrides
        // ($CNN_FLOW_DISPATCH / $CNN_FLOW_ADMISSION / $CNN_FLOW_AUTOSCALE)
        // via `ServerConfig::default`; the flags below win over both.
        ..Default::default()
    };
    if let Some(s) = opts.get("dispatch") {
        config.dispatch = DispatchKind::parse(s)
            .ok_or_else(|| format!("--dispatch {s}: expected predictive|roundrobin"))?;
    }
    if let Some(s) = opts.get("admission") {
        config.admission =
            on_off(s).ok_or_else(|| format!("--admission {s}: expected on|off"))?;
    }
    if let Some(s) = opts.get("autoscale") {
        config.autoscale = AutoscaleConfig::parse(s)
            .ok_or_else(|| format!("--autoscale {s}: expected on|off|MIN:MAX"))?;
    }
    // Observability switches (DESIGN.md §13); the defaults honour
    // $CNN_FLOW_TRACE via `ServerConfig::default`.
    if let Some(s) = opts.get("trace") {
        config.trace = on_off(s).ok_or_else(|| format!("--trace {s}: expected on|off"))?;
    }
    if let Some(s) = opts.get("profile") {
        config.profile = on_off(s).ok_or_else(|| format!("--profile {s}: expected on|off"))?;
    }
    Ok(config)
}

/// Dump the machine-readable metrics report (`--metrics-json PATH`).
fn write_metrics_json(
    path: &str,
    aggregate: &MetricsSnapshot,
    per_model: &[ModelMetricsSnapshot],
    net: Option<&NetMetricsSnapshot>,
) -> Result<(), String> {
    let doc = metrics_report_json(aggregate, per_model, net);
    std::fs::write(path, doc.render_pretty()).map_err(|e| format!("write {path}: {e}"))
}

/// Periodic-flush variant (`--metrics-interval`): write to `<path>.tmp`
/// and atomically rename over `path`, so a concurrent reader never
/// observes a half-written report.
fn write_metrics_json_atomic(
    path: &str,
    aggregate: &MetricsSnapshot,
    per_model: &[ModelMetricsSnapshot],
    net: Option<&NetMetricsSnapshot>,
) -> Result<(), String> {
    let doc = metrics_report_json(aggregate, per_model, net);
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, doc.render_pretty()).map_err(|e| format!("write {tmp}: {e}"))?;
    std::fs::rename(&tmp, path).map_err(|e| format!("rename {tmp} -> {path}: {e}"))
}

/// `serve --models a,b,c`: lower each zoo config once through the
/// `ModelRegistry`, serve them behind per-model shard groups, replay a
/// seeded heterogeneous trace checked bit-for-bit against each model's
/// own golden sim, and report per-model + aggregate metrics.
fn cmd_serve_multi(list: &str, opts: &HashMap<String, String>) -> i32 {
    use cnn_flow::coordinator::loadgen;

    let requests: usize = opts
        .get("requests")
        .and_then(|s| s.parse().ok())
        .unwrap_or(256);
    let config = match serve_config(opts, 2, 8, 200) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let workers = config.workers;
    let engine = config.engine;
    if opts.contains_key("verify-every") {
        eprintln!("note: --verify-every is ignored with --models (no PJRT golden verifier on the synthesized zoo path)");
    }

    let models = match lower_zoo_models(list) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };

    let mut server = match Server::start_multi(models.clone(), config, None) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };

    let specs: Vec<(String, usize)> = models
        .iter()
        .map(|(id, sim)| (id.clone(), sim.input_len()))
        .collect();
    let trace = loadgen::MultiTrace::seeded(0x517A, requests, &specs, 1);
    let sims: Vec<&PipelineSim> = models.iter().map(|(_, sim)| sim).collect();
    let expected = loadgen::golden_outputs_multi(&sims, &trace);
    let started = bench::Stopwatch::start();
    let report = loadgen::replay_multi(&server, &trace, 4 * workers.max(1), Some(&expected));
    let elapsed = started.elapsed();
    server.drain();

    let m = server.metrics();
    println!(
        "served {}/{} requests in {elapsed:?} ({} mismatched, {} rejected)",
        report.aggregate.ok, requests, report.aggregate.mismatched, report.aggregate.rejected
    );
    let mut t = Table::new(
        format!("per-model serving stats ({engine:?} engine)"),
        &["model", "shards", "completed", "batches", "mean batch", "p99", "agg MInf/s"],
    );
    for (mm, rep) in server.model_metrics().iter().zip(&report.per_model) {
        t.row(&[
            mm.model.clone(),
            mm.metrics.workers.to_string(),
            format!("{} ({} ok)", mm.metrics.completed, rep.ok),
            mm.metrics.batches.to_string(),
            format!("{:.1}", mm.metrics.mean_batch),
            format!("{:?}", mm.metrics.p99),
            format!("{:.2}", mm.metrics.aggregate_fps / 1e6),
        ]);
    }
    println!("{t}");
    println!(
        "aggregate: {} models, {} shards, {} completed, mean batch {:.1}, \
         {:.2} MInf/s aggregate, {} predicted cycles, {} divergent groups",
        m.models,
        m.workers,
        m.completed,
        m.mean_batch,
        m.aggregate_fps / 1e6,
        m.predicted_cycles,
        m.cycle_divergence
    );
    if let Some(path) = opts.get("metrics-json") {
        if let Err(e) = write_metrics_json(path, &m, &server.model_metrics(), None) {
            eprintln!("{e}");
            return 1;
        }
        println!("wrote {path}");
    }
    if report.aggregate.mismatched > 0 {
        eprintln!("PER-MODEL GOLDEN MISMATCHES DETECTED");
        return 1;
    }
    if m.occupancy_frames != m.completed + m.errored {
        eprintln!("METRICS RECONCILIATION FAILED");
        return 1;
    }
    0
}

/// `serve --listen host:port`: expose the coordinator over TCP. Hosts
/// either the zoo fleet (`--models a,b,c`, registry-lowered) or a single
/// model (`--model`/`--synthetic`), prints the bound address, then
/// serves until stdin reaches EOF — at which point the net front-end
/// drains gracefully (in-flight requests complete, sockets close) and
/// the final coordinator + net metrics are reported.
fn cmd_serve_listen(addr: &str, opts: &HashMap<String, String>) -> i32 {
    let mut config = match serve_config(opts, 2, 16, 1000) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };

    let server = if let Some(list) = opts.get("models") {
        let models = match lower_zoo_models(list) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        Server::start_multi(models, config, None)
    } else if opts.contains_key("synthetic") {
        Server::start(QModel::synthetic(12, 8, 10, 0xF1C), config, None)
    } else {
        let name = opts.get("model").map(String::as_str).unwrap_or("digits");
        let qm = match load_qmodel(name) {
            Ok(q) => q,
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        };
        config.verify_every = opts
            .get("verify-every")
            .and_then(|s| s.parse().ok())
            .unwrap_or(8);
        Server::start(qm, config, Some(name.to_string()))
    };
    let server = match server {
        Ok(s) => std::sync::Arc::new(s),
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };

    let core = match net_core_flag(opts) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mut net = match FrontEnd::bind(core, addr, std::sync::Arc::clone(&server)) {
        Ok(n) => n,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let bound = net.local_addr();
    let routed: Vec<String> = server
        .model_specs()
        .iter()
        .map(|(id, len)| format!("{id} ({len} inputs)"))
        .collect();
    println!("listening on {bound} ({core} core) — routing {}", routed.join(", "));
    println!("serving until stdin reaches EOF (try `cnn-flow client --connect {bound}`)");

    // Live observability taps (DESIGN.md §13). Both render from shared
    // handles, so they keep serving fresh snapshots while this thread
    // blocks on stdin below.
    let net_metrics = net.metrics_handle();
    let reactor = net.reactor_handle();
    let mut metrics_ep = match opts.get("metrics-listen") {
        Some(maddr) => {
            let render_server = std::sync::Arc::clone(&server);
            let nm = std::sync::Arc::clone(&net_metrics);
            let rs = reactor.clone();
            match cnn_flow::obs::TextEndpoint::bind(maddr, move || {
                let rsnap = rs.as_ref().map(|r| r.snapshot());
                render_server.metrics_text(Some(&nm.snapshot()), rsnap.as_ref())
            }) {
                Ok(ep) => {
                    println!(
                        "metrics exposition on {} (plain TCP: one page per connection)",
                        ep.local_addr()
                    );
                    Some(ep)
                }
                Err(e) => {
                    eprintln!("{e}");
                    return 1;
                }
            }
        }
        None => None,
    };
    let flush_stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let flush_thread = match opts.get("metrics-interval") {
        Some(secs) => {
            let period: u64 = match secs.parse() {
                Ok(p) if p > 0 => p,
                _ => {
                    eprintln!(
                        "--metrics-interval {secs}: expected a positive whole number of seconds"
                    );
                    return 2;
                }
            };
            let Some(path) = opts.get("metrics-json").cloned() else {
                eprintln!("--metrics-interval needs --metrics-json PATH (the file it refreshes)");
                return 2;
            };
            let s = std::sync::Arc::clone(&server);
            let nm = std::sync::Arc::clone(&net_metrics);
            let stop = std::sync::Arc::clone(&flush_stop);
            println!("refreshing {path} every {period}s (atomic rename)");
            Some(std::thread::spawn(move || {
                let period = std::time::Duration::from_secs(period);
                let nap = std::time::Duration::from_millis(50);
                let mut last = bench::Stopwatch::start();
                while !stop.load(std::sync::atomic::Ordering::Acquire) {
                    if last.elapsed() < period {
                        std::thread::sleep(nap);
                        continue;
                    }
                    last = bench::Stopwatch::start();
                    let snap = nm.snapshot();
                    if let Err(e) =
                        write_metrics_json_atomic(&path, &s.metrics(), &s.model_metrics(), Some(&snap))
                    {
                        eprintln!("{e}");
                    }
                }
            }))
        }
        None => None,
    };

    // Block until the controlling stdin closes, then drain.
    let mut buf = [0u8; 4096];
    let mut stdin = std::io::stdin();
    while matches!(std::io::Read::read(&mut stdin, &mut buf), Ok(n) if n > 0) {}

    flush_stop.store(true, std::sync::atomic::Ordering::Release);
    if let Some(h) = flush_thread {
        let _ = h.join();
    }
    if let Some(ep) = metrics_ep.as_mut() {
        ep.shutdown();
    }
    let net_snap = net.shutdown(); // drains the coordinator too
    let m = server.metrics();
    if let Some(r) = net.reactor_stats() {
        println!(
            "reactor: {} polls, {} events, {} wakeups, {} completions, {} read-pauses, \
             {} stall-teardowns",
            r.polls, r.events, r.wakeups, r.completions, r.read_pauses, r.stall_teardowns
        );
    }
    println!(
        "net: {} connection(s), {} request(s), {} ok, {} queue-full, {} slo-miss, \
         {} invalid-frame, {} unknown-model, {} draining, {} malformed",
        net_snap.connections,
        net_snap.requests,
        net_snap.responses_ok,
        net_snap.err_queue_full,
        net_snap.err_slo_miss,
        net_snap.err_invalid_frame,
        net_snap.err_unknown_model,
        net_snap.err_draining,
        net_snap.err_malformed
    );
    println!(
        "coordinator: {} completed, {} batches (mean {:.1}), {} rejected, {} shed, \
         {} unrouted, {}/{} shards active (+{}/-{} scale events), p99 {:?}, \
         {:.2} MInf/s aggregate",
        m.completed,
        m.batches,
        m.mean_batch,
        m.rejected,
        m.shed,
        m.unrouted,
        m.active_workers,
        m.workers,
        m.scale_up_events,
        m.scale_down_events,
        m.p99,
        m.aggregate_fps / 1e6
    );
    if let Some(path) = opts.get("metrics-json") {
        if let Err(e) = write_metrics_json(path, &m, &server.model_metrics(), Some(&net_snap)) {
            eprintln!("{e}");
            return 1;
        }
        println!("wrote {path}");
    }
    0
}

/// `cnn-flow client --connect host:port`: the TCP counterpart of `serve
/// --listen`. Queries the server's model list, sends seeded random
/// traffic at the requested model (default: the first route), and
/// reports wall-clock latency quantiles and throughput.
fn cmd_client(opts: &HashMap<String, String>) -> i32 {
    let Some(addr) = opts.get("connect") else {
        eprintln!("client requires --connect <host:port>");
        return 2;
    };
    let requests: usize = opts
        .get("requests")
        .and_then(|s| s.parse().ok())
        .unwrap_or(64);
    let pool: usize = opts.get("pool").and_then(|s| s.parse().ok()).unwrap_or(2);
    let seed: u64 = opts
        .get("seed")
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC11E27);
    // v2 SLO envelope: 0/0 keeps the request byte-identical to v1.
    let deadline_us: u64 = opts
        .get("deadline-us")
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let class: u8 = opts.get("class").and_then(|s| s.parse().ok()).unwrap_or(0);
    let client = match Client::connect(addr, pool) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let specs = match client.models() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    println!("server routes {} model(s):", specs.len());
    for (id, len) in &specs {
        println!("  {id}: {len} inputs");
    }
    let (model, input_len) = match opts.get("model") {
        Some(want) => match specs.iter().find(|(id, _)| id == want) {
            Some(s) => s.clone(),
            None => {
                eprintln!("server has no route for '{want}'");
                return 1;
            }
        },
        None => match specs.first() {
            Some(s) => s.clone(),
            None => {
                eprintln!("server advertises no models");
                return 1;
            }
        },
    };

    let mut rng = Rng::new(seed);
    let mut latencies = Vec::with_capacity(requests);
    let mut errors = 0usize;
    let mut shed = 0usize;
    let mut slo_met = 0usize;
    let started = bench::Stopwatch::start();
    for _ in 0..requests {
        let frame: Vec<i64> = (0..input_len).map(|_| rng.int8() as i64).collect();
        let t0 = bench::Stopwatch::start();
        match client.infer_slo(&model, &frame, deadline_us, class) {
            Ok(resp) => {
                latencies.push(t0.elapsed());
                if resp.slo_met {
                    slo_met += 1;
                }
            }
            Err(e) if e.code == Some(cnn_flow::net::proto::ErrorCode::SloMiss) => shed += 1,
            Err(e) => {
                errors += 1;
                if errors <= 3 {
                    eprintln!("{e}");
                }
            }
        }
    }
    let wall = started.elapsed();
    latencies.sort();
    let quantile = |q: f64| -> std::time::Duration {
        if latencies.is_empty() {
            std::time::Duration::ZERO
        } else {
            let idx = ((latencies.len() as f64 * q) as usize).min(latencies.len() - 1);
            latencies[idx]
        }
    };
    println!(
        "{}: {}/{} ok in {wall:?} ({:.0} req/s), p50 {:?}, p99 {:?}",
        model,
        latencies.len(),
        requests,
        latencies.len() as f64 / wall.as_secs_f64().max(1e-9),
        quantile(0.50),
        quantile(0.99),
    );
    if deadline_us > 0 {
        println!(
            "slo: {slo_met}/{} met ({deadline_us} us budget), {shed} shed at admission",
            latencies.len()
        );
    }
    if errors > 0 {
        eprintln!("{errors} request(s) failed");
        return 1;
    }
    0
}

/// `cnn-flow trace`: run a traced serving session (flight recorder on)
/// and dump the per-stage latency quantiles plus the span/intake
/// reconciliation identity (DESIGN.md §13).
fn cmd_trace(opts: &HashMap<String, String>) -> i32 {
    let requests: usize = opts
        .get("requests")
        .and_then(|s| s.parse().ok())
        .unwrap_or(256);
    let mut config = match serve_config(opts, 2, 8, 200) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    config.trace = true;
    let qm = match opts.get("model") {
        Some(name) => match resolve_qmodel(name) {
            Ok(q) => q,
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        },
        None => QModel::synthetic(12, 8, 10, 0xF1C),
    };
    let server = match Server::start(qm.clone(), config, None) {
        Ok(s) => std::sync::Arc::new(s),
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let input_len: usize = qm.input_shape.iter().map(|&d| d.max(1)).product();
    let vectors: Vec<Vec<i64>> = if qm.test_vectors.is_empty() {
        let mut rng = Rng::new(0x7ACE);
        (0..64)
            .map(|_| (0..input_len).map(|_| rng.int8() as i64).collect())
            .collect()
    } else {
        qm.test_vectors.iter().map(|tv| tv.x_q.clone()).collect()
    };
    let mut handles = Vec::new();
    for c in 0..4usize {
        let s = std::sync::Arc::clone(&server);
        let vectors = vectors.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..requests / 4 {
                let _ = s.infer(vectors[(c + i) % vectors.len()].clone());
            }
        }));
    }
    for h in handles {
        let _ = h.join();
    }
    let mut server = match std::sync::Arc::try_unwrap(server) {
        Ok(s) => s,
        Err(_) => {
            eprintln!("internal error: client threads still hold the server");
            return 1;
        }
    };
    server.drain();

    let rec = server.flight_recorder().expect("trace was enabled");
    let spans = rec.spans();
    let stats = rec.stats();
    let mut t = Table::new(
        format!(
            "{} trace: {} span(s) retained ({} recorded, {} dropped, ring capacity {})",
            qm.name, stats.retained, stats.spans_recorded, stats.spans_dropped, stats.capacity
        ),
        &["stage", "count", "p50", "p95", "p99"],
    );
    for s in cnn_flow::obs::stage_summary(&spans) {
        t.row(&[
            s.stage.to_string(),
            s.count.to_string(),
            format!("{:?}", std::time::Duration::from_nanos(s.p50_ns)),
            format!("{:?}", std::time::Duration::from_nanos(s.p95_ns)),
            format!("{:?}", std::time::Duration::from_nanos(s.p99_ns)),
        ]);
    }
    println!("{t}");
    let m = server.metrics();
    let terminal = m.completed + m.errored + m.rejected + m.shed;
    println!(
        "reconciliation: {} recorded + {} dropped vs {} terminal outcomes \
         ({} completed, {} errored, {} rejected, {} shed)",
        stats.spans_recorded,
        stats.spans_dropped,
        terminal,
        m.completed,
        m.errored,
        m.rejected,
        m.shed
    );
    if stats.spans_recorded + stats.spans_dropped != terminal {
        eprintln!("SPAN RECONCILIATION FAILED");
        return 1;
    }
    0
}

/// `cnn-flow profile <model>`: run a profiled serving session and print
/// the divergence table between the measured per-layer time share and
/// the analytic cycle share from `SchedulePrediction::cycle_shares`,
/// alongside the folded-unit figures from `FoldedPrediction` — the
/// software analogue of the paper's per-layer utilization tables.
fn cmd_profile(rest: &[String], opts: &HashMap<String, String>) -> i32 {
    let name = rest
        .first()
        .filter(|a| !a.starts_with("--"))
        .map(String::as_str)
        .or_else(|| opts.get("model").map(String::as_str))
        .unwrap_or("mobilenet_micro");
    let requests: usize = opts
        .get("requests")
        .and_then(|s| s.parse().ok())
        .unwrap_or(256)
        .max(1);
    let mut config = match serve_config(opts, 2, 8, 200) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    config.profile = true;
    let engine = config.engine;
    let qm = match resolve_qmodel(name) {
        Ok(q) => q,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let sim = match PipelineSim::new(qm.clone(), None) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let predicted = sim.predicted.clone();
    let shares = predicted.cycle_shares();
    let folded = predicted.folded(requests, &sim.fold_factors);
    let mut server = match Server::start_prelowered(sim, config, None) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let input_len: usize = qm.input_shape.iter().map(|&d| d.max(1)).product();
    let mut rng = Rng::new(0x9F0F11E);
    for _ in 0..requests {
        let frame: Vec<i64> = (0..input_len).map(|_| rng.int8() as i64).collect();
        if let Err(e) = server.infer(frame) {
            eprintln!("{e}");
            return 1;
        }
    }
    server.drain();

    let profiles = server.layer_profiles();
    let Some((_, rows)) = profiles.into_iter().next() else {
        eprintln!("no profile rows recorded");
        return 1;
    };
    let samples: u64 = rows.iter().map(|r| r.samples).sum();
    let mut t = Table::new(
        format!(
            "{} per-layer profile ({requests} requests, {engine:?} engine)",
            qm.name
        ),
        &[
            "Layer",
            "units",
            "analytic",
            "measured",
            "delta",
            "samples",
            "fold",
            "folded units",
            "folded util",
        ],
    );
    for (i, l) in predicted.layers.iter().enumerate() {
        let measured = rows.get(i);
        let m_share = measured.map(|r| r.measured_share).unwrap_or(0.0);
        let analytic = shares.get(i).copied().unwrap_or(0.0);
        t.row(&[
            l.name.clone(),
            l.units.to_string(),
            format!("{:.1}%", analytic * 100.0),
            format!("{:.1}%", m_share * 100.0),
            format!("{:+.1}%", (m_share - analytic) * 100.0),
            measured.map(|r| r.samples).unwrap_or(0).to_string(),
            folded.fold_factors.get(i).copied().unwrap_or(1).to_string(),
            folded.folded_units.get(i).copied().unwrap_or(0).to_string(),
            format!(
                "{:.1}%",
                folded.utilization.get(i).copied().unwrap_or(0.0) * 100.0
            ),
        ]);
    }
    println!("{t}");
    println!(
        "analytic = SchedulePrediction::cycle_shares (ops/frame per unit); \
         folded columns = SchedulePrediction::folded at batch {} (exact: {})",
        folded.batch, folded.exact
    );
    if samples == 0 {
        eprintln!(
            "note: no per-layer samples recorded — the {engine:?} engine does not feed the \
             profiler (use --engine compiled or folded)"
        );
    }
    0
}

fn cmd_serve(opts: &HashMap<String, String>) -> i32 {
    if let Some(addr) = opts.get("listen") {
        return cmd_serve_listen(addr, opts);
    }
    if let Some(list) = opts.get("models") {
        return cmd_serve_multi(list, opts);
    }
    let name = opts.get("model").map(String::as_str).unwrap_or("digits");
    let requests: usize = opts
        .get("requests")
        .and_then(|s| s.parse().ok())
        .unwrap_or(256);
    let mut config = match serve_config(opts, 1, 16, 1000) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    config.verify_every = opts
        .get("verify-every")
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let engine = config.engine;
    // --synthetic serves the artifact-free fixture (no golden verifier).
    let (qm, verify_model) = if opts.contains_key("synthetic") {
        (QModel::synthetic(12, 8, 10, 0xF1C), None)
    } else {
        match load_qmodel(name) {
            Ok(q) => (q, Some(name.to_string())),
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        }
    };
    // Plan + lower once; every shard clones the compiled state.
    let sim = match PipelineSim::new(qm.clone(), None) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let lowered = if sim.compiled.is_narrow() {
        "narrow/i32"
    } else {
        "wide/i64"
    };
    println!(
        "engine: {engine:?} (lowered {lowered}, predicted {} cycles/frame steady, {} cycles frame-0 latency)",
        sim.predicted.steady_cycles_per_frame,
        sim.predicted.first_frame_latency,
    );
    let server = match Server::start_prelowered(sim, config, verify_model) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let vectors: Vec<Vec<i64>> = if qm.test_vectors.is_empty() {
        let input_len: usize = qm.input_shape.iter().map(|&d| d.max(1)).product();
        let mut rng = cnn_flow::util::Rng::new(0x5E21);
        (0..64)
            .map(|_| (0..input_len).map(|_| rng.int8() as i64).collect())
            .collect()
    } else {
        qm.test_vectors.iter().map(|tv| tv.x_q.clone()).collect()
    };
    let started = bench::Stopwatch::start();
    let server = std::sync::Arc::new(server);
    let mut handles = Vec::new();
    for c in 0..4usize {
        let s = std::sync::Arc::clone(&server);
        let vectors = vectors.clone();
        handles.push(std::thread::spawn(move || {
            let mut ok = 0usize;
            for i in 0..requests / 4 {
                let x = vectors[(c + i) % vectors.len()].clone();
                if s.infer(x).is_ok() {
                    ok += 1;
                }
            }
            ok
        }));
    }
    let served: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let elapsed = started.elapsed();
    // Graceful drain: joins the workers and the verifier (which empties
    // its sampling queue first), so the final snapshot is deterministic.
    let mut server = match std::sync::Arc::try_unwrap(server) {
        Ok(s) => s,
        Err(_) => {
            eprintln!("internal error: client threads still hold the server");
            return 1;
        }
    };
    server.drain();
    let m = server.metrics();
    println!(
        "served {served}/{requests} requests in {elapsed:?} ({:.0} req/s wall)",
        served as f64 / elapsed.as_secs_f64()
    );
    println!(
        "coordinator: {} shard(s), mean batch {:.1}, mean service {:?} (p50 {:?}, p95 {:?}, p99 {:?})",
        m.workers, m.mean_batch, m.mean_service, m.p50, m.p95, m.p99
    );
    println!(
        "micro-batching: {} batches ({} full, {} deadline, {} drain), {} frames batched",
        m.batches, m.flush_full, m.flush_deadline, m.flush_drain, m.occupancy_frames
    );
    let occupied: Vec<String> = m
        .batch_occupancy
        .iter()
        .enumerate()
        .filter(|(_, &c)| c > 0)
        .map(|(i, &c)| {
            // The final slot is the overflow bucket: batches larger than
            // OCC_BUCKETS frames (exact buckets stop at OCC_BUCKETS).
            if i == cnn_flow::coordinator::metrics::OCC_BUCKETS {
                format!(">{i}x{c}")
            } else {
                format!("{}x{c}", i + 1)
            }
        })
        .collect();
    println!("batch occupancy (size x count): {}", occupied.join(" "));
    println!(
        "projected hw throughput: {:.2} MInf/s per pipeline, {:.2} MInf/s aggregate ({} shards)",
        m.projected_fps / 1e6,
        m.aggregate_fps / 1e6,
        m.workers
    );
    let mut t = Table::new(
        "per-shard serving stats".to_string(),
        &["shard", "completed", "batches", "busy cycles", "p50", "p99"],
    );
    for s in server.shard_metrics() {
        t.row(&[
            s.shard.to_string(),
            s.completed.to_string(),
            s.batches.to_string(),
            s.busy_cycles.to_string(),
            format!("{:?}", s.p50),
            format!("{:?}", s.p99),
        ]);
    }
    println!("{t}");
    println!(
        "cycle model: {} predicted cycles, {} interpreter-simulated, {} divergent groups",
        m.predicted_cycles, m.simulated_cycles, m.cycle_divergence
    );
    println!(
        "golden cross-check: {} verified, {} mismatches",
        m.verified, m.mismatches
    );
    if let Some(path) = opts.get("metrics-json") {
        if let Err(e) = write_metrics_json(path, &m, &server.model_metrics(), None) {
            eprintln!("{e}");
            return 1;
        }
        println!("wrote {path}");
    }
    if m.mismatches > 0 {
        eprintln!("GOLDEN MISMATCHES DETECTED");
        return 1;
    }
    if m.cycle_divergence > 0 {
        eprintln!("SCHEDULE PREDICTION DIVERGED FROM THE INTERPRETER");
        return 1;
    }
    0
}

/// `cnn-flow bench`: interpreter vs compiled frames/sec per model, with
/// the comparison persisted to BENCH_pipeline.json (machine-readable, so
/// the perf trajectory is tracked across PRs).
fn cmd_bench(opts: &HashMap<String, String>) -> i32 {
    let frames_n: usize = opts
        .get("frames")
        .and_then(|s| s.parse().ok())
        .unwrap_or(16)
        .max(1);
    let out_path = opts
        .get("out")
        .cloned()
        .unwrap_or_else(|| "BENCH_pipeline.json".to_string());
    // Artifact models when present (unless --synthetic), plus the
    // always-available synthetic digits-shaped fixture and the serving
    // zoo configs — every BENCH_pipeline.json row names the model that
    // produced its figures, so mixed reports stay attributable.
    let mut models: Vec<QModel> = Vec::new();
    if !opts.contains_key("synthetic") {
        for name in ["digits", "jsc"] {
            if let Ok(qm) = load_qmodel(name) {
                models.push(qm);
            }
        }
    }
    models.push(QModel::synthetic(12, 8, 10, 0xBE7C));
    for zm in zoo::serving_zoo() {
        match QModel::synthesize(&zm, model_seed(&zm.name)) {
            Ok(qm) => models.push(qm),
            Err(e) => {
                eprintln!("{}: {e}", zm.name);
                return 1;
            }
        }
    }
    let b = bench::Bencher::with_opts(
        "pipeline-cli",
        bench::BenchOpts {
            warmup: std::time::Duration::from_millis(100),
            measure: std::time::Duration::from_millis(400),
            max_iters: 100_000,
        },
    );
    let mut comparisons = Vec::new();
    for qm in models {
        let name = qm.name.clone();
        let input_len: usize = qm.input_shape.iter().map(|&d| d.max(1)).product();
        let sim = match PipelineSim::new(qm.clone(), None) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("{name}: {e}");
                return 1;
            }
        };
        let frames: Vec<Vec<i64>> = if qm.test_vectors.is_empty() {
            let mut rng = cnn_flow::util::Rng::new(0xF2A);
            (0..frames_n)
                .map(|_| (0..input_len).map(|_| rng.int8() as i64).collect())
                .collect()
        } else {
            qm.test_vectors
                .iter()
                .cycle()
                .take(frames_n)
                .map(|tv| tv.x_q.clone())
                .collect()
        };
        let cmp = bench::compare_engines(&b, &sim, &frames);
        println!(
            "{name}: interpreter {:.3}M frames/s, compiled {:.3}M frames/s ({:.1}x), \
             batched {:.3}M frames/s ({:.2}x over single-frame), \
             folded {:.3}M frames/s ({:.2}x over batched)",
            cmp.interp_fps() / 1e6,
            cmp.compiled_fps() / 1e6,
            cmp.speedup(),
            cmp.batched_fps() / 1e6,
            cmp.batch_speedup(),
            cmp.folded_fps() / 1e6,
            cmp.fold_speedup()
        );
        comparisons.push(cmp);
    }
    if let Err(e) = bench::write_pipeline_bench_json(std::path::Path::new(&out_path), &comparisons)
    {
        eprintln!("{e}");
        return 1;
    }
    println!("wrote {out_path}");
    let fanin_max: usize = opts
        .get("fanin")
        .and_then(|s| s.parse().ok())
        .unwrap_or(1024);
    if fanin_max > 0 {
        match bench_fanin(fanin_max) {
            Ok(rows) if rows.is_empty() => {}
            Ok(rows) => {
                if let Err(e) =
                    bench::merge_fanin_bench_json(std::path::Path::new(&out_path), &rows)
                {
                    eprintln!("{e}");
                    return 1;
                }
                println!("merged fan-in ladder into {out_path}");
            }
            Err(e) => {
                eprintln!("fan-in bench: {e}");
                return 1;
            }
        }
    }
    0
}

/// Connections-vs-throughput and RTT-under-fan-in: drive the same
/// fan-in load at both network cores over a fresh coordinator per rung,
/// so the per-rung metrics are isolated. The ladder tops out at
/// `fanin_max` concurrent connections (`--fanin 0` skips it entirely —
/// e.g. on fd-limited machines).
#[cfg(unix)]
fn bench_fanin(fanin_max: usize) -> Result<Vec<bench::FanInComparison>, String> {
    let mut rungs: Vec<usize> = [64usize, 256, 1024]
        .into_iter()
        .filter(|&c| c <= fanin_max)
        .collect();
    if rungs.is_empty() {
        rungs.push(fanin_max);
    }
    cnn_flow::net::fanin::ladder(&rungs, 16)
}

#[cfg(not(unix))]
fn bench_fanin(_fanin_max: usize) -> Result<Vec<bench::FanInComparison>, String> {
    eprintln!("note: skipping the fan-in ladder (the evented core requires a unix platform)");
    Ok(Vec::new())
}
