//! # cnn-flow
//!
//! Reproduction of *Continuous-Flow Data-Rate-Aware CNN Inference on FPGA*
//! (Habermann, Mecik, Wang, Vera, Kumm, Garrido — TCAS-AI 2026).
//!
//! The crate provides, as a library plus a CLI (`cnn-flow`):
//!
//! * [`model`] — a layer-graph IR and the paper's model zoo,
//! * [`flow`] — exact data-rate propagation (Eq. 8) and the interleaving
//!   planner (Eqs. 12-22),
//! * [`complexity`] — the closed-form resource model (Eqs. 23-37) with the
//!   fully-parallel reference, regenerating Tables V-VIII,
//! * [`sim`] — cycle-accurate, bit-accurate simulators for the KPU / PPU /
//!   FCU units (Tables I-IV) and whole-network pipelines, plus the
//!   compile-once lowered value engine ([`sim::compiled`]) and its
//!   analytic cycle model ([`flow::schedule`]) that serving executes on
//!   (DESIGN.md §4),
//! * [`quant`] — the 8-bit fixed-point substrate shared with the JAX side,
//! * [`fpga`] — the synthesis estimator standing in for Vivado
//!   (Tables IX/X, Fig. 13),
//! * [`runtime`] — PJRT loading/execution of the AOT-compiled JAX model
//!   (real backend gated behind the `pjrt-xla` cargo feature; a stub
//!   otherwise, so the default build has zero dependencies), plus the
//!   multi-model serving registry ([`runtime::ModelRegistry`]): each
//!   model id is lowered once into its compiled pipeline bundle,
//!   LRU-bounded with hit/miss/eviction counters,
//! * [`coordinator`] — the sharded streaming inference server: N worker
//!   shards each owning a [`sim::pipeline::PipelineSim`] replica, fed by a
//!   round-robin dispatcher with backpressure-aware spill;
//!   deadline-aware micro-batching (accumulate up to `max_batch` frames
//!   or until the oldest request's `batch_deadline` expires, then run
//!   the whole batch through one compiled program traversal); per-shard
//!   metrics with p50/p95/p99 latency histograms, batch occupancy and
//!   flush-reason accounting, graceful drain-on-shutdown, multi-model
//!   routing (per-model shard groups fed by a route table, tagged
//!   submits, per-model + aggregate metrics views — DESIGN.md §7), and a
//!   deterministic seeded-trace load harness ([`coordinator::loadgen`],
//!   incl. heterogeneous multi-model traces) with a virtual clock,
//! * [`net`] — the dependency-free TCP serving front-end: a versioned
//!   length-prefixed wire protocol with typed error codes mapping 1:1
//!   onto coordinator rejection reasons, a threaded pipelining server
//!   that fronts `Server::start_multi` (backpressure as protocol errors,
//!   graceful drain over sockets), a pooled blocking client, and a
//!   network replay harness whose responses are byte-identical to
//!   in-process serving (DESIGN.md §8),
//! * [`obs`] — the observability tier (DESIGN.md §13): a flight
//!   recorder of per-request stage spans on a shared wall/virtual
//!   [`obs::Clock`], an optional per-layer execute-path profiler whose
//!   measured time shares sit next to the analytic cycle shares, and
//!   Prometheus text-format exposition of every metrics snapshot
//!   (served over the wire protocol's `MetricsText` request and the
//!   `--metrics-listen` plain-TCP endpoint),
//! * [`report`] — generators that print every paper table and figure.
//!
//! Serving scale-out mirrors the companion work (*Data-Rate-Aware
//! High-Speed CNN Inference on FPGAs*): replicate the continuous-flow
//! pipeline per stream, keep each replica's frames contiguous, and measure
//! aggregate throughput as frames over the simulated makespan.

pub mod complexity;
pub mod coordinator;
pub mod flow;
pub mod fpga;
pub mod model;
pub mod net;
pub mod obs;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;
