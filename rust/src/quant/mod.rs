//! 8-bit fixed-point quantization substrate (system S7), bit-for-bit
//! compatible with `python/compile/quantize.py`.
//!
//! Scheme: symmetric per-tensor int8 (zero point 0, clamp ±127), integer
//! accumulators, bias at accumulator scale, and requantization through a
//! single f32 multiplier with round-half-away-from-zero:
//!
//! ```text
//! y_q = clamp( half_away_round( (acc as f32) * m ), -127, 127 )
//! ```
//!
//! Both sides use identical f32 operations (|acc| < 2^24 is asserted at
//! export), so the rust pipeline simulator and the JAX int8 golden model
//! must agree *exactly* — integration tests require equality.

use crate::util::json::Json;

pub const QMAX: i64 = 127;

/// Round half away from zero in f32, matching
/// `python/compile/quantize.half_away_round`.
#[inline]
pub fn half_away_round(x: f32) -> f32 {
    (x.abs() + 0.5).floor().copysign(x)
}

/// Requantize an integer accumulator to the int8 activation grid.
#[inline]
pub fn requant(acc: i64, m: f32) -> i64 {
    let y = half_away_round(acc as f32 * m) as i64;
    y.clamp(-QMAX, QMAX)
}

/// Quantize a float to the int8 grid with a given scale.
pub fn quantize(x: f32, scale: f32) -> i64 {
    (half_away_round(x / scale) as i64).clamp(-QMAX, QMAX)
}

/// One quantized layer loaded from `artifacts/weights/<model>.json`.
#[derive(Debug, Clone)]
pub struct QLayer {
    pub name: String,
    pub kind: QKind,
    pub k: usize,
    pub s: usize,
    pub p: usize,
    pub relu: bool,
    /// Quantized weights, flattened in the python export layout:
    /// conv (k,k,Cin,Cout), dwconv (k,k,C), dense (units, feats).
    pub w_q: Vec<i64>,
    pub w_shape: Vec<usize>,
    /// Accumulator-scale bias, one per output channel.
    pub b_q: Vec<i64>,
    /// Requant multiplier (exact f32 from the exporter).
    pub m: f32,
    pub in_shape: [usize; 3],
    pub out_shape: [usize; 3],
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QKind {
    Conv,
    DwConv,
    MaxPool,
    AvgPool,
    Dense,
}

impl QKind {
    fn parse(s: &str) -> Result<Self, String> {
        Ok(match s {
            "conv" => QKind::Conv,
            "dwconv" => QKind::DwConv,
            "maxpool" => QKind::MaxPool,
            "avgpool" => QKind::AvgPool,
            "dense" => QKind::Dense,
            other => return Err(format!("unknown layer kind '{other}'")),
        })
    }
}

/// Dataflow of one flat node in a DAG-lowered [`QModel`], parallel to
/// `layers`. `src == None` reads the model input.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QTopo {
    pub src: Option<usize>,
    pub merge: Option<QMerge>,
}

/// Residual merge epilogue carried by the node at the merge point: the
/// other branch's int8 output (`with`; `None` = the model input) is added
/// elementwise to this node's requantized output, optionally ReLU'd, and
/// requantized back onto the int8 grid by `m` (`0` = raw sum).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QMerge {
    pub with: Option<usize>,
    pub m: f32,
    pub relu: bool,
}

/// A quantized model plus its exporter-provided test vectors.
#[derive(Debug, Clone)]
pub struct QModel {
    pub name: String,
    pub input_shape: [usize; 3],
    pub input_scale: f32,
    pub layers: Vec<QLayer>,
    /// Per-node dataflow for residual/branching graphs, parallel to
    /// `layers`. Empty = plain chain (every exporter artifact and every
    /// chain zoo config); see [`QModel::node_topology`].
    pub topology: Vec<QTopo>,
    pub test_vectors: Vec<TestVector>,
    pub qat_accuracy: f64,
}

/// One exporter test vector: quantized input and expected final-layer
/// accumulator-scale outputs.
#[derive(Debug, Clone)]
pub struct TestVector {
    pub x_q: Vec<i64>,
    pub y: Vec<i64>,
}

fn shape3(j: &Json, key: &str) -> Result<[usize; 3], String> {
    let arr = j
        .get(key)
        .as_arr()
        .ok_or_else(|| format!("missing {key}"))?;
    if arr.len() != 3 {
        return Err(format!("{key} must have 3 dims"));
    }
    let mut out = [0usize; 3];
    for (i, v) in arr.iter().enumerate() {
        out[i] = v.as_usize().ok_or_else(|| format!("bad {key}[{i}]"))?;
    }
    Ok(out)
}

fn int_vec(j: &Json, key: &str) -> Vec<i64> {
    j.get(key)
        .as_arr()
        .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|v| v as i64).collect())
        .unwrap_or_default()
}

impl QModel {
    /// Parse the exporter's JSON manifest.
    pub fn from_json(text: &str) -> Result<QModel, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        let name = j.get("name").as_str().unwrap_or("model").to_string();
        let input_shape = shape3(&j, "input_shape")?;
        let input_scale = j
            .get("input_scale")
            .as_f64()
            .ok_or("missing input_scale")? as f32;
        let mut layers = Vec::new();
        for lj in j.get("layers").as_arr().ok_or("missing layers")? {
            let kind = QKind::parse(lj.get("kind").as_str().ok_or("layer missing kind")?)?;
            let w_shape: Vec<usize> = lj
                .get("w_shape")
                .as_arr()
                .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
                .unwrap_or_default();
            layers.push(QLayer {
                name: lj.get("name").as_str().unwrap_or("?").to_string(),
                kind,
                k: lj.get("k").as_usize().unwrap_or(0),
                s: lj.get("s").as_usize().unwrap_or(1),
                p: lj.get("p").as_usize().unwrap_or(0),
                relu: lj.get("relu").as_bool().unwrap_or(false),
                w_q: int_vec(lj, "w_q"),
                w_shape,
                b_q: int_vec(lj, "b_q"),
                m: lj.get("m").as_f64().unwrap_or(0.0) as f32,
                in_shape: shape3(lj, "in_shape")?,
                out_shape: shape3(lj, "out_shape")?,
            });
        }
        let mut test_vectors = Vec::new();
        if let Some(vs) = j.get("test_vectors").as_arr() {
            for v in vs {
                test_vectors.push(TestVector {
                    x_q: int_vec(v, "x_q"),
                    y: int_vec(v, "y"),
                });
            }
        }
        Ok(QModel {
            name,
            input_shape,
            input_scale,
            layers,
            topology: vec![],
            test_vectors,
            qat_accuracy: j.get("qat_accuracy").as_f64().unwrap_or(f64::NAN),
        })
    }

    /// Load from `artifacts/weights/<name>.json`.
    pub fn load(path: &std::path::Path) -> Result<QModel, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Self::from_json(&text)
    }

    /// Build a tiny deterministic conv→pool→dense int8 model *without*
    /// artifacts: a synthetic fixture for coordinator/pipeline tests and
    /// benches, so they run (rather than skip) when `make artifacts`
    /// hasn't. Weights are seeded int8/16 values, so all requantized
    /// activations stay on the int8 grid; the final dense layer emits
    /// accumulator-scale outputs exactly like the exporter's models.
    ///
    /// `f` is the (even, >= 4) input side length; the model is
    /// conv 3x3 p1 (1 -> `channels`, ReLU, requant) → maxpool 2x2 →
    /// dense (`classes` outputs, accumulator scale).
    pub fn synthetic(f: usize, channels: usize, classes: usize, seed: u64) -> QModel {
        assert!(f >= 4 && f % 2 == 0, "synthetic fixture needs even f >= 4");
        assert!(channels >= 1 && classes >= 1);
        let mut rng = crate::util::Rng::new(seed);
        let mut wq = |n: usize| -> Vec<i64> {
            (0..n).map(|_| rng.int8() as i64 / 16).collect()
        };
        let conv = QLayer {
            name: "C1".into(),
            kind: QKind::Conv,
            k: 3,
            s: 1,
            p: 1,
            relu: true,
            w_q: wq(3 * 3 * channels),
            w_shape: vec![3, 3, 1, channels],
            b_q: (0..channels).map(|i| (i as i64 % 5) - 2).collect(),
            m: 0.05,
            in_shape: [f, f, 1],
            out_shape: [f, f, channels],
        };
        let pool = QLayer {
            name: "P1".into(),
            kind: QKind::MaxPool,
            k: 2,
            s: 2,
            p: 0,
            relu: false,
            w_q: vec![],
            w_shape: vec![],
            b_q: vec![],
            m: 0.0,
            in_shape: [f, f, channels],
            out_shape: [f / 2, f / 2, channels],
        };
        let feats = (f / 2) * (f / 2) * channels;
        let dense = QLayer {
            name: "F1".into(),
            kind: QKind::Dense,
            k: 0,
            s: 1,
            p: 0,
            relu: false,
            w_q: wq(classes * feats),
            w_shape: vec![classes, feats],
            b_q: (0..classes).map(|i| i as i64 + 1).collect(),
            m: 0.0, // final layer: accumulator out
            in_shape: [1, 1, feats],
            out_shape: [1, 1, classes],
        };
        QModel {
            name: format!("synthetic-{f}x{f}x{channels}"),
            input_shape: [f, f, 1],
            input_scale: 1.0,
            layers: vec![conv, pool, dense],
            topology: vec![],
            test_vectors: vec![],
            qat_accuracy: 1.0,
        }
    }

    /// Synthesize a deterministic int8 [`QModel`] from a layer-graph
    /// [`crate::model::Model`] (a zoo config), so any architecture —
    /// chains and residual DAGs alike — becomes a first-class serving
    /// scenario without artifacts: conv / pointwise / depthwise / pooling
    /// / dense layers get seeded small-magnitude weights (same grid as
    /// [`QModel::synthetic`]), intermediate layers requantize back onto
    /// the int8 activation grid, and the final layer emits
    /// accumulator-scale outputs exactly like the exporter's models.
    ///
    /// Residual blocks lower to a DAG recorded in [`QModel::topology`]:
    /// the node at each merge point carries a [`QMerge`] epilogue that
    /// adds the shortcut branch (both operands int8), applies the block's
    /// post-add ReLU, and requantizes the sum by `m = 0.5` — exactly
    /// halving keeps the sum on the int8 grid without widening.
    pub fn synthesize(
        model: &crate::model::Model,
        seed: u64,
    ) -> Result<QModel, SynthesisError> {
        use crate::model::LayerKind;
        let shaped = model.shapes().map_err(SynthesisError::Shape)?;
        let links = model.links().map_err(SynthesisError::Shape)?;
        let mut rng = crate::util::Rng::new(seed);
        let mut wq = |n: usize| -> Vec<i64> {
            (0..n).map(|_| rng.int8() as i64 / 16).collect()
        };
        let n_layers = shaped.len();
        let mut layers = Vec::with_capacity(n_layers);
        for (i, sl) in shaped.iter().enumerate() {
            let l = &sl.layer;
            let is_last = i + 1 == n_layers;
            let (f_in, d_in) = (sl.input.f, sl.input.d);
            let (f_out, d_out) = (sl.output.f, sl.output.d);
            // Intermediate layers requantize; the final layer emits
            // accumulator-scale values (m = 0).
            let m = |scale: f32| if is_last { 0.0 } else { scale };
            let ql = match l.kind {
                LayerKind::Conv | LayerKind::Pointwise => {
                    let k = l.k.max(1); // pointwise is a 1x1 conv
                    QLayer {
                        name: l.name.clone(),
                        kind: QKind::Conv,
                        k,
                        s: l.s,
                        p: l.p,
                        relu: l.relu,
                        w_q: wq(k * k * d_in * d_out),
                        w_shape: vec![k, k, d_in, d_out],
                        b_q: (0..d_out).map(|c| (c as i64 % 5) - 2).collect(),
                        m: m(0.05),
                        in_shape: [f_in, f_in, d_in],
                        out_shape: [f_out, f_out, d_out],
                    }
                }
                LayerKind::DepthwiseConv => QLayer {
                    name: l.name.clone(),
                    kind: QKind::DwConv,
                    k: l.k,
                    s: l.s,
                    p: l.p,
                    relu: l.relu,
                    w_q: wq(l.k * l.k * d_in),
                    w_shape: vec![l.k, l.k, d_in],
                    b_q: (0..d_out).map(|c| (c as i64 % 3) - 1).collect(),
                    m: m(0.05),
                    in_shape: [f_in, f_in, d_in],
                    out_shape: [f_out, f_out, d_out],
                },
                LayerKind::MaxPool => QLayer {
                    name: l.name.clone(),
                    kind: QKind::MaxPool,
                    k: l.k,
                    s: l.s,
                    p: l.p,
                    relu: false,
                    w_q: vec![],
                    w_shape: vec![],
                    b_q: vec![],
                    m: 0.0, // max pooling forwards maxima untouched
                    in_shape: [f_in, f_in, d_in],
                    out_shape: [f_out, f_out, d_out],
                },
                LayerKind::AvgPool => QLayer {
                    name: l.name.clone(),
                    kind: QKind::AvgPool,
                    k: l.k,
                    s: l.s,
                    p: l.p,
                    relu: false,
                    // Constant weights + requant by 1/k^2: the paper's
                    // average pool as a depthwise conv (Section VI). The
                    // multiplier is part of the op's definition, so it is
                    // recorded unconditionally — though if an avgpool is
                    // the FINAL layer, the engines still emit
                    // accumulator-scale window sums (every last layer
                    // skips requant by convention; see fused_requant).
                    w_q: vec![1; l.k * l.k * d_in],
                    w_shape: vec![l.k, l.k, d_in],
                    b_q: vec![0; d_out],
                    m: 1.0 / (l.k * l.k) as f32,
                    in_shape: [f_in, f_in, d_in],
                    out_shape: [f_out, f_out, d_out],
                },
                LayerKind::Dense => {
                    let feats = sl.input.features();
                    QLayer {
                        name: l.name.clone(),
                        kind: QKind::Dense,
                        k: 0,
                        s: 1,
                        p: 0,
                        relu: l.relu,
                        w_q: wq(d_out * feats),
                        w_shape: vec![d_out, feats],
                        b_q: (0..d_out).map(|c| c as i64 + 1).collect(),
                        m: m(0.02),
                        in_shape: [1, 1, feats],
                        out_shape: [1, 1, d_out],
                    }
                }
            };
            layers.push(ql);
        }
        // Residual dataflow: keep `topology` empty for chains so chain
        // lowering stays byte-identical to the pre-DAG path.
        let is_chain = links
            .iter()
            .enumerate()
            .all(|(i, l)| l.merge.is_none() && l.src == i.checked_sub(1));
        let topology = if is_chain {
            vec![]
        } else {
            let mut topo = Vec::with_capacity(links.len());
            for (i, l) in links.iter().enumerate() {
                let merge = match l.merge {
                    Some(ml) => {
                        if i + 1 == n_layers {
                            // The output layer skips requant (accumulator
                            // scale), so its merge operands would sit on
                            // different grids.
                            return Err(SynthesisError::UnsupportedBlock {
                                model: model.name.clone(),
                                index: i,
                                reason: "residual merge on the final layer \
                                         (accumulator-scale output)"
                                    .into(),
                            });
                        }
                        Some(QMerge {
                            with: ml.with,
                            m: 0.5,
                            relu: ml.post_relu,
                        })
                    }
                    None => None,
                };
                topo.push(QTopo { src: l.src, merge });
            }
            topo
        };
        Ok(QModel {
            name: model.name.clone(),
            input_shape: [model.input.f, model.input.f, model.input.d],
            input_scale: 1.0,
            layers,
            topology,
            test_vectors: vec![],
            qat_accuracy: 1.0,
        })
    }

    /// True when the lowered graph is a plain chain (every node reads its
    /// predecessor, no merges).
    pub fn is_chain(&self) -> bool {
        self.topology.is_empty()
            || self
                .topology
                .iter()
                .enumerate()
                .all(|(i, t)| t.merge.is_none() && t.src == i.checked_sub(1))
    }

    /// Per-node dataflow, chain-filled when [`QModel::topology`] is
    /// empty — the single graph view every execution tier lowers from.
    pub fn node_topology(&self) -> Vec<QTopo> {
        if self.topology.is_empty() {
            (0..self.layers.len())
                .map(|i| QTopo {
                    src: i.checked_sub(1),
                    merge: None,
                })
                .collect()
        } else {
            self.topology.clone()
        }
    }

    /// Conv weight accessor: w[(u, v, cin, cout)].
    pub fn conv_w(l: &QLayer, u: usize, v: usize, cin: usize, cout: usize) -> i64 {
        let (k, ci, co) = (l.w_shape[0], l.w_shape[2], l.w_shape[3]);
        debug_assert_eq!(l.w_shape[0], l.w_shape[1]);
        l.w_q[((u * k + v) * ci + cin) * co + cout]
    }

    /// Depthwise weight accessor: w[(u, v, c)].
    pub fn dw_w(l: &QLayer, u: usize, v: usize, c: usize) -> i64 {
        let (k, ch) = (l.w_shape[0], l.w_shape[2]);
        l.w_q[(u * k + v) * ch + c]
    }

    /// Dense weight accessor: w[(unit, feat)].
    pub fn dense_w(l: &QLayer, unit: usize, feat: usize) -> i64 {
        l.w_q[unit * l.w_shape[1] + feat]
    }
}

/// Lowering accessors used by the compile-once engine (`sim::compiled`).
impl QLayer {
    /// The requant multiplier this layer applies after ReLU, fused at
    /// lowering time: `None` for the final layer (accumulator-scale
    /// output, the paper's wider final word), for m == 0, and always for
    /// max pooling (which forwards maxima untouched whatever its m field
    /// says — mirroring the pipeline interpreter).
    pub fn fused_requant(&self, is_last: bool) -> Option<f32> {
        if self.kind != QKind::MaxPool && !is_last && self.m != 0.0 {
            Some(self.m)
        } else {
            None
        }
    }

    /// Worst-case |accumulator| over this layer's outputs, given a bound
    /// on the input magnitude — max over output channels of
    /// |bias| + sum |w| * in_bound. Saturating, so pathological
    /// non-requantized chains peg at `i128::MAX` instead of wrapping.
    /// Pooling layers pass the input bound through. This is what proves
    /// (or refutes) 32-bit-lane safety at lowering time.
    pub fn acc_bound(&self, in_bound: i128) -> i128 {
        let c_out = self.out_shape[2];
        if self.kind == QKind::MaxPool || c_out == 0 {
            return in_bound;
        }
        let mut sums = vec![0i128; c_out];
        if self.kind == QKind::Dense {
            let feats = self.w_shape.get(1).copied().unwrap_or(0).max(1);
            for (i, &w) in self.w_q.iter().enumerate() {
                let term = (w.unsigned_abs() as i128).saturating_mul(in_bound);
                let u = (i / feats).min(c_out - 1);
                sums[u] = sums[u].saturating_add(term);
            }
        } else {
            for (i, &w) in self.w_q.iter().enumerate() {
                let term = (w.unsigned_abs() as i128).saturating_mul(in_bound);
                sums[i % c_out] = sums[i % c_out].saturating_add(term);
            }
        }
        let mut worst = 0i128;
        for (co, s) in sums.iter().enumerate() {
            let b = self
                .b_q
                .get(co)
                .map(|b| b.unsigned_abs() as i128)
                .unwrap_or(0);
            worst = worst.max(s.saturating_add(b));
        }
        worst
    }
}

/// Typed lowering error for [`QModel::synthesize`]: shape/dataflow
/// propagation failures keep their structured cause, and blocks the
/// quantized IR cannot express name the offending flat node index — so
/// registry and CLI callers fail loudly instead of swallowing a string.
#[derive(Debug, PartialEq)]
pub enum SynthesisError {
    /// Shape or dataflow propagation failed (see [`crate::model::ShapeError`]).
    Shape(crate::model::ShapeError),
    /// A block at flat node `index` cannot be lowered.
    UnsupportedBlock {
        model: String,
        index: usize,
        reason: String,
    },
}

impl std::fmt::Display for SynthesisError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SynthesisError::Shape(e) => write!(f, "{e}"),
            SynthesisError::UnsupportedBlock {
                model,
                index,
                reason,
            } => write!(f, "{model}: block {index}: {reason}"),
        }
    }
}

impl std::error::Error for SynthesisError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SynthesisError::Shape(e) => Some(e),
            SynthesisError::UnsupportedBlock { .. } => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn half_away_matches_python_semantics() {
        for (x, want) in [
            (-2.5f32, -3.0f32),
            (-1.5, -2.0),
            (-0.5, -1.0),
            (0.5, 1.0),
            (1.5, 2.0),
            (2.5, 3.0),
            (0.49, 0.0),
        ] {
            assert_eq!(half_away_round(x), want, "x={x}");
        }
    }

    #[test]
    fn requant_clamps() {
        assert_eq!(requant(1_000_000, 1.0), 127);
        assert_eq!(requant(-1_000_000, 1.0), -127);
        assert_eq!(requant(100, 0.5), 50);
        assert_eq!(requant(101, 0.5), 51); // 50.5 rounds away
        assert_eq!(requant(-101, 0.5), -51);
    }

    #[test]
    fn parse_minimal_model() {
        let text = r#"{
            "name": "t", "input_shape": [2,2,1], "input_scale": 0.5,
            "qat_accuracy": 0.9,
            "layers": [{
                "name": "d", "kind": "dense", "k": 0, "s": 1, "p": 0,
                "relu": false, "w_shape": [2, 4],
                "w_q": [1,2,3,4,5,6,7,8], "b_q": [0, 1], "m": 0.01,
                "in_shape": [1,1,4], "out_shape": [1,1,2]
            }],
            "test_vectors": [{"x_q": [1,2,3,4], "y": [30, 71]}]
        }"#;
        let m = QModel::from_json(text).unwrap();
        assert_eq!(m.layers.len(), 1);
        assert_eq!(m.layers[0].kind, QKind::Dense);
        assert_eq!(QModel::dense_w(&m.layers[0], 1, 2), 7);
        assert_eq!(m.test_vectors[0].y, vec![30, 71]);
    }

    #[test]
    fn conv_weight_indexing() {
        // w (k,k,cin,cout) = (2,2,1,2), flat row-major.
        let l = QLayer {
            name: "c".into(),
            kind: QKind::Conv,
            k: 2,
            s: 1,
            p: 0,
            relu: false,
            w_q: (0..8).collect(),
            w_shape: vec![2, 2, 1, 2],
            b_q: vec![0, 0],
            m: 1.0,
            in_shape: [3, 3, 1],
            out_shape: [2, 2, 2],
        };
        assert_eq!(QModel::conv_w(&l, 0, 0, 0, 0), 0);
        assert_eq!(QModel::conv_w(&l, 0, 0, 0, 1), 1);
        assert_eq!(QModel::conv_w(&l, 0, 1, 0, 0), 2);
        assert_eq!(QModel::conv_w(&l, 1, 1, 0, 1), 7);
    }

    #[test]
    fn quantize_roundtrip_grid() {
        for q in [-127i64, -3, 0, 5, 127] {
            assert_eq!(quantize(q as f32 * 0.25, 0.25), q);
        }
    }

    #[test]
    fn synthetic_fixture_is_deterministic_and_int8() {
        let a = QModel::synthetic(8, 4, 6, 42);
        let b = QModel::synthetic(8, 4, 6, 42);
        assert_eq!(a.layers.len(), 3);
        assert_eq!(a.input_shape, [8, 8, 1]);
        assert_eq!(a.layers[0].w_q, b.layers[0].w_q);
        assert_eq!(a.layers[2].w_q, b.layers[2].w_q);
        assert_ne!(
            QModel::synthetic(8, 4, 6, 43).layers[0].w_q,
            a.layers[0].w_q
        );
        for l in &a.layers {
            for &w in &l.w_q {
                assert!(w.abs() <= 7, "weight {w} outside int8/16 grid");
            }
        }
        assert_eq!(a.layers[2].w_shape, vec![6, 4 * 4 * 4]);
        assert_eq!(a.layers[2].b_q.len(), 6);
    }

    #[test]
    fn lowering_accessors() {
        let m = QModel::synthetic(8, 4, 6, 7);
        // Conv layer requants unless it is last; final dense never does.
        assert_eq!(m.layers[0].fused_requant(false), Some(0.05));
        assert_eq!(m.layers[0].fused_requant(true), None);
        assert_eq!(m.layers[2].fused_requant(true), None);
        // MaxPool passes the bound through; conv bound covers |b| + Σ|w|·x.
        assert_eq!(m.layers[1].acc_bound(127), 127);
        let conv = &m.layers[0];
        let max_abs_w: i64 = (0..conv.out_shape[2])
            .map(|co| {
                (0..9)
                    .map(|t| conv.w_q[t * conv.out_shape[2] + co].abs())
                    .sum::<i64>()
            })
            .max()
            .unwrap();
        assert!(conv.acc_bound(127) >= max_abs_w as i128 * 127);
        assert!(conv.acc_bound(127) <= (max_abs_w as i128 + 2) * 127 + 2);
    }

    #[test]
    fn synthesize_zoo_chain_is_deterministic() {
        let m = crate::model::zoo::vgg_micro();
        let a = QModel::synthesize(&m, 7).unwrap();
        let b = QModel::synthesize(&m, 7).unwrap();
        assert_eq!(a.name, "vgg_micro");
        assert_eq!(a.input_shape, [16, 16, 1]);
        assert_eq!(a.layers.len(), m.layers().len());
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(la.w_q, lb.w_q, "{}", la.name);
            assert!(la.w_q.iter().all(|w| w.abs() <= 7), "{}", la.name);
        }
        assert_ne!(
            QModel::synthesize(&m, 8).unwrap().layers[0].w_q,
            a.layers[0].w_q,
            "different seeds must give different weights"
        );
        // Intermediate layers requantize; the final layer is
        // accumulator-scale; maxpool never requantizes.
        assert_eq!(a.layers.last().unwrap().m, 0.0);
        assert!(a.layers[0].m != 0.0);
        let pool = a.layers.iter().find(|l| l.kind == QKind::MaxPool).unwrap();
        assert_eq!(pool.m, 0.0);
    }

    #[test]
    fn synthesize_maps_pointwise_dw_and_avgpool() {
        let q = QModel::synthesize(&crate::model::zoo::mobilenet_micro(), 1).unwrap();
        let pw = q.layers.iter().find(|l| l.name == "pw1").unwrap();
        assert_eq!(pw.kind, QKind::Conv);
        assert_eq!(pw.k, 1);
        assert_eq!(pw.w_shape, vec![1, 1, 8, 16]);
        let dw = q.layers.iter().find(|l| l.name == "dw1").unwrap();
        assert_eq!(dw.kind, QKind::DwConv);
        assert_eq!(dw.w_shape, vec![3, 3, 8]);
        let ap = q.layers.iter().find(|l| l.name == "ap").unwrap();
        assert_eq!(ap.kind, QKind::AvgPool);
        assert!(ap.w_q.iter().all(|&w| w == 1));
        assert_eq!(ap.m, 0.25);
        // Dense head flattens to [1, 1, feats].
        let fc = q.layers.last().unwrap();
        assert_eq!(fc.kind, QKind::Dense);
        assert_eq!(fc.in_shape, [1, 1, 4 * 4 * 32]);
    }

    #[test]
    fn synthesize_lowers_residual_topologies_to_a_dag() {
        let q = QModel::synthesize(&crate::model::zoo::resnet_micro(), 1).unwrap();
        assert!(!q.is_chain());
        assert_eq!(q.topology.len(), q.layers.len());
        // r1b merges the identity shortcut from c1 (node 0), ReLU'd.
        let t = q.topology[2];
        assert_eq!(t.src, Some(1));
        let mg = t.merge.unwrap();
        assert_eq!(mg.with, Some(0));
        assert_eq!(mg.m, 0.5);
        assert!(mg.relu);
        // Projection node r2p reads the block entry, merges r2b.
        let tp = q.topology[5];
        assert_eq!(tp.src, Some(2));
        assert_eq!(tp.merge.unwrap().with, Some(4));
        // Merge operands are intermediate nodes: both requantize.
        assert!(q.layers[2].m != 0.0 && q.layers[5].m != 0.0);
        // MobileNetV2 merges are linear (no post-add ReLU).
        let q2 = QModel::synthesize(&crate::model::zoo::mobilenet_v2_micro(), 1).unwrap();
        assert!(q2
            .topology
            .iter()
            .filter_map(|t| t.merge)
            .all(|m| !m.relu));
        // Chains keep an empty topology — byte-identical to the old path.
        let qc = QModel::synthesize(&crate::model::zoo::digits_cnn(), 1).unwrap();
        assert!(qc.topology.is_empty() && qc.is_chain());
        assert_eq!(qc.node_topology().len(), qc.layers.len());
    }

    #[test]
    fn synthesize_rejects_final_layer_merge_with_block_index() {
        use crate::model::{Block, Layer, Model};
        let mut m = Model::new("tail_res", 8, 4);
        m.blocks.push(Block::Residual {
            name: "r".into(),
            body: vec![
                Block::Layer(Layer::conv("a", 3, 1, 1, 4)),
                Block::Layer(Layer::conv("b", 3, 1, 1, 4).no_relu()),
            ],
            projection: None,
            post_relu: true,
        });
        let err = QModel::synthesize(&m, 1).unwrap_err();
        match &err {
            SynthesisError::UnsupportedBlock { index, .. } => assert_eq!(*index, 1),
            other => panic!("expected UnsupportedBlock, got {other:?}"),
        }
        assert!(err.to_string().contains("block 1"), "{err}");
    }

    #[test]
    fn load_exported_digits_model_if_present() {
        // Integration: parse the real artifact when `make artifacts` ran.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/weights/digits.json");
        if !path.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = QModel::load(&path).unwrap();
        assert_eq!(m.input_shape, [12, 12, 1]);
        assert_eq!(m.layers.len(), 5);
        assert!(!m.test_vectors.is_empty());
        assert!(m.qat_accuracy > 0.9);
    }
}
