//! PJRT runtime (system S9): load the AOT-compiled HLO-text artifacts and
//! execute them on the CPU PJRT client via the `xla` crate.
//!
//! This is the only place python-originated computation runs at serving
//! time — and it runs as a *compiled XLA executable*, never as python.
//! Interchange is HLO text (see `python/compile/aot.py` and
//! /opt/xla-example/README.md for why serialized protos don't work with
//! xla_extension 0.5.1).
//!
//! The PJRT backend is gated behind two cargo features because the `xla`
//! crate is a vendored, platform-specific dependency that minimal CI
//! containers don't carry: `pjrt` selects the PJRT-facing API surface and
//! its gated tests (CI exercises it against the stub backend), while
//! `pjrt-xla` additionally compiles the real backend and therefore
//! requires the vendored `xla` crate. Without `pjrt-xla` this module
//! compiles to a stub whose constructors return `Err`, so every caller
//! (the coordinator's verifier thread, the e2e tests, the benches)
//! degrades gracefully: the serving and simulation paths never require
//! PJRT. The API surface is identical in all configurations, and errors
//! are plain `String`s so the crate stays dependency-free by default.
//!
//! [`registry`] holds the multi-model serving cache: each model id is
//! lowered once into its compiled pipeline bundle (LRU-bounded,
//! single-flight, hit/miss/eviction counters) and shared by every shard
//! group the coordinator routes to it.

pub mod registry;

use std::path::{Path, PathBuf};

use crate::quant::QModel;

pub use registry::{LoweredModel, ModelRegistry, RegistryStats};

/// Runtime results use plain string errors so the default build carries no
/// error-handling dependency.
pub type RtResult<T> = Result<T, String>;

/// Locate the artifacts directory: `$CNN_FLOW_ARTIFACTS` or
/// `<manifest>/artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("CNN_FLOW_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

#[cfg(feature = "pjrt-xla")]
mod backend {
    use super::RtResult;
    use std::path::Path;

    /// A compiled model executable bound to a PJRT client.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        /// Input element count expected by the HLO entry (flattened f32).
        pub input_shape: Vec<usize>,
    }

    /// The runtime: one PJRT CPU client hosting any number of executables.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        pub fn cpu() -> RtResult<Self> {
            Ok(Self {
                client: xla::PjRtClient::cpu()
                    .map_err(|e| format!("create PJRT CPU client: {e}"))?,
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO text artifact.
        pub fn load_hlo_text(&self, path: &Path, input_shape: &[usize]) -> RtResult<Executable> {
            let text_path = path.to_str().ok_or("non-utf8 path")?;
            let proto = xla::HloModuleProto::from_text_file(text_path)
                .map_err(|e| format!("parse HLO text {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| format!("compile {}: {e}", path.display()))?;
            Ok(Executable {
                exe,
                input_shape: input_shape.to_vec(),
            })
        }
    }

    impl Executable {
        /// Execute on one flattened f32 input; returns the flattened f32
        /// output of the (single-element) result tuple.
        pub fn run_f32(&self, input: &[f32]) -> RtResult<Vec<f32>> {
            let n: usize = self.input_shape.iter().product();
            if input.len() != n {
                return Err(format!("input length {} != expected {n}", input.len()));
            }
            let dims: Vec<i64> = self.input_shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(input)
                .reshape(&dims)
                .map_err(|e| format!("reshape input: {e}"))?;
            let result = self
                .exe
                .execute::<xla::Literal>(&[lit])
                .map_err(|e| format!("execute: {e}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| format!("fetch result: {e}"))?;
            // aot.py lowers with return_tuple=True -> a 1-tuple.
            let out = result.to_tuple1().map_err(|e| format!("untuple: {e}"))?;
            out.to_vec::<f32>().map_err(|e| format!("to_vec: {e}"))
        }
    }
}

#[cfg(not(feature = "pjrt-xla"))]
mod backend {
    use super::RtResult;
    use std::path::Path;

    const UNAVAILABLE: &str = "PJRT runtime unavailable: this build carries the stub backend \
         (the `pjrt-xla` feature is off). Vendor the `xla` crate (add `xla = { path = \"...\" }` \
         under [dependencies] in rust/Cargo.toml) and build with `--features pjrt-xla`";

    /// Stub executable: carries the expected shape but cannot run.
    pub struct Executable {
        /// Input element count expected by the HLO entry (flattened f32).
        pub input_shape: Vec<usize>,
    }

    /// Stub runtime: construction always fails with a diagnostic, so any
    /// caller that tolerates a missing runtime (the coordinator's verifier
    /// thread, artifact-gated tests) degrades gracefully.
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        pub fn cpu() -> RtResult<Self> {
            Err(UNAVAILABLE.to_string())
        }

        pub fn platform(&self) -> String {
            "stub".to_string()
        }

        pub fn load_hlo_text(&self, _path: &Path, _input_shape: &[usize]) -> RtResult<Executable> {
            Err(UNAVAILABLE.to_string())
        }
    }

    impl Executable {
        pub fn run_f32(&self, _input: &[f32]) -> RtResult<Vec<f32>> {
            Err(UNAVAILABLE.to_string())
        }
    }
}

pub use backend::{Executable, Runtime};

/// Everything the serving stack needs for one model: the quantized weight
/// manifest, the planned-and-lowered pipeline (compiled value engine with
/// its batched tier + analytic schedule, ready for worker shards to clone
/// without re-planning), plus the compiled int8 golden executable (drives
/// verification).
pub struct ModelBundle {
    pub qmodel: QModel,
    /// Pre-lowered pipeline: pass to
    /// [`crate::coordinator::Server::start_prelowered`] so every shard
    /// clones compiled state instead of re-planning. The clone carries
    /// the lowered program behind an `Arc`, so sharding never duplicates
    /// weights or tap tables — each shard adds only its own execution
    /// scratch (single-frame ping-pong plus the batched tier's
    /// lane-interleaved buffers).
    pub pipeline: crate::sim::pipeline::PipelineSim,
    pub golden: Executable,
}

impl ModelBundle {
    /// Load `<artifacts>/weights/<name>.json` + `<artifacts>/<name>_int8.hlo.txt`.
    pub fn load(rt: &Runtime, name: &str) -> RtResult<ModelBundle> {
        let dir = artifacts_dir();
        let qmodel = QModel::load(&dir.join("weights").join(format!("{name}.json")))?;
        let pipeline = crate::sim::pipeline::PipelineSim::new(qmodel.clone(), None)?;
        let golden = rt.load_hlo_text(
            &dir.join(format!("{name}_int8.hlo.txt")),
            &qmodel.input_shape.to_vec(),
        )?;
        Ok(ModelBundle {
            qmodel,
            pipeline,
            golden,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(not(feature = "pjrt-xla"))]
    #[test]
    fn stub_runtime_reports_unavailable() {
        let err = Runtime::cpu().err().expect("stub must not construct");
        assert!(err.contains("pjrt"), "{err}");
    }

    #[cfg(all(feature = "pjrt", not(feature = "pjrt-xla")))]
    #[test]
    fn pjrt_surface_degrades_gracefully_on_stub_backend() {
        // The `pjrt` feature selects the PJRT-facing surface; without the
        // vendored backend (`pjrt-xla`) every constructor must report
        // itself unavailable and the serving stack must degrade — a
        // server started WITH a verifier model still answers requests,
        // because the verifier thread disables itself instead of
        // crashing. This is the coverage CI's pjrt-stub matrix leg adds.
        let err = Runtime::cpu().err().expect("stub must not construct");
        assert!(err.contains("pjrt-xla"), "{err}");
        let qm = QModel::synthetic(8, 4, 6, 0x57B);
        let server = crate::coordinator::Server::start(
            qm,
            crate::coordinator::ServerConfig::default(),
            Some("digits".into()),
        )
        .unwrap();
        let resp = server.infer(vec![0; 64]).unwrap();
        assert_eq!(resp.logits.len(), 6);
        let m = server.shutdown();
        assert_eq!(m.completed, 1);
        assert_eq!(m.verified, 0, "stub backend must never verify");
        assert_eq!(m.mismatches, 0);
    }

    #[test]
    fn artifacts_dir_is_absolute_or_env() {
        // Sanity: the resolver always yields a usable path string.
        let d = artifacts_dir();
        assert!(!d.as_os_str().is_empty());
    }

    #[cfg(feature = "pjrt-xla")]
    fn artifacts_ready() -> bool {
        artifacts_dir().join("meta.json").exists()
    }

    #[cfg(feature = "pjrt-xla")]
    #[test]
    fn runtime_creates_cpu_client() {
        let rt = Runtime::cpu().unwrap();
        assert!(!rt.platform().is_empty());
    }

    #[cfg(feature = "pjrt-xla")]
    #[test]
    fn golden_executable_matches_test_vectors() {
        // PJRT-executed JAX int8 golden vs the exporter's recorded outputs.
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::cpu().unwrap();
        for name in ["digits", "jsc"] {
            let bundle = ModelBundle::load(&rt, name).unwrap();
            for (i, tv) in bundle.qmodel.test_vectors.iter().enumerate() {
                let x: Vec<f32> = tv.x_q.iter().map(|&v| v as f32).collect();
                let y = bundle.golden.run_f32(&x).unwrap();
                let y_i: Vec<i64> = y.iter().map(|&v| v as i64).collect();
                assert_eq!(y_i, tv.y, "{name} vector {i}");
            }
        }
    }

    #[cfg(feature = "pjrt-xla")]
    #[test]
    fn golden_agrees_with_cycle_sim_on_random_inputs() {
        // Three-way agreement beyond the exported vectors: PJRT golden ==
        // rust pipeline sim on fresh random int8 inputs.
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let bundle = ModelBundle::load(&rt, "digits").unwrap();
        let sim =
            crate::sim::pipeline::PipelineSim::new(bundle.qmodel.clone(), None).unwrap();
        let mut rng = crate::util::Rng::new(0xD161);
        let n: usize = bundle.qmodel.input_shape.iter().product();
        for case in 0..8 {
            let x_q: Vec<i64> = (0..n).map(|_| rng.int8() as i64).collect();
            let xf: Vec<f32> = x_q.iter().map(|&v| v as f32).collect();
            let golden: Vec<i64> = bundle
                .golden
                .run_f32(&xf)
                .unwrap()
                .iter()
                .map(|&v| v as i64)
                .collect();
            let simulated = sim.run(&[x_q]).unwrap().outputs[0].clone();
            assert_eq!(simulated, golden, "case {case}");
        }
    }

    #[cfg(feature = "pjrt-xla")]
    #[test]
    fn float_pallas_hlo_loads_and_runs() {
        // The pallas-kernel float graph must also load and execute.
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let exe = rt
            .load_hlo_text(
                &artifacts_dir().join("digits_float.hlo.txt"),
                &[12, 12, 1],
            )
            .unwrap();
        let y = exe.run_f32(&vec![0.5f32; 144]).unwrap();
        assert_eq!(y.len(), 10);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[cfg(feature = "pjrt-xla")]
    #[test]
    fn wrong_input_length_rejected() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let bundle = ModelBundle::load(&rt, "jsc").unwrap();
        assert!(bundle.golden.run_f32(&[0.0; 3]).is_err());
    }
}
