//! Multi-model lowering cache (system S9b): lower each registered
//! [`QModel`] **once** into its compiled serving bundle and hand every
//! later caller the same artifact.
//!
//! The dataflow toolflows this reproduction follows (Haddoc-style
//! automated deployment, FINN-style dataflow builds — see PAPERS.md) pay
//! a real per-model cost before the first frame runs: rate analysis
//! (Eq. 8), unit planning (Eqs. 12-22), and the compile-once lowering of
//! DESIGN.md §4 (tap tables, transposed weights, fused epilogues, the
//! analytic schedule). Serving many heterogeneous CNNs behind one
//! coordinator therefore needs a registry that amortizes that cost:
//!
//! * **keyed by model id** — the caller-chosen string the coordinator's
//!   route table uses (`zoo` name, artifact name, tenant id, ...);
//! * **single-flight** — concurrent [`ModelRegistry::get_or_lower`] calls
//!   for the same id observe exactly one lowering and share one
//!   [`Arc<LoweredModel>`] (the registry lock is held across the lowering,
//!   so a second caller always finds the finished entry; hits never pay
//!   more than the lock);
//! * **LRU-bounded** — at most `capacity` lowered models are retained;
//!   inserting past the bound evicts the least-recently-used entry (an
//!   `Arc` already handed out stays alive with its holder — eviction only
//!   drops the cache's reference);
//! * **observable** — hit / miss / eviction counters
//!   ([`ModelRegistry::stats`]) so serving dashboards can see whether the
//!   cache is sized right.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::quant::QModel;
use crate::sim::pipeline::PipelineSim;

/// One model lowered for serving: the quantized manifest plus the
/// planned-and-lowered [`PipelineSim`] (compiled value engine, batched
/// tier and closed-form [`crate::flow::schedule::SchedulePrediction`] —
/// everything a shard group clones without re-planning).
pub struct LoweredModel {
    pub qmodel: QModel,
    pub pipeline: PipelineSim,
}

impl LoweredModel {
    /// Flattened input frame length the lowered engines expect.
    pub fn input_len(&self) -> usize {
        self.pipeline.input_len()
    }
}

struct Entry {
    lowered: Arc<LoweredModel>,
    /// Logical access time (monotone tick), the LRU ordering key.
    last_used: u64,
}

struct Inner {
    map: HashMap<String, Entry>,
    tick: u64,
}

/// Point-in-time registry counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RegistryStats {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that had to lower (including re-lowering after eviction).
    pub misses: u64,
    /// Entries dropped to enforce the capacity bound.
    pub evictions: u64,
    /// Models currently cached.
    pub cached: usize,
}

/// The LRU-bounded model-id → lowered-pipeline cache.
pub struct ModelRegistry {
    capacity: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl ModelRegistry {
    /// Lock the map, recovering from poisoning: the map is only mutated
    /// AFTER a lowering succeeds, so a panic inside a caller's `build`
    /// closure (or the lowering itself) leaves the map consistent — one
    /// bad model must not brick the registry for every other model.
    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// A registry retaining at most `capacity` lowered models
    /// (`capacity` is clamped to at least 1).
    pub fn new(capacity: usize) -> ModelRegistry {
        ModelRegistry {
            capacity: capacity.max(1),
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                tick: 0,
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// The lowered bundle for `id`, lowering `build`'s [`QModel`] on the
    /// first request (or after an eviction). Concurrent callers for the
    /// same id are single-flight: exactly one runs `build` + lowering,
    /// everyone receives the same [`Arc`]. A `build` or lowering error is
    /// returned to the caller and nothing is cached. `build` may return
    /// any displayable error — notably the typed
    /// [`crate::quant::SynthesisError`] from `QModel::synthesize`, whose
    /// rendering (model, block index, reason) survives into the serving
    /// error path verbatim.
    pub fn get_or_lower<F, E>(&self, id: &str, build: F) -> Result<Arc<LoweredModel>, String>
    where
        F: FnOnce() -> Result<QModel, E>,
        E: std::fmt::Display,
    {
        let mut inner = self.lock();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(e) = inner.map.get_mut(id) {
            e.last_used = tick;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(&e.lowered));
        }
        // Miss: lower while holding the lock (single-flight). Lowering a
        // model is milliseconds at most; a second caller blocking here is
        // exactly the caller that must not lower twice. Known trade-off:
        // a cold lowering also briefly blocks hits for OTHER ids — if a
        // future workload lowers models large enough for that to matter,
        // replace the map values with per-id in-flight slots (e.g.
        // Arc<OnceLock>) so the map lock is only held for lookup/insert.
        self.misses.fetch_add(1, Ordering::Relaxed);
        let qmodel = build().map_err(|e| e.to_string())?;
        let pipeline = PipelineSim::new(qmodel.clone(), None)?;
        let lowered = Arc::new(LoweredModel { qmodel, pipeline });
        if inner.map.len() >= self.capacity {
            // Evict the least-recently-used entry to stay within bound.
            if let Some(victim) = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            {
                inner.map.remove(&victim);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        inner.map.insert(
            id.to_string(),
            Entry {
                lowered: Arc::clone(&lowered),
                last_used: tick,
            },
        );
        Ok(lowered)
    }

    /// Cache lookup without lowering (refreshes the LRU position). A
    /// cold or evicted id counts as a miss, so mixed `get`/`get_or_lower`
    /// callers still see honest hit/miss ratios in [`ModelRegistry::stats`].
    pub fn get(&self, id: &str) -> Option<Arc<LoweredModel>> {
        let mut inner = self.lock();
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(id) {
            Some(e) => {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(Arc::clone(&e.lowered))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Whether `id` is currently cached (no LRU refresh, no counters).
    pub fn contains(&self, id: &str) -> bool {
        self.lock().map.contains_key(id)
    }

    /// Models currently cached.
    pub fn len(&self) -> usize {
        self.lock().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The retention bound this registry was built with (`len()` never
    /// exceeds it) — lets serving dashboards report cache pressure as
    /// `len() / capacity()` next to the hit/miss counters.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Point-in-time hit / miss / eviction counters.
    pub fn stats(&self) -> RegistryStats {
        RegistryStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            cached: self.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn qm(seed: u64) -> QModel {
        QModel::synthetic(8, 4, 6, seed)
    }

    #[test]
    fn miss_then_hit_shares_one_artifact() {
        let reg = ModelRegistry::new(4);
        let a = reg.get_or_lower("a", || Ok(qm(1))).unwrap();
        let b = reg
            .get_or_lower("a", || Err("must not re-lower a cached model".to_string()))
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let s = reg.stats();
        assert_eq!((s.hits, s.misses, s.evictions, s.cached), (1, 1, 0, 1));
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let reg = ModelRegistry::new(2);
        reg.get_or_lower("a", || Ok(qm(1))).unwrap();
        reg.get_or_lower("b", || Ok(qm(2))).unwrap();
        reg.get("a").unwrap(); // refresh a: b is now LRU
        reg.get_or_lower("c", || Ok(qm(3))).unwrap();
        assert!(reg.contains("a"));
        assert!(!reg.contains("b"));
        assert!(reg.contains("c"));
        assert_eq!(reg.stats().evictions, 1);
        assert_eq!(reg.len(), 2);
    }

    #[test]
    fn build_error_caches_nothing() {
        let reg = ModelRegistry::new(2);
        let err = reg.get_or_lower("bad", || Err("nope".to_string())).unwrap_err();
        assert_eq!(err, "nope");
        assert!(!reg.contains("bad"));
        assert_eq!(reg.stats().misses, 1);
        // A later successful build still works.
        reg.get_or_lower("bad", || Ok(qm(4))).unwrap();
        assert!(reg.contains("bad"));
    }

    #[test]
    fn panicking_build_does_not_brick_the_registry() {
        let reg = Arc::new(ModelRegistry::new(2));
        let r = Arc::clone(&reg);
        let res = std::thread::spawn(move || {
            let _ = r.get_or_lower("boom", || panic!("bad model config"));
        })
        .join();
        assert!(res.is_err(), "build panic must surface in its own thread");
        // The poisoned lock is reclaimed (the map was never mutated), so
        // every other model keeps working.
        assert!(!reg.contains("boom"));
        reg.get_or_lower("ok", || Ok(qm(9))).unwrap();
        assert!(reg.contains("ok"));
    }

    #[test]
    fn capacity_is_reported_and_bounds_len() {
        let reg = ModelRegistry::new(2);
        assert_eq!(reg.capacity(), 2);
        assert_eq!(reg.len(), 0);
        for (i, id) in ["a", "b", "c", "d"].iter().enumerate() {
            reg.get_or_lower(id, || Ok(qm(i as u64))).unwrap();
            assert!(reg.len() <= reg.capacity());
        }
        assert_eq!(reg.len(), 2);
        // The clamp: capacity 0 still retains one model.
        assert_eq!(ModelRegistry::new(0).capacity(), 1);
    }

    #[test]
    fn lru_eviction_order_under_interleaved_hits() {
        // Pin the exact eviction sequence when `get_or_lower` hits
        // interleave with inserts: a hit refreshes recency, so the victim
        // is always the entry whose last *touch* (not insert) is oldest.
        let reg = ModelRegistry::new(3);
        for (i, id) in ["a", "b", "c"].iter().enumerate() {
            reg.get_or_lower(id, || Ok(qm(i as u64))).unwrap();
        }
        // Recency now a < b < c. Touch a then b: recency c < a < b.
        reg.get_or_lower("a", || Err("a is cached".to_string())).unwrap();
        reg.get_or_lower("b", || Err("b is cached".to_string())).unwrap();
        // Insert d: the victim must be c (oldest touch), not a (oldest
        // insert).
        reg.get_or_lower("d", || Ok(qm(3))).unwrap();
        assert!(!reg.contains("c"), "c was LRU after a and b were re-hit");
        assert!(reg.contains("a") && reg.contains("b") && reg.contains("d"));
        // Touch a again: recency b < d < a. Insert e: victim is b.
        reg.get_or_lower("a", || Err("a is cached".to_string())).unwrap();
        reg.get_or_lower("e", || Ok(qm(4))).unwrap();
        assert!(!reg.contains("b"), "b was LRU after a's second re-hit");
        assert!(reg.contains("a") && reg.contains("d") && reg.contains("e"));
        let s = reg.stats();
        assert_eq!(s.evictions, 2, "{s:?}");
        assert_eq!(s.hits, 3, "{s:?}");
        assert_eq!(s.misses, 5, "{s:?}");
        assert_eq!(s.cached, 3, "{s:?}");
    }

    #[test]
    fn evicted_arc_stays_alive_with_holder() {
        let reg = ModelRegistry::new(1);
        let a = reg.get_or_lower("a", || Ok(qm(5))).unwrap();
        reg.get_or_lower("b", || Ok(qm(6))).unwrap();
        assert!(!reg.contains("a"));
        // The handed-out bundle is still usable after eviction.
        assert_eq!(a.input_len(), 64);
    }
}
