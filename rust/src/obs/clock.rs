//! The observability clock contract (DESIGN.md §13).
//!
//! Every stage timestamp a flight-recorder span carries comes from one
//! [`Clock`], stored in the server config and cloned wherever spans are
//! stamped. Production servers run the monotonic [`Clock::wall`] clock;
//! the loadgen replay harness substitutes a [`Clock::virtual_from`]
//! clock driven by its trace tick counter, which is what makes recorded
//! spans **byte-deterministic** across seeded replays: the harness only
//! advances the shared tick after every in-flight request has settled,
//! so no stamp ever races a tick edge.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// A nanosecond-resolution span clock: monotonic wall time anchored at
/// construction, or the loadgen's virtual trace ticks.
#[derive(Debug, Clone)]
pub enum Clock {
    /// Monotonic wall clock; `now_nanos` is nanoseconds since `base`.
    Wall { base: Instant },
    /// Virtual clock: `now_nanos` reads the shared tick counter the
    /// replay harness advances between settled trace ticks.
    Virtual { ticks: Arc<AtomicU64> },
}

impl Clock {
    /// A wall clock anchored now. Stamps from two different wall clocks
    /// are not comparable; share one clock per server.
    pub fn wall() -> Clock {
        Clock::Wall {
            base: Instant::now(),
        }
    }

    /// A virtual clock over a shared tick cell (the loadgen's
    /// `tick_sink`). The harness owns advancement; readers only load.
    pub fn virtual_from(ticks: Arc<AtomicU64>) -> Clock {
        Clock::Virtual { ticks }
    }

    /// Current reading in nanoseconds (wall) or ticks (virtual). The
    /// u64 saturates rather than wraps on pathological uptimes.
    pub fn now_nanos(&self) -> u64 {
        match self {
            Clock::Wall { base } => {
                u64::try_from(base.elapsed().as_nanos()).unwrap_or(u64::MAX)
            }
            Clock::Virtual { ticks } => ticks.load(Ordering::Acquire),
        }
    }

    /// Whether this is the deterministic virtual clock (tests/replays).
    pub fn is_virtual(&self) -> bool {
        matches!(self, Clock::Virtual { .. })
    }
}

impl Default for Clock {
    fn default() -> Clock {
        Clock::wall()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_is_monotone() {
        let c = Clock::wall();
        let a = c.now_nanos();
        let b = c.now_nanos();
        assert!(b >= a);
        assert!(!c.is_virtual());
    }

    #[test]
    fn virtual_clock_reads_the_shared_cell() {
        let ticks = Arc::new(AtomicU64::new(0));
        let c = Clock::virtual_from(Arc::clone(&ticks));
        assert!(c.is_virtual());
        assert_eq!(c.now_nanos(), 0);
        ticks.store(42, Ordering::Release);
        assert_eq!(c.now_nanos(), 42);
        // Clones share the cell, like server-config clones must.
        let c2 = c.clone();
        ticks.store(7, Ordering::Release);
        assert_eq!(c2.now_nanos(), 7);
    }
}
