//! Plain-TCP metrics text endpoint (`serve --metrics-listen`).
//!
//! Deliberately not HTTP: one accepted connection gets one freshly
//! rendered exposition page written to it, then the socket is closed —
//! `nc host port` or a Prometheus scraper with a text-file bridge reads
//! it directly. Keeping the endpoint off the inference wire protocol
//! means a scrape can never occupy a protocol connection slot, and a
//! half-open scraper can never stall the serving path: the endpoint
//! runs on its own accept thread with short write timeouts.

use std::io::Write;
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// How long the accept loop naps when idle; bounds shutdown latency.
const IDLE_NAP: Duration = Duration::from_millis(25);
/// Per-connection write timeout: a stuck scraper costs at most this.
const WRITE_TIMEOUT: Duration = Duration::from_secs(2);

/// A live metrics text listener. Dropping it (or calling
/// [`TextEndpoint::shutdown`]) stops the accept thread.
pub struct TextEndpoint {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl TextEndpoint {
    /// Bind `addr` and serve `render()` to every connection. `render`
    /// runs on the endpoint thread per scrape, so it should snapshot
    /// and format — never block on the serving path.
    pub fn bind<F>(addr: &str, render: F) -> Result<TextEndpoint, String>
    where
        F: Fn() -> String + Send + 'static,
    {
        let listener = TcpListener::bind(addr)
            .map_err(|e| format!("metrics-listen bind {addr}: {e}"))?;
        let local = listener
            .local_addr()
            .map_err(|e| format!("metrics-listen local_addr: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("metrics-listen nonblocking: {e}"))?;
        let stop = Arc::new(AtomicBool::new(false));
        let tstop = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("cnn-flow-metrics-text".into())
            .spawn(move || loop {
                if tstop.load(Ordering::Acquire) {
                    return;
                }
                match listener.accept() {
                    Ok((mut sock, _)) => {
                        let _ = sock.set_nonblocking(false);
                        let _ = sock.set_write_timeout(Some(WRITE_TIMEOUT));
                        let page = render();
                        let _ = sock.write_all(page.as_bytes());
                        let _ = sock.flush();
                        // Socket drops here; the peer sees EOF after
                        // the page.
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(IDLE_NAP);
                    }
                    Err(_) => std::thread::sleep(IDLE_NAP),
                }
            })
            .map_err(|e| format!("metrics-listen thread: {e}"))?;
        Ok(TextEndpoint {
            addr: local,
            stop,
            handle: Some(handle),
        })
    }

    /// The bound address (useful with port 0 in tests).
    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting and join the endpoint thread (≤ one idle nap).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for TextEndpoint {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;
    use std::net::TcpStream;

    #[test]
    fn serves_fresh_page_per_connection_and_shuts_down() {
        let n = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let rn = Arc::clone(&n);
        let mut ep = TextEndpoint::bind("127.0.0.1:0", move || {
            let k = rn.fetch_add(1, Ordering::SeqCst);
            format!("scrape {k}\n")
        })
        .expect("bind");
        let addr = ep.local_addr();
        for expect in ["scrape 0\n", "scrape 1\n"] {
            let mut s = TcpStream::connect(addr).expect("connect");
            let mut buf = String::new();
            s.read_to_string(&mut buf).expect("read page");
            assert_eq!(buf, expect);
        }
        ep.shutdown();
        // After shutdown nothing accepts; connect may succeed at the OS
        // backlog level but reads must EOF without a page, or the
        // connect itself fails. Either way, no third render happens.
        assert_eq!(n.load(Ordering::SeqCst), 2);
    }
}
