//! Prometheus text-format exposition of every serving snapshot.
//!
//! [`render_exposition`] is a pure function over the existing snapshot
//! structs — it holds no locks and takes no references into the live
//! server, so both net cores (and the plain-TCP `--metrics-listen`
//! endpoint) call it with whatever snapshots they have. The output
//! follows the Prometheus text format v0.0.4: every family gets one
//! `# HELP` and one `# TYPE` line, counters end in `_total`, durations
//! are seconds, and labels carry the model / reason / quantile axes.
//!
//! [`lint`] enforces the format invariants CI gates on: a `# TYPE` line
//! per family, no duplicate family declarations, and no duplicate
//! samples.

use std::collections::BTreeSet;
use std::time::Duration;

use crate::coordinator::metrics::{
    MetricsSnapshot, ModelMetricsSnapshot, NetMetricsSnapshot, ReactorStatsSnapshot,
};

use super::trace::TraceStatsSnapshot;

/// Incremental text-format writer that tracks declared families so the
/// renderer cannot emit a sample before (or a duplicate of) its `# TYPE`
/// header.
struct Prom {
    out: String,
    declared: BTreeSet<String>,
}

impl Prom {
    fn new() -> Prom {
        Prom {
            out: String::with_capacity(8 * 1024),
            declared: BTreeSet::new(),
        }
    }

    fn family(&mut self, name: &str, kind: &str, help: &str) {
        debug_assert!(
            self.declared.insert(name.to_string()),
            "duplicate metric family {name}"
        );
        self.out.push_str("# HELP ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(help);
        self.out.push_str("\n# TYPE ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(kind);
        self.out.push('\n');
    }

    fn sample_start(&mut self, name: &str, labels: &[(&str, &str)]) {
        debug_assert!(
            self.declared.contains(name),
            "sample for undeclared family {name}"
        );
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(k);
                self.out.push_str("=\"");
                // Label escaping per the text format: backslash, quote,
                // newline.
                for c in v.chars() {
                    match c {
                        '\\' => self.out.push_str("\\\\"),
                        '"' => self.out.push_str("\\\""),
                        '\n' => self.out.push_str("\\n"),
                        _ => self.out.push(c),
                    }
                }
                self.out.push('"');
            }
            self.out.push('}');
        }
        self.out.push(' ');
    }

    /// Exact-integer sample: counters never pass through f64 (the cycle
    /// accumulators exceed 2^53 on long sessions).
    fn uint(&mut self, name: &str, labels: &[(&str, &str)], value: u64) {
        self.sample_start(name, labels);
        self.out.push_str(&value.to_string());
        self.out.push('\n');
    }

    fn float(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.sample_start(name, labels);
        if value.is_finite() {
            self.out.push_str(&format!("{value}"));
        } else if value.is_nan() {
            self.out.push_str("NaN");
        } else if value > 0.0 {
            self.out.push_str("+Inf");
        } else {
            self.out.push_str("-Inf");
        }
        self.out.push('\n');
    }
}

fn secs(d: Duration) -> f64 {
    d.as_secs_f64()
}

/// Render every snapshot into one Prometheus text-format page.
///
/// `net` is present when a TCP front-end ran, `reactor` only for the
/// evented core (the threaded core passes `None`), `trace` when the
/// flight recorder is enabled.
pub fn render_exposition(
    aggregate: &MetricsSnapshot,
    per_model: &[ModelMetricsSnapshot],
    net: Option<&NetMetricsSnapshot>,
    reactor: Option<&ReactorStatsSnapshot>,
    trace: Option<&TraceStatsSnapshot>,
) -> String {
    let mut p = Prom::new();

    // --- coordinator aggregate ---------------------------------------
    p.family("cnn_flow_workers", "gauge", "Configured shard workers.");
    p.uint("cnn_flow_workers", &[], aggregate.workers as u64);
    p.family(
        "cnn_flow_active_workers",
        "gauge",
        "Shards currently admitted by dispatch/autoscaling.",
    );
    p.uint("cnn_flow_active_workers", &[], aggregate.active_workers as u64);
    p.family("cnn_flow_models", "gauge", "Model groups served.");
    p.uint("cnn_flow_models", &[], aggregate.models as u64);

    let intake: [(&str, &str, u64); 6] = [
        (
            "cnn_flow_accepted_total",
            "Requests accepted into a shard queue.",
            aggregate.accepted,
        ),
        (
            "cnn_flow_rejected_total",
            "Requests refused with every shard queue full.",
            aggregate.rejected,
        ),
        (
            "cnn_flow_shed_total",
            "Requests shed by deadline admission control.",
            aggregate.shed,
        ),
        (
            "cnn_flow_spilled_total",
            "Accepted requests that overflowed their preferred shard.",
            aggregate.spilled,
        ),
        (
            "cnn_flow_unrouted_total",
            "Submissions naming an unknown model.",
            aggregate.unrouted,
        ),
        (
            "cnn_flow_completed_total",
            "Requests answered with logits.",
            aggregate.completed,
        ),
    ];
    for (name, help, v) in intake {
        p.family(name, "counter", help);
        p.uint(name, &[], v);
    }
    p.family(
        "cnn_flow_errored_total",
        "counter",
        "Requests answered with an engine error.",
    );
    p.uint("cnn_flow_errored_total", &[], aggregate.errored);
    p.family(
        "cnn_flow_batches_total",
        "counter",
        "Batches executed across all shards.",
    );
    p.uint("cnn_flow_batches_total", &[], aggregate.batches);
    p.family(
        "cnn_flow_flush_total",
        "counter",
        "Batch flushes by reason; reasons sum to cnn_flow_batches_total.",
    );
    p.uint("cnn_flow_flush_total", &[("reason", "full")], aggregate.flush_full);
    p.uint(
        "cnn_flow_flush_total",
        &[("reason", "deadline")],
        aggregate.flush_deadline,
    );
    p.uint(
        "cnn_flow_flush_total",
        &[("reason", "drain")],
        aggregate.flush_drain,
    );
    p.family(
        "cnn_flow_scale_events_total",
        "counter",
        "Autoscale controller grow/shrink events.",
    );
    p.uint(
        "cnn_flow_scale_events_total",
        &[("direction", "up")],
        aggregate.scale_up_events,
    );
    p.uint(
        "cnn_flow_scale_events_total",
        &[("direction", "down")],
        aggregate.scale_down_events,
    );
    p.family(
        "cnn_flow_verified_total",
        "counter",
        "Batches cross-checked against the interpreter oracle.",
    );
    p.uint("cnn_flow_verified_total", &[], aggregate.verified);
    p.family(
        "cnn_flow_mismatches_total",
        "counter",
        "Oracle cross-check mismatches (must stay 0).",
    );
    p.uint("cnn_flow_mismatches_total", &[], aggregate.mismatches);
    p.family(
        "cnn_flow_predicted_cycles_total",
        "counter",
        "Closed-form predicted cycles across served groups.",
    );
    p.uint("cnn_flow_predicted_cycles_total", &[], aggregate.predicted_cycles);
    p.family(
        "cnn_flow_simulated_cycles_total",
        "counter",
        "Interpreter-measured cycles (0 unless interpreting).",
    );
    p.uint("cnn_flow_simulated_cycles_total", &[], aggregate.simulated_cycles);
    p.family(
        "cnn_flow_cycle_divergence_total",
        "counter",
        "Groups where prediction differed from interpreter cycles.",
    );
    p.uint("cnn_flow_cycle_divergence_total", &[], aggregate.cycle_divergence);
    p.family(
        "cnn_flow_occupancy_frames_total",
        "counter",
        "Frames summed over all batch occupancies.",
    );
    p.uint("cnn_flow_occupancy_frames_total", &[], aggregate.occupancy_frames);
    p.family(
        "cnn_flow_batch_occupancy_total",
        "counter",
        "Batches by exact frame count (last bucket is overflow).",
    );
    let occ = &aggregate.batch_occupancy;
    for (i, &count) in occ.iter().enumerate() {
        let label = if i + 1 == occ.len() {
            format!("{}+", occ.len())
        } else {
            (i + 1).to_string()
        };
        p.uint(
            "cnn_flow_batch_occupancy_total",
            &[("size", label.as_str())],
            count,
        );
    }
    p.family("cnn_flow_mean_batch", "gauge", "Mean frames per batch.");
    p.float("cnn_flow_mean_batch", &[], aggregate.mean_batch);
    p.family(
        "cnn_flow_service_latency_seconds",
        "summary",
        "Wall-clock enqueue-to-answer latency quantiles.",
    );
    for (q, d) in [
        ("0.5", aggregate.p50),
        ("0.95", aggregate.p95),
        ("0.99", aggregate.p99),
    ] {
        p.float(
            "cnn_flow_service_latency_seconds",
            &[("quantile", q)],
            secs(d),
        );
    }
    p.family(
        "cnn_flow_service_latency_mean_seconds",
        "gauge",
        "Mean wall-clock enqueue-to-answer latency.",
    );
    p.float(
        "cnn_flow_service_latency_mean_seconds",
        &[],
        secs(aggregate.mean_service),
    );
    p.family(
        "cnn_flow_projected_fps",
        "gauge",
        "Projected single-pipeline throughput at the configured clock.",
    );
    p.float("cnn_flow_projected_fps", &[], aggregate.projected_fps);
    p.family(
        "cnn_flow_aggregate_fps",
        "gauge",
        "Projected sharded-deployment throughput.",
    );
    p.float("cnn_flow_aggregate_fps", &[], aggregate.aggregate_fps);

    // --- per-model views ----------------------------------------------
    if !per_model.is_empty() {
        let model_counters: [(&str, &str, fn(&MetricsSnapshot) -> u64); 5] = [
            (
                "cnn_flow_model_accepted_total",
                "Per-model requests accepted into a shard queue.",
                |m| m.accepted,
            ),
            (
                "cnn_flow_model_rejected_total",
                "Per-model requests refused on full queues.",
                |m| m.rejected,
            ),
            (
                "cnn_flow_model_shed_total",
                "Per-model requests shed by admission control.",
                |m| m.shed,
            ),
            (
                "cnn_flow_model_completed_total",
                "Per-model requests answered with logits.",
                |m| m.completed,
            ),
            (
                "cnn_flow_model_errored_total",
                "Per-model requests answered with an engine error.",
                |m| m.errored,
            ),
        ];
        for (name, help, get) in model_counters {
            p.family(name, "counter", help);
            for m in per_model {
                p.uint(name, &[("model", m.model.as_str())], get(&m.metrics));
            }
        }
        p.family(
            "cnn_flow_model_latency_seconds",
            "summary",
            "Per-model enqueue-to-answer latency quantiles.",
        );
        for m in per_model {
            for (q, d) in [
                ("0.5", m.metrics.p50),
                ("0.95", m.metrics.p95),
                ("0.99", m.metrics.p99),
            ] {
                p.float(
                    "cnn_flow_model_latency_seconds",
                    &[("model", m.model.as_str()), ("quantile", q)],
                    secs(d),
                );
            }
        }
    }

    // --- net front-end ------------------------------------------------
    if let Some(n) = net {
        p.family(
            "cnn_flow_net_connections_total",
            "counter",
            "TCP connections accepted.",
        );
        p.uint("cnn_flow_net_connections_total", &[], n.connections);
        p.family(
            "cnn_flow_net_disconnects_total",
            "counter",
            "TCP connections fully torn down.",
        );
        p.uint("cnn_flow_net_disconnects_total", &[], n.disconnects);
        p.family(
            "cnn_flow_net_requests_total",
            "counter",
            "Decoded inference requests.",
        );
        p.uint("cnn_flow_net_requests_total", &[], n.requests);
        p.family(
            "cnn_flow_net_responses_ok_total",
            "counter",
            "Successful inference replies.",
        );
        p.uint("cnn_flow_net_responses_ok_total", &[], n.responses_ok);
        p.family(
            "cnn_flow_net_errors_total",
            "counter",
            "Protocol errors answered, by error code.",
        );
        for (code, v) in [
            ("queue_full", n.err_queue_full),
            ("slo_miss", n.err_slo_miss),
            ("invalid_frame", n.err_invalid_frame),
            ("unknown_model", n.err_unknown_model),
            ("draining", n.err_draining),
            ("malformed", n.err_malformed),
        ] {
            p.uint("cnn_flow_net_errors_total", &[("code", code)], v);
        }
    }

    // --- evented reactor ----------------------------------------------
    if let Some(r) = reactor {
        for (name, help, v) in [
            (
                "cnn_flow_reactor_polls_total",
                "Readiness-loop poll calls.",
                r.polls,
            ),
            (
                "cnn_flow_reactor_events_total",
                "Readiness events dispatched.",
                r.events,
            ),
            (
                "cnn_flow_reactor_wakeups_total",
                "Completion-pipe wakeups.",
                r.wakeups,
            ),
            (
                "cnn_flow_reactor_completions_total",
                "Coordinator completions collected.",
                r.completions,
            ),
            (
                "cnn_flow_reactor_read_pauses_total",
                "Connections paused for per-conn backlog.",
                r.read_pauses,
            ),
            (
                "cnn_flow_reactor_stall_teardowns_total",
                "Connections torn down by the stall sweeper.",
                r.stall_teardowns,
            ),
        ] {
            p.family(name, "counter", help);
            p.uint(name, &[], v);
        }
    }

    // --- flight recorder ----------------------------------------------
    if let Some(t) = trace {
        p.family(
            "cnn_flow_trace_spans_recorded_total",
            "counter",
            "Spans retained by the flight recorder.",
        );
        p.uint("cnn_flow_trace_spans_recorded_total", &[], t.spans_recorded);
        p.family(
            "cnn_flow_trace_spans_dropped_total",
            "counter",
            "Spans dropped on recorder overflow.",
        );
        p.uint("cnn_flow_trace_spans_dropped_total", &[], t.spans_dropped);
        p.family(
            "cnn_flow_trace_retained",
            "gauge",
            "Spans currently held in the ring.",
        );
        p.uint("cnn_flow_trace_retained", &[], t.retained);
        p.family(
            "cnn_flow_trace_capacity",
            "gauge",
            "Flight recorder ring capacity.",
        );
        p.uint("cnn_flow_trace_capacity", &[], t.capacity);
    }

    p.out
}

/// Validate Prometheus text-format invariants: every sample's family
/// has exactly one `# TYPE` line appearing before its first sample, the
/// type is a known kind, and no (name, labels) sample repeats. Returns
/// the first violation.
pub fn lint(text: &str) -> Result<(), String> {
    const KINDS: [&str; 5] = ["counter", "gauge", "histogram", "summary", "untyped"];
    let mut typed: BTreeSet<&str> = BTreeSet::new();
    let mut seen: BTreeSet<&str> = BTreeSet::new();
    for (lineno, line) in text.lines().enumerate() {
        let lineno = lineno + 1;
        if line.is_empty() || line.starts_with("# HELP") {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it
                .next()
                .ok_or_else(|| format!("line {lineno}: TYPE without a family name"))?;
            let kind = it
                .next()
                .ok_or_else(|| format!("line {lineno}: TYPE {name} without a kind"))?;
            if !KINDS.contains(&kind) {
                return Err(format!("line {lineno}: unknown TYPE kind '{kind}'"));
            }
            if !typed.insert(name) {
                return Err(format!("line {lineno}: duplicate TYPE for family {name}"));
            }
            continue;
        }
        if line.starts_with('#') {
            continue;
        }
        // Sample line: `<name>[{labels}] <value>`.
        let series = line
            .split(' ')
            .next()
            .filter(|s| !s.is_empty())
            .ok_or_else(|| format!("line {lineno}: malformed sample line"))?;
        let family = series.split('{').next().unwrap_or(series);
        if !typed.contains(family) {
            return Err(format!(
                "line {lineno}: sample for family {family} with no preceding # TYPE"
            ));
        }
        if !seen.insert(series) {
            return Err(format!("line {lineno}: duplicate sample {series}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::OCC_SLOTS;

    fn sample_aggregate() -> MetricsSnapshot {
        MetricsSnapshot {
            workers: 4,
            active_workers: 3,
            models: 2,
            accepted: 100,
            rejected: 5,
            shed: 2,
            scale_up_events: 1,
            scale_down_events: 1,
            spilled: 3,
            unrouted: 1,
            completed: 98,
            batches: 40,
            verified: 10,
            mismatches: 0,
            predicted_cycles: 1 << 60,
            simulated_cycles: 0,
            cycle_divergence: 0,
            errored: 2,
            occupancy_frames: 100,
            flush_full: 30,
            flush_deadline: 8,
            flush_drain: 2,
            batch_occupancy: [1; OCC_SLOTS],
            mean_batch: 2.5,
            mean_service: Duration::from_micros(120),
            p50: Duration::from_micros(100),
            p95: Duration::from_micros(300),
            p99: Duration::from_micros(500),
            projected_fps: 1.5e6,
            aggregate_fps: 6.0e6,
        }
    }

    #[test]
    fn exposition_passes_the_lint() {
        let agg = sample_aggregate();
        let per = vec![
            ModelMetricsSnapshot {
                model: "digits".into(),
                metrics: sample_aggregate(),
            },
            ModelMetricsSnapshot {
                model: "mobilenet_micro".into(),
                metrics: sample_aggregate(),
            },
        ];
        let net = NetMetricsSnapshot {
            connections: 3,
            disconnects: 3,
            requests: 100,
            responses_ok: 98,
            err_queue_full: 1,
            err_slo_miss: 1,
            err_invalid_frame: 0,
            err_unknown_model: 0,
            err_draining: 0,
            err_malformed: 0,
        };
        let reactor = ReactorStatsSnapshot {
            polls: 10,
            events: 20,
            wakeups: 5,
            completions: 98,
            read_pauses: 0,
            stall_teardowns: 0,
        };
        let trace = TraceStatsSnapshot {
            capacity: 4096,
            retained: 100,
            spans_recorded: 100,
            spans_dropped: 5,
        };
        let text = render_exposition(&agg, &per, Some(&net), Some(&reactor), Some(&trace));
        lint(&text).expect("rendered exposition must lint clean");
        // Exact-integer counters: the 2^60 cycle counter survives
        // verbatim, which f64 would have rounded.
        assert!(text.contains(&format!("cnn_flow_predicted_cycles_total {}", 1u64 << 60)));
        assert!(text.contains("cnn_flow_model_completed_total{model=\"digits\"} 98"));
        assert!(text.contains("# TYPE cnn_flow_net_errors_total counter"));
        assert!(text.contains("cnn_flow_trace_spans_dropped_total 5"));
    }

    #[test]
    fn minimal_exposition_lints_without_optional_sections() {
        let text = render_exposition(&sample_aggregate(), &[], None, None, None);
        lint(&text).expect("minimal exposition must lint clean");
        assert!(!text.contains("cnn_flow_net_"));
        assert!(!text.contains("cnn_flow_trace_"));
        assert!(!text.contains("cnn_flow_model_"));
    }

    #[test]
    fn lint_rejects_sample_without_type() {
        let bad = "cnn_flow_orphan_total 3\n";
        assert!(lint(bad).is_err());
    }

    #[test]
    fn lint_rejects_duplicate_type_and_duplicate_sample() {
        let dup_type = "# TYPE a counter\n# TYPE a counter\na 1\n";
        assert!(lint(dup_type).unwrap_err().contains("duplicate TYPE"));
        let dup_sample = "# TYPE a counter\na{x=\"1\"} 1\na{x=\"1\"} 2\n";
        assert!(lint(dup_sample).unwrap_err().contains("duplicate sample"));
        let ok = "# TYPE a counter\na{x=\"1\"} 1\na{x=\"2\"} 2\n";
        assert!(lint(ok).is_ok());
    }

    #[test]
    fn lint_rejects_unknown_kind() {
        assert!(lint("# TYPE a widget\na 1\n").is_err());
    }

    #[test]
    fn label_values_are_escaped() {
        let mut p = Prom::new();
        p.family("m", "gauge", "h");
        p.uint("m", &[("model", "a\"b\\c\nd")], 1);
        assert!(p.out.contains("m{model=\"a\\\"b\\\\c\\nd\"} 1"));
    }
}
