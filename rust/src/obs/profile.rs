//! Per-layer sampling profiler for the compiled/folded execute paths.
//!
//! The engines carry an `Option<Arc<LayerProfiler>>`; `None` keeps the
//! hot loops on the exact code they had before this module existed (a
//! single untaken branch per layer), and `Some` adds one `Instant`
//! read per layer plus two relaxed atomic adds — timing only, never
//! touching data buffers, which is the whole exactness argument: a
//! profiled run is bit-identical to an unprofiled one by construction.
//!
//! Measurements always use wall time (a layer's cost is real
//! nanoseconds) even when span stamps run on the virtual clock; the
//! profiler answers "where did the time go", not "when".
//!
//! The snapshot pairs measured time share with the analytic cycle share
//! from `SchedulePrediction::cycle_shares` — the divergence table
//! `cnn-flow profile` prints, the software analogue of the paper's
//! per-layer utilization figures.

use std::sync::atomic::{AtomicU64, Ordering};

/// Atomic per-layer accumulators, shared across every shard clone of a
/// model's engines so accumulation is fleet-wide per model.
#[derive(Debug)]
pub struct LayerProfiler {
    names: Vec<String>,
    nanos: Vec<AtomicU64>,
    samples: Vec<AtomicU64>,
}

/// One layer's accumulated measurements plus its share of total time.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerProfileRow {
    pub name: String,
    pub nanos: u64,
    pub samples: u64,
    /// This layer's fraction of all measured time (0 if nothing ran).
    pub measured_share: f64,
}

impl LayerProfiler {
    pub fn new(names: Vec<String>) -> LayerProfiler {
        let n = names.len();
        LayerProfiler {
            names,
            nanos: (0..n).map(|_| AtomicU64::new(0)).collect(),
            samples: (0..n).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Record `nanos` spent in `layer`. Out-of-range indices are
    /// ignored so a layer-count mismatch between a program and its
    /// prediction degrades to missing rows, never a panic in the hot
    /// path.
    #[inline]
    pub fn record(&self, layer: usize, nanos: u64) {
        if let (Some(t), Some(c)) = (self.nanos.get(layer), self.samples.get(layer)) {
            t.fetch_add(nanos, Ordering::Relaxed);
            c.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Snapshot all rows with each layer's share of total measured
    /// time.
    pub fn snapshot(&self) -> Vec<LayerProfileRow> {
        let nanos: Vec<u64> = self.nanos.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        let total: u64 = nanos.iter().sum();
        self.names
            .iter()
            .zip(&nanos)
            .zip(&self.samples)
            .map(|((name, &ns), samples)| LayerProfileRow {
                name: name.clone(),
                nanos: ns,
                samples: samples.load(Ordering::Relaxed),
                measured_share: if total == 0 {
                    0.0
                } else {
                    ns as f64 / total as f64
                },
            })
            .collect()
    }

    /// Total measured nanoseconds across all layers.
    pub fn total_nanos(&self) -> u64 {
        self.nanos.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shares_sum_to_one_when_time_recorded() {
        let p = LayerProfiler::new(vec!["a".into(), "b".into(), "c".into()]);
        p.record(0, 100);
        p.record(1, 300);
        p.record(2, 600);
        let rows = p.snapshot();
        assert_eq!(rows.len(), 3);
        let total: f64 = rows.iter().map(|r| r.measured_share).sum();
        assert!((total - 1.0).abs() < 1e-12);
        assert!((rows[2].measured_share - 0.6).abs() < 1e-12);
        assert_eq!(rows[1].samples, 1);
        assert_eq!(p.total_nanos(), 1000);
    }

    #[test]
    fn empty_profiler_yields_zero_shares() {
        let p = LayerProfiler::new(vec!["a".into()]);
        let rows = p.snapshot();
        assert_eq!(rows[0].measured_share, 0.0);
        assert_eq!(rows[0].samples, 0);
    }

    #[test]
    fn out_of_range_record_is_ignored() {
        let p = LayerProfiler::new(vec!["a".into()]);
        p.record(5, 1_000);
        assert_eq!(p.total_nanos(), 0);
    }
}
