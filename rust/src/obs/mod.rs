//! Observability tier (DESIGN.md §13): flight-recorder tracing,
//! per-layer profiling, and live metrics exposition.
//!
//! Three legs, all std-only and dependency-free:
//!
//! * [`trace`] — a fixed-capacity [`FlightRecorder`] of per-request
//!   [`SpanRecord`]s stamped by the shared [`Clock`] (wall in
//!   production, the loadgen virtual clock under seeded replay, making
//!   traces byte-deterministic). Overflow is counted, never blocking:
//!   `spans_recorded + spans_dropped` reconciles exactly with the
//!   intake counters `completed + errored + rejected + shed`.
//! * [`profile`] — optional atomic per-layer accumulators inside the
//!   compiled/folded execute paths; timing-only, so profiled runs are
//!   bit-identical to unprofiled ones. Surfaced as the measured side of
//!   the `cnn-flow profile` divergence table against
//!   `SchedulePrediction::cycle_shares` and `FoldedPrediction`.
//! * [`prom`] — Prometheus text-format rendering of every snapshot,
//!   served via the `MetricsText` wire request on both net cores and
//!   the plain-TCP [`TextEndpoint`] (`serve --metrics-listen`).

pub mod clock;
pub mod endpoint;
pub mod profile;
pub mod prom;
pub mod trace;

pub use clock::Clock;
pub use endpoint::TextEndpoint;
pub use profile::{LayerProfileRow, LayerProfiler};
pub use prom::{lint, render_exposition};
pub use trace::{
    stage_summary, ActiveSpan, FlightRecorder, SpanOutcome, SpanRecord, StageStats,
    TraceStatsSnapshot,
};
