//! Flight recorder: a fixed-capacity ring of per-request span records.
//!
//! Every request routed into the coordinator is assigned a `TraceId` and
//! carries an [`ActiveSpan`] from intake to its terminal outcome. Stage
//! timestamps come from the one [`Clock`](super::Clock) in the server
//! config. The recorder never blocks the serving path: when the ring is
//! full, new spans are *dropped and counted*, so the accounting identity
//!
//! ```text
//! spans_recorded + spans_dropped == completed + errored + rejected + shed
//! ```
//!
//! holds exactly against the coordinator's intake counters after a
//! drain (unrouted submissions never reach a group, so they carry no
//! span — mirroring how `MetricsSnapshot` keeps `unrouted` outside the
//! per-model intake ledger).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::Clock;

/// Terminal outcome of a traced request. Maps 1:1 onto the intake
/// counters the recorder reconciles against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanOutcome {
    /// Executed and answered with logits (`completed`).
    Completed,
    /// Executed but the engine returned an error (`errored`).
    Errored,
    /// Turned away at intake: every shard queue full (`rejected`).
    Rejected,
    /// Turned away by admission control: predicted SLO miss (`shed`).
    Shed,
}

impl SpanOutcome {
    pub fn as_str(&self) -> &'static str {
        match self {
            SpanOutcome::Completed => "completed",
            SpanOutcome::Errored => "errored",
            SpanOutcome::Rejected => "rejected",
            SpanOutcome::Shed => "shed",
        }
    }
}

/// One request's life, stamped at each pipeline stage. A stage the
/// request never reached keeps its stamp at 0 (rejected/shed requests
/// never dequeue, batch, or execute).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanRecord {
    /// Monotone intake-order id, unique per recorder.
    pub trace_id: u64,
    /// Model the request was routed to.
    pub model: Arc<str>,
    /// Shard whose queue accepted the request.
    pub shard: u32,
    /// Frames in the batch this request executed with (0 if never
    /// batched).
    pub batch_size: u32,
    pub outcome: SpanOutcome,
    /// Clock reading at intake, before admission screening.
    pub submitted_ns: u64,
    /// Accepted into a shard queue (admission + dispatch done).
    pub admitted_ns: u64,
    /// Pulled off the queue by a worker (queue wait ends).
    pub dequeued_ns: u64,
    /// Batch assembly closed (flush fired) and execution is imminent.
    pub batched_ns: u64,
    /// Engine execute began for the batch holding this request.
    pub exec_start_ns: u64,
    /// Engine execute finished.
    pub exec_end_ns: u64,
    /// Reply handed to the response channel (span finalized).
    pub replied_ns: u64,
}

/// Recorder occupancy and accounting counters, snapshot for exposition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceStatsSnapshot {
    pub capacity: u64,
    /// Spans currently retained in the ring.
    pub retained: u64,
    pub spans_recorded: u64,
    pub spans_dropped: u64,
}

/// Lock-light fixed-capacity span sink. The hot path touches the mutex
/// only once per *finished* request (never per stage); overflow drops
/// the new span and bumps a counter instead of blocking or evicting —
/// eviction would break the reconciliation identity by double-counting
/// a request as both recorded and dropped.
#[derive(Debug)]
pub struct FlightRecorder {
    capacity: usize,
    ring: Mutex<Vec<SpanRecord>>,
    next_id: AtomicU64,
    recorded: AtomicU64,
    dropped: AtomicU64,
}

impl FlightRecorder {
    pub fn new(capacity: usize) -> FlightRecorder {
        let capacity = capacity.max(1);
        FlightRecorder {
            capacity,
            ring: Mutex::new(Vec::with_capacity(capacity)),
            next_id: AtomicU64::new(0),
            recorded: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Next intake-order trace id (1-based; 0 means "untraced").
    pub fn next_trace_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Sink a finalized span. Never blocks beyond the ring lock; a full
    /// ring counts the span as dropped.
    pub fn record(&self, span: SpanRecord) {
        let mut ring = self.ring.lock().unwrap();
        if ring.len() < self.capacity {
            ring.push(span);
            drop(ring);
            self.recorded.fetch_add(1, Ordering::Release);
        } else {
            drop(ring);
            self.dropped.fetch_add(1, Ordering::Release);
        }
    }

    /// Clone out the retained spans, sorted by trace id (intake order)
    /// so dumps are stable regardless of worker finish order.
    pub fn spans(&self) -> Vec<SpanRecord> {
        let mut out = self.ring.lock().unwrap().clone();
        out.sort_by_key(|s| s.trace_id);
        out
    }

    pub fn stats(&self) -> TraceStatsSnapshot {
        let retained = self.ring.lock().unwrap().len() as u64;
        TraceStatsSnapshot {
            capacity: self.capacity as u64,
            retained,
            spans_recorded: self.recorded.load(Ordering::Acquire),
            spans_dropped: self.dropped.load(Ordering::Acquire),
        }
    }
}

/// A span in flight, owned by the request it traces. Stages are stamped
/// in place; `finish` stamps the reply time and sinks the record. The
/// clock rides along so worker threads stamp without reaching back into
/// the server config.
#[derive(Debug)]
pub struct ActiveSpan {
    pub span: SpanRecord,
    pub recorder: Arc<FlightRecorder>,
    pub clock: Clock,
}

impl ActiveSpan {
    /// Open a span at intake: allocates the trace id and stamps
    /// `submitted_ns`.
    pub fn begin(recorder: &Arc<FlightRecorder>, clock: &Clock, model: &Arc<str>) -> ActiveSpan {
        let submitted_ns = clock.now_nanos();
        ActiveSpan {
            span: SpanRecord {
                trace_id: recorder.next_trace_id(),
                model: Arc::clone(model),
                shard: 0,
                batch_size: 0,
                outcome: SpanOutcome::Rejected,
                submitted_ns,
                admitted_ns: 0,
                dequeued_ns: 0,
                batched_ns: 0,
                exec_start_ns: 0,
                exec_end_ns: 0,
                replied_ns: 0,
            },
            recorder: Arc::clone(recorder),
            clock: clock.clone(),
        }
    }

    pub fn now(&self) -> u64 {
        self.clock.now_nanos()
    }

    /// Finalize: stamp `replied_ns`, set the outcome, and sink the
    /// record. Consumes the span — a request ends exactly once.
    pub fn finish(mut self, outcome: SpanOutcome) {
        self.span.replied_ns = self.clock.now_nanos();
        self.span.outcome = outcome;
        self.recorder.record(self.span);
    }
}

/// Latency quantiles for one pipeline stage across a span dump.
#[derive(Debug, Clone, PartialEq)]
pub struct StageStats {
    pub stage: &'static str,
    /// Spans that actually passed through this stage.
    pub count: u64,
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
}

fn quantile_sorted(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Per-stage latency breakdown (p50/p95/p99) over a span dump. Stages
/// are durations between consecutive stamps; only spans that reached a
/// stage contribute to it, so rejected/shed spans show up in the
/// `total` row but not in `execute`.
pub fn stage_summary(spans: &[SpanRecord]) -> Vec<StageStats> {
    let stages: [(&'static str, fn(&SpanRecord) -> Option<u64>); 6] = [
        ("admit", |s| {
            (s.admitted_ns > 0).then(|| s.admitted_ns.saturating_sub(s.submitted_ns))
        }),
        ("queue_wait", |s| {
            (s.dequeued_ns > 0).then(|| s.dequeued_ns.saturating_sub(s.admitted_ns))
        }),
        ("batch_assembly", |s| {
            (s.batched_ns > 0).then(|| s.batched_ns.saturating_sub(s.dequeued_ns))
        }),
        ("execute", |s| {
            (s.exec_end_ns > 0).then(|| s.exec_end_ns.saturating_sub(s.exec_start_ns))
        }),
        ("reply", |s| {
            (s.exec_end_ns > 0).then(|| s.replied_ns.saturating_sub(s.exec_end_ns))
        }),
        ("total", |s| {
            Some(s.replied_ns.saturating_sub(s.submitted_ns))
        }),
    ];
    stages
        .iter()
        .map(|(name, dur)| {
            let mut xs: Vec<u64> = spans.iter().filter_map(dur).collect();
            xs.sort_unstable();
            StageStats {
                stage: name,
                count: xs.len() as u64,
                p50_ns: quantile_sorted(&xs, 0.50),
                p95_ns: quantile_sorted(&xs, 0.95),
                p99_ns: quantile_sorted(&xs, 0.99),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(recorder: &Arc<FlightRecorder>, clock: &Clock) -> ActiveSpan {
        let model: Arc<str> = Arc::from("m");
        ActiveSpan::begin(recorder, clock, &model)
    }

    #[test]
    fn wrap_accounting_reconciles_with_submitted_total() {
        // Ring capacity 4, 10 spans submitted: exactly 4 recorded, 6
        // dropped — recorded + dropped equals the submitted-side total.
        let rec = Arc::new(FlightRecorder::new(4));
        let clock = Clock::wall();
        for _ in 0..10 {
            span(&rec, &clock).finish(SpanOutcome::Completed);
        }
        let stats = rec.stats();
        assert_eq!(stats.spans_recorded, 4);
        assert_eq!(stats.spans_dropped, 6);
        assert_eq!(stats.spans_recorded + stats.spans_dropped, 10);
        assert_eq!(stats.retained, 4);
        assert_eq!(stats.capacity, 4);
    }

    #[test]
    fn trace_ids_are_monotone_and_dump_is_intake_ordered() {
        let rec = Arc::new(FlightRecorder::new(8));
        let clock = Clock::wall();
        let a = span(&rec, &clock);
        let b = span(&rec, &clock);
        assert!(b.span.trace_id > a.span.trace_id);
        // Finish out of order; the dump still sorts by intake order.
        b.finish(SpanOutcome::Errored);
        a.finish(SpanOutcome::Completed);
        let spans = rec.spans();
        assert_eq!(spans.len(), 2);
        assert!(spans[0].trace_id < spans[1].trace_id);
        assert_eq!(spans[0].outcome, SpanOutcome::Completed);
    }

    #[test]
    fn stage_summary_skips_unreached_stages() {
        let rec = Arc::new(FlightRecorder::new(8));
        let clock = Clock::wall();
        // One completed span with all stamps, one rejected span that
        // never made it past intake.
        let mut s = span(&rec, &clock);
        s.span.admitted_ns = s.span.submitted_ns + 10;
        s.span.dequeued_ns = s.span.submitted_ns + 30;
        s.span.batched_ns = s.span.submitted_ns + 40;
        s.span.exec_start_ns = s.span.submitted_ns + 40;
        s.span.exec_end_ns = s.span.submitted_ns + 90;
        s.finish(SpanOutcome::Completed);
        span(&rec, &clock).finish(SpanOutcome::Rejected);

        let spans = rec.spans();
        let summary = stage_summary(&spans);
        let by_name = |n: &str| summary.iter().find(|s| s.stage == n).unwrap().clone();
        assert_eq!(by_name("admit").count, 1);
        assert_eq!(by_name("queue_wait").count, 1);
        assert_eq!(by_name("queue_wait").p50_ns, 20);
        assert_eq!(by_name("execute").count, 1);
        assert_eq!(by_name("execute").p50_ns, 50);
        assert_eq!(by_name("total").count, 2);
    }

    #[test]
    fn quantiles_on_sorted_data() {
        let xs: Vec<u64> = (1..=100).collect();
        assert_eq!(quantile_sorted(&xs, 0.50), 50);
        assert_eq!(quantile_sorted(&xs, 0.95), 95);
        assert_eq!(quantile_sorted(&xs, 0.99), 99);
        assert_eq!(quantile_sorted(&[], 0.5), 0);
    }
}
