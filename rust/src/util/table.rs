//! Plain-text table rendering for the paper-table reports.

/// A simple column-aligned table with a title, used by `report/` to print
/// every reproduced paper table in a uniform format.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub footnotes: Vec<String>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Self {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            footnotes: Vec::new(),
        }
    }

    pub fn row<S: ToString>(&mut self, cells: &[S]) -> &mut Self {
        self.rows.push(cells.iter().map(|c| c.to_string()).collect());
        self
    }

    pub fn footnote(&mut self, note: impl Into<String>) -> &mut Self {
        self.footnotes.push(note.into());
        self
    }

    /// Column widths: max over header and all rows.
    fn widths(&self) -> Vec<usize> {
        let ncols = self
            .header
            .len()
            .max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut w = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, width) in w.iter().enumerate() {
                let cell = cells.get(i).map(String::as_str).unwrap_or("");
                if i > 0 {
                    line.push_str("  ");
                }
                // Right-align numeric-looking cells, left-align text.
                let numeric = cell
                    .chars()
                    .next()
                    .map(|c| c.is_ascii_digit() || c == '-' || c == '+')
                    .unwrap_or(false);
                if numeric && i > 0 {
                    line.push_str(&format!("{cell:>width$}"));
                } else {
                    line.push_str(&format!("{cell:<width$}"));
                }
            }
            line.trim_end().to_string()
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push('\n');
            out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len().saturating_sub(1))));
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        for note in &self.footnotes {
            out.push_str(&format!("  {note}\n"));
        }
        out
    }

    /// Render as CSV (for downstream ingestion / plotting).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .header
                .iter()
                .map(|h| esc(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["name", "count"]);
        t.row(&["a", "1"]);
        t.row(&["long-name", "12345"]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("long-name"));
        // count column right-aligned under its header width
        assert!(s.lines().count() >= 4);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(&["x,y", "2"]);
        assert_eq!(t.to_csv(), "a,b\n\"x,y\",2\n");
    }

    #[test]
    fn handles_ragged_rows() {
        let mut t = Table::new("", &["a"]);
        t.row(&["1", "2", "3"]);
        let s = t.render();
        assert!(s.contains('3'));
    }
}
