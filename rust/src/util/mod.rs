//! Small self-contained utilities.
//!
//! This build environment is fully offline with only the `xla` crate tree
//! vendored, so the usual ecosystem crates (rand, serde_json, proptest,
//! criterion, clap) are replaced by the minimal implementations in this
//! module. Each sub-module documents which crate it stands in for.

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod table;

pub use rng::Rng;
pub use table::Table;

/// Ceiling division for unsigned integers.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Greatest divisor of `n` that is `<= cap` (paper Eq. 14).
///
/// `n >= 1` is required; the result is always >= 1 because 1 divides n.
pub fn greatest_divisor_leq(n: usize, cap: usize) -> usize {
    assert!(n >= 1, "n must be positive");
    let cap = cap.max(1).min(n);
    (1..=cap).rev().find(|d| n % d == 0).unwrap_or(1)
}

/// Format a count the way the paper's tables do: exact below 1000,
/// `x.yk` / `x.yM` above.
pub fn paper_count(n: u64) -> String {
    if n < 1000 {
        format!("{n}")
    } else if n < 1_000_000 {
        let k = n as f64 / 1000.0;
        if k >= 100.0 {
            format!("{:.0}k", k)
        } else {
            format!("{:.1}k", k)
        }
    } else {
        format!("{:.1}M", n as f64 / 1_000_000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(8, 4), 2);
        assert_eq!(ceil_div(9, 4), 3);
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 1), 1);
    }

    #[test]
    fn greatest_divisor_examples() {
        // Paper Eq. 14 example: d_l = 10 neurons, h_max = 9 -> h = 5.
        assert_eq!(greatest_divisor_leq(10, 9), 5);
        assert_eq!(greatest_divisor_leq(16, 16), 16);
        assert_eq!(greatest_divisor_leq(16, 15), 8);
        assert_eq!(greatest_divisor_leq(7, 3), 1);
        assert_eq!(greatest_divisor_leq(12, 6), 6);
    }

    #[test]
    fn paper_count_formats() {
        assert_eq!(paper_count(999), "999");
        assert_eq!(paper_count(1024), "1.0k");
        assert_eq!(paper_count(6672), "6.7k");
        assert_eq!(paper_count(5060), "5.1k");
        assert_eq!(paper_count(11_700_000), "11.7M");
    }
}
