//! Minimal JSON parser/writer (stands in for `serde_json`).
//!
//! Used for: model configs loaded by the CLI, the `artifacts/meta.json`
//! weight manifest written by `python/compile/aot.py`, and report export.
//! Supports the full JSON grammar except `\u` surrogate pairs outside the
//! BMP being recombined (unpaired surrogates are replaced).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
///
/// Numbers come in two flavours: [`Json::UInt`] holds non-negative
/// integers **exactly** (counters above 2^53 survive render/parse
/// round-trips bit-for-bit), while [`Json::Num`] holds everything else
/// as f64. The parser routes fraction-less non-negative literals to
/// `UInt`, so `parse(render(x)) == x` for both variants.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    /// Exact non-negative integer — lossless where f64 is not.
    UInt(u64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- accessors -------------------------------------------------------

    /// Numeric value as f64 — lossy above 2^53 for [`Json::UInt`]; use
    /// [`Json::as_u64`] where exactness matters.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            Json::UInt(n) => Some(*n as f64),
            _ => None,
        }
    }

    /// Exact integer value: `UInt` verbatim, or a `Num` that happens to
    /// be a representable non-negative integer.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::UInt(n) => Some(*n),
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n < 1.8446744073709552e19 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().and_then(|n| usize::try_from(n).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` convenience; returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // -- writer ----------------------------------------------------------

    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    pub fn render_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                out.push_str(&"  ".repeat(n));
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::UInt(n) => out.push_str(&format!("{n}")),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !o.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.i,
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .ok_or_else(|| self.err("bad number"))?;
        // Fraction-less non-negative literals stay exact (u64), matching
        // what the writer emits for Json::UInt — counters above 2^53
        // round-trip losslessly. Everything else goes through f64.
        if !s.starts_with('-') && !s.contains(['.', 'e', 'E']) {
            if let Ok(n) = s.parse::<u64>() {
                return Ok(Json::UInt(n));
            }
        }
        s.parse::<f64>()
            .ok()
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u"))?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Advance one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut o = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(o));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            o.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(o));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Builder helpers.
impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::UInt(x as u64)).collect())
    }

    pub fn arr_u64(xs: &[u64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::UInt(x)).collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}

impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::UInt(n as u64)
    }
}

impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::UInt(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2.5,-3e2],"b":{"c":"x\ny","d":true,"e":null}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.render()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(v.get("b").get("c").as_str(), Some("x\ny"));
        assert_eq!(v.get("b").get("d").as_bool(), Some(true));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(5.0).render(), "5");
        assert_eq!(Json::Num(5.5).render(), "5.5");
        assert_eq!(Json::UInt(5).render(), "5");
    }

    #[test]
    fn u64_counters_round_trip_exactly_above_2_pow_53() {
        // 2^53 + 1 is NOT representable in f64; the integer variant must
        // carry it (and u64::MAX) through render+parse bit-for-bit.
        for n in [(1u64 << 53) + 1, u64::MAX, u64::MAX - 1] {
            let v = Json::from(n);
            let back = Json::parse(&v.render()).unwrap();
            assert_eq!(back, v, "{n} mangled by round-trip");
            assert_eq!(back.as_u64(), Some(n));
        }
        // The f64 path really would have lost it — guard the guard.
        assert_ne!(((1u64 << 53) + 1) as f64 as u64, (1u64 << 53) + 1);
        // Negative and fractional literals still parse as f64.
        assert_eq!(Json::parse("-3").unwrap(), Json::Num(-3.0));
        assert_eq!(Json::parse("2.5").unwrap(), Json::Num(2.5));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(
            Json::parse("{}").unwrap(),
            Json::Obj(Default::default())
        );
    }

    #[test]
    fn missing_key_is_null() {
        let v = Json::parse(r#"{"a":1}"#).unwrap();
        assert_eq!(*v.get("nope"), Json::Null);
        assert_eq!(v.get("a").as_usize(), Some(1));
    }

    /// Golden round-trip for the `serve --metrics-json` report: the
    /// schema version is present, counters above 2^53 survive exactly,
    /// and render → parse reproduces the report value-for-value. This
    /// pins the report *format* where it is produced and consumed — a
    /// schema change without a version bump trips this test first.
    #[test]
    fn metrics_report_round_trips_with_schema_version() {
        use crate::coordinator::metrics::{
            metrics_report_json, MetricsSnapshot, ModelMetricsSnapshot, NetMetricsSnapshot,
            METRICS_SCHEMA_VERSION, OCC_SLOTS,
        };
        use std::time::Duration;

        let snap = MetricsSnapshot {
            workers: 4,
            active_workers: 3,
            models: 2,
            accepted: 100,
            rejected: 5,
            shed: 2,
            scale_up_events: 1,
            scale_down_events: 1,
            spilled: 7,
            unrouted: 1,
            completed: 97,
            batches: 40,
            verified: 97,
            mismatches: 0,
            predicted_cycles: (1u64 << 60) + 3, // past f64's exact range
            simulated_cycles: 0,
            cycle_divergence: 0,
            errored: 3,
            occupancy_frames: 100,
            flush_full: 30,
            flush_deadline: 8,
            flush_drain: 2,
            batch_occupancy: [1; OCC_SLOTS],
            mean_batch: 2.5,
            mean_service: Duration::from_micros(120),
            p50: Duration::from_micros(100),
            p95: Duration::from_micros(300),
            p99: Duration::from_micros(900),
            projected_fps: 1.25e6,
            aggregate_fps: 5.0e6,
        };
        let per_model = vec![ModelMetricsSnapshot {
            model: "mobilenet_micro".into(),
            metrics: snap,
        }];
        let net = NetMetricsSnapshot {
            connections: 12,
            disconnects: 12,
            requests: 110,
            responses_ok: 97,
            err_queue_full: 5,
            err_slo_miss: 2,
            err_invalid_frame: 3,
            err_unknown_model: 1,
            err_draining: 2,
            err_malformed: 1,
        };
        let report = metrics_report_json(&snap, &per_model, Some(&net));
        assert_eq!(
            report.get("schema_version").as_u64(),
            Some(METRICS_SCHEMA_VERSION)
        );
        let back = Json::parse(&report.render()).expect("report must parse");
        assert_eq!(back, report, "render → parse must be lossless");
        // The over-2^53 counter survived exactly, in both copies.
        for v in [&report, &back] {
            assert_eq!(
                v.get("aggregate").get("predicted_cycles").as_u64(),
                Some((1u64 << 60) + 3)
            );
        }
        assert_eq!(
            back.get("models").as_arr().unwrap()[0].get("model").as_str(),
            Some("mobilenet_micro")
        );
        assert_eq!(back.get("net").get("requests").as_u64(), Some(110));
    }
}
