//! Tiny property-testing harness (stands in for `proptest`, which is not
//! vendored in this offline environment).
//!
//! Usage:
//! ```ignore
//! prop_check(256, 0xBEEF, |rng| {
//!     let k = rng.range(1, 7);
//!     // ... build inputs from rng, return Err(msg) on violation
//!     Ok(())
//! });
//! ```
//! On failure the harness reports the case index and the sub-seed so the
//! exact case replays deterministically (no shrinking — cases are kept
//! small by construction instead).

use super::rng::Rng;

/// Run `cases` random cases of `property`. Each case gets an independent
/// deterministic RNG derived from `seed` and the case index.
///
/// Panics with a replayable diagnostic on the first failing case.
pub fn prop_check<F>(cases: usize, seed: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let sub_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(sub_seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property failed at case {case}/{cases} (replay with seed {sub_seed:#x}): {msg}"
            );
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Equality helper with value printing.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr, $($fmt:tt)*) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} (left={:?}, right={:?})",
                format!($($fmt)*), a, b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        prop_check(50, 1, |rng| {
            n += 1;
            let x = rng.range(0, 100);
            prop_assert!(x <= 100, "x out of range: {x}");
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        prop_check(50, 2, |rng| {
            let x = rng.range(0, 10);
            prop_assert!(x < 5, "x={x}");
            Ok(())
        });
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<usize> = Vec::new();
        prop_check(10, 77, |rng| {
            first.push(rng.range(0, 1000));
            Ok(())
        });
        let mut second: Vec<usize> = Vec::new();
        prop_check(10, 77, |rng| {
            second.push(rng.range(0, 1000));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
