//! Deterministic PRNG (stands in for the `rand` crate).
//!
//! splitmix64 seeded xoshiro256++ — good statistical quality, tiny, and
//! reproducible across runs, which matters because simulator tests and the
//! property harness derive all inputs from fixed seeds.

/// A xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so that nearby seeds produce unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. `n` must be non-zero.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's method without the rejection loop is fine here: the
        // simulator only needs uniformity to ~2^-64 * n bias.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Uniform i8 in `[-127, 127]` (the symmetric int8 range the
    /// quantized hardware uses; -128 excluded like the paper's toolchain).
    pub fn int8(&mut self) -> i8 {
        (self.below(255) as i16 - 127) as i8
    }

    /// Shuffle a slice (Fisher-Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn int8_symmetric() {
        let mut r = Rng::new(5);
        for _ in 0..5000 {
            let v = r.int8();
            assert!(v >= -127);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
