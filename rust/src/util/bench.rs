//! Micro-benchmark harness (stands in for `criterion`, not vendored here).
//!
//! Each `[[bench]]` target with `harness = false` builds a binary that uses
//! this module: warm-up, fixed-duration measurement, and a summary line of
//! median / mean / p95 per iteration plus derived throughput. Output is
//! intentionally grep-stable: one `BENCH <name> ...` line per benchmark so
//! `bench_output.txt` can be diffed across the perf-pass iterations.

use std::time::{Duration, Instant};

pub struct BenchOpts {
    pub warmup: Duration,
    pub measure: Duration,
    /// Hard cap on measured iterations (for very slow benches).
    pub max_iters: u64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(900),
            max_iters: 1_000_000,
        }
    }
}

pub struct Bencher {
    group: String,
    opts: BenchOpts,
}

/// A started timer — the one helper behind every "how long did this
/// take" loop in the bench harness and the CLI, so elapsed-time
/// bookkeeping (ns truncation, secs conversion, budget loops) lives in
/// one place instead of being re-rolled per call site.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed wall nanoseconds, saturating at `u64::MAX` (≈ 584 years).
    pub fn elapsed_ns(&self) -> u64 {
        u64::try_from(self.start.elapsed().as_nanos()).unwrap_or(u64::MAX)
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Time one invocation of `f`, in nanoseconds.
    pub fn time_ns<F: FnOnce()>(f: F) -> u64 {
        let sw = Stopwatch::start();
        f();
        sw.elapsed_ns()
    }

    /// Run `f` repeatedly until `budget` has elapsed (zero budget runs
    /// it zero times); returns the iteration count.
    pub fn run_for<F: FnMut()>(budget: Duration, mut f: F) -> u64 {
        let sw = Stopwatch::start();
        let mut iters = 0u64;
        while sw.elapsed() < budget {
            f();
            iters += 1;
        }
        iters
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

impl Bencher {
    pub fn new(group: &str) -> Self {
        println!("# bench group: {group}");
        Self {
            group: group.to_string(),
            opts: BenchOpts::default(),
        }
    }

    pub fn with_opts(group: &str, opts: BenchOpts) -> Self {
        println!("# bench group: {group}");
        Self {
            group: group.to_string(),
            opts,
        }
    }

    /// Benchmark `f`, reporting per-iteration stats. Returns median ns.
    pub fn bench<F: FnMut()>(&self, name: &str, mut f: F) -> f64 {
        // Warm-up.
        Stopwatch::run_for(self.opts.warmup, &mut f);
        // Measure in batches; record per-batch time to estimate spread.
        let mut samples_ns: Vec<f64> = Vec::new();
        let mut iters: u64 = 0;
        let t0 = Stopwatch::start();
        while t0.elapsed() < self.opts.measure && iters < self.opts.max_iters {
            samples_ns.push(Stopwatch::time_ns(&mut f) as f64);
            iters += 1;
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples_ns[samples_ns.len() / 2];
        let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
        let p95 = samples_ns[(samples_ns.len() as f64 * 0.95) as usize % samples_ns.len()];
        println!(
            "BENCH {}/{name} iters={iters} median={} mean={} p95={}",
            self.group,
            fmt_ns(median),
            fmt_ns(mean),
            fmt_ns(p95),
        );
        median
    }

    /// Benchmark with a throughput annotation (elements per iteration).
    pub fn bench_throughput<F: FnMut()>(&self, name: &str, elems: u64, f: F) -> f64 {
        let median = self.bench(name, f);
        let per_sec = elems as f64 / (median * 1e-9);
        println!(
            "BENCH {}/{name} throughput={:.3}M elems/s",
            self.group,
            per_sec / 1e6
        );
        median
    }
}

/// One interpreter-vs-compiled-vs-batched throughput comparison row,
/// shared by `benches/bench_pipeline.rs` and the `cnn-flow bench` CLI and
/// persisted to `BENCH_pipeline.json` so the perf trajectory is tracked
/// across PRs.
#[derive(Debug, Clone)]
pub struct EngineComparison {
    pub model: String,
    /// Frames per measured iteration.
    pub frames: usize,
    pub interp_median_ns: f64,
    pub compiled_median_ns: f64,
    /// One `execute_batch` traversal over the same frames.
    pub batched_median_ns: f64,
    /// One batched traversal through the rate-aware folded engine
    /// (fused low-rate pairs + register-blocked kernels, DESIGN.md §9).
    pub folded_median_ns: f64,
    /// Whether the lowering proved 32-bit lanes safe.
    pub narrow: bool,
}

impl EngineComparison {
    pub fn interp_fps(&self) -> f64 {
        self.frames as f64 / (self.interp_median_ns * 1e-9)
    }

    pub fn compiled_fps(&self) -> f64 {
        self.frames as f64 / (self.compiled_median_ns * 1e-9)
    }

    pub fn batched_fps(&self) -> f64 {
        self.frames as f64 / (self.batched_median_ns * 1e-9)
    }

    pub fn folded_fps(&self) -> f64 {
        self.frames as f64 / (self.folded_median_ns * 1e-9)
    }

    pub fn speedup(&self) -> f64 {
        self.interp_median_ns / self.compiled_median_ns
    }

    /// Batched tier vs frame-at-a-time compiled execution.
    pub fn batch_speedup(&self) -> f64 {
        self.compiled_median_ns / self.batched_median_ns
    }

    /// Folded engine vs the unfolded batched tier on the same frames —
    /// the rate-aware folding pass's measured win.
    pub fn fold_speedup(&self) -> f64 {
        self.batched_median_ns / self.folded_median_ns
    }
}

/// Measure one lowered model four ways — the fused interpreter, the
/// compiled engine executing frame-at-a-time, the compiled engine's
/// batched tier traversing the program once for the whole group
/// (iteration = one pass over `frames`), and the rate-aware folded
/// engine over the same batch — after asserting all paths agree
/// bit- and cycle-exactly. Shared by `benches/bench_pipeline.rs` and the
/// `cnn-flow bench` CLI so BENCH_pipeline.json numbers stay comparable.
pub fn compare_engines(
    b: &Bencher,
    sim: &crate::sim::pipeline::PipelineSim,
    frames: &[Vec<i64>],
) -> EngineComparison {
    let name = sim.qmodel.name.clone();
    let fast = sim.run(frames).expect("compiled run failed");
    let oracle = sim.run_interpreted(frames).expect("interpreter run failed");
    assert_eq!(fast.outputs, oracle.outputs, "{name}: value divergence");
    assert_eq!(
        fast.total_cycles, oracle.total_cycles,
        "{name}: cycle divergence"
    );
    let mut engine = sim.compiled.clone();
    let refs: Vec<&[i64]> = frames.iter().map(|f| f.as_slice()).collect();
    let batched = engine.execute_batch(&refs).expect("batched run failed");
    assert_eq!(batched, oracle.outputs, "{name}: batched value divergence");
    let mut folded = sim.folded.clone();
    let folded_out = folded.execute_batch(&refs).expect("folded run failed");
    assert_eq!(folded_out, oracle.outputs, "{name}: folded value divergence");
    let fp = sim.predicted.folded(frames.len(), &sim.fold_factors);
    if fp.exact {
        let replay = sim.schedule.run_folded(frames.len(), &sim.fold_factors);
        assert_eq!(
            fp.total_cycles, replay.total_cycles,
            "{name}: folded cycle prediction diverged from exact replay"
        );
    }
    let interp_median_ns = b.bench_throughput(
        &format!("{name}_interpreter/{}_frames", frames.len()),
        frames.len() as u64,
        || {
            black_box(sim.run_interpreted(frames).unwrap());
        },
    );
    let compiled_median_ns = b.bench_throughput(
        &format!("{name}_compiled/{}_frames", frames.len()),
        frames.len() as u64,
        || {
            for f in frames {
                black_box(engine.execute(f).unwrap());
            }
            black_box(sim.predicted.total_cycles(frames.len()));
        },
    );
    let batched_median_ns = b.bench_throughput(
        &format!("{name}_batched/{}_frames", frames.len()),
        frames.len() as u64,
        || {
            black_box(engine.execute_batch(&refs).unwrap());
            black_box(sim.predicted.batched(frames.len()).total_cycles);
        },
    );
    let folded_median_ns = b.bench_throughput(
        &format!("{name}_folded/{}_frames", frames.len()),
        frames.len() as u64,
        || {
            black_box(folded.execute_batch(&refs).unwrap());
            black_box(
                sim.predicted
                    .folded(frames.len(), &sim.fold_factors)
                    .total_cycles,
            );
        },
    );
    EngineComparison {
        model: name,
        frames: frames.len(),
        interp_median_ns,
        compiled_median_ns,
        batched_median_ns,
        folded_median_ns,
        narrow: sim.compiled.is_narrow(),
    }
}

/// Write the machine-readable benchmark report. Layout:
/// `{"bench":"pipeline","models":[{model, frames, interp_fps,
/// compiled_fps, batched_fps, folded_fps, speedup, batch_speedup,
/// fold_speedup, narrow}, ...]}`.
pub fn write_pipeline_bench_json(
    path: &std::path::Path,
    comparisons: &[EngineComparison],
) -> Result<(), String> {
    use crate::util::json::Json;
    let models: Vec<Json> = comparisons
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("model", Json::from(c.model.as_str())),
                ("frames", Json::from(c.frames)),
                ("interp_fps", Json::from(c.interp_fps())),
                ("compiled_fps", Json::from(c.compiled_fps())),
                ("batched_fps", Json::from(c.batched_fps())),
                ("folded_fps", Json::from(c.folded_fps())),
                ("speedup", Json::from(c.speedup())),
                ("batch_speedup", Json::from(c.batch_speedup())),
                ("fold_speedup", Json::from(c.fold_speedup())),
                ("narrow", Json::Bool(c.narrow)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::from("pipeline")),
        ("models", Json::Arr(models)),
    ]);
    std::fs::write(path, doc.render_pretty())
        .map_err(|e| format!("write {}: {e}", path.display()))
}

/// Localhost TCP round-trip vs in-process submit+wait on the same
/// coordinator — the serving front-end's overhead figure, measured by
/// `benches/bench_coordinator.rs` and merged into `BENCH_pipeline.json`
/// under `"net"` so the socket tax is tracked across PRs next to the
/// engine numbers.
#[derive(Debug, Clone)]
pub struct NetComparison {
    /// Median ns for one blocking in-process `Server::infer`.
    pub inproc_rtt_ns: f64,
    /// Median ns for the same request through the TCP client/server path.
    pub tcp_rtt_ns: f64,
}

impl NetComparison {
    /// Absolute socket overhead per request.
    pub fn overhead_ns(&self) -> f64 {
        self.tcp_rtt_ns - self.inproc_rtt_ns
    }

    /// TCP round-trip as a multiple of the in-process round-trip.
    pub fn overhead_ratio(&self) -> f64 {
        self.tcp_rtt_ns / self.inproc_rtt_ns
    }
}

/// Merge the net figures into `BENCH_pipeline.json` without disturbing
/// the engine rows: the existing document is parsed (or a fresh
/// `{"bench":"pipeline","models":[]}` skeleton is used when absent or
/// unparseable) and its `"net"` key is replaced. Run
/// `cargo bench --bench bench_pipeline` first for a complete report.
pub fn merge_net_bench_json(path: &std::path::Path, net: &NetComparison) -> Result<(), String> {
    use crate::util::json::Json;
    let mut root = std::fs::read_to_string(path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .filter(|v| v.as_obj().is_some())
        .unwrap_or_else(|| {
            Json::obj(vec![
                ("bench", Json::from("pipeline")),
                ("models", Json::Arr(Vec::new())),
            ])
        });
    if let Json::Obj(map) = &mut root {
        map.insert(
            "net".to_string(),
            Json::obj(vec![
                ("inproc_rtt_ns", Json::from(net.inproc_rtt_ns)),
                ("tcp_rtt_ns", Json::from(net.tcp_rtt_ns)),
                ("overhead_ns", Json::from(net.overhead_ns())),
                ("overhead_ratio", Json::from(net.overhead_ratio())),
            ]),
        );
    }
    std::fs::write(path, root.render_pretty())
        .map_err(|e| format!("write {}: {e}", path.display()))
}

/// One rung of the connections-vs-throughput ladder: the same fan-in
/// load (`connections` pipelined sockets × `requests_per_conn`
/// requests, plus a closed-loop window-1 RTT probe) driven against the
/// threaded and evented network cores. Measured by
/// `benches/bench_pipeline.rs` and merged into `BENCH_pipeline.json`
/// under `"fanin"` — the row where the evented core must strictly
/// dominate at high connection counts.
#[derive(Debug, Clone)]
pub struct FanInComparison {
    pub connections: usize,
    pub requests_per_conn: usize,
    /// Settled responses per second, fully pipelined.
    pub threaded_rps: f64,
    pub evented_rps: f64,
    /// Closed-loop (window = 1) round-trip p99 under the fan-in, µs.
    pub threaded_rtt_p99_us: f64,
    pub evented_rtt_p99_us: f64,
}

impl FanInComparison {
    /// Evented throughput as a multiple of threaded (>1 = evented wins).
    pub fn rps_ratio(&self) -> f64 {
        self.evented_rps / self.threaded_rps
    }
}

/// Merge the fan-in ladder into `BENCH_pipeline.json` without
/// disturbing the engine rows or the `"net"` object: the existing
/// document is parsed (or the pipeline skeleton is used when absent)
/// and its `"fanin"` key is replaced.
pub fn merge_fanin_bench_json(
    path: &std::path::Path,
    rows: &[FanInComparison],
) -> Result<(), String> {
    use crate::util::json::Json;
    let mut root = std::fs::read_to_string(path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .filter(|v| v.as_obj().is_some())
        .unwrap_or_else(|| {
            Json::obj(vec![
                ("bench", Json::from("pipeline")),
                ("models", Json::Arr(Vec::new())),
            ])
        });
    if let Json::Obj(map) = &mut root {
        let arr: Vec<Json> = rows
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("connections", Json::from(r.connections)),
                    ("requests_per_conn", Json::from(r.requests_per_conn)),
                    ("threaded_rps", Json::from(r.threaded_rps)),
                    ("evented_rps", Json::from(r.evented_rps)),
                    ("rps_ratio", Json::from(r.rps_ratio())),
                    ("threaded_rtt_p99_us", Json::from(r.threaded_rtt_p99_us)),
                    ("evented_rtt_p99_us", Json::from(r.evented_rtt_p99_us)),
                ])
            })
            .collect();
        map.insert("fanin".to_string(), Json::Arr(arr));
    }
    std::fs::write(path, root.render_pretty())
        .map_err(|e| format!("write {}: {e}", path.display()))
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bencher::with_opts(
            "test",
            BenchOpts {
                warmup: Duration::from_millis(5),
                measure: Duration::from_millis(20),
                max_iters: 10_000,
            },
        );
        let mut acc = 0u64;
        let med = b.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(med >= 0.0);
        assert!(acc > 0);
    }

    #[test]
    fn comparison_report_roundtrips() {
        let c = EngineComparison {
            model: "synthetic".into(),
            frames: 16,
            interp_median_ns: 8.0e6,
            compiled_median_ns: 1.0e6,
            batched_median_ns: 0.5e6,
            folded_median_ns: 0.25e6,
            narrow: true,
        };
        assert!((c.speedup() - 8.0).abs() < 1e-9);
        assert!((c.compiled_fps() - 16.0e6).abs() < 1.0);
        assert!((c.batched_fps() - 32.0e6).abs() < 1.0);
        assert!((c.folded_fps() - 64.0e6).abs() < 1.0);
        assert!((c.batch_speedup() - 2.0).abs() < 1e-9);
        assert!((c.fold_speedup() - 2.0).abs() < 1e-9);
        let path = std::env::temp_dir().join("cnn_flow_bench_pipeline_test.json");
        write_pipeline_bench_json(&path, &[c]).unwrap();
        let parsed =
            crate::util::json::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("bench").as_str(), Some("pipeline"));
        let row = &parsed.get("models").as_arr().unwrap()[0];
        assert_eq!(row.get("model").as_str(), Some("synthetic"));
        assert!((row.get("speedup").as_f64().unwrap() - 8.0).abs() < 1e-9);
        assert!((row.get("batch_speedup").as_f64().unwrap() - 2.0).abs() < 1e-9);
        assert!((row.get("fold_speedup").as_f64().unwrap() - 2.0).abs() < 1e-9);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn net_merge_preserves_engine_rows() {
        let path = std::env::temp_dir().join("cnn_flow_bench_net_merge_test.json");
        let engines = EngineComparison {
            model: "synthetic".into(),
            frames: 16,
            interp_median_ns: 8.0e6,
            compiled_median_ns: 1.0e6,
            batched_median_ns: 0.5e6,
            folded_median_ns: 0.25e6,
            narrow: true,
        };
        write_pipeline_bench_json(&path, &[engines]).unwrap();
        let net = NetComparison {
            inproc_rtt_ns: 10_000.0,
            tcp_rtt_ns: 40_000.0,
        };
        assert!((net.overhead_ns() - 30_000.0).abs() < 1e-9);
        assert!((net.overhead_ratio() - 4.0).abs() < 1e-9);
        merge_net_bench_json(&path, &net).unwrap();
        let parsed =
            crate::util::json::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        // The engine rows survive the merge and the net object lands.
        assert_eq!(parsed.get("models").as_arr().unwrap().len(), 1);
        assert_eq!(
            parsed.get("net").get("tcp_rtt_ns").as_f64(),
            Some(40_000.0)
        );
        // Merging into a missing file builds the skeleton.
        let _ = std::fs::remove_file(&path);
        merge_net_bench_json(&path, &net).unwrap();
        let parsed =
            crate::util::json::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("bench").as_str(), Some("pipeline"));
        assert_eq!(parsed.get("models").as_arr().unwrap().len(), 0);
        assert_eq!(
            parsed.get("net").get("overhead_ratio").as_f64(),
            Some(4.0)
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fanin_merge_preserves_other_sections() {
        let path = std::env::temp_dir().join("cnn_flow_bench_fanin_merge_test.json");
        let engines = EngineComparison {
            model: "synthetic".into(),
            frames: 16,
            interp_median_ns: 8.0e6,
            compiled_median_ns: 1.0e6,
            batched_median_ns: 0.5e6,
            folded_median_ns: 0.25e6,
            narrow: true,
        };
        write_pipeline_bench_json(&path, &[engines]).unwrap();
        let rows = [
            FanInComparison {
                connections: 64,
                requests_per_conn: 16,
                threaded_rps: 10_000.0,
                evented_rps: 20_000.0,
                threaded_rtt_p99_us: 900.0,
                evented_rtt_p99_us: 450.0,
            },
            FanInComparison {
                connections: 1024,
                requests_per_conn: 8,
                threaded_rps: 5_000.0,
                evented_rps: 25_000.0,
                threaded_rtt_p99_us: 4_000.0,
                evented_rtt_p99_us: 800.0,
            },
        ];
        assert!((rows[1].rps_ratio() - 5.0).abs() < 1e-9);
        merge_fanin_bench_json(&path, &rows).unwrap();
        let parsed =
            crate::util::json::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("models").as_arr().unwrap().len(), 1);
        let fanin = parsed.get("fanin").as_arr().unwrap();
        assert_eq!(fanin.len(), 2);
        assert_eq!(fanin[1].get("connections").as_f64(), Some(1024.0));
        assert_eq!(fanin[1].get("rps_ratio").as_f64(), Some(5.0));
        // Re-merging replaces the ladder instead of appending.
        merge_fanin_bench_json(&path, &rows[..1]).unwrap();
        let parsed =
            crate::util::json::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("fanin").as_arr().unwrap().len(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn stopwatch_times_and_budgets() {
        let ns = Stopwatch::time_ns(|| {
            std::hint::black_box(1 + 1);
        });
        assert!(ns < 1_000_000_000, "a no-op cannot take a second: {ns}");
        let sw = Stopwatch::start();
        assert!(sw.elapsed_secs() >= 0.0);
        assert!(sw.elapsed_ns() <= sw.elapsed_ns(), "monotone");
        assert_eq!(Stopwatch::run_for(Duration::ZERO, || ()), 0);
        let mut n = 0u64;
        let iters = Stopwatch::run_for(Duration::from_millis(2), || n += 1);
        assert_eq!(iters, n);
        assert!(iters > 0);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("us"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }
}
