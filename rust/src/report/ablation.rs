//! Ablation studies for the design choices DESIGN.md calls out — each
//! isolates one mechanism of the paper and quantifies what it buys.
//!
//! 1. [`interleaving_ablation`] — reconfigurable shared units (Section
//!    IV-C) vs. naive replication at the same input rate: the
//!    arithmetic-for-multiplexers trade at every data rate.
//! 2. [`padding_ablation`] — implicit zero padding (Fig. 4) vs. the
//!    conventional explicit zero feed: cycles per frame and the
//!    throughput the masking trick recovers (Section III-B).
//! 3. [`aggregation_ablation`] — the FCU input aggregation factor a
//!    (Eq. 15): how widening the batch trades FCU count against buffer
//!    registers and fill latency.

use crate::complexity::{layer_cost, CostOpts};
use crate::flow::{plan_layer, PlannedLayer, Ratio, UnitPlan};
use crate::util::Table;

/// Ablation 1: interleaving on/off for a conv layer across data rates.
///
/// "Off" keeps one kernel per KPU (the unrolled mapping) while the input
/// rate drops — units idle 1 - r/d of the time. "On" is the paper's plan.
pub fn interleaving_ablation(f: usize, k: usize, d_in: usize, d_out: usize) -> Table {
    let mut t = Table::new(
        format!("Ablation: interleaving vs replication (conv f={f},k={k},{d_in}->{d_out})"),
        &[
            "r_in", "KPUs off", "KPUs on", "Mul off", "Mul on", "MUX on", "util off",
            "util on",
        ],
    );
    let mut r = Ratio::int(d_in as u64);
    for _ in 0..6 {
        let on = crate::report::synthetic_conv_layer(f, k, (k - 1) / 2, d_in, d_out, r);
        let cost_on = layer_cost(&on, CostOpts::LAYER_ONLY);
        // Replication baseline: force the full-rate plan (C = 1) but feed
        // it at rate r -> utilisation r / d_in.
        let mut forced = on.rated.clone();
        forced.r_in = Ratio::int(d_in as u64);
        let off = plan_layer(&forced);
        let cost_off = layer_cost(&off, CostOpts::LAYER_ONLY);
        let util_off = r.to_f64() / d_in as f64;
        let util_on = if on.plan.stalled() {
            (d_in * d_out) as f64 / (on.plan.unit_count() * r.ceil_div_into(d_in as u64) as usize) as f64
        } else {
            1.0
        };
        t.row(&[
            r.paper(),
            cost_off.kpus.to_string(),
            cost_on.kpus.to_string(),
            cost_off.multipliers.to_string(),
            cost_on.multipliers.to_string(),
            cost_on.mux2.to_string(),
            format!("{:.0}%", util_off * 100.0),
            format!("{:.0}%", util_on.min(1.0) * 100.0),
        ]);
        r = r.div_int(2);
    }
    t.footnote("off = one kernel per unit at full parallelism (idle when r < d);");
    t.footnote("on  = the paper's interleaved plan (busy every cycle).");
    t
}

/// Ablation 2: implicit vs explicit zero padding, per Section III-B.
///
/// Explicit padding widens the input stream to (f+2p)^2 cycles per frame
/// and breaks input continuity; implicit padding keeps f^2 data cycles
/// plus the shared p*f+p inter-frame zero rows.
pub fn padding_ablation() -> Table {
    let mut t = Table::new(
        "Ablation: implicit vs explicit zero padding (cycles per frame, s=1)",
        &[
            "f", "k", "p", "explicit", "implicit", "speedup", "extra MUX2/KPU",
        ],
    );
    for (f, k) in [(5usize, 3usize), (12, 3), (24, 5), (28, 7), (112, 3)] {
        let p = (k - 1) / 2;
        let explicit = (f + 2 * p) * (f + 2 * p);
        let implicit = f * f + p * f + p;
        // The masking hardware: one AND-mask (~1 LUT-mux eq.) per
        // multiplier column select line, k selects per KPU.
        t.row(&[
            f.to_string(),
            k.to_string(),
            p.to_string(),
            explicit.to_string(),
            implicit.to_string(),
            format!("{:.3}x", explicit as f64 / implicit as f64),
            k.to_string(),
        ]);
    }
    t.footnote("explicit = conventional zero-fed stream (f+2p)^2;");
    t.footnote("implicit = Fig. 4 masking: f^2 + p*f + p shared inter-frame rows.");
    t
}

/// Ablation 3: FCU aggregation factor a (Eq. 15) on a low-rate dense
/// layer (r = 1): each doubling of a halves the FCU count while growing
/// the aggregation buffer and the fill latency.
pub fn aggregation_ablation(d_in: usize, d_out: usize) -> Table {
    let mut t = Table::new(
        format!("Ablation: FCU aggregation a (dense {d_in}->{d_out}, r=1)"),
        &["a", "j", "h", "FCUs", "Mul", "Reg (FCU+agg)", "fill latency (cycles)"],
    );
    for a in [1usize, 2, 4, 8] {
        if a > d_in {
            break;
        }
        // Aggregated rate: a inputs over a cycles (Eq. 15).
        let j = a;
        let h_cap = a;
        let h = crate::util::greatest_divisor_leq(d_out, h_cap);
        let fcus = d_out.div_ceil(h);
        let configs = (h * d_in).div_ceil(j);
        let unit = crate::complexity::fcu_cost(j, h, configs);
        let agg = crate::complexity::aggregator_cost(1, a);
        let mul = unit.multipliers * fcus as u64;
        let reg = unit.registers * fcus as u64 + agg.registers;
        // Fill: all inputs arrive over d_in cycles; aggregation adds a-1
        // cycles before the first wide batch, as in Table IV.
        let latency = d_in + (a - 1) + h;
        t.row(&[
            a.to_string(),
            j.to_string(),
            h.to_string(),
            fcus.to_string(),
            mul.to_string(),
            reg.to_string(),
            latency.to_string(),
        ]);
    }
    t.footnote("Paper Section III-E: aggregation keeps h above the adder pipeline");
    t.footnote("depth at a small latency cost (Table IV: +1 cycle for a=4).");
    t
}

/// Render all three studies (CLI `cnn-flow ablation`).
pub fn all_ablations() -> Vec<Table> {
    vec![
        interleaving_ablation(28, 7, 8, 16),
        padding_ablation(),
        aggregation_ablation(256, 10),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interleaving_keeps_mults_proportional_to_rate() {
        let t = interleaving_ablation(28, 7, 8, 16);
        assert_eq!(t.rows.len(), 6);
        // Off column constant (replication); On column halves per row.
        let off: Vec<u64> = t.rows.iter().map(|r| r[3].parse().unwrap()).collect();
        assert!(off.windows(2).all(|w| w[0] == w[1]));
        let on: Vec<u64> = t.rows.iter().map(|r| r[4].parse().unwrap()).collect();
        for pair in on.windows(2) {
            assert!(pair[1] <= pair[0]);
        }
        // At the lowest rate the saving is >= 16x.
        assert!(off[5] / on[5].max(1) >= 16);
    }

    #[test]
    fn implicit_padding_always_faster() {
        let t = padding_ablation();
        for row in &t.rows {
            let explicit: f64 = row[3].parse().unwrap();
            let implicit: f64 = row[4].parse().unwrap();
            assert!(explicit > implicit, "row {row:?}");
        }
    }

    #[test]
    fn aggregation_halves_fcus() {
        let t = aggregation_ablation(256, 10);
        let fcus: Vec<u64> = t.rows.iter().map(|r| r[3].parse().unwrap()).collect();
        assert!(fcus.windows(2).all(|w| w[1] <= w[0]));
        // a=1 -> one neuron per FCU -> 10 FCUs; a=2 -> h=2 -> 5 FCUs.
        assert_eq!(fcus[0], 10);
        assert_eq!(fcus[1], 5);
        // Latency grows only by a-1 + (h-1) cycles.
        let lat: Vec<u64> = t.rows.iter().map(|r| r[6].parse().unwrap()).collect();
        assert!(lat[3] - lat[0] <= 16);
    }

    #[test]
    fn all_ablations_render() {
        for t in all_ablations() {
            assert!(!t.render().is_empty());
        }
    }
}
