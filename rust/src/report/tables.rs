//! Generators for the complexity tables: Table V (running example),
//! Table VI (conv-layer rate sweep), Table VII (depthwise-separable rate
//! sweep) and Table VIII (model comparison vs fully parallel).

use super::{dw_separable_cost, synthetic_conv_layer};
use crate::complexity::{
    layer_cost, model_cost, parallel::fully_parallel_cost, CostOpts, Resources,
};
use crate::flow::{analyze, plan_all, Ratio};
use crate::model::{zoo, Model};
use crate::util::{paper_count, Table};

/// Table V: structure and per-layer analysis of the running example.
pub fn table5() -> Table {
    let model = zoo::running_example();
    let analysis = analyze(&model, None).unwrap();
    let plans = plan_all(&analysis);
    // Table V excludes interleaving FIFO costs from the per-layer cells.
    let opts = CostOpts {
        include_bias: true,
        include_interleaving: false,
    };
    let mut t = Table::new(
        "Table V: structure and analysis of the running example",
        &[
            "Layer", "Input", "f", "k", "s", "p", "d_l", "C", "r_l", "Add.", "Mul.", "Reg.",
            "2:1 MUX", "MAX", "KPU", "FCU", "PPU",
        ],
    );
    let mut total = Resources::default();
    for pl in &plans {
        let r = layer_cost(pl, opts);
        total.add(&r);
        let l = &pl.rated.shaped.layer;
        let input = pl.rated.shaped.input;
        t.row(&[
            l.name.clone(),
            format!("({},{},{})", input.f, input.f, input.d),
            format!("{}", if l.kind == crate::model::LayerKind::Dense { 4 } else { input.f }),
            format!("{}", if l.k == 0 { 4 } else { l.k }),
            format!("{}", l.s),
            format!("{}", l.p),
            format!("{}", pl.rated.d_out()),
            format!("{}", pl.plan.configs()),
            pl.rated.r_out.paper(),
            paper_count(r.adders),
            paper_count(r.multipliers),
            paper_count(r.registers),
            paper_count(r.mux2),
            paper_count(r.max_units),
            paper_count(r.kpus),
            paper_count(r.fcus),
            paper_count(r.ppus),
        ]);
    }
    t.row(&[
        "Sum.".to_string(),
        format!("params={}", paper_count(model.param_count().unwrap())),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        paper_count(total.adders),
        paper_count(total.multipliers),
        paper_count(total.registers),
        paper_count(total.mux2),
        paper_count(total.max_units),
        paper_count(total.kpus),
        paper_count(total.fcus),
        paper_count(total.ppus),
    ]);
    t
}

/// The data-rate sweep used by Tables VI and VII.
pub fn rate_sweep() -> Vec<Ratio> {
    vec![
        Ratio::int(8),
        Ratio::int(4),
        Ratio::int(2),
        Ratio::int(1),
        Ratio::new(1, 2),
        Ratio::new(1, 4),
        Ratio::new(1, 8),
        Ratio::new(1, 16),
        Ratio::new(1, 32),
    ]
}

/// Table VI: convolutional layer (f=28, k=7, p=3, 8->16 channels) swept
/// over input data rates.
pub fn table6() -> Table {
    let mut t = Table::new(
        "Table VI: conv layer resources vs input data rate (f=28,k=7,p=3,8->16)",
        &["r_{l-1}", "Add.", "Mul.", "Reg.", "2:1 MUX", "KPUs"],
    );
    for r in rate_sweep() {
        let pl = synthetic_conv_layer(28, 7, 3, 8, 16, r);
        let cost = layer_cost(&pl, CostOpts::LAYER_ONLY);
        let stall = if pl.plan.stalled() { "*" } else { "" };
        t.row(&[
            format!("{}{stall}", r.paper()),
            cost.adders.to_string(),
            cost.multipliers.to_string(),
            format!("{}", cost.registers),
            cost.mux2.to_string(),
            cost.kpus.to_string(),
        ]);
    }
    t.footnote("*The input data rate leads to a stall.");
    t
}

/// Table VII: depthwise-separable layer (same geometry) swept over rates.
pub fn table7() -> Table {
    let mut t = Table::new(
        "Table VII: depthwise-separable conv resources vs input data rate",
        &["r_{l-1}", "Add.", "Mul.", "Reg.", "2:1 MUX", "KPUs", "FCUs"],
    );
    for r in rate_sweep().into_iter().take(6) {
        let cost = dw_separable_cost(28, 7, 3, 8, 16, r);
        let pl = super::synthetic_layer(
            crate::model::Layer::dwconv("dw", 7, 1, 3),
            28,
            8,
            r,
        );
        let stall = if pl.plan.stalled() { "*" } else { "" };
        t.row(&[
            format!("{}{stall}", r.paper()),
            cost.adders.to_string(),
            cost.multipliers.to_string(),
            cost.registers.to_string(),
            cost.mux2.to_string(),
            cost.kpus.to_string(),
            cost.fcus.to_string(),
        ]);
    }
    t.footnote("*The input data rate leads to a stall.");
    t
}

/// One model's Ref./Ours pair for Table VIII.
pub struct ModelComparison {
    pub name: String,
    pub params: u64,
    pub reference: Resources,
    pub ours: Resources,
}

/// Compare the continuous-flow implementation against the fully-parallel
/// reference for one model.
pub fn compare_model(model: &Model) -> ModelComparison {
    let analysis = analyze(model, None).unwrap();
    let ours = model_cost(&plan_all(&analysis), CostOpts::FULL).total;
    let reference = fully_parallel_cost(&analysis, CostOpts::FULL).total;
    ModelComparison {
        name: model.name.clone(),
        params: model.param_count().unwrap(),
        reference,
        ours,
    }
}

/// Table VIII: fully-parallel vs continuous-flow for the paper's models.
pub fn table8() -> Table {
    let mut t = Table::new(
        "Table VIII: fully parallel (Ref.) vs continuous flow (Ours)",
        &[
            "Model", "Param.", "Imp.", "Add.", "Mul.", "Reg.", "2:1 MUX", "KPUs", "FCUs",
        ],
    );
    let models = vec![
        zoo::running_example(),
        zoo::mobilenet_v1(25),
        zoo::mobilenet_v1(50),
        zoo::mobilenet_v1(75),
        zoo::mobilenet_v1(100),
        zoo::resnet18(),
    ];
    for m in models {
        let c = compare_model(&m);
        for (imp, r) in [("Ref.", &c.reference), ("Ours", &c.ours)] {
            t.row(&[
                if imp == "Ref." { c.name.clone() } else { String::new() },
                if imp == "Ref." {
                    paper_count(c.params)
                } else {
                    String::new()
                },
                imp.to_string(),
                paper_count(r.adders),
                paper_count(r.multipliers),
                paper_count(r.registers),
                paper_count(r.mux2),
                paper_count(r.kpus),
                paper_count(r.fcus),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_renders_all_layers() {
        let t = table5();
        assert_eq!(t.rows.len(), 6); // 5 layers + sum
        let s = t.render();
        assert!(s.contains("C1"));
        assert!(s.contains("4/9")); // P2 rate
        assert!(s.contains("Sum."));
    }

    #[test]
    fn table6_shape_matches_paper() {
        let t = table6();
        assert_eq!(t.rows.len(), 9);
        // First row fully parallel: 6272 adders, 128 KPUs.
        assert_eq!(t.rows[0][1], "6272");
        assert_eq!(t.rows[0][5], "128");
        // Last row stalls.
        assert!(t.rows[8][0].ends_with('*'));
        // Registers constant across the sweep.
        for row in &t.rows {
            assert_eq!(row[3], "22288");
        }
    }

    #[test]
    fn table7_fcus_shrink_below_rate_1() {
        let t = table7();
        assert_eq!(t.rows.len(), 6);
        assert_eq!(t.rows[0][6], "16");
        assert_eq!(t.rows[4][6], "8");
        assert_eq!(t.rows[5][6], "4");
    }

    #[test]
    fn table8_savings_order_of_magnitude() {
        // MobileNet a=1.0: Ref 4.3M mults vs Ours 12.2k (paper) — ours
        // must come out orders of magnitude below the reference.
        let c = compare_model(&zoo::mobilenet_v1(100));
        assert!(c.reference.multipliers > 4_000_000);
        assert!(c.ours.multipliers < 100_000);
        let factor = c.reference.multipliers as f64 / c.ours.multipliers as f64;
        assert!(factor > 100.0, "saving factor {factor}");
    }

    #[test]
    fn table8_registers_invariant() {
        // "the number of registers does not change when our continuous-flow
        // approach is applied" (within rounding-induced slack).
        for m in [zoo::running_example(), zoo::mobilenet_v1(100)] {
            let c = compare_model(&m);
            let ratio = c.ours.registers as f64 / c.reference.registers as f64;
            assert!(
                (0.95..=1.15).contains(&ratio),
                "{}: reg ratio {ratio}",
                c.name
            );
        }
    }
}
