//! Generators for the synthesis-results tables: Table IX (MobileNetV1
//! comparison), Table X (JSC MLP data-rate sweep) and Fig. 13 (Pareto
//! plot data).
//!
//! Comparator rows (FINN, [18], [41], PolyLUT, NeuraLUT, ...) are quoted
//! from the paper, exactly as the paper itself quotes published numbers.
//! "Ours" rows come from this crate's estimator + timing models (and the
//! cycle-accurate pipeline simulator when artifacts are available).

use crate::flow::{analyze, plan_all, Ratio};
use crate::fpga::{
    estimate::{estimate_model, EstimatorOpts},
    timing::timing_analytic,
    XCVU9P,
};
use crate::model::zoo;
use crate::quant::QModel;
use crate::util::Table;

/// Published comparator rows of Table IX (quoted from the paper).
pub const TABLE9_BASELINES: [(&str, &str, u64, u64, u64, f64, u64, &str, f64, f64, f64, &str, f64); 3] = [
    // name, fmax, LUT, FF, DSP, BRAM, URAM?, device, power, fps, latency_ms, bits, top1
    (
        "FINN [40]", "333", 501_363, 476_316, 106, 898.0, 0, "Alveo U280", 41.69, 925.0,
        45.07, "4-bit", 70.4,
    ),
    (
        "[18]", "211", 412_354, 991_909, 5_852, 1_838.5, 0, "XCVU37P", 39.465, 4_205.5,
        9.38, "8-bit", 70.1,
    ),
    (
        "[41]", "250", 402_200, 0, 6_414, 214.0, 394, "XCVU9P", 0.0, 2_637.0, 0.0,
        "8-bit", 0.0,
    ),
];

/// Table IX: MobileNetV1 implementation comparison.
pub fn table9() -> Table {
    let mut t = Table::new(
        "Table IX: MobileNetV1 implementations (baselines quoted from the paper)",
        &[
            "Impl", "Fmax MHz", "LUT", "FF", "DSP", "BRAM", "Device", "Power W", "FPS",
            "Latency ms", "mJ/inf", "Format", "Top-1",
        ],
    );
    for (name, fmax, lut, ff, dsp, bram, _uram, device, power, fps, lat, bits, top1) in
        TABLE9_BASELINES
    {
        t.row(&[
            name.to_string(),
            fmax.to_string(),
            lut.to_string(),
            ff.to_string(),
            dsp.to_string(),
            format!("{bram}"),
            device.to_string(),
            if power > 0.0 { format!("{power}") } else { "-".into() },
            format!("{fps}"),
            if lat > 0.0 { format!("{lat}") } else { "-".into() },
            if power > 0.0 && fps > 0.0 {
                format!("{:.2}", power / fps * 1e3)
            } else {
                "-".into()
            },
            bits.to_string(),
            if top1 > 0.0 { format!("{top1}%") } else { "-".into() },
        ]);
    }
    // Ours: estimator over the MobileNetV1 architecture at full rate.
    let analysis = analyze(&zoo::mobilenet_v1(100), None).unwrap();
    let plans = plan_all(&analysis);
    let est = estimate_model(&plans, EstimatorOpts::default(), None);
    let timing = timing_analytic(&analysis, 1);
    let fps = est.fmax_mhz * 1.0e6 / timing.cycles_per_frame;
    let latency_ms = timing.latency_cycles / (est.fmax_mhz * 1.0e6) * 1e3;
    t.row(&[
        "Ours (estimated)".to_string(),
        format!("{:.0}", est.fmax_mhz),
        est.lut.to_string(),
        est.ff.to_string(),
        est.dsp.to_string(),
        format!("{:.1}", est.bram36),
        "XCVU37P (model)".to_string(),
        format!("{:.1}", est.power_w),
        format!("{fps:.0}"),
        format!("{latency_ms:.2}"),
        format!("{:.2}", est.power_w / fps * 1e3),
        "8-bit".to_string(),
        "70.5% (paper)".to_string(),
    ]);
    t.footnote("Baseline rows are the paper's published values; 'Ours' is this crate's");
    t.footnote("synthesis estimator + analytic timing (see docs/PAPER_MAP.md).");
    t
}

/// Published fully-parallel comparator rows of Table X / Fig. 13.
pub const TABLE10_BASELINES: [(&str, f64, u64, u64, u64, u64, f64, f64); 6] = [
    // name, acc%, r0, fmax, LUT, FF(unused in plot), speed MInf/s, latency ns
    ("PolyLUT (JSC-XL) [22]", 75.0, 16, 235, 236_541, 2_775, 235.0, 21.0),
    ("NeuraLUT (JSC-5L) [43]", 75.0, 16, 368, 92_357, 4_885, 368.0, 14.0),
    ("NeuraLUT-Assemble [44]", 76.0, 16, 941, 1_780, 540, 941.0, 2.1),
    ("TreeLUT [45]", 75.6, 16, 735, 2_234, 347, 735.0, 2.7),
    ("DWN [46]", 76.3, 16, 695, 6_302, 4_128, 695.0, 14.4),
    ("hls4ml [47]", 76.2, 16, 200, 63_251, 4_394, 200.0, 45.0),
];

/// The r0 sweep of Table X.
pub fn table10_rates() -> Vec<Ratio> {
    vec![
        Ratio::int(16),
        Ratio::int(8),
        Ratio::int(4),
        Ratio::int(2),
        Ratio::int(1),
        Ratio::new(1, 2),
        Ratio::new(1, 4),
        Ratio::new(1, 8),
        Ratio::new(1, 16),
    ]
}

/// One "Proposed" design point of Table X.
#[derive(Debug, Clone)]
pub struct JscPoint {
    pub r0: Ratio,
    pub use_dsp: bool,
    pub fmax_mhz: f64,
    pub lut: u64,
    pub ff: u64,
    pub bram36: f64,
    pub dsp: u64,
    pub speed_minf_s: f64,
    pub latency_ns: f64,
}

/// Compute the proposed design points. `qmodel` (the trained JSC artifact)
/// refines the DSP count via measured trivial-weight lanes and replaces
/// analytic timing with simulated cycles.
pub fn jsc_sweep(qmodel: Option<&QModel>) -> Vec<JscPoint> {
    let mut points = Vec::new();
    for use_dsp in [true, false] {
        for r0 in table10_rates() {
            let analysis = analyze(&zoo::jsc_mlp(), Some(r0)).unwrap();
            let plans = plan_all(&analysis);
            let est = estimate_model(
                &plans,
                EstimatorOpts {
                    use_dsp,
                    trivial_frac: None,
                },
                qmodel,
            );
            let fmax = est.fmax_mhz.min(XCVU9P.fmax_cap_mhz);
            // Timing: prefer the cycle-accurate pipeline when weights exist.
            let (cycles_per_frame, latency_cycles) = match qmodel {
                Some(qm) => {
                    let sim =
                        crate::sim::pipeline::PipelineSim::new(qm.clone(), Some(r0)).unwrap();
                    let frames: Vec<Vec<i64>> = qm
                        .test_vectors
                        .iter()
                        .cycle()
                        .take(12)
                        .map(|tv| tv.x_q.clone())
                        .collect();
                    match sim.run(&frames) {
                        Ok(res) => (
                            res.cycles_per_frame,
                            res.first_frame_latency as f64,
                        ),
                        Err(_) => {
                            let t = timing_analytic(&analysis, 0);
                            (t.cycles_per_frame, t.latency_cycles)
                        }
                    }
                }
                None => {
                    let t = timing_analytic(&analysis, 0);
                    (t.cycles_per_frame, t.latency_cycles)
                }
            };
            points.push(JscPoint {
                r0,
                use_dsp,
                fmax_mhz: fmax,
                lut: est.lut,
                ff: est.ff,
                bram36: est.bram36,
                dsp: est.dsp,
                speed_minf_s: fmax / cycles_per_frame,
                latency_ns: latency_cycles / fmax * 1e3,
            });
        }
    }
    points
}

/// Table X: JSC MLP synthesis sweep.
pub fn table10(qmodel: Option<&QModel>) -> Table {
    let mut t = Table::new(
        "Table X: JSC 16-16-5 MLP vs data rate (baselines quoted from the paper)",
        &[
            "Impl", "Acc", "r0", "Fmax MHz", "LUT", "FF", "BRAM", "DSP", "Speed MInf/s",
            "Latency ns",
        ],
    );
    for (name, acc, r0, fmax, lut, ff, speed, lat) in TABLE10_BASELINES {
        t.row(&[
            name.to_string(),
            format!("{acc}%"),
            r0.to_string(),
            fmax.to_string(),
            lut.to_string(),
            ff.to_string(),
            "0".to_string(),
            if name.contains("hls4ml") { "38" } else { "0" }.to_string(),
            format!("{speed}"),
            format!("{lat}"),
        ]);
    }
    let acc = qmodel
        .map(|q| format!("{:.1}%", q.qat_accuracy * 100.0))
        .unwrap_or_else(|| "75.2% (paper)".to_string());
    for p in jsc_sweep(qmodel) {
        t.row(&[
            format!(
                "Proposed ({})",
                if p.use_dsp { "DSP" } else { "no DSP" }
            ),
            acc.clone(),
            p.r0.paper(),
            format!("{:.0}", p.fmax_mhz),
            p.lut.to_string(),
            p.ff.to_string(),
            format!("{:.1}", p.bram36),
            p.dsp.to_string(),
            format!("{:.1}", p.speed_minf_s),
            format!("{:.1}", p.latency_ns),
        ]);
    }
    t.footnote("'Proposed' rows: this crate's estimator; timing from the cycle-accurate");
    t.footnote("pipeline simulator when artifacts are present, else analytic.");
    t
}

/// Fig. 13: throughput (MInf/s) vs LUT Pareto data, as CSV-ready rows.
/// Contains the paper's published points plus our sweep, and marks the
/// points on the Pareto frontier (max speed for <= LUT).
pub fn fig13(qmodel: Option<&QModel>) -> Table {
    let mut rows: Vec<(String, u64, f64)> = Vec::new();
    for (name, acc, _r0, _fmax, lut, _ff, speed, _lat) in TABLE10_BASELINES {
        if acc >= 75.0 {
            rows.push((name.to_string(), lut, speed));
        }
    }
    for p in jsc_sweep(qmodel) {
        rows.push((
            format!(
                "Proposed ({}) r0={}",
                if p.use_dsp { "DSP" } else { "no-DSP" },
                p.r0.paper()
            ),
            p.lut,
            p.speed_minf_s,
        ));
    }
    // Pareto frontier: sort by LUT, track running max speed.
    let mut sorted: Vec<usize> = (0..rows.len()).collect();
    sorted.sort_by_key(|&i| rows[i].1);
    let mut frontier = vec![false; rows.len()];
    let mut best = f64::NEG_INFINITY;
    // A point is on the frontier if no point with <= LUT has >= speed.
    for &i in &sorted {
        if rows[i].2 > best {
            best = rows[i].2;
            frontier[i] = true;
        }
    }
    let mut t = Table::new(
        "Fig. 13 data: throughput vs LUT utilisation (Pareto plot)",
        &["Design", "LUT", "MInf/s", "Pareto"],
    );
    for (i, (name, lut, speed)) in rows.iter().enumerate() {
        t.row(&[
            name.clone(),
            lut.to_string(),
            format!("{speed:.1}"),
            if frontier[i] { "*".into() } else { String::new() },
        ]);
    }
    t.footnote("* = on the Pareto frontier (no design with fewer LUTs is faster).");
    t
}

/// Load the JSC artifact if present.
pub fn load_jsc_artifact() -> Option<QModel> {
    let path = crate::runtime::artifacts_dir().join("weights/jsc.json");
    QModel::load(&path).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table9_has_ours_row() {
        let t = table9();
        assert_eq!(t.rows.len(), 4);
        let s = t.render();
        assert!(s.contains("Ours"));
        assert!(s.contains("FINN"));
    }

    #[test]
    fn table10_has_18_proposed_rows() {
        let t = table10(None);
        // 6 baselines + 9 DSP + 9 no-DSP.
        assert_eq!(t.rows.len(), 24);
    }

    #[test]
    fn jsc_speed_halves_with_rate() {
        let pts = jsc_sweep(None);
        let dsp: Vec<&JscPoint> = pts.iter().filter(|p| p.use_dsp).collect();
        for pair in dsp.windows(2) {
            // Speed must drop (roughly halve) as the rate halves.
            assert!(
                pair[1].speed_minf_s < pair[0].speed_minf_s,
                "speed not monotone at r0={}",
                pair[1].r0
            );
        }
        // Full rate: ~1 inference/cycle at ~600-690 MHz.
        assert!(dsp[0].speed_minf_s > 400.0, "{}", dsp[0].speed_minf_s);
        // Lowest rate: 256 cycles/inference.
        let slowest = dsp.last().unwrap();
        assert!(
            (1.0..5.0).contains(&slowest.speed_minf_s),
            "{}",
            slowest.speed_minf_s
        );
    }

    #[test]
    fn fig13_pareto_extends_to_low_lut() {
        // The paper's claim: our approach extends the Pareto frontier at
        // lower throughput/LUT targets. The lowest-LUT frontier point must
        // be one of ours.
        let t = fig13(None);
        let first_frontier = t
            .rows
            .iter()
            .filter(|r| r[3] == "*")
            .min_by_key(|r| r[1].parse::<u64>().unwrap())
            .expect("frontier nonempty");
        assert!(
            first_frontier[0].contains("Proposed"),
            "lowest-LUT frontier point is {first_frontier:?}"
        );
    }

    #[test]
    fn fig13_with_artifact_if_present() {
        if let Some(qm) = load_jsc_artifact() {
            let t = fig13(Some(&qm));
            assert!(t.rows.len() >= 20);
        }
    }
}
