//! Paper-table and figure generators (system S11).
//!
//! Every table and figure of the paper's evaluation has a generator here
//! that prints the same rows the paper reports, from this crate's own
//! models — see docs/PAPER_MAP.md for the artifact → module → test
//! index. The CLI exposes
//! them as `cnn-flow table <n>` / `cnn-flow fig 13`.

pub mod ablation;
pub mod synthesis;
pub mod tables;
pub mod timing;

use crate::complexity::{layer_cost, CostOpts, Resources};
use crate::flow::{plan_layer, PlannedLayer, RatedLayer, Ratio};
use crate::model::{Layer, LayerKind, Shape, ShapedLayer};

/// Build a standalone rated+planned convolutional layer, for the layer-in-
/// isolation sweeps of Tables VI and VII.
pub fn synthetic_conv_layer(
    f: usize,
    k: usize,
    p: usize,
    d_in: usize,
    d_out: usize,
    r_in: Ratio,
) -> PlannedLayer {
    synthetic_layer(Layer::conv("conv", k, 1, p, d_out), f, d_in, r_in)
}

/// Build a standalone rated+planned layer of any kind.
pub fn synthetic_layer(layer: Layer, f: usize, d_in: usize, r_in: Ratio) -> PlannedLayer {
    let mut layer = layer;
    if layer.filters == 0 {
        layer.filters = d_in;
    }
    let input = Shape { f, d: d_in };
    let output = crate::model::layer_output_shape(&layer, input).expect("valid synthetic layer");
    let d_in_eff = match layer.kind {
        LayerKind::Dense => input.features(),
        _ => input.d,
    };
    let r_out = crate::flow::layer_rate(d_in_eff, output.d, layer.s, r_in);
    plan_layer(&RatedLayer {
        shaped: ShapedLayer {
            layer,
            input,
            output,
            merges: false,
        },
        r_in,
        r_out,
    })
}

/// Cost of a depthwise-separable convolution (depthwise conv + pointwise
/// conv) in isolation, as swept by Table VII. Bias and interleaving are
/// excluded, matching the table's accounting.
pub fn dw_separable_cost(
    f: usize,
    k: usize,
    p: usize,
    d_in: usize,
    d_out: usize,
    r_in: Ratio,
) -> Resources {
    let dw = synthetic_layer(Layer::dwconv("dw", k, 1, p), f, d_in, r_in);
    let dw_cost = layer_cost(&dw, CostOpts::LAYER_ONLY);
    let pw = synthetic_layer(Layer::pwconv("pw", d_out), f, d_in, dw.rated.r_out);
    let pw_cost = layer_cost(&pw, CostOpts::LAYER_ONLY);
    let mut total = dw_cost;
    total.add(&pw_cost);
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_conv_shapes() {
        let pl = synthetic_conv_layer(28, 7, 3, 8, 16, Ratio::int(8));
        assert_eq!(pl.rated.shaped.output.f, 28);
        assert_eq!(pl.rated.d_out(), 16);
        assert_eq!(pl.plan.unit_count(), 128);
    }

    #[test]
    fn synthetic_dense_layer() {
        let pl = synthetic_layer(Layer::dense("d", 5), 1, 16, Ratio::int(16));
        assert_eq!(pl.rated.d_in(), 16);
        assert_eq!(pl.rated.d_out(), 5);
    }
}
