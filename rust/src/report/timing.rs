//! Generators for the timing tables (Tables I-IV) — thin wrappers over
//! [`crate::sim::trace`], which both emits and oracle-verifies the traces.

use crate::sim::trace::{render_kpu_trace, trace_fcu, trace_kpu, verify_kpu_trace, KpuTraceCfg};
use crate::util::Table;

/// Table I: KPU timing for a 5x5 feature map with a 3x3 kernel, no padding.
pub fn table1() -> Table {
    let trace = trace_kpu(KpuTraceCfg {
        f: 5,
        k: 3,
        p: 0,
        s: 1,
        cycles: 25,
    });
    verify_kpu_trace(&trace).expect("table I trace failed oracle check");
    render_kpu_trace(
        &trace,
        "Table I: KPU timing, 5x5 feature map, 3x3 kernel (no padding)",
    )
}

/// Table II: KPU timing with implicit zero padding p=1.
pub fn table2() -> Table {
    let trace = trace_kpu(KpuTraceCfg {
        f: 5,
        k: 3,
        p: 1,
        s: 1,
        cycles: 37,
    });
    verify_kpu_trace(&trace).expect("table II trace failed oracle check");
    render_kpu_trace(
        &trace,
        "Table II: KPU timing with implicit zero padding p=1 (5x5 map, 3x3 kernel)",
    )
}

/// Table III: FCU timing with h=5 neurons, j=4 inputs, 8 input features.
pub fn table3() -> Table {
    let (t, _) = trace_fcu(8, 4, 5, "Table III: FCU timing, h=5, j=4, 8 inputs");
    t
}

/// Table IV: FCU timing with aggregation (h=4, j=4, d_in=8).
pub fn table4() -> Table {
    let (t, _) = trace_fcu(
        8,
        4,
        4,
        "Table IV: FCU timing with aggregation a=4 (h=4, j=4, 8 inputs)",
    );
    t
}

#[cfg(test)]
mod tests {
    #[test]
    fn all_timing_tables_render() {
        for t in [
            super::table1(),
            super::table2(),
            super::table3(),
            super::table4(),
        ] {
            assert!(!t.rows.is_empty());
        }
    }
}
