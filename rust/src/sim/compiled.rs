//! Compile-once lowered execution engine — the *values* half of the
//! values/cycles split (DESIGN.md §4).
//!
//! [`CompiledPipeline::lower`] turns a quantized model into a flat,
//! branch-free program executed per frame by [`CompiledPipeline::execute`]:
//!
//! * **window index tables** — every conv/dwconv/pool output pixel gets a
//!   precomputed list of `(weight base, input base)` taps with padding
//!   already resolved (out-of-map taps simply don't exist), so the hot
//!   loop never does per-pixel bounds arithmetic;
//! * **contiguous weights** — conv weights stay in the exporter's
//!   `[tap][c_in][c_out]` layout (the inner axpy walks one cache line);
//!   dense weights are transposed to `[feature][unit]` so the per-feature
//!   axpy is contiguous instead of strided per-MAC accessor calls;
//! * **fused requant constants** — ReLU + requantization decisions
//!   (including the final layer's accumulator-scale passthrough) are baked
//!   into each layer at lowering time;
//! * **preallocated ping-pong buffers** — `execute` allocates nothing;
//!   activations bounce between two reusable buffers;
//! * **narrow arithmetic when provably safe** — lowering computes exact
//!   worst-case accumulator bounds (weights × int8 activation range); when
//!   every bound fits `i32` the whole pipeline runs in 32-bit lanes
//!   (twice the SIMD width of the interpreter's `i64` loop), otherwise it
//!   falls back to a bit-identical 64-bit program.
//!
//! The contract, enforced by `tests/prop_compiled.rs`: `execute` is
//! **bit-identical** to the interpreter (`PipelineSim::run_interpreted`)
//! for int8-range frames. The engine computes values only; cycle figures
//! come from `flow::schedule` — together they replace the fused
//! interpreter on the serving hot path.
//!
//! # The batched tier (DESIGN.md §6)
//!
//! [`CompiledPipeline::execute_batch`] runs B frames through the same
//! lowered program with the batch as the **innermost loop of every
//! instruction**: one program traversal per batch instead of one per
//! frame. Activations live in lane-interleaved ping-pong buffers
//! (`buffer[position * lane_stride + lane]`), and every kernel walks its
//! tap table once per output position while a fixed-size accumulator tile
//! covers `LANES` lanes — full tiles get compile-time loop bounds (so
//! the lane loop unrolls and vectorises, with each weight scalar
//! broadcast across the whole tile), the tail tile runs the same code
//! with a runtime bound. Per frame the result is bit-identical to
//! [`CompiledPipeline::execute`]: integer accumulation commutes exactly,
//! so reordering lanes never changes a value.
//!
//! # The folded tier (DESIGN.md §9)
//!
//! [`FoldedPipeline`] is the rate-aware lowering: it reads each layer's
//! Eq.-8 fold factor (how many source pixel periods pass between the
//! layer's output pixels) and *folds* low-rate layers the way the paper's
//! hardware time-multiplexes them —
//!
//! * **fusion** — a low-rate window layer feeding a low-rate 1x1 conv
//!   (MobileNet's dw→pw pairs after a stride) or a dense head runs in
//!   *one* traversal: each produced pixel is consumed straight out of
//!   registers, never written to the intermediate map;
//! * **register-blocked micro-kernels** — low-rate layers that stay
//!   unfused run a branch-free, fixed-width (`CHUNK`) channel-blocked
//!   kernel whose inner tap loop autovectorises, instead of the
//!   zero-skip kernel that favours sparse full-rate maps;
//! * **kernel-selection table** — the per-layer choice is recorded and
//!   exposed ([`FoldedPipeline::kernel_table`]) so tests, docs and the
//!   CLI can see exactly how each layer was folded.
//!
//! Values stay bit-identical to [`CompiledPipeline`] and the interpreter
//! (integer accumulation is order-independent, and folding only changes
//! *where* partial sums live); cycle figures for the folded engine come
//! from `flow::schedule`'s `FoldedPrediction`, certified against the
//! exact replay.

use std::sync::Arc;
use std::time::Instant;

use crate::obs::LayerProfiler;
use crate::quant::{requant, QKind, QModel, QMAX};

/// Lanes per batch tile: accumulator tiles are `[T; LANES]` locals so
/// full tiles stay in registers across a whole tap walk.
const LANES: usize = 8;

/// Accumulator cell: the two arithmetic widths a lowered program can run
/// in. Narrow (`i32`) programs are only built when the lowering-time bound
/// analysis proves no accumulator can overflow for int8-range inputs.
pub trait Cell:
    Copy
    + PartialEq
    + PartialOrd
    + std::ops::AddAssign
    + std::ops::Mul<Output = Self>
    + std::fmt::Debug
    + Send
    + Sync
    + 'static
{
    const ZERO: Self;
    /// Identity for max-pooling (the interpreter's `i64::MIN` seed; a
    /// pool window is never empty in a narrow-eligible model).
    const FLOOR: Self;
    /// Narrow engines must validate frames to the int8 grid the bound
    /// analysis assumed.
    const CHECK_INT8: bool;
    fn from_i64(v: i64) -> Self;
    fn to_i64(self) -> i64;
}

impl Cell for i32 {
    const ZERO: i32 = 0;
    const FLOOR: i32 = i32::MIN;
    const CHECK_INT8: bool = true;
    #[inline(always)]
    fn from_i64(v: i64) -> i32 {
        v as i32
    }
    #[inline(always)]
    fn to_i64(self) -> i64 {
        self as i64
    }
}

impl Cell for i64 {
    const ZERO: i64 = 0;
    const FLOOR: i64 = i64::MIN;
    const CHECK_INT8: bool = false;
    #[inline(always)]
    fn from_i64(v: i64) -> i64 {
        v
    }
    #[inline(always)]
    fn to_i64(self) -> i64 {
        self
    }
}

/// One precomputed window tap: base offsets into the weight and input
/// buffers (all shapes here are far below `u32::MAX`).
#[derive(Debug, Clone, Copy)]
struct Tap {
    w: u32,
    x: u32,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum COp {
    Conv,
    /// Depthwise conv; also average pooling (a depthwise conv with
    /// constant weights, per Section VI).
    Depthwise,
    MaxPool,
    Dense,
}

/// Residual merge epilogue lowered onto the node at the merge point: the
/// shortcut branch's stream (parked in `other_buf` — the software form of
/// the paper's delay-balancing skip FIFO) is added elementwise to this
/// layer's output, optionally ReLU'd, and requantized by `m`.
#[derive(Debug, Clone, Copy)]
struct CMerge {
    /// The shortcut node merged in (`None` = the program input).
    with: Option<usize>,
    /// Scratch-pool buffer holding the shortcut branch's output.
    other_buf: usize,
    /// `Some(m)` = requantize the merged sum; `None` = raw sum (m == 0).
    m: Option<f32>,
    relu: bool,
}

#[derive(Debug, Clone)]
struct CLayer<T> {
    name: String,
    op: COp,
    c_in: usize,
    c_out: usize,
    in_len: usize,
    out_len: usize,
    /// Per-output-pixel ranges into `taps` (window ops only).
    tap_start: Vec<u32>,
    taps: Vec<Tap>,
    weights: Vec<T>,
    bias: Vec<T>,
    relu: bool,
    /// `Some(m)` = requantize to int8 after ReLU; `None` = emit
    /// accumulator-scale values (the final layer, or m == 0).
    m: Option<f32>,
    /// Which node's output this layer consumes (`None` = program input).
    src: Option<usize>,
    /// Scratch-pool buffer the source value lives in.
    in_buf: usize,
    /// Scratch-pool buffer this layer's output lands in.
    out_buf: usize,
    /// Residual merge epilogue, if this node is a merge point.
    merge: Option<CMerge>,
}

#[derive(Debug, Clone)]
struct Program<T> {
    layers: Vec<CLayer<T>>,
    in_len: usize,
    out_len: usize,
    buf_len: usize,
    /// Scratch buffers the liveness allocator assigned (2 for chains —
    /// the classic ping-pong; +1 per concurrently-live shortcut).
    pool: usize,
    /// Buffer the input frame is written to before layer 0 runs.
    in_buf: usize,
    /// Buffer holding the final layer's output after a traversal.
    out_buf: usize,
}

/// A lowered program plus its reusable execution scratch. `Clone + Send`
/// by construction: serving shards clone the compiled state instead of
/// re-planning or re-lowering. The immutable program sits behind an
/// `Arc`, so a clone shares weights/tap tables and copies only the
/// per-executor scratch buffers.
#[derive(Debug, Clone)]
struct Engine<T> {
    prog: Arc<Program<T>>,
    /// Scratch pool (`prog.pool` buffers of `prog.buf_len`): chains use
    /// it as the classic ping-pong pair; residual graphs park each live
    /// shortcut stream in its own buffer (the software skip FIFO).
    bufs: Vec<Vec<T>>,
    acc: Vec<T>,
    out: Vec<i64>,
    /// Lane-interleaved scratch pool for the batched tier; grown on
    /// first use, then reused across batches.
    bbufs: Vec<Vec<T>>,
    /// Optional per-layer wall-time accumulators (DESIGN.md §13).
    /// Timing-only: attaching a profiler never changes a value.
    profiler: Option<Arc<LayerProfiler>>,
}

#[derive(Debug, Clone)]
enum Inner {
    Narrow(Engine<i32>),
    Wide(Engine<i64>),
}

/// The compile-once value engine. See the module docs for the lowering.
#[derive(Debug, Clone)]
pub struct CompiledPipeline {
    inner: Inner,
}

impl CompiledPipeline {
    /// Lower a quantized model. Fails on inconsistent layer shape chains
    /// or weight layouts (conditions under which the interpreter would
    /// panic or read out of bounds rather than answer).
    pub fn lower(qm: &QModel) -> Result<CompiledPipeline, String> {
        let inner = if narrow_safe(qm)? {
            Inner::Narrow(Engine::build(qm)?)
        } else {
            Inner::Wide(Engine::build(qm)?)
        };
        Ok(CompiledPipeline { inner })
    }

    /// Run one frame (flat HWC int8-valued input) through the lowered
    /// program; returns the final layer's outputs at accumulator scale,
    /// bit-identical to the interpreter. The slice borrows internal
    /// scratch — copy it out before the next `execute`.
    pub fn execute(&mut self, frame: &[i64]) -> Result<&[i64], String> {
        match &mut self.inner {
            Inner::Narrow(e) => e.execute(frame),
            Inner::Wide(e) => e.execute(frame),
        }
    }

    /// Run a batch of frames with one program traversal (the batch is the
    /// innermost loop of every instruction — see the module docs and
    /// DESIGN.md §6). Returns one output vector per frame, each
    /// **bit-identical** to what [`CompiledPipeline::execute`] returns
    /// for that frame alone. All-or-nothing: any malformed frame fails
    /// the whole batch (pre-screen with
    /// [`CompiledPipeline::validate_frame`] to isolate bad requests).
    pub fn execute_batch(&mut self, frames: &[&[i64]]) -> Result<Vec<Vec<i64>>, String> {
        match &mut self.inner {
            Inner::Narrow(e) => e.execute_batch(frames),
            Inner::Wide(e) => e.execute_batch(frames),
        }
    }

    /// Like [`CompiledPipeline::execute_batch`] but without the per-frame
    /// input screening: for callers that have already screened every
    /// frame with [`CompiledPipeline::validate_frame`] (the coordinator's
    /// serving hot path), so each frame is scanned exactly once.
    /// Crate-internal because an unscreened malformed frame can corrupt
    /// the lane scratch or panic instead of returning `Err`.
    pub(crate) fn execute_batch_prevalidated(
        &mut self,
        frames: &[&[i64]],
    ) -> Result<Vec<Vec<i64>>, String> {
        match &mut self.inner {
            Inner::Narrow(e) => e.execute_batch_prevalidated(frames),
            Inner::Wide(e) => e.execute_batch_prevalidated(frames),
        }
    }

    /// Check one frame against the lowered program's input contract:
    /// exact length, and the int8 grid when the narrow lowering's bound
    /// analysis assumed it. Exactly the screening `execute` performs, so
    /// callers batching many requests can reject malformed ones
    /// individually before a group [`CompiledPipeline::execute_batch`].
    pub fn validate_frame(&self, frame: &[i64]) -> Result<(), String> {
        match &self.inner {
            Inner::Narrow(e) => validate(&e.prog, frame),
            Inner::Wide(e) => validate(&e.prog, frame),
        }
    }

    /// Whether the bound analysis proved 32-bit lanes safe.
    pub fn is_narrow(&self) -> bool {
        matches!(self.inner, Inner::Narrow(_))
    }

    pub fn input_len(&self) -> usize {
        match &self.inner {
            Inner::Narrow(e) => e.prog.in_len,
            Inner::Wide(e) => e.prog.in_len,
        }
    }

    pub fn output_len(&self) -> usize {
        match &self.inner {
            Inner::Narrow(e) => e.prog.out_len,
            Inner::Wide(e) => e.prog.out_len,
        }
    }

    /// Attach (or detach with `None`) a per-layer profiler. Timing-only:
    /// execute paths record wall nanos per layer into it and nothing
    /// else, so profiled outputs stay bit-identical (DESIGN.md §13).
    pub fn set_profiler(&mut self, profiler: Option<Arc<LayerProfiler>>) {
        match &mut self.inner {
            Inner::Narrow(e) => e.profiler = profiler,
            Inner::Wide(e) => e.profiler = profiler,
        }
    }
}

/// Exact worst-case bound analysis: propagate the maximum possible
/// activation magnitude node by node through the dataflow graph
/// (requantized nodes reset it to the int8 grid; residual merges add the
/// two branch bounds) and check every accumulator fits `i32`. Saturating
/// `i128` arithmetic, so pathological non-requantized chains simply land
/// on the wide path. Also forces the wide path when a max-pool window can
/// be empty (the interpreter's `i64::MIN` seed would then be observable).
fn narrow_safe(qm: &QModel) -> Result<bool, String> {
    const NARROW_LIMIT: i128 = i32::MAX as i128;
    let topo = qm.node_topology();
    let mut bounds: Vec<i128> = Vec::with_capacity(qm.layers.len());
    let mut narrow = true;
    let n = qm.layers.len();
    for (idx, ql) in qm.layers.iter().enumerate() {
        let last = idx + 1 == n;
        if ql.kind != QKind::MaxPool && ql.out_shape[2] == 0 {
            return Err(format!("compile: {}: zero output channels", ql.name));
        }
        if ql.kind == QKind::MaxPool {
            // A pool window falling entirely off the map would surface the
            // interpreter's i64::MIN seed: only the wide program matches.
            let [h_in, w_in, _] = ql.in_shape;
            let [h_out, w_out, _] = ql.out_shape;
            if h_out > 0
                && w_out > 0
                && ((h_out - 1) * ql.s >= h_in || (w_out - 1) * ql.s >= w_in)
            {
                narrow = false;
            }
        }
        let in_bound = match topo.get(idx).and_then(|t| t.src) {
            Some(j) if j < idx => bounds[j],
            _ => QMAX as i128,
        };
        let acc_bound = ql.acc_bound(in_bound);
        if acc_bound > NARROW_LIMIT {
            narrow = false;
        }
        let mut out_bound = if ql.fused_requant(last).is_some() {
            QMAX as i128
        } else {
            acc_bound
        };
        if let Some(mg) = topo.get(idx).and_then(|t| t.merge) {
            let other = match mg.with {
                Some(j) if j < idx => bounds[j],
                _ => QMAX as i128,
            };
            let merged = out_bound.saturating_add(other);
            if merged > NARROW_LIMIT {
                narrow = false;
            }
            out_bound = if mg.m != 0.0 { QMAX as i128 } else { merged };
        }
        bounds.push(out_bound);
    }
    Ok(narrow)
}

/// The input screening shared by `execute`, `execute_batch` and
/// `CompiledPipeline::validate_frame`: exact frame length, plus the int8
/// grid whenever the narrow bound analysis assumed it.
fn validate<T: Cell>(prog: &Program<T>, frame: &[i64]) -> Result<(), String> {
    if frame.len() != prog.in_len {
        return Err(format!(
            "compiled execute: frame len {} != {}",
            frame.len(),
            prog.in_len
        ));
    }
    if T::CHECK_INT8 {
        if let Some(bad) = frame.iter().find(|v| v.unsigned_abs() > QMAX as u64) {
            return Err(format!(
                "compiled execute: frame value {bad} outside the int8 grid \
                 the narrow lowering is proven for"
            ));
        }
    }
    Ok(())
}

impl<T: Cell> Engine<T> {
    fn build(qm: &QModel) -> Result<Engine<T>, String> {
        let prog = lower_program::<T>(qm)?;
        Ok(Engine {
            bufs: vec![vec![T::ZERO; prog.buf_len]; prog.pool],
            acc: Vec::new(),
            out: Vec::new(),
            bbufs: Vec::new(),
            profiler: None,
            prog: Arc::new(prog),
        })
    }

    fn execute(&mut self, frame: &[i64]) -> Result<&[i64], String> {
        validate(&self.prog, frame)?;
        self.execute_unchecked(frame)
    }

    /// The scalar path minus the input screening — callers must have run
    /// `validate` on `frame` already.
    fn execute_unchecked(&mut self, frame: &[i64]) -> Result<&[i64], String> {
        let Engine {
            prog,
            bufs,
            acc,
            out,
            profiler,
            ..
        } = self;
        for (slot, &v) in bufs[prog.in_buf].iter_mut().zip(frame) {
            *slot = T::from_i64(v);
        }
        for (li, layer) in prog.layers.iter().enumerate() {
            let t0 = profiler.as_ref().map(|_| Instant::now());
            // The allocator guarantees out_buf aliases neither the source
            // nor the shortcut buffer, so taking it out never hides data
            // the layer still reads.
            let mut dst = std::mem::take(&mut bufs[layer.out_buf]);
            run_layer(
                layer,
                &bufs[layer.in_buf][..layer.in_len],
                &mut dst[..layer.out_len],
                acc,
            );
            if let Some(mg) = &layer.merge {
                apply_merge(
                    mg,
                    &bufs[mg.other_buf][..layer.out_len],
                    &mut dst[..layer.out_len],
                );
            }
            bufs[layer.out_buf] = dst;
            if let (Some(p), Some(t0)) = (profiler.as_deref(), t0) {
                p.record(li, t0.elapsed().as_nanos() as u64);
            }
        }
        let res: &[T] = &bufs[prog.out_buf][..prog.out_len];
        out.clear();
        out.extend(res.iter().map(|v| v.to_i64()));
        Ok(out.as_slice())
    }

    fn execute_batch(&mut self, frames: &[&[i64]]) -> Result<Vec<Vec<i64>>, String> {
        for (i, f) in frames.iter().enumerate() {
            validate(&self.prog, f).map_err(|e| format!("batch frame {i}: {e}"))?;
        }
        self.execute_batch_prevalidated(frames)
    }

    /// The batched path minus the per-frame screening — callers must have
    /// run `validate` on every frame already (the coordinator's hot path
    /// screens per request via `validate_frame`, so re-validating here
    /// would scan every frame twice).
    fn execute_batch_prevalidated(&mut self, frames: &[&[i64]]) -> Result<Vec<Vec<i64>>, String> {
        if frames.is_empty() {
            return Ok(Vec::new());
        }
        if frames.len() == 1 {
            // Lane tiling buys nothing at B = 1: reuse the scalar path.
            let out = self.execute_unchecked(frames[0])?;
            return Ok(vec![out.to_vec()]);
        }
        let b = frames.len();
        // Lane stride rounded up to LANES so every tile can slice a full
        // chunk; pad lanes are never read (tiles loop to their length).
        let bp = b.div_ceil(LANES) * LANES;
        let Engine {
            prog,
            bbufs,
            profiler,
            ..
        } = self;
        bbufs.resize(prog.pool, Vec::new());
        for bbuf in bbufs.iter_mut() {
            bbuf.resize(prog.buf_len * bp, T::ZERO);
        }
        // Transpose in: position-major, lane-minor interleave.
        for (lane, f) in frames.iter().enumerate() {
            for (pos, &v) in f.iter().enumerate() {
                bbufs[prog.in_buf][pos * bp + lane] = T::from_i64(v);
            }
        }
        for (li, layer) in prog.layers.iter().enumerate() {
            let t0 = profiler.as_ref().map(|_| Instant::now());
            let mut dst = std::mem::take(&mut bbufs[layer.out_buf]);
            run_layer_batch(
                layer,
                &bbufs[layer.in_buf][..layer.in_len * bp],
                &mut dst[..layer.out_len * bp],
                b,
                bp,
            );
            if let Some(mg) = &layer.merge {
                apply_merge_batch(
                    mg,
                    &bbufs[mg.other_buf],
                    &mut dst,
                    layer.out_len,
                    b,
                    bp,
                );
            }
            bbufs[layer.out_buf] = dst;
            if let (Some(p), Some(t0)) = (profiler.as_deref(), t0) {
                p.record(li, t0.elapsed().as_nanos() as u64);
            }
        }
        let res: &[T] = &bbufs[prog.out_buf][..prog.out_len * bp];
        let mut outs = vec![Vec::with_capacity(prog.out_len); b];
        for pos in 0..prog.out_len {
            let lanes = &res[pos * bp..pos * bp + b];
            for (out, &v) in outs.iter_mut().zip(lanes) {
                out.push(v.to_i64());
            }
        }
        Ok(outs)
    }
}

/// ReLU + requant epilogue, fused per layer at lowering time.
#[inline]
fn finalize<T: Cell>(layer: &CLayer<T>, acc: &[T], dst: &mut [T]) {
    match layer.m {
        Some(m) => {
            for (d, &a) in dst.iter_mut().zip(acc) {
                let v = if layer.relu && a < T::ZERO { T::ZERO } else { a };
                *d = T::from_i64(requant(v.to_i64(), m));
            }
        }
        None => {
            for (d, &a) in dst.iter_mut().zip(acc) {
                *d = if layer.relu && a < T::ZERO { T::ZERO } else { a };
            }
        }
    }
}

/// Residual merge epilogue, scalar path: elementwise sum of the layer's
/// finished output and the shortcut stream, then the optional ReLU and
/// requantization — the exact interpreter order (sum → ReLU → requant).
fn apply_merge<T: Cell>(mg: &CMerge, other: &[T], dst: &mut [T]) {
    for (d, &o) in dst.iter_mut().zip(other) {
        let mut s = *d;
        s += o;
        if mg.relu && s < T::ZERO {
            s = T::ZERO;
        }
        *d = match mg.m {
            Some(m) => T::from_i64(requant(s.to_i64(), m)),
            None => s,
        };
    }
}

/// Residual merge epilogue over a lane-interleaved batch buffer: the
/// scalar [`apply_merge`] applied to lanes `0..b` of every output
/// position (pad lanes hold stale values and must stay untouched).
fn apply_merge_batch<T: Cell>(
    mg: &CMerge,
    other: &[T],
    dst: &mut [T],
    out_len: usize,
    b: usize,
    bp: usize,
) {
    for pos in 0..out_len {
        let base = pos * bp;
        apply_merge(mg, &other[base..base + b], &mut dst[base..base + b]);
    }
}

fn run_layer<T: Cell>(layer: &CLayer<T>, src: &[T], dst: &mut [T], acc: &mut Vec<T>) {
    let c_out = layer.c_out;
    acc.resize(c_out, T::ZERO);
    match layer.op {
        COp::Conv => {
            let c_in = layer.c_in;
            let mut o = 0usize;
            for win in layer.tap_start.windows(2) {
                let a = &mut acc[..c_out];
                a.copy_from_slice(&layer.bias);
                for t in &layer.taps[win[0] as usize..win[1] as usize] {
                    let xs = &src[t.x as usize..t.x as usize + c_in];
                    for (ci, &x) in xs.iter().enumerate() {
                        if x == T::ZERO {
                            continue; // common after int8 ReLU
                        }
                        let wb = t.w as usize + ci * c_out;
                        for (av, &wv) in a.iter_mut().zip(&layer.weights[wb..wb + c_out]) {
                            *av += wv * x;
                        }
                    }
                }
                finalize(layer, a, &mut dst[o..o + c_out]);
                o += c_out;
            }
        }
        COp::Depthwise => {
            let mut o = 0usize;
            for win in layer.tap_start.windows(2) {
                let a = &mut acc[..c_out];
                a.copy_from_slice(&layer.bias);
                for t in &layer.taps[win[0] as usize..win[1] as usize] {
                    let xs = &src[t.x as usize..t.x as usize + c_out];
                    let ws = &layer.weights[t.w as usize..t.w as usize + c_out];
                    for ((av, &wv), &xv) in a.iter_mut().zip(ws).zip(xs) {
                        *av += wv * xv;
                    }
                }
                finalize(layer, a, &mut dst[o..o + c_out]);
                o += c_out;
            }
        }
        COp::MaxPool => {
            let mut o = 0usize;
            for win in layer.tap_start.windows(2) {
                let a = &mut acc[..c_out];
                a.fill(T::FLOOR);
                for t in &layer.taps[win[0] as usize..win[1] as usize] {
                    let xs = &src[t.x as usize..t.x as usize + c_out];
                    for (av, &xv) in a.iter_mut().zip(xs) {
                        if xv > *av {
                            *av = xv;
                        }
                    }
                }
                // Pooling has no bias/ReLU/requant: emit the maxima as-is.
                dst[o..o + c_out].copy_from_slice(a);
                o += c_out;
            }
        }
        COp::Dense => {
            let a = &mut acc[..c_out];
            a.copy_from_slice(&layer.bias);
            for (f, &x) in src[..layer.in_len].iter().enumerate() {
                if x == T::ZERO {
                    continue;
                }
                let wrow = &layer.weights[f * c_out..(f + 1) * c_out];
                for (av, &wv) in a.iter_mut().zip(wrow) {
                    *av += wv * x;
                }
            }
            finalize(layer, a, &mut dst[..c_out]);
        }
    }
}

/// ReLU + requant epilogue for one accumulator tile: the scalar
/// [`finalize`] applied to `len` lanes, so the fused epilogue logic lives
/// in exactly one place.
#[inline]
fn store_tile<T: Cell>(layer: &CLayer<T>, acc: &[T; LANES], dst: &mut [T], len: usize) {
    finalize(layer, &acc[..len], &mut dst[..len]);
}

/// One lowered layer over the whole batch: full [`LANES`]-wide tiles get
/// a compile-time lane bound (the call below passes the literal, so the
/// inlined tile unrolls), the tail tile reuses the same code with a
/// runtime bound.
fn run_layer_batch<T: Cell>(layer: &CLayer<T>, src: &[T], dst: &mut [T], b: usize, bp: usize) {
    let full = b / LANES;
    for c in 0..full {
        run_layer_tile(layer, src, dst, bp, c * LANES, LANES);
    }
    let tail = b % LANES;
    if tail > 0 {
        run_layer_tile(layer, src, dst, bp, full * LANES, tail);
    }
}

/// One lane tile of one layer. The accumulator is a `[T; LANES]` local,
/// so a full tile keeps it in registers across the whole tap walk and
/// every weight scalar is broadcast over the tile — the loop structure
/// that makes the batch the innermost axis of each instruction. Per lane
/// the accumulation order over (tap, channel) is exactly [`run_layer`]'s,
/// and skipped zero terms (there: zero activations, here: zero weights)
/// only ever drop additions of zero, so values stay bit-identical.
#[inline]
fn run_layer_tile<T: Cell>(
    layer: &CLayer<T>,
    src: &[T],
    dst: &mut [T],
    bp: usize,
    off: usize,
    len: usize,
) {
    let c_out = layer.c_out;
    match layer.op {
        COp::Conv => {
            let c_in = layer.c_in;
            let mut o = 0usize;
            for win in layer.tap_start.windows(2) {
                let taps = &layer.taps[win[0] as usize..win[1] as usize];
                for (co, &bias) in layer.bias.iter().enumerate() {
                    let mut acc = [bias; LANES];
                    for t in taps {
                        let xb = t.x as usize * bp + off;
                        let wb = t.w as usize + co;
                        for ci in 0..c_in {
                            let w = layer.weights[wb + ci * c_out];
                            if w == T::ZERO {
                                continue;
                            }
                            let xs = &src[xb + ci * bp..xb + ci * bp + LANES];
                            for (a, &x) in acc[..len].iter_mut().zip(xs) {
                                *a += w * x;
                            }
                        }
                    }
                    store_tile(layer, &acc, &mut dst[(o + co) * bp + off..], len);
                }
                o += c_out;
            }
        }
        COp::Depthwise => {
            let mut o = 0usize;
            for win in layer.tap_start.windows(2) {
                let taps = &layer.taps[win[0] as usize..win[1] as usize];
                for (ch, &bias) in layer.bias.iter().enumerate() {
                    let mut acc = [bias; LANES];
                    for t in taps {
                        let w = layer.weights[t.w as usize + ch];
                        if w == T::ZERO {
                            continue;
                        }
                        let xb = (t.x as usize + ch) * bp + off;
                        let xs = &src[xb..xb + LANES];
                        for (a, &x) in acc[..len].iter_mut().zip(xs) {
                            *a += w * x;
                        }
                    }
                    store_tile(layer, &acc, &mut dst[(o + ch) * bp + off..], len);
                }
                o += c_out;
            }
        }
        COp::MaxPool => {
            let mut o = 0usize;
            for win in layer.tap_start.windows(2) {
                let taps = &layer.taps[win[0] as usize..win[1] as usize];
                for ch in 0..c_out {
                    let mut acc = [T::FLOOR; LANES];
                    for t in taps {
                        let xb = (t.x as usize + ch) * bp + off;
                        let xs = &src[xb..xb + LANES];
                        for (a, &x) in acc[..len].iter_mut().zip(xs) {
                            if x > *a {
                                *a = x;
                            }
                        }
                    }
                    // Pooling has no bias/ReLU/requant: emit maxima as-is.
                    dst[(o + ch) * bp + off..(o + ch) * bp + off + len]
                        .copy_from_slice(&acc[..len]);
                }
                o += c_out;
            }
        }
        COp::Dense => {
            for (u, &bias) in layer.bias.iter().enumerate() {
                let mut acc = [bias; LANES];
                for f in 0..layer.c_in {
                    let w = layer.weights[f * c_out + u];
                    if w == T::ZERO {
                        continue;
                    }
                    let xs = &src[f * bp + off..f * bp + off + LANES];
                    for (a, &x) in acc[..len].iter_mut().zip(xs) {
                        *a += w * x;
                    }
                }
                store_tile(layer, &acc, &mut dst[u * bp + off..], len);
            }
        }
    }
}

fn lower_program<T: Cell>(qm: &QModel) -> Result<Program<T>, String> {
    if qm.layers.is_empty() {
        return Err("compile: model has no layers".into());
    }
    let n = qm.layers.len();
    let topo = qm.node_topology();
    if topo.len() != n {
        return Err(format!(
            "compile: {}: topology has {} nodes for {n} layers",
            qm.name,
            topo.len()
        ));
    }
    let [h0, w0, c0] = qm.input_shape;
    let in_len = h0.max(1) * w0.max(1) * c0;
    let mut out_lens: Vec<usize> = Vec::with_capacity(n);
    let mut buf_len = in_len;
    let mut layers = Vec::with_capacity(n);
    for (idx, ql) in qm.layers.iter().enumerate() {
        let last = idx + 1 == n;
        let [h_in, w_in, c_in] = ql.in_shape;
        let [h_out, w_out, c_out] = ql.out_shape;
        let lin = h_in.max(1) * w_in.max(1) * c_in;
        let lout = h_out.max(1) * w_out.max(1) * c_out;
        // Resolve the upstream value: a named earlier node, or the input.
        let src_len = match topo[idx].src {
            None => in_len,
            Some(j) if j < idx => out_lens[j],
            Some(j) => {
                return Err(format!(
                    "compile: {}: reads non-earlier node {j}",
                    ql.name
                ));
            }
        };
        if lin != src_len {
            return Err(format!(
                "compile: {}: input len {lin} != upstream {src_len}",
                ql.name
            ));
        }
        if let Some(mg) = &topo[idx].merge {
            let other_len = match mg.with {
                None => in_len,
                Some(j) if j < idx => out_lens[j],
                Some(j) => {
                    return Err(format!(
                        "compile: {}: merges non-earlier node {j}",
                        ql.name
                    ));
                }
            };
            if other_len != lout {
                return Err(format!(
                    "compile: {}: merge branch len {other_len} != output {lout}",
                    ql.name
                ));
            }
        }
        let m = ql.fused_requant(last);
        let layer = match ql.kind {
            QKind::Dense => {
                let feats = lin;
                if ql.w_shape.len() != 2 || ql.w_shape[1] != feats {
                    return Err(format!(
                        "compile: {}: dense w_shape {:?} inconsistent with {feats} features",
                        ql.name, ql.w_shape
                    ));
                }
                if ql.w_q.len() != c_out * feats || ql.b_q.len() != c_out {
                    return Err(format!("compile: {}: dense weight/bias length", ql.name));
                }
                // Transpose (unit, feat) -> (feat, unit) for contiguous
                // per-feature axpy rows.
                let mut wt = vec![T::ZERO; ql.w_q.len()];
                for (i, &w) in ql.w_q.iter().enumerate() {
                    let (u, f) = (i / feats, i % feats);
                    wt[f * c_out + u] = T::from_i64(w);
                }
                CLayer {
                    name: ql.name.clone(),
                    op: COp::Dense,
                    c_in: feats,
                    c_out,
                    in_len: lin,
                    out_len: lout,
                    tap_start: Vec::new(),
                    taps: Vec::new(),
                    weights: wt,
                    bias: ql.b_q.iter().map(|&b| T::from_i64(b)).collect(),
                    relu: ql.relu,
                    m,
                    src: topo[idx].src,
                    in_buf: 0,
                    out_buf: 0,
                    merge: None,
                }
            }
            QKind::Conv => {
                let (k, s, p) = (ql.k, ql.s, ql.p);
                if k == 0 || s == 0 {
                    return Err(format!("compile: {}: zero kernel/stride", ql.name));
                }
                if ql.w_q.len() != k * k * c_in * c_out || ql.b_q.len() != c_out {
                    return Err(format!("compile: {}: conv weight/bias length", ql.name));
                }
                let (tap_start, taps) =
                    padded_taps(h_in, w_in, h_out, w_out, k, s, p, c_in, c_in * c_out);
                CLayer {
                    name: ql.name.clone(),
                    op: COp::Conv,
                    c_in,
                    c_out,
                    in_len: lin,
                    out_len: lout,
                    tap_start,
                    taps,
                    weights: ql.w_q.iter().map(|&w| T::from_i64(w)).collect(),
                    bias: ql.b_q.iter().map(|&b| T::from_i64(b)).collect(),
                    relu: ql.relu,
                    m,
                    src: topo[idx].src,
                    in_buf: 0,
                    out_buf: 0,
                    merge: None,
                }
            }
            QKind::DwConv | QKind::AvgPool => {
                let (k, s, p) = (ql.k, ql.s, ql.p);
                if k == 0 || s == 0 {
                    return Err(format!("compile: {}: zero kernel/stride", ql.name));
                }
                if c_in != c_out {
                    return Err(format!("compile: {}: depthwise c_in != c_out", ql.name));
                }
                if ql.w_q.len() != k * k * c_out || ql.b_q.len() != c_out {
                    return Err(format!(
                        "compile: {}: depthwise weight/bias length",
                        ql.name
                    ));
                }
                let (tap_start, taps) =
                    padded_taps(h_in, w_in, h_out, w_out, k, s, p, c_in, c_out);
                CLayer {
                    name: ql.name.clone(),
                    op: COp::Depthwise,
                    c_in,
                    c_out,
                    in_len: lin,
                    out_len: lout,
                    tap_start,
                    taps,
                    weights: ql.w_q.iter().map(|&w| T::from_i64(w)).collect(),
                    bias: ql.b_q.iter().map(|&b| T::from_i64(b)).collect(),
                    relu: ql.relu,
                    m,
                    src: topo[idx].src,
                    in_buf: 0,
                    out_buf: 0,
                    merge: None,
                }
            }
            QKind::MaxPool => {
                let (k, s) = (ql.k, ql.s);
                if k == 0 || s == 0 {
                    return Err(format!("compile: {}: zero kernel/stride", ql.name));
                }
                if c_in != c_out {
                    return Err(format!("compile: {}: pool c_in != c_out", ql.name));
                }
                // The interpreter's pool windows ignore padding and clip
                // at the map edge; mirror that exactly.
                let mut tap_start = Vec::with_capacity(h_out * w_out + 1);
                tap_start.push(0u32);
                let mut taps = Vec::new();
                for orow in 0..h_out {
                    for ocol in 0..w_out {
                        for u in 0..k {
                            let r = orow * s + u;
                            if r >= h_in {
                                continue;
                            }
                            for v in 0..k {
                                let c = ocol * s + v;
                                if c >= w_in {
                                    continue;
                                }
                                taps.push(Tap {
                                    w: 0,
                                    x: ((r * w_in + c) * c_in) as u32,
                                });
                            }
                        }
                        tap_start.push(taps.len() as u32);
                    }
                }
                CLayer {
                    name: ql.name.clone(),
                    op: COp::MaxPool,
                    c_in,
                    c_out,
                    in_len: lin,
                    out_len: lout,
                    tap_start,
                    taps,
                    weights: Vec::new(),
                    bias: Vec::new(),
                    relu: false,
                    m: None,
                    src: topo[idx].src,
                    in_buf: 0,
                    out_buf: 0,
                    merge: None,
                }
            }
        };
        let mut layer = layer;
        if let Some(mg) = &topo[idx].merge {
            layer.merge = Some(CMerge {
                with: mg.with,
                other_buf: 0, // patched by the allocator below
                m: if mg.m != 0.0 { Some(mg.m) } else { None },
                relu: mg.relu,
            });
        }
        buf_len = buf_len.max(lout);
        out_lens.push(lout);
        layers.push(layer);
    }
    // Liveness-driven scratch allocation. Value v: 0 = the program input,
    // i + 1 = node i's output. A value's buffer is recycled right after
    // its last reader runs; a node's output buffer is drawn from the free
    // stack only after its source and shortcut buffers are resolved, so
    // it can never alias either. Chains degenerate to the classic
    // two-buffer ping-pong; each concurrently-live residual shortcut
    // holds one extra buffer — the software skip FIFO.
    let n_vals = n + 1;
    let mut last_use: Vec<usize> = (0..n_vals).map(|v| v.saturating_sub(1)).collect();
    last_use[n] = n; // the final output outlives every node
    for (i, t) in topo.iter().enumerate() {
        let sv = t.src.map_or(0, |j| j + 1);
        last_use[sv] = last_use[sv].max(i);
        if let Some(mg) = &t.merge {
            let ov = mg.with.map_or(0, |j| j + 1);
            last_use[ov] = last_use[ov].max(i);
        }
    }
    let mut buf_of = vec![usize::MAX; n_vals];
    let mut free: Vec<usize> = Vec::new();
    let mut pool = 0usize;
    buf_of[0] = {
        pool += 1;
        pool - 1
    };
    for i in 0..n {
        let in_b = buf_of[topo[i].src.map_or(0, |j| j + 1)];
        let other_b = topo[i]
            .merge
            .as_ref()
            .map(|mg| buf_of[mg.with.map_or(0, |j| j + 1)]);
        let out_b = free.pop().unwrap_or_else(|| {
            pool += 1;
            pool - 1
        });
        buf_of[i + 1] = out_b;
        layers[i].in_buf = in_b;
        layers[i].out_buf = out_b;
        if let Some(cm) = &mut layers[i].merge {
            cm.other_buf = other_b.expect("merge without topology entry");
        }
        for v in 0..n_vals {
            if v != n && last_use[v] == i && buf_of[v] != usize::MAX {
                free.push(buf_of[v]);
            }
        }
    }
    Ok(Program {
        layers,
        in_len,
        out_len: *out_lens.last().expect("non-empty model"),
        buf_len,
        pool,
        in_buf: buf_of[0],
        out_buf: buf_of[n],
    })
}

/// Window tap table for padded (conv-style) kinds: per output pixel, the
/// in-map taps in the interpreter's (u, v) order; padding taps are simply
/// absent. `w_stride` is the weight-buffer distance between taps.
#[allow(clippy::too_many_arguments)]
fn padded_taps(
    h_in: usize,
    w_in: usize,
    h_out: usize,
    w_out: usize,
    k: usize,
    s: usize,
    p: usize,
    c_in: usize,
    w_stride: usize,
) -> (Vec<u32>, Vec<Tap>) {
    let mut tap_start = Vec::with_capacity(h_out * w_out + 1);
    tap_start.push(0u32);
    let mut taps = Vec::new();
    for orow in 0..h_out {
        for ocol in 0..w_out {
            for u in 0..k {
                let r = (orow * s + u) as isize - p as isize;
                if r < 0 || r >= h_in as isize {
                    continue;
                }
                for v in 0..k {
                    let c = (ocol * s + v) as isize - p as isize;
                    if c < 0 || c >= w_in as isize {
                        continue;
                    }
                    taps.push(Tap {
                        w: ((u * k + v) * w_stride) as u32,
                        x: ((r as usize * w_in + c as usize) * c_in) as u32,
                    });
                }
            }
            tap_start.push(taps.len() as u32);
        }
    }
    (tap_start, taps)
}

// ---------------------------------------------------------------------------
// The folded tier: rate-aware lowering (DESIGN.md §9).
// ---------------------------------------------------------------------------

/// Fixed channel-block width of the register-blocked micro-kernels. Eight
/// accumulators fit the narrow path in two SIMD registers on every target
/// the suite runs on, and the fixed bound lets the inner tap loop
/// autovectorise without a lane mask.
const CHUNK: usize = 8;

/// Which micro-kernel the folding pass selected for a layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelSel {
    /// Scalar zero-activation-skip kernel — the unfolded engine's default,
    /// best on full-rate maps where post-ReLU sparsity pays for the branch.
    ZeroSkip,
    /// Register-blocked, branch-free, `CHUNK`-wide channel-chunked
    /// kernel: selected for low-rate MAC layers left unfused.
    Blocked,
    /// Member of a fused window→1x1-conv pair: the pair runs in one
    /// traversal, the intermediate pixel never touches memory.
    FusedPw,
    /// Member of a fused window→dense pair: the flattened map is consumed
    /// straight out of registers by the dense accumulators.
    FusedDense,
}

impl std::fmt::Display for KernelSel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            KernelSel::ZeroSkip => "zero-skip",
            KernelSel::Blocked => "blocked",
            KernelSel::FusedPw => "fused-pw",
            KernelSel::FusedDense => "fused-dense",
        })
    }
}

/// One row of the per-layer kernel-selection table.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelChoice {
    pub layer: String,
    /// The Eq.-8 fold factor the selection keyed on (1 = full rate).
    pub fold: u64,
    pub kernel: KernelSel,
}

/// One step of a folded program: indices into `Program::layers`.
#[derive(Debug, Clone, Copy)]
enum FStep {
    Single { li: usize, blocked: bool },
    /// Window layer `a` fused with the 1x1 conv `b` that consumes it.
    FusedPw { a: usize, b: usize },
    /// Window layer `a` fused with the dense layer `b` that flattens it.
    FusedDense { a: usize, b: usize },
}

/// A lowered 1x1 stride-1 unpadded conv: exactly one tap per output
/// pixel, at weight base 0 and input base `pixel * c_in`.
fn is_pointwise<T: Cell>(l: &CLayer<T>) -> bool {
    l.op == COp::Conv
        && l.tap_start.len() >= 2
        && l.taps.len() == l.tap_start.len() - 1
        && l.tap_start.windows(2).enumerate().all(|(pix, w)| {
            w[1] - w[0] == 1 && {
                let t = l.taps[w[0] as usize];
                t.w == 0 && t.x as usize == pix * l.c_in
            }
        })
}

/// How many layers read node `i`'s output, counting both straight-line
/// sources and residual-merge shortcuts. Fusion across a step boundary is
/// only sound when the produced value has exactly one reader: a fused
/// step never materialises the intermediate map.
fn consumer_count<T: Cell>(prog: &Program<T>, node: usize) -> usize {
    prog.layers
        .iter()
        .filter(|l| {
            l.src == Some(node) || matches!(&l.merge, Some(m) if m.with == Some(node))
        })
        .count()
}

/// The folding pass: walk the lowered program with its per-layer Eq.-8
/// fold factors and decide, per layer, which kernel runs it — fusing
/// consecutive low-rate layers into single-traversal steps and routing
/// unfused low-rate MAC layers to the register-blocked kernel.
fn plan_folding<T: Cell>(
    prog: &Program<T>,
    folds: &[u64],
) -> Result<(Vec<FStep>, Vec<KernelChoice>), String> {
    let n = prog.layers.len();
    if folds.len() != n {
        return Err(format!(
            "folded lowering: {} fold factors for {n} layers",
            folds.len()
        ));
    }
    let mut table: Vec<KernelChoice> = prog
        .layers
        .iter()
        .zip(folds)
        .map(|(l, &f)| KernelChoice {
            layer: l.name.clone(),
            fold: f,
            kernel: KernelSel::ZeroSkip,
        })
        .collect();
    let mut steps = Vec::new();
    let mut i = 0usize;
    while i < n {
        let l = &prog.layers[i];
        let window = matches!(l.op, COp::Conv | COp::Depthwise | COp::MaxPool);
        // Fusing skips the intermediate buffer, so the pair must be a
        // pure chain link: adjacent in dataflow (not just index order),
        // with no residual merge on either side and no shortcut tapping
        // the intermediate value.
        let fusable = i + 1 < n
            && prog.layers[i + 1].src == Some(i)
            && l.merge.is_none()
            && prog.layers[i + 1].merge.is_none()
            && consumer_count(prog, i) == 1;
        if folds[i] > 1 && window && l.c_out > 0 && fusable {
            let next = &prog.layers[i + 1];
            if folds[i + 1] > 1
                && is_pointwise(next)
                && next.in_len == l.out_len
                && next.c_in == l.c_out
            {
                table[i].kernel = KernelSel::FusedPw;
                table[i + 1].kernel = KernelSel::FusedPw;
                steps.push(FStep::FusedPw { a: i, b: i + 1 });
                i += 2;
                continue;
            }
            if next.op == COp::Dense && next.c_in == l.out_len && next.in_len == l.out_len {
                table[i].kernel = KernelSel::FusedDense;
                table[i + 1].kernel = KernelSel::FusedDense;
                steps.push(FStep::FusedDense { a: i, b: i + 1 });
                i += 2;
                continue;
            }
        }
        let blocked = folds[i] > 1
            && matches!(l.op, COp::Conv | COp::Depthwise | COp::Dense)
            && l.c_out >= CHUNK;
        if blocked {
            table[i].kernel = KernelSel::Blocked;
        }
        steps.push(FStep::Single { li: i, blocked });
        i += 1;
    }
    Ok((steps, table))
}

/// Register-blocked, branch-free kernel: output channels in fixed
/// [`CHUNK`]-wide blocks held in a local array, inner loops free of
/// data-dependent branches so they autovectorise. Per output channel the
/// accumulated terms are exactly [`run_layer`]'s (the zero-skip there
/// only ever drops additions of zero), so values stay bit-identical.
fn run_layer_blocked<T: Cell>(layer: &CLayer<T>, src: &[T], dst: &mut [T]) {
    let c_out = layer.c_out;
    match layer.op {
        COp::Conv => {
            let c_in = layer.c_in;
            let mut o = 0usize;
            for win in layer.tap_start.windows(2) {
                let taps = &layer.taps[win[0] as usize..win[1] as usize];
                let mut cb = 0usize;
                while cb < c_out {
                    let bl = CHUNK.min(c_out - cb);
                    let mut acc = [T::ZERO; CHUNK];
                    acc[..bl].copy_from_slice(&layer.bias[cb..cb + bl]);
                    for t in taps {
                        let xb = t.x as usize;
                        let wb = t.w as usize + cb;
                        for ci in 0..c_in {
                            let x = src[xb + ci];
                            let ws = &layer.weights[wb + ci * c_out..wb + ci * c_out + bl];
                            for (a, &w) in acc[..bl].iter_mut().zip(ws) {
                                *a += w * x;
                            }
                        }
                    }
                    finalize(layer, &acc[..bl], &mut dst[o + cb..o + cb + bl]);
                    cb += bl;
                }
                o += c_out;
            }
        }
        COp::Depthwise => {
            let mut o = 0usize;
            for win in layer.tap_start.windows(2) {
                let taps = &layer.taps[win[0] as usize..win[1] as usize];
                let mut cb = 0usize;
                while cb < c_out {
                    let bl = CHUNK.min(c_out - cb);
                    let mut acc = [T::ZERO; CHUNK];
                    acc[..bl].copy_from_slice(&layer.bias[cb..cb + bl]);
                    for t in taps {
                        let ws = &layer.weights[t.w as usize + cb..t.w as usize + cb + bl];
                        let xs = &src[t.x as usize + cb..t.x as usize + cb + bl];
                        for ((a, &w), &x) in acc[..bl].iter_mut().zip(ws).zip(xs) {
                            *a += w * x;
                        }
                    }
                    finalize(layer, &acc[..bl], &mut dst[o + cb..o + cb + bl]);
                    cb += bl;
                }
                o += c_out;
            }
        }
        COp::Dense => {
            let mut cb = 0usize;
            while cb < c_out {
                let bl = CHUNK.min(c_out - cb);
                let mut acc = [T::ZERO; CHUNK];
                acc[..bl].copy_from_slice(&layer.bias[cb..cb + bl]);
                for (f, &x) in src[..layer.in_len].iter().enumerate() {
                    let ws = &layer.weights[f * c_out + cb..f * c_out + cb + bl];
                    for (a, &w) in acc[..bl].iter_mut().zip(ws) {
                        *a += w * x;
                    }
                }
                finalize(layer, &acc[..bl], &mut dst[cb..cb + bl]);
                cb += bl;
            }
        }
        COp::MaxPool => {
            // Never selected by the planner (no MACs to block); kept
            // correct so the dispatch is total.
            let mut o = 0usize;
            for win in layer.tap_start.windows(2) {
                let taps = &layer.taps[win[0] as usize..win[1] as usize];
                let mut cb = 0usize;
                while cb < c_out {
                    let bl = CHUNK.min(c_out - cb);
                    let mut acc = [T::FLOOR; CHUNK];
                    for t in taps {
                        let xs = &src[t.x as usize + cb..t.x as usize + cb + bl];
                        for (a, &x) in acc[..bl].iter_mut().zip(xs) {
                            if x > *a {
                                *a = x;
                            }
                        }
                    }
                    dst[o + cb..o + cb + bl].copy_from_slice(&acc[..bl]);
                    cb += bl;
                }
                o += c_out;
            }
        }
    }
}

/// Accumulate one output pixel of a fused producer into `acc`
/// (len = `l.c_out`), without the epilogue. Mirrors [`run_layer`]'s
/// per-window body for each window op.
fn produce_window<T: Cell>(l: &CLayer<T>, src: &[T], taps: &[Tap], acc: &mut [T]) {
    match l.op {
        COp::Conv => {
            acc.copy_from_slice(&l.bias);
            let (c_in, c_out) = (l.c_in, l.c_out);
            for t in taps {
                let xs = &src[t.x as usize..t.x as usize + c_in];
                for (ci, &x) in xs.iter().enumerate() {
                    if x == T::ZERO {
                        continue;
                    }
                    let wb = t.w as usize + ci * c_out;
                    for (av, &wv) in acc.iter_mut().zip(&l.weights[wb..wb + c_out]) {
                        *av += wv * x;
                    }
                }
            }
        }
        COp::Depthwise => {
            acc.copy_from_slice(&l.bias);
            for t in taps {
                let xs = &src[t.x as usize..t.x as usize + l.c_out];
                let ws = &l.weights[t.w as usize..t.w as usize + l.c_out];
                for ((av, &wv), &xv) in acc.iter_mut().zip(ws).zip(xs) {
                    *av += wv * xv;
                }
            }
        }
        COp::MaxPool => {
            acc.fill(T::FLOOR);
            for t in taps {
                let xs = &src[t.x as usize..t.x as usize + l.c_out];
                for (av, &xv) in acc.iter_mut().zip(xs) {
                    if xv > *av {
                        *av = xv;
                    }
                }
            }
        }
        COp::Dense => debug_assert!(false, "dense is never a fused producer"),
    }
}

/// The producer's per-window epilogue: pooling emits maxima as-is, every
/// other op runs the fused ReLU/requant.
#[inline]
fn emit_window<T: Cell>(l: &CLayer<T>, acc: &[T], dst: &mut [T]) {
    if l.op == COp::MaxPool {
        dst.copy_from_slice(acc);
    } else {
        finalize(l, acc, dst);
    }
}

/// Fused window→1x1-conv step, scalar path: each produced pixel is
/// consumed immediately by the pointwise consumer, so the intermediate
/// map (`la.out_len` cells) is never written.
fn run_fused_pw<T: Cell>(
    la: &CLayer<T>,
    lb: &CLayer<T>,
    src: &[T],
    dst: &mut [T],
    pacc: &mut Vec<T>,
    mid: &mut Vec<T>,
    acc: &mut Vec<T>,
) {
    let c_mid = la.c_out;
    let c_out = lb.c_out;
    pacc.resize(c_mid, T::ZERO);
    mid.resize(c_mid, T::ZERO);
    acc.resize(c_out, T::ZERO);
    let mut o = 0usize;
    for win in la.tap_start.windows(2) {
        let taps = &la.taps[win[0] as usize..win[1] as usize];
        produce_window(la, src, taps, &mut pacc[..c_mid]);
        emit_window(la, &pacc[..c_mid], &mut mid[..c_mid]);
        let a = &mut acc[..c_out];
        a.copy_from_slice(&lb.bias);
        for (ci, &x) in mid[..c_mid].iter().enumerate() {
            if x == T::ZERO {
                continue;
            }
            let wb = ci * c_out;
            for (av, &wv) in a.iter_mut().zip(&lb.weights[wb..wb + c_out]) {
                *av += wv * x;
            }
        }
        finalize(lb, a, &mut dst[o..o + c_out]);
        o += c_out;
    }
}

/// Fused window→dense step, scalar path: the dense accumulators live
/// across the whole traversal and consume each produced pixel's channels
/// in flattening order (pixel-major, channel-minor — exactly the feature
/// order of the unfused dense kernel).
fn run_fused_dense<T: Cell>(
    la: &CLayer<T>,
    lb: &CLayer<T>,
    src: &[T],
    dst: &mut [T],
    pacc: &mut Vec<T>,
    mid: &mut Vec<T>,
    acc: &mut Vec<T>,
) {
    let c_mid = la.c_out;
    let c_out = lb.c_out;
    pacc.resize(c_mid, T::ZERO);
    mid.resize(c_mid, T::ZERO);
    acc.resize(c_out, T::ZERO);
    acc[..c_out].copy_from_slice(&lb.bias);
    let mut feat = 0usize;
    for win in la.tap_start.windows(2) {
        let taps = &la.taps[win[0] as usize..win[1] as usize];
        produce_window(la, src, taps, &mut pacc[..c_mid]);
        emit_window(la, &pacc[..c_mid], &mut mid[..c_mid]);
        for (ci, &x) in mid[..c_mid].iter().enumerate() {
            if x == T::ZERO {
                continue;
            }
            let wrow = &lb.weights[(feat + ci) * c_out..(feat + ci + 1) * c_out];
            for (av, &wv) in acc[..c_out].iter_mut().zip(wrow) {
                *av += wv * x;
            }
        }
        feat += c_mid;
    }
    finalize(lb, &acc[..c_out], &mut dst[..c_out]);
}

/// One lane tile of a fused producer's window: the finalized pixel lands
/// in the `mid` lane block (`c_out * LANES` cells) instead of the
/// ping-pong buffer. Mirrors [`run_layer_tile`]'s per-window body.
fn produce_window_tile<T: Cell>(
    l: &CLayer<T>,
    src: &[T],
    taps: &[Tap],
    bp: usize,
    off: usize,
    len: usize,
    mid: &mut [T],
) {
    let c_out = l.c_out;
    match l.op {
        COp::Conv => {
            let c_in = l.c_in;
            for (co, &bias) in l.bias.iter().enumerate() {
                let mut acc = [bias; LANES];
                for t in taps {
                    let xb = t.x as usize * bp + off;
                    let wb = t.w as usize + co;
                    for ci in 0..c_in {
                        let w = l.weights[wb + ci * c_out];
                        if w == T::ZERO {
                            continue;
                        }
                        let xs = &src[xb + ci * bp..xb + ci * bp + LANES];
                        for (a, &x) in acc[..len].iter_mut().zip(xs) {
                            *a += w * x;
                        }
                    }
                }
                finalize(l, &acc[..len], &mut mid[co * LANES..co * LANES + len]);
            }
        }
        COp::Depthwise => {
            for (ch, &bias) in l.bias.iter().enumerate() {
                let mut acc = [bias; LANES];
                for t in taps {
                    let w = l.weights[t.w as usize + ch];
                    if w == T::ZERO {
                        continue;
                    }
                    let xb = (t.x as usize + ch) * bp + off;
                    let xs = &src[xb..xb + LANES];
                    for (a, &x) in acc[..len].iter_mut().zip(xs) {
                        *a += w * x;
                    }
                }
                finalize(l, &acc[..len], &mut mid[ch * LANES..ch * LANES + len]);
            }
        }
        COp::MaxPool => {
            for ch in 0..c_out {
                let mut acc = [T::FLOOR; LANES];
                for t in taps {
                    let xb = (t.x as usize + ch) * bp + off;
                    let xs = &src[xb..xb + LANES];
                    for (a, &x) in acc[..len].iter_mut().zip(xs) {
                        if x > *a {
                            *a = x;
                        }
                    }
                }
                mid[ch * LANES..ch * LANES + len].copy_from_slice(&acc[..len]);
            }
        }
        COp::Dense => debug_assert!(false, "dense is never a fused producer"),
    }
}

/// One lane tile of a fused window→1x1-conv step.
#[allow(clippy::too_many_arguments)]
fn run_fused_pw_tile<T: Cell>(
    la: &CLayer<T>,
    lb: &CLayer<T>,
    src: &[T],
    dst: &mut [T],
    bp: usize,
    off: usize,
    len: usize,
    mid: &mut [T],
) {
    let c_mid = la.c_out;
    let c_out = lb.c_out;
    let mut o = 0usize;
    for win in la.tap_start.windows(2) {
        let taps = &la.taps[win[0] as usize..win[1] as usize];
        produce_window_tile(la, src, taps, bp, off, len, mid);
        for (co, &bias) in lb.bias.iter().enumerate() {
            let mut acc = [bias; LANES];
            for ci in 0..c_mid {
                let w = lb.weights[ci * c_out + co];
                if w == T::ZERO {
                    continue;
                }
                let xs = &mid[ci * LANES..ci * LANES + LANES];
                for (a, &x) in acc[..len].iter_mut().zip(xs) {
                    *a += w * x;
                }
            }
            store_tile(lb, &acc, &mut dst[(o + co) * bp + off..], len);
        }
        o += c_out;
    }
}

/// One lane tile of a fused window→dense step. `dacc` holds the dense
/// accumulators (`c_out * LANES` cells) across the whole traversal.
#[allow(clippy::too_many_arguments)]
fn run_fused_dense_tile<T: Cell>(
    la: &CLayer<T>,
    lb: &CLayer<T>,
    src: &[T],
    dst: &mut [T],
    bp: usize,
    off: usize,
    len: usize,
    mid: &mut [T],
    dacc: &mut [T],
) {
    let c_mid = la.c_out;
    let c_out = lb.c_out;
    for (u, &bias) in lb.bias.iter().enumerate() {
        dacc[u * LANES..(u + 1) * LANES].fill(bias);
    }
    let mut feat = 0usize;
    for win in la.tap_start.windows(2) {
        let taps = &la.taps[win[0] as usize..win[1] as usize];
        produce_window_tile(la, src, taps, bp, off, len, mid);
        for ci in 0..c_mid {
            let xs = &mid[ci * LANES..ci * LANES + LANES];
            let wrow = &lb.weights[(feat + ci) * c_out..(feat + ci + 1) * c_out];
            for (u, &w) in wrow.iter().enumerate() {
                if w == T::ZERO {
                    continue;
                }
                let d = &mut dacc[u * LANES..u * LANES + len];
                for (a, &x) in d.iter_mut().zip(xs) {
                    *a += w * x;
                }
            }
        }
        feat += c_mid;
    }
    for u in 0..c_out {
        finalize(
            lb,
            &dacc[u * LANES..u * LANES + len],
            &mut dst[u * bp + off..u * bp + off + len],
        );
    }
}

/// One folded step, scalar path.
fn run_step<T: Cell>(
    prog: &Program<T>,
    step: FStep,
    src: &[T],
    dst: &mut [T],
    acc: &mut Vec<T>,
    pacc: &mut Vec<T>,
    mid: &mut Vec<T>,
) {
    match step {
        FStep::Single { li, blocked } => {
            let l = &prog.layers[li];
            if blocked {
                run_layer_blocked(l, &src[..l.in_len], &mut dst[..l.out_len]);
            } else {
                run_layer(l, &src[..l.in_len], &mut dst[..l.out_len], acc);
            }
        }
        FStep::FusedPw { a, b } => {
            let (la, lb) = (&prog.layers[a], &prog.layers[b]);
            run_fused_pw(la, lb, &src[..la.in_len], &mut dst[..lb.out_len], pacc, mid, acc);
        }
        FStep::FusedDense { a, b } => {
            let (la, lb) = (&prog.layers[a], &prog.layers[b]);
            run_fused_dense(la, lb, &src[..la.in_len], &mut dst[..lb.out_len], pacc, mid, acc);
        }
    }
}

/// One folded step over the whole batch. Unfused steps reuse the batched
/// tier's lane tiles (which are already register-blocked); fused steps
/// run their single-traversal kernels tile by tile.
#[allow(clippy::too_many_arguments)]
fn run_step_batch<T: Cell>(
    prog: &Program<T>,
    step: FStep,
    src: &[T],
    dst: &mut [T],
    b: usize,
    bp: usize,
    bmid: &mut Vec<T>,
    bacc: &mut Vec<T>,
) {
    match step {
        FStep::Single { li, .. } => {
            let l = &prog.layers[li];
            run_layer_batch(l, &src[..l.in_len * bp], &mut dst[..l.out_len * bp], b, bp);
        }
        FStep::FusedPw { a, b: bi } => {
            let (la, lb) = (&prog.layers[a], &prog.layers[bi]);
            bmid.resize(la.c_out * LANES, T::ZERO);
            let full = b / LANES;
            for c in 0..full {
                run_fused_pw_tile(la, lb, src, dst, bp, c * LANES, LANES, bmid);
            }
            let tail = b % LANES;
            if tail > 0 {
                run_fused_pw_tile(la, lb, src, dst, bp, full * LANES, tail, bmid);
            }
        }
        FStep::FusedDense { a, b: bi } => {
            let (la, lb) = (&prog.layers[a], &prog.layers[bi]);
            bmid.resize(la.c_out * LANES, T::ZERO);
            bacc.resize(lb.c_out * LANES, T::ZERO);
            let full = b / LANES;
            for c in 0..full {
                run_fused_dense_tile(la, lb, src, dst, bp, c * LANES, LANES, bmid, bacc);
            }
            let tail = b % LANES;
            if tail > 0 {
                run_fused_dense_tile(la, lb, src, dst, bp, full * LANES, tail, bmid, bacc);
            }
        }
    }
}

/// First and last program layer of a folded step: the step reads the
/// first layer's input buffer and writes the last layer's output buffer.
fn step_io(step: FStep) -> (usize, usize) {
    match step {
        FStep::Single { li, .. } => (li, li),
        FStep::FusedPw { a, b } | FStep::FusedDense { a, b } => (a, b),
    }
}

/// A folded program plus its reusable execution scratch; the same
/// clone-shares-program structure as [`Engine`].
#[derive(Debug, Clone)]
struct FoldedEngine<T> {
    prog: Arc<Program<T>>,
    steps: Arc<Vec<FStep>>,
    table: Arc<Vec<KernelChoice>>,
    bufs: Vec<Vec<T>>,
    tmp: Vec<T>,
    acc: Vec<T>,
    pacc: Vec<T>,
    mid: Vec<T>,
    out: Vec<i64>,
    bbufs: Vec<Vec<T>>,
    btmp: Vec<T>,
    bmid: Vec<T>,
    bacc: Vec<T>,
    /// Optional per-layer wall-time accumulators (DESIGN.md §13). Fused
    /// steps attribute their whole step time to the step's first layer.
    profiler: Option<Arc<LayerProfiler>>,
}

impl<T: Cell> FoldedEngine<T> {
    fn build(qm: &QModel, folds: &[u64]) -> Result<FoldedEngine<T>, String> {
        let prog = lower_program::<T>(qm)?;
        let (steps, table) = plan_folding(&prog, folds)?;
        Ok(FoldedEngine {
            bufs: vec![vec![T::ZERO; prog.buf_len]; prog.pool],
            tmp: vec![T::ZERO; prog.buf_len],
            acc: Vec::new(),
            pacc: Vec::new(),
            mid: Vec::new(),
            out: Vec::new(),
            bbufs: Vec::new(),
            btmp: Vec::new(),
            bmid: Vec::new(),
            bacc: Vec::new(),
            profiler: None,
            prog: Arc::new(prog),
            steps: Arc::new(steps),
            table: Arc::new(table),
        })
    }

    fn execute(&mut self, frame: &[i64]) -> Result<&[i64], String> {
        validate(&self.prog, frame)?;
        self.execute_unchecked(frame)
    }

    fn execute_unchecked(&mut self, frame: &[i64]) -> Result<&[i64], String> {
        let FoldedEngine {
            prog,
            steps,
            bufs,
            tmp,
            acc,
            pacc,
            mid,
            out,
            profiler,
            ..
        } = self;
        for (slot, &v) in bufs[prog.in_buf].iter_mut().zip(frame) {
            *slot = T::from_i64(v);
        }
        for &step in steps.iter() {
            let (first, last) = step_io(step);
            let in_b = prog.layers[first].in_buf;
            let out_b = prog.layers[last].out_buf;
            let t0 = profiler.as_ref().map(|_| Instant::now());
            if let FStep::Single { .. } = step {
                let mut dst = std::mem::take(&mut bufs[out_b]);
                run_step(prog, step, &bufs[in_b], &mut dst, acc, pacc, mid);
                if let Some(mg) = &prog.layers[last].merge {
                    let ol = prog.layers[last].out_len;
                    apply_merge(mg, &bufs[mg.other_buf][..ol], &mut dst[..ol]);
                }
                bufs[out_b] = dst;
            } else {
                // Fused steps bypass the intermediate buffer, so the
                // allocator's recycling may alias `out_b` with `in_b`;
                // run into the spare buffer and swap it in.
                run_step(prog, step, &bufs[in_b], tmp, acc, pacc, mid);
                std::mem::swap(&mut bufs[out_b], tmp);
            }
            if let (Some(p), Some(t0)) = (profiler.as_deref(), t0) {
                p.record(first, t0.elapsed().as_nanos() as u64);
            }
        }
        let res: &[T] = &bufs[prog.out_buf][..prog.out_len];
        out.clear();
        out.extend(res.iter().map(|v| v.to_i64()));
        Ok(out.as_slice())
    }

    fn execute_batch(&mut self, frames: &[&[i64]]) -> Result<Vec<Vec<i64>>, String> {
        for (i, f) in frames.iter().enumerate() {
            validate(&self.prog, f).map_err(|e| format!("batch frame {i}: {e}"))?;
        }
        self.execute_batch_prevalidated(frames)
    }

    fn execute_batch_prevalidated(&mut self, frames: &[&[i64]]) -> Result<Vec<Vec<i64>>, String> {
        if frames.is_empty() {
            return Ok(Vec::new());
        }
        if frames.len() == 1 {
            let out = self.execute_unchecked(frames[0])?;
            return Ok(vec![out.to_vec()]);
        }
        let b = frames.len();
        let bp = b.div_ceil(LANES) * LANES;
        let FoldedEngine {
            prog,
            steps,
            bbufs,
            btmp,
            bmid,
            bacc,
            profiler,
            ..
        } = self;
        bbufs.resize(prog.pool, Vec::new());
        for bb in bbufs.iter_mut() {
            bb.resize(prog.buf_len * bp, T::ZERO);
        }
        btmp.resize(prog.buf_len * bp, T::ZERO);
        for (lane, f) in frames.iter().enumerate() {
            for (pos, &v) in f.iter().enumerate() {
                bbufs[prog.in_buf][pos * bp + lane] = T::from_i64(v);
            }
        }
        for &step in steps.iter() {
            let (first, last) = step_io(step);
            let in_b = prog.layers[first].in_buf;
            let out_b = prog.layers[last].out_buf;
            let t0 = profiler.as_ref().map(|_| Instant::now());
            if let FStep::Single { .. } = step {
                let mut dst = std::mem::take(&mut bbufs[out_b]);
                run_step_batch(prog, step, &bbufs[in_b], &mut dst, b, bp, bmid, bacc);
                if let Some(mg) = &prog.layers[last].merge {
                    let ol = prog.layers[last].out_len;
                    apply_merge_batch(mg, &bbufs[mg.other_buf], &mut dst, ol, b, bp);
                }
                bbufs[out_b] = dst;
            } else {
                run_step_batch(prog, step, &bbufs[in_b], btmp, b, bp, bmid, bacc);
                std::mem::swap(&mut bbufs[out_b], btmp);
            }
            if let (Some(p), Some(t0)) = (profiler.as_deref(), t0) {
                p.record(first, t0.elapsed().as_nanos() as u64);
            }
        }
        let res: &[T] = &bbufs[prog.out_buf][..prog.out_len * bp];
        let mut outs = vec![Vec::with_capacity(prog.out_len); b];
        for pos in 0..prog.out_len {
            let lanes = &res[pos * bp..pos * bp + b];
            for (out, &v) in outs.iter_mut().zip(lanes) {
                out.push(v.to_i64());
            }
        }
        Ok(outs)
    }
}

#[derive(Debug, Clone)]
enum FInner {
    Narrow(FoldedEngine<i32>),
    Wide(FoldedEngine<i64>),
}

/// The rate-aware folded value engine (DESIGN.md §9): the compiled
/// lowering plus the folding pass that fuses consecutive low-rate layers
/// into single-traversal steps and register-blocks what stays unfused.
/// Bit-identical to [`CompiledPipeline`] and the interpreter on every
/// frame; `fold_factors` come from `flow`'s Eq.-8 rate analysis.
#[derive(Debug, Clone)]
pub struct FoldedPipeline {
    inner: FInner,
}

impl FoldedPipeline {
    /// Lower a quantized model with its per-layer Eq.-8 fold factors
    /// (`folds[i]` = layer i's output pixel period over the source pixel
    /// period; 1 = full rate). Width selection (narrow vs wide) is the
    /// same bound analysis as [`CompiledPipeline::lower`].
    pub fn lower(qm: &QModel, folds: &[u64]) -> Result<FoldedPipeline, String> {
        let inner = if narrow_safe(qm)? {
            FInner::Narrow(FoldedEngine::build(qm, folds)?)
        } else {
            FInner::Wide(FoldedEngine::build(qm, folds)?)
        };
        Ok(FoldedPipeline { inner })
    }

    /// Run one frame; bit-identical to [`CompiledPipeline::execute`].
    pub fn execute(&mut self, frame: &[i64]) -> Result<&[i64], String> {
        match &mut self.inner {
            FInner::Narrow(e) => e.execute(frame),
            FInner::Wide(e) => e.execute(frame),
        }
    }

    /// Run a batch; bit-identical to [`CompiledPipeline::execute_batch`].
    pub fn execute_batch(&mut self, frames: &[&[i64]]) -> Result<Vec<Vec<i64>>, String> {
        match &mut self.inner {
            FInner::Narrow(e) => e.execute_batch(frames),
            FInner::Wide(e) => e.execute_batch(frames),
        }
    }

    /// Batched path minus per-frame screening — callers must have screened
    /// every frame with [`FoldedPipeline::validate_frame`] already.
    pub(crate) fn execute_batch_prevalidated(
        &mut self,
        frames: &[&[i64]],
    ) -> Result<Vec<Vec<i64>>, String> {
        match &mut self.inner {
            FInner::Narrow(e) => e.execute_batch_prevalidated(frames),
            FInner::Wide(e) => e.execute_batch_prevalidated(frames),
        }
    }

    /// Same input contract as [`CompiledPipeline::validate_frame`].
    pub fn validate_frame(&self, frame: &[i64]) -> Result<(), String> {
        match &self.inner {
            FInner::Narrow(e) => validate(&e.prog, frame),
            FInner::Wide(e) => validate(&e.prog, frame),
        }
    }

    pub fn is_narrow(&self) -> bool {
        matches!(self.inner, FInner::Narrow(_))
    }

    pub fn input_len(&self) -> usize {
        match &self.inner {
            FInner::Narrow(e) => e.prog.in_len,
            FInner::Wide(e) => e.prog.in_len,
        }
    }

    pub fn output_len(&self) -> usize {
        match &self.inner {
            FInner::Narrow(e) => e.prog.out_len,
            FInner::Wide(e) => e.prog.out_len,
        }
    }

    /// The per-layer kernel-selection table the folding pass produced.
    pub fn kernel_table(&self) -> &[KernelChoice] {
        match &self.inner {
            FInner::Narrow(e) => &e.table,
            FInner::Wide(e) => &e.table,
        }
    }

    /// How many fused (two-layer, single-traversal) steps the plan holds.
    pub fn fused_steps(&self) -> usize {
        let steps: &[FStep] = match &self.inner {
            FInner::Narrow(e) => &e.steps,
            FInner::Wide(e) => &e.steps,
        };
        steps
            .iter()
            .filter(|s| matches!(s, FStep::FusedPw { .. } | FStep::FusedDense { .. }))
            .count()
    }

    /// Attach (or detach with `None`) a per-layer profiler; fused steps
    /// book their whole step under the step's first layer. Timing-only —
    /// see [`CompiledPipeline::set_profiler`].
    pub fn set_profiler(&mut self, profiler: Option<Arc<LayerProfiler>>) {
        match &mut self.inner {
            FInner::Narrow(e) => e.profiler = profiler,
            FInner::Wide(e) => e.profiler = profiler,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QLayer;
    use crate::sim::pipeline::PipelineSim;
    use crate::util::Rng;

    fn rand_frame(rng: &mut Rng, n: usize) -> Vec<i64> {
        (0..n).map(|_| rng.int8() as i64).collect()
    }

    /// conv -> dwconv -> avgpool -> maxpool -> dense, exercising every
    /// lowered kind in one chain (8x8x1 input).
    fn mixed_qmodel(seed: u64) -> QModel {
        let mut rng = Rng::new(seed);
        let mut wq = |n: usize| -> Vec<i64> {
            (0..n).map(|_| rng.int8() as i64 / 16).collect()
        };
        let conv = QLayer {
            name: "C1".into(),
            kind: QKind::Conv,
            k: 3,
            s: 1,
            p: 1,
            relu: true,
            w_q: wq(3 * 3 * 4),
            w_shape: vec![3, 3, 1, 4],
            b_q: vec![1, -2, 3, 0],
            m: 0.04,
            in_shape: [8, 8, 1],
            out_shape: [8, 8, 4],
        };
        let dw = QLayer {
            name: "DW".into(),
            kind: QKind::DwConv,
            k: 3,
            s: 1,
            p: 1,
            relu: true,
            w_q: wq(3 * 3 * 4),
            w_shape: vec![3, 3, 4],
            b_q: vec![0, 1, -1, 2],
            m: 0.03,
            in_shape: [8, 8, 4],
            out_shape: [8, 8, 4],
        };
        let avg = QLayer {
            name: "AP".into(),
            kind: QKind::AvgPool,
            k: 2,
            s: 2,
            p: 0,
            relu: false,
            w_q: vec![1; 2 * 2 * 4],
            w_shape: vec![2, 2, 4],
            b_q: vec![0, 0, 0, 0],
            m: 0.2,
            in_shape: [8, 8, 4],
            out_shape: [4, 4, 4],
        };
        let pool = QLayer {
            name: "P1".into(),
            kind: QKind::MaxPool,
            k: 2,
            s: 2,
            p: 0,
            relu: false,
            w_q: vec![],
            w_shape: vec![],
            b_q: vec![],
            m: 0.0,
            in_shape: [4, 4, 4],
            out_shape: [2, 2, 4],
        };
        let dense = QLayer {
            name: "F1".into(),
            kind: QKind::Dense,
            k: 0,
            s: 1,
            p: 0,
            relu: false,
            w_q: wq(5 * 16),
            w_shape: vec![5, 16],
            b_q: vec![1, 2, 3, 4, 5],
            m: 0.0,
            in_shape: [1, 1, 16],
            out_shape: [1, 1, 5],
        };
        QModel {
            name: "mixed".into(),
            input_shape: [8, 8, 1],
            input_scale: 1.0,
            layers: vec![conv, dw, avg, pool, dense],
            topology: vec![],
            test_vectors: vec![],
            qat_accuracy: 1.0,
        }
    }

    /// Chained non-requantized (m = 0) conv layers inflate the activation
    /// bound until the dense head's accumulator exceeds i32, forcing the
    /// 64-bit program.
    fn wide_qmodel() -> QModel {
        let big = |n: usize| -> Vec<i64> { vec![100; n] };
        let mk_conv = |name: &str, m: f32| QLayer {
            name: name.into(),
            kind: QKind::Conv,
            k: 3,
            s: 1,
            p: 1,
            relu: false,
            w_q: big(3 * 3 * 2 * 2),
            w_shape: vec![3, 3, 2, 2],
            b_q: vec![0, 0],
            m,
            in_shape: [4, 4, 2],
            out_shape: [4, 4, 2],
        };
        QModel {
            name: "wide".into(),
            input_shape: [4, 4, 2],
            input_scale: 1.0,
            layers: vec![
                mk_conv("W1", 0.0),
                mk_conv("W2", 0.0),
                QLayer {
                    name: "F".into(),
                    kind: QKind::Dense,
                    k: 0,
                    s: 1,
                    p: 0,
                    relu: false,
                    w_q: vec![1; 2 * 32],
                    w_shape: vec![2, 32],
                    b_q: vec![0, 0],
                    m: 0.0,
                    in_shape: [1, 1, 32],
                    out_shape: [1, 1, 2],
                },
            ],
            topology: vec![],
            test_vectors: vec![],
            qat_accuracy: 1.0,
        }
    }

    #[test]
    fn mixed_model_matches_interpreter() {
        let qm = mixed_qmodel(7);
        let sim = PipelineSim::new(qm.clone(), None).unwrap();
        let mut engine = CompiledPipeline::lower(&qm).unwrap();
        assert!(engine.is_narrow(), "small int8 model must lower narrow");
        let mut rng = Rng::new(8);
        for _ in 0..12 {
            let x = rand_frame(&mut rng, 64);
            let want = sim.run_interpreted(&[x.clone()]).unwrap().outputs[0].clone();
            let got = engine.execute(&x).unwrap().to_vec();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn synthetic_fixture_matches_interpreter() {
        let qm = QModel::synthetic(8, 4, 6, 0xC0);
        let sim = PipelineSim::new(qm.clone(), None).unwrap();
        let mut engine = CompiledPipeline::lower(&qm).unwrap();
        assert_eq!(engine.input_len(), 64);
        assert_eq!(engine.output_len(), 6);
        let mut rng = Rng::new(0xC1);
        for _ in 0..8 {
            let x = rand_frame(&mut rng, 64);
            let want = sim.run_interpreted(&[x.clone()]).unwrap().outputs[0].clone();
            assert_eq!(engine.execute(&x).unwrap(), &want[..]);
        }
    }

    #[test]
    fn wide_path_selected_and_bit_identical() {
        let qm = wide_qmodel();
        let mut engine = CompiledPipeline::lower(&qm).unwrap();
        assert!(!engine.is_narrow(), "m=0 chain must force the i64 path");
        let sim = PipelineSim::new(qm, None).unwrap();
        let mut rng = Rng::new(3);
        let x = rand_frame(&mut rng, 32);
        let want = sim.run_interpreted(&[x.clone()]).unwrap().outputs[0].clone();
        assert_eq!(engine.execute(&x).unwrap(), &want[..]);
    }

    #[test]
    fn rejects_bad_frames() {
        let qm = QModel::synthetic(8, 4, 6, 1);
        let mut engine = CompiledPipeline::lower(&qm).unwrap();
        assert!(engine.execute(&[0; 7]).is_err(), "wrong length");
        let mut big = vec![0i64; 64];
        big[5] = 4096; // outside the int8 grid a narrow engine is proven for
        assert!(engine.is_narrow());
        assert!(engine.execute(&big).is_err());
    }

    #[test]
    fn clones_are_independent() {
        let qm = QModel::synthetic(8, 4, 6, 2);
        let mut a = CompiledPipeline::lower(&qm).unwrap();
        let mut b = a.clone();
        let mut rng = Rng::new(4);
        let x = rand_frame(&mut rng, 64);
        let y = rand_frame(&mut rng, 64);
        let ax = a.execute(&x).unwrap().to_vec();
        let _ = b.execute(&y).unwrap();
        assert_eq!(a.execute(&x).unwrap(), &ax[..], "scratch must not leak");
    }

    #[test]
    fn rejects_inconsistent_shape_chain() {
        let mut qm = QModel::synthetic(8, 4, 6, 3);
        qm.layers[1].in_shape = [9, 9, 4];
        assert!(CompiledPipeline::lower(&qm).is_err());
    }

    /// THE batched-tier contract: every batch size (full tiles, tail
    /// tiles, the B = 1 scalar dispatch) is bit-identical per frame to
    /// `execute`, on a model exercising every lowered kind.
    #[test]
    fn execute_batch_matches_execute_per_frame() {
        let qm = mixed_qmodel(19);
        let mut engine = CompiledPipeline::lower(&qm).unwrap();
        let mut rng = Rng::new(20);
        for b in [1usize, 2, 3, 7, 8, 9, 15, 16, 33] {
            let frames: Vec<Vec<i64>> = (0..b).map(|_| rand_frame(&mut rng, 64)).collect();
            let want: Vec<Vec<i64>> = frames
                .iter()
                .map(|f| engine.execute(f).unwrap().to_vec())
                .collect();
            let refs: Vec<&[i64]> = frames.iter().map(|f| f.as_slice()).collect();
            let got = engine.execute_batch(&refs).unwrap();
            assert_eq!(got, want, "batch size {b} diverged");
        }
    }

    #[test]
    fn execute_batch_wide_path_matches() {
        let qm = wide_qmodel();
        let mut engine = CompiledPipeline::lower(&qm).unwrap();
        assert!(!engine.is_narrow());
        let mut rng = Rng::new(21);
        let frames: Vec<Vec<i64>> = (0..5).map(|_| rand_frame(&mut rng, 32)).collect();
        let want: Vec<Vec<i64>> = frames
            .iter()
            .map(|f| engine.execute(f).unwrap().to_vec())
            .collect();
        let refs: Vec<&[i64]> = frames.iter().map(|f| f.as_slice()).collect();
        assert_eq!(engine.execute_batch(&refs).unwrap(), want);
    }

    #[test]
    fn execute_batch_rejects_any_malformed_frame() {
        let qm = QModel::synthetic(8, 4, 6, 5);
        let mut engine = CompiledPipeline::lower(&qm).unwrap();
        assert!(engine.execute_batch(&[]).unwrap().is_empty());
        let good = vec![1i64; 64];
        let short = vec![1i64; 7];
        let err = engine.execute_batch(&[good.as_slice(), short.as_slice()]).unwrap_err();
        assert!(err.contains("batch frame 1"), "{err}");
        let mut big = vec![0i64; 64];
        big[3] = 4096;
        assert!(engine.is_narrow());
        assert!(engine
            .execute_batch(&[good.as_slice(), big.as_slice(), good.as_slice()])
            .is_err());
    }

    #[test]
    fn validate_frame_mirrors_execute_screening() {
        let qm = QModel::synthetic(8, 4, 6, 6);
        let engine = CompiledPipeline::lower(&qm).unwrap();
        let zeros = vec![0i64; 64];
        assert!(engine.validate_frame(&zeros).is_ok());
        assert!(engine.validate_frame(&[0; 7]).is_err());
        let mut big = vec![0i64; 64];
        big[0] = 1 << 20;
        assert!(engine.is_narrow());
        assert!(engine.validate_frame(&big).is_err());
    }

    fn kernels_of(engine: &FoldedPipeline) -> Vec<KernelSel> {
        engine.kernel_table().iter().map(|c| c.kernel).collect()
    }

    #[test]
    fn folded_rejects_fold_vector_length_mismatch() {
        let qm = mixed_qmodel(30);
        let err = FoldedPipeline::lower(&qm, &[1, 1]).unwrap_err();
        assert!(err.contains("fold factors"), "{err}");
    }

    /// Low-rate pool → dense tail fuses into one traversal (the maxpool
    /// maxima never touch the activation buffer), and the fused step is
    /// bit-identical to the unfolded engine.
    #[test]
    fn folded_fuses_dense_head_and_matches_compiled() {
        let qm = mixed_qmodel(31);
        let folds = [1, 1, 4, 16, 64];
        let mut folded = FoldedPipeline::lower(&qm, &folds).unwrap();
        assert!(folded.is_narrow());
        assert_eq!(folded.fused_steps(), 1);
        assert_eq!(
            kernels_of(&folded),
            [
                KernelSel::ZeroSkip,
                KernelSel::ZeroSkip,
                KernelSel::ZeroSkip,
                KernelSel::FusedDense,
                KernelSel::FusedDense,
            ]
        );
        let mut oracle = CompiledPipeline::lower(&qm).unwrap();
        let mut rng = Rng::new(32);
        for _ in 0..10 {
            let x = rand_frame(&mut rng, 64);
            assert_eq!(folded.execute(&x).unwrap(), oracle.execute(&x).unwrap());
        }
    }

    /// The folded batched tier: every batch size (full tiles, ragged
    /// tails, the B = 1 dispatch) matches the unfolded engine per frame.
    #[test]
    fn folded_batch_matches_compiled_across_sizes() {
        let qm = mixed_qmodel(33);
        let mut folded = FoldedPipeline::lower(&qm, &[1, 1, 4, 16, 64]).unwrap();
        let mut oracle = CompiledPipeline::lower(&qm).unwrap();
        let mut rng = Rng::new(34);
        for b in [1usize, 2, 3, 7, 8, 9, 15, 16, 33] {
            let frames: Vec<Vec<i64>> = (0..b).map(|_| rand_frame(&mut rng, 64)).collect();
            let refs: Vec<&[i64]> = frames.iter().map(|f| f.as_slice()).collect();
            let want = oracle.execute_batch(&refs).unwrap();
            let got = folded.execute_batch(&refs).unwrap();
            assert_eq!(got, want, "folded batch size {b} diverged");
        }
    }

    /// Conv → dense fusion on the i64 path (the conv producer's window
    /// accumulation feeds the dense accumulators from registers).
    #[test]
    fn folded_wide_path_fuses_and_matches() {
        let qm = wide_qmodel();
        let mut folded = FoldedPipeline::lower(&qm, &[4, 4, 4]).unwrap();
        assert!(!folded.is_narrow(), "m=0 chain must force the i64 path");
        assert_eq!(folded.fused_steps(), 1);
        assert_eq!(
            kernels_of(&folded),
            [
                KernelSel::ZeroSkip,
                KernelSel::FusedDense,
                KernelSel::FusedDense,
            ]
        );
        let mut oracle = CompiledPipeline::lower(&qm).unwrap();
        let mut rng = Rng::new(35);
        let frames: Vec<Vec<i64>> = (0..9).map(|_| rand_frame(&mut rng, 32)).collect();
        let refs: Vec<&[i64]> = frames.iter().map(|f| f.as_slice()).collect();
        assert_eq!(
            folded.execute_batch(&refs).unwrap(),
            oracle.execute_batch(&refs).unwrap()
        );
        assert_eq!(
            folded.execute(&frames[0]).unwrap(),
            oracle.execute(&frames[0]).unwrap()
        );
    }

    /// Unfused low-rate MAC layers with >= CHUNK output channels route to
    /// the register-blocked kernel (conv and dense here), bit-identically.
    #[test]
    fn folded_blocked_kernels_selected_and_bit_identical() {
        let qm = QModel::synthetic(12, 8, 10, 0x51);
        let mut folded = FoldedPipeline::lower(&qm, &[2, 1, 4]).unwrap();
        assert_eq!(folded.fused_steps(), 0);
        assert_eq!(
            kernels_of(&folded),
            [KernelSel::Blocked, KernelSel::ZeroSkip, KernelSel::Blocked]
        );
        let mut oracle = CompiledPipeline::lower(&qm).unwrap();
        let mut rng = Rng::new(0x52);
        let frames: Vec<Vec<i64>> = (0..9).map(|_| rand_frame(&mut rng, 144)).collect();
        let refs: Vec<&[i64]> = frames.iter().map(|f| f.as_slice()).collect();
        assert_eq!(
            folded.execute_batch(&refs).unwrap(),
            oracle.execute_batch(&refs).unwrap()
        );
        for f in &frames {
            assert_eq!(folded.execute(f).unwrap(), oracle.execute(f).unwrap());
        }
    }

    /// The full rate-aware path on the MobileNet-style zoo config: the
    /// Eq.-8 analysis folds the post-stride tail, so dw2+pw2 and dw3+pw3
    /// fuse pairwise and the pool feeds the dense head from registers.
    #[test]
    fn mobilenet_rate_folding_shape_and_equivalence() {
        let model = crate::model::zoo::mobilenet_micro();
        let qm = QModel::synthesize(&model, 0x777).unwrap();
        let sim = PipelineSim::new(qm.clone(), None).unwrap();
        assert_eq!(sim.folded.fused_steps(), 3);
        let table = sim.folded.kernel_table();
        let got: Vec<(&str, KernelSel)> = table
            .iter()
            .map(|c| (c.layer.as_str(), c.kernel))
            .collect();
        assert_eq!(
            got,
            [
                ("c1", KernelSel::ZeroSkip),
                ("dw1", KernelSel::ZeroSkip),
                ("pw1", KernelSel::ZeroSkip),
                ("dw2", KernelSel::FusedPw),
                ("pw2", KernelSel::FusedPw),
                ("dw3", KernelSel::FusedPw),
                ("pw3", KernelSel::FusedPw),
                ("ap", KernelSel::FusedDense),
                ("fc", KernelSel::FusedDense),
            ]
        );
        // Fold factors in the table are the raw Eq.-8 periods relative to
        // the source: monotone non-decreasing down the stride-2 tail.
        assert!(table.windows(2).all(|w| w[0].fold <= w[1].fold));
        assert_eq!(table[0].fold, 1);
        assert!(table.last().unwrap().fold > table[3].fold);
        let mut folded = sim.folded.clone();
        let mut oracle = CompiledPipeline::lower(&qm).unwrap();
        let len: usize = qm.input_shape.iter().product();
        let mut rng = Rng::new(0x778);
        let frames: Vec<Vec<i64>> = (0..11).map(|_| rand_frame(&mut rng, len)).collect();
        let refs: Vec<&[i64]> = frames.iter().map(|f| f.as_slice()).collect();
        assert_eq!(
            folded.execute_batch(&refs).unwrap(),
            oracle.execute_batch(&refs).unwrap()
        );
        for f in &frames {
            assert_eq!(folded.execute(f).unwrap(), oracle.execute(f).unwrap());
        }
    }

    /// A depthwise layer left unfused by a full-rate pointwise successor
    /// still register-blocks when its own rate is low.
    #[test]
    fn folded_blocked_depthwise_bit_identical() {
        let model = crate::model::zoo::mobilenet_micro();
        let qm = QModel::synthesize(&model, 0x779).unwrap();
        let folds = [1, 4, 1, 1, 1, 1, 1, 1, 1];
        let mut folded = FoldedPipeline::lower(&qm, &folds).unwrap();
        assert_eq!(folded.fused_steps(), 0);
        assert_eq!(folded.kernel_table()[1].kernel, KernelSel::Blocked);
        let mut oracle = CompiledPipeline::lower(&qm).unwrap();
        let len: usize = qm.input_shape.iter().product();
        let mut rng = Rng::new(0x77A);
        let frames: Vec<Vec<i64>> = (0..5).map(|_| rand_frame(&mut rng, len)).collect();
        let refs: Vec<&[i64]> = frames.iter().map(|f| f.as_slice()).collect();
        assert_eq!(
            folded.execute_batch(&refs).unwrap(),
            oracle.execute_batch(&refs).unwrap()
        );
    }

    #[test]
    fn folded_clones_are_independent() {
        let qm = mixed_qmodel(36);
        let mut a = FoldedPipeline::lower(&qm, &[1, 1, 4, 16, 64]).unwrap();
        let mut b = a.clone();
        let mut rng = Rng::new(37);
        let x = rand_frame(&mut rng, 64);
        let y = rand_frame(&mut rng, 64);
        let ax = a.execute(&x).unwrap().to_vec();
        let _ = b.execute(&y).unwrap();
        assert_eq!(a.execute(&x).unwrap(), &ax[..], "scratch must not leak");
    }
}
