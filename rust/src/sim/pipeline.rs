//! Whole-CNN continuous-flow pipeline simulator (system S6).
//!
//! Two concerns, deliberately layered (DESIGN.md §4):
//!
//! * **values** — bit-exact int8 inference replaying the quantization
//!   semantics of `python/compile/quantize.py`; integration tests require
//!   *equality* with the JAX int8 golden model (and with the PJRT-executed
//!   HLO artifact);
//! * **cycles** — a schedule-exact model of the continuous-flow
//!   architecture: every layer consumes interleaved input pixels at its
//!   planned rate (Eq. 8), units execute one kernel-dot / window-op /
//!   weighted-sum per cycle, and per-layer utilisation is measured, which
//!   is how the paper's "close to 100% utilization" claim is validated
//!   (the micro-timing of individual units is proven separately by
//!   `sim::trace` against Tables I-IV).
//!
//! The same simulator runs the fully-parallel reference plan (one unit per
//! kernel/neuron) for the utilisation comparison of Table VIII.
//!
//! Since the compile-once refactor the two concerns are also *executed*
//! separately: [`PipelineSim::run`] computes values on the lowered
//! [`super::compiled::CompiledPipeline`] and cycles on the analytic
//! [`crate::flow::schedule::ScheduleModel`], while
//! [`PipelineSim::run_interpreted`] keeps the original fused loop as the
//! oracle both tiers are property-tested against (`tests/prop_compiled.rs`).

use super::compiled::{CompiledPipeline, FoldedPipeline};
use crate::flow::schedule::{steady_cycles_per_frame, ScheduleModel, SchedulePrediction, LAT_MERGE};
use crate::flow::{
    analyze, analyze_dag, fold_factor, fold_plan, pixel_period, plan_all, PlannedLayer,
    RateAnalysis, Ratio, UnitPlan,
};
use crate::model::{Layer, MergeLink, Model, NodeLink, Shape, ShapedLayer};
use crate::quant::{requant, QKind, QLayer, QModel};

/// Per-layer schedule statistics for one simulation run.
#[derive(Debug, Clone)]
pub struct LayerStats {
    pub name: String,
    pub units: usize,
    pub unit_kind: &'static str,
    /// Useful operations executed (kernel dots / window ops / MAC groups).
    pub useful_ops: u64,
    /// First cycle with work and last completion cycle.
    pub first_cycle: u64,
    pub last_cycle: u64,
    /// useful_ops / (units * elapsed).
    pub utilization: f64,
}

/// Result of simulating one or more frames.
#[derive(Debug, Clone)]
pub struct PipelineResult {
    /// Final-layer outputs per frame (accumulator scale, matching
    /// `forward_int8`).
    pub outputs: Vec<Vec<i64>>,
    pub stats: Vec<LayerStats>,
    /// Cycle at which the last output of the last frame completed.
    pub total_cycles: u64,
    /// Latency of frame 0: input cycle 0 -> last output cycle.
    pub first_frame_latency: u64,
    /// Cycles per frame in steady state (throughput), measured after a
    /// one-frame warm-up (see `flow::schedule::steady_cycles_per_frame`).
    pub cycles_per_frame: f64,
}

/// One quantized layer back in the analysis IR (pointwise layers were
/// lowered to 1x1 convs by `QModel::synthesize`, so they stay convs here).
fn qlayer_to_layer(l: &QLayer) -> Layer {
    let layer = match l.kind {
        QKind::Conv => Layer::conv(&l.name, l.k, l.s, l.p, l.out_shape[2]),
        QKind::DwConv => Layer::dwconv(&l.name, l.k, l.s, l.p),
        QKind::MaxPool => Layer::maxpool_padded(&l.name, l.k, l.s, l.p),
        QKind::AvgPool => Layer::avgpool(&l.name, l.k, l.s),
        QKind::Dense => Layer::dense(&l.name, l.out_shape[2]),
    };
    if l.relu {
        layer
    } else {
        layer.no_relu()
    }
}

/// Convert a quantized model into the analysis IR (for rate planning).
/// Chain view only — residual topology travels separately via
/// [`qmodel_links`].
pub fn qmodel_to_model(qm: &QModel) -> Model {
    let mut m = Model::new(&qm.name, qm.input_shape[0].max(1), qm.input_shape[2]);
    for l in &qm.layers {
        m.push(qlayer_to_layer(l));
    }
    m
}

/// The flat dataflow links of a quantized model, in layer order — the
/// bridge from [`QModel::node_topology`] to the DAG-aware rate analysis
/// and schedule model.
pub fn qmodel_links(qm: &QModel) -> Vec<NodeLink> {
    qm.node_topology()
        .iter()
        .map(|t| NodeLink {
            src: t.src,
            merge: t.merge.map(|m| MergeLink {
                with: m.with,
                post_relu: m.relu,
            }),
        })
        .collect()
}

/// Resolved shapes for the DAG rate analysis: every quantized layer
/// already carries its own in/out shapes, so no chain propagation is
/// needed. `merges` marks the two branches feeding each residual adder
/// (complexity accounting counts one adder per physical output there).
fn qmodel_shaped(qm: &QModel) -> Vec<ShapedLayer> {
    let topo = qm.node_topology();
    qm.layers
        .iter()
        .enumerate()
        .map(|(i, l)| ShapedLayer {
            layer: qlayer_to_layer(l),
            input: Shape {
                f: l.in_shape[0].max(1),
                d: l.in_shape[2],
            },
            output: Shape {
                f: l.out_shape[0].max(1),
                d: l.out_shape[2],
            },
            merges: topo[i].merge.is_some()
                || topo
                    .iter()
                    .any(|t| matches!(&t.merge, Some(m) if m.with == Some(i))),
        })
        .collect()
}

/// The pipeline simulator: a quantized model plus a unit plan, lowered
/// once at construction into the two-tier execution engine (DESIGN.md §4):
///
/// * [`CompiledPipeline`] — the flat value engine [`PipelineSim::run`]
///   executes frames on (bit-identical to the interpreter);
/// * [`ScheduleModel`] / [`SchedulePrediction`] — the value-free cycle
///   replay and its closed form, replacing the fused loop's bookkeeping;
/// * [`PipelineSim::run_interpreted`] — the original fused
///   pixel-by-pixel interpreter, retained as the oracle the compiled
///   tiers are property-tested against.
///
/// `Clone + Send` by construction (all state is owned): the sharded
/// coordinator plans and lowers once, then hands each worker shard its
/// own clone, so shards execute concurrently without sharing mutable
/// state — and without re-planning.
#[derive(Clone)]
pub struct PipelineSim {
    pub qmodel: QModel,
    pub plans: Vec<PlannedLayer>,
    pub fully_parallel: bool,
    /// Lowered value engine (clone it to execute; see [`CompiledPipeline`]).
    pub compiled: CompiledPipeline,
    /// Exact value-free replay of the interpreter's cycle schedule.
    pub schedule: ScheduleModel,
    /// Closed-form schedule figures for the serving hot path.
    pub predicted: SchedulePrediction,
    /// Rate-aware folded value engine (DESIGN.md §9) — bit-identical to
    /// `compiled`, but consecutive low-rate layers run fused and unfused
    /// low-rate MAC layers run register-blocked.
    pub folded: FoldedPipeline,
    /// Plan-relative Eq.-8 fold factors (`flow::fold_plan`): the rate
    /// slack the planner's interleaving left unabsorbed, per layer. Feeds
    /// `SchedulePrediction::folded` for certified folded cycle figures.
    pub fold_factors: Vec<u64>,
    /// Per-merge skip-FIFO depths `(merge layer index, depth)` from an
    /// assemble-time schedule replay — the delay-balancing FIFO sizing of
    /// DESIGN.md §11. Empty for chain models.
    pub skip_fifo_depths: Vec<(usize, usize)>,
}

impl PipelineSim {
    /// Plan at input rate `r0` (None = full rate d0).
    pub fn new(qmodel: QModel, r0: Option<Ratio>) -> Result<Self, String> {
        let analysis = Self::analysis_of(&qmodel, r0)?;
        let plans = plan_all(&analysis);
        Self::assemble(qmodel, plans, false)
    }

    /// Fully-parallel reference plan (Table VIII "Ref.").
    pub fn new_reference(qmodel: QModel) -> Result<Self, String> {
        let analysis = Self::analysis_of(&qmodel, None)?;
        let plans = crate::complexity::parallel::fully_parallel_plan(&analysis);
        Self::assemble(qmodel, plans, true)
    }

    /// Eq.-8 rate analysis for a quantized model: chains go through the
    /// recursive block walk ([`analyze`]); residual graphs through the
    /// flat DAG propagation ([`analyze_dag`]) over the stored topology.
    fn analysis_of(qm: &QModel, r0: Option<Ratio>) -> Result<RateAnalysis, String> {
        if qm.is_chain() {
            let model = qmodel_to_model(qm);
            analyze(&model, r0).map_err(|e| e.to_string())
        } else {
            let r0 = r0.unwrap_or_else(|| Ratio::int(qm.input_shape[2] as u64));
            Ok(analyze_dag(
                &qm.name,
                qmodel_shaped(qm),
                &qmodel_links(qm),
                r0,
            ))
        }
    }

    /// Lower the planned model into the compiled value engine and the
    /// analytic schedule — the compile-once step every constructor funnels
    /// through.
    fn assemble(
        qmodel: QModel,
        plans: Vec<PlannedLayer>,
        fully_parallel: bool,
    ) -> Result<Self, String> {
        let compiled = CompiledPipeline::lower(&qmodel)?;
        // Raw Eq.-8 fold factors: each layer's output pixel period over
        // the source pixel period — what the folded engine keys fusion and
        // kernel selection on (the planner's interleaving is irrelevant to
        // the software lowering, so it is *not* divided out here).
        let rate_folds: Vec<u64> = match plans.first() {
            Some(first) if !first.rated.r_in.is_zero() => {
                let src = pixel_period(first.rated.d_in(), first.rated.r_in);
                plans
                    .iter()
                    .map(|p| {
                        if p.rated.r_out.is_zero() {
                            1
                        } else {
                            fold_factor(pixel_period(p.rated.d_out(), p.rated.r_out), src)
                        }
                    })
                    .collect()
            }
            _ => vec![1; plans.len()],
        };
        let folded = FoldedPipeline::lower(&qmodel, &rate_folds)?;
        let fold_factors = fold_plan(&plans);
        let [h0, w0, c0] = qmodel.input_shape;
        let links = qmodel_links(&qmodel);
        let schedule = ScheduleModel::with_links(&plans, (h0.max(1), w0.max(1)), c0, &links)
            .map_err(|e| e.to_string())?;
        let predicted = SchedulePrediction::new(&schedule);
        // Skip-FIFO sizing (DESIGN.md §11): replay a short steady stream
        // and take each merge's peak shortcut occupancy as the depth the
        // delay-balancing FIFO must provision.
        let skip_fifo_depths: Vec<(usize, usize)> = if qmodel.is_chain() {
            Vec::new()
        } else {
            schedule
                .run(8)
                .merge_fifo
                .iter()
                .map(|f| (f.layer, f.max_occupancy))
                .collect()
        };
        Ok(Self {
            qmodel,
            plans,
            fully_parallel,
            compiled,
            schedule,
            predicted,
            folded,
            fold_factors,
            skip_fifo_depths,
        })
    }

    /// Flattened input frame length (HWC) the engines expect — what the
    /// serving registry, load generators and CLI size their frames to.
    pub fn input_len(&self) -> usize {
        self.qmodel.input_shape.iter().map(|&d| d.max(1)).product()
    }

    /// Simulate `frames` (each a flat x_q of the model's input shape, HWC
    /// row-major, int8-valued): values via the compiled engine's batched
    /// tier (one program traversal for the whole stream), cycles via the
    /// analytic schedule replay. Bit- and cycle-identical to
    /// [`PipelineSim::run_interpreted`] (property-tested), but without
    /// re-deriving window indices, weight lookups, or schedule state per
    /// pixel.
    pub fn run(&self, frames: &[Vec<i64>]) -> Result<PipelineResult, String> {
        let in_len = self.input_len();
        for (i, f) in frames.iter().enumerate() {
            if f.len() != in_len {
                return Err(format!("frame {i}: len {} != {in_len}", f.len()));
            }
        }
        let mut engine = self.compiled.clone();
        // Fixed-size batches keep the lane-interleaved scratch bounded on
        // long streams (it scales with the batch size); per-frame values
        // are independent, so chunking never changes them.
        let mut outputs = Vec::with_capacity(frames.len());
        for chunk in frames.chunks(64) {
            let refs: Vec<&[i64]> = chunk.iter().map(|f| f.as_slice()).collect();
            outputs.extend(engine.execute_batch(&refs)?);
        }
        let sched = self.schedule.run(frames.len());
        let stats = sched
            .stats
            .into_iter()
            .map(|s| LayerStats {
                name: s.name,
                units: s.units,
                unit_kind: s.unit_kind,
                useful_ops: s.useful_ops,
                first_cycle: s.first_cycle,
                last_cycle: s.last_cycle,
                utilization: s.utilization,
            })
            .collect();
        Ok(PipelineResult {
            outputs,
            stats,
            total_cycles: sched.total_cycles,
            first_frame_latency: sched.first_frame_latency,
            cycles_per_frame: sched.cycles_per_frame,
        })
    }

    /// The original fused interpreter: values and cycles re-derived
    /// pixel-by-pixel in one loop. Retained as the oracle for the
    /// compiled engine and the schedule model (and for engine comparison
    /// in serving); `run` is the fast path.
    pub fn run_interpreted(&self, frames: &[Vec<i64>]) -> Result<PipelineResult, String> {
        let [h0, w0, c0] = self.qmodel.input_shape;
        let in_len = h0.max(1) * w0.max(1) * c0;
        for (i, f) in frames.iter().enumerate() {
            if f.len() != in_len {
                return Err(format!("frame {i}: len {} != {in_len}", f.len()));
            }
        }
        let mut stats: Vec<LayerStats> = Vec::new();

        // --- Source schedule -------------------------------------------
        // Pixel m's last feature arrives at ceil((m+1) * d0 / r0) - 1.
        // With a padded first conv, each frame is followed by the p*f + p
        // zero-feed rows of Section III-B (shared top/bottom padding).
        let r0 = self.plans[0].rated.r_in;
        let first = &self.qmodel.layers[0];
        let frame_pixels = h0.max(1) * w0.max(1);
        let gap_pixels = if first.p > 0 {
            first.p * w0.max(1) + first.p
        } else {
            0
        };
        let pixel_cycles = |i: u64| -> u64 {
            // cycle when the i-th pixel's last feature has arrived
            ((i + 1) * c0 as u64 * r0.den()).div_ceil(r0.num()) - 1
        };
        let mut in_cycles: Vec<Vec<u64>> = Vec::with_capacity(frames.len());
        for fi in 0..frames.len() {
            let base = (fi * (frame_pixels + gap_pixels)) as u64;
            in_cycles.push(
                (0..frame_pixels as u64)
                    .map(|m| pixel_cycles(base + m))
                    .collect(),
            );
        }

        // --- Per-layer streaming ----------------------------------------
        // Streams are kept per node so residual shortcuts can read a
        // branch point after the body has advanced past it; chains visit
        // each node exactly once in order, as the single-map walk did.
        let topo = self.qmodel.node_topology();
        let n = self.qmodel.layers.len();
        let mut node_vals: Vec<Vec<Vec<i64>>> = Vec::with_capacity(n);
        let mut node_outs: Vec<Vec<Vec<u64>>> = Vec::with_capacity(n);
        let mut frame_out_last: Vec<u64> = vec![0; frames.len()];
        for (li, ql) in self.qmodel.layers.iter().enumerate() {
            let plan = &self.plans[li];
            let mut layer_stat = LayerStats {
                name: ql.name.clone(),
                units: plan.plan.unit_count(),
                unit_kind: match plan.plan {
                    UnitPlan::Kpu { .. } => "KPU",
                    UnitPlan::Ppu { .. } => "PPU",
                    UnitPlan::Fcu { .. } => "FCU",
                },
                useful_ops: 0,
                first_cycle: u64::MAX,
                last_cycle: 0,
                utilization: 0.0,
            };
            let mut prev_finish: u64 = 0;
            let mut vals_per_frame: Vec<Vec<i64>> = Vec::with_capacity(frames.len());
            let mut outs_per_frame: Vec<Vec<u64>> = Vec::with_capacity(frames.len());
            for fi in 0..frames.len() {
                let is_last = li + 1 == n;
                let (map, ins): (&[i64], &[u64]) = match topo[li].src {
                    None => (&frames[fi], &in_cycles[fi]),
                    Some(j) => (&node_vals[j][fi], &node_outs[j][fi]),
                };
                let (mut vals, mut outs) = step_layer(
                    ql,
                    plan,
                    map,
                    ins,
                    &mut prev_finish,
                    &mut layer_stat,
                    is_last,
                )?;
                if let Some(mg) = &topo[li].merge {
                    let (ovals, oouts): (&[i64], &[u64]) = match mg.with {
                        None => (&frames[fi], &in_cycles[fi]),
                        Some(j) => (&node_vals[j][fi], &node_outs[j][fi]),
                    };
                    if ovals.len() != vals.len() {
                        return Err(format!(
                            "{}: merge branch len {} != {}",
                            ql.name,
                            ovals.len(),
                            vals.len()
                        ));
                    }
                    // Values: add the shortcut's int8 stream onto this
                    // node's requantized output, optionally ReLU, and
                    // requantize the sum back onto the int8 grid — the
                    // exact epilogue the compiled engines apply.
                    for (v, &o) in vals.iter_mut().zip(ovals) {
                        let mut s = *v + o;
                        if mg.relu {
                            s = s.max(0);
                        }
                        *v = if mg.m != 0.0 { requant(s, mg.m) } else { s };
                    }
                    // Cycles: the merge adder fires once both branch
                    // pixels are available — the earlier one waits in the
                    // delay-balancing skip FIFO, so arrival is the max of
                    // the branches plus the adder stage.
                    for (slot, &arr) in outs.iter_mut().zip(oouts) {
                        let merged = (*slot).max(arr) + LAT_MERGE;
                        layer_stat.last_cycle = layer_stat.last_cycle.max(merged);
                        *slot = merged;
                    }
                }
                frame_out_last[fi] = *outs.last().unwrap_or(&frame_out_last[fi]);
                vals_per_frame.push(vals);
                outs_per_frame.push(outs);
            }
            let elapsed = layer_stat
                .last_cycle
                .saturating_sub(layer_stat.first_cycle)
                .max(1);
            layer_stat.utilization =
                layer_stat.useful_ops as f64 / (layer_stat.units as f64 * elapsed as f64);
            stats.push(layer_stat);
            node_vals.push(vals_per_frame);
            node_outs.push(outs_per_frame);
        }

        let total_cycles = *frame_out_last.last().unwrap_or(&0);
        let first_frame_latency = frame_out_last[0];
        let cycles_per_frame = steady_cycles_per_frame(&frame_out_last);
        let outputs = node_vals.pop().unwrap_or_default();
        Ok(PipelineResult {
            outputs,
            stats,
            total_cycles,
            first_frame_latency,
            cycles_per_frame,
        })
    }
}

/// Stream one frame through one layer: returns (values, out_cycles) with
/// one entry per output pixel (dense: one "pixel" carrying all units).
#[allow(clippy::too_many_arguments)]
fn step_layer(
    ql: &QLayer,
    plan: &PlannedLayer,
    map: &[i64],
    in_cycles: &[u64],
    prev_finish: &mut u64,
    stat: &mut LayerStats,
    is_last: bool,
) -> Result<(Vec<i64>, Vec<u64>), String> {
    let [h_in, w_in, c_in] = ql.in_shape;
    let [h_out, w_out, c_out] = ql.out_shape;

    // Output emission period in cycles per output pixel: d_out / r_out.
    let r_out = plan.rated.r_out;
    let out_period = (c_out as u64 * r_out.den()).div_ceil(r_out.num()).max(1);
    // Dots of work per output pixel for utilisation accounting.
    let (ops_per_out, latency): (u64, u64) = match ql.kind {
        QKind::Conv => ((c_in * c_out) as u64, 3),
        QKind::DwConv | QKind::AvgPool => (c_out as u64, 3),
        QKind::MaxPool => (c_out as u64, 2),
        QKind::Dense => (0, 2), // accounted separately below
    };

    let mut vals = Vec::with_capacity(h_out * w_out * c_out);
    let mut outs = Vec::with_capacity(h_out * w_out);
    match ql.kind {
        QKind::Dense => {
            let feats = h_in * w_in * c_in;
            if map.len() != feats {
                return Err(format!("{}: input len {} != {feats}", ql.name, map.len()));
            }
            let dep = in_cycles.last().copied().unwrap_or(0);
            for unit in 0..c_out {
                let mut acc = ql.b_q[unit];
                for (f, &x) in map.iter().enumerate() {
                    acc += QModel::dense_w(ql, unit, f) * x;
                }
                if ql.relu {
                    acc = acc.max(0);
                }
                // The final layer emits accumulator-scale values (the
                // paper's wider final output; matches forward_int8).
                vals.push(if !is_last && ql.m != 0.0 { requant(acc, ql.m) } else { acc });
            }
            let h = match plan.plan {
                UnitPlan::Fcu { h, .. } => h as u64,
                _ => 1,
            };
            // Latency: weight-cycle tail + pipeline regs. Occupancy: the
            // FCU accepts a new frame every C cycles (its initiation
            // interval), not every latency — frames overlap in the
            // accumulator FIFO exactly as Table III shows.
            let ii = plan.plan.configs() as u64;
            let finish = (dep + h + latency).max(*prev_finish + ii);
            // FCU lanes: each of the `units` FCUs executes j MACs per cycle
            // over C cycles -> count weighted-sum cycles as useful ops.
            let c_cfg = plan.plan.configs() as u64;
            stat.useful_ops += c_cfg * plan.plan.unit_count() as u64;
            stat.first_cycle = stat
                .first_cycle
                .min(in_cycles.first().copied().unwrap_or(dep));
            stat.last_cycle = stat.last_cycle.max(finish);
            *prev_finish = finish;
            outs.push(finish);
        }
        QKind::MaxPool => {
            for orow in 0..h_out {
                for ocol in 0..w_out {
                    // Last input pixel needed by this window.
                    let lr = (orow * ql.s + ql.k - 1).min(h_in - 1);
                    let lc = (ocol * ql.s + ql.k - 1).min(w_in - 1);
                    let dep = in_cycles[lr * w_in + lc];
                    let finish = dep.max(*prev_finish + out_period) + latency;
                    for ch in 0..c_out {
                        let mut m = i64::MIN;
                        for u in 0..ql.k {
                            for v in 0..ql.k {
                                let (r, c) = (orow * ql.s + u, ocol * ql.s + v);
                                if r < h_in && c < w_in {
                                    m = m.max(map[(r * w_in + c) * c_in + ch]);
                                }
                            }
                        }
                        vals.push(m);
                    }
                    stat.useful_ops += ops_per_out;
                    stat.first_cycle = stat.first_cycle.min(dep);
                    stat.last_cycle = stat.last_cycle.max(finish);
                    *prev_finish = finish - latency;
                    outs.push(finish);
                }
            }
        }
        QKind::Conv | QKind::DwConv | QKind::AvgPool => {
            let p = ql.p as isize;
            // Hot loop (see DESIGN.md §4): accumulate all output
            // channels of a pixel together so each (u, v) tap touches the
            // weight tensor contiguously ([ci][co] layout) and the inner
            // loop vectorises; skips multiplying zero activations (common
            // after ReLU on int8).
            let mut acc = vec![0i64; c_out];
            for orow in 0..h_out {
                for ocol in 0..w_out {
                    let lr = ((orow * ql.s) as isize + ql.k as isize - 1 - p)
                        .clamp(0, h_in as isize - 1) as usize;
                    let lc = ((ocol * ql.s) as isize + ql.k as isize - 1 - p)
                        .clamp(0, w_in as isize - 1) as usize;
                    let dep = in_cycles[lr * w_in + lc];
                    let finish = dep.max(*prev_finish + out_period) + latency;
                    acc.copy_from_slice(&ql.b_q);
                    for u in 0..ql.k {
                        let r = (orow * ql.s) as isize + u as isize - p;
                        if r < 0 || r >= h_in as isize {
                            continue; // implicit zero padding (rows)
                        }
                        for v in 0..ql.k {
                            let c = (ocol * ql.s) as isize + v as isize - p;
                            if c < 0 || c >= w_in as isize {
                                continue; // implicit zero padding (cols)
                            }
                            let base = (r as usize * w_in + c as usize) * c_in;
                            match ql.kind {
                                QKind::Conv => {
                                    let xs = &map[base..base + c_in];
                                    let wbase = (u * ql.k + v) * c_in * c_out;
                                    for (ci, &xv) in xs.iter().enumerate() {
                                        if xv == 0 {
                                            continue;
                                        }
                                        let wrow =
                                            &ql.w_q[wbase + ci * c_out..wbase + (ci + 1) * c_out];
                                        for (a, &wv) in acc.iter_mut().zip(wrow) {
                                            *a += wv * xv;
                                        }
                                    }
                                }
                                _ => {
                                    let wbase = (u * ql.k + v) * c_out;
                                    let wrow = &ql.w_q[wbase..wbase + c_out];
                                    let xs = &map[base..base + c_out];
                                    for ((a, &wv), &xv) in
                                        acc.iter_mut().zip(wrow).zip(xs)
                                    {
                                        *a += wv * xv;
                                    }
                                }
                            }
                        }
                    }
                    for co in 0..c_out {
                        let mut a = acc[co];
                        if ql.relu {
                            a = a.max(0);
                        }
                        vals.push(if !is_last && ql.m != 0.0 {
                            requant(a, ql.m)
                        } else {
                            a
                        });
                    }
                    stat.useful_ops += ops_per_out;
                    stat.first_cycle = stat.first_cycle.min(dep);
                    stat.last_cycle = stat.last_cycle.max(finish);
                    *prev_finish = finish - latency;
                    outs.push(finish);
                }
            }
        }
    }
    Ok((vals, outs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QMAX;
    use crate::util::Rng;

    /// A hand-built tiny quantized model for tests without artifacts:
    /// conv 3x3 p1 (1->2) + maxpool 2x2 + dense 4.
    pub fn tiny_qmodel(seed: u64) -> QModel {
        let mut rng = Rng::new(seed);
        let mut wq = |n: usize| -> Vec<i64> { (0..n).map(|_| rng.int8() as i64 / 16).collect() };
        let conv = QLayer {
            name: "C1".into(),
            kind: QKind::Conv,
            k: 3,
            s: 1,
            p: 1,
            relu: true,
            w_q: wq(3 * 3 * 2),
            w_shape: vec![3, 3, 1, 2],
            b_q: vec![3, -2],
            m: 0.05,
            in_shape: [4, 4, 1],
            out_shape: [4, 4, 2],
        };
        let pool = QLayer {
            name: "P1".into(),
            kind: QKind::MaxPool,
            k: 2,
            s: 2,
            p: 0,
            relu: false,
            w_q: vec![],
            w_shape: vec![],
            b_q: vec![],
            m: 0.0,
            in_shape: [4, 4, 2],
            out_shape: [2, 2, 2],
        };
        let dense = QLayer {
            name: "F1".into(),
            kind: QKind::Dense,
            k: 0,
            s: 1,
            p: 0,
            relu: false,
            w_q: wq(4 * 8),
            w_shape: vec![4, 8],
            b_q: vec![1, 2, 3, 4],
            m: 0.0, // final layer: accumulator out
            in_shape: [1, 1, 8],
            out_shape: [1, 1, 4],
        };
        QModel {
            name: "tiny".into(),
            input_shape: [4, 4, 1],
            input_scale: 1.0,
            layers: vec![conv, pool, dense],
            topology: vec![],
            test_vectors: vec![],
            qat_accuracy: 1.0,
        }
    }

    /// Plain direct implementation of the int8 pipeline for cross-check.
    fn oracle(qm: &QModel, x: &[i64]) -> Vec<i64> {
        let mut map = x.to_vec();
        for ql in &qm.layers {
            let [h, w, cin] = ql.in_shape;
            let [ho, wo, cout] = ql.out_shape;
            let mut out = Vec::new();
            match ql.kind {
                QKind::Dense => {
                    for u in 0..cout {
                        let mut acc = ql.b_q[u];
                        for (f, &v) in map.iter().enumerate() {
                            acc += QModel::dense_w(ql, u, f) * v;
                        }
                        if ql.relu {
                            acc = acc.max(0);
                        }
                        out.push(if ql.m != 0.0 { requant(acc, ql.m) } else { acc });
                    }
                }
                QKind::MaxPool => {
                    for orow in 0..ho {
                        for ocol in 0..wo {
                            for ch in 0..cout {
                                let mut m = i64::MIN;
                                for u in 0..ql.k {
                                    for v in 0..ql.k {
                                        m = m.max(
                                            map[((orow * ql.s + u) * w + ocol * ql.s + v) * cin
                                                + ch],
                                        );
                                    }
                                }
                                out.push(m);
                            }
                        }
                    }
                }
                _ => {
                    for orow in 0..ho {
                        for ocol in 0..wo {
                            for co in 0..cout {
                                let mut acc = ql.b_q[co];
                                for u in 0..ql.k {
                                    for v in 0..ql.k {
                                        let r = (orow * ql.s + u) as isize - ql.p as isize;
                                        let c = (ocol * ql.s + v) as isize - ql.p as isize;
                                        if r < 0 || c < 0 || r >= h as isize || c >= w as isize {
                                            continue;
                                        }
                                        let b = (r as usize * w + c as usize) * cin;
                                        acc += QModel::conv_w(ql, u, v, 0, co) * map[b];
                                    }
                                }
                                if ql.relu {
                                    acc = acc.max(0);
                                }
                                out.push(if ql.m != 0.0 { requant(acc, ql.m) } else { acc });
                            }
                        }
                    }
                }
            }
            map = out;
        }
        map
    }

    fn rand_frame(rng: &mut Rng, n: usize) -> Vec<i64> {
        (0..n).map(|_| rng.int8() as i64).collect()
    }

    #[test]
    fn pipeline_values_match_direct_oracle() {
        let qm = tiny_qmodel(1);
        let sim = PipelineSim::new(qm.clone(), None).unwrap();
        let mut rng = Rng::new(2);
        for _ in 0..10 {
            let x = rand_frame(&mut rng, 16);
            let res = sim.run(&[x.clone()]).unwrap();
            assert_eq!(res.outputs[0], oracle(&qm, &x));
        }
    }

    #[test]
    fn activations_bounded_by_qmax() {
        // All intermediate (requantized) values must stay in int8; the
        // final dense layer is accumulator-scale by design.
        let qm = tiny_qmodel(3);
        let mut rng = Rng::new(4);
        let x = rand_frame(&mut rng, 16);
        let mut map = x;
        for ql in &qm.layers[..2] {
            let one_layer = QModel {
                layers: vec![ql.clone()],
                input_shape: ql.in_shape,
                ..qm.clone()
            };
            map = oracle(&one_layer, &map);
            for &v in &map {
                assert!(v.abs() <= QMAX, "intermediate {v} exceeds int8");
            }
        }
    }

    #[test]
    fn reference_plan_same_values_more_units() {
        let qm = tiny_qmodel(5);
        let mut rng = Rng::new(6);
        let frames: Vec<Vec<i64>> = (0..8).map(|_| rand_frame(&mut rng, 16)).collect();
        let ours = PipelineSim::new(qm.clone(), None)
            .unwrap()
            .run(&frames)
            .unwrap();
        let reference = PipelineSim::new_reference(qm).unwrap().run(&frames).unwrap();
        assert_eq!(ours.outputs, reference.outputs);
        for (a, b) in ours.stats.iter().zip(reference.stats.iter()) {
            assert!(b.units >= a.units, "{}", a.name);
        }
    }

    #[test]
    fn throughput_matches_rate_analysis() {
        // Steady-state cycles/frame must approach the frame period
        // (f^2 + p*f + p pixels at d0 = r0 = 1 feature/pixel/cycle).
        let qm = tiny_qmodel(7);
        let mut rng = Rng::new(8);
        let frames: Vec<Vec<i64>> = (0..16).map(|_| rand_frame(&mut rng, 16)).collect();
        let res = PipelineSim::new(qm, None).unwrap().run(&frames).unwrap();
        let expect = 21.0; // 16 + 4 + 1
        let got = res.cycles_per_frame;
        assert!(
            (got - expect).abs() / expect < 0.25,
            "cycles/frame {got} vs {expect}"
        );
    }

    #[test]
    fn latency_is_bounded() {
        let qm = tiny_qmodel(9);
        let mut rng = Rng::new(10);
        let x = rand_frame(&mut rng, 16);
        let res = PipelineSim::new(qm, None).unwrap().run(&[x]).unwrap();
        // Single frame latency covers the input stream (16 pixels) plus a
        // small pipeline margin.
        assert!(res.first_frame_latency >= 15);
        assert!(res.first_frame_latency < 64, "{}", res.first_frame_latency);
    }

    #[test]
    fn pipeline_sim_clones_are_independent_and_send() {
        fn assert_send_clone<T: Send + Clone + 'static>(_: &T) {}
        let qm = crate::quant::QModel::synthetic(8, 4, 6, 21);
        let sim = PipelineSim::new(qm, None).unwrap();
        assert_send_clone(&sim);
        let clone = sim.clone();
        let mut rng = Rng::new(22);
        let x = rand_frame(&mut rng, 64);
        let a = sim.run(&[x.clone()]).unwrap();
        let b = clone.run(&[x]).unwrap();
        assert_eq!(a.outputs, b.outputs);
        assert_eq!(a.total_cycles, b.total_cycles);
    }

    #[test]
    fn synthetic_fixture_matches_direct_oracle() {
        // The public fixture must agree with the plain int8 oracle, so
        // coordinator tests can trust it as a golden path.
        let qm = crate::quant::QModel::synthetic(8, 4, 6, 33);
        let sim = PipelineSim::new(qm.clone(), None).unwrap();
        let mut rng = Rng::new(34);
        for _ in 0..6 {
            let x = rand_frame(&mut rng, 64);
            let res = sim.run(&[x.clone()]).unwrap();
            assert_eq!(res.outputs[0], oracle(&qm, &x));
        }
    }

    #[test]
    fn rejects_wrong_frame_size() {
        let qm = tiny_qmodel(11);
        let sim = PipelineSim::new(qm, None).unwrap();
        assert!(sim.run(&[vec![0; 7]]).is_err());
        assert!(sim.run_interpreted(&[vec![0; 7]]).is_err());
    }

    #[test]
    fn compiled_run_is_identical_to_interpreter() {
        // THE two-tier contract: run (compiled values + analytic schedule)
        // must reproduce the fused interpreter outcome field for field.
        for seed in [21u64, 22, 23] {
            let qm = QModel::synthetic(8, 4, 6, seed);
            let sim = PipelineSim::new(qm, None).unwrap();
            let mut rng = Rng::new(seed ^ 0xF00);
            let frames: Vec<Vec<i64>> =
                (0..7).map(|_| rand_frame(&mut rng, 64)).collect();
            let fast = sim.run(&frames).unwrap();
            let oracle = sim.run_interpreted(&frames).unwrap();
            assert_eq!(fast.outputs, oracle.outputs);
            assert_eq!(fast.total_cycles, oracle.total_cycles);
            assert_eq!(fast.first_frame_latency, oracle.first_frame_latency);
            assert_eq!(fast.cycles_per_frame, oracle.cycles_per_frame);
            assert_eq!(fast.stats.len(), oracle.stats.len());
            for (a, b) in fast.stats.iter().zip(oracle.stats.iter()) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.units, b.units);
                assert_eq!(a.unit_kind, b.unit_kind);
                assert_eq!(a.useful_ops, b.useful_ops);
                assert_eq!(a.first_cycle, b.first_cycle);
                assert_eq!(a.last_cycle, b.last_cycle);
                assert_eq!(a.utilization, b.utilization, "{}", a.name);
            }
        }
    }

    #[test]
    fn cycles_per_frame_excludes_warmup_frame() {
        // Satellite pin: the steady-state figure must equal the shared
        // warm-up-excluding formula applied to the per-frame completion
        // cycles (prefix runs expose them: frames are causal, so an
        // n-frame run's total_cycles is frame n-1's completion cycle).
        use crate::flow::schedule::steady_cycles_per_frame;
        let qm = tiny_qmodel(31);
        let sim = PipelineSim::new(qm, None).unwrap();
        let mut rng = Rng::new(32);
        let frames: Vec<Vec<i64>> = (0..6).map(|_| rand_frame(&mut rng, 16)).collect();
        let finishes: Vec<u64> = (1..=frames.len())
            .map(|n| sim.run_interpreted(&frames[..n]).unwrap().total_cycles)
            .collect();
        let res = sim.run_interpreted(&frames).unwrap();
        assert_eq!(res.cycles_per_frame, steady_cycles_per_frame(&finishes));
        // And the analytic prediction agrees on the same figures.
        assert_eq!(sim.predicted.total_cycles(frames.len()), res.total_cycles);
        assert_eq!(
            sim.predicted.cycles_per_frame(frames.len()),
            res.cycles_per_frame
        );
    }

    #[test]
    fn digits_artifact_matches_exported_vectors() {
        // THE bit-exactness integration test: the rust pipeline must
        // reproduce the JAX int8 golden outputs exactly on the exporter's
        // test vectors.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/weights/digits.json");
        if !path.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let qm = QModel::load(&path).unwrap();
        let sim = PipelineSim::new(qm.clone(), None).unwrap();
        for (i, tv) in qm.test_vectors.iter().enumerate() {
            let res = sim.run(&[tv.x_q.clone()]).unwrap();
            assert_eq!(res.outputs[0], tv.y, "test vector {i}");
        }
    }

    #[test]
    fn jsc_artifact_matches_exported_vectors() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/weights/jsc.json");
        if !path.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let qm = QModel::load(&path).unwrap();
        let sim = PipelineSim::new(qm.clone(), None).unwrap();
        for (i, tv) in qm.test_vectors.iter().enumerate() {
            let res = sim.run(&[tv.x_q.clone()]).unwrap();
            assert_eq!(res.outputs[0], tv.y, "test vector {i}");
        }
    }

    #[test]
    fn digits_utilization_near_full() {
        // The continuous-flow pipeline's stride-1 conv layers must run
        // close to full utilisation over a back-to-back frame stream.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/weights/digits.json");
        if !path.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let qm = QModel::load(&path).unwrap();
        let sim = PipelineSim::new(qm.clone(), None).unwrap();
        let frames: Vec<Vec<i64>> = qm
            .test_vectors
            .iter()
            .cycle()
            .take(24)
            .map(|tv| tv.x_q.clone())
            .collect();
        let res = sim.run(&frames).unwrap();
        for s in &res.stats {
            if s.name == "C1" || s.name == "C2" {
                assert!(
                    s.utilization > 0.80,
                    "{} utilization {:.3}",
                    s.name,
                    s.utilization
                );
            }
        }
    }
}
