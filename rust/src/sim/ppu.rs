//! Structural, cycle-accurate pooling processing unit (PPU) — Fig. 5
//! (2x2 max pooling) generalised to `k x k` windows and `C` interleaved
//! configurations (Fig. 12).
//!
//! The circuit mirrors the KPU's transposed form with MAX units in place
//! of multiply-add: `k-1` comparison stages per row, `k-1` line buffers
//! between rows, every storage element a depth-C FIFO under interleaving.

use super::fifo::Fifo;

/// Sentinel for "no value yet" in the max chain. Using i64::MIN would
/// overflow on comparisons with offsets; the pipeline only emits valid
/// outputs after the chain has filled, so any very negative value works.
const NEG: i64 = i64::MIN / 2;

#[derive(Debug, Clone)]
pub struct PpuOut {
    /// Max accumulated along each row chain (last tap of each row).
    pub row_max: Vec<i64>,
    /// The window maximum (last row's chain output).
    pub y: i64,
}

/// A PPU instance. `configs` is the interleave depth C.
#[derive(Debug, Clone)]
pub struct Ppu {
    k: usize,
    row_regs: Vec<Vec<Fifo>>,
    line_bufs: Vec<Fifo>,
    cycle: u64,
}

impl Ppu {
    pub fn new(k: usize, f: usize, configs: usize) -> Self {
        assert!(k >= 1 && f >= k && configs >= 1);
        let row_regs = (0..k)
            .map(|_| {
                (0..k.saturating_sub(1))
                    .map(|_| {
                        let mut fifo = Fifo::new(configs);
                        // Pre-fill with the sentinel so max() ignores
                        // unfilled positions.
                        for _ in 0..configs {
                            fifo.push(NEG);
                        }
                        fifo
                    })
                    .collect()
            })
            .collect();
        let line_bufs = (0..k.saturating_sub(1))
            .map(|_| {
                let mut fifo = Fifo::new((f - k + 1) * configs);
                for _ in 0..fifo.depth() {
                    fifo.push(NEG);
                }
                fifo
            })
            .collect();
        Self {
            k,
            row_regs,
            line_bufs,
            cycle: 0,
        }
    }

    /// One clock cycle with input pixel `x`.
    pub fn tick(&mut self, x: i64) -> PpuOut {
        let k = self.k;
        let mut node_vals = vec![vec![NEG; k]; k];
        let mut row_max = Vec::with_capacity(k);
        // Phase 1 — combinational max chains against pre-edge state.
        for u in 0..k {
            let row_in = if u == 0 {
                NEG
            } else {
                self.line_bufs[u - 1].peek()
            };
            for v in 0..k {
                let partial_in = if v == 0 {
                    row_in
                } else {
                    self.row_regs[u][v - 1].peek()
                };
                node_vals[u][v] = partial_in.max(x);
            }
            row_max.push(node_vals[u][k - 1]);
        }
        // Phase 2 — clock edge.
        for u in 0..k {
            for v in 0..k - 1 {
                self.row_regs[u][v].push(node_vals[u][v]);
            }
            if u < k - 1 {
                self.line_bufs[u].push(node_vals[u][k - 1]);
            }
        }
        self.cycle += 1;
        PpuOut {
            y: row_max[k - 1],
            row_max,
        }
    }
}

/// Reference max-pool oracle (Eq. 6): window top-left at flat index n.
pub fn maxpool_oracle(xmap: &[i64], f: usize, k: usize, n: usize) -> i64 {
    let (r, c) = (n / f, n % f);
    let mut m = i64::MIN;
    for u in 0..k {
        for v in 0..k {
            m = m.max(xmap[(r + u) * f + (c + v)]);
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn ppu_2x2_stride2_matches_oracle() {
        let f = 6;
        let mut rng = Rng::new(3);
        let xmap: Vec<i64> = (0..f * f).map(|_| rng.range(0, 100) as i64 - 50).collect();
        let mut ppu = Ppu::new(2, f, 1);
        let delay = f + 1; // f*(k-1) + (k-1)
        for (t, &x) in xmap.iter().enumerate() {
            let out = ppu.tick(x);
            if t >= delay {
                let n = t - delay;
                let (r, c) = (n / f, n % f);
                // Valid at stride-2 positions fully inside the map (Eq. 11).
                if r % 2 == 0 && c % 2 == 0 && r + 2 <= f && c + 2 <= f {
                    assert_eq!(out.y, maxpool_oracle(&xmap, f, 2, n), "n={n}");
                }
            }
        }
    }

    #[test]
    fn ppu_3x3_stride3() {
        let f = 9;
        let mut rng = Rng::new(5);
        let xmap: Vec<i64> = (0..f * f).map(|_| rng.range(0, 1000) as i64).collect();
        let mut ppu = Ppu::new(3, f, 1);
        let delay = 2 * f + 2;
        let mut count = 0;
        for (t, &x) in xmap.iter().enumerate() {
            let out = ppu.tick(x);
            if t >= delay {
                let n = t - delay;
                let (r, c) = (n / f, n % f);
                if r % 3 == 0 && c % 3 == 0 && r + 3 <= f && c + 3 <= f {
                    assert_eq!(out.y, maxpool_oracle(&xmap, f, 3, n));
                    count += 1;
                }
            }
        }
        // All 9 windows of the 3x3 output grid are produced; the last one
        // lands exactly on the final input cycle t = f^2 - 1.
        assert_eq!(count, 9);
    }

    #[test]
    fn interleaved_ppu_c4() {
        // 4 channels interleaved into one PPU (Fig. 12).
        let (f, k, c) = (4, 2, 4);
        let mut rng = Rng::new(11);
        let maps: Vec<Vec<i64>> = (0..c)
            .map(|_| (0..f * f).map(|_| rng.range(0, 60) as i64 - 30).collect())
            .collect();
        let mut ppu = Ppu::new(k, f, c);
        let delay = (f * (k - 1) + (k - 1)) * c;
        let mut checked = 0;
        for t in 0..f * f * c {
            let (ch, m) = (t % c, t / c);
            let out = ppu.tick(maps[ch][m]);
            if t >= delay {
                let nt = t - delay;
                let (ch_o, n) = (nt % c, nt / c);
                let (r, cc) = (n / f, n % f);
                if r % 2 == 0 && cc % 2 == 0 && r + k <= f && cc + k <= f {
                    assert_eq!(out.y, maxpool_oracle(&maps[ch_o], f, k, n));
                    checked += 1;
                }
            }
        }
        assert!(checked >= 12, "checked {checked}");
    }

    #[test]
    fn negative_inputs_survive_sentinel() {
        // All-negative input map: outputs must still be the window max,
        // not the sentinel.
        let f = 4;
        let xmap: Vec<i64> = (0..16).map(|i| -100 - i as i64).collect();
        let mut ppu = Ppu::new(2, f, 1);
        let delay = f + 1;
        for (t, &x) in xmap.iter().enumerate() {
            let out = ppu.tick(x);
            if t >= delay {
                let n = t - delay;
                let (r, c) = (n / f, n % f);
                if r % 2 == 0 && c % 2 == 0 && r + 2 <= f && c + 2 <= f {
                    assert_eq!(out.y, maxpool_oracle(&xmap, f, 2, n));
                }
            }
        }
    }
}
