//! Structural, cycle-accurate kernel processing unit (KPU).
//!
//! Implements the transposed-form convolution circuit of Fig. 2 (plain),
//! Fig. 4 (implicit zero padding via per-column masking) and Fig. 9
//! (multi-configuration pipeline interleaving): `k*k` multipliers, a chain
//! of `k-1` registers per row, and `k-1` line buffers of `f-k+1` stages
//! between the rows. With `C` configurations every storage element becomes
//! a depth-C FIFO and the weight set switches every clock cycle.
//!
//! One `tick` is one clock edge. The returned [`KpuOut`] carries the
//! combinational values of the observable nodes (the `a_uv` columns of
//! Tables I/II) *before* the edge, exactly as the paper's tables list them.

use super::fifo::Fifo;

/// Output of one KPU clock cycle. Borrows the KPU's scratch buffers so a
/// tick performs no heap allocation.
#[derive(Debug)]
pub struct KpuOut<'a> {
    /// Combinational node values, flat k*k row-major: `node(u, v)` is the
    /// adder output at row u, tap v (a_{u+1,v+1} in Tables I/II).
    pub nodes: &'a [i64],
    /// The convolution output (node (k-1, k-1)).
    pub y: i64,
    /// Padding select signals used this cycle (`true` = pass, `false` =
    /// masked to zero), one per multiplier column — the `Pad` column of
    /// Table II.
    pub pad: &'a [bool],
}

impl KpuOut<'_> {
    /// Node value at row `u`, tap `v`.
    #[inline]
    pub fn node(&self, u: usize, v: usize) -> i64 {
        let k = self.pad.len();
        self.nodes[u * k + v]
    }
}

/// A KPU instance.
#[derive(Debug, Clone)]
pub struct Kpu {
    k: usize,
    f: usize,
    p: usize,
    configs: usize,
    /// Weight sets, one per configuration, each `k*k` row-major.
    weights: Vec<Vec<i64>>,
    /// Register chains inside each row: `row_regs[u][v]` delays the
    /// partial sum between tap v and tap v+1 of row u.
    row_regs: Vec<Vec<Fifo>>,
    /// Line buffers between row u and u+1, depth (f-k+1)*C.
    line_bufs: Vec<Fifo>,
    cycle: u64,
    /// Per-tick scratch (avoids per-tick allocation on the hot path).
    scratch_nodes: Vec<i64>,
    scratch_pad: Vec<bool>,
}

impl Kpu {
    /// Build a KPU. `weights.len()` defines the configuration count C;
    /// each set must have `k*k` entries. `p` enables implicit zero padding
    /// (Fig. 4); `p = 0` is the plain Fig. 2 circuit.
    pub fn new(k: usize, f: usize, p: usize, weights: Vec<Vec<i64>>) -> Self {
        assert!(k >= 1 && f >= k, "need f >= k >= 1");
        assert!(!weights.is_empty(), "at least one weight configuration");
        for w in &weights {
            assert_eq!(w.len(), k * k, "weight set must be k*k");
        }
        let configs = weights.len();
        let row_regs = (0..k)
            .map(|_| (0..k.saturating_sub(1)).map(|_| Fifo::new(configs)).collect())
            .collect();
        let line_bufs = (0..k.saturating_sub(1))
            .map(|_| Fifo::new((f - k + 1) * configs))
            .collect();
        Self {
            k,
            f,
            p,
            configs,
            weights,
            row_regs,
            line_bufs,
            cycle: 0,
            scratch_nodes: vec![0; k * k],
            scratch_pad: vec![true; k],
        }
    }

    pub fn configs(&self) -> usize {
        self.configs
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Padding select signal for multiplier column `v` when the current
    /// input pixel is at feature-map column `c` (Eq. 10). Returns `true`
    /// when the product passes, `false` when it is masked to zero.
    pub fn pad_select(&self, v: usize, c: usize) -> bool {
        let (c, v, f, p, k) = (
            c as isize,
            v as isize,
            self.f as isize,
            self.p as isize,
            self.k as isize,
        );
        if c >= f - p + v {
            return false;
        }
        if c < p - k + v + 1 {
            return false;
        }
        true
    }

    /// One clock cycle. `x` is the input value broadcast to all
    /// multipliers; `col` is the feature-map column of the current input
    /// pixel (`None` during zero-feed cycles, where masking is moot).
    ///
    /// The active weight configuration is `cycle mod C`, matching the
    /// interleaved channel order produced by the planner.
    pub fn tick(&mut self, x: i64, col: Option<usize>) -> KpuOut<'_> {
        let cfg = (self.cycle % self.configs as u64) as usize;
        let w = &self.weights[cfg];
        let k = self.k;
        if self.p > 0 {
            match col {
                Some(c) => {
                    for v in 0..k {
                        // Inline Eq. 10 (avoids the method-call casts on
                        // the hot path; see pad_select for the spec form).
                        let ci = c as isize;
                        let vi = v as isize;
                        self.scratch_pad[v] = ci < self.f as isize - self.p as isize + vi
                            && ci >= self.p as isize - self.k as isize + vi + 1;
                    }
                }
                None => self.scratch_pad.fill(true),
            }
        }
        // Phase 1 — combinational evaluation against the pre-edge register
        // state. All peeks happen before any push so every storage element
        // clocks simultaneously, like the hardware.
        let mut y = 0i64;
        for u in 0..k {
            let row_in = if u == 0 {
                0
            } else {
                self.line_bufs[u - 1].peek()
            };
            for v in 0..k {
                let product = if self.scratch_pad[v] { w[u * k + v] * x } else { 0 };
                let partial_in = if v == 0 {
                    row_in
                } else {
                    self.row_regs[u][v - 1].peek()
                };
                self.scratch_nodes[u * k + v] = partial_in + product;
            }
            if u == k - 1 {
                y = self.scratch_nodes[u * k + k - 1];
            }
        }
        // Phase 2 — clock edge: shift every register and line buffer.
        for u in 0..k {
            for v in 0..k - 1 {
                self.row_regs[u][v].push(self.scratch_nodes[u * k + v]);
            }
            if u < k - 1 {
                self.line_bufs[u].push(self.scratch_nodes[u * k + k - 1]);
            }
        }
        self.cycle += 1;
        KpuOut {
            nodes: &self.scratch_nodes,
            y,
            pad: &self.scratch_pad,
        }
    }

    pub fn reset(&mut self) {
        for row in &mut self.row_regs {
            for r in row {
                r.reset();
            }
        }
        for lb in &mut self.line_bufs {
            lb.reset();
        }
        self.cycle = 0;
    }
}

/// Reference convolution for oracle checks: computes y_n per Eq. 2 on a
/// flat row-major feature map, with virtual zero padding of `p` when the
/// window leaves the map (Section III-B semantics). `n` indexes the
/// *padded-coordinate* top-left when `p > 0` (i.e. y_n is centred like the
/// paper's Table II), and the plain top-left when `p = 0`.
pub fn conv_oracle(xmap: &[i64], f: usize, k: usize, p: usize, w: &[i64], n: usize) -> i64 {
    let (r, c) = (n / f, n % f);
    let mut acc = 0i64;
    for u in 0..k {
        for v in 0..k {
            // Window element position in unpadded coordinates.
            let rr = r as isize + u as isize - p as isize;
            let cc = c as isize + v as isize - p as isize;
            let x = if rr < 0 || cc < 0 || rr >= f as isize || cc >= f as isize {
                0
            } else {
                xmap[rr as usize * f + cc as usize]
            };
            acc += w[u * k + v] * x;
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn ramp_map(f: usize) -> Vec<i64> {
        (0..(f * f) as i64).collect()
    }

    /// Drive an unpadded KPU over one frame and collect y values at the
    /// analytically-predicted cycles t = n + f*(k-1) + (k-1).
    fn run_unpadded(f: usize, k: usize, xmap: &[i64], w: &[i64]) -> Vec<(usize, i64)> {
        let mut kpu = Kpu::new(k, f, 0, vec![w.to_vec()]);
        let mut got = Vec::new();
        for (t, &x) in xmap.iter().enumerate() {
            let out = kpu.tick(x, None);
            let delay = f * (k - 1) + (k - 1);
            if t >= delay {
                got.push((t - delay, out.y));
            }
        }
        got
    }

    #[test]
    fn unpadded_kpu_matches_oracle_on_valid_outputs() {
        let (f, k) = (5, 3);
        let xmap = ramp_map(f);
        let w: Vec<i64> = (1..=9).collect();
        for (n, y) in run_unpadded(f, k, &xmap, &w) {
            let (r, c) = (n / f, n % f);
            if r <= f - k && c <= f - k {
                assert_eq!(y, conv_oracle(&xmap, f, k, 0, &w, n), "n={n}");
            }
        }
    }

    #[test]
    fn unpadded_kpu_random_shapes() {
        let mut rng = Rng::new(0xC0FFEE);
        for _ in 0..20 {
            let k = rng.range(1, 4);
            let f = rng.range(k, k + 5);
            let xmap: Vec<i64> = (0..f * f).map(|_| rng.range(0, 200) as i64 - 100).collect();
            let w: Vec<i64> = (0..k * k).map(|_| rng.range(0, 20) as i64 - 10).collect();
            for (n, y) in run_unpadded(f, k, &xmap, &w) {
                let (r, c) = (n / f, n % f);
                if r + k <= f && c + k <= f {
                    assert_eq!(y, conv_oracle(&xmap, f, k, 0, &w, n), "f={f} k={k} n={n}");
                }
            }
        }
    }

    /// Drive a padded KPU: p*f+p zero cycles, the frame, then p*f+p zeros.
    fn run_padded(f: usize, k: usize, p: usize, xmap: &[i64], w: &[i64]) -> Vec<(usize, i64)> {
        let mut kpu = Kpu::new(k, f, p, vec![w.to_vec()]);
        let offset = p * f + p;
        let total = offset + f * f + offset;
        let mut got = Vec::new();
        for t in 0..total {
            let (x, col) = if t >= offset && t < offset + f * f {
                let m = t - offset;
                (xmap[m], Some(m % f))
            } else {
                (0, None)
            };
            let out = kpu.tick(x, col);
            // y_n appears at t = n + f*(k-1) + (k-1) (same relation as
            // unpadded; the offset cancels — see DESIGN.md).
            let delay = f * (k - 1) + (k - 1);
            if t >= delay && t - delay < f * f {
                got.push((t - delay, out.y));
            }
        }
        got
    }

    #[test]
    fn padded_kpu_produces_all_f2_outputs() {
        let (f, k, p) = (5, 3, 1);
        let xmap = ramp_map(f);
        let w: Vec<i64> = (1..=9).collect();
        let got = run_padded(f, k, p, &xmap, &w);
        assert_eq!(got.len(), f * f, "continuous flow at the output");
        for (n, y) in got {
            assert_eq!(y, conv_oracle(&xmap, f, k, p, &w, n), "n={n}");
        }
    }

    #[test]
    fn padded_kpu_random() {
        let mut rng = Rng::new(0xB0BA);
        for _ in 0..15 {
            let k = 2 * rng.range(0, 1) + 3; // 3 or 5 (odd for p=(k-1)/2)
            let p = (k - 1) / 2;
            let f = rng.range(k, k + 4);
            let xmap: Vec<i64> = (0..f * f).map(|_| rng.range(0, 100) as i64 - 50).collect();
            let w: Vec<i64> = (0..k * k).map(|_| rng.range(0, 10) as i64 - 5).collect();
            for (n, y) in run_padded(f, k, p, &xmap, &w) {
                assert_eq!(y, conv_oracle(&xmap, f, k, p, &w, n), "f={f} k={k} n={n}");
            }
        }
    }

    #[test]
    fn multi_config_kpu_interleaves_channels() {
        // C=4 channels interleaved; each channel has its own weights.
        // The KPU must produce channel ch's convolution on the cycles
        // congruent to ch mod 4, at C times the single-channel latency.
        let (f, k, c) = (4, 2, 4);
        let mut rng = Rng::new(7);
        let maps: Vec<Vec<i64>> = (0..c)
            .map(|_| (0..f * f).map(|_| rng.range(0, 40) as i64 - 20).collect())
            .collect();
        let weights: Vec<Vec<i64>> = (0..c)
            .map(|_| (0..k * k).map(|_| rng.range(0, 10) as i64 - 5).collect())
            .collect();
        let mut kpu = Kpu::new(k, f, 0, weights.clone());
        let delay = (f * (k - 1) + (k - 1)) * c;
        let mut checked = 0;
        for t in 0..(f * f * c) {
            let ch = t % c;
            let m = t / c;
            let out = kpu.tick(maps[ch][m], None);
            if t >= delay {
                let nt = t - delay;
                let (ch_o, n) = (nt % c, nt / c);
                let (r, cc) = (n / f, n % f);
                if r + k <= f && cc + k <= f {
                    assert_eq!(
                        out.y,
                        conv_oracle(&maps[ch_o], f, k, 0, &weights[ch_o], n),
                        "t={t} ch={ch_o} n={n}"
                    );
                    checked += 1;
                }
            }
        }
        assert!(checked > 0);
    }

    #[test]
    fn pad_select_matches_paper_example() {
        // k=3, p=1, f=5: c=0 masks column 2; c=4 masks column 0.
        let kpu = Kpu::new(3, 5, 1, vec![vec![0; 9]]);
        assert_eq!(
            (0..3).map(|v| kpu.pad_select(v, 0)).collect::<Vec<_>>(),
            vec![true, true, false]
        );
        assert_eq!(
            (0..3).map(|v| kpu.pad_select(v, 4)).collect::<Vec<_>>(),
            vec![false, true, true]
        );
        for c in 1..=3 {
            assert!((0..3).all(|v| kpu.pad_select(v, c)), "c={c}");
        }
    }

    #[test]
    fn oracle_zero_padding_edges() {
        // 1x1 map, 3x3 kernel, p=1: only the centre tap contributes.
        let w: Vec<i64> = (1..=9).collect();
        assert_eq!(conv_oracle(&[7], 1, 3, 1, &w, 0), 5 * 7);
    }
}
