//! Fixed-depth shift register (the `D`, `hD` and `LD` elements of the
//! paper's figures). Pipeline interleaving (Section IV-C) replaces every
//! register with a depth-C FIFO, so depth is a constructor parameter.

/// A shift register of fixed depth holding `i64` partial sums.
///
/// `push` inserts at the tail and returns the value shifted out of the
/// head — exactly one value per clock edge, like the hardware.
#[derive(Debug, Clone)]
pub struct Fifo {
    buf: Vec<i64>,
    head: usize,
}

impl Fifo {
    pub fn new(depth: usize) -> Self {
        assert!(depth > 0, "FIFO depth must be >= 1");
        Self {
            buf: vec![0; depth],
            head: 0,
        }
    }

    pub fn depth(&self) -> usize {
        self.buf.len()
    }

    /// The value that will be shifted out on the next push (combinational
    /// read of the head register's output).
    #[inline]
    pub fn peek(&self) -> i64 {
        self.buf[self.head]
    }

    /// Clock edge: shift in `v`, shift out the head.
    #[inline]
    pub fn push(&mut self, v: i64) -> i64 {
        let out = self.buf[self.head];
        self.buf[self.head] = v;
        self.head = (self.head + 1) % self.buf.len();
        out
    }

    pub fn reset(&mut self) {
        self.buf.fill(0);
        self.head = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_one_is_a_register() {
        let mut f = Fifo::new(1);
        assert_eq!(f.push(7), 0);
        assert_eq!(f.push(9), 7);
        assert_eq!(f.peek(), 9);
    }

    #[test]
    fn depth_n_delays_by_n() {
        let mut f = Fifo::new(3);
        for i in 1..=10 {
            let out = f.push(i);
            if i > 3 {
                assert_eq!(out, i - 3);
            } else {
                assert_eq!(out, 0);
            }
        }
    }

    #[test]
    fn reset_clears() {
        let mut f = Fifo::new(2);
        f.push(5);
        f.reset();
        assert_eq!(f.push(1), 0);
        assert_eq!(f.push(2), 0);
        assert_eq!(f.push(3), 1);
    }

    #[test]
    #[should_panic]
    fn zero_depth_rejected() {
        let _ = Fifo::new(0);
    }
}
