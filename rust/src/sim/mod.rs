//! Cycle-accurate, bit-accurate hardware simulators (systems S5 + S6).
//!
//! This is the substitution for the paper's FPGA testbed (DESIGN.md §2):
//! every processing unit of Figs. 2/4/5/6/7/9/12 is modelled at the
//! register level — each storage element is a [`fifo::Fifo`] clocked once
//! per `tick` — so the schedules of Tables I-IV and the utilisation claims
//! of Section IV are reproduced and *checked*, not asserted.
//!
//! * [`kpu`] — kernel processing unit (plain / implicit-padding / multi-config),
//! * [`ppu`] — pooling processing unit,
//! * [`fcu`] — fully connected unit + input aggregator,
//! * [`trace`] — the Tables I-IV emitters with oracle verification,
//! * [`pipeline`] — whole-CNN continuous-flow pipeline with int8
//!   quantised arithmetic and per-unit utilisation counters,
//! * [`compiled`] — the compile-once lowered value engine serving
//!   executes on (bit-identical to the pipeline interpreter; DESIGN.md §4).

pub mod compiled;
pub mod fcu;
pub mod fifo;
pub mod kpu;
pub mod pipeline;
pub mod ppu;
pub mod trace;

pub use compiled::{CompiledPipeline, FoldedPipeline, KernelChoice, KernelSel};
pub use fcu::{Aggregator, Fcu};
pub use kpu::Kpu;
pub use ppu::Ppu;
