//! Structural, cycle-accurate fully connected unit (FCU) — Fig. 6 — and
//! the input aggregation circuit of Fig. 7.
//!
//! An FCU computes `h` neurons over `d_in` input features, taking `j`
//! features per batch. A batch is held at the inputs for `h` consecutive
//! cycles while the weight ROM steps through one configuration per cycle;
//! the depth-`h` accumulator FIFO (`hD` in the figure) carries each
//! neuron's partial sum between batches (Eq. 12: C = h * d_in / j
//! configurations in total).

use super::fifo::Fifo;

#[derive(Debug, Clone)]
pub struct FcuOut {
    /// Accumulator value read this cycle (the `q` column of Table III).
    pub q: i64,
    /// Combinational sum written back (the `y` column: a partial `z` or,
    /// on the final batch, the finished neuron output).
    pub y: i64,
    /// Which neuron this cycle's sum belongs to.
    pub neuron: usize,
    /// True when `y` is the finished output of `neuron`.
    pub valid: bool,
}

#[derive(Debug, Clone)]
pub struct Fcu {
    j: usize,
    h: usize,
    d_in: usize,
    /// Weight ROM: `weights[config][m]` for m in 0..j. Config order is
    /// neuron-major within a batch: config = batch * h + neuron.
    weights: Vec<Vec<i64>>,
    /// Per-neuron bias, loaded as the initial partial sum of batch 0.
    bias: Vec<i64>,
    acc: Fifo,
    cycle: u64,
}

impl Fcu {
    /// `weights.len()` must equal C = h * ceil(d_in/j).
    pub fn new(j: usize, h: usize, d_in: usize, weights: Vec<Vec<i64>>, bias: Vec<i64>) -> Self {
        assert!(j >= 1 && h >= 1 && d_in >= j);
        let batches = d_in.div_ceil(j);
        assert_eq!(weights.len(), h * batches, "need C = h * d_in/j configs");
        for w in &weights {
            assert_eq!(w.len(), j);
        }
        assert_eq!(bias.len(), h);
        Self {
            j,
            h,
            d_in,
            weights,
            bias,
            acc: Fifo::new(h),
            cycle: 0,
        }
    }

    pub fn configs(&self) -> usize {
        self.weights.len()
    }

    pub fn batches(&self) -> usize {
        self.d_in.div_ceil(self.j)
    }

    /// One clock cycle. `x` is the current input batch (j values); the
    /// driver must hold each batch for `h` consecutive cycles.
    pub fn tick(&mut self, x: &[i64]) -> FcuOut {
        assert_eq!(x.len(), self.j);
        let c_total = self.weights.len() as u64;
        let cfg = (self.cycle % c_total) as usize;
        let neuron = cfg % self.h;
        let batch = cfg / self.h;
        // q: bias seeds the first batch; later batches read the FIFO,
        // which holds this neuron's partial from h cycles ago.
        let q = if batch == 0 {
            self.bias[neuron]
        } else {
            self.acc.peek()
        };
        let dot: i64 = self.weights[cfg]
            .iter()
            .zip(x.iter())
            .map(|(w, v)| w * v)
            .sum();
        let y = q + dot;
        self.acc.push(y);
        self.cycle += 1;
        FcuOut {
            q: if batch == 0 { 0 } else { q },
            y,
            neuron,
            valid: batch + 1 == self.batches(),
        }
    }
}

/// The data aggregation circuit of Fig. 7: widens a stream of `j_in`-wide
/// groups into `a * j_in`-wide groups. The output becomes valid once every
/// `a` pushes and then *holds* (the FCU reads it for `h` cycles).
#[derive(Debug, Clone)]
pub struct Aggregator {
    a: usize,
    j_in: usize,
    shift: Vec<i64>,
    latched: Vec<i64>,
    count: usize,
    filled: bool,
}

impl Aggregator {
    pub fn new(j_in: usize, a: usize) -> Self {
        assert!(a >= 1 && j_in >= 1);
        Self {
            a,
            j_in,
            shift: vec![0; j_in * a],
            latched: vec![0; j_in * a],
            count: 0,
            filled: false,
        }
    }

    /// Push one `j_in`-wide input group; returns the latched wide group
    /// and whether it was refreshed this cycle.
    pub fn push(&mut self, group: &[i64]) -> (&[i64], bool) {
        assert_eq!(group.len(), self.j_in);
        // Shift left by one group, insert at the tail (matches Fig. 7's
        // register chain ordering: oldest group occupies the low lanes).
        self.shift.rotate_left(self.j_in);
        let tail = self.shift.len() - self.j_in;
        self.shift[tail..].copy_from_slice(group);
        self.count += 1;
        let mut refreshed = false;
        if self.count == self.a {
            self.latched.copy_from_slice(&self.shift);
            self.count = 0;
            self.filled = true;
            refreshed = true;
        }
        (&self.latched, refreshed)
    }

    pub fn filled(&self) -> bool {
        self.filled
    }
}

/// Dense-layer oracle: `y[n] = bias[n] + sum_m x[m] * w[n][m]` (Eq. 7).
pub fn dense_oracle(x: &[i64], w: &[Vec<i64>], bias: &[i64]) -> Vec<i64> {
    w.iter()
        .zip(bias.iter())
        .map(|(row, b)| b + row.iter().zip(x.iter()).map(|(wv, xv)| wv * xv).sum::<i64>())
        .collect()
}

/// Arrange a dense layer's `[neuron][feature]` weight matrix into the FCU
/// ROM layout `[config][lane]` for an FCU with `j` inputs and `h` neurons
/// computing neurons `base..base+h`.
pub fn fcu_rom(w: &[Vec<i64>], base: usize, j: usize, h: usize, d_in: usize) -> Vec<Vec<i64>> {
    let batches = d_in.div_ceil(j);
    let mut rom = Vec::with_capacity(h * batches);
    for batch in 0..batches {
        for neuron in 0..h {
            let mut cfg = Vec::with_capacity(j);
            for lane in 0..j {
                let feat = batch * j + lane;
                cfg.push(if feat < d_in { w[base + neuron][feat] } else { 0 });
            }
            rom.push(cfg);
        }
    }
    rom
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Run a full dense layer through one or more FCUs and compare with
    /// the oracle.
    fn run_dense(d_in: usize, d_out: usize, j: usize, h: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let x: Vec<i64> = (0..d_in).map(|_| rng.range(0, 40) as i64 - 20).collect();
        let w: Vec<Vec<i64>> = (0..d_out)
            .map(|_| (0..d_in).map(|_| rng.range(0, 10) as i64 - 5).collect())
            .collect();
        let bias: Vec<i64> = (0..d_out).map(|_| rng.range(0, 20) as i64 - 10).collect();
        let expect = dense_oracle(&x, &w, &bias);
        let fcus = d_out / h;
        let batches = d_in.div_ceil(j);
        for u in 0..fcus {
            let base = u * h;
            let rom = fcu_rom(&w, base, j, h, d_in);
            let mut fcu = Fcu::new(j, h, d_in, rom, bias[base..base + h].to_vec());
            let mut got = vec![None; h];
            for batch in 0..batches {
                let mut lane = vec![0i64; j];
                for (m, l) in lane.iter_mut().enumerate() {
                    let feat = batch * j + m;
                    *l = if feat < d_in { x[feat] } else { 0 };
                }
                for _ in 0..h {
                    let out = fcu.tick(&lane);
                    if out.valid {
                        got[out.neuron] = Some(out.y);
                    }
                }
            }
            for n in 0..h {
                assert_eq!(got[n], Some(expect[base + n]), "fcu {u} neuron {n}");
            }
        }
    }

    #[test]
    fn table_iii_configuration() {
        // h=5, j=4, d_in=8 -> C=10, outputs after the 2nd batch.
        run_dense(8, 5, 4, 5, 1);
    }

    #[test]
    fn f1_running_example_configuration() {
        // F1: d_in=256, j=4, h=5, 2 FCUs, C=320.
        run_dense(256, 10, 4, 5, 2);
    }

    #[test]
    fn fully_parallel_fcu() {
        // j = d_in, h = 1: one neuron per FCU, single-cycle output.
        run_dense(16, 16, 16, 1, 3);
    }

    #[test]
    fn random_fcu_shapes() {
        let mut rng = Rng::new(0xFC);
        for _ in 0..20 {
            let j = rng.range(1, 8);
            let batches = rng.range(1, 5);
            let d_in = j * batches;
            let h = rng.range(1, 6);
            let fcus = rng.range(1, 3);
            run_dense(d_in, h * fcus, j, h, rng.next_u64());
        }
    }

    #[test]
    fn ragged_last_batch_zero_padded() {
        // d_in = 10 with j = 4: last batch has 2 real lanes.
        run_dense(10, 4, 4, 4, 9);
    }

    #[test]
    fn aggregator_widens_groups() {
        let mut agg = Aggregator::new(1, 4);
        let mut last = Vec::new();
        for i in 0..8i64 {
            let (out, refreshed) = agg.push(&[i]);
            if refreshed {
                last = out.to_vec();
            }
        }
        // After 8 pushes the latched window is [4,5,6,7].
        assert_eq!(last, vec![4, 5, 6, 7]);
        assert!(agg.filled());
    }

    #[test]
    fn aggregator_holds_between_refreshes() {
        let mut agg = Aggregator::new(2, 2);
        agg.push(&[1, 2]);
        let (out, r) = agg.push(&[3, 4]);
        assert!(r);
        assert_eq!(out, &[1, 2, 3, 4]);
        let (held, r2) = agg.push(&[5, 6]);
        assert!(!r2);
        assert_eq!(held, &[1, 2, 3, 4]); // still latched
    }

    #[test]
    fn fcu_configs_match_eq12() {
        let rom = fcu_rom(&vec![vec![0; 256]; 5], 0, 4, 5, 256);
        assert_eq!(rom.len(), 320); // C = 5 * 256 / 4
    }
}
