//! Timing-trace emission for the paper's Tables I-IV.
//!
//! The tables show, cycle by cycle, which partial sum `z_{n,i}` each
//! observable node of a KPU / FCU holds. The label attached to node
//! `(u, v)` at cycle `t` follows the closed-form relation derived from the
//! transposed structure:
//!
//! ```text
//! n = t - f*u - v          (both with and without implicit padding:
//!                           the p*f + p zero-feed offset cancels)
//! i = u*k + v
//! ```
//!
//! A label is *displayed* only when n lands inside the frame and the
//! output y_n is valid per Eq. 5 (no padding), Eq. 9 (padding) or Eq. 11
//! (stride). Crucially these labels are not trusted: [`verify_kpu_trace`]
//! recomputes every labelled cell from the structural simulator's actual
//! values against the convolution oracle, so the printed tables are
//! machine-checked.

use super::fcu::{fcu_rom, Fcu};
use super::kpu::{conv_oracle, Kpu};
use crate::util::Table;

/// Configuration of a KPU timing trace.
#[derive(Debug, Clone, Copy)]
pub struct KpuTraceCfg {
    pub f: usize,
    pub k: usize,
    pub p: usize,
    pub s: usize,
    /// Number of cycles to trace.
    pub cycles: usize,
}

/// One traced cell: the label (if displayed) and the structural value.
#[derive(Debug, Clone)]
pub struct TraceCell {
    pub label: Option<(i64, usize)>, // (n, i)
    pub value: i64,
}

/// A full KPU trace: per cycle, the input label, pad tuple, and one cell
/// per observable node (first/last tap of each row), plus the output.
#[derive(Debug)]
pub struct KpuTrace {
    pub cfg: KpuTraceCfg,
    /// Node captions, e.g. ["a11", "a13", "a21", "a23", "a31"].
    pub node_names: Vec<String>,
    /// (u, v) of each observable node, matching `node_names`.
    pub node_pos: Vec<(usize, usize)>,
    /// `rows[t]` = (input label, pad tuple, cells, y cell)
    pub rows: Vec<(String, String, Vec<TraceCell>, TraceCell)>,
}

/// Is output y_n valid (Eqs. 5 / 9 / 11)?
pub fn output_valid(n: i64, f: usize, k: usize, p: usize, s: usize) -> bool {
    if n < 0 || n >= (f * f) as i64 {
        return false;
    }
    let (r, c) = (n as usize / f, n as usize % f);
    let hi = f + 2 * p - k; // r, c in {0, s, 2s, ..., f - k + 2p}
    r <= hi && c <= hi && r % s == 0 && c % s == 0
}

/// The frame period: f*f for back-to-back unpadded frames; padded frames
/// are separated by the shared p*f + p zero-feed rows (Section III-B).
pub fn frame_period(f: usize, p: usize) -> usize {
    f * f + p * f + p
}

/// Trace a single-configuration KPU over `cfg.cycles` cycles on a ramp
/// feature map x_n = n (values chosen so every z is distinct).
pub fn trace_kpu(cfg: KpuTraceCfg) -> KpuTrace {
    let KpuTraceCfg { f, k, p, .. } = cfg;
    let xmap: Vec<i64> = (0..(f * f) as i64).collect();
    // Small distinct weights keep values readable and collisions unlikely.
    let w: Vec<i64> = (1..=(k * k) as i64).collect();
    let mut kpu = Kpu::new(k, f, p, vec![w.clone()]);
    let offset = p * f + p;
    let period = frame_period(f, p);

    // Observable nodes: (u, 0) and (u, k-1) for each row, deduplicated for
    // k = 1, dropping the final (k-1, k-1) which is the y column.
    let mut node_pos: Vec<(usize, usize)> = Vec::new();
    for u in 0..k {
        node_pos.push((u, 0));
        if k > 1 && !(u == k - 1) {
            node_pos.push((u, k - 1));
        }
    }
    let node_names: Vec<String> = node_pos
        .iter()
        .map(|(u, v)| format!("a{}{}", u + 1, v + 1))
        .collect();

    let mut rows = Vec::with_capacity(cfg.cycles);
    for t in 0..cfg.cycles {
        // Input feed: with padding, frames are separated by `offset`
        // zero cycles; without, frames stream back to back.
        let m = t as i64 - offset as i64;
        let in_frame = if p == 0 {
            true
        } else {
            m >= 0 && (m as usize % period) < f * f
        };
        let (x, col, x_label) = if p == 0 {
            let n = t % (f * f);
            (xmap[n], Some(n % f), format!("x{n}"))
        } else if in_frame {
            let n = (m as usize) % period;
            (xmap[n], Some(n % f), format!("x{n}"))
        } else {
            (0, None, "0".to_string())
        };
        let out = kpu.tick(x, col);
        let pad_label = if p == 0 || !in_frame {
            "-".to_string()
        } else {
            format!(
                "({})",
                out.pad
                    .iter()
                    .map(|&b| if b { "1" } else { "0" })
                    .collect::<Vec<_>>()
                    .join(",")
            )
        };
        let mut cells = Vec::with_capacity(node_pos.len());
        for (idx, &(u, v)) in node_pos.iter().enumerate() {
            let _ = idx;
            let n_raw = t as i64 - (f * u + v) as i64;
            let n = if n_raw >= 0 {
                n_raw % period as i64
            } else {
                -1
            };
            let displayed = n_raw >= 0 && (n as usize) < f * f && partial_displayed(n, f, k, p, cfg.s);
            cells.push(TraceCell {
                label: displayed.then_some((n, (u * k + v) as usize)),
                value: out.node(u, v),
            });
        }
        // Output column.
        let n_y = t as i64 - (f * (k - 1) + (k - 1)) as i64;
        let n_y_mod = if n_y >= 0 { n_y % period as i64 } else { -1 };
        let y_displayed = n_y >= 0 && output_valid(n_y_mod, f, k, p, cfg.s);
        rows.push((
            x_label,
            pad_label,
            cells,
            TraceCell {
                label: y_displayed.then_some((n_y_mod, k * k - 1)),
                value: out.y,
            },
        ));
    }
    KpuTrace {
        cfg,
        node_names,
        node_pos,
        rows,
    }
}

/// Display rule for intermediate partials: the paper greys out partials
/// whose terminal output is invalid (Table I's '-' cells).
fn partial_displayed(n: i64, f: usize, k: usize, p: usize, s: usize) -> bool {
    output_valid(n, f, k, p, s)
}

/// Render a KPU trace as a paper-style table.
pub fn render_kpu_trace(trace: &KpuTrace, title: &str) -> Table {
    let mut header: Vec<String> = vec!["t".into(), "x_n".into()];
    if trace.cfg.p > 0 {
        header.push("Pad".into());
    }
    header.extend(trace.node_names.iter().cloned());
    header.push("y_n".into());
    let hdr_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut t = Table::new(title, &hdr_refs);
    for (cycle, (x, pad, cells, y)) in trace.rows.iter().enumerate() {
        let mut row: Vec<String> = vec![cycle.to_string(), x.clone()];
        if trace.cfg.p > 0 {
            row.push(pad.clone());
        }
        for c in cells {
            row.push(match c.label {
                Some((n, i)) => format!("z{n},{i}"),
                None => "-".into(),
            });
        }
        row.push(match y.label {
            Some((n, _)) => format!("y{n}"),
            None => "-".into(),
        });
        t.row(&row);
    }
    t
}

/// Verify every displayed label in a KPU trace against the convolution
/// oracle: the structural value at a labelled cell must equal the partial
/// sum z_{n,i} (Eq. 3). Returns the number of checked cells.
pub fn verify_kpu_trace(trace: &KpuTrace) -> Result<usize, String> {
    let KpuTraceCfg { f, k, p, .. } = trace.cfg;
    let xmap: Vec<i64> = (0..(f * f) as i64).collect();
    let w: Vec<i64> = (1..=(k * k) as i64).collect();
    let mut checked = 0;
    for (cycle, (_, _, cells, y)) in trace.rows.iter().enumerate() {
        for (cell, &(u, v)) in cells.iter().zip(trace.node_pos.iter()) {
            if let Some((n, i)) = cell.label {
                debug_assert_eq!(i, u * k + v);
                let expect = partial_oracle(&xmap, f, k, p, &w, n as usize, i);
                if cell.value != expect {
                    return Err(format!(
                        "cycle {cycle} node a{}{}: value {} != z_({n},{i}) = {expect}",
                        u + 1,
                        v + 1,
                        cell.value
                    ));
                }
                checked += 1;
            }
        }
        if let Some((n, _)) = y.label {
            let expect = conv_oracle(&xmap, f, k, p, &w, n as usize);
            if y.value != expect {
                return Err(format!("cycle {cycle} y: {} != y_{n} = {expect}", y.value));
            }
            checked += 1;
        }
    }
    Ok(checked)
}

/// Partial-sum oracle (Eq. 3): products 0..=i of window n.
pub fn partial_oracle(
    xmap: &[i64],
    f: usize,
    k: usize,
    p: usize,
    w: &[i64],
    n: usize,
    i: usize,
) -> i64 {
    let (r, c) = (n / f, n % f);
    let mut acc = 0i64;
    for j in 0..=i {
        let (u, v) = (j / k, j % k);
        let rr = r as isize + u as isize - p as isize;
        let cc = c as isize + v as isize - p as isize;
        let x = if rr < 0 || cc < 0 || rr >= f as isize || cc >= f as isize {
            0
        } else {
            xmap[rr as usize * f + cc as usize]
        };
        acc += w[j] * x;
    }
    acc
}

/// FCU timing trace (Tables III/IV): returns a rendered table plus the
/// verified output count.
pub fn trace_fcu(d_in: usize, j: usize, h: usize, title: &str) -> (Table, usize) {
    // Ramp inputs and distinct weights, bias 0 to match the paper's table.
    let x: Vec<i64> = (0..d_in as i64).map(|v| v + 1).collect();
    let w: Vec<Vec<i64>> = (0..h)
        .map(|n| (0..d_in).map(|m| (n * d_in + m + 1) as i64).collect())
        .collect();
    let rom = fcu_rom(&w, 0, j, h, d_in);
    let mut fcu = Fcu::new(j, h, d_in, rom, vec![0; h]);
    let batches = d_in.div_ceil(j);

    let mut header: Vec<String> = vec!["t".into(), "n".into()];
    for m in 0..j {
        header.push(format!("w_i,{m}"));
    }
    header.push("q".into());
    header.push("y".into());
    let hdr: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut table = Table::new(title, &hdr);

    let expect = super::fcu::dense_oracle(&x, &w, &vec![0; h]);
    let mut verified = 0;
    let mut t = 0usize;
    for batch in 0..batches {
        let lane: Vec<i64> = (0..j)
            .map(|m| {
                let feat = batch * j + m;
                if feat < d_in {
                    x[feat]
                } else {
                    0
                }
            })
            .collect();
        for _ in 0..h {
            let out = fcu.tick(&lane);
            let cfg = batch * h + out.neuron;
            let mut row: Vec<String> = vec![t.to_string(), (batch * j).to_string()];
            for m in 0..j {
                row.push(format!("w{cfg},{m}"));
            }
            row.push(if batch == 0 {
                "0".into()
            } else {
                format!("z{},{}", out.neuron, batch * j - 1)
            });
            row.push(if out.valid {
                // Final batch: must equal the dense oracle.
                assert_eq!(out.y, expect[out.neuron], "neuron {}", out.neuron);
                verified += 1;
                format!("y{}", out.neuron)
            } else {
                format!("z{},{}", out.neuron, (batch + 1) * j - 1)
            });
            table.row(&row);
            t += 1;
        }
    }
    (table, verified)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_i_trace_verified() {
        // Table I: 5x5 map, 3x3 kernel, no padding, 25 cycles.
        let trace = trace_kpu(KpuTraceCfg {
            f: 5,
            k: 3,
            p: 0,
            s: 1,
            cycles: 25,
        });
        let checked = verify_kpu_trace(&trace).unwrap();
        assert!(checked > 30, "only {checked} labelled cells verified");
    }

    #[test]
    fn table_i_spot_labels() {
        let trace = trace_kpu(KpuTraceCfg {
            f: 5,
            k: 3,
            p: 0,
            s: 1,
            cycles: 25,
        });
        // Paper Table I: t=12 -> a11=z12,0 a13=z10,2 a21=z7,3 a23=z5,5
        // a31=z2,6 y=y0.
        let (_, _, cells, y) = &trace.rows[12];
        let labels: Vec<Option<(i64, usize)>> = cells.iter().map(|c| c.label).collect();
        assert_eq!(
            labels,
            vec![
                Some((12, 0)),
                Some((10, 2)),
                Some((7, 3)),
                Some((5, 5)),
                Some((2, 6)),
            ]
        );
        assert_eq!(y.label, Some((0, 8)));
        // t=15/16: invalid outputs (the windows highlighted in Fig. 3a).
        assert_eq!(trace.rows[15].3.label, None);
        assert_eq!(trace.rows[16].3.label, None);
        // t=3: a11 shows '-' because y_3 is invalid.
        assert_eq!(trace.rows[3].2[0].label, None);
    }

    #[test]
    fn table_ii_trace_verified_and_continuous() {
        // Table II: padding p=1, 37 cycles (one frame + lead-in/out).
        let trace = trace_kpu(KpuTraceCfg {
            f: 5,
            k: 3,
            p: 1,
            s: 1,
            cycles: 37,
        });
        verify_kpu_trace(&trace).unwrap();
        // Continuous flow at the output: y_0..y_24 on consecutive cycles
        // 12..=36.
        for (t, row) in trace.rows.iter().enumerate().take(37).skip(12) {
            let (n, _) = row.3.label.unwrap_or((-1, 0));
            assert_eq!(n, (t - 12) as i64, "cycle {t}");
        }
    }

    #[test]
    fn table_ii_pad_tuples() {
        let trace = trace_kpu(KpuTraceCfg {
            f: 5,
            k: 3,
            p: 1,
            s: 1,
            cycles: 37,
        });
        // Paper Table II: t=6 (x0) pad=(1,1,0); t=7 (x1) pad=(1,1,1);
        // t=10 (x4) pad=(0,1,1).
        assert_eq!(trace.rows[6].1, "(1,1,0)");
        assert_eq!(trace.rows[7].1, "(1,1,1)");
        assert_eq!(trace.rows[10].1, "(0,1,1)");
        assert_eq!(trace.rows[0].1, "-"); // zero-feed cycle
    }

    #[test]
    fn stride_filters_outputs() {
        // s=2: only windows at even (r, c) are valid (Eq. 11).
        let trace = trace_kpu(KpuTraceCfg {
            f: 6,
            k: 2,
            p: 0,
            s: 2,
            cycles: 36,
        });
        verify_kpu_trace(&trace).unwrap();
        let valid: Vec<i64> = trace
            .rows
            .iter()
            .filter_map(|r| r.3.label.map(|(n, _)| n))
            .collect();
        for n in &valid {
            let (r, c) = (*n as usize / 6, *n as usize % 6);
            assert_eq!((r % 2, c % 2), (0, 0));
        }
        assert!(!valid.is_empty());
    }

    #[test]
    fn fcu_trace_table_iii() {
        // Table III: h=5, j=4, d_in=8 (two batches, outputs in batch 2).
        let (table, verified) = trace_fcu(8, 4, 5, "Table III");
        assert_eq!(verified, 5);
        assert_eq!(table.rows.len(), 10);
        // First batch rows show q=0; the second batch emits y0..y4.
        assert_eq!(table.rows[0][6], "0");
        assert!(table.rows[5][7].starts_with('y'));
    }

    #[test]
    fn fcu_trace_table_iv_with_aggregation() {
        // Table IV: aggregated FCU h=4, j=4, d_in=8.
        let (_, verified) = trace_fcu(8, 4, 4, "Table IV");
        assert_eq!(verified, 4);
    }

    #[test]
    fn render_contains_paper_labels() {
        let trace = trace_kpu(KpuTraceCfg {
            f: 5,
            k: 3,
            p: 0,
            s: 1,
            cycles: 25,
        });
        let s = render_kpu_trace(&trace, "Table I").render();
        assert!(s.contains("z0,0"));
        assert!(s.contains("y0"));
        assert!(s.contains("a31"));
    }
}
