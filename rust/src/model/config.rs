//! JSON (de)serialisation of models, so users can analyse their own
//! networks: `cnn-flow analyze --model my_net.json`.
//!
//! Schema (see `examples/` and README):
//! ```json
//! {
//!   "name": "my_net",
//!   "input": {"f": 24, "d": 1},
//!   "layers": [
//!     {"type": "conv", "name": "C1", "k": 5, "s": 1, "p": 2, "filters": 8},
//!     {"type": "maxpool", "name": "P1", "k": 2, "s": 2},
//!     {"type": "residual", "name": "r1",
//!      "body": [ ... ], "projection": { ... } },
//!     {"type": "dense", "name": "F1", "units": 10}
//!   ]
//! }
//! ```

use super::{Block, Layer, LayerKind, Model, Shape};
use crate::util::json::Json;

#[derive(Debug)]
pub struct ConfigError(pub String);

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "model config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

fn err<T>(msg: impl Into<String>) -> Result<T, ConfigError> {
    Err(ConfigError(msg.into()))
}

fn usize_field(j: &Json, key: &str, default: Option<usize>) -> Result<usize, ConfigError> {
    match (j.get(key), default) {
        (Json::Null, Some(d)) => Ok(d),
        (Json::Null, None) => err(format!("missing field '{key}'")),
        (v, _) => v
            .as_usize()
            .ok_or_else(|| ConfigError(format!("field '{key}' must be a non-negative integer"))),
    }
}

fn parse_layer(j: &Json) -> Result<Layer, ConfigError> {
    let ty = j
        .get("type")
        .as_str()
        .ok_or_else(|| ConfigError("layer missing 'type'".into()))?;
    let name = j.get("name").as_str().unwrap_or(ty).to_string();
    let mut layer = match ty {
        "conv" => Layer::conv(
            &name,
            usize_field(j, "k", None)?,
            usize_field(j, "s", Some(1))?,
            usize_field(j, "p", Some(0))?,
            usize_field(j, "filters", None)?,
        ),
        "dwconv" | "depthwise" => Layer::dwconv(
            &name,
            usize_field(j, "k", None)?,
            usize_field(j, "s", Some(1))?,
            usize_field(j, "p", Some(0))?,
        ),
        "pwconv" | "pointwise" => Layer::pwconv(&name, usize_field(j, "filters", None)?),
        "maxpool" => Layer::maxpool_padded(
            &name,
            usize_field(j, "k", None)?,
            usize_field(j, "s", Some(1))?,
            usize_field(j, "p", Some(0))?,
        ),
        "avgpool" => Layer::avgpool(
            &name,
            usize_field(j, "k", None)?,
            usize_field(j, "s", Some(1))?,
        ),
        "dense" => Layer::dense(&name, usize_field(j, "units", None)?),
        other => return err(format!("unknown layer type '{other}'")),
    };
    if let Some(b) = j.get("bias").as_bool() {
        layer.bias = b;
    }
    if let Some(r) = j.get("relu").as_bool() {
        layer.relu = r;
    }
    Ok(layer)
}

fn parse_block(j: &Json) -> Result<Block, ConfigError> {
    if j.get("type").as_str() == Some("residual") {
        let name = j.get("name").as_str().unwrap_or("residual").to_string();
        let body = j
            .get("body")
            .as_arr()
            .ok_or_else(|| ConfigError("residual missing 'body' array".into()))?
            .iter()
            .map(parse_block)
            .collect::<Result<Vec<_>, _>>()?;
        let projection = match j.get("projection") {
            Json::Null => None,
            p => Some(parse_layer(p)?),
        };
        let post_relu = j.get("post_relu").as_bool().unwrap_or(true);
        Ok(Block::Residual {
            name,
            body,
            projection,
            post_relu,
        })
    } else {
        Ok(Block::Layer(parse_layer(j)?))
    }
}

/// Parse a model from JSON text.
pub fn model_from_json(text: &str) -> Result<Model, ConfigError> {
    let j = Json::parse(text).map_err(|e| ConfigError(e.to_string()))?;
    let name = j.get("name").as_str().unwrap_or("model").to_string();
    let input = j.get("input");
    let f = usize_field(input, "f", None)?;
    let d = usize_field(input, "d", Some(1))?;
    let layers = j
        .get("layers")
        .as_arr()
        .ok_or_else(|| ConfigError("missing 'layers' array".into()))?;
    let mut m = Model::new(&name, f, d);
    for lj in layers {
        m.blocks.push(parse_block(lj)?);
    }
    // Validate shapes eagerly so errors point at the config, not later use.
    m.shapes().map_err(|e| ConfigError(e.to_string()))?;
    Ok(m)
}

fn layer_to_json(l: &Layer) -> Json {
    let ty = match l.kind {
        LayerKind::Conv => "conv",
        LayerKind::DepthwiseConv => "dwconv",
        LayerKind::Pointwise => "pwconv",
        LayerKind::MaxPool => "maxpool",
        LayerKind::AvgPool => "avgpool",
        LayerKind::Dense => "dense",
    };
    let mut pairs: Vec<(&str, Json)> = vec![("type", ty.into()), ("name", l.name.as_str().into())];
    match l.kind {
        LayerKind::Dense => pairs.push(("units", l.filters.into())),
        LayerKind::Pointwise => pairs.push(("filters", l.filters.into())),
        _ => {
            pairs.push(("k", l.k.into()));
            pairs.push(("s", l.s.into()));
            pairs.push(("p", l.p.into()));
            if l.kind == LayerKind::Conv {
                pairs.push(("filters", l.filters.into()));
            }
        }
    }
    pairs.push(("bias", Json::Bool(l.bias)));
    pairs.push(("relu", Json::Bool(l.relu)));
    Json::obj(pairs)
}

fn block_to_json(b: &Block) -> Json {
    match b {
        Block::Layer(l) => layer_to_json(l),
        Block::Residual {
            name,
            body,
            projection,
            post_relu,
        } => {
            let mut pairs: Vec<(&str, Json)> = vec![
                ("type", "residual".into()),
                ("name", name.as_str().into()),
                ("body", Json::Arr(body.iter().map(block_to_json).collect())),
            ];
            if let Some(p) = projection {
                pairs.push(("projection", layer_to_json(p)));
            }
            pairs.push(("post_relu", Json::Bool(*post_relu)));
            Json::obj(pairs)
        }
    }
}

/// Serialise a model to pretty JSON.
pub fn model_to_json(m: &Model) -> String {
    let Shape { f, d } = m.input;
    Json::obj(vec![
        ("name", m.name.as_str().into()),
        ("input", Json::obj(vec![("f", f.into()), ("d", d.into())])),
        (
            "layers",
            Json::Arr(m.blocks.iter().map(block_to_json).collect()),
        ),
    ])
    .render_pretty()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn roundtrip_all_zoo_models() {
        for m in zoo::all_models() {
            let text = model_to_json(&m);
            let back = model_from_json(&text)
                .unwrap_or_else(|e| panic!("{}: {e}\n{text}", m.name));
            // Shapes and params must survive the roundtrip (layer filter
            // defaults may be filled in, so compare semantics not structs).
            assert_eq!(
                m.shapes().unwrap().len(),
                back.shapes().unwrap().len(),
                "{}",
                m.name
            );
            assert_eq!(
                m.param_count().unwrap(),
                back.param_count().unwrap(),
                "{}",
                m.name
            );
        }
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(model_from_json(r#"{"input":{"f":8},"layers":[{"type":"conv"}]}"#).is_err());
        assert!(model_from_json(r#"{"layers":[]}"#).is_err());
        assert!(model_from_json("not json").is_err());
    }

    #[test]
    fn rejects_invalid_shapes() {
        // 5x5 pool on an 3x3 input must fail at load time.
        let bad = r#"{"name":"x","input":{"f":3,"d":1},
            "layers":[{"type":"maxpool","k":5,"s":5}]}"#;
        assert!(model_from_json(bad).is_err());
    }

    #[test]
    fn unknown_layer_type_rejected() {
        let bad = r#"{"input":{"f":8,"d":1},"layers":[{"type":"transformer"}]}"#;
        assert!(model_from_json(bad).is_err());
    }

    #[test]
    fn bias_relu_flags_roundtrip() {
        let src = r#"{"input":{"f":8,"d":1},"layers":[
            {"type":"conv","k":3,"s":1,"p":1,"filters":4,"bias":false,"relu":false}]}"#;
        let m = model_from_json(src).unwrap();
        let l = &m.shapes().unwrap()[0].layer;
        assert!(!l.bias);
        assert!(!l.relu);
    }
}
