//! Model zoo: every network the paper analyses or synthesises.
//!
//! - [`running_example`] — the 5-layer CNN of Table V.
//! - [`mobilenet_v1`] — MobileNetV1 with width multiplier alpha (Table VIII/IX).
//! - [`resnet18`] — ResNet18 (Table VIII).
//! - [`jsc_mlp`] — the 16-16-5 jet-substructure-classification MLP (Table X).
//! - [`digits_cnn`] — the small trainable CNN used by the end-to-end
//!   serving experiment (E12); same topology class as the running example
//!   but sized so QAT on synthetic digits converges in seconds.

use super::{Block, Layer, Model};

/// The running example of Section IV-A / Table V:
/// C1 conv 5x5 p2 (1->8), P1 maxpool 2x2 s2, C2 conv 5x5 p2 (8->16),
/// P2 maxpool 3x3 s3, F1 dense 10. Input 24x24x1.
pub fn running_example() -> Model {
    let mut m = Model::new("running_example", 24, 1);
    m.push(Layer::conv("C1", 5, 1, 2, 8));
    m.push(Layer::maxpool("P1", 2, 2));
    m.push(Layer::conv("C2", 5, 1, 2, 16));
    m.push(Layer::maxpool("P2", 3, 3));
    m.push(Layer::dense("F1", 10));
    m
}

/// Apply the MobileNet width multiplier. The original paper rounds to
/// multiples of 8 but all four published alphas produce exact multiples
/// anyway (e.g. 64 * 0.25 = 16), so plain rounding is equivalent here.
fn scale(c: usize, alpha_pct: usize) -> usize {
    ((c * alpha_pct + 50) / 100).max(1)
}

/// MobileNetV1 at width multiplier `alpha_pct` (percent: 25, 50, 75, 100).
///
/// conv 3x3 s2 -> 13 depthwise-separable blocks -> global avgpool -> FC 1000.
/// The global average pool is expressed as a depthwise conv with constant
/// weights (Section VI), which [`crate::complexity`] costs as an
/// [`super::LayerKind::AvgPool`].
pub fn mobilenet_v1(alpha_pct: usize) -> Model {
    assert!(alpha_pct > 0);
    let a = |c| scale(c, alpha_pct);
    let mut m = Model::new(&format!("mobilenet_v1_a{alpha_pct}"), 224, 3);
    m.push(Layer::conv("conv1", 3, 2, 1, a(32)));
    // (pointwise filters, dw stride) for the 13 separable blocks.
    let blocks: [(usize, usize); 13] = [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    for (i, (filters, stride)) in blocks.iter().enumerate() {
        m.push(Layer::dwconv(&format!("dw{}", i + 1), 3, *stride, 1));
        m.push(Layer::pwconv(&format!("pw{}", i + 1), a(*filters)));
    }
    m.push(Layer::avgpool("avgpool", 7, 7));
    m.push(Layer::dense("fc", 1000));
    m
}

/// ResNet18: conv7x7 s2, maxpool3x3 s2, four stages of two basic blocks
/// (64, 128, 256, 512 channels; stride-2 projection block at the start of
/// stages 2-4), global avgpool, FC 1000.
pub fn resnet18() -> Model {
    let mut m = Model::new("resnet18", 224, 3);
    m.push(Layer::conv("conv1", 7, 2, 3, 64));
    m.push(Layer::maxpool_padded("maxpool", 3, 2, 1));
    let stages: [(usize, usize); 4] = [(64, 1), (128, 2), (256, 2), (512, 2)];
    for (si, (ch, first_stride)) in stages.iter().enumerate() {
        for bi in 0..2 {
            let stride = if bi == 0 { *first_stride } else { 1 };
            let name = format!("res{}_{}", si + 2, bi + 1);
            let body = vec![
                Block::Layer(Layer::conv(&format!("{name}a"), 3, stride, 1, *ch)),
                Block::Layer(Layer::conv(&format!("{name}b"), 3, 1, 1, *ch).no_relu()),
            ];
            let projection = if stride != 1 || (si > 0 && bi == 0) {
                Some(Layer::conv(&format!("{name}p"), 1, stride, 0, *ch).no_relu())
            } else {
                None
            };
            m.blocks.push(Block::Residual {
                name,
                body,
                projection,
                post_relu: true,
            });
        }
    }
    m.push(Layer::avgpool("avgpool", 7, 7));
    m.push(Layer::dense("fc", 1000));
    m
}

/// The jet-substructure-classification MLP of Section VII, experiment 2:
/// 16 input features -> dense 16 -> dense 16 -> dense 5. Input is modelled
/// as a 1x1 "pixel" with 16 channels so that the full input data rate is
/// r0 = d0 = 16, matching Table X's r0 = 16 fully-parallel row.
pub fn jsc_mlp() -> Model {
    let mut m = Model::new("jsc_mlp", 1, 16);
    m.push(Layer::dense("fc1", 16));
    m.blocks.last_mut().map(|b| {
        if let Block::Layer(l) = b {
            l.relu = true;
        }
    });
    m.push(Layer::dense("fc2", 16));
    m.blocks.last_mut().map(|b| {
        if let Block::Layer(l) = b {
            l.relu = true;
        }
    });
    m.push(Layer::dense("fc3", 5));
    m
}

/// Small trainable CNN for the end-to-end experiment (E12): 12x12x1
/// synthetic digit images, conv 3x3 p1 (1->4), maxpool 2x2, conv 3x3 p1
/// (4->8), maxpool 2x2, dense 10. ~1.1k parameters — trains to >95% on the
/// synthetic digits in a few hundred QAT steps while still exercising
/// every continuous-flow mechanism (stride-induced rate drops x2,
/// interleaving, FCU weight cycling).
pub fn digits_cnn() -> Model {
    let mut m = Model::new("digits_cnn", 12, 1);
    m.push(Layer::conv("C1", 3, 1, 1, 4));
    m.push(Layer::maxpool("P1", 2, 2));
    m.push(Layer::conv("C2", 3, 1, 1, 8));
    m.push(Layer::maxpool("P2", 2, 2));
    m.push(Layer::dense("F1", 10));
    m
}

/// LeNet-5-style CNN (32x32x1): the classic small CNN, included to widen
/// the analysis sweeps beyond the paper's own models.
pub fn lenet5() -> Model {
    let mut m = Model::new("lenet5", 32, 1);
    m.push(Layer::conv("C1", 5, 1, 0, 6));
    m.push(Layer::maxpool("S2", 2, 2));
    m.push(Layer::conv("C3", 5, 1, 0, 16));
    m.push(Layer::maxpool("S4", 2, 2));
    m.push(Layer::conv("C5", 5, 1, 0, 120));
    m.push(Layer::dense("F6", 84));
    m.push(Layer::dense("OUT", 10));
    m
}

/// A VGG-style all-3x3 CNN scaled to 64x64 input — stresses the analysis
/// with deep same-padding stacks and repeated rate halvings.
pub fn vgg_tiny() -> Model {
    let mut m = Model::new("vgg_tiny", 64, 3);
    let mut block = |m: &mut Model, idx: usize, ch: usize, convs: usize| {
        for c in 0..convs {
            m.push(Layer::conv(&format!("conv{idx}_{c}"), 3, 1, 1, ch));
        }
        m.push(Layer::maxpool(&format!("pool{idx}"), 2, 2));
    };
    block(&mut m, 1, 16, 2);
    block(&mut m, 2, 32, 2);
    block(&mut m, 3, 64, 3);
    block(&mut m, 4, 128, 3);
    m.push(Layer::dense("fc1", 128));
    m.push(Layer::dense("fc2", 10));
    m
}

/// MobileNet-like depthwise-separable stack at serving scale (16x16x1
/// input): first standard conv, then three dw/pw blocks (one stride-2),
/// global-ish average pool, dense head. Small enough that the full
/// compiled/batched serving path (and its interpreter oracle) runs it in
/// test time, while still exercising every MobileNet mechanism the
/// paper's lowering cares about — depthwise kernels, FCU-mapped pointwise
/// layers, stride-induced rate drops, and the avgpool-as-dwconv trick.
pub fn mobilenet_micro() -> Model {
    let mut m = Model::new("mobilenet_micro", 16, 1);
    m.push(Layer::conv("c1", 3, 1, 1, 8));
    m.push(Layer::dwconv("dw1", 3, 1, 1));
    m.push(Layer::pwconv("pw1", 16));
    m.push(Layer::dwconv("dw2", 3, 2, 1));
    m.push(Layer::pwconv("pw2", 24));
    m.push(Layer::dwconv("dw3", 3, 1, 1));
    m.push(Layer::pwconv("pw3", 32));
    m.push(Layer::avgpool("ap", 2, 2));
    m.push(Layer::dense("fc", 10));
    m
}

/// VGG-style all-3x3 net at serving scale (16x16x1 input): two
/// double-conv + maxpool stages and a two-layer dense head — the deep
/// same-padding stack shape of [`vgg_tiny`], sized for the serving tests.
pub fn vgg_micro() -> Model {
    let mut m = Model::new("vgg_micro", 16, 1);
    m.push(Layer::conv("conv1_0", 3, 1, 1, 8));
    m.push(Layer::conv("conv1_1", 3, 1, 1, 8));
    m.push(Layer::maxpool("pool1", 2, 2));
    m.push(Layer::conv("conv2_0", 3, 1, 1, 16));
    m.push(Layer::conv("conv2_1", 3, 1, 1, 16));
    m.push(Layer::maxpool("pool2", 2, 2));
    m.push(Layer::dense("fc1", 24));
    m.push(Layer::dense("fc2", 10));
    m
}

/// ResNet-style residual CNN at serving scale (12x12x1 input): a stem
/// conv, one identity-shortcut basic block, one stride-2 projection
/// block, average pool and dense head. Both shortcut flavours of the
/// paper's delay-balancing story (Section VI) in the smallest model the
/// full serving path can replay in test time.
pub fn resnet_micro() -> Model {
    let mut m = Model::new("resnet_micro", 12, 1);
    m.push(Layer::conv("c1", 3, 1, 1, 8));
    m.blocks.push(Block::Residual {
        name: "r1".into(),
        body: vec![
            Block::Layer(Layer::conv("r1a", 3, 1, 1, 8)),
            Block::Layer(Layer::conv("r1b", 3, 1, 1, 8).no_relu()),
        ],
        projection: None,
        post_relu: true,
    });
    m.blocks.push(Block::Residual {
        name: "r2".into(),
        body: vec![
            Block::Layer(Layer::conv("r2a", 3, 2, 1, 16)),
            Block::Layer(Layer::conv("r2b", 3, 1, 1, 16).no_relu()),
        ],
        projection: Some(Layer::conv("r2p", 1, 2, 0, 16).no_relu()),
        post_relu: true,
    });
    m.push(Layer::avgpool("ap", 2, 2));
    m.push(Layer::dense("fc", 10));
    m
}

/// MobileNetV2-style inverted-residual stack at serving scale (12x12x1
/// input): expand/depthwise/project bottlenecks whose linear (no ReLU)
/// identity shortcuts merge without a post-add activation, plus a
/// stride-2 non-residual bottleneck between them.
pub fn mobilenet_v2_micro() -> Model {
    let mut m = Model::new("mobilenet_v2_micro", 12, 1);
    m.push(Layer::conv("c1", 3, 1, 1, 8));
    m.blocks.push(Block::Residual {
        name: "mb1".into(),
        body: vec![
            Block::Layer(Layer::pwconv("mb1e", 16)),
            Block::Layer(Layer::dwconv("mb1d", 3, 1, 1)),
            Block::Layer(Layer::pwconv("mb1p", 8).no_relu()),
        ],
        projection: None,
        post_relu: false,
    });
    m.push(Layer::dwconv("dw2", 3, 2, 1));
    m.push(Layer::pwconv("pw2", 16));
    m.blocks.push(Block::Residual {
        name: "mb2".into(),
        body: vec![
            Block::Layer(Layer::pwconv("mb2e", 24)),
            Block::Layer(Layer::dwconv("mb2d", 3, 1, 1)),
            Block::Layer(Layer::pwconv("mb2p", 16).no_relu()),
        ],
        projection: None,
        post_relu: false,
    });
    m.push(Layer::avgpool("ap", 2, 2));
    m.push(Layer::dense("fc", 10));
    m
}

/// The serving zoo: every config sized to run through the full
/// compiled/batched serving path (registry lowering, shard groups,
/// differential tests) in test time — chains plus the residual
/// [`resnet_micro`] / [`mobilenet_v2_micro`] DAGs. These are the models
/// `serve --models a,b,c` accepts and `tests/prop_compiled.rs` pins
/// bit-identical across interpreter / `execute` / `execute_batch`.
pub fn serving_zoo() -> Vec<Model> {
    vec![
        digits_cnn(),
        mobilenet_micro(),
        vgg_micro(),
        jsc_mlp(),
        resnet_micro(),
        mobilenet_v2_micro(),
    ]
}

/// Every model in the zoo, for CLI listing and sweep harnesses.
pub fn all_models() -> Vec<Model> {
    vec![
        running_example(),
        mobilenet_v1(25),
        mobilenet_v1(50),
        mobilenet_v1(75),
        mobilenet_v1(100),
        resnet18(),
        jsc_mlp(),
        digits_cnn(),
        lenet5(),
        vgg_tiny(),
        mobilenet_micro(),
        vgg_micro(),
        resnet_micro(),
        mobilenet_v2_micro(),
    ]
}

/// Look a zoo model up by name (used by the CLI).
pub fn by_name(name: &str) -> Option<Model> {
    match name {
        "running_example" | "running" => Some(running_example()),
        "mobilenet_v1_a25" | "mobilenet0.25" => Some(mobilenet_v1(25)),
        "mobilenet_v1_a50" | "mobilenet0.5" => Some(mobilenet_v1(50)),
        "mobilenet_v1_a75" | "mobilenet0.75" => Some(mobilenet_v1(75)),
        "mobilenet_v1_a100" | "mobilenet1.0" | "mobilenet" => Some(mobilenet_v1(100)),
        "resnet18" => Some(resnet18()),
        "jsc_mlp" | "jsc" => Some(jsc_mlp()),
        "digits_cnn" | "digits" => Some(digits_cnn()),
        "lenet5" | "lenet" => Some(lenet5()),
        "vgg_tiny" | "vgg" => Some(vgg_tiny()),
        "mobilenet_micro" => Some(mobilenet_micro()),
        "vgg_micro" => Some(vgg_micro()),
        "resnet_micro" => Some(resnet_micro()),
        "mobilenet_v2_micro" | "mbv2_micro" => Some(mobilenet_v2_micro()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Shape;

    #[test]
    fn mobilenet_output_shapes() {
        for alpha in [25, 50, 75, 100] {
            let m = mobilenet_v1(alpha);
            let out = m.output_shape().unwrap();
            assert_eq!(out, Shape { f: 1, d: 1000 }, "alpha={alpha}");
        }
    }

    #[test]
    fn mobilenet_spatial_progression() {
        let m = mobilenet_v1(100);
        let shapes = m.shapes().unwrap();
        // conv1: 224 -> 112; final dw block output must be 7x7 before pool.
        assert_eq!(shapes[0].output.f, 112);
        let before_pool = shapes[shapes.len() - 3].output;
        assert_eq!(before_pool.f, 7);
        assert_eq!(before_pool.d, 1024);
    }

    #[test]
    fn mobilenet_param_counts_match_table_viii() {
        // Table VIII Param. column: 470k / 1.3M / 2.6M / 4.2M.
        let p25 = mobilenet_v1(25).param_count().unwrap();
        let p50 = mobilenet_v1(50).param_count().unwrap();
        let p75 = mobilenet_v1(75).param_count().unwrap();
        let p100 = mobilenet_v1(100).param_count().unwrap();
        assert!((460_000..=480_000).contains(&p25), "a=0.25: {p25}");
        assert!((1_250_000..=1_400_000).contains(&p50), "a=0.5: {p50}");
        assert!((2_500_000..=2_700_000).contains(&p75), "a=0.75: {p75}");
        assert!((4_100_000..=4_300_000).contains(&p100), "a=1.0: {p100}");
    }

    #[test]
    fn resnet18_shapes_and_params() {
        let m = resnet18();
        assert_eq!(m.output_shape().unwrap(), Shape { f: 1, d: 1000 });
        // Table VIII: 11.7M parameters.
        let p = m.param_count().unwrap();
        assert!((11_100_000..=12_000_000).contains(&p), "params {p}");
    }

    #[test]
    fn resnet18_has_8_residual_blocks() {
        let m = resnet18();
        let res = m
            .blocks
            .iter()
            .filter(|b| matches!(b, Block::Residual { .. }))
            .count();
        assert_eq!(res, 8);
    }

    #[test]
    fn jsc_mlp_structure() {
        let m = jsc_mlp();
        let shapes = m.shapes().unwrap();
        assert_eq!(shapes.len(), 3);
        assert_eq!(shapes[0].input.features(), 16);
        assert_eq!(m.output_shape().unwrap().d, 5);
        // 16*16+16 + 16*16+16 + 16*5+5 = 629 params
        assert_eq!(m.param_count().unwrap(), 629);
    }

    #[test]
    fn alpha_scaling() {
        assert_eq!(scale(64, 25), 16);
        assert_eq!(scale(1024, 75), 768);
        assert_eq!(scale(32, 50), 16);
        assert_eq!(scale(1, 25), 1); // floor at 1
    }

    #[test]
    fn by_name_roundtrip() {
        for m in all_models() {
            assert!(by_name(&m.name).is_some(), "{} not resolvable", m.name);
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn lenet5_shapes() {
        let m = lenet5();
        assert_eq!(m.output_shape().unwrap(), Shape { f: 1, d: 10 });
        // Classic LeNet-5 parameter count ~61.7k (with conv C5 as conv).
        let p = m.param_count().unwrap();
        assert!((55_000..70_000).contains(&p), "params {p}");
    }

    #[test]
    fn vgg_tiny_rate_progression() {
        use crate::flow::analyze;
        let m = vgg_tiny();
        assert_eq!(m.output_shape().unwrap(), Shape { f: 1, d: 10 });
        // Every pooling stage divides the rate by 4; convs multiply by the
        // channel expansion. No layer should stall at full input rate.
        let a = analyze(&m, None).unwrap();
        for l in &a.layers {
            assert!(!l.r_out.is_zero());
        }
    }

    #[test]
    fn serving_zoo_shapes_resolve_and_stay_small() {
        for m in serving_zoo() {
            m.shapes().unwrap();
            m.links().unwrap();
            assert!(
                m.input.features() <= 16 * 16 * 3,
                "{}: serving zoo must stay test-sized",
                m.name
            );
            assert_eq!(m.output_shape().unwrap().f, 1, "{}", m.name);
        }
        // Both residual flavours are represented in the serving zoo.
        let has_merge = |m: &Model| m.links().unwrap().iter().any(|l| l.merge.is_some());
        assert!(serving_zoo().iter().any(has_merge));
        assert!(serving_zoo().iter().any(|m| !has_merge(m)));
    }

    #[test]
    fn resnet_micro_progression() {
        let m = resnet_micro();
        assert_eq!(m.output_shape().unwrap(), Shape { f: 1, d: 10 });
        let shapes = m.shapes().unwrap();
        // c1, r1a, r1b, r2a, r2b, r2p, ap, fc
        assert_eq!(shapes.len(), 8);
        assert!(shapes[2].merges && shapes[5].merges);
        assert_eq!((shapes[4].output.f, shapes[4].output.d), (6, 16));
        let links = m.links().unwrap();
        // Identity shortcut on r1b; r2b merges into the projection node.
        assert_eq!(links[2].merge.unwrap().with, Some(0));
        assert_eq!(links[5].src, Some(2));
        assert_eq!(links[5].merge.unwrap().with, Some(4));
        assert_eq!(links[6].src, Some(5));
    }

    #[test]
    fn mobilenet_v2_micro_progression() {
        let m = mobilenet_v2_micro();
        assert_eq!(m.output_shape().unwrap(), Shape { f: 1, d: 10 });
        let links = m.links().unwrap();
        let merges: Vec<usize> = links
            .iter()
            .enumerate()
            .filter(|(_, l)| l.merge.is_some())
            .map(|(i, _)| i)
            .collect();
        assert_eq!(merges.len(), 2);
        // Linear bottlenecks: no ReLU after either addition.
        assert!(merges
            .iter()
            .all(|&i| !links[i].merge.unwrap().post_relu));
    }

    #[test]
    fn mobilenet_micro_progression() {
        let m = mobilenet_micro();
        let shapes = m.shapes().unwrap();
        // conv1 16x16x8; dw2 halves to 8x8; avgpool to 4x4x32; fc 10.
        assert_eq!((shapes[0].output.f, shapes[0].output.d), (16, 8));
        let ap = &shapes[shapes.len() - 2];
        assert_eq!((ap.output.f, ap.output.d), (4, 32));
        assert_eq!(m.output_shape().unwrap(), Shape { f: 1, d: 10 });
    }

    #[test]
    fn vgg_micro_progression() {
        let m = vgg_micro();
        assert_eq!(m.output_shape().unwrap(), Shape { f: 1, d: 10 });
        let shapes = m.shapes().unwrap();
        // Two pool halvings: 16 -> 8 -> 4 before the dense head.
        let before_fc = &shapes[shapes.len() - 3];
        assert_eq!((before_fc.output.f, before_fc.output.d), (4, 16));
    }

    #[test]
    fn digits_cnn_small() {
        let m = digits_cnn();
        let p = m.param_count().unwrap();
        assert!(p < 2000, "digits cnn should stay tiny, got {p}");
        assert_eq!(m.output_shape().unwrap().d, 10);
    }
}
