//! Layer-graph IR for continuous-flow CNNs (system S1).
//!
//! The paper analyses CNNs as a sequence of layers, each characterised by
//! the feature-map size `f`, kernel size `k`, stride `s`, padding `p`, and
//! channel counts `d_{l-1}` / `d_l` (Table V). Residual topologies
//! (ResNet) are expressed with [`Block::Residual`]; everything else is a
//! plain chain. Shapes are propagated by [`Model::shapes`], which is the
//! single source of truth the flow analysis, complexity model, simulator,
//! and code paths in `python/compile/model.py` all agree on.

pub mod config;
pub mod zoo;

/// The kind of a layer. `Pointwise` is a 1x1 convolution, kept distinct
/// because the paper implements it with FCUs instead of KPUs (Section IV-C).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerKind {
    /// Standard convolution: every filter reads every input channel.
    Conv,
    /// Depthwise convolution (g = d_{l-1} groups, one kernel per channel).
    DepthwiseConv,
    /// Pointwise (1x1) convolution, implemented as FCUs.
    Pointwise,
    /// Max pooling.
    MaxPool,
    /// Average pooling (implemented as a depthwise conv with constant
    /// weights 1/k^2, per Section VI).
    AvgPool,
    /// Fully connected layer over the flattened input tensor.
    Dense,
}

impl LayerKind {
    pub fn is_pool(self) -> bool {
        matches!(self, LayerKind::MaxPool | LayerKind::AvgPool)
    }

    pub fn short(&self) -> &'static str {
        match self {
            LayerKind::Conv => "conv",
            LayerKind::DepthwiseConv => "dwconv",
            LayerKind::Pointwise => "pwconv",
            LayerKind::MaxPool => "maxpool",
            LayerKind::AvgPool => "avgpool",
            LayerKind::Dense => "dense",
        }
    }
}

/// One layer of the network.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Layer {
    /// Display name ("C1", "P2", "dw3", ...).
    pub name: String,
    pub kind: LayerKind,
    /// Kernel size k (k x k window). 0 for Dense (derived as k = f).
    pub k: usize,
    /// Stride s.
    pub s: usize,
    /// Zero padding p on each side. The paper's continuous-flow condition
    /// for s = 1 is p = (k-1)/2 (Section III-B).
    pub p: usize,
    /// Number of output channels d_l. For pooling and depthwise layers
    /// this must equal the input channel count and may be set to 0 to mean
    /// "same as input".
    pub filters: usize,
    /// Whether the layer has a per-output-channel bias.
    pub bias: bool,
    /// Whether a ReLU follows (cost-free in the paper's model; recorded
    /// for the simulator and the JAX model).
    pub relu: bool,
}

impl Layer {
    pub fn conv(name: &str, k: usize, s: usize, p: usize, filters: usize) -> Self {
        Self {
            name: name.into(),
            kind: LayerKind::Conv,
            k,
            s,
            p,
            filters,
            bias: true,
            relu: true,
        }
    }

    pub fn dwconv(name: &str, k: usize, s: usize, p: usize) -> Self {
        Self {
            name: name.into(),
            kind: LayerKind::DepthwiseConv,
            k,
            s,
            p,
            filters: 0,
            bias: true,
            relu: true,
        }
    }

    pub fn pwconv(name: &str, filters: usize) -> Self {
        Self {
            name: name.into(),
            kind: LayerKind::Pointwise,
            k: 1,
            s: 1,
            p: 0,
            filters,
            bias: true,
            relu: true,
        }
    }

    pub fn maxpool(name: &str, k: usize, s: usize) -> Self {
        Self {
            name: name.into(),
            kind: LayerKind::MaxPool,
            k,
            s,
            p: 0,
            filters: 0,
            bias: false,
            relu: false,
        }
    }

    pub fn maxpool_padded(name: &str, k: usize, s: usize, p: usize) -> Self {
        Self {
            p,
            ..Self::maxpool(name, k, s)
        }
    }

    pub fn avgpool(name: &str, k: usize, s: usize) -> Self {
        Self {
            name: name.into(),
            kind: LayerKind::AvgPool,
            k,
            s,
            p: 0,
            filters: 0,
            bias: false,
            relu: false,
        }
    }

    pub fn dense(name: &str, units: usize) -> Self {
        Self {
            name: name.into(),
            kind: LayerKind::Dense,
            k: 0,
            s: 1,
            p: 0,
            filters: units,
            bias: true,
            relu: false,
        }
    }

    pub fn no_relu(mut self) -> Self {
        self.relu = false;
        self
    }

    pub fn no_bias(mut self) -> Self {
        self.bias = false;
        self
    }
}

/// A block: a single layer or a residual group (body + optional
/// projection shortcut) merged by elementwise addition, as in ResNet.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Block {
    Layer(Layer),
    Residual {
        name: String,
        body: Vec<Block>,
        /// `None` = identity shortcut; `Some(conv1x1)` = projection.
        projection: Option<Layer>,
        /// ReLU after the elementwise addition (ResNet style). MobileNetV2
        /// bottlenecks merge linearly (`false`).
        post_relu: bool,
    },
}

impl Block {
    /// Iterate over contained layers depth-first (body before projection).
    pub fn layers(&self) -> Vec<&Layer> {
        match self {
            Block::Layer(l) => vec![l],
            Block::Residual {
                body, projection, ..
            } => {
                let mut v: Vec<&Layer> = body.iter().flat_map(|b| b.layers()).collect();
                if let Some(p) = projection {
                    v.push(p);
                }
                v
            }
        }
    }
}

/// The spatial/channel shape of a tensor flowing between layers:
/// an `f x f` feature map with `d` channels. Dense layers flatten to
/// `f = 1`, `d = f^2 * d` of their input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shape {
    pub f: usize,
    pub d: usize,
}

impl Shape {
    pub fn features(&self) -> usize {
        self.f * self.f * self.d
    }
}

/// A whole model: named input shape plus a chain of blocks.
#[derive(Debug, Clone, PartialEq)]
pub struct Model {
    pub name: String,
    pub input: Shape,
    pub blocks: Vec<Block>,
}

/// Shape-propagation error.
#[derive(Debug, PartialEq, Eq)]
pub enum ShapeError {
    /// Window larger than (padded) feature map.
    WindowTooLarge { layer: String, f: usize, k: usize },
    /// Residual branches produced different shapes.
    ResidualMismatch {
        block: String,
        body: Shape,
        shortcut: Shape,
    },
    /// Stride or kernel of zero, etc.
    BadParam { layer: String, what: String },
}

impl std::fmt::Display for ShapeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShapeError::WindowTooLarge { layer, f: fm, k } => {
                write!(f, "layer {layer}: kernel {k} larger than feature map {fm}")
            }
            ShapeError::ResidualMismatch {
                block,
                body,
                shortcut,
            } => write!(
                f,
                "residual {block}: body {body:?} != shortcut {shortcut:?}"
            ),
            ShapeError::BadParam { layer, what } => write!(f, "layer {layer}: {what}"),
        }
    }
}

impl std::error::Error for ShapeError {}

/// Output shape of a single layer given its input shape.
pub fn layer_output_shape(layer: &Layer, input: Shape) -> Result<Shape, ShapeError> {
    if layer.s == 0 {
        return Err(ShapeError::BadParam {
            layer: layer.name.clone(),
            what: "stride 0".into(),
        });
    }
    match layer.kind {
        LayerKind::Dense => Ok(Shape {
            f: 1,
            d: layer.filters,
        }),
        LayerKind::Pointwise => Ok(Shape {
            f: input.f,
            d: layer.filters,
        }),
        _ => {
            if layer.k == 0 {
                return Err(ShapeError::BadParam {
                    layer: layer.name.clone(),
                    what: "kernel 0".into(),
                });
            }
            let padded = input.f + 2 * layer.p;
            if layer.k > padded {
                return Err(ShapeError::WindowTooLarge {
                    layer: layer.name.clone(),
                    f: input.f,
                    k: layer.k,
                });
            }
            let f_out = (padded - layer.k) / layer.s + 1;
            let d = match layer.kind {
                LayerKind::Conv => layer.filters,
                // depthwise/pool keep the channel count
                _ => input.d,
            };
            Ok(Shape { f: f_out, d })
        }
    }
}

/// Where a residual addition folds another node's output into the node
/// that carries it (part of [`NodeLink`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MergeLink {
    /// The other branch merged into this node's output (`None` = the
    /// model input).
    pub with: Option<usize>,
    /// ReLU after the addition (ResNet) vs linear merge (MobileNetV2).
    pub post_relu: bool,
}

/// Dataflow link of one flat node, in [`Model::shapes`] order: which
/// node's output it consumes (`None` = the model input) and, if it is the
/// merge point of a residual block, which other node merges into it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeLink {
    pub src: Option<usize>,
    pub merge: Option<MergeLink>,
}

impl NodeLink {
    /// A plain chain link: node `i` reads node `i - 1` (or the input).
    pub fn chain(i: usize) -> Self {
        NodeLink {
            src: i.checked_sub(1),
            merge: None,
        }
    }
}

/// A layer together with its resolved input/output shapes, produced by
/// [`Model::shapes`]. `merge_of` marks the *last* layer of a residual body
/// whose output is merged with the shortcut.
#[derive(Debug, Clone, PartialEq)]
pub struct ShapedLayer {
    pub layer: Layer,
    pub input: Shape,
    pub output: Shape,
    /// True if this layer's output feeds a residual merge (addition).
    pub merges: bool,
}

impl Model {
    pub fn new(name: &str, f: usize, d: usize) -> Self {
        Self {
            name: name.into(),
            input: Shape { f, d },
            blocks: Vec::new(),
        }
    }

    pub fn push(&mut self, layer: Layer) -> &mut Self {
        self.blocks.push(Block::Layer(layer));
        self
    }

    /// All layers in analysis order (residual bodies inline, projection
    /// after the body), with shapes resolved. Channel counts of
    /// pool/depthwise layers are filled in from the input.
    pub fn shapes(&self) -> Result<Vec<ShapedLayer>, ShapeError> {
        let mut out = Vec::new();
        let mut cur = self.input;
        for b in &self.blocks {
            cur = shape_block(b, cur, &mut out)?;
        }
        Ok(out)
    }

    /// Output shape of the whole model.
    pub fn output_shape(&self) -> Result<Shape, ShapeError> {
        Ok(self
            .shapes()?
            .last()
            .map(|l| l.output)
            .unwrap_or(self.input))
    }

    /// Total number of trainable parameters (weights + biases), used for
    /// the "Param." column of Table VIII.
    pub fn param_count(&self) -> Result<u64, ShapeError> {
        let mut total = 0u64;
        for sl in self.shapes()? {
            let l = &sl.layer;
            let weights = match l.kind {
                LayerKind::Conv => (l.k * l.k * sl.input.d * sl.output.d) as u64,
                LayerKind::DepthwiseConv => (l.k * l.k * sl.input.d) as u64,
                LayerKind::Pointwise => (sl.input.d * sl.output.d) as u64,
                LayerKind::Dense => (sl.input.features() * sl.output.d) as u64,
                LayerKind::MaxPool | LayerKind::AvgPool => 0,
            };
            let biases = if l.bias && weights > 0 {
                sl.output.d as u64
            } else {
                0
            };
            total += weights + biases;
        }
        Ok(total)
    }

    /// Convenience: flat layer list without shapes.
    pub fn layers(&self) -> Vec<&Layer> {
        self.blocks.iter().flat_map(|b| b.layers()).collect()
    }

    /// Dataflow links of every flat node, parallel to [`Model::shapes`]:
    /// each entry says which node the layer reads and, at residual merge
    /// points, which other node is added in. Chains get
    /// `[NodeLink::chain(0), NodeLink::chain(1), ...]`. Rejects the one
    /// shape `shapes()` tolerates but single-merge dataflow cannot
    /// express: an identity-shortcut block whose merge target already
    /// carries a merge of its own.
    pub fn links(&self) -> Result<Vec<NodeLink>, ShapeError> {
        let mut out = Vec::new();
        let mut cur = None;
        for b in &self.blocks {
            cur = link_block(b, cur, &mut out)?;
        }
        Ok(out)
    }
}

fn link_block(
    block: &Block,
    entry: Option<usize>,
    out: &mut Vec<NodeLink>,
) -> Result<Option<usize>, ShapeError> {
    match block {
        Block::Layer(_) => {
            out.push(NodeLink {
                src: entry,
                merge: None,
            });
            Ok(Some(out.len() - 1))
        }
        Block::Residual {
            name,
            body,
            projection,
            post_relu,
        } => {
            let mut cur = entry;
            for b in body {
                cur = link_block(b, cur, out)?;
            }
            match projection {
                Some(_) => {
                    // Projection node reads the block entry; the body's
                    // last node merges into it.
                    out.push(NodeLink {
                        src: entry,
                        merge: Some(MergeLink {
                            with: cur,
                            post_relu: *post_relu,
                        }),
                    });
                    Ok(Some(out.len() - 1))
                }
                None => {
                    if let Some(last) = cur {
                        if last != entry.unwrap_or(usize::MAX) {
                            if out[last].merge.is_some() {
                                return Err(ShapeError::BadParam {
                                    layer: name.clone(),
                                    what: "identity merge target already merges".into(),
                                });
                            }
                            out[last].merge = Some(MergeLink {
                                with: entry,
                                post_relu: *post_relu,
                            });
                        }
                    }
                    Ok(cur)
                }
            }
        }
    }
}

fn shape_block(
    block: &Block,
    input: Shape,
    out: &mut Vec<ShapedLayer>,
) -> Result<Shape, ShapeError> {
    match block {
        Block::Layer(l) => {
            let mut l = l.clone();
            // Fill in "same as input" channel counts.
            if l.filters == 0 {
                l.filters = input.d;
            }
            let output = layer_output_shape(&l, input)?;
            out.push(ShapedLayer {
                layer: l,
                input,
                output,
                merges: false,
            });
            Ok(output)
        }
        Block::Residual {
            name,
            body,
            projection,
            ..
        } => {
            let mut cur = input;
            let body_start = out.len();
            for b in body {
                cur = shape_block(b, cur, out)?;
            }
            let shortcut_shape = match projection {
                Some(proj) => {
                    let mut proj = proj.clone();
                    if proj.filters == 0 {
                        proj.filters = cur.d;
                    }
                    let s = layer_output_shape(&proj, input)?;
                    out.push(ShapedLayer {
                        layer: proj,
                        input,
                        output: s,
                        merges: true,
                    });
                    s
                }
                None => input,
            };
            if shortcut_shape != cur {
                return Err(ShapeError::ResidualMismatch {
                    block: name.clone(),
                    body: cur,
                    shortcut: shortcut_shape,
                });
            }
            // Mark the last body layer as merging.
            if let Some(last_body) = out[body_start..]
                .iter_mut()
                .filter(|l| !l.merges)
                .next_back()
            {
                last_body.merges = true;
            }
            Ok(cur)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn running() -> Model {
        zoo::running_example()
    }

    #[test]
    fn running_example_shapes_match_table_v() {
        let shapes = running().shapes().unwrap();
        let fs: Vec<(usize, usize)> = shapes.iter().map(|s| (s.output.f, s.output.d)).collect();
        // C1 24x24x8, P1 12x12x8, C2 12x12x16, P2 4x4x16, F1 1x1x10
        assert_eq!(fs, vec![(24, 8), (12, 8), (12, 16), (4, 16), (1, 10)]);
    }

    #[test]
    fn running_example_params_match_table_viii() {
        // Table VIII: "Running example" Param. = 6.0k
        let p = running().param_count().unwrap();
        // 5*5*1*8 + 8 + 5*5*8*16 + 16 + 256*10 + 10 = 5994
        assert_eq!(p, 5994);
        assert_eq!(crate::util::paper_count(p), "6.0k");
    }

    #[test]
    fn conv_shape_arithmetic() {
        let l = Layer::conv("c", 3, 2, 1, 32);
        let s = layer_output_shape(&l, Shape { f: 224, d: 3 }).unwrap();
        assert_eq!(s, Shape { f: 112, d: 32 });
    }

    #[test]
    fn dense_flattens() {
        let l = Layer::dense("fc", 10);
        let s = layer_output_shape(&l, Shape { f: 4, d: 16 }).unwrap();
        assert_eq!(s, Shape { f: 1, d: 10 });
    }

    #[test]
    fn window_too_large_rejected() {
        let l = Layer::maxpool("p", 5, 5);
        assert!(matches!(
            layer_output_shape(&l, Shape { f: 3, d: 1 }),
            Err(ShapeError::WindowTooLarge { .. })
        ));
    }

    #[test]
    fn pool_keeps_channels() {
        let l = Layer::maxpool("p", 2, 2);
        let s = layer_output_shape(&l, Shape { f: 24, d: 8 }).unwrap();
        assert_eq!(s, Shape { f: 12, d: 8 });
    }

    #[test]
    fn residual_identity_shapes() {
        let mut m = Model::new("res", 8, 4);
        m.blocks.push(Block::Residual {
            name: "r1".into(),
            body: vec![
                Block::Layer(Layer::conv("a", 3, 1, 1, 4)),
                Block::Layer(Layer::conv("b", 3, 1, 1, 4).no_relu()),
            ],
            projection: None,
            post_relu: true,
        });
        let shapes = m.shapes().unwrap();
        assert_eq!(shapes.len(), 2);
        assert!(shapes[1].merges);
        assert!(!shapes[0].merges);
        assert_eq!(m.output_shape().unwrap(), Shape { f: 8, d: 4 });
        let links = m.links().unwrap();
        assert_eq!(links[0], NodeLink { src: None, merge: None });
        assert_eq!(
            links[1],
            NodeLink {
                src: Some(0),
                merge: Some(MergeLink {
                    with: None,
                    post_relu: true
                })
            }
        );
    }

    #[test]
    fn residual_mismatch_rejected() {
        let mut m = Model::new("res", 8, 4);
        m.blocks.push(Block::Residual {
            name: "r1".into(),
            body: vec![Block::Layer(Layer::conv("a", 3, 2, 1, 8))],
            projection: None, // identity shortcut has wrong shape
            post_relu: true,
        });
        assert!(matches!(
            m.shapes(),
            Err(ShapeError::ResidualMismatch { .. })
        ));
    }

    #[test]
    fn residual_projection_marks_merge() {
        let mut m = Model::new("res", 8, 4);
        m.blocks.push(Block::Residual {
            name: "r1".into(),
            body: vec![
                Block::Layer(Layer::conv("a", 3, 2, 1, 8)),
                Block::Layer(Layer::conv("b", 3, 1, 1, 8).no_relu()),
            ],
            projection: Some(Layer::conv("proj", 1, 2, 0, 8).no_relu()),
            post_relu: true,
        });
        let shapes = m.shapes().unwrap();
        assert_eq!(shapes.len(), 3);
        assert!(shapes[1].merges); // last body layer
        assert!(shapes[2].merges); // projection
        let links = m.links().unwrap();
        assert_eq!(links.len(), 3);
        // Projection reads the block entry (the model input here) and the
        // body's last node merges into it.
        assert_eq!(
            links[2],
            NodeLink {
                src: None,
                merge: Some(MergeLink {
                    with: Some(1),
                    post_relu: true
                })
            }
        );
        assert_eq!(links[1], NodeLink { src: Some(0), merge: None });
    }

    #[test]
    fn links_reject_identity_merge_onto_merge() {
        // Identity residual whose body ends in another identity residual:
        // the outer merge has nowhere to attach.
        let mut m = Model::new("res", 8, 4);
        m.blocks.push(Block::Residual {
            name: "outer".into(),
            body: vec![Block::Residual {
                name: "inner".into(),
                body: vec![
                    Block::Layer(Layer::conv("a", 3, 1, 1, 4)),
                    Block::Layer(Layer::conv("b", 3, 1, 1, 4).no_relu()),
                ],
                projection: None,
                post_relu: true,
            }],
            projection: None,
            post_relu: true,
        });
        assert!(m.shapes().is_ok());
        assert!(matches!(m.links(), Err(ShapeError::BadParam { .. })));
    }

    #[test]
    fn zero_filters_means_same_as_input() {
        let mut m = Model::new("m", 24, 8);
        m.push(Layer::maxpool("p", 2, 2));
        let shapes = m.shapes().unwrap();
        assert_eq!(shapes[0].layer.filters, 8);
    }
}
