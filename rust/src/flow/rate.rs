//! Data-rate propagation (system S2) — Section III/IV-B of the paper.
//!
//! The output data rate of a layer is (Eq. 8):
//!
//! ```text
//! r_l = d_l * r_{l-1} / (d_{l-1} * s^2)
//! ```
//!
//! with `r_0 = d_0` for a fully-utilised input (one pixel of `d_0`
//! features per clock cycle); Table X additionally sweeps scaled-down
//! input rates, so `r_0` is a parameter here.
//!
//! Residual merges take the minimum of the merging branch rates
//! (Section VI: "the layer after the merged activations has an input data
//! rate equal to the lowest data rate of the two merged layers").

use super::Ratio;
use crate::model::{LayerKind, Model, NodeLink, ShapeError, ShapedLayer};

/// A layer annotated with its resolved shapes and input/output data rates.
#[derive(Debug, Clone)]
pub struct RatedLayer {
    pub shaped: ShapedLayer,
    /// Input data rate r_{l-1} in features (valid values) per clock cycle.
    pub r_in: Ratio,
    /// Output data rate r_l per Eq. 8, after any residual-merge clamping.
    pub r_out: Ratio,
}

impl RatedLayer {
    /// d_{l-1}: input channel count — for Dense layers the *flattened*
    /// feature count, per Section II-D (k = f reformulation).
    pub fn d_in(&self) -> usize {
        match self.shaped.layer.kind {
            LayerKind::Dense => self.shaped.input.features(),
            _ => self.shaped.input.d,
        }
    }

    /// d_l: output channel count.
    pub fn d_out(&self) -> usize {
        self.shaped.output.d
    }
}

/// Rates for every layer of a model.
#[derive(Debug, Clone)]
pub struct RateAnalysis {
    pub model_name: String,
    /// Input rate r_0 used for the analysis.
    pub r0: Ratio,
    pub layers: Vec<RatedLayer>,
}

/// Apply Eq. 8 to a single layer.
pub fn layer_rate(d_in: usize, d_out: usize, s: usize, r_in: Ratio) -> Ratio {
    r_in.mul(Ratio::new(d_out as u64, (d_in * s * s) as u64))
}

/// Propagate data rates through the model starting from `r0`.
///
/// `r0 = None` means the full input rate `d_0` (one input pixel per cycle).
///
/// The walk recurses over the block structure so residual groups see the
/// rate at their entry for the shortcut branch; `Model::shapes` is used in
/// lockstep (it flattens in the identical order) to attach shapes.
pub fn analyze(model: &Model, r0: Option<Ratio>) -> Result<RateAnalysis, ShapeError> {
    let shapes = model.shapes()?;
    let r0 = r0.unwrap_or_else(|| Ratio::int(model.input.d as u64));
    let mut layers: Vec<RatedLayer> = Vec::with_capacity(shapes.len());
    let mut iter = shapes.into_iter();
    let mut cur = r0;
    for block in &model.blocks {
        cur = rate_block(block, cur, &mut iter, &mut layers);
    }
    debug_assert!(iter.next().is_none(), "shape/block walk out of sync");
    Ok(RateAnalysis {
        model_name: model.name.clone(),
        r0,
        layers,
    })
}

fn rate_one(
    sl: ShapedLayer,
    r_in: Ratio,
    out: &mut Vec<RatedLayer>,
) -> Ratio {
    let d_in = match sl.layer.kind {
        LayerKind::Dense => sl.input.features(),
        _ => sl.input.d,
    };
    let r_out = layer_rate(d_in, sl.output.d, sl.layer.s, r_in);
    out.push(RatedLayer {
        shaped: sl,
        r_in,
        r_out,
    });
    r_out
}

fn rate_block(
    block: &crate::model::Block,
    entry: Ratio,
    iter: &mut std::vec::IntoIter<ShapedLayer>,
    out: &mut Vec<RatedLayer>,
) -> Ratio {
    use crate::model::Block;
    match block {
        Block::Layer(_) => {
            let sl = iter.next().expect("shape walk underflow");
            rate_one(sl, entry, out)
        }
        Block::Residual {
            body, projection, ..
        } => {
            let mut cur = entry;
            for b in body {
                cur = rate_block(b, cur, iter, out);
            }
            let shortcut = match projection {
                Some(_) => {
                    let sl = iter.next().expect("projection shape underflow");
                    rate_one(sl, entry, out)
                }
                None => entry,
            };
            // Section VI: downstream rate = min of the merged branch rates.
            cur.min(shortcut)
        }
    }
}

/// Propagate Eq.-8 rates through an explicit DAG: `shaped[i]` is node
/// `i`'s layer with resolved shapes, `links[i]` says which node (or the
/// input) it reads and which node merges into it. The stored `r_out` is
/// the raw Eq.-8 rate, exactly as [`analyze`] stores it; the Section VI
/// min-of-branches clamp is applied where a downstream node *reads* a
/// merged stream — so on chain links the two functions agree
/// layer-for-layer, and on residual graphs this is the flat-graph
/// counterpart of [`analyze`]'s recursive block walk.
pub fn analyze_dag(
    model_name: &str,
    shaped: Vec<ShapedLayer>,
    links: &[NodeLink],
    r0: Ratio,
) -> RateAnalysis {
    assert_eq!(shaped.len(), links.len(), "shaped/links out of sync");
    let mut layers: Vec<RatedLayer> = Vec::with_capacity(shaped.len());
    // The rate of node j's stream after any merge clamp at j.
    let mut merged_out: Vec<Ratio> = Vec::with_capacity(shaped.len());
    let branch = |m: &[Ratio], s: Option<usize>| match s {
        Some(j) => m[j],
        None => r0,
    };
    for (i, sl) in shaped.into_iter().enumerate() {
        let r_in = branch(&merged_out, links[i].src);
        let d_in = match sl.layer.kind {
            LayerKind::Dense => sl.input.features(),
            _ => sl.input.d,
        };
        let r_out = layer_rate(d_in, sl.output.d, sl.layer.s, r_in);
        let clamped = match links[i].merge {
            Some(ml) => r_out.min(branch(&merged_out, ml.with)),
            None => r_out,
        };
        merged_out.push(clamped);
        layers.push(RatedLayer {
            shaped: sl,
            r_in,
            r_out,
        });
    }
    RateAnalysis {
        model_name: model_name.to_string(),
        r0,
        layers,
    }
}

/// Cycles between consecutive pixels of a `d`-channel stream flowing at
/// rate `r` features/cycle: `⌈d / r⌉`, floored at one cycle. This is the
/// stream's *pixel period* — the paper's Eq. 17 quantity that decides how
/// many configurations a shared unit can cycle through between arrivals.
pub fn pixel_period(d: usize, r: Ratio) -> u64 {
    r.ceil_div_into(d as u64).max(1)
}

/// Fold factor for a layer whose output stream has pixel period
/// `out_period`, relative to the pipeline's source pixel period: how many
/// idle cycles a full-width unit would burn per pixel, i.e. how many ways
/// its work can be time-multiplexed onto shared hardware while still
/// keeping up with the data rate. Always ≥ 1; 1 means full-rate (no
/// folding possible).
pub fn fold_factor(out_period: u64, source_period: u64) -> u64 {
    (out_period / source_period.max(1)).max(1)
}

impl RateAnalysis {
    /// Effective input rate for the layer *after* a given index, taking
    /// residual merges into account: this is simply the stored r_in of the
    /// next layer, exposed for reporting.
    pub fn final_rate(&self) -> Ratio {
        self.layers.last().map(|l| l.r_out).unwrap_or(self.r0)
    }

    /// Throughput in inferences (input frames) per cycle: the input frame
    /// has f^2 pixels of d features arriving at r0 features/cycle.
    pub fn frames_per_cycle(&self, input_pixels: usize, d0: usize) -> Ratio {
        self.r0
            .div_int((input_pixels * d0) as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn rates_of(model: &Model) -> Vec<Ratio> {
        analyze(model, None).unwrap().layers.iter().map(|l| l.r_out).collect()
    }

    #[test]
    fn running_example_rates_match_table_v() {
        // Table V r_l column: C1=8, P1=2, C2=4, P2=4/9, F1=10*(4/9)/256
        let m = zoo::running_example();
        let r = rates_of(&m);
        assert_eq!(
            r,
            vec![
                Ratio::int(8),
                Ratio::int(2),
                Ratio::int(4),
                Ratio::new(4, 9),
                Ratio::new(4 * 10, 9 * 256), // = 5/288 ≈ 0.017, paper rounds to 0.02
            ]
        );
        assert!((r[4].to_f64() - 0.02).abs() < 0.005);
    }

    #[test]
    fn eq8_single_layer() {
        // 2x2 maxpool: rate drops to 1/4 per channel.
        assert_eq!(
            layer_rate(8, 8, 2, Ratio::int(8)),
            Ratio::int(2)
        );
        // conv stride 1 with channel expansion 1->8 at r=1: r_out = 8.
        assert_eq!(layer_rate(1, 8, 1, Ratio::ONE), Ratio::int(8));
    }

    #[test]
    fn r0_scaling_is_linear() {
        let m = zoo::running_example();
        let full = analyze(&m, None).unwrap();
        let half = analyze(&m, Some(Ratio::new(1, 2))).unwrap();
        for (f, h) in full.layers.iter().zip(half.layers.iter()) {
            // full r0 = 1 (d0=1), so half-rate analysis scales all rates by 1/2
            assert_eq!(f.r_out.mul(Ratio::new(1, 2)), h.r_out);
        }
    }

    #[test]
    fn jsc_rates_at_r0_16() {
        let m = zoo::jsc_mlp();
        let a = analyze(&m, None).unwrap();
        assert_eq!(a.r0, Ratio::int(16));
        // dense 16->16 at r=16: r_out = 16*16/16 = 16; fc3: 5*16/16 = 5
        assert_eq!(
            a.layers.iter().map(|l| l.r_out).collect::<Vec<_>>(),
            vec![Ratio::int(16), Ratio::int(16), Ratio::int(5)]
        );
    }

    #[test]
    fn mobilenet_rates_monotone_and_positive() {
        let m = zoo::mobilenet_v1(25);
        let a = analyze(&m, None).unwrap();
        for l in &a.layers {
            assert!(!l.r_out.is_zero(), "{} rate collapsed", l.shaped.layer.name);
        }
        // conv1 (3->8, s=2): r = 8*3/(3*4) = 2
        assert_eq!(a.layers[0].r_out, Ratio::int(2));
    }

    #[test]
    fn resnet_merge_takes_min_rate() {
        let m = zoo::resnet18();
        let a = analyze(&m, None).unwrap();
        // Find the first projection layer (name res3_1p): its r_in must be
        // the rate entering the residual group, not the body output rate.
        let i = a
            .layers
            .iter()
            .position(|l| l.shaped.layer.name == "res3_1p")
            .unwrap();
        let proj = &a.layers[i];
        let body_first = a
            .layers
            .iter()
            .find(|l| l.shaped.layer.name == "res3_1a")
            .unwrap();
        assert_eq!(proj.r_in, body_first.r_in);
        // The next layer's input rate equals min(body r_out, proj r_out).
        let next = &a.layers[i + 1];
        let body_last = &a.layers[i - 1];
        assert_eq!(next.r_in, body_last.r_out.min(proj.r_out));
    }

    #[test]
    fn analyze_dag_agrees_with_block_walk() {
        // On chains AND residual graphs, the flat-DAG propagation must
        // reproduce the recursive block walk layer-for-layer.
        for m in [
            zoo::mobilenet_micro(),
            zoo::running_example(),
            zoo::resnet_micro(),
            zoo::mobilenet_v2_micro(),
            zoo::resnet18(),
        ] {
            let a = analyze(&m, None).unwrap();
            let d = analyze_dag(
                &m.name,
                m.shapes().unwrap(),
                &m.links().unwrap(),
                Ratio::int(m.input.d as u64),
            );
            assert_eq!(a.layers.len(), d.layers.len(), "{}", m.name);
            for (la, ld) in a.layers.iter().zip(&d.layers) {
                assert_eq!(la.r_in, ld.r_in, "{}: {}", m.name, la.shaped.layer.name);
                assert_eq!(la.r_out, ld.r_out, "{}: {}", m.name, la.shaped.layer.name);
            }
        }
    }

    #[test]
    fn analyze_dag_merge_reader_gets_min_of_branches() {
        let m = zoo::resnet_micro();
        let d = analyze_dag(
            &m.name,
            m.shapes().unwrap(),
            &m.links().unwrap(),
            Ratio::int(1),
        );
        // ap (node 6) reads the r2 merge: min(r2b raw, r2p raw).
        let want = d.layers[4].r_out.min(d.layers[5].r_out);
        assert_eq!(d.layers[6].r_in, want);
    }

    #[test]
    fn dense_uses_flattened_inputs() {
        let m = zoo::running_example();
        let a = analyze(&m, None).unwrap();
        let f1 = a.layers.last().unwrap();
        assert_eq!(f1.d_in(), 256);
        assert_eq!(f1.d_out(), 10);
    }
}
