//! Exact rational arithmetic for data rates.
//!
//! Data rates in the paper are ratios of channel counts and squared
//! strides (Eq. 8) — e.g. the running example's P2 output rate is 4/9.
//! Floating point would mis-round quantities like C = h * d/j = 320 that
//! must come out exactly, so rates are kept as reduced u64 fractions.

/// A non-negative rational number `num/den`, always stored reduced with
/// `den > 0`.
///
/// ```
/// use cnn_flow::flow::Ratio;
///
/// // Eq. 8 for the running example's P2 layer:
/// // r = d_l * r_in / (d_in * s^2) = 16 * 4 / (16 * 9) = 4/9, kept exact.
/// let r = Ratio::int(4).mul(Ratio::new(16, 16 * 9));
/// assert_eq!(r, Ratio::new(4, 9));
/// assert_eq!(r.paper(), "4/9");
/// // Eq. 17's ceiling division: 256 features at rate 4/9 need 576 cycles.
/// assert_eq!(r.ceil_div_into(256), 576);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Ratio {
    num: u64,
    den: u64,
}

fn gcd(mut a: u64, mut b: u64) -> u64 {
    while b != 0 {
        (a, b) = (b, a % b);
    }
    a.max(1)
}

impl Ratio {
    pub const ZERO: Ratio = Ratio { num: 0, den: 1 };
    pub const ONE: Ratio = Ratio { num: 1, den: 1 };

    pub fn new(num: u64, den: u64) -> Self {
        assert!(den != 0, "zero denominator");
        let g = gcd(num, den);
        Self {
            num: num / g,
            den: den / g,
        }
    }

    pub fn int(n: u64) -> Self {
        Self { num: n, den: 1 }
    }

    pub fn num(&self) -> u64 {
        self.num
    }

    pub fn den(&self) -> u64 {
        self.den
    }

    pub fn is_zero(&self) -> bool {
        self.num == 0
    }

    pub fn is_integer(&self) -> bool {
        self.den == 1
    }

    /// ⌈r⌉ as used by Eqs. 16, 19, 20, 22.
    pub fn ceil(&self) -> u64 {
        self.num.div_ceil(self.den)
    }

    pub fn floor(&self) -> u64 {
        self.num / self.den
    }

    pub fn to_f64(&self) -> f64 {
        self.num as f64 / self.den as f64
    }

    pub fn recip(&self) -> Ratio {
        assert!(self.num != 0, "reciprocal of zero rate");
        Ratio {
            num: self.den,
            den: self.num,
        }
    }

    pub fn mul(&self, other: Ratio) -> Ratio {
        // Cross-reduce first so u64 never overflows for realistic models.
        let g1 = gcd(self.num, other.den);
        let g2 = gcd(other.num, self.den);
        Ratio::new(
            (self.num / g1) * (other.num / g2),
            (self.den / g2) * (other.den / g1),
        )
    }

    pub fn div(&self, other: Ratio) -> Ratio {
        self.mul(other.recip())
    }

    pub fn mul_int(&self, n: u64) -> Ratio {
        self.mul(Ratio::int(n))
    }

    pub fn div_int(&self, n: u64) -> Ratio {
        assert!(n != 0);
        self.mul(Ratio::new(1, n))
    }

    /// ⌈a / r⌉ for integer a — e.g. Eq. 17's ⌈d_{l-1} / r_{l-1}⌉.
    pub fn ceil_div_into(&self, a: u64) -> u64 {
        assert!(self.num != 0, "division by zero rate");
        // a / (num/den) = a*den/num
        (a as u128 * self.den as u128).div_ceil(self.num as u128) as u64
    }

    /// Render like the paper's tables: integers plain, else "n/d".
    pub fn paper(&self) -> String {
        if self.den == 1 {
            format!("{}", self.num)
        } else {
            format!("{}/{}", self.num, self.den)
        }
    }
}

impl PartialOrd for Ratio {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Ratio {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        let lhs = self.num as u128 * other.den as u128;
        let rhs = other.num as u128 * self.den as u128;
        lhs.cmp(&rhs)
    }
}

impl std::fmt::Display for Ratio {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.paper())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduction() {
        let r = Ratio::new(4, 8);
        assert_eq!((r.num(), r.den()), (1, 2));
        assert_eq!(Ratio::new(0, 5), Ratio::ZERO);
    }

    #[test]
    fn running_example_p2_rate() {
        // P2: r = d_l * r_in / (d_in * s^2) = 16*4/(16*9) = 4/9
        let r = Ratio::int(4).mul(Ratio::new(16, 16 * 9));
        assert_eq!(r, Ratio::new(4, 9));
        assert_eq!(r.paper(), "4/9");
    }

    #[test]
    fn ceil_floor() {
        assert_eq!(Ratio::new(4, 9).ceil(), 1);
        assert_eq!(Ratio::new(4, 9).floor(), 0);
        assert_eq!(Ratio::new(9, 4).ceil(), 3);
        assert_eq!(Ratio::int(2).ceil(), 2);
    }

    #[test]
    fn ceil_div_into_matches_eq17() {
        // ⌈d/r⌉ with d=8, r=0.5 -> 16
        assert_eq!(Ratio::new(1, 2).ceil_div_into(8), 16);
        // d=8, r=3 -> ⌈8/3⌉ = 3
        assert_eq!(Ratio::int(3).ceil_div_into(8), 3);
        // F1: C = h*d/j: via rate 4/9 ⌈256/(4/9)⌉ = 576
        assert_eq!(Ratio::new(4, 9).ceil_div_into(256), 576);
    }

    #[test]
    fn ordering() {
        assert!(Ratio::new(1, 3) < Ratio::new(1, 2));
        assert!(Ratio::int(2) > Ratio::new(9, 5));
        assert_eq!(Ratio::new(2, 4), Ratio::new(1, 2));
    }

    #[test]
    fn mul_div_roundtrip() {
        let a = Ratio::new(7, 9);
        let b = Ratio::new(3, 14);
        assert_eq!(a.mul(b).div(b), a);
    }

    #[test]
    fn cross_reduction_avoids_overflow() {
        let big = Ratio::new(u64::MAX / 2, 3);
        let r = big.mul(Ratio::new(3, u64::MAX / 2));
        assert_eq!(r, Ratio::ONE);
    }

    #[test]
    #[should_panic]
    fn zero_denominator_panics() {
        let _ = Ratio::new(1, 0);
    }
}
