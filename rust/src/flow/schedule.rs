//! Analytic schedule model (system S2/S6 bridge) — the *cycles* half of
//! the values/cycles split (DESIGN.md §4).
//!
//! The pipeline interpreter (`sim::pipeline::PipelineSim::run_interpreted`)
//! fuses two independent concerns in one loop: bit-exact int8 values and
//! the continuous-flow cycle schedule. The schedule half is completely
//! value-free: which cycle an output pixel completes on depends only on
//! the Eq. 8 rates, the unit plan (initiation intervals), and the window
//! geometry — never on activations. This module factors that half out:
//!
//! * [`ScheduleModel`] — a lowered, value-free replay of the interpreter's
//!   exact cycle recurrence (`finish = max(dep, prev + period) + latency`),
//!   with per-output-pixel dependency indices precomputed once. Replaying
//!   `n` frames is O(output pixels · n) with no arithmetic on values, and
//!   is **exactly** the interpreter's schedule by construction.
//! * [`SchedulePrediction`] — a closed form on top of the replay: the
//!   recurrence is a max-plus linear system, so after a short transient
//!   every layer's completion times advance by a constant per frame. The
//!   prediction replays frames until it certifies that steady state
//!   (two consecutive frames with identical uniform shifts of the entire
//!   schedule state), then answers `total_cycles(n)`,
//!   `cycles_per_frame(n)` and per-layer utilisation for *any* frame
//!   count in O(1) — which is what lets serving skip cycle simulation
//!   entirely.

use super::{PlannedLayer, Ratio, UnitPlan};
use crate::model::{LayerKind, NodeLink};

/// Typed schedule-construction failure. Degenerate-but-reachable layer
/// configurations (a window layer whose output collapses to zero pixels,
/// a rate that bottoms out at zero) are analysis answers, not process
/// aborts: [`ScheduleModel::new`] returns one of these instead of
/// panicking mid-replay.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScheduleError {
    /// The plan list is empty — nothing to schedule.
    EmptyPlan,
    /// The first layer's input rate is zero: no data ever arrives.
    ZeroInputRate,
    /// A layer's Eq.-8 output rate collapsed to zero.
    ZeroOutputRate { layer: String },
    /// A window layer emits no output pixels (or consumes an empty map),
    /// so the completion recurrence has no stream to advance.
    NoOutputPixels { layer: String },
    /// The layer kind is not pipeline-simulated (pointwise layers lower
    /// through the dense path elsewhere).
    Unsupported { layer: String },
    /// The dataflow links are malformed: wrong length, a forward
    /// reference, or merged branches with different pixel counts.
    BadTopology { what: String },
}

impl std::fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScheduleError::EmptyPlan => write!(f, "schedule: empty plan"),
            ScheduleError::ZeroInputRate => write!(f, "schedule: zero input rate"),
            ScheduleError::ZeroOutputRate { layer } => {
                write!(f, "schedule: {layer}: zero output rate")
            }
            ScheduleError::NoOutputPixels { layer } => {
                write!(f, "schedule: {layer}: layer emits no pixels")
            }
            ScheduleError::Unsupported { layer } => {
                write!(f, "schedule: {layer}: pointwise layers are not pipeline-simulated")
            }
            ScheduleError::BadTopology { what } => {
                write!(f, "schedule: bad topology: {what}")
            }
        }
    }
}

impl std::error::Error for ScheduleError {}

/// Latency (pipeline register stages) per unit kind, as modelled by the
/// interpreter: KPU-style window units take 3 cycles, PPU comparators 2,
/// FCU accumulate/forward 2 (plus its weight-cycle tail `h`).
const LAT_KPU: u64 = 3;
const LAT_PPU: u64 = 2;
const LAT_FCU: u64 = 2;
/// The residual merge adder stage: one cycle after both branch pixels
/// are available (the slower branch arrives directly, the faster one
/// from the delay-balancing skip FIFO). Public so the fused interpreter
/// (`sim::pipeline::PipelineSim::run_interpreted`) models the same stage.
pub const LAT_MERGE: u64 = 1;

/// Value-free per-layer schedule program.
#[derive(Debug, Clone)]
enum SKind {
    /// Window layers (conv / dwconv / maxpool / avgpool): one entry per
    /// output pixel giving the index (into the upstream completion
    /// vector) of the last input pixel the window depends on.
    Window { dep_idx: Vec<u32>, ops_per_out: u64 },
    /// Fully connected: consumes the whole upstream frame, emits one
    /// "pixel"; `h` is the FCU weight-cycle tail, `ii` the initiation
    /// interval (= configurations C).
    Dense { h: u64, ii: u64, ops_per_frame: u64 },
}

#[derive(Debug, Clone)]
struct SLayer {
    name: String,
    unit_kind: &'static str,
    units: usize,
    latency: u64,
    /// Cycles per output pixel, d_l / r_l rounded up (unused by Dense).
    out_period: u64,
    kind: SKind,
    /// Which node's output stream this layer consumes (`None` = input).
    src: Option<usize>,
    /// `Some(other)` when this node is a residual merge point: `other`'s
    /// stream (`None` = input) is added in after the layer's own compute.
    merge_with: Option<Option<usize>>,
}

/// Per-layer cycle statistics accumulated by a replay — field-for-field
/// the schedule content of `sim::pipeline::LayerStats`.
#[derive(Debug, Clone)]
pub struct CycleStats {
    pub name: String,
    pub unit_kind: &'static str,
    pub units: usize,
    pub useful_ops: u64,
    pub first_cycle: u64,
    pub last_cycle: u64,
    pub utilization: f64,
}

/// Per-merge-point skip-FIFO trace extracted from an exact replay: the
/// shortcut branch's pixel completion cycles (FIFO pushes), the merge
/// layer's output completions (FIFO pops), and the resulting maximum
/// occupancy — the minimum delay-balancing FIFO depth (`sim::fifo`) that
/// never overflows and is never read empty.
#[derive(Debug, Clone)]
pub struct MergeFifoStats {
    /// Flat index of the merging layer.
    pub layer: usize,
    /// The shortcut branch feeding the merge (`None` = pipeline input).
    pub with: Option<usize>,
    /// Completion cycle of each shortcut pixel, in stream order.
    pub shortcut_arrivals: Vec<u64>,
    /// Completion cycle of each merged output, in stream order.
    pub merge_consumes: Vec<u64>,
    /// Peak number of shortcut pixels buffered at once.
    pub max_occupancy: usize,
}

/// Result of replaying `n` frames through the schedule.
#[derive(Debug, Clone)]
pub struct ScheduleResult {
    /// Final-layer completion cycle of each frame.
    pub frame_finishes: Vec<u64>,
    pub stats: Vec<CycleStats>,
    pub total_cycles: u64,
    pub first_frame_latency: u64,
    pub cycles_per_frame: f64,
    /// One entry per residual merge point (empty for chains).
    pub merge_fifo: Vec<MergeFifoStats>,
}

/// Steady-state cycles/frame from per-frame completion cycles: frame 0 is
/// the latency measurement and frame 1 absorbs pipeline warm-up, so the
/// throughput difference is taken from frame 1 onward. (Differencing from
/// frame 0 lets the fill transient — e.g. nonzero inter-frame zero-feed
/// gaps, or ceil-rounded layer periods that only saturate after a frame —
/// skew the multi-frame figure.)
pub fn steady_cycles_per_frame(finishes: &[u64]) -> f64 {
    match finishes.len() {
        0 => 0.0,
        1 => finishes[0] as f64,
        2 => (finishes[1] - finishes[0]) as f64,
        n => (finishes[n - 1] - finishes[1]) as f64 / (n - 2) as f64,
    }
}

/// Mutable replay state: one entry per layer, carried across frames.
#[derive(Debug, Clone)]
pub struct ScheduleState {
    /// Source pixel completion cycles for the current frame.
    src: Vec<u64>,
    /// Per-layer output-pixel completion cycles for the current frame.
    outs: Vec<Vec<u64>>,
    prev_finish: Vec<u64>,
    ops: Vec<u64>,
    first: Vec<u64>,
    last: Vec<u64>,
    frames_done: u64,
}

/// The lowered value-free schedule of a planned pipeline.
#[derive(Debug, Clone)]
pub struct ScheduleModel {
    layers: Vec<SLayer>,
    frame_pixels: usize,
    /// Zero-feed pixels between frames (Section III-B shared padding
    /// rows): p*f + p when the first layer pads, else 0.
    gap_pixels: usize,
    c0: u64,
    r0: Ratio,
}

impl ScheduleModel {
    /// Lower a unit plan into a replayable schedule. `input_hw` is the
    /// (h, w) of the input feature map (each already `.max(1)`), `d0` its
    /// channel count — exactly the values the interpreter reads from the
    /// quantized model's input shape.
    pub fn new(
        plans: &[PlannedLayer],
        input_hw: (usize, usize),
        d0: usize,
    ) -> Result<ScheduleModel, ScheduleError> {
        let links: Vec<NodeLink> = (0..plans.len()).map(NodeLink::chain).collect();
        Self::with_links(plans, input_hw, d0, &links)
    }

    /// Lower a unit plan over an explicit dataflow topology: `links[i]`
    /// names the node whose stream layer `i` consumes and, for residual
    /// merge points, the shortcut branch added in after its own compute.
    /// Chain links reproduce [`ScheduleModel::new`] exactly.
    pub fn with_links(
        plans: &[PlannedLayer],
        input_hw: (usize, usize),
        d0: usize,
        links: &[NodeLink],
    ) -> Result<ScheduleModel, ScheduleError> {
        if plans.is_empty() {
            return Err(ScheduleError::EmptyPlan);
        }
        if links.len() != plans.len() {
            return Err(ScheduleError::BadTopology {
                what: format!("{} links for {} layers", links.len(), plans.len()),
            });
        }
        let r0 = plans[0].rated.r_in;
        if r0.is_zero() {
            return Err(ScheduleError::ZeroInputRate);
        }
        let mut layers = Vec::with_capacity(plans.len());
        for (i, plan) in plans.iter().enumerate() {
            let mut sl = lower_layer(plan)?;
            let link = &links[i];
            if let Some(s) = link.src {
                if s >= i {
                    return Err(ScheduleError::BadTopology {
                        what: format!("layer {i} reads non-earlier node {s}"),
                    });
                }
            }
            sl.src = link.src;
            if let Some(m) = &link.merge {
                if let Some(w) = m.with {
                    if w >= i {
                        return Err(ScheduleError::BadTopology {
                            what: format!("layer {i} merges non-earlier node {w}"),
                        });
                    }
                }
                sl.merge_with = Some(m.with);
            }
            layers.push(sl);
        }
        // A merge adds streams element-wise, so both branches must emit
        // the same number of pixels per frame.
        let frame_pixels = input_hw.0 * input_hw.1;
        let pixels_of = |j: Option<usize>, layers: &[SLayer]| -> usize {
            match j {
                None => frame_pixels,
                Some(j) => match &layers[j].kind {
                    SKind::Window { dep_idx, .. } => dep_idx.len(),
                    SKind::Dense { .. } => 1,
                },
            }
        };
        for i in 0..layers.len() {
            if let Some(w) = layers[i].merge_with {
                let own = pixels_of(Some(i), &layers);
                let other = pixels_of(w, &layers);
                if own != other {
                    return Err(ScheduleError::BadTopology {
                        what: format!(
                            "merge at layer {i}: {own} output pixels vs {other} on the shortcut"
                        ),
                    });
                }
            }
        }
        let first = &plans[0].rated.shaped.layer;
        let gap_pixels = if first.p > 0 {
            first.p * input_hw.1 + first.p
        } else {
            0
        };
        Ok(ScheduleModel {
            layers,
            frame_pixels,
            gap_pixels,
            c0: d0 as u64,
            r0,
        })
    }

    pub fn start(&self) -> ScheduleState {
        let n = self.layers.len();
        ScheduleState {
            src: vec![0; self.frame_pixels],
            outs: vec![Vec::new(); n],
            prev_finish: vec![0; n],
            ops: vec![0; n],
            first: vec![u64::MAX; n],
            last: vec![0; n],
            frames_done: 0,
        }
    }

    /// Advance the replay by one frame; returns the final layer's last
    /// completion cycle for this frame. Bit-for-bit the interpreter's
    /// schedule recurrence (frames-outer vs layers-outer iteration order
    /// is immaterial: each (layer, frame) step depends only on the same
    /// layer's previous frame and the previous layer's same frame).
    pub fn step_frame(&self, st: &mut ScheduleState) -> u64 {
        // Source: pixel m's last feature arrives at ceil((m+1)*d0/r0) - 1,
        // with inter-frame zero-feed gap pixels advancing the base index.
        let base = st.frames_done * (self.frame_pixels + self.gap_pixels) as u64;
        for (m, slot) in st.src.iter_mut().enumerate() {
            *slot = ((base + m as u64 + 1) * self.c0 * self.r0.den()).div_ceil(self.r0.num()) - 1;
        }
        let mut frame_final = 0u64;
        for (li, layer) in self.layers.iter().enumerate() {
            let (done, rest) = st.outs.split_at_mut(li);
            let ins: &[u64] = match layer.src {
                None => &st.src,
                Some(j) => &done[j],
            };
            let out = &mut rest[0];
            out.clear();
            match &layer.kind {
                SKind::Dense { h, ii, ops_per_frame } => {
                    let dep = ins.last().copied().unwrap_or(0);
                    let finish = (dep + h + layer.latency).max(st.prev_finish[li] + ii);
                    st.ops[li] += ops_per_frame;
                    st.first[li] = st.first[li].min(ins.first().copied().unwrap_or(dep));
                    st.last[li] = st.last[li].max(finish);
                    st.prev_finish[li] = finish;
                    out.push(finish);
                }
                SKind::Window { dep_idx, ops_per_out } => {
                    let mut prev = st.prev_finish[li];
                    for &di in dep_idx {
                        let dep = ins[di as usize];
                        let finish = dep.max(prev + layer.out_period) + layer.latency;
                        st.ops[li] += ops_per_out;
                        st.first[li] = st.first[li].min(dep);
                        st.last[li] = st.last[li].max(finish);
                        prev = finish - layer.latency;
                        out.push(finish);
                    }
                    st.prev_finish[li] = prev;
                }
            }
            // Residual merge epilogue: each merged output completes one
            // adder cycle after both branch pixels are available. The
            // shortcut pixel waits in the skip FIFO, so its arrival cycle
            // is exactly its completion on the other branch; `prev_finish`
            // deliberately stays pre-merge (the layer's own initiation
            // cadence is unaffected by the downstream adder).
            if let Some(w) = layer.merge_with {
                let other: &[u64] = match w {
                    None => &st.src,
                    Some(j) => &done[j],
                };
                for (slot, &arr) in out.iter_mut().zip(other) {
                    let merged = (*slot).max(arr) + LAT_MERGE;
                    st.last[li] = st.last[li].max(merged);
                    *slot = merged;
                }
            }
            // Construction rejects layers that emit no pixels
            // (`ScheduleError::NoOutputPixels`), so `out` is never empty.
            frame_final = out.last().copied().unwrap_or(frame_final);
        }
        st.frames_done += 1;
        frame_final
    }

    /// Replay `frames` frames from a cold pipeline and report the exact
    /// interpreter schedule: per-frame finishes and per-layer statistics.
    pub fn run(&self, frames: usize) -> ScheduleResult {
        let mut st = self.start();
        let mut finishes = Vec::with_capacity(frames);
        let mut fifo: Vec<MergeFifoStats> = self
            .layers
            .iter()
            .enumerate()
            .filter_map(|(li, l)| {
                l.merge_with.map(|w| MergeFifoStats {
                    layer: li,
                    with: w,
                    shortcut_arrivals: Vec::new(),
                    merge_consumes: Vec::new(),
                    max_occupancy: 0,
                })
            })
            .collect();
        for _ in 0..frames {
            finishes.push(self.step_frame(&mut st));
            for f in &mut fifo {
                let other: &[u64] = match f.with {
                    None => &st.src,
                    Some(j) => &st.outs[j],
                };
                f.shortcut_arrivals.extend_from_slice(other);
                f.merge_consumes.extend_from_slice(&st.outs[f.layer]);
            }
        }
        // Peak FIFO occupancy by two-pointer sweep: both streams are
        // monotone (the initiation recurrence threads `prev_finish`
        // across frames), and every merged output strictly postdates its
        // shortcut arrival, so pixel p is still resident at its own
        // arrival — occupancy is arrivals so far minus consumes so far.
        for f in &mut fifo {
            let mut consumed = 0usize;
            for (p, &a) in f.shortcut_arrivals.iter().enumerate() {
                while consumed < f.merge_consumes.len() && f.merge_consumes[consumed] <= a {
                    consumed += 1;
                }
                f.max_occupancy = f.max_occupancy.max(p + 1 - consumed);
            }
        }
        let stats = self.stats_of(&st);
        let total_cycles = finishes.last().copied().unwrap_or(0);
        ScheduleResult {
            first_frame_latency: finishes.first().copied().unwrap_or(0),
            cycles_per_frame: steady_cycles_per_frame(&finishes),
            frame_finishes: finishes,
            stats,
            total_cycles,
            merge_fifo: fifo,
        }
    }

    fn stats_of(&self, st: &ScheduleState) -> Vec<CycleStats> {
        self.layers
            .iter()
            .enumerate()
            .map(|(li, l)| {
                let elapsed = st.last[li].saturating_sub(st.first[li]).max(1);
                CycleStats {
                    name: l.name.clone(),
                    unit_kind: l.unit_kind,
                    units: l.units,
                    useful_ops: st.ops[li],
                    first_cycle: st.first[li],
                    last_cycle: st.last[li],
                    utilization: st.ops[li] as f64 / (l.units as f64 * elapsed as f64),
                }
            })
            .collect()
    }

    /// Per-layer useful operations accounted per frame (constant).
    fn ops_per_frame(&self, li: usize) -> u64 {
        match &self.layers[li].kind {
            SKind::Dense { ops_per_frame, .. } => *ops_per_frame,
            SKind::Window {
                dep_idx,
                ops_per_out,
            } => dep_idx.len() as u64 * ops_per_out,
        }
    }
}

fn lower_layer(plan: &PlannedLayer) -> Result<SLayer, ScheduleError> {
    let sl = &plan.rated.shaped;
    let layer = &sl.layer;
    let (h_in, w_in) = (sl.input.f, sl.input.f);
    let (h_out, w_out) = (sl.output.f, sl.output.f);
    let (c_in, c_out) = (sl.input.d, sl.output.d);
    let r_out = plan.rated.r_out;
    if r_out.is_zero() {
        return Err(ScheduleError::ZeroOutputRate {
            layer: layer.name.clone(),
        });
    }
    // Window layers drive the recurrence one output pixel at a time; a
    // layer whose output map collapses to zero pixels (or that reads an
    // empty input map) has no stream to schedule. Catching it here turns
    // the former mid-replay `expect("layer emitted no pixels")` abort
    // into a typed analysis error.
    if layer.kind != LayerKind::Dense && (h_out == 0 || w_out == 0 || h_in == 0 || w_in == 0) {
        return Err(ScheduleError::NoOutputPixels {
            layer: layer.name.clone(),
        });
    }
    let out_period = (c_out as u64 * r_out.den()).div_ceil(r_out.num()).max(1);
    let unit_kind = match plan.plan {
        UnitPlan::Kpu { .. } => "KPU",
        UnitPlan::Ppu { .. } => "PPU",
        UnitPlan::Fcu { .. } => "FCU",
    };
    let units = plan.plan.unit_count();
    let (k, s, p) = (layer.k, layer.s, layer.p);
    let kind = match layer.kind {
        LayerKind::Dense => {
            let h = match plan.plan {
                UnitPlan::Fcu { h, .. } => h as u64,
                _ => 1,
            };
            let ii = plan.plan.configs() as u64;
            SKind::Dense {
                h,
                ii,
                ops_per_frame: ii * units as u64,
            }
        }
        LayerKind::MaxPool => {
            // The interpreter's maxpool dependency ignores padding: the
            // window's last pixel, clipped to the map.
            let mut dep_idx = Vec::with_capacity(h_out * w_out);
            for orow in 0..h_out {
                for ocol in 0..w_out {
                    let lr = (orow * s + k - 1).min(h_in - 1);
                    let lc = (ocol * s + k - 1).min(w_in - 1);
                    dep_idx.push((lr * w_in + lc) as u32);
                }
            }
            SKind::Window {
                dep_idx,
                ops_per_out: c_out as u64,
            }
        }
        LayerKind::Conv | LayerKind::DepthwiseConv | LayerKind::AvgPool => {
            let pi = p as isize;
            let mut dep_idx = Vec::with_capacity(h_out * w_out);
            for orow in 0..h_out {
                for ocol in 0..w_out {
                    let lr = ((orow * s) as isize + k as isize - 1 - pi)
                        .clamp(0, h_in as isize - 1) as usize;
                    let lc = ((ocol * s) as isize + k as isize - 1 - pi)
                        .clamp(0, w_in as isize - 1) as usize;
                    dep_idx.push((lr * w_in + lc) as u32);
                }
            }
            let ops_per_out = match layer.kind {
                LayerKind::Conv => (c_in * c_out) as u64,
                _ => c_out as u64,
            };
            SKind::Window {
                dep_idx,
                ops_per_out,
            }
        }
        LayerKind::Pointwise => {
            return Err(ScheduleError::Unsupported {
                layer: layer.name.clone(),
            });
        }
    };
    let latency = match layer.kind {
        LayerKind::MaxPool => LAT_PPU,
        LayerKind::Dense => LAT_FCU,
        _ => LAT_KPU,
    };
    Ok(SLayer {
        name: layer.name.clone(),
        unit_kind,
        units,
        latency,
        out_period,
        kind,
        src: None,
        merge_with: None,
    })
}

/// Closed-form per-layer prediction derived from a certified steady state.
#[derive(Debug, Clone)]
pub struct LayerPrediction {
    pub name: String,
    pub unit_kind: &'static str,
    pub units: usize,
    pub ops_per_frame: u64,
    pub first_cycle: u64,
    /// Per-frame last-completion-cycle prefix (observed frames).
    last_prefix: Vec<u64>,
    /// Steady per-frame advance of this layer's completions.
    last_delta: u64,
    /// Limit utilisation as the frame count grows.
    pub steady_utilization: f64,
}

/// Closed-form schedule figures: frame-0 latency, steady cycles/frame and
/// per-layer utilisation, answering any frame count in O(1).
///
/// ```
/// use cnn_flow::flow::schedule::{ScheduleModel, SchedulePrediction};
/// use cnn_flow::flow::{analyze, plan_all};
/// use cnn_flow::model::{Layer, Model};
///
/// // conv3x3 p1 (1 -> 2) + maxpool 2x2 + dense 4 on a 4x4x1 input.
/// let mut m = Model::new("tiny", 4, 1);
/// m.push(Layer::conv("C1", 3, 1, 1, 2));
/// m.push(Layer::maxpool("P1", 2, 2));
/// m.push(Layer::dense("F1", 4).no_relu());
/// let plans = plan_all(&analyze(&m, None).unwrap());
/// let model = ScheduleModel::new(&plans, (4, 4), 1).unwrap();
///
/// let pred = SchedulePrediction::new(&model);
/// assert!(pred.exact);
/// // Steady advance = the frame period: 16 pixels + 5 gap pixels.
/// assert_eq!(pred.steady_cycles_per_frame, 21);
/// // O(1) answers equal the exact replay at any frame count.
/// assert_eq!(pred.total_cycles(100), model.run(100).total_cycles);
/// ```
///
/// `exact` is true when the replay certified steady state (two
/// consecutive frames whose entire schedule state — every layer's
/// completion vector, carried initiation state, and the source stream —
/// shifted by identical per-layer constants). Within the observed prefix
/// the prediction is always exact; beyond it, extrapolation is exact when
/// `exact` holds and a best-effort linear estimate otherwise.
#[derive(Debug, Clone)]
pub struct SchedulePrediction {
    pub first_frame_latency: u64,
    /// Steady per-frame advance of the final layer (throughput bound).
    pub steady_cycles_per_frame: u64,
    pub exact: bool,
    finish_prefix: Vec<u64>,
    finish_delta: u64,
    pub layers: Vec<LayerPrediction>,
}

/// Frames the certification replay is allowed to observe before giving up
/// and marking the prediction inexact.
const CERT_HORIZON: usize = 32;

impl SchedulePrediction {
    pub fn new(model: &ScheduleModel) -> SchedulePrediction {
        Self::with_horizon(model, CERT_HORIZON)
    }

    pub fn with_horizon(model: &ScheduleModel, max_frames: usize) -> SchedulePrediction {
        let max_frames = max_frames.max(3);
        let n_layers = model.layers.len();
        let mut st = model.start();
        let mut finishes: Vec<u64> = Vec::new();
        let mut last_prefix: Vec<Vec<u64>> = vec![Vec::new(); n_layers];
        let mut prev_deltas: Option<Vec<u64>> = None;
        let mut exact = false;
        let mut deltas: Vec<u64> = vec![0; n_layers];
        while finishes.len() < max_frames {
            let snap_src = st.src.clone();
            let snap_outs = st.outs.clone();
            let snap_pf = st.prev_finish.clone();
            finishes.push(model.step_frame(&mut st));
            for (li, prefix) in last_prefix.iter_mut().enumerate() {
                prefix.push(st.last[li]);
            }
            if finishes.len() < 2 {
                continue;
            }
            // Uniform-shift certificate for this frame vs the previous one.
            let ds = uniform_deltas(&snap_src, &snap_outs, &snap_pf, &st);
            match (ds, &prev_deltas) {
                (Some(ds), Some(prev)) if *prev == ds => {
                    deltas = ds;
                    exact = true;
                    break;
                }
                (Some(ds), _) => prev_deltas = Some(ds),
                (None, _) => prev_deltas = None,
            }
        }
        if !exact {
            // Best-effort: extrapolate with the last observed advances.
            for (li, prefix) in last_prefix.iter().enumerate() {
                deltas[li] = match prefix.len() {
                    0 | 1 => 1,
                    n => (prefix[n - 1] - prefix[n - 2]).max(1),
                };
            }
        }
        let finish_delta = deltas.last().copied().unwrap_or(1).max(1);
        let layers = model
            .layers
            .iter()
            .enumerate()
            .map(|(li, l)| {
                let opf = model.ops_per_frame(li);
                let d = deltas[li].max(1);
                LayerPrediction {
                    name: l.name.clone(),
                    unit_kind: l.unit_kind,
                    units: l.units,
                    ops_per_frame: opf,
                    first_cycle: st.first[li],
                    last_prefix: last_prefix[li].clone(),
                    last_delta: d,
                    steady_utilization: opf as f64 / (l.units as f64 * d as f64),
                }
            })
            .collect();
        SchedulePrediction {
            first_frame_latency: finishes.first().copied().unwrap_or(0),
            steady_cycles_per_frame: finish_delta,
            exact,
            finish_prefix: finishes,
            finish_delta,
            layers,
        }
    }

    /// Frames the replay observed before certifying (or giving up);
    /// predictions up to this count are exact replays by construction.
    pub fn frames_observed(&self) -> usize {
        self.finish_prefix.len()
    }

    fn finish(&self, frame_idx: usize) -> u64 {
        let n = self.finish_prefix.len();
        if frame_idx < n {
            self.finish_prefix[frame_idx]
        } else {
            self.finish_prefix[n - 1] + (frame_idx + 1 - n) as u64 * self.finish_delta
        }
    }

    /// Completion cycle of the last output of an `frames`-frame stream —
    /// the interpreter's `total_cycles`.
    pub fn total_cycles(&self, frames: usize) -> u64 {
        if frames == 0 {
            return 0;
        }
        self.finish(frames - 1)
    }

    /// The interpreter's steady-state `cycles_per_frame` for an
    /// `frames`-frame stream (same warm-up-excluding formula).
    pub fn cycles_per_frame(&self, frames: usize) -> f64 {
        match frames {
            0 => 0.0,
            1 => self.finish(0) as f64,
            2 => (self.finish(1) - self.finish(0)) as f64,
            n => (self.finish(n - 1) - self.finish(1)) as f64 / (n - 2) as f64,
        }
    }

    /// Closed-form figures for a `batch`-frame group streamed
    /// back-to-back — the batched serving tier's cycle source (DESIGN.md
    /// §6). Every field is the O(1) answer the per-count methods give, so
    /// divergence against [`ScheduleModel::run`] stays checkable at any
    /// batch size.
    pub fn batched(&self, batch: usize) -> BatchPrediction {
        BatchPrediction {
            batch,
            total_cycles: self.total_cycles(batch),
            steady_cycles_per_frame: self.cycles_per_frame(batch),
            first_frame_latency: if batch == 0 { 0 } else { self.first_frame_latency },
            utilization: self.utilization(batch),
            exact: self.exact || batch <= self.frames_observed(),
        }
    }

    /// Projected steady-state hardware throughput (frames/s) at a given
    /// modelled clock — the per-model headline figure the multi-model
    /// serve CLI reports next to each group's measured metrics.
    pub fn throughput_fps(&self, clock_hz: f64) -> f64 {
        if self.steady_cycles_per_frame == 0 {
            0.0
        } else {
            clock_hz / self.steady_cycles_per_frame as f64
        }
    }

    /// Closed-form figures for a `batch`-frame group executed by the
    /// **folded** engine (DESIGN.md §9): per-layer work is
    /// time-multiplexed onto `units / fold` shared units, exactly the
    /// paper's rate-aware interleaving. Folding never moves a completion
    /// cycle — the out-periods already encode each layer's Eq.-8 rate, so
    /// the folded schedule finishes when the unfolded one does; what
    /// changes is the *unit count the work is accounted against*, which
    /// is why folded utilisation approaches 1.0 where the unfolded
    /// figures idle at 1/fold.
    ///
    /// The contract mirrors [`SchedulePrediction::batched`]: every field
    /// must equal [`ScheduleModel::run_folded`]'s exact replay of the
    /// same frame count — cycle divergence at any batch size is a bug.
    pub fn folded(&self, batch: usize, folds: &[u64]) -> FoldedPrediction {
        assert_eq!(folds.len(), self.layers.len(), "one fold factor per layer");
        let folded_units = folded_unit_counts(self.layers.iter().map(|l| l.units), folds);
        let utilization = self
            .layers
            .iter()
            .zip(&folded_units)
            .map(|(l, &fu)| {
                if batch == 0 {
                    return 0.0;
                }
                let n = l.last_prefix.len();
                let last = if batch <= n {
                    l.last_prefix[batch - 1]
                } else {
                    l.last_prefix[n - 1] + (batch - n) as u64 * l.last_delta
                };
                let elapsed = last.saturating_sub(l.first_cycle).max(1);
                (l.ops_per_frame * batch as u64) as f64 / (fu as f64 * elapsed as f64)
            })
            .collect();
        FoldedPrediction {
            batch,
            total_cycles: self.total_cycles(batch),
            steady_cycles_per_frame: self.cycles_per_frame(batch),
            first_frame_latency: if batch == 0 { 0 } else { self.first_frame_latency },
            fold_factors: folds.to_vec(),
            folded_units,
            utilization,
            exact: self.exact || batch <= self.frames_observed(),
        }
    }

    /// Analytic per-layer share of a frame's busy unit-cycles: each
    /// layer's `ops_per_frame / units` (the cycles one shared unit spends
    /// on the layer per frame), normalised to sum to 1. This is the
    /// analytic column the `cnn-flow profile` divergence table places
    /// next to the measured time shares from
    /// [`crate::obs::LayerProfiler`] (DESIGN.md §13).
    pub fn cycle_shares(&self) -> Vec<f64> {
        let per_unit: Vec<f64> = self
            .layers
            .iter()
            .map(|l| l.ops_per_frame as f64 / l.units.max(1) as f64)
            .collect();
        let total: f64 = per_unit.iter().sum();
        if total <= 0.0 {
            return vec![0.0; per_unit.len()];
        }
        per_unit.iter().map(|c| c / total).collect()
    }

    /// Per-layer utilisation over an `frames`-frame stream.
    pub fn utilization(&self, frames: usize) -> Vec<f64> {
        self.layers
            .iter()
            .map(|l| {
                if frames == 0 {
                    return 0.0;
                }
                let n = l.last_prefix.len();
                let last = if frames <= n {
                    l.last_prefix[frames - 1]
                } else {
                    l.last_prefix[n - 1] + (frames - n) as u64 * l.last_delta
                };
                let elapsed = last.saturating_sub(l.first_cycle).max(1);
                (l.ops_per_frame * frames as u64) as f64 / (l.units as f64 * elapsed as f64)
            })
            .collect()
    }
}

/// Closed-form schedule figures for one fixed batch size, produced by
/// [`SchedulePrediction::batched`]: what a `batch`-frame group costs when
/// its frames stream back-to-back through the pipeline.
///
/// The contract (enforced by unit and property tests): `total_cycles`,
/// `steady_cycles_per_frame` and `utilization` equal the
/// [`ScheduleModel::run`] replay of the same frame count **exactly** —
/// cycle divergence at any batch size is a bug.
#[derive(Debug, Clone)]
pub struct BatchPrediction {
    /// Frames in the group.
    pub batch: usize,
    /// Completion cycle of the group's last output (the interpreter's
    /// `total_cycles` for a `batch`-frame stream).
    pub total_cycles: u64,
    /// Warm-up-excluding cycles/frame over the group (the interpreter's
    /// `cycles_per_frame`).
    pub steady_cycles_per_frame: f64,
    /// Frame-0 latency (0 for an empty group).
    pub first_frame_latency: u64,
    /// Per-layer utilisation over the group.
    pub utilization: Vec<f64>,
    /// Whether the figures are certified-exact extrapolations (always
    /// true within the observed prefix).
    pub exact: bool,
}

/// Closed-form schedule figures for one fixed batch size under the
/// folded engine, produced by [`SchedulePrediction::folded`] and
/// certified against [`ScheduleModel::run_folded`].
///
/// Cycle fields (`total_cycles`, `steady_cycles_per_frame`,
/// `first_frame_latency`) are identical to the unfolded
/// [`BatchPrediction`] for the same batch — folding shares hardware, it
/// does not reschedule completions. The folded content is
/// `fold_factors` / `folded_units` / `utilization`: the rate-weighted
/// unit counts the paper saves and the near-1.0 utilisation that saving
/// buys.
#[derive(Debug, Clone)]
pub struct FoldedPrediction {
    /// Frames in the group.
    pub batch: usize,
    /// Completion cycle of the group's last output.
    pub total_cycles: u64,
    /// Warm-up-excluding cycles/frame over the group.
    pub steady_cycles_per_frame: f64,
    /// Frame-0 latency (0 for an empty group).
    pub first_frame_latency: u64,
    /// Per-layer fold factor (1 = full width, no sharing).
    pub fold_factors: Vec<u64>,
    /// Per-layer physical unit count after folding: `⌈units / fold⌉`.
    pub folded_units: Vec<usize>,
    /// Per-layer utilisation of the *folded* units over the group.
    pub utilization: Vec<f64>,
    /// Whether the figures are certified-exact extrapolations.
    pub exact: bool,
}

/// `⌈units / fold⌉` per layer, floored at one physical unit.
fn folded_unit_counts(units: impl Iterator<Item = usize>, folds: &[u64]) -> Vec<usize> {
    units
        .zip(folds)
        .map(|(u, &f)| u.div_ceil((f.max(1)) as usize).max(1))
        .collect()
}

impl ScheduleModel {
    /// Exact-replay counterpart of [`SchedulePrediction::folded`]: replay
    /// `frames` frames cycle-for-cycle, then account each layer's work
    /// against its folded unit count. The certification tests pin
    /// [`SchedulePrediction::folded`] to this with zero cycle divergence.
    pub fn run_folded(&self, frames: usize, folds: &[u64]) -> FoldedPrediction {
        assert_eq!(folds.len(), self.layers.len(), "one fold factor per layer");
        let res = self.run(frames);
        let folded_units = folded_unit_counts(self.layers.iter().map(|l| l.units), folds);
        let utilization = res
            .stats
            .iter()
            .zip(&folded_units)
            .map(|(s, &fu)| {
                let elapsed = s.last_cycle.saturating_sub(s.first_cycle).max(1);
                s.useful_ops as f64 / (fu as f64 * elapsed as f64)
            })
            .collect();
        FoldedPrediction {
            batch: frames,
            total_cycles: res.total_cycles,
            steady_cycles_per_frame: res.cycles_per_frame,
            first_frame_latency: if frames == 0 { 0 } else { res.first_frame_latency },
            fold_factors: folds.to_vec(),
            folded_units,
            utilization,
            exact: true,
        }
    }
}

/// If every layer's completion vector (and carried state), plus the
/// source stream, advanced by a per-layer-uniform shift this frame,
/// return those shifts.
fn uniform_deltas(
    snap_src: &[u64],
    snap_outs: &[Vec<u64>],
    snap_pf: &[u64],
    st: &ScheduleState,
) -> Option<Vec<u64>> {
    // Source must shift uniformly (any constant).
    let s0 = st.src.first()?.checked_sub(*snap_src.first()?)?;
    if !st.src.iter().zip(snap_src).all(|(c, p)| c.wrapping_sub(*p) == s0) {
        return None;
    }
    let mut ds = Vec::with_capacity(snap_outs.len());
    for (li, prev) in snap_outs.iter().enumerate() {
        let cur = &st.outs[li];
        if prev.len() != cur.len() || prev.is_empty() {
            return None;
        }
        let d = cur[0].checked_sub(prev[0])?;
        if !cur.iter().zip(prev).all(|(c, p)| c.wrapping_sub(*p) == d) {
            return None;
        }
        if st.prev_finish[li].checked_sub(snap_pf[li]) != Some(d) {
            return None;
        }
        ds.push(d);
    }
    Some(ds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::{analyze, plan_all};
    use crate::model::{Layer, Model};

    fn tiny_model() -> (Vec<PlannedLayer>, (usize, usize), usize) {
        // Mirrors sim::pipeline's tiny fixture: conv3x3 p1 (1->2),
        // maxpool 2x2, dense 4 on a 4x4x1 input.
        let mut m = Model::new("tiny", 4, 1);
        m.push(Layer::conv("C1", 3, 1, 1, 2));
        m.push(Layer::maxpool("P1", 2, 2));
        m.push(Layer::dense("F1", 4).no_relu());
        let a = analyze(&m, None).unwrap();
        (plan_all(&a), (4, 4), 1)
    }

    #[test]
    fn steady_formula_excludes_warmup_frame() {
        // Pinned semantics: frame 0 measures latency, frame 1 absorbs
        // warm-up, steady state is the tail average from frame 1 on.
        assert_eq!(steady_cycles_per_frame(&[]), 0.0);
        assert_eq!(steady_cycles_per_frame(&[10]), 10.0);
        assert_eq!(steady_cycles_per_frame(&[10, 31]), 21.0);
        // Warm-up: frame 0 finishes early (delta 30), steady delta is 21.
        // The old frame-0 baseline would report (103-10)/4 = 23.25.
        assert_eq!(steady_cycles_per_frame(&[10, 40, 61, 82, 103]), 21.0);
    }

    #[test]
    fn replay_is_deterministic_and_monotone() {
        let (plans, hw, d0) = tiny_model();
        let model = ScheduleModel::new(&plans, hw, d0).unwrap();
        let a = model.run(6);
        let b = model.run(6);
        assert_eq!(a.frame_finishes, b.frame_finishes);
        assert!(a.frame_finishes.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(a.total_cycles, *a.frame_finishes.last().unwrap());
        assert_eq!(a.first_frame_latency, a.frame_finishes[0]);
        for s in &a.stats {
            assert!(s.utilization > 0.0 && s.utilization <= 1.0, "{s:?}");
        }
    }

    #[test]
    fn prediction_matches_replay_exactly() {
        let (plans, hw, d0) = tiny_model();
        let model = ScheduleModel::new(&plans, hw, d0).unwrap();
        let pred = SchedulePrediction::new(&model);
        assert!(pred.exact, "tiny model must certify steady state");
        for n in [1usize, 2, 3, 5, 16, 64, 100] {
            let replay = model.run(n);
            assert_eq!(pred.total_cycles(n), replay.total_cycles, "n={n}");
            assert_eq!(
                pred.cycles_per_frame(n),
                replay.cycles_per_frame,
                "n={n}"
            );
            let u = pred.utilization(n);
            for (li, s) in replay.stats.iter().enumerate() {
                assert!(
                    (u[li] - s.utilization).abs() < 1e-12,
                    "n={n} layer {li}: {} vs {}",
                    u[li],
                    s.utilization
                );
            }
        }
        assert_eq!(pred.first_frame_latency, model.run(1).total_cycles);
    }

    #[test]
    fn prediction_horizon_caps_observation() {
        let (plans, hw, d0) = tiny_model();
        let model = ScheduleModel::new(&plans, hw, d0).unwrap();
        let pred = SchedulePrediction::with_horizon(&model, 4);
        assert!(pred.frames_observed() <= 4);
        // Steady advance equals the frame period: 16 pixels + 5 gap.
        assert_eq!(pred.steady_cycles_per_frame, 21);
    }

    #[test]
    fn batch_prediction_has_zero_divergence_at_any_size() {
        // The batched serving tier's contract: the closed-form group
        // figures equal the exact schedule replay at every batch size.
        let (plans, hw, d0) = tiny_model();
        let model = ScheduleModel::new(&plans, hw, d0).unwrap();
        let pred = SchedulePrediction::new(&model);
        for b in [1usize, 2, 3, 4, 7, 8, 16, 64, 257] {
            let bp = pred.batched(b);
            let replay = model.run(b);
            assert_eq!(bp.batch, b);
            assert!(bp.exact, "B={b}");
            assert_eq!(bp.total_cycles, replay.total_cycles, "B={b}");
            assert_eq!(bp.steady_cycles_per_frame, replay.cycles_per_frame, "B={b}");
            assert_eq!(bp.first_frame_latency, replay.first_frame_latency, "B={b}");
            for (u, s) in bp.utilization.iter().zip(&replay.stats) {
                assert!((u - s.utilization).abs() < 1e-12, "B={b}");
            }
        }
        let empty = pred.batched(0);
        assert_eq!(empty.total_cycles, 0);
        assert_eq!(empty.first_frame_latency, 0);
        assert_eq!(empty.steady_cycles_per_frame, 0.0);
    }

    #[test]
    fn pointwise_is_rejected() {
        let mut m = Model::new("pw", 4, 2);
        m.push(Layer::pwconv("pw1", 4));
        let a = analyze(&m, None).unwrap();
        let plans = plan_all(&a);
        assert_eq!(
            ScheduleModel::new(&plans, (4, 4), 2).unwrap_err(),
            ScheduleError::Unsupported { layer: "pw1".into() }
        );
    }

    #[test]
    fn zero_pixel_layer_is_a_typed_error_not_a_panic() {
        // A 0x0 input map with a padded conv produces a layer that reads
        // an empty map — formerly an `expect("layer emitted no pixels")`
        // abort mid-replay, now a construction-time ScheduleError.
        let mut m = Model::new("degenerate", 0, 1);
        m.push(Layer::conv("C1", 2, 1, 1, 2));
        let a = analyze(&m, None).unwrap();
        let plans = plan_all(&a);
        let err = ScheduleModel::new(&plans, (1, 1), 1).unwrap_err();
        assert_eq!(err, ScheduleError::NoOutputPixels { layer: "C1".into() });
        assert!(err.to_string().contains("no pixels"), "{err}");
    }

    #[test]
    fn schedule_errors_render_their_layer() {
        let e = ScheduleError::ZeroOutputRate { layer: "dw7".into() };
        assert_eq!(e.to_string(), "schedule: dw7: zero output rate");
        assert_eq!(ScheduleError::EmptyPlan.to_string(), "schedule: empty plan");
    }

    #[test]
    fn folded_prediction_has_zero_divergence_at_any_size() {
        // The folded engine's cycle contract: the closed-form folded
        // figures equal the exact folded replay at every batch size.
        let (plans, hw, d0) = tiny_model();
        let model = ScheduleModel::new(&plans, hw, d0).unwrap();
        let pred = SchedulePrediction::new(&model);
        let folds = vec![1u64, 4, 2];
        for b in [1usize, 2, 3, 4, 7, 8, 16, 64, 257] {
            let fp = pred.folded(b, &folds);
            let replay = model.run_folded(b, &folds);
            assert!(fp.exact, "B={b}");
            assert_eq!(fp.total_cycles, replay.total_cycles, "B={b}");
            assert_eq!(
                fp.steady_cycles_per_frame, replay.steady_cycles_per_frame,
                "B={b}"
            );
            assert_eq!(fp.first_frame_latency, replay.first_frame_latency, "B={b}");
            assert_eq!(fp.folded_units, replay.folded_units, "B={b}");
            for (u, v) in fp.utilization.iter().zip(&replay.utilization) {
                assert!((u - v).abs() < 1e-12, "B={b}: {u} vs {v}");
            }
        }
        let empty = pred.folded(0, &folds);
        assert_eq!(empty.total_cycles, 0);
        assert_eq!(empty.first_frame_latency, 0);
        assert!(empty.utilization.iter().all(|&u| u == 0.0));
    }

    #[test]
    fn folding_shares_units_without_moving_cycles() {
        let (plans, hw, d0) = tiny_model();
        let model = ScheduleModel::new(&plans, hw, d0).unwrap();
        let pred = SchedulePrediction::new(&model);
        let folds = vec![2u64, 1, 1];
        let bp = pred.batched(32);
        let fp = pred.folded(32, &folds);
        // Cycle figures are untouched by folding (shared hardware, same
        // dataflow), while the folded layer's work is accounted against
        // half the units, doubling its utilisation.
        assert_eq!(fp.total_cycles, bp.total_cycles);
        assert_eq!(fp.steady_cycles_per_frame, bp.steady_cycles_per_frame);
        assert_eq!(fp.first_frame_latency, bp.first_frame_latency);
        assert!((fp.utilization[0] - 2.0 * bp.utilization[0]).abs() < 1e-12);
        assert!((fp.utilization[1] - bp.utilization[1]).abs() < 1e-12);
    }
}
