//! Continuous-flow analysis (systems S2 + S3): exact rational data rates,
//! Eq.-8 propagation, and the interleaving planner of Section IV.

pub mod plan;
pub mod rate;
pub mod ratio;

pub use plan::{plan_all, plan_layer, PlannedLayer, UnitPlan};
pub use rate::{analyze, layer_rate, RateAnalysis, RatedLayer};
pub use ratio::Ratio;
