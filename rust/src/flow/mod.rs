//! Continuous-flow analysis (systems S2 + S3): exact rational data rates,
//! Eq.-8 propagation, the interleaving planner of Section IV, and the
//! analytic schedule model that turns a plan into closed-form cycle
//! figures (DESIGN.md §4).

pub mod plan;
pub mod rate;
pub mod ratio;
pub mod schedule;

pub use plan::{fold_plan, plan_all, plan_layer, PlannedLayer, UnitPlan};
pub use rate::{
    analyze, analyze_dag, fold_factor, layer_rate, pixel_period, RateAnalysis, RatedLayer,
};
pub use ratio::Ratio;
pub use schedule::{
    BatchPrediction, FoldedPrediction, MergeFifoStats, ScheduleError, ScheduleModel,
    SchedulePrediction,
};
