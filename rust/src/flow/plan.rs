//! Interleaving planner (system S3) — Sections IV-C/D/E of the paper.
//!
//! Given a layer's input data rate, the planner decides how many physical
//! processing units to instantiate and how many configurations each cycles
//! through:
//!
//! * convolutional layers: Eqs. 16-19 (KPUs, configurations C, interleave
//!   factor I),
//! * depthwise convolutions: Eqs. 20-21,
//! * pooling: Eq. 22,
//! * fully connected / pointwise: Eqs. 12-15 (FCU j inputs, h neurons,
//!   aggregation factor a).
//!
//! A plan where the data rate is too low for interleaving to restore
//! continuous flow is marked [`UnitPlan::stalled`] (the `*` rows of
//! Tables VI/VII).

use super::{RatedLayer, Ratio};
use crate::model::LayerKind;
use crate::util::{ceil_div, greatest_divisor_leq};

/// How a layer is physically realised.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum UnitPlan {
    /// Standard or depthwise convolution mapped onto KPUs.
    Kpu {
        /// Number of physical KPUs (Eqs. 16/19/20).
        kpus: usize,
        /// Configurations per KPU (Eqs. 17/21).
        configs: usize,
        /// Interleave factor I = ⌈C / d_{l-1}⌉ (Eq. 18); number of output
        /// channels interleaved onto one physical output signal.
        interleave: usize,
        /// Accumulator units for cross-channel summation (one per
        /// physical output signal: d_l / I). Zero for the special cases
        /// (d_{l-1} = 1, depthwise) where no accumulation is needed.
        accumulators: usize,
        /// Inputs accumulated per accumulator per cycle, j = ⌈#KPUs/d_l⌉.
        accum_inputs: usize,
        /// True if continuous flow cannot be restored (KPUs stall).
        stalled: bool,
    },
    /// Pooling layers mapped onto PPUs.
    Ppu {
        ppus: usize,
        configs: usize,
        stalled: bool,
    },
    /// Fully connected / pointwise layers mapped onto FCUs.
    Fcu {
        fcus: usize,
        /// Parallel inputs per FCU (j).
        j: usize,
        /// Neurons per FCU (h).
        h: usize,
        /// Weight configurations C = h * d_{l-1} / j (Eq. 12).
        configs: usize,
        /// Aggregation factor a (Eq. 15); 1 = no aggregation circuit.
        aggregation: usize,
    },
}

impl UnitPlan {
    pub fn stalled(&self) -> bool {
        match self {
            UnitPlan::Kpu { stalled, .. } | UnitPlan::Ppu { stalled, .. } => *stalled,
            UnitPlan::Fcu { .. } => false,
        }
    }

    pub fn unit_count(&self) -> usize {
        match self {
            UnitPlan::Kpu { kpus, .. } => *kpus,
            UnitPlan::Ppu { ppus, .. } => *ppus,
            UnitPlan::Fcu { fcus, .. } => *fcus,
        }
    }

    pub fn configs(&self) -> usize {
        match self {
            UnitPlan::Kpu { configs, .. }
            | UnitPlan::Ppu { configs, .. }
            | UnitPlan::Fcu { configs, .. } => *configs,
        }
    }
}

/// A planned layer: the rated layer plus its unit mapping.
#[derive(Debug, Clone)]
pub struct PlannedLayer {
    pub rated: RatedLayer,
    pub plan: UnitPlan,
}

/// Minimum number of accumulator pipeline stages an FCU tolerates; when
/// h would fall below this, inputs are aggregated (Section III-E, Eq. 15).
/// The paper's example aggregates to a*j = 4; we keep the same default.
pub const FCU_MIN_DEPTH: usize = 1;

/// Plan a single rated layer.
pub fn plan_layer(rated: &RatedLayer) -> PlannedLayer {
    let d_in = rated.d_in();
    let d_out = rated.d_out();
    let r_in = rated.r_in;
    let layer = &rated.shaped.layer;
    let plan = match layer.kind {
        LayerKind::Conv => plan_conv(d_in, d_out, r_in),
        LayerKind::DepthwiseConv | LayerKind::AvgPool => plan_depthwise(d_in, r_in),
        LayerKind::MaxPool => plan_pool(d_in, r_in),
        LayerKind::Pointwise | LayerKind::Dense => plan_fcu(d_in, d_out, r_in),
    };
    PlannedLayer {
        rated: rated.clone(),
        plan,
    }
}

/// Standard convolution (Eqs. 16-19).
fn plan_conv(d_in: usize, d_out: usize, r_in: Ratio) -> UnitPlan {
    assert!(!r_in.is_zero(), "zero input rate");
    // Eq. 17: C = min(⌈d_{l-1} / r⌉, d_{l-1} * d_l)
    let c_uncapped = r_in.ceil_div_into(d_in as u64) as usize;
    let cap = d_in * d_out;
    let configs = c_uncapped.min(cap);
    let stalled = c_uncapped > cap;
    // Eq. 18: I = ⌈C / d_{l-1}⌉
    let interleave = ceil_div(configs, d_in);
    // Eq. 19: #KPUs = ⌈r⌉ * d_l / I   (Eq. 16 when I = 1)
    let kpus = (r_in.ceil() as usize) * ceil_div(d_out, interleave);
    // Channel accumulation (Section V-C): skipped when each output channel
    // sums a single kernel (d_in == 1).
    let (accumulators, accum_inputs) = if d_in == 1 {
        (0, 0)
    } else {
        (ceil_div(d_out, interleave), ceil_div(kpus, d_out).max(1))
    };
    UnitPlan::Kpu {
        kpus,
        configs,
        interleave,
        accumulators,
        accum_inputs,
        stalled,
    }
}

/// Depthwise convolution (Eqs. 20-21); also used for average pooling,
/// which Section VI implements as a depthwise conv with constant weights.
fn plan_depthwise(d_in: usize, r_in: Ratio) -> UnitPlan {
    assert!(!r_in.is_zero(), "zero input rate");
    let c_uncapped = r_in.ceil_div_into(d_in as u64) as usize;
    let configs = c_uncapped.min(d_in);
    let stalled = c_uncapped > d_in;
    UnitPlan::Kpu {
        kpus: r_in.ceil() as usize,
        configs,
        interleave: 1,
        // Depthwise outputs are single-kernel sums: no accumulation adders,
        // but the d_l output registers remain (see Table VII analysis).
        accumulators: 0,
        accum_inputs: 0,
        stalled,
    }
}

/// Pooling (Eq. 22). Configuration count mirrors the depthwise case: each
/// PPU serves ⌈d/r⌉ interleaved channels (capped at d).
fn plan_pool(d_in: usize, r_in: Ratio) -> UnitPlan {
    assert!(!r_in.is_zero(), "zero input rate");
    let c_uncapped = r_in.ceil_div_into(d_in as u64) as usize;
    let configs = c_uncapped.min(d_in);
    let stalled = c_uncapped > d_in;
    UnitPlan::Ppu {
        ppus: r_in.ceil() as usize,
        configs,
        stalled,
    }
}

/// Fully connected / pointwise layers (Eqs. 12-15).
///
/// The input rate is interpreted as r = j_max / h_max (Eq. 13) in lowest
/// terms; each FCU takes j = j_max inputs and computes
/// h = max{divisor of d_l <= h_max} neurons (Eq. 14). If h_max comes out
/// below `FCU_MIN_DEPTH`, inputs are aggregated by a (Eq. 15).
fn plan_fcu(d_in: usize, d_out: usize, r_in: Ratio) -> UnitPlan {
    assert!(!r_in.is_zero(), "zero input rate");
    let mut j_max = r_in.num().max(1) as usize;
    let mut h_max = r_in.den() as usize;
    // j can never exceed the number of distinct input features.
    if j_max > d_in {
        // More input lanes than features: clamp (still one pixel/cycle).
        h_max = (h_max * d_in).div_ceil(j_max).max(1);
        j_max = d_in;
    }
    // Aggregation (Eq. 15): scale j and h together until the accumulator
    // depth h_max supports the pipeline.
    let mut aggregation = 1;
    while h_max * aggregation < FCU_MIN_DEPTH && j_max * aggregation < d_in {
        aggregation *= 2;
    }
    let j = (j_max * aggregation).min(d_in);
    let h_cap = h_max * aggregation;
    let h = greatest_divisor_leq(d_out, h_cap);
    let fcus = ceil_div(d_out, h);
    // Eq. 12: C = h * d_{l-1} / j
    let configs = ceil_div(h * d_in, j);
    UnitPlan::Fcu {
        fcus,
        j,
        h,
        configs,
        aggregation,
    }
}

/// Plan every layer of a rate analysis.
pub fn plan_all(analysis: &super::RateAnalysis) -> Vec<PlannedLayer> {
    analysis.layers.iter().map(plan_layer).collect()
}

/// Per-layer fold factors for a planned pipeline (DESIGN.md §9).
///
/// The source stream delivers one input pixel every
/// `pixel_period(d_0, r_0)` cycles; a layer whose output pixel period is
/// longer only needs to emit every so many source periods, so its work
/// can be time-multiplexed onto shared hardware without falling behind
/// the flow — the software analogue of the paper's rate-aware unit
/// interleaving (Sections IV-C/D/E). The planner *already* interleaves
/// `configs` configurations per unit, so the fold factor here is the
/// slack the plan leaves on the table:
///
/// ```text
/// fold_l = max(1, out_period_l / (configs_l * src_period))
/// ```
///
/// Full-rate layers (and layers the planner has fully interleaved, like
/// FCU-mapped dense heads) get factor 1; stride/pool layers — whose units
/// the planner sizes for the *input* rate while outputs emerge at 1/s² of
/// it — get the stride-squared factors the paper's Table V rates imply.
/// Folding a layer by its factor drives its utilisation toward 1.0, which
/// is exactly the "close to 100% utilization" claim the folded engine
/// certifies via [`crate::flow::schedule::FoldedPrediction`].
pub fn fold_plan(plans: &[PlannedLayer]) -> Vec<u64> {
    let Some(first) = plans.first() else {
        return Vec::new();
    };
    if first.rated.r_in.is_zero() {
        // No flow at all: nothing to fold against (the schedule builder
        // rejects this pipeline with a typed error anyway).
        return vec![1; plans.len()];
    }
    let src = super::rate::pixel_period(first.rated.d_in(), first.rated.r_in);
    plans
        .iter()
        .map(|p| {
            if p.rated.r_out.is_zero() {
                return 1;
            }
            let out = super::rate::pixel_period(p.rated.d_out(), p.rated.r_out);
            let interleaved = src.saturating_mul(p.plan.configs().max(1) as u64);
            super::rate::fold_factor(out, interleaved)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::{analyze, rate::RateAnalysis};
    use crate::model::zoo;

    fn plan_of(model: &crate::model::Model) -> Vec<PlannedLayer> {
        let a: RateAnalysis = analyze(model, None).unwrap();
        plan_all(&a)
    }

    #[test]
    fn running_example_units_match_table_v() {
        let plans = plan_of(&zoo::running_example());
        // C1: 8 KPUs, C=1
        match &plans[0].plan {
            UnitPlan::Kpu {
                kpus,
                configs,
                accumulators,
                ..
            } => {
                assert_eq!(*kpus, 8);
                assert_eq!(*configs, 1);
                assert_eq!(*accumulators, 0); // d_in = 1 special case
            }
            p => panic!("C1: {p:?}"),
        }
        // P1: 8 PPUs, C=1
        match &plans[1].plan {
            UnitPlan::Ppu { ppus, configs, .. } => {
                assert_eq!((*ppus, *configs), (8, 1));
            }
            p => panic!("P1: {p:?}"),
        }
        // C2: 32 KPUs, C=4, I=1, 16 accumulators with j=2
        match &plans[2].plan {
            UnitPlan::Kpu {
                kpus,
                configs,
                interleave,
                accumulators,
                accum_inputs,
                ..
            } => {
                assert_eq!(*kpus, 32);
                assert_eq!(*configs, 4);
                assert_eq!(*interleave, 1);
                assert_eq!(*accumulators, 16);
                assert_eq!(*accum_inputs, 2);
            }
            p => panic!("C2: {p:?}"),
        }
        // P2: 4 PPUs, C=4
        match &plans[3].plan {
            UnitPlan::Ppu { ppus, configs, .. } => {
                assert_eq!((*ppus, *configs), (4, 4));
            }
            p => panic!("P2: {p:?}"),
        }
        // F1: 2 FCUs, j=4, h=5, C=320
        match &plans[4].plan {
            UnitPlan::Fcu {
                fcus, j, h, configs, ..
            } => {
                assert_eq!((*fcus, *j, *h, *configs), (2, 4, 5, 320));
            }
            p => panic!("F1: {p:?}"),
        }
    }

    #[test]
    fn table_vi_kpu_counts() {
        // Conv f=28,k=7,p=3,d_in=8,d_out=16 at sweeping rates.
        // Expected KPUs: 128,64,32,16,8,4,2,1,1(stall)
        let expect: [(u64, u64, usize, usize, bool); 9] = [
            (8, 1, 128, 1, false),
            (4, 1, 64, 2, false),
            (2, 1, 32, 4, false),
            (1, 1, 16, 8, false),
            (1, 2, 8, 16, false),
            (1, 4, 4, 32, false),
            (1, 8, 2, 64, false),
            (1, 16, 1, 128, false),
            (1, 32, 1, 128, true),
        ];
        for (num, den, kpus, configs, stalled) in expect {
            let plan = plan_conv(8, 16, Ratio::new(num, den));
            match plan {
                UnitPlan::Kpu {
                    kpus: k,
                    configs: c,
                    stalled: st,
                    ..
                } => {
                    assert_eq!((k, c, st), (kpus, configs, stalled), "r={num}/{den}");
                }
                p => panic!("{p:?}"),
            }
        }
    }

    #[test]
    fn table_vii_depthwise_counts() {
        // dw conv d=8: KPUs 8,4,2,1,1*,1* and C capped at d_in=8.
        let expect: [(u64, u64, usize, usize, bool); 6] = [
            (8, 1, 8, 1, false),
            (4, 1, 4, 2, false),
            (2, 1, 2, 4, false),
            (1, 1, 1, 8, false),
            (1, 2, 1, 8, true),
            (1, 4, 1, 8, true),
        ];
        for (num, den, kpus, configs, stalled) in expect {
            match plan_depthwise(8, Ratio::new(num, den)) {
                UnitPlan::Kpu {
                    kpus: k,
                    configs: c,
                    stalled: st,
                    ..
                } => assert_eq!((k, c, st), (kpus, configs, stalled), "r={num}/{den}"),
                p => panic!("{p:?}"),
            }
        }
    }

    #[test]
    fn table_vii_fcu_counts() {
        // Pointwise d_in=8 -> d_out=16 at rates 8,4,2,1,1/2,1/4:
        // FCUs = 16,16,16,16,8,4 (Table VII last column).
        let expect: [(u64, u64, usize, usize); 6] = [
            (8, 1, 16, 8),
            (4, 1, 16, 4),
            (2, 1, 16, 2),
            (1, 1, 16, 1),
            (1, 2, 8, 1),
            (1, 4, 4, 1),
        ];
        for (num, den, fcus, j) in expect {
            match plan_fcu(8, 16, Ratio::new(num, den)) {
                UnitPlan::Fcu {
                    fcus: f, j: jj, h, ..
                } => {
                    assert_eq!((f, jj), (fcus, j), "r={num}/{den}");
                    // h grows as rate falls: r=1/2 -> h=2, r=1/4 -> h=4
                    assert_eq!(h, (den as usize).min(16));
                }
                p => panic!("{p:?}"),
            }
        }
    }

    #[test]
    fn low_rate_kpu_shares_filters() {
        // Fig. 10: r=0.5, d_in=8, d_out=16 -> 8 KPUs, 16 configs, I=2.
        match plan_conv(8, 16, Ratio::new(1, 2)) {
            UnitPlan::Kpu {
                kpus,
                configs,
                interleave,
                ..
            } => {
                assert_eq!(kpus, 8);
                assert_eq!(configs, 16);
                assert_eq!(interleave, 2);
            }
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn jsc_r0_16_is_fully_parallel() {
        let plans = plan_of(&zoo::jsc_mlp());
        match &plans[0].plan {
            UnitPlan::Fcu {
                fcus, j, h, configs, ..
            } => assert_eq!((*fcus, *j, *h, *configs), (16, 16, 1, 1)),
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn fcu_j_clamped_to_inputs() {
        // Rate 32 into a 16-feature dense layer: j caps at 16.
        match plan_fcu(16, 8, Ratio::int(32)) {
            UnitPlan::Fcu { j, .. } => assert_eq!(j, 16),
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn mobilenet_stalls_only_in_the_low_rate_regime() {
        // Deep MobileNet depthwise layers reach r < 1 where interleaving
        // cannot restore continuous flow (Table VII's `*` rows); stalls
        // must occur there and only there.
        for alpha in [25, 50, 75, 100] {
            let plans = plan_of(&zoo::mobilenet_v1(alpha));
            for p in &plans {
                if p.plan.stalled() {
                    assert!(
                        p.rated.r_in < Ratio::ONE,
                        "alpha={alpha} layer {} stalled at r_in={}",
                        p.rated.shaped.layer.name,
                        p.rated.r_in
                    );
                }
            }
            // At least one deep dw layer stalls for this input size
            // (the a=0.25 model reaches r=1/2 at dw7).
            if alpha == 25 {
                assert!(plans.iter().any(|p| p.plan.stalled()));
            }
        }
    }

    #[test]
    fn fold_plan_folds_exactly_the_rate_slack() {
        // mobilenet_micro: full-rate layers and FCU-interleaved pointwise
        // layers fold 1; the stride-2 depthwise and the avgpool — whose
        // units the planner sizes for the input rate while outputs emerge
        // at 1/4 of it — fold 4.
        let plans = plan_of(&zoo::mobilenet_micro());
        assert_eq!(fold_plan(&plans), vec![1, 1, 1, 4, 1, 1, 1, 4, 1]);
        // digits_cnn: both maxpools fold 4, everything else is saturated.
        let plans = plan_of(&zoo::digits_cnn());
        assert_eq!(fold_plan(&plans), vec![1, 4, 1, 4, 1]);
        // jsc at r0 = 16 is fully parallel end to end: nothing folds.
        let plans = plan_of(&zoo::jsc_mlp());
        assert_eq!(fold_plan(&plans), vec![1, 1, 1]);
    }

    #[test]
    fn fold_plan_handles_empty_and_degenerate() {
        assert!(fold_plan(&[]).is_empty());
    }

    #[test]
    fn unit_plan_accessors() {
        let p = plan_conv(8, 16, Ratio::int(2));
        assert_eq!(p.unit_count(), 32);
        assert_eq!(p.configs(), 4);
        assert!(!p.stalled());
    }
}
