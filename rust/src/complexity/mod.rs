//! Closed-form complexity/resource model (system S4) — Section V.
//!
//! Costs every planned layer in the paper's abstract resource units:
//! adders, multipliers, registers, 2:1 multiplexers, MAX units, and the
//! unit counts (KPU/PPU/FCU). Implements Eqs. 23-37 with the special
//! cases the paper's tables imply:
//!
//! * channel accumulation is skipped when `d_{l-1} = 1` (Table V, C1);
//! * depthwise convolutions keep the `d_l` accumulation output registers
//!   but need no accumulation adders (Table VII row analysis);
//! * Tables VI/VII exclude bias and input-interleaving costs ("costs for
//!   FIFOs and data interleaving are left out because they depend on the
//!   previous layer"), so both are controlled by [`CostOpts`].
//!
//! The fully-parallel reference of Table VIII ("Ref.") lives in
//! [`parallel`]: it is this same model evaluated at the full data rate
//! `r_{l-1} = d_{l-1}` for every layer.

pub mod parallel;

use crate::flow::{PlannedLayer, UnitPlan};
use crate::model::LayerKind;
use crate::util::ceil_div;

/// Abstract resource counts, in the units of the paper's tables.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Resources {
    pub adders: u64,
    pub multipliers: u64,
    pub registers: u64,
    /// 2:1 multiplexer equivalents (an N:1 mux counts as N-1).
    pub mux2: u64,
    pub max_units: u64,
    pub kpus: u64,
    pub fcus: u64,
    pub ppus: u64,
    /// Weight-ROM words (weights held across configurations); used by the
    /// FPGA estimator to place weight storage into BRAM/LUTRAM.
    pub rom_words: u64,
}

impl Resources {
    pub fn add(&mut self, other: &Resources) {
        self.adders += other.adders;
        self.multipliers += other.multipliers;
        self.registers += other.registers;
        self.mux2 += other.mux2;
        self.max_units += other.max_units;
        self.kpus += other.kpus;
        self.fcus += other.fcus;
        self.ppus += other.ppus;
        self.rom_words += other.rom_words;
    }

    pub fn sum<'a>(items: impl IntoIterator<Item = &'a Resources>) -> Resources {
        let mut total = Resources::default();
        for r in items {
            total.add(r);
        }
        total
    }
}

/// What to include in the per-layer cost (the paper's tables differ).
#[derive(Debug, Clone, Copy)]
pub struct CostOpts {
    /// Per-output-channel bias adders + their config muxes (Section V-D).
    pub include_bias: bool,
    /// Input data interleaving FIFO registers + muxes (Section V-A).
    pub include_interleaving: bool,
}

impl CostOpts {
    /// Full-model accounting (Tables V and VIII).
    pub const FULL: CostOpts = CostOpts {
        include_bias: true,
        include_interleaving: true,
    };
    /// Layer-in-isolation accounting (Tables VI and VII).
    pub const LAYER_ONLY: CostOpts = CostOpts {
        include_bias: false,
        include_interleaving: false,
    };
}

/// Cost of one KPU (Section V-B). `k` kernel size, `f` feature-map width,
/// `c` configurations.
pub fn kpu_cost(k: usize, f: usize, c: usize) -> Resources {
    let k = k as u64;
    let f = f as u64;
    let c = c as u64;
    Resources {
        adders: k * k - 1,                                  // Eq. 25
        multipliers: k * k,                                 // Eq. 26
        registers: (k * (k - 1) + (k - 1) * (f - k + 1)) * c, // Eq. 27
        mux2: k * k * (c - 1),                              // Eq. 28
        kpus: 1,
        rom_words: k * k * c,
        ..Default::default()
    }
}

/// Cost of one PPU (Section V-E): same register structure as a KPU, MAX
/// units instead of arithmetic, and the same per-configuration input
/// multiplexing (Table V, P2: 9*(C-1) per PPU).
pub fn ppu_cost(k: usize, f: usize, c: usize) -> Resources {
    let k = k as u64;
    let f = f as u64;
    let c = c as u64;
    Resources {
        max_units: k * k - 1, // Eq. 33
        registers: (k * (k - 1) + (k - 1) * (f - k + 1)) * c,
        mux2: k * k * (c - 1),
        ppus: 1,
        ..Default::default()
    }
}

/// Cost of one FCU (Section V-F) with `j` inputs, `h` neurons and `c`
/// weight configurations.
pub fn fcu_cost(j: usize, h: usize, c: usize) -> Resources {
    let j = j as u64;
    let h = h as u64;
    let c = c as u64;
    Resources {
        multipliers: j,        // Eq. 34
        adders: j,             // Eq. 36 (j-1 tree + 1 accumulator)
        registers: h,          // Eq. 37 (accumulator FIFO depth h)
        mux2: j * (c - 1),     // Eq. 35
        fcus: 1,
        rom_words: j * c,
        ..Default::default()
    }
}

/// Aggregation circuit upstream of an FCU (Fig. 7): widens `j_in` lanes to
/// `a * j_in` by holding `a` consecutive input groups in registers.
pub fn aggregator_cost(j_in: usize, a: usize) -> Resources {
    if a <= 1 {
        return Resources::default();
    }
    Resources {
        registers: (j_in * a) as u64,
        ..Default::default()
    }
}

/// Cost of a whole planned layer.
pub fn layer_cost(pl: &PlannedLayer, opts: CostOpts) -> Resources {
    let layer = &pl.rated.shaped.layer;
    let f_in = pl.rated.shaped.input.f;
    let d_in = pl.rated.d_in();
    let d_out = pl.rated.d_out();
    let mut total = Resources::default();

    match &pl.plan {
        UnitPlan::Kpu {
            kpus,
            configs,
            interleave,
            accumulators,
            accum_inputs,
            ..
        } => {
            // Implicit zero padding (Section III-B) keeps the stream at f
            // columns — the line-buffer length is f - k + 1 regardless of p.
            let unit = kpu_cost(layer.k, f_in, *configs);
            for _ in 0..*kpus {
                total.add(&unit);
            }
            // Channel accumulation (Section V-C): Eq. 29 registers,
            // Eq. 30 adders. Depthwise keeps only the output registers.
            let depthwise = matches!(
                layer.kind,
                LayerKind::DepthwiseConv | LayerKind::AvgPool
            );
            if *accumulators > 0 {
                total.adders += (*accumulators as u64) * (*accum_inputs as u64); // Eq. 30
                total.registers += d_out as u64; // Eq. 29
            } else if depthwise && d_in > 1 {
                total.registers += d_out as u64; // dw output registers only
            }
            // Bias (Section V-D): Eq. 31 adders, Eq. 32 muxes.
            if opts.include_bias && layer.bias {
                let per_signal = ceil_div(d_out, *interleave) as u64;
                total.adders += per_signal;
                total.mux2 += d_out as u64 - per_signal;
            }
            // Input interleaving (Section V-A): Eq. 23 muxes, Eq. 24 regs.
            if opts.include_interleaving && *configs > 1 {
                let r_ceil = pl.rated.r_in.ceil();
                let signals = ceil_div(d_in, *interleave) as u64;
                total.mux2 += signals.saturating_sub(r_ceil); // Eq. 23
                total.registers += d_in as u64; // Eq. 24 (FIFO depth)
            }
        }
        UnitPlan::Ppu { ppus, configs, .. } => {
            let unit = ppu_cost(layer.k, f_in, *configs);
            for _ in 0..*ppus {
                total.add(&unit);
            }
            if opts.include_interleaving && *configs > 1 {
                let r_ceil = pl.rated.r_in.ceil();
                total.mux2 += (d_in as u64).saturating_sub(r_ceil);
                total.registers += d_in as u64;
            }
        }
        UnitPlan::Fcu {
            fcus,
            j,
            h,
            configs,
            aggregation,
        } => {
            let unit = fcu_cost(*j, *h, *configs);
            for _ in 0..*fcus {
                total.add(&unit);
            }
            total.add(&aggregator_cost(
                ceil_div(*j, *aggregation),
                *aggregation,
            ));
            if opts.include_bias && layer.bias {
                // The FCU accumulator adds the bias as the initial partial
                // sum from the weight ROM — no extra adders, only the ROM
                // words (one bias word per neuron).
                total.rom_words += d_out as u64;
            }
        }
    }

    // Residual merge (Section VI): one adder per physical output signal.
    if pl.rated.shaped.merges {
        let i = match &pl.plan {
            UnitPlan::Kpu { interleave, .. } => *interleave,
            _ => 1,
        };
        total.adders += ceil_div(d_out, i) as u64;
    }

    total
}

/// Per-layer cost rows plus the model total.
#[derive(Debug, Clone)]
pub struct ModelCost {
    pub layers: Vec<(PlannedLayer, Resources)>,
    pub total: Resources,
}

/// Cost a full model plan.
pub fn model_cost(plans: &[PlannedLayer], opts: CostOpts) -> ModelCost {
    let layers: Vec<(PlannedLayer, Resources)> = plans
        .iter()
        .map(|p| (p.clone(), layer_cost(p, opts)))
        .collect();
    let total = Resources::sum(layers.iter().map(|(_, r)| r));
    ModelCost { layers, total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::{analyze, plan_all, Ratio};
    use crate::model::zoo;

    fn planned(model: &crate::model::Model) -> Vec<PlannedLayer> {
        plan_all(&analyze(model, None).unwrap())
    }

    #[test]
    fn kpu_cost_fig2() {
        // Fig. 2: 3x3 KPU on f=5: 9 mult, 8 add, 6 regs + 2 line buffers
        // of length 3 -> 6 + 6 = 12 registers total, no muxes.
        let r = kpu_cost(3, 5, 1);
        assert_eq!(r.multipliers, 9);
        assert_eq!(r.adders, 8);
        assert_eq!(r.registers, 3 * 2 + 2 * 3);
        assert_eq!(r.mux2, 0);
    }

    #[test]
    fn table_v_per_layer_rows() {
        let plans = planned(&zoo::running_example());
        let opts = CostOpts::FULL;
        let rows: Vec<Resources> = plans.iter().map(|p| layer_cost(p, opts)).collect();

        // C1: 200 add, 200 mul, 800 reg, 0 mux
        assert_eq!(rows[0].adders, 200);
        assert_eq!(rows[0].multipliers, 200);
        assert_eq!(rows[0].registers, 800);
        assert_eq!(rows[0].mux2, 0);
        assert_eq!(rows[0].kpus, 8);

        // P1: 200 reg, 24 MAX, 8 PPUs
        assert_eq!(rows[1].registers, 200);
        assert_eq!(rows[1].max_units, 24);
        assert_eq!(rows[1].ppus, 8);

        // C2: 816 add, 800 mul, ~6.7k reg, ~2.4k mux, 32 KPUs
        assert_eq!(rows[2].adders, 816);
        assert_eq!(rows[2].multipliers, 800);
        assert_eq!(crate::util::paper_count(rows[2].registers), "6.7k");
        assert_eq!(crate::util::paper_count(rows[2].mux2), "2.4k");
        assert_eq!(rows[2].kpus, 32);

        // P2: 416 reg, 108 mux, 32 MAX, 4 PPUs
        assert_eq!(rows[3].registers, 416 + 16); // +16 = interleave FIFO (Eq. 24)
        assert_eq!(rows[3].mux2, 108 + 12); // +12 = interleave mux (Eq. 23)
        assert_eq!(rows[3].max_units, 32);
        assert_eq!(rows[3].ppus, 4);

        // F1: 8 add, 8 mul, 10 reg, ~2.6k mux, 2 FCUs
        assert_eq!(rows[4].adders, 8);
        assert_eq!(rows[4].multipliers, 8);
        assert_eq!(rows[4].registers, 10);
        assert_eq!(crate::util::paper_count(rows[4].mux2), "2.6k");
        assert_eq!(rows[4].fcus, 2);
    }

    #[test]
    fn table_v_layer_only_matches_paper_exactly() {
        // With interleaving costs excluded (as Table V's P2/C2 cells do),
        // the exact paper numbers come out.
        let plans = planned(&zoo::running_example());
        let rows: Vec<Resources> = plans
            .iter()
            .map(|p| layer_cost(p, CostOpts { include_bias: true, include_interleaving: false }))
            .collect();
        assert_eq!(rows[2].registers, 6672);
        assert_eq!(rows[2].mux2, 2400);
        assert_eq!(rows[3].registers, 416);
        assert_eq!(rows[3].mux2, 108);
        assert_eq!(rows[4].mux2, 2552);
        let total = Resources::sum(rows.iter());
        assert_eq!(total.adders, 1024);
        assert_eq!(total.multipliers, 1008);
        assert_eq!(total.registers, 800 + 200 + 6672 + 416 + 10); // 8098
        assert_eq!(crate::util::paper_count(total.registers), "8.1k");
        assert_eq!(total.mux2, 2400 + 108 + 2552); // 5060
        assert_eq!(crate::util::paper_count(total.mux2), "5.1k");
        assert_eq!(total.max_units, 56);
        assert_eq!(total.kpus, 40);
        assert_eq!(total.fcus, 2);
        assert_eq!(total.ppus, 12);
    }

    #[test]
    fn table_vi_conv_sweep() {
        // f=28, k=7, p=3, d_in=8, d_out=16; Table VI rows.
        let expect: [(u64, u64, u64, u64, u64, u64); 9] = [
            // r_num, r_den, add, mul, reg, mux
            (8, 1, 6272, 6272, 22288, 0),
            (4, 1, 3136, 3136, 22288, 3136),
            (2, 1, 1568, 1568, 22288, 4704),
            (1, 1, 784, 784, 22288, 5488),
            (1, 2, 392, 392, 22288, 5880),
            (1, 4, 196, 196, 22288, 6076),
            (1, 8, 98, 98, 22288, 6174),
            (1, 16, 49, 49, 22288, 6223),
            (1, 32, 49, 49, 22288, 6223), // stall row
        ];
        for (num, den, add, mul, reg, mux) in expect {
            let pl = crate::report::synthetic_conv_layer(28, 7, 3, 8, 16, Ratio::new(num, den));
            let r = layer_cost(&pl, CostOpts::LAYER_ONLY);
            assert_eq!(
                (r.adders, r.multipliers, r.registers, r.mux2),
                (add, mul, reg, mux),
                "r = {num}/{den}"
            );
        }
    }

    #[test]
    fn table_vii_depthwise_separable_sweep() {
        let expect: [(u64, u64, u64, u64, u64, u64, u64, u64); 6] = [
            // r_num, r_den, add, mul, reg, mux, kpus, fcus
            (8, 1, 512, 520, 1416, 0, 8, 16),
            (4, 1, 256, 260, 1416, 260, 4, 16),
            (2, 1, 128, 130, 1416, 390, 2, 16),
            (1, 1, 64, 65, 1416, 455, 1, 16),
            (1, 2, 56, 57, 1416, 463, 1, 8),
            (1, 4, 52, 53, 1416, 467, 1, 4),
        ];
        for (num, den, add, mul, reg, mux, kpus, fcus) in expect {
            let r = crate::report::dw_separable_cost(28, 7, 3, 8, 16, Ratio::new(num, den));
            assert_eq!(
                (
                    r.adders,
                    r.multipliers,
                    r.registers,
                    r.mux2,
                    r.kpus,
                    r.fcus
                ),
                (add, mul, reg, mux, kpus, fcus),
                "r = {num}/{den}"
            );
        }
    }

    #[test]
    fn resources_sum() {
        let a = Resources {
            adders: 1,
            multipliers: 2,
            ..Default::default()
        };
        let b = Resources {
            adders: 10,
            registers: 5,
            ..Default::default()
        };
        let s = Resources::sum([&a, &b]);
        assert_eq!(s.adders, 11);
        assert_eq!(s.multipliers, 2);
        assert_eq!(s.registers, 5);
    }
}
