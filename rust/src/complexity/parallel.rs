//! Fully-parallel reference implementation cost (system S13) — the "Ref."
//! rows of Table VIII.
//!
//! The reference is the classic unrolled mapping: one hardware unit per
//! neuron/kernel, no interleaving, no reconfiguration. It is exactly this
//! crate's cost model evaluated at the *full* data rate
//! `r_{l-1} = d_{l-1}` for every layer independently — at full rate the
//! planner chooses C = 1, I = 1, `#KPUs = d_{l-1} * d_l`, one FCU per
//! neuron — so no separate formulas are needed and the two columns of
//! Table VIII are guaranteed to be comparable.

use super::{model_cost, CostOpts, ModelCost};
use crate::flow::{plan_layer, PlannedLayer, RateAnalysis, Ratio};

/// Re-plan a rate analysis with every layer forced to full input rate.
pub fn fully_parallel_plan(analysis: &RateAnalysis) -> Vec<PlannedLayer> {
    analysis
        .layers
        .iter()
        .map(|rl| {
            let mut forced = rl.clone();
            forced.r_in = Ratio::int(rl.d_in() as u64);
            forced.r_out = crate::flow::layer_rate(
                rl.d_in(),
                rl.d_out(),
                rl.shaped.layer.s,
                forced.r_in,
            );
            plan_layer(&forced)
        })
        .collect()
}

/// Cost of the fully-parallel reference for a model.
pub fn fully_parallel_cost(analysis: &RateAnalysis, opts: CostOpts) -> ModelCost {
    model_cost(&fully_parallel_plan(analysis), opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::complexity::CostOpts;
    use crate::flow::{analyze, UnitPlan};
    use crate::model::zoo;
    use crate::util::paper_count;

    #[test]
    fn reference_uses_no_reconfiguration() {
        let a = analyze(&zoo::running_example(), None).unwrap();
        for pl in fully_parallel_plan(&a) {
            assert_eq!(pl.plan.configs(), 1, "{}", pl.rated.shaped.layer.name);
            assert!(!pl.plan.stalled());
        }
    }

    #[test]
    fn running_example_ref_matches_table_viii() {
        // Table VIII "Running example / Ref.": Add 6.0k, Mul 6.0k,
        // Reg 8.1k, MUX 0, 136 KPUs, 10 FCUs.
        let a = analyze(&zoo::running_example(), None).unwrap();
        let cost = fully_parallel_cost(&a, CostOpts::FULL);
        assert_eq!(paper_count(cost.total.adders), "6.0k");
        assert_eq!(paper_count(cost.total.multipliers), "6.0k");
        assert_eq!(paper_count(cost.total.registers), "8.1k");
        assert_eq!(cost.total.mux2, 0);
        assert_eq!(cost.total.kpus, 136);
        assert_eq!(cost.total.fcus, 10);
    }

    #[test]
    fn conv_reference_is_one_kpu_per_kernel() {
        let a = analyze(&zoo::running_example(), None).unwrap();
        let plans = fully_parallel_plan(&a);
        // C2: d_in=8, d_out=16 -> 128 KPUs.
        match &plans[2].plan {
            UnitPlan::Kpu { kpus, configs, .. } => {
                assert_eq!(*kpus, 128);
                assert_eq!(*configs, 1);
            }
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn dense_reference_is_one_fcu_per_neuron() {
        let a = analyze(&zoo::jsc_mlp(), None).unwrap();
        let plans = fully_parallel_plan(&a);
        match &plans[0].plan {
            UnitPlan::Fcu { fcus, j, h, .. } => {
                assert_eq!(*fcus, 16);
                assert_eq!(*j, 16);
                assert_eq!(*h, 1);
            }
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn mobilenet_ref_unit_counts_match_table_viii() {
        // Table VIII MobileNet a=0.25 Ref.: 1.5k KPUs, 2.5k FCUs,
        // 476k multipliers, 475k adders.
        let a = analyze(&zoo::mobilenet_v1(25), None).unwrap();
        let cost = fully_parallel_cost(&a, CostOpts::FULL);
        assert_eq!(paper_count(cost.total.kpus), "1.5k");
        assert_eq!(paper_count(cost.total.fcus), "2.5k");
        assert_eq!(paper_count(cost.total.multipliers), "476k");
        // Adders land within a percent of the paper's 475k (rounding of
        // bias/accumulation conventions).
        let add = cost.total.adders as f64;
        assert!((add - 475_000.0).abs() / 475_000.0 < 0.02, "adders {add}");
    }

    #[test]
    fn ours_never_exceeds_reference() {
        // The continuous-flow plan must use <= arithmetic of the reference
        // for every zoo model at full input rate.
        for m in zoo::all_models() {
            let a = analyze(&m, None).unwrap();
            let ours = crate::complexity::model_cost(
                &crate::flow::plan_all(&a),
                CostOpts::FULL,
            );
            let r = fully_parallel_cost(&a, CostOpts::FULL);
            assert!(
                ours.total.multipliers <= r.total.multipliers,
                "{}: ours {} > ref {}",
                m.name,
                ours.total.multipliers,
                r.total.multipliers
            );
            assert!(ours.total.adders <= r.total.adders, "{}", m.name);
        }
    }
}
